"""LM data-plane end-to-end driver: train a reduced qwen3 for a few hundred
steps on the synthetic stream with checkpoint/restart and (optionally) the
compressed data-parallel sync.

  PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import argparse

import jax

from repro.configs.base import get_config, reduced
from repro.data.pipeline import for_arch
from repro.models import transformer
from repro.models.steps import make_train_step
from repro.runtime.checkpoint import CheckpointManager
from repro.runtime.resilience import StragglerMonitor


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="/tmp/lm_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    cfg = reduced(get_config("qwen3-0.6b"), n_layers=4, d_model=128,
                  d_ff=256, n_heads=4, n_kv=2, head_dim=32, vocab=512)
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    print(f"model: {transformer.param_count(params)/1e6:.2f}M params")

    stream = for_arch(cfg, batch=8, seq=64)
    opt_init, train_step = make_train_step(cfg, lr=1e-3, microbatches=2)
    opt = opt_init(params)
    step_fn = jax.jit(train_step)

    mgr = CheckpointManager(args.ckpt_dir, keep=2)
    mon = StragglerMonitor(threshold=3.0)
    start = 0
    if mgr.latest_step() is not None:
        (params, opt), manifest = mgr.restore((params, opt))
        start = manifest["step"]
        print(f"[restore] resumed at step {start}")

    for step in range(start, args.steps):
        mon.start_step(step)
        params, opt, metrics = step_fn(params, opt, stream.get_batch(step))
        slow = mon.end_step()
        if step % 25 == 0 or step == args.steps - 1:
            # logging-cadence sync (every 25th step), not per-step
            print(f"step {step:4d} loss {float(metrics['loss']):.4f}"  # reprolint: ignore[host-sync]
                  + ("  [straggler]" if slow else ""))
        if (step + 1) % args.ckpt_every == 0:
            mgr.save(step + 1, (params, opt), extra={"data_step": step + 1})
    mgr.wait()
    print(f"done; checkpoints in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
