"""Scenario-registry demo: a whole Fig. 4 arrival-rate sweep plus a
multi-cell grid, evaluated as single batched programs.

  PYTHONPATH=src python examples/scenario_sweep.py

Instead of looping `paper_env(...)` per rate (scripts/train_compare.py's
seed-era pattern), every (cell, rate) configuration becomes one cell of a
``ScenarioGrid`` and all cells advance together under one jitted lax.scan.

To see the grid sharded across devices (on CPU, forced host devices):

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python examples/scenario_sweep.py
"""
import jax
import numpy as np

from repro.core.lymdo import run_fixed_batched
from repro.core.scenarios import (ScenarioGrid, describe, grid_from_names,
                                  multicell_grid)
from repro.launch.mesh import make_cells_mesh


def main():
    print("registered scenarios:")
    print(describe(), "\n")

    # -- Fig. 4 sweep: five fixed-rate cells, one program -------------------
    rates = (0.5, 1.0, 1.5, 2.0, 2.5)
    grid = grid_from_names([("fixed_rate", {"rate": r}) for r in rates])
    for policy in ("oracle", "local", "edge"):
        metrics, _ = run_fixed_batched(grid, policy, episodes=3, steps=200)
        row = " ".join(f"@{r:g}:{d*1e3:6.1f}ms"
                       for r, d in zip(rates, metrics["delay"]))
        print(f"{policy:>7s} E2E delay  {row}")

    # -- 16-cell heterogeneous grid under the batched Oracle ----------------
    grid = ScenarioGrid(multicell_grid(cells=16, ues=8, seed=0))
    metrics, results = run_fixed_batched(grid, "oracle", episodes=1,
                                         steps=200)
    delays = np.asarray(metrics["delay"])
    print(f"\n16-cell grid, oracle: mean delay {delays.mean()*1e3:.1f} ms "
          f"(best cell {delays.min()*1e3:.1f}, worst {delays.max()*1e3:.1f}); "
          f"results stacked {results.delay.shape} = (slots, cells, UEs)")

    # -- the same grid sharded over the device mesh -------------------------
    # With one device this is a degenerate 1-way mesh; under forced host
    # devices (see module docstring) the cells split across all of them.
    # Either way the numbers match the unsharded run to 1e-5.
    n_dev = len(jax.devices())
    sharded = ScenarioGrid(multicell_grid(cells=16, ues=8, seed=0),
                           mesh=make_cells_mesh())
    m_sh, _ = run_fixed_batched(sharded, "oracle", episodes=1, steps=200)
    drift = float(np.max(np.abs(np.asarray(m_sh["delay"]) - delays)))
    print(f"sharded over {n_dev} device(s) "
          f"(pad {sharded.gridshard.pad} cells): "
          f"max |delay drift| vs unsharded = {drift:.2e}")


if __name__ == "__main__":
    main()
