"""Scenario-registry demo: a whole Fig. 4 arrival-rate sweep plus a
multi-cell grid, evaluated as single batched programs.

  PYTHONPATH=src python examples/scenario_sweep.py

Instead of looping `paper_env(...)` per rate (scripts/train_compare.py's
seed-era pattern), every (cell, rate) configuration becomes one cell of a
``ScenarioGrid`` and all cells advance together under one jitted lax.scan.
"""
import numpy as np

from repro.core.lymdo import run_fixed_batched
from repro.core.scenarios import (ScenarioGrid, describe, grid_from_names,
                                  multicell_grid)


def main():
    print("registered scenarios:")
    print(describe(), "\n")

    # -- Fig. 4 sweep: five fixed-rate cells, one program -------------------
    rates = (0.5, 1.0, 1.5, 2.0, 2.5)
    grid = grid_from_names([("fixed_rate", {"rate": r}) for r in rates])
    for policy in ("oracle", "local", "edge"):
        metrics, _ = run_fixed_batched(grid, policy, episodes=3, steps=200)
        row = " ".join(f"@{r:g}:{d*1e3:6.1f}ms"
                       for r, d in zip(rates, metrics["delay"]))
        print(f"{policy:>7s} E2E delay  {row}")

    # -- 16-cell heterogeneous grid under the batched Oracle ----------------
    grid = ScenarioGrid(multicell_grid(cells=16, ues=8, seed=0))
    metrics, results = run_fixed_batched(grid, "oracle", episodes=1,
                                         steps=200)
    delays = np.asarray(metrics["delay"])
    print(f"\n16-cell grid, oracle: mean delay {delays.mean()*1e3:.1f} ms "
          f"(best cell {delays.min()*1e3:.1f}, worst {delays.max()*1e3:.1f}); "
          f"results stacked {results.delay.shape} = (slots, cells, UEs)")


if __name__ == "__main__":
    main()
