"""Quickstart: the paper's system in ~60 seconds on CPU.

Builds the Sec. V-A scenario (5 UEs: 2x AlexNet + 3x ResNet18), trains the
LyMDO controller briefly, and compares it against the paper's baselines.

  PYTHONPATH=src python examples/quickstart.py
"""
from repro.core.env import MecConfig, LAM_FIXED, paper_env
from repro.core.lymdo import (Runner, RunConfig, edge_cut_fn, local_cut_fn,
                              oracle_cut_fn, random_cut_fn, run_fixed)
from repro.core.policies import CategoricalPolicy
from repro.core.ppo import PPO, PPOConfig


def main():
    env = paper_env()
    print(f"MEC scenario: {env.n_ue} UEs, profiles "
          f"{[p.name for p in env.batch.profiles]}")

    agent = PPO(CategoricalPolicy(env.obs_dim, env.L), env.obs_dim, PPOConfig())
    runner = Runner(env, agent, steps=200)
    print("\ntraining LyMDO (60 episodes)...")
    state, hist = runner.train(RunConfig(episodes=60, steps=200, chunk=20))

    eval_env = paper_env(MecConfig(lam_mode=LAM_FIXED))   # lam = 2.5 req/s
    metrics, _ = Runner(eval_env, agent, steps=200).evaluate(state, episodes=3)
    print(f"\nLyMDO   @2.5req/s: delay {metrics['delay']*1e3:7.1f} ms  "
          f"energy {metrics['energy']*1e3:5.1f} mJ  reward {metrics['reward']:8.2f}")

    for name, fn in [("Local", local_cut_fn(eval_env)),
                     ("Edge", edge_cut_fn(eval_env)),
                     ("Random", random_cut_fn(eval_env)),
                     ("Oracle", oracle_cut_fn(eval_env))]:
        m, _ = run_fixed(eval_env, fn, episodes=3, steps=200)
        print(f"{name:7s} @2.5req/s: delay {m['delay']*1e3:7.1f} ms  "
              f"energy {m['energy']*1e3:5.1f} mJ  reward {m['reward']:8.2f}")


if __name__ == "__main__":
    main()
