"""Partitioned LM serving demo (deliverable b, serving flavor).

The paper's full loop on an LM workload: the LyMDO controller watches the
per-slot MEC state (channels, arrivals, virtual queues) over the *LM layer
profile* and picks the partition cut; a PartitionedLM executes the split
(UE half / ES half) on a reduced qwen3 config; the ES side also demos the
batched continuous-batching engine.

  PYTHONPATH=src python examples/serve_partitioned.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config, reduced
from repro.core import sweep
from repro.core.env import MecConfig, MecEnv
from repro.models import transformer
from repro.profiling.lmprofiles import lm_profile
from repro.serving.engine import Request, ServingEngine
from repro.serving.partitioned import PartitionedLM, layer_cut_to_unit


def main():
    cfg_full = get_config("qwen3-0.6b")
    cfg = reduced(cfg_full, n_layers=8)          # 8 layers -> 8 units
    key = jax.random.PRNGKey(0)
    k_params, k_env, k_tokens = jax.random.split(key, 3)
    params = transformer.init_params(k_params, cfg)

    # -- LyMDO controller over the FULL arch's layer profile ---------------
    profile = lm_profile(cfg_full, prompt_tokens=64)
    n_clients = 3
    env = MecEnv([profile] * n_clients,
                 MecConfig(f_max_ue=4e9, f_max_es=100e9),
                 e_budget=[0.5] * n_clients, c_budget=[1.5] * n_clients)
    st = env.reset(k_env)
    print(f"controller over {profile.name}: L={profile.num_layers} "
          f"logical layers")
    for slot in range(3):
        cut = sweep.oracle_cut(env, st)              # per-slot decision
        st, res = env.step(st, cut)
        print(f" slot {slot}: cuts={np.asarray(res.cut).tolist()} "
              f"delay={np.asarray(res.delay).round(3).tolist()} s")

    # -- execute the split on the reduced model ----------------------------
    layer_cut = int(np.asarray(res.cut)[0])
    unit_cut = layer_cut_to_unit(cfg, min(layer_cut, cfg.n_layers + 1))
    plm = PartitionedLM(cfg, params, unit_cut)
    tokens = jax.random.randint(k_tokens, (2, 16), 0, cfg.vocab)
    logits, boundary = plm.infer(tokens)
    ref_logits, _ = transformer.forward_train(params, cfg, {"tokens": tokens})
    err = float(jnp.max(jnp.abs(logits - ref_logits)))
    print(f"\npartitioned execution at unit {unit_cut}/{cfg.n_units}: "
          f"boundary={plm.boundary_bytes(2, 16)} B, "
          f"max|split - monolithic| = {err:.2e}")

    # -- ES-side batched serving engine -------------------------------------
    eng = ServingEngine(cfg, params, slots=2, s_max=64)
    rng = np.random.default_rng(0)
    for rid in range(4):
        eng.submit(Request(rid=rid,
                           prompt=rng.integers(0, cfg.vocab, 12).astype(np.int32),
                           max_new=8))
    steps = 0
    while eng.step():
        steps += 1
    print(f"\nserving engine: 4 requests finished in {steps} engine steps "
          f"(2 slots, continuous batching)")


if __name__ == "__main__":
    main()
