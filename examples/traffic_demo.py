"""Traffic-subsystem tour: generators, trace record/replay, batched grids.

  PYTHONPATH=src python examples/traffic_demo.py

Walks the full serving->trace->MEC loop in four steps:

1. sample the arrival-process catalogue (repro.traffic.processes);
2. serve prompts on a ServingEngine with a TrafficRecorder attached;
3. bin the recorded lifecycle into a canonical (T, N) trace, save/load it;
4. replay the trace as the arrival process of a 16-cell batched
   ScenarioGrid rollout (each cell a de-phased rotation of the recording).

See docs/traffic.md for the subsystem reference and
benchmarks/traffic_replay.py for the measured batched-vs-loop speedup.
"""
import tempfile
from pathlib import Path

import jax
import numpy as np

from repro import traffic
from repro.core.lymdo import run_fixed_batched
from repro.core.scenarios import ScenarioGrid, make


def show_generators():
    print("== arrival-process catalogue ==")
    print(traffic.processes.describe(), "\n")
    n = 4
    procs = {
        "poisson": traffic.PoissonArrivals(lam=traffic.per_ue(2.0, n),
                                           slot_s=np.float32(1.0)),
        "mmpp": traffic.make_mmpp(n, seed=0, rates=(0.5, 3.0)),
        "diurnal": traffic.Diurnal(base=traffic.per_ue(1.5, n),
                                   amp=traffic.per_ue(1.0, n),
                                   period=np.float32(100.0),
                                   phase=np.float32(0.0)),
        "flash_crowd": traffic.FlashCrowd(base=traffic.per_ue(1.0, n),
                                          spike=np.float32(3.0),
                                          t0=np.int32(40),
                                          decay=np.float32(15.0)),
    }
    for name, proc in procs.items():
        rates = traffic.materialize(proc, 120, jax.random.PRNGKey(1))
        print(f"  {name:12s} mean {rates.mean():.2f} req/s, "
              f"peak {rates.max():.2f}, trough {rates.min():.2f}")
    print()


def record_trace(n_ue: int = 4):
    print("== record: ServingEngine + TrafficRecorder ==")
    from repro.configs.base import get_config, reduced
    from repro.models import transformer
    from repro.serving.engine import Request, ServingEngine

    cfg = reduced(get_config("qwen3-0.6b"), n_layers=4)
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    rec = traffic.TrafficRecorder()
    eng = ServingEngine(cfg, params, slots=2, s_max=32, recorder=rec)

    rng = np.random.default_rng(0)
    rid = 0
    for tick in range(60):
        lam = 0.9 if 20 <= tick < 40 else 0.3       # mid-run burst
        for _ in range(rng.poisson(lam)):
            eng.submit(Request(rid=rid,
                               prompt=rng.integers(0, cfg.vocab, 6)
                               .astype(np.int32),
                               max_new=2, ue=rid % n_ue))
            rid += 1
        eng.step()
    eng.run_until_idle()
    waits = [ev.queueing_ticks for ev in rec.events.values()]
    print(f"  served {rid} requests; prefill compiled "
          f"{eng.prefill_compiles}x (bucketed); mean queueing wait "
          f"{np.mean(waits):.1f} ticks")
    trace = rec.to_trace(n_ue=n_ue, bin_ticks=2, slot_s=1.0, horizon=30)
    print(f"  trace: T={trace.n_slots} x N={trace.n_ue}, "
          f"mean {trace.rates.mean():.2f} req/s, "
          f"peak {trace.rates.max():.2f} req/s\n")
    return trace


def replay(trace, cells: int = 16, steps: int = 60):
    print(f"== replay: {cells}-cell batched grid under the recorded load ==")
    with tempfile.TemporaryDirectory() as d:
        path = Path(d) / "serving_trace.npz"
        trace.save(path)                             # the on-disk round trip
        grid = ScenarioGrid([make("trace_replay", path=str(path),
                                  offset=2 * b, seed=b)
                             for b in range(cells)])
    metrics, results = run_fixed_batched(grid, "oracle", episodes=1,
                                         steps=steps)
    print(f"  per-cell mean delay  : {np.mean(metrics['delay']):.4f} s "
          f"(spread {np.min(metrics['delay']):.4f}.."
          f"{np.max(metrics['delay']):.4f})")
    print(f"  per-cell mean reward : {np.mean(metrics['reward']):.3f}")
    print(f"  results stack        : reward {results.reward.shape} "
          f"(steps, B), delay {results.delay.shape} (steps, B, N)")


def main():
    show_generators()
    trace = record_trace()
    replay(trace)
    print("\nDone.  benchmarks/traffic_replay.py measures this same loop; "
          "docs/traffic.md documents it.")


if __name__ == "__main__":
    main()
