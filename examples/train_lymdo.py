"""End-to-end LyMDO training driver (deliverable b): trains the DRL
controller for a few hundred episodes with fault-tolerant checkpointing --
kill the process mid-run and rerun: it resumes from the last checkpoint.

  PYTHONPATH=src python examples/train_lymdo.py --episodes 300
"""
import argparse

import jax
import numpy as np

from repro.core.env import MecConfig, LAM_FIXED, paper_env
from repro.core.lymdo import Runner
from repro.core.policies import GaussianTanhPolicy
from repro.core.ppo import PPO, PPOConfig
from repro.runtime.checkpoint import CheckpointManager


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--episodes", type=int, default=300)
    ap.add_argument("--chunk", type=int, default=25)
    ap.add_argument("--ckpt-dir", default="/tmp/lymdo_ckpt")
    args = ap.parse_args()

    env = paper_env()
    agent = PPO(GaussianTanhPolicy(env.obs_dim, env.L), env.obs_dim,
                PPOConfig())
    runner = Runner(env, agent, steps=200)
    mgr = CheckpointManager(args.ckpt_dir, keep=2)

    key = jax.random.PRNGKey(0)
    key, k_init = jax.random.split(key)
    state = agent.init(k_init)
    start = 0
    latest = mgr.latest_step()
    if latest is not None:
        restored, manifest = mgr.restore(state)
        state = type(state)(*restored) if isinstance(restored, tuple) \
            else restored
        start = manifest["step"]
        print(f"[restore] resumed from episode {start}")

    done = start
    while done < args.episodes:
        n = min(args.chunk, args.episodes - done)
        key = jax.random.fold_in(jax.random.PRNGKey(0), done)
        state, metrics = runner._train_chunk(state, key, n=n)
        done += n
        print(f"ep {done:4d}/{args.episodes} "
              f"reward {float(np.asarray(metrics['reward'])[-1]):9.2f} "
              f"delay {float(np.asarray(metrics['delay'])[-1])*1e3:7.1f} ms")
        mgr.save(done, state, extra={"episodes": done})
    mgr.wait()

    eval_env = paper_env(MecConfig(lam_mode=LAM_FIXED))
    m, _ = Runner(eval_env, agent, steps=200).evaluate(state, episodes=5)
    print(f"\nfinal eval @2.5 req/s: delay {m['delay']*1e3:.1f} ms, "
          f"reward {m['reward']:.2f} (checkpoints in {args.ckpt_dir})")


if __name__ == "__main__":
    main()
