"""Traffic-replay benchmark: the serving->trace->MEC loop, measured.

  PYTHONPATH=src python -m benchmarks.traffic_replay --cells 16

Pipeline (the tentpole demo of the traffic subsystem):

1. **Record** -- drive a small ServingEngine under a bursty submission
   schedule with a ``TrafficRecorder`` attached; bin the submit events into
   the canonical slot-indexed (T, N) arrival trace (``--source mmpp`` skips
   the engine and materializes an MMPP process instead -- faster, pure-MEC).
2. **Replay** -- build B ``trace_replay`` cells (each a de-phased rotation
   of the trace) and evaluate them two ways over the same slots:

   * batched -- ``ScenarioGrid.make_rollout``: one jitted vmap+scan program;
   * loop    -- one jitted single-cell episode re-dispatched per cell, with
     the grid's own fold_in key discipline so both legs draw identical
     randomness.

3. **Check + measure** -- per-cell mean rewards must agree to 1e-5
   (batched==looped parity), then slots/sec and the batched-over-loop
   speedup are reported.  CSV rows follow the benchmarks/run.py convention.

``--devices N`` adds a cells-sharded replay leg over N forced host devices,
and ``--model M`` makes it the 2-D ``("cells", "model")`` mesh (N/M cell
shards x M-way per-cell tensor parallelism); layout preconditions are
validated up front, as in benchmarks/scenario_grid.py.

``--gate 0`` (default) is informational; pass a positive speedup bar to get
a nonzero exit code below it (CI runs the informational mode -- the hard 5x
bar lives in benchmarks/scenario_grid.py where the grid is larger).
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np


def _sync(tree):
    jax.block_until_ready(tree)


def record_serving_trace(n_ue: int, ticks: int = 60, seed: int = 0,
                         engine: str = "continuous"):
    """Drive a tiny ServingEngine under a bursty schedule; bin the submits.

    ``engine`` picks the serving mode: ``"continuous"`` (default -- per-tick
    admission over the paged KV pool) or ``"sync"`` (the synchronized-batch
    compat mode; benchmarks/serving_latency.py A/Bs the two head-to-head).
    """
    from repro import traffic
    from repro.configs.base import get_config, reduced
    from repro.models import transformer
    from repro.serving.engine import Request, ServingEngine

    cfg = reduced(get_config("qwen3-0.6b"), n_layers=4)
    params = transformer.init_params(jax.random.PRNGKey(seed), cfg)
    rec = traffic.TrafficRecorder()
    eng = ServingEngine(cfg, params, slots=2, s_max=32, recorder=rec,
                        sync_batching=(engine == "sync"))

    rng = np.random.default_rng(seed)
    rid = 0
    for tick in range(ticks):
        # bursty: quiet baseline with a 3x surge in the middle third
        lam = 0.9 if ticks // 3 <= tick < 2 * ticks // 3 else 0.3
        for _ in range(rng.poisson(lam)):
            eng.submit(Request(rid=rid,
                               prompt=rng.integers(0, cfg.vocab, 6)
                               .astype(np.int32),
                               max_new=2, ue=rid % n_ue))
            rid += 1
        eng.step()
    eng.run_until_idle()
    trace = rec.to_trace(n_ue=n_ue, bin_ticks=2, slot_s=1.0,
                         horizon=ticks // 2)
    lat = rec.latency_stats()
    print(f"recorded {rid} requests over {eng.clock} engine ticks "
          f"({engine} engine, p50/p99 E2E "
          f"{lat.get('p50', 0):.0f}/{lat.get('p99', 0):.0f} ticks) -> "
          f"trace T={trace.n_slots} x N={trace.n_ue}, "
          f"mean {trace.rates.mean():.2f} req/s, "
          f"peak {trace.rates.max():.2f} req/s")
    return trace


def mmpp_trace(n_ue: int, horizon: int = 200, seed: int = 0):
    from repro import traffic
    proc = traffic.make_mmpp(n_ue, seed=seed, rates=(0.5, 3.0),
                             horizon=horizon)
    return traffic.from_process(proc, horizon)


def build_grid(trace, cells: int, seed: int):
    from repro.core.scenarios import ScenarioGrid, make
    stride = max(1, trace.n_slots // cells)
    return ScenarioGrid([make("trace_replay", trace=trace,
                              offset=b * stride, seed=seed + b)
                         for b in range(cells)])


def bench_batched(grid, policy: str, steps: int, repeats: int):
    fn = grid.make_rollout(policy, steps)
    key = jax.random.PRNGKey(0)
    _, _, summary = jax.block_until_ready(fn(key))        # compile
    _sync(fn(key))                                        # reprolint: ignore[key-reuse] (warm: same program on purpose)
    best = float("inf")
    for r in range(repeats):
        t0 = time.perf_counter()
        _sync(fn(jax.random.fold_in(key, r)))
        best = min(best, time.perf_counter() - t0)
    return best, grid.b * steps / best, summary


def bench_loop(grid, policy: str, steps: int, repeats: int):
    """Per-cell loop with the SAME randomness as the batched rollout: reset
    keys come from gridshard.cell_keys(k0, b), exactly as grid.reset does."""
    from repro.core import gridshard, sweep
    from repro.core.env import reset_p, step_p
    from repro.core.scenarios import POLICIES

    oracle = policy == "oracle"
    act = None if oracle else POLICIES[policy]

    @jax.jit
    def episode(params, k0):
        st0 = reset_p(params, k0)

        def body(carry, _):
            st, k = carry
            k, k_act = jax.random.split(k)
            cut = (sweep.oracle_cut_p(params, st) if oracle
                   else act(params, st, k_act))
            st2, res = step_p(params, st, cut)
            return (st2, k), res.reward
        (_, _), rewards = jax.lax.scan(body, (st0, k0), None, length=steps)
        return rewards

    cell_params = [jax.tree.map(lambda x, b=b: x[b], grid.params)
                   for b in range(grid.b)]
    key, k0 = jax.random.split(jax.random.PRNGKey(0))
    cell_keys = gridshard.cell_keys(k0, grid.b)
    _sync(episode(cell_params[0], cell_keys[0]))          # compile
    _sync(episode(cell_params[0], cell_keys[0]))          # warm
    rewards = [np.asarray(episode(p, k))
               for p, k in zip(cell_params, cell_keys)]
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        for p, k in zip(cell_params, cell_keys):
            _sync(episode(p, k))
        best = min(best, time.perf_counter() - t0)
    return best, grid.b * steps / best, np.stack(rewards)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--cells", type=int, default=16)
    ap.add_argument("--ues", type=int, default=4)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--policy", default="oracle",
                    choices=("oracle", "local", "edge"))
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--source", default="serving",
                    choices=("serving", "mmpp"),
                    help="record the trace from a live ServingEngine run "
                         "(the full loop) or materialize an MMPP process")
    ap.add_argument("--engine", default="continuous",
                    choices=("continuous", "sync"),
                    help="serving mode for --source serving: continuous "
                         "batching (paged KV) or the synchronized-batch "
                         "compat mode")
    ap.add_argument("--save-trace", default=None, metavar="NPZ",
                    help="also save the recorded trace for reuse "
                         "(python -m repro.traffic --show NPZ)")
    ap.add_argument("--devices", type=int, default=0,
                    help="also run a cells-sharded replay leg over this "
                         "many (forced host) devices")
    ap.add_argument("--model", type=int, default=1,
                    help="per-cell tensor-parallel degree for the sharded "
                         "leg (('cells','model') mesh; must divide "
                         "--devices)")
    ap.add_argument("--gate", type=float, default=0.0,
                    help="min batched-over-loop speedup for exit code 0 "
                         "(0 = informational)")
    args = ap.parse_args(argv)

    from benchmarks._sharded import (backend_ready, force_devices, leg_tag,
                                     validate_mesh_args)
    err = validate_mesh_args(args.devices, args.model)
    if err:
        print(f"error: {err}")
        return 2
    if args.devices:
        force_devices(args.devices)   # before jax initializes its backend

    trace = (record_serving_trace(args.ues, seed=args.seed,
                                  engine=args.engine)
             if args.source == "serving"
             else mmpp_trace(args.ues, seed=args.seed))
    if args.save_trace:
        trace.save(args.save_trace)
        print(f"trace saved to {args.save_trace}")

    grid = build_grid(trace, args.cells, args.seed)
    print(f"replay grid: B={grid.b} cells x N={grid.n_ue} UEs, "
          f"{args.steps} slots, policy={args.policy}, "
          f"backend={jax.default_backend()}")

    print("name,us_per_call,derived")
    dt_b, sps_b, summary = bench_batched(grid, args.policy, args.steps,
                                         args.repeats)
    print(f"traffic_replay_batched[{grid.b}x{grid.n_ue}],{dt_b*1e6:.0f},"
          f"slots_per_s={sps_b:.0f}")
    dt_l, sps_l, loop_rewards = bench_loop(grid, args.policy, args.steps,
                                           args.repeats)
    print(f"traffic_replay_loop[{grid.b}x{grid.n_ue}],{dt_l*1e6:.0f},"
          f"slots_per_s={sps_l:.0f}")

    if args.devices:
        tag = leg_tag(args.devices, args.model)
        if not backend_ready(args.devices):
            print(f"traffic_replay_sharded[{grid.b}x{grid.n_ue}"
                  f"{tag}],0,SKIPPED_backend_already_initialized")
        else:
            from repro.launch.mesh import make_cells_mesh
            grid_sh = build_grid(trace, args.cells, args.seed)
            grid_sh.use_mesh(make_cells_mesh(args.devices,
                                             model=args.model))
            dt_s, sps_s, sum_s = bench_batched(grid_sh, args.policy,
                                               args.steps, args.repeats)
            err_s = float(np.max(np.abs(
                np.asarray(sum_s["reward"]) - np.asarray(summary["reward"]))
                / np.maximum(np.abs(np.asarray(summary["reward"])), 1e-7)))
            print(f"traffic_replay_sharded[{grid.b}x{grid.n_ue}{tag}],"
                  f"{dt_s*1e6:.0f},slots_per_s={sps_s:.0f}")
            print(f"traffic_replay_sharded_parity[{grid.b}x{grid.n_ue}"
                  f"{tag}],0,max_rel_err={err_s:.2e}"
                  f"_{'OK' if err_s < 1e-5 else 'FAIL'}")
            if err_s >= 1e-5:
                print("PARITY FAILURE: sharded and batched replays diverged")
                return 1

    # batched == looped parity on per-cell mean reward (identical keys)
    batched = np.asarray(summary["reward"])
    looped = loop_rewards.mean(axis=1)
    err = float(np.max(np.abs(batched - looped)
                       / np.maximum(np.abs(looped), 1e-7)))
    ok_parity = err < 1e-5
    print(f"traffic_replay_parity[{grid.b}x{grid.n_ue}],0,"
          f"max_rel_err={err:.2e}_{'OK' if ok_parity else 'FAIL'}")

    speedup = sps_b / sps_l
    print(f"traffic_replay_speedup[{grid.b}x{grid.n_ue}],0,"
          f"batched_over_loop={speedup:.1f}x")
    if not ok_parity:
        print("PARITY FAILURE: batched and looped rollouts diverged")
        return 1
    if args.gate <= 0:
        print(f"speedup: {speedup:.1f}x (gate disabled)")
        return 0
    ok = speedup >= args.gate
    print(f"speedup: {speedup:.1f}x "
          f"({'meets' if ok else 'BELOW'} the {args.gate:g}x bar)")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
