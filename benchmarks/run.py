"""Benchmark harness entry point (deliverable d): one experiment per paper
figure + kernel micro-benchmarks + the serving-engine A/B + the batched
scenario-grid A/B + the roofline table.

Prints ``name,us_per_call,derived`` CSV per experiment, as required, and
writes the canonical ``BENCH_N.json`` perf-trajectory artifact at the repo
root (currently ``BENCH_10.json``), which folds together:

* ``serving``       -- continuous-vs-sync replay latency, goodput,
                       slot-steps/sec, prefill-compile counts
                       (benchmarks/serving_latency.py, the old BENCH_6 body)
* ``chunked_prefill`` -- long-prompt flash-crowd A/B: chunked vs
                       whole-prompt admission, identical tokens asserted,
                       per-tick wall p50/p99
                       (benchmarks/serving_latency.chunked_prefill_ab)
* ``scenario_grid`` -- batched-vs-loop grid rollout throughput + speedup
                       (benchmarks/scenario_grid.bench_payload)
* ``kernels``       -- the kernel micro-benchmark rows
                       (benchmarks/kernels_micro.bench_all)
* ``sanitize_overhead`` -- per-tick p50 with the KV-pool sanitizer off vs
                       on, identical schedule, identical tokens
                       (benchmarks/serving_latency.sanitize_overhead)

``--json-only`` skips the slow paper-figure / ablation / roofline legs and
just measures + writes the JSON artifact (the CI bench leg uses this).
"""
from __future__ import annotations

import argparse
import json
import os
import time


def _row(name, us, derived):
    print(f"{name},{us:.1f},{derived}")


def build_bench_payload(*, grid_cells: int = 8, grid_ues: int = 4,
                        grid_steps: int = 24, grid_repeats: int = 2) -> dict:
    """Measure the five tracked subsystems and assemble the BENCH_10 body."""
    from . import kernels_micro, scenario_grid, serving_latency
    serving = serving_latency.bench_all()
    chunked = serving_latency.chunked_prefill_ab()
    kernels = [{"name": name, "us_per_call": round(us, 1), "derived": derived}
               for name, us, derived in kernels_micro.bench_all()]
    grid = scenario_grid.bench_payload(cells=grid_cells, ues=grid_ues,
                                       steps=grid_steps,
                                       repeats=grid_repeats)
    sanitize = serving_latency.sanitize_overhead()
    return {"bench": 10, "serving": serving, "chunked_prefill": chunked,
            "scenario_grid": grid, "kernels": kernels,
            "sanitize_overhead": sanitize}


def _emit_bench_rows(payload: dict) -> None:
    """Print the payload's measurements in the harness CSV convention."""
    from . import serving_latency
    for k in payload["kernels"]:
        _row(f"kernel[{k['name']}]", k["us_per_call"], k["derived"])
    for name, us, derived in serving_latency.rows(payload["serving"]):
        _row(name, us, derived)
    for name, us, derived in serving_latency.chunked_rows(
            payload["chunked_prefill"]):
        _row(name, us, derived)
    g = payload["scenario_grid"]
    shape = f"{g['config']['cells']}x{g['config']['ues']}"
    _row(f"scenario_grid[{shape}]", g["batched"]["best_seconds"] * 1e6,
         f"batched_slots_per_s={g['batched']['slots_per_s']:.0f}"
         f";loop_slots_per_s={g['loop']['slots_per_s']:.0f}"
         f";speedup={g['batched_speedup']:.2f}x")
    s = payload["sanitize_overhead"]
    _row("sanitize_overhead", s["p50_tick_us"]["off"],
         f"on_p50_us={s['p50_tick_us']['on']:.1f}"
         f";on_over_off={s['on_over_off']:.2f}x"
         f";outputs_match={'OK' if s['outputs_match'] else 'FAIL'}")


def _write_bench_json(payload: dict) -> None:
    bench_path = os.path.join(os.path.dirname(__file__), "..",
                              "BENCH_10.json")
    with open(bench_path, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
    _row("bench_json", 0.0, f"wrote={os.path.normpath(bench_path)}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json-only", action="store_true",
                    help="measure and write BENCH_10.json only (skips the "
                         "paper-figure, ablation, and roofline legs)")
    args = ap.parse_args(argv)

    t_start = time.time()
    print("name,us_per_call,derived")

    if args.json_only:
        payload = build_bench_payload()
        _emit_bench_rows(payload)
        _write_bench_json(payload)
        _row("bench_total", (time.time() - t_start) * 1e6,
             "seconds=%.1f" % (time.time() - t_start))
        return 0

    # -- paper figures -------------------------------------------------------
    from . import paper_figs
    t0 = time.time()
    art = paper_figs.load_or_build()
    build_us = (time.time() - t0) * 1e6

    for r in paper_figs.fig3_convergence(art):
        _row(f"fig3_convergence[{r['algo']}]",
             r["train_s"] * 1e6 / max(art["episodes"], 1),
             f"final_reward={r['reward_last10pct']:.2f};conv_ep={r['convergence_episode']}")
    for r in paper_figs.fig4_rate_sweep(art):
        _row(f"fig4[{r['algo']}@{r['rate']}]", 0.0,
             f"delay={r['delay_s']:.4f}s;energy={r['energy_J']*1e3:.1f}mJ;"
             f"mem={r['mem_GB']*1e3:.0f}MB;qE={r['q_energy_final']:.1f}")
    for r in paper_figs.fig5_queue_stability(art):
        _row(f"fig5[{r['task']}:{r['algo']}]", 0.0,
             f"peak_queue={r['peak_queue']:.3f}")
    h = paper_figs.headline(art)
    by_rate = ";".join(f"@{r:g}={v*100:+.0f}%"
                       for r, v in sorted(h["delay_reduction_by_rate"].items()))
    _row("headline_delay_vs_ppo", build_us,
         f"won_{h['rates_won']}of5_rates;{by_rate}"
         f";mean={h['mean_delay_reduction']*100:+.1f}%_vs_paper_claim_30%"
         f";episodes={h['episodes']}"
         f";note=@2.5_PPO_violates_energy_budget_7x_queue")

    # -- Lyapunov V ablation (beyond-paper) ------------------------------------
    from . import ablation_v
    t0 = time.time()
    vrows = ablation_v.sweep(v_values=(1.0, 10.0, 100.0), episodes=2,
                             steps=200)
    for r in vrows:
        _row(f"ablation_v[V={r['V']:g}]", (time.time() - t0) * 1e6 / 3,
             f"delay={r['delay_s']:.4f}s;qE={r['q_energy_final']:.1f}")

    # -- kernels + serving A/Bs + scenario grid -> BENCH_10.json ---------------
    payload = build_bench_payload()
    _emit_bench_rows(payload)
    _write_bench_json(payload)

    # -- roofline (from dry-run artifacts; skip silently if sweep not run) -----
    from . import roofline
    dd = os.path.join(os.path.dirname(__file__), "out", "dryrun")
    if os.path.isdir(dd) and os.listdir(dd):
        rows = roofline.build_table(dd, "single")
        ok = [r for r in rows if r["status"] == "ok"]
        for r in ok:
            _row(f"roofline[{r['arch']}@{r['shape']}]", r["step_s"] * 1e6,
                 f"bound={r['dominant']};mfu_at_roof={r['roofline_fraction']*100:.1f}%"
                 f";useful={r['useful_fraction']*100:.0f}%")
        doms = {}
        for r in ok:
            doms[r["dominant"]] = doms.get(r["dominant"], 0) + 1
        _row("roofline_summary", 0.0,
             ";".join(f"{k}={v}" for k, v in sorted(doms.items())))
    else:
        _row("roofline_summary", 0.0, "dryrun_artifacts_missing")

    _row("bench_total", (time.time() - t_start) * 1e6,
         "seconds=%.1f" % (time.time() - t_start))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
