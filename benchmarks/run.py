"""Benchmark harness entry point (deliverable d): one experiment per paper
figure + kernel micro-benchmarks + the serving-engine A/B + the roofline
table.

Prints ``name,us_per_call,derived`` CSV per experiment, as required, and
writes the canonical ``BENCH_N.json`` perf-trajectory artifact at the repo
root (currently ``BENCH_6.json``: continuous-vs-sync serving latency --
p50/p99 replay latency, goodput, slot-steps/sec, prefill-compile counts
from BOTH engine modes; see benchmarks/serving_latency.py).
"""
from __future__ import annotations

import json
import os
import time


def _row(name, us, derived):
    print(f"{name},{us:.1f},{derived}")


def main() -> None:
    t_start = time.time()
    print("name,us_per_call,derived")

    # -- paper figures -------------------------------------------------------
    from . import paper_figs
    t0 = time.time()
    art = paper_figs.load_or_build()
    build_us = (time.time() - t0) * 1e6

    for r in paper_figs.fig3_convergence(art):
        _row(f"fig3_convergence[{r['algo']}]",
             r["train_s"] * 1e6 / max(art["episodes"], 1),
             f"final_reward={r['reward_last10pct']:.2f};conv_ep={r['convergence_episode']}")
    for r in paper_figs.fig4_rate_sweep(art):
        _row(f"fig4[{r['algo']}@{r['rate']}]", 0.0,
             f"delay={r['delay_s']:.4f}s;energy={r['energy_J']*1e3:.1f}mJ;"
             f"mem={r['mem_GB']*1e3:.0f}MB;qE={r['q_energy_final']:.1f}")
    for r in paper_figs.fig5_queue_stability(art):
        _row(f"fig5[{r['task']}:{r['algo']}]", 0.0,
             f"peak_queue={r['peak_queue']:.3f}")
    h = paper_figs.headline(art)
    by_rate = ";".join(f"@{r:g}={v*100:+.0f}%"
                       for r, v in sorted(h["delay_reduction_by_rate"].items()))
    _row("headline_delay_vs_ppo", build_us,
         f"won_{h['rates_won']}of5_rates;{by_rate}"
         f";mean={h['mean_delay_reduction']*100:+.1f}%_vs_paper_claim_30%"
         f";episodes={h['episodes']}"
         f";note=@2.5_PPO_violates_energy_budget_7x_queue")

    # -- Lyapunov V ablation (beyond-paper) ------------------------------------
    from . import ablation_v
    t0 = time.time()
    vrows = ablation_v.sweep(v_values=(1.0, 10.0, 100.0), episodes=2,
                             steps=200)
    for r in vrows:
        _row(f"ablation_v[V={r['V']:g}]", (time.time() - t0) * 1e6 / 3,
             f"delay={r['delay_s']:.4f}s;qE={r['q_energy_final']:.1f}")

    # -- kernels ---------------------------------------------------------------
    from . import kernels_micro
    for name, us, derived in kernels_micro.bench_all():
        _row(f"kernel[{name}]", us, derived)

    # -- serving engine A/B (continuous vs sync) + BENCH_6.json ----------------
    from . import serving_latency
    payload = serving_latency.bench_all()
    for name, us, derived in serving_latency.rows(payload):
        _row(name, us, derived)
    bench_path = os.path.join(os.path.dirname(__file__), "..", "BENCH_6.json")
    with open(bench_path, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
    _row("bench_json", 0.0, f"wrote={os.path.normpath(bench_path)}")

    # -- roofline (from dry-run artifacts; skip silently if sweep not run) -----
    from . import roofline
    dd = os.path.join(os.path.dirname(__file__), "out", "dryrun")
    if os.path.isdir(dd) and os.listdir(dd):
        rows = roofline.build_table(dd, "single")
        ok = [r for r in rows if r["status"] == "ok"]
        for r in ok:
            _row(f"roofline[{r['arch']}@{r['shape']}]", r["step_s"] * 1e6,
                 f"bound={r['dominant']};mfu_at_roof={r['roofline_fraction']*100:.1f}%"
                 f";useful={r['useful_fraction']*100:.0f}%")
        doms = {}
        for r in ok:
            doms[r["dominant"]] = doms.get(r["dominant"], 0) + 1
        _row("roofline_summary", 0.0,
             ";".join(f"{k}={v}" for k, v in sorted(doms.items())))
    else:
        _row("roofline_summary", 0.0, "dryrun_artifacts_missing")

    _row("bench_total", (time.time() - t_start) * 1e6,
         "seconds=%.1f" % (time.time() - t_start))


if __name__ == "__main__":
    main()
