"""Roofline table builder (deliverable g): merges the dry-run artifacts
(benchmarks/out/dryrun/*.json) with the analytic estimators into the
EXPERIMENTS.md §Roofline table.

Usage:
  PYTHONPATH=src python -m benchmarks.roofline [--dryrun-dir ...] [--md out.md]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

from repro.configs.base import get_config
from repro.launch import specs
from repro.profiling import roofline as rl


def _advice(cell) -> str:
    dom = cell["dominant"]
    shape = cell["shape"]
    if dom == "collective":
        return ("cut TP all-reduces (overlap/reduce-scatter) or FSDP "
                "re-gathers (fewer microbatches / wider activation sharding)")
    if dom == "memory":
        if "decode" in shape or "500k" in shape:
            return ("KV/cache traffic bound: quantize cache to int8 or grow "
                    "batch to amortize weight reads")
        return "cut activation r/w: fuse norms/FFN, wider remat blocks"
    return "MXU-bound: raise arithmetic intensity (larger tiles, bf16 flash)"


def build_table(dryrun_dir: str, mesh: str = "single"):
    rows = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        r = json.load(open(path))
        if r["mesh"] != mesh:
            continue
        if r["status"] == "skipped":
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "status": "skipped", "reason": r["reason"]})
            continue
        if r["status"] != "ok":
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "status": "error"})
            continue
        cfg = get_config(r["arch"])
        shape = specs.SHAPES[r["shape"]]
        if shape.kind == "train":
            from repro.models.steps import default_microbatches
            mb = default_microbatches(cfg, shape.batch)
        else:
            mb = 1
        coll = r["collectives"]
        by_kind = dict(coll["bytes_by_kind"])
        if "f32_bytes" in coll and coll.get("total_bytes"):
            # bf16-wire correction: XLA:CPU upcasts bf16 collectives to f32;
            # TPU keeps bf16 on the wire (EXPERIMENTS §Perf accounting note).
            scale = coll["bf16_wire_corrected_bytes"] / coll["total_bytes"]
            by_kind = {k: v * scale for k, v in by_kind.items()}
        terms = rl.terms_for(cfg, shape, shape.kind, by_kind,
                             chips=r["devices"], microbatches=mb)
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "status": "ok",
            "chips": r["devices"],
            "compute_s": terms.compute_s,
            "memory_s": terms.memory_s,
            "collective_s": terms.collective_s,
            "dominant": terms.dominant,
            "step_s": terms.step_time_s,
            "model_flops": terms.model_flops,
            "executed_flops": terms.executed_flops,
            "useful_fraction": terms.useful_fraction,
            "roofline_fraction": terms.roofline_fraction,
            "hlo_flops_per_dev": r["flops"],
            "memory_per_dev_gb": (
                (r["memory"]["argument_bytes"] + r["memory"]["temp_bytes"]) / 1e9
                if r.get("memory") and "argument_bytes" in r["memory"] else None),
            "compile_s": r["compile_s"],
        })
    for row in rows:
        if row["status"] == "ok":
            row["advice"] = _advice(row)
    return rows


def to_markdown(rows) -> str:
    md = ["| arch | shape | comp s | mem s | coll s | bound | MFU@roof | useful | HBM GB/dev |",
          "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["status"] != "ok":
            md.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                      f"SKIP ({r.get('reason','')[:40]}…) | — | — | — |")
            continue
        mem = f"{r['memory_per_dev_gb']:.1f}" if r["memory_per_dev_gb"] else "?"
        md.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3g} | "
            f"{r['memory_s']:.3g} | {r['collective_s']:.3g} | "
            f"**{r['dominant']}** | {r['roofline_fraction']*100:.1f}% | "
            f"{r['useful_fraction']*100:.0f}% | {mem} |")
    return "\n".join(md)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun-dir", default="benchmarks/out/dryrun")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--json", default="benchmarks/out/roofline.json")
    args = ap.parse_args()
    rows = build_table(args.dryrun_dir, args.mesh)
    os.makedirs(os.path.dirname(args.json), exist_ok=True)
    with open(args.json, "w") as f:
        json.dump(rows, f, indent=1)
    print(to_markdown(rows))
    ok = [r for r in rows if r["status"] == "ok"]
    print(f"\ncells: {len(ok)} ok / {len(rows)} total")
    for bound in ("compute", "memory", "collective"):
        n = sum(1 for r in ok if r["dominant"] == bound)
        print(f"  {bound}-bound: {n}")


if __name__ == "__main__":
    main()
