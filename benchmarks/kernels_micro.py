"""Kernel micro-benchmarks: wall time of the jitted reference paths on CPU
(the TPU kernels are validated in interpret mode; wall-clock TPU numbers are
out of scope for this container -- see EXPERIMENTS.md §Roofline for the
derived performance model)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp


def _time(fn, *args, iters=5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def bench_all():
    from repro.kernels import ops, ref
    key = jax.random.PRNGKey(0)
    rows = []

    # attention (reference path, jitted)
    b, s, h, kv, hd = 2, 1024, 8, 4, 64
    ks = jax.random.split(jax.random.fold_in(key, 0), 3)
    q = jax.random.normal(ks[0], (b, s, h, hd), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, kv, hd), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, kv, hd), jnp.float32)
    f = jax.jit(lambda q, k, v: ops.flash_attention(q, k, v, kind="causal"))
    us = _time(f, q, k, v)
    flops = 2 * b * s * s * h * hd * 2
    rows.append(("attention_causal_1k", us, f"{flops/us/1e6:.1f}GFLOP/s"))

    qd = q[:, :1]
    valid = jnp.ones((b, s), bool)
    fd = jax.jit(lambda q, k, v: ops.decode_attention(q, k, v, valid_mask=valid))
    us = _time(fd, qd, k, v)
    rows.append(("decode_attention_1k", us,
                 f"{(k.size+v.size)*4/us/1e3:.1f}GB/s_cache_read"))

    # ragged flash at engine bucket widths: masked-Pallas (interpret; the
    # kernel body the TPU runs) vs the dense reference that used to serve
    # every ragged batch.  Wall times are CPU-interpreter-skewed -- the
    # point of the leg is exercising the masked kernel at serving shapes
    # and recording the dense-fallback cost it replaces.
    # micro-bench of the RAW kernel entry point on purpose: the wrapper's
    # tile padding is exactly the overhead this leg isolates
    from repro.kernels.flash_attention import flash_attention_pallas  # reprolint: ignore[pallas-wrapper]
    bw = 64                                   # engine bucket width
    ks = jax.random.split(jax.random.fold_in(key, 1), 4)
    qb = jax.random.normal(ks[0], (4, bw, h, hd), jnp.float32)
    kb = jax.random.normal(ks[1], (4, bw, kv, hd), jnp.float32)
    vb = jax.random.normal(ks[2], (4, bw, kv, hd), jnp.float32)
    pad = jnp.asarray([0, 11, 23, 40], jnp.int32)
    pad_mask = jnp.arange(bw)[None, :] >= pad[:, None]
    fm = jax.jit(lambda q, k, v, p: flash_attention_pallas(
        q, k, v, kind="causal", q_block=32, k_block=32, pad=p,
        interpret=True))
    us = _time(fm, qb, kb, vb, pad, iters=3)
    rows.append((f"ragged_flash_masked_b{bw}", us, "pallas_interpret"))

    def dense_ragged(q, k, v):
        mask = (jnp.broadcast_to(pad_mask[:, None, :], (4, bw, bw))
                & ref.build_mask("causal", bw, bw)[None])
        return ref.attention_ref(q, k, v, mask=mask)

    us = _time(jax.jit(dense_ragged), qb, kb, vb)
    rows.append((f"ragged_flash_dense_ref_b{bw}", us, "old_fallback"))

    # SSD scan
    bs, ss, hh, pp, nn = 2, 512, 8, 64, 64
    ks = jax.random.split(jax.random.fold_in(key, 2), 4)
    x = jax.random.normal(ks[0], (bs, ss, hh, pp))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (bs, ss, hh)))
    a_log = jnp.log(jnp.linspace(1.0, 8.0, hh))
    bm = jax.random.normal(ks[2], (bs, ss, 1, nn)) * 0.5
    cm = jax.random.normal(ks[3], (bs, ss, 1, nn)) * 0.5
    d = jnp.ones((hh,))
    fs = jax.jit(lambda *a: ops.ssd_scan(*a, chunk=128))
    us = _time(fs, x, dt, a_log, bm, cm, d)
    rows.append(("ssd_scan_512", us, f"chunk128"))

    # RG-LRU scan
    kr = jax.random.split(jax.random.fold_in(key, 3), 2)
    xx = jax.random.normal(kr[0], (2, 1024, 512)) * 0.3
    aa = jax.nn.sigmoid(jax.random.normal(kr[1], (2, 1024, 512)) + 2.0)
    fr = jax.jit(ops.rglru_scan)
    us = _time(fr, xx, aa)
    rows.append(("rglru_scan_1k", us, "assoc_scan"))

    # partition sweep: the controller hot spot at serving scale (256 UEs)
    from repro.profiling.lmprofiles import all_lm_profiles
    from repro.profiling.profiles import ProfileBatch
    profs = list(all_lm_profiles().values())
    batch = ProfileBatch([profs[i % len(profs)] for i in range(256)])
    f32 = lambda t: jnp.asarray(t, jnp.float32)
    scalars = dict(rho=0.12, kappa=1e-28, p_tx=0.1, w_hz=5e6,
                   n0=10 ** (-17.4) / 1000, f_max_ue=5e9, f_max_es=200e9,
                   v=10.0, gamma_ue=0.2, gamma_es=0.8, stability_margin=1e-3)
    lam = jnp.full((256,), 2.0)
    gain = jnp.full((256,), 1.6e-11)
    qq = jnp.zeros((256,))
    fp = jax.jit(lambda *a: ref.partition_sweep_ref(*a, scalars))
    us = _time(fp, f32(batch.macs), f32(batch.param_bytes),
               f32(batch.act_bytes), f32(batch.psi),
               jnp.asarray(batch.L), lam, gain, qq, qq)
    cells = 256 * (batch.Lmax + 1)
    rows.append(("partition_sweep_256ue", us, f"{cells/us:.1f}cells/us"))

    return rows
