"""Beyond-paper ablation: the Lyapunov V tradeoff.

Drift-plus-penalty theory (paper refs. [15][16]) promises delay gap O(1/V)
and queue backlog O(V).  The paper fixes V=10 and never shows the curve; we
sweep V with the Oracle policy (per-slot exhaustive partition + exact convex
allocation — no DRL training confound) and verify both monotonicities.

  PYTHONPATH=src python -m benchmarks.ablation_v
"""
from __future__ import annotations

from repro.core.env import LAM_FIXED, MecConfig, paper_env
from repro.core.lymdo import oracle_cut_fn, run_fixed


def sweep(v_values=(1.0, 3.0, 10.0, 30.0, 100.0), episodes: int = 3,
          steps: int = 300):
    rows = []
    for v in v_values:
        env = paper_env(MecConfig(lam_mode=LAM_FIXED, v=v))
        metrics, _ = run_fixed(env, oracle_cut_fn(env), episodes=episodes,
                               steps=steps, seed=7)
        rows.append({"V": v, "delay_s": metrics["delay"],
                     "energy_J": metrics["energy"],
                     "q_energy_final": metrics["q_energy_final"],
                     "q_memory_final": metrics["q_memory_final"]})
    return rows


def main():
    rows = sweep()
    print("V,delay_s,energy_J,q_energy_final,q_memory_final")
    for r in rows:
        print(f"{r['V']},{r['delay_s']:.4f},{r['energy_J']:.4f},"
              f"{r['q_energy_final']:.2f},{r['q_memory_final']:.2f}")
    delays = [r["delay_s"] for r in rows]
    queues = [r["q_energy_final"] for r in rows]
    print("delay monotone nonincreasing in V:",
          all(delays[i + 1] <= delays[i] * 1.02 for i in range(len(rows) - 1)))
    print("queue monotone nondecreasing in V:",
          all(queues[i + 1] >= queues[i] * 0.98 - 1.0 for i in range(len(rows) - 1)))


if __name__ == "__main__":
    main()
