"""Shared sharded-leg plumbing for the grid benchmarks.

Both ``scenario_grid.py`` and ``traffic_replay.py`` grow a device-sharded
leg from the same ``--devices N [--model M]`` flags; this module holds the
pieces they share so validation/error text never diverges:

* :func:`validate_mesh_args` -- every ``("cells", "model")`` layout
  precondition checked BEFORE jax initializes (the in-library check in
  ``repro.launch.mesh.make_cells_mesh`` re-validates with the same rules;
  doing it pre-init here keeps the message clear of any XLA state).
* :func:`force_devices` -- the ``XLA_FLAGS`` host-device forcing, which
  must land before the first jax array op.
* :func:`leg_tag` -- the ``@8dev`` / ``@4x2dev`` CSV-row suffix.
* :func:`backend_ready` -- False when something initialized the backend
  before the flag landed (the leg then reports SKIPPED instead of lying).
"""
from __future__ import annotations

import os


def validate_mesh_args(devices: int, model: int) -> str | None:
    """Return an error string for impossible ``--devices/--model`` combos
    (None when valid).  Mirrors ``make_cells_mesh``'s rules."""
    if model < 1:
        return f"--model {model} must be >= 1"
    if model > 1 and not devices:
        return (f"--model {model} needs --devices N (the ('cells','model') "
                "mesh is built over forced host devices)")
    if devices and devices % model:
        return (f"--model {model} does not divide --devices {devices}; "
                "pick a model-axis size from the divisors of "
                f"{devices}")
    return None


def force_devices(devices: int) -> None:
    """Append the host-device forcing flag; call before any jax array op."""
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={devices}")


def leg_tag(devices: int, model: int) -> str:
    """CSV-row suffix naming the device grid: ``@8dev`` or ``@4x2dev``."""
    if model == 1:
        return f"@{devices}dev"
    return f"@{devices // model}x{model}dev"


def backend_ready(devices: int) -> bool:
    """True when the forced device count actually materialized."""
    import jax
    return len(jax.devices()) >= devices
