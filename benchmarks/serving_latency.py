"""Engine A/B latency benchmark: continuous batching vs the synchronized
compat mode, under the traffic subsystem's bursty arrival processes.

  PYTHONPATH=src python -m benchmarks.serving_latency --json BENCH_6.json

The experiment the paged-KV engine exists for: materialize a bursty arrival
process (``flash_crowd``: quiet base + spike with exponential decay;
``mmpp_burst``: 2-state MMPP), draw one deterministic request schedule from
it (heterogeneous prompt lengths AND decode budgets -- the mix that makes
head-of-line blocking visible), and replay the IDENTICAL schedule through

* the continuous engine (per-tick admission, paged KV, preemption), and
* ``sync_batching=True`` (admission waits for every slot to drain),

at equal slot count.  The ``TrafficRecorder`` clocks both runs on the same
tick base, so p50/p99 submit->complete latency, goodput (completed requests
per tick), and slot-steps/sec are directly comparable; per-request greedy
outputs are asserted IDENTICAL across the two engines (same model, same
schedule -- the engines may only differ in *when*, never *what*).

CSV rows follow the benchmarks/run.py convention; ``--json`` additionally
writes the canonical ``BENCH_6.json`` perf-trajectory artifact with both
engines' numbers per workload.

``chunked_prefill_ab`` is the second A/B: chunked vs whole-prompt
admission under a long-prompt flash crowd, gated (in ``main`` and CI) on
identical tokens AND a per-tick wall-p99 win for chunking -- the
head-of-line-blocking fix this benchmark exists to keep honest.
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np


def make_schedule(workload: str, n_ue: int, ticks: int, seed: int,
                  vocab: int):
    """Deterministic (tick, rid, prompt, max_new, ue) request schedule drawn
    from a traffic-subsystem arrival process."""
    import jax
    import jax.numpy as jnp
    from repro import traffic

    if workload == "flash_crowd":
        proc = traffic.FlashCrowd(
            base=jnp.full((n_ue,), 0.08), spike=jnp.asarray(2.5),
            t0=jnp.asarray(ticks // 4, jnp.int32),
            decay=jnp.asarray(ticks / 6.0))
        rates = traffic.materialize(proc, ticks, jax.random.PRNGKey(seed))
    elif workload == "mmpp_burst":
        proc = traffic.make_mmpp(n_ue, seed=seed, rates=(0.05, 1.2),
                                 horizon=ticks)
        rates = traffic.materialize(proc, ticks, jax.random.PRNGKey(seed))
    else:
        raise ValueError(f"unknown workload {workload!r}")

    rng = np.random.default_rng(seed)
    counts = rng.poisson(np.asarray(rates))            # (T, N) arrivals
    schedule, rid = [], 0
    for t in range(ticks):
        for ue in range(n_ue):
            for _ in range(int(counts[t, ue])):
                n = int(rng.integers(4, 11))
                schedule.append((t, rid,
                                 rng.integers(0, vocab, n).astype(np.int32),
                                 int(rng.integers(2, 9)), ue))
                rid += 1
    return schedule


def replay(cfg, params, schedule, *, sync: bool, slots: int, s_max: int,
           max_ticks: int = 5000) -> dict:
    """Feed the schedule into one engine; return latency + throughput stats
    and the per-request outputs (for the cross-engine parity check)."""
    from repro.serving.engine import Request, ServingEngine
    from repro.traffic import TrafficRecorder

    rec = TrafficRecorder()
    eng = ServingEngine(cfg, params, slots=slots, s_max=s_max,
                        recorder=rec, sync_batching=sync)
    reqs = [Request(rid=rid, prompt=prompt, max_new=max_new, ue=ue)
            for _, rid, prompt, max_new, ue in schedule]
    pending = list(zip((t for t, *_ in schedule), reqs))

    t0 = time.perf_counter()
    i = 0
    for _ in range(max_ticks):
        while i < len(pending) and pending[i][0] <= eng.clock:
            eng.submit(pending[i][1])
            i += 1
        busy = eng.step()
        if i == len(pending) and not busy:
            break
    wall = time.perf_counter() - t0
    assert all(r.done for r in reqs), "schedule did not drain"

    lat = rec.latency_stats()
    ticks = eng.clock
    return {
        "engine": "sync" if sync else "continuous",
        "requests": len(reqs),
        "ticks": int(ticks),
        "wall_s": round(wall, 4),
        "latency_ticks": lat,
        "goodput_req_per_tick": round(len(reqs) / max(ticks, 1), 4),
        "slot_steps_per_s": round(eng.decode_steps * slots / max(wall, 1e-9)),
        "decode_steps": int(eng.decode_steps),
        "prefill_compiles": int(eng.prefill_compiles),
        "preemptions": int(eng.preemptions),
        "_outputs": [list(r.out) for r in reqs],
    }


def bench_all(*, slots: int = 2, s_max: int = 32, ticks: int = 48,
              n_ue: int = 4, seed: int = 0, n_layers: int = 4) -> dict:
    """Both engines x both workloads on a reduced attention stack.  Returns
    the BENCH_6 payload (outputs stripped, parity recorded as a bool)."""
    import jax
    from repro.configs.base import get_config, reduced
    from repro.models import transformer

    cfg = reduced(get_config("qwen3-0.6b"), n_layers=n_layers)
    params = transformer.init_params(jax.random.PRNGKey(seed), cfg)

    out = {"bench": 6,
           "config": {"arch": cfg.name, "n_layers": n_layers, "slots": slots,
                      "s_max": s_max, "ticks": ticks, "n_ue": n_ue,
                      "seed": seed},
           "workloads": {}}
    for workload in ("flash_crowd", "mmpp_burst"):
        sched = make_schedule(workload, n_ue, ticks, seed, cfg.vocab)
        cont = replay(cfg, params, sched, sync=False, slots=slots,
                      s_max=s_max)
        sync = replay(cfg, params, sched, sync=True, slots=slots,
                      s_max=s_max)
        match = cont.pop("_outputs") == sync.pop("_outputs")
        p99_c = cont["latency_ticks"]["p99"]
        p99_s = sync["latency_ticks"]["p99"]
        out["workloads"][workload] = {
            "continuous": cont, "sync": sync,
            "outputs_match": bool(match),
            "p99_speedup": round(p99_s / max(p99_c, 1e-9), 3),
        }
    return out


def sanitize_overhead(*, slots: int = 2, s_max: int = 32, seed: int = 0,
                      n_layers: int = 2, n_requests: int = 6,
                      max_new: int = 16) -> dict:
    """Per-tick p50 cost of the engine with the sanitizer off vs on.

    The off run IS the shipping path (`sanitize=False` costs one
    ``is None`` check per lifecycle edge); the on run pays shadow
    ownership bookkeeping plus a checkify host sync per dispatch.  Both
    replay the identical schedule and must emit identical greedy tokens
    -- the sanitizer may only change *cost*, never results.  The p50
    (not mean) makes the number robust to the compile ticks at the
    front of each run.
    """
    import jax
    from repro.configs.base import get_config, reduced
    from repro.models import transformer
    from repro.serving.engine import Request, ServingEngine

    cfg = reduced(get_config("qwen3-0.6b"), n_layers=n_layers)
    params = transformer.init_params(jax.random.PRNGKey(seed), cfg)
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, cfg.vocab,
                            int(rng.integers(4, 12))).astype(np.int32)
               for _ in range(n_requests)]

    def run(sanitize: bool):
        eng = ServingEngine(cfg, params, slots=slots, s_max=s_max,
                            sanitize=sanitize)
        for i, p in enumerate(prompts):
            eng.submit(Request(rid=i, prompt=p, max_new=max_new))
        durs = []
        while True:
            t0 = time.perf_counter()
            alive = eng.step()
            durs.append(time.perf_counter() - t0)
            if not alive:
                break
        outs = [list(r.out) for r in sorted(eng.pop_completed(),
                                            key=lambda r: r.rid)]
        return float(np.median(durs)) * 1e6, len(durs), outs

    off_us, off_ticks, off_out = run(False)
    on_us, on_ticks, on_out = run(True)
    return {
        "config": {"arch": cfg.name, "n_layers": n_layers, "slots": slots,
                   "s_max": s_max, "requests": n_requests,
                   "max_new": max_new, "seed": seed},
        "p50_tick_us": {"off": round(off_us, 1), "on": round(on_us, 1)},
        "ticks": {"off": off_ticks, "on": on_ticks},
        "on_over_off": round(on_us / max(off_us, 1e-9), 3),
        "outputs_match": off_out == on_out,
    }


def chunked_prefill_ab(*, slots: int = 2, s_max: int = 128, seed: int = 0,
                       n_layers: int = 2, chunk: int = 32,
                       n_long: int = 4, n_short: int = 6) -> dict:
    """Head-of-line-blocking A/B: a flash crowd of LONG prompts replayed
    through chunked-prefill admission (``prefill_chunk=chunk``) vs
    whole-prompt admission (``prefill_chunk=None``) at equal geometry.

    Whole-prompt admission spends one monolithic tick per long prompt, so
    every already-decoding slot stalls for the full prompt width -- the
    per-tick wall p99 carries that spike.  Chunked admission bounds any
    tick's prefill work to one chunk.  Each mode runs a warm-up wave
    first (every program compiles), then an identical measured wave on
    the SAME engine instance; per-request greedy tokens are asserted
    identical across modes, warm and measured alike -- chunking may only
    change *when*, never *what*.
    """
    import jax
    from repro.configs.base import get_config, reduced
    from repro.models import transformer
    from repro.serving.engine import Request, ServingEngine

    cfg = reduced(get_config("qwen3-0.6b"), n_layers=n_layers)
    params = transformer.init_params(jax.random.PRNGKey(seed), cfg)
    rng = np.random.default_rng(seed)
    spec = [(0, rng.integers(0, cfg.vocab,
                             int(rng.integers(88, 101))).astype(np.int32),
             int(rng.integers(3, 6))) for _ in range(n_long)]
    spec += [(int(rng.integers(0, 4)),
              rng.integers(0, cfg.vocab,
                           int(rng.integers(5, 12))).astype(np.int32),
              int(rng.integers(3, 8))) for _ in range(n_short)]
    spec.sort(key=lambda s: s[0])

    def wave(eng, base_rid):
        t_base = eng.clock
        reqs = [Request(rid=base_rid + i, prompt=p, max_new=m)
                for i, (_, p, m) in enumerate(spec)]
        i, durs = 0, []
        for _ in range(5000):
            while i < len(reqs) and spec[i][0] + t_base <= eng.clock:
                eng.submit(reqs[i])
                i += 1
            t0 = time.perf_counter()
            busy = eng.step()
            durs.append(time.perf_counter() - t0)
            if i == len(reqs) and not busy:
                break
        assert all(r.done for r in reqs), "wave did not drain"
        eng.pop_completed()
        return durs, [list(r.out) for r in reqs]

    results = {}
    for label, pc in (("chunked", chunk), ("whole", None)):
        eng = ServingEngine(cfg, params, slots=slots, s_max=s_max,
                            prefill_chunk=pc)
        _, warm_out = wave(eng, 0)           # compiles every program
        durs, out = wave(eng, 10_000)        # steady state, measured
        assert out == warm_out, f"{label}: warm/measured token mismatch"
        results[label] = {
            "p50_tick_us": round(float(np.percentile(durs, 50)) * 1e6, 1),
            "p99_tick_us": round(float(np.percentile(durs, 99)) * 1e6, 1),
            "max_tick_us": round(float(np.max(durs)) * 1e6, 1),
            "ticks": len(durs),
            "prefill_compiles": int(eng.prefill_compiles),
            "_outputs": out,
        }
    match = (results["chunked"].pop("_outputs")
             == results["whole"].pop("_outputs"))
    return {
        "config": {"arch": cfg.name, "n_layers": n_layers, "slots": slots,
                   "s_max": s_max, "chunk": chunk, "n_long": n_long,
                   "n_short": n_short, "seed": seed},
        "chunked": results["chunked"],
        "whole": results["whole"],
        "outputs_match": bool(match),
        "p99_tick_speedup": round(
            results["whole"]["p99_tick_us"]
            / max(results["chunked"]["p99_tick_us"], 1e-9), 3),
    }


def chunked_rows(payload: dict):
    """benchmarks/run.py CSV rows for the chunked-prefill A/B payload."""
    for mode in ("chunked", "whole"):
        r = payload[mode]
        yield (f"chunked_prefill[{mode}]", r["p50_tick_us"],
               f"p99_tick_us={r['p99_tick_us']:.0f};"
               f"max_tick_us={r['max_tick_us']:.0f};"
               f"ticks={r['ticks']};"
               f"prefill_compiles={r['prefill_compiles']}")
    yield ("chunked_prefill_ab", 0.0,
           f"p99_tick_speedup={payload['p99_tick_speedup']:.2f}x;"
           f"outputs_match={'OK' if payload['outputs_match'] else 'FAIL'}")


def rows(payload: dict):
    """Flatten the payload into benchmarks/run.py CSV rows."""
    for workload, w in payload["workloads"].items():
        for mode in ("continuous", "sync"):
            r = w[mode]
            lat = r["latency_ticks"]
            yield (f"serving_latency[{workload}:{mode}]",
                   r["wall_s"] * 1e6 / max(r["ticks"], 1),
                   f"p50={lat['p50']:.0f}t;p99={lat['p99']:.0f}t;"
                   f"goodput={r['goodput_req_per_tick']:.2f}req/t;"
                   f"slot_steps_per_s={r['slot_steps_per_s']};"
                   f"prefill_compiles={r['prefill_compiles']};"
                   f"preemptions={r['preemptions']}")
        yield (f"serving_latency_ab[{workload}]", 0.0,
               f"p99_speedup={w['p99_speedup']:.2f}x;"
               f"outputs_match={'OK' if w['outputs_match'] else 'FAIL'}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--s-max", type=int, default=32)
    ap.add_argument("--ticks", type=int, default=48)
    ap.add_argument("--ues", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the BENCH_6.json payload here")
    args = ap.parse_args(argv)

    payload = bench_all(slots=args.slots, s_max=args.s_max, ticks=args.ticks,
                        n_ue=args.ues, seed=args.seed)
    chunked = chunked_prefill_ab(slots=args.slots, seed=args.seed)
    print("name,us_per_call,derived")
    for name, us, derived in rows(payload):
        print(f"{name},{us:.1f},{derived}")
    for name, us, derived in chunked_rows(chunked):
        print(f"{name},{us:.1f},{derived}")
    if args.json:
        payload = dict(payload, chunked_prefill=chunked)
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=1, sort_keys=True)
        print(f"wrote {args.json}")

    ok = all(w["outputs_match"] for w in payload["workloads"].values())
    crowd = payload["workloads"]["flash_crowd"]
    improved = crowd["p99_speedup"] > 1.0
    chunk_ok = chunked["outputs_match"]
    chunk_improved = chunked["p99_tick_speedup"] > 1.0
    if not ok:
        print("PARITY FAILURE: engines produced different tokens")
    if not improved:
        print("LATENCY REGRESSION: continuous p99 not better than sync "
              "on flash_crowd")
    if not chunk_ok:
        print("PARITY FAILURE: chunked prefill produced different tokens")
    if not chunk_improved:
        print("LATENCY REGRESSION: chunked prefill did not improve the "
              "per-tick wall p99 on the long-prompt flash crowd")
    return 0 if ok and improved and chunk_ok and chunk_improved else 1


if __name__ == "__main__":
    raise SystemExit(main())
