"""Batched scenario-grid benchmark: measures the speedup of evaluating B
cells x N UEs in ONE jitted vmap+scan program over the equivalent per-cell
Python loop, so the batching win is measured, not claimed.

  PYTHONPATH=src python -m benchmarks.scenario_grid --cells 64 --ues 8

All legs run the identical per-cell math (reset + `steps` slots of policy
decision -> C7 projection -> P3/P4/P5 convex allocation -> queue update):

* batched  -- ``ScenarioGrid.make_rollout``: vmap over cells inside one
  ``lax.scan`` over slots; a single dispatch for the whole grid.
* loop     -- one jitted single-cell episode (same scan over slots),
  compiled once and re-dispatched from Python per cell.
* sharded  -- (``--devices N``) the batched grid placed over an N-way
  ``("cells",)`` mesh (``ScenarioGrid.use_mesh``); on CPU the devices are
  forced with ``XLA_FLAGS=--xla_force_host_platform_device_count=N``, which
  this script sets itself BEFORE jax initializes -- so ``--devices`` only
  works when nothing else touched the backend first (always true under
  ``python -m benchmarks.scenario_grid``).  Forced host devices share the
  machine's cores, so the sharded leg measures partitioning overhead /
  scaling shape, not a real multi-chip speedup; it is reported, not gated.
* 2-D sharded -- (``--model M``, with ``--devices N``) the same grid over
  the ``("cells", "model")`` mesh: N/M cell shards x M-way per-cell tensor
  parallelism (``use_mesh(model=M)``).  Layout preconditions (M divides N,
  N devices actually forcible) are validated up front with actionable
  errors -- never an opaque XLA device-assignment failure.

Reported unit: slots/sec, where one slot = one (cell, time-slot) advance of
all N UEs.  CSV rows follow the benchmarks/run.py convention.
"""
from __future__ import annotations

import argparse
import time

import jax


def _sync(tree):
    jax.block_until_ready(tree)


def build_grid(cells: int, ues: int, seed: int):
    from repro.core.scenarios import ScenarioGrid, multicell_grid
    return ScenarioGrid(multicell_grid(cells=cells, ues=ues, seed=seed))


def bench_batched(grid, policy: str, steps: int, repeats: int):
    fn = grid.make_rollout(policy, steps)
    key = jax.random.PRNGKey(0)
    _sync(fn(key))                       # compile
    _sync(fn(key))                       # reprolint: ignore[key-reuse] (warm: same program on purpose)
    best = float("inf")
    for r in range(repeats):             # min-of-N: robust to CPU co-tenancy
        t0 = time.perf_counter()
        _sync(fn(jax.random.fold_in(key, r)))
        best = min(best, time.perf_counter() - t0)
    return best, grid.b * steps / best


def bench_loop(grid, policy: str, steps: int, repeats: int):
    from repro.core.env import reset_p, step_p
    from repro.core.scenarios import POLICIES

    act = POLICIES[policy]

    @jax.jit
    def episode(params, key):
        key, k0 = jax.random.split(key)
        st0 = reset_p(params, k0)

        def body(carry, _):
            st, k = carry
            k, k_act = jax.random.split(k)
            st2, res = step_p(params, st, act(params, st, k_act))
            return (st2, k), res.reward

        (_, _), rewards = jax.lax.scan(body, (st0, key), None, length=steps)
        return rewards

    cell_params = [s.params() for s in grid.scenarios]
    key = jax.random.PRNGKey(0)
    _sync(episode(cell_params[0], key))  # compile once (shapes shared)
    _sync(episode(cell_params[0], key))  # reprolint: ignore[key-reuse] (warm: same program on purpose)
    best = float("inf")
    for r in range(repeats):             # min-of-N: robust to CPU co-tenancy
        t0 = time.perf_counter()
        for b, params in enumerate(cell_params):
            _sync(episode(params, jax.random.fold_in(key, r * grid.b + b)))
        best = min(best, time.perf_counter() - t0)
    return best, grid.b * steps / best


def bench_payload(*, cells: int = 8, ues: int = 4, steps: int = 24,
                  repeats: int = 2, policy: str = "oracle",
                  seed: int = 0) -> dict:
    """Small-grid batched-vs-loop measurement as a JSON-ready block for the
    ``BENCH_N.json`` perf-trajectory artifact (benchmarks/run.py).  Defaults
    are far below the CLI's gate-grade 64x8 run on purpose: the artifact
    tracks the speedup trend per PR, the CLI ``--gate`` proves it."""
    grid = build_grid(cells, ues, seed)
    sec_b, sps_b = bench_batched(grid, policy, steps, repeats)
    sec_l, sps_l = bench_loop(grid, policy, steps, repeats)
    return {
        "config": {"cells": cells, "ues": ues, "steps": steps,
                   "repeats": repeats, "policy": policy, "seed": seed},
        "batched": {"best_seconds": sec_b, "slots_per_s": round(sps_b, 1)},
        "loop": {"best_seconds": sec_l, "slots_per_s": round(sps_l, 1)},
        "batched_speedup": round(sps_b / sps_l, 3),
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--cells", type=int, default=64)
    ap.add_argument("--ues", type=int, default=8)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--policy", default="oracle",
                    choices=("oracle", "local", "edge", "random"))
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--devices", type=int, default=0,
                    help="also run a cells-sharded leg over this many "
                         "(forced host) devices")
    ap.add_argument("--model", type=int, default=1,
                    help="per-cell tensor-parallel degree for the sharded "
                         "leg: a ('cells','model') mesh with --devices/M "
                         "cell shards x M-way model parallelism "
                         "(requires --devices divisible by M)")
    ap.add_argument("--gate", type=float, default=5.0,
                    help="min batched-over-loop speedup for exit code 0 "
                         "(0 disables the gate -- e.g. informational runs "
                         "on small configs or contended runners)")
    args = ap.parse_args(argv)

    from benchmarks._sharded import (backend_ready, force_devices, leg_tag,
                                     validate_mesh_args)
    # Validate the 2-D layout BEFORE touching jax: the same rules
    # make_cells_mesh enforces, surfaced pre-init with the exact flags.
    err = validate_mesh_args(args.devices, args.model)
    if err:
        print(f"error: {err}")
        return 2
    if args.devices:
        force_devices(args.devices)   # before jax initializes its backend

    grid = build_grid(args.cells, args.ues, args.seed)
    print(f"grid: B={grid.b} cells x N={grid.n_ue} UEs x C={grid.num_cuts} "
          f"cuts, {args.steps} slots, policy={args.policy}, "
          f"backend={jax.default_backend()}")

    print("name,us_per_call,derived")
    dt_b, sps_b = bench_batched(grid, args.policy, args.steps, args.repeats)
    print(f"scenario_grid_batched[{grid.b}x{grid.n_ue}],{dt_b*1e6:.0f},"
          f"slots_per_s={sps_b:.0f}")
    dt_l, sps_l = bench_loop(grid, args.policy, args.steps, args.repeats)
    print(f"scenario_grid_loop[{grid.b}x{grid.n_ue}],{dt_l*1e6:.0f},"
          f"slots_per_s={sps_l:.0f}")

    if args.devices:
        tag = leg_tag(args.devices, args.model)
        if not backend_ready(args.devices):
            print(f"scenario_grid_sharded[{grid.b}x{grid.n_ue}"
                  f"{tag}],0,SKIPPED_backend_already_initialized")
        else:
            from repro.launch.mesh import make_cells_mesh
            # Layout preconditions were validated pre-init; make_cells_mesh
            # re-checks them and raises an actionable ValueError either way.
            grid_sh = build_grid(args.cells, args.ues, args.seed)
            grid_sh.use_mesh(make_cells_mesh(args.devices,
                                             model=args.model))
            dt_s, sps_s = bench_batched(grid_sh, args.policy, args.steps,
                                        args.repeats)
            print(f"scenario_grid_sharded[{grid.b}x{grid.n_ue}"
                  f"{tag}],{dt_s*1e6:.0f},"
                  f"slots_per_s={sps_s:.0f}")
            print(f"scenario_grid_sharded_speedup[{grid.b}x{grid.n_ue}"
                  f"{tag}],0,"
                  f"sharded_over_batched={sps_s / sps_b:.2f}x")

    speedup = sps_b / sps_l
    print(f"scenario_grid_speedup[{grid.b}x{grid.n_ue}],0,"
          f"batched_over_loop={speedup:.1f}x")
    if args.gate <= 0:
        print(f"speedup: {speedup:.1f}x (gate disabled)")
        return 0
    ok = speedup >= args.gate
    print(f"speedup: {speedup:.1f}x "
          f"({'meets' if ok else 'BELOW'} the {args.gate:g}x acceptance bar)")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
