"""Paper-figure benchmarks (one per paper table/figure).

Uses scripts/out/paper_artifacts.json (the full-scale background run) when
present; otherwise quick-trains at REPRO_BENCH_EPISODES (default 200) so
``python -m benchmarks.run`` is self-contained.
"""
from __future__ import annotations

import json
import os

import numpy as np

_CACHE = os.path.join(os.path.dirname(__file__), "..", "scripts", "out",
                      "paper_artifacts.json")


def load_or_build(episodes: int | None = None) -> dict:
    if os.path.exists(_CACHE) and episodes is None:
        with open(_CACHE) as f:
            return json.load(f)
    import subprocess
    import sys
    eps = episodes or int(os.environ.get("REPRO_BENCH_EPISODES", "200"))
    subprocess.run([sys.executable,
                    os.path.join(os.path.dirname(__file__), "..", "scripts",
                                 "train_compare.py"), str(eps)],
                   check=True)
    with open(_CACHE) as f:
        return json.load(f)


def fig3_convergence(art: dict):
    """Fig. 3: convergence of LyMDO vs joint PPO."""
    rows = []
    for name, rec in art["fig3"].items():
        curve = np.asarray(rec["reward_curve"])
        n = len(curve)
        early = curve[: max(n // 10, 1)].mean()
        late = curve[-max(n // 10, 1):].mean()
        # convergence episode: first sustained crossing of 95% of final level
        target = late - 0.05 * abs(late)
        conv = next((i for i in range(n) if curve[i:i + 25].mean() >= target),
                    n)
        rows.append({"algo": name, "reward_first10pct": float(early),
                     "reward_last10pct": float(late),
                     "convergence_episode": int(conv),
                     "train_s": rec["train_s"]})
    return rows


def fig4_rate_sweep(art: dict):
    """Fig. 4(a-d): E2E delay / energy / memory / queue vs arrival rate."""
    rows = []
    for rate, algos in art["fig4"].items():
        for algo, m in algos.items():
            rows.append({"rate": float(rate), "algo": algo,
                         "delay_s": m["delay"], "energy_J": m["energy"],
                         "mem_GB": m["mem"],
                         "q_energy_final": m["q_energy_final"]})
    return rows


def fig5_queue_stability(art: dict):
    """Fig. 5: energy-queue peaks under the slot-75..110 burst."""
    rows = []
    for task in ("alexnet", "resnet"):
        for algo in ("lymdo", "ppo_joint"):
            trace = art["fig5"][algo][f"{task}_queue"]
            rows.append({"task": task, "algo": algo,
                         "peak_queue": float(max(trace)),
                         "final_queue": float(trace[-1])})
        rows.append({"task": task, "algo": "reduction_vs_ppo",
                     "peak_queue": art[f"fig5_{task}_queue_reduction"],
                     "final_queue": None})
    return rows


def headline(art: dict) -> dict:
    # per-rate delay reduction vs joint PPO (positive = LyMDO faster)
    reductions = {}
    for rate, algos in art["fig4"].items():
        d_l = algos["lymdo"]["delay"]
        d_j = algos["ppo_joint"]["delay"]
        reductions[float(rate)] = 1.0 - d_l / d_j
    rates_won = sum(1 for v in reductions.values() if v > 0)
    return {
        "episodes": art["episodes"],
        "delay_reduction_at_2p5": art["headline_delay_reduction_vs_ppo"],
        "delay_reduction_by_rate": reductions,
        "mean_delay_reduction": float(np.mean(list(reductions.values()))),
        "rates_won": rates_won,
        "paper_claim": 0.30,
    }
