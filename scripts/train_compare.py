"""Full paper-experiment artifact builder (run in background; benchmarks
read the JSON when present rather than re-training).

Produces scripts/out/paper_artifacts.json with:
  * fig3: reward curves (LyMDO, LyMDO-categorical, PPO-joint)
  * fig4: {delay, energy, mem, qE} x arrival rate x algorithm
  * fig5: per-slot energy-queue traces at lam=2.5 peak pattern
  * headline: delay reduction vs joint PPO at lam=2.5

Evaluation runs on the scenario registry: the Fig. 4 rate sweep is ONE
``ScenarioGrid`` of ``fixed_rate`` cells (every rate rolls out in a single
jitted batched program, device-sharded over a ``("cells",)`` mesh when
more than one device is live) and Fig. 5 is the ``peak_window`` scenario.
Only the joint-PPO baseline still evaluates per-env: it allocates
resources itself (``env.step_joint``), which the cut-policy grid rollout
deliberately does not model.
"""
import json
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.core.lymdo import (Runner, RunConfig, eval_policy_batched,
                              run_fixed_batched)
from repro.core.policies import (CategoricalPolicy, GaussianTanhPolicy,
                                 JointGaussianPolicy)
from repro.core.ppo import PPO, PPOConfig
from repro.core.scenarios import grid_from_names, make

EPISODES = int(sys.argv[1]) if len(sys.argv) > 1 else 1500
RATES = [0.5, 1.0, 1.5, 2.0, 2.5]
EVAL_EPISODES = 5
STEPS = 200
OUT = os.path.join(os.path.dirname(__file__), "out")
os.makedirs(OUT, exist_ok=True)

train_env = make("paper_table1").build()       # Table I, iid-uniform rates
artifacts = {"episodes": EPISODES, "rates": RATES}

agents = {}
for name, policy_cls, mode in [
        ("lymdo", GaussianTanhPolicy, "lymdo"),
        ("lymdo_categorical", CategoricalPolicy, "lymdo"),
        ("ppo_joint", JointGaussianPolicy, "joint")]:
    t0 = time.time()
    if policy_cls is JointGaussianPolicy:
        pol = policy_cls(train_env.obs_dim, train_env.L,
                         train_env.cfg.f_max_ue, train_env.cfg.f_max_es)
    else:
        pol = policy_cls(train_env.obs_dim, train_env.L)
    agent = PPO(pol, train_env.obs_dim, PPOConfig())
    runner = Runner(train_env, agent, steps=STEPS, mode=mode)
    state, hist = runner.train(RunConfig(episodes=EPISODES, steps=STEPS,
                                         chunk=50))
    agents[name] = (agent, state, mode)
    artifacts.setdefault("fig3", {})[name] = {
        "reward_curve": [float(x) for x in hist["reward"]],
        "train_s": time.time() - t0,
    }
    print(f"[trained] {name} in {time.time()-t0:.0f}s", flush=True)

# ---- Fig. 4: sweep arrival rates as ONE batched grid ------------------------
# One fixed_rate cell per sweep point; every rate evaluates in a single
# jitted rollout per policy instead of a Python loop over envs.
grid = grid_from_names([("fixed_rate", {"rate": r}) for r in RATES])
if jax.device_count() > 1:
    grid.use_mesh()                            # ("cells",) over live devices

fig4 = {str(r): {} for r in RATES}


def record(name, metrics):
    """metrics: summary name -> (B,) per-cell means; fan out to rates."""
    for b, rate in enumerate(RATES):
        fig4[str(rate)][name] = {k: float(v[b]) for k, v in metrics.items()}


for name in ("lymdo", "lymdo_categorical"):
    agent, state, _ = agents[name]
    metrics, _ = eval_policy_batched(grid, agent, state,
                                     episodes=EVAL_EPISODES, steps=STEPS)
    record(name, metrics)
for name in ("local", "edge", "random", "oracle"):
    metrics, _ = run_fixed_batched(grid, name, episodes=EVAL_EPISODES,
                                   steps=STEPS)
    record(name, metrics)

# joint PPO allocates resources itself (env.step_joint): per-env evaluation
agent_j, state_j, mode_j = agents["ppo_joint"]
for rate in RATES:
    env_r = make("fixed_rate", rate=rate).build()
    m, _ = Runner(env_r, agent_j, steps=STEPS, mode=mode_j).evaluate(
        state_j, episodes=EVAL_EPISODES)
    fig4[str(rate)]["ppo_joint"] = {k: float(v) for k, v in m.items()}

for rate in RATES:
    row = fig4[str(rate)]
    print(f"[fig4] rate {rate}: lymdo delay {row['lymdo']['delay']:.4f} "
          f"ppo {row['ppo_joint']['delay']:.4f} "
          f"local {row['local']['delay']:.4f}", flush=True)
artifacts["fig4"] = fig4

d_l = fig4["2.5"]["lymdo"]["delay"]
d_j = fig4["2.5"]["ppo_joint"]["delay"]
artifacts["headline_delay_reduction_vs_ppo"] = 1.0 - d_l / d_j
best = min(d_l, fig4["2.5"]["lymdo_categorical"]["delay"])
artifacts["headline_delay_reduction_best"] = 1.0 - best / d_j

# ---- Fig. 5: queue stability under peak workload ----------------------------
fig5 = {}
peak_grid = grid_from_names([("peak_window", {"boost": 1.0})])
agent_l, state_l, _ = agents["lymdo"]
_, results = eval_policy_batched(peak_grid, agent_l, state_l,
                                 episodes=1, steps=STEPS)
qe_traces = {"lymdo": np.asarray(results.q_energy)[:, 0, :]}  # (steps, N)
env_p = make("peak_window", boost=1.0).build()
_, results_j = Runner(env_p, agent_j, steps=STEPS, mode=mode_j).evaluate(
    state_j, episodes=1)
qe_traces["ppo_joint"] = np.asarray(results_j.q_energy)
for name, qe in qe_traces.items():
    fig5[name] = {
        "alexnet_queue": qe[:, :2].mean(1).tolist(),   # UEs 0-1: AlexNet
        "resnet_queue": qe[:, 2:].mean(1).tolist(),    # UEs 2-4: ResNet18
    }
artifacts["fig5"] = fig5
for task, idx in [("alexnet", "alexnet_queue"), ("resnet", "resnet_queue")]:
    peak_l = max(fig5["lymdo"][idx])
    peak_j = max(fig5["ppo_joint"][idx])
    artifacts[f"fig5_{task}_queue_reduction"] = 1.0 - peak_l / max(peak_j, 1e-9)

with open(os.path.join(OUT, "paper_artifacts.json"), "w") as f:
    json.dump(artifacts, f)
print("headline: %.1f%% delay reduction vs joint PPO (best %.1f%%)"
      % (100 * artifacts["headline_delay_reduction_vs_ppo"],
         100 * artifacts["headline_delay_reduction_best"]), flush=True)
print("saved paper_artifacts.json")
