"""Full paper-experiment artifact builder (run in background; benchmarks
read the JSON when present rather than re-training).

Produces scripts/out/paper_artifacts.json with:
  * fig3: reward curves (LyMDO, LyMDO-categorical, PPO-joint)
  * fig4: {delay, energy, mem, qE} x arrival rate x algorithm
  * fig5: per-slot energy-queue traces at lam=2.5 peak pattern
  * headline: delay reduction vs joint PPO at lam=2.5
"""
import json
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.core.env import (LAM_FIXED, LAM_IID_UNIFORM, LAM_PEAK, MecConfig,
                            paper_env)
from repro.core.lymdo import (Runner, RunConfig, edge_cut_fn, local_cut_fn,
                              oracle_cut_fn, random_cut_fn, run_fixed)
from repro.core.policies import (CategoricalPolicy, GaussianTanhPolicy,
                                 JointGaussianPolicy)
from repro.core.ppo import PPO, PPOConfig

EPISODES = int(sys.argv[1]) if len(sys.argv) > 1 else 1500
RATES = [0.5, 1.0, 1.5, 2.0, 2.5]
OUT = os.path.join(os.path.dirname(__file__), "out")
os.makedirs(OUT, exist_ok=True)

train_env = paper_env(MecConfig(lam_mode=LAM_IID_UNIFORM))
js = lambda d: {k: float(v) for k, v in d.items()}
artifacts = {"episodes": EPISODES, "rates": RATES}

agents = {}
for name, policy_cls, mode in [
        ("lymdo", GaussianTanhPolicy, "lymdo"),
        ("lymdo_categorical", CategoricalPolicy, "lymdo"),
        ("ppo_joint", JointGaussianPolicy, "joint")]:
    t0 = time.time()
    if policy_cls is JointGaussianPolicy:
        pol = policy_cls(train_env.obs_dim, train_env.L,
                         train_env.cfg.f_max_ue, train_env.cfg.f_max_es)
    else:
        pol = policy_cls(train_env.obs_dim, train_env.L)
    agent = PPO(pol, train_env.obs_dim, PPOConfig())
    runner = Runner(train_env, agent, steps=200, mode=mode)
    state, hist = runner.train(RunConfig(episodes=EPISODES, steps=200,
                                         chunk=50))
    agents[name] = (agent, state, mode)
    artifacts.setdefault("fig3", {})[name] = {
        "reward_curve": [float(x) for x in hist["reward"]],
        "train_s": time.time() - t0,
    }
    print(f"[trained] {name} in {time.time()-t0:.0f}s", flush=True)

# ---- Fig. 4: sweep arrival rates -------------------------------------------
fig4 = {}
for rate in RATES:
    env_r = paper_env(MecConfig(lam_mode=LAM_FIXED),)
    env_r.lam_fixed = jnp.full((env_r.n_ue,), rate, jnp.float32)
    row = {}
    for name, (agent, state, mode) in agents.items():
        m, _ = Runner(env_r, agent, steps=200, mode=mode).evaluate(
            state, episodes=5)
        row[name] = js(m)
    for name, fn in [("local", local_cut_fn(env_r)), ("edge", edge_cut_fn(env_r)),
                     ("random", random_cut_fn(env_r)),
                     ("oracle", oracle_cut_fn(env_r))]:
        m, _ = run_fixed(env_r, fn, episodes=5, steps=200)
        row[name] = js(m)
    fig4[str(rate)] = row
    print(f"[fig4] rate {rate}: lymdo delay {row['lymdo']['delay']:.4f} "
          f"ppo {row['ppo_joint']['delay']:.4f} local {row['local']['delay']:.4f}",
          flush=True)
artifacts["fig4"] = fig4

d_l = fig4["2.5"]["lymdo"]["delay"]
d_j = fig4["2.5"]["ppo_joint"]["delay"]
artifacts["headline_delay_reduction_vs_ppo"] = 1.0 - d_l / d_j
best = min(d_l, fig4["2.5"]["lymdo_categorical"]["delay"])
artifacts["headline_delay_reduction_best"] = 1.0 - best / d_j

# ---- Fig. 5: queue stability under peak workload ----------------------------
fig5 = {}
env_p = paper_env(MecConfig(lam_mode=LAM_PEAK, peak_boost=1.0))
for name in ("lymdo", "ppo_joint"):
    agent, state, mode = agents[name]
    _, results = Runner(env_p, agent, steps=200, mode=mode).evaluate(
        state, episodes=1)
    qe = np.asarray(results.q_energy)          # (slots, n_ue)
    fig5[name] = {
        "alexnet_queue": qe[:, :2].mean(1).tolist(),   # UEs 0-1: AlexNet
        "resnet_queue": qe[:, 2:].mean(1).tolist(),    # UEs 2-4: ResNet18
    }
artifacts["fig5"] = fig5
for task, idx in [("alexnet", "alexnet_queue"), ("resnet", "resnet_queue")]:
    peak_l = max(fig5["lymdo"][idx])
    peak_j = max(fig5["ppo_joint"][idx])
    artifacts[f"fig5_{task}_queue_reduction"] = 1.0 - peak_l / max(peak_j, 1e-9)

with open(os.path.join(OUT, "paper_artifacts.json"), "w") as f:
    json.dump(artifacts, f)
print("headline: %.1f%% delay reduction vs joint PPO (best %.1f%%)"
      % (100 * artifacts["headline_delay_reduction_vs_ppo"],
         100 * artifacts["headline_delay_reduction_best"]), flush=True)
print("saved paper_artifacts.json")
