"""Shared model primitives: norms, RoPE, initializers, dtype policy."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def dtype_of(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16}[name]


def dense_init(key, shape, dtype, scale: float | None = None):
    """Truncated-normal fan-in init (LeCun-ish), cast to param dtype."""
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = scale if scale is not None else 1.0 / jnp.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


def rms_norm(x, scale, eps: float = 1e-6):
    """RMSNorm in fp32, output cast back to input dtype."""
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    normed = x32 * jax.lax.rsqrt(var + eps)
    return (normed * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def head_rms_norm(x, scale, eps: float = 1e-6):
    """Per-head QK-norm (Qwen3/Gemma3): normalizes the head_dim axis."""
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)
            * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def rope(x, positions, theta: float):
    """Rotary embeddings. x: (..., S, H, hd); positions: (..., S) or (S,)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions.astype(jnp.float32)[..., None] * freqs  # (..., S, half)
    cos = jnp.cos(angles)[..., None, :]   # broadcast over heads
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    x32_1, x32_2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([x32_1 * cos - x32_2 * sin,
                           x32_2 * cos + x32_1 * sin], axis=-1)
    return out.astype(x.dtype)


def causal_mask(s_q: int, s_k: int, q_offset=0):
    """(s_q, s_k) boolean mask: query i attends key j iff j <= i + offset."""
    qi = jnp.arange(s_q)[:, None] + q_offset
    kj = jnp.arange(s_k)[None, :]
    return kj <= qi


def local_mask(s_q: int, s_k: int, window: int, q_offset=0):
    qi = jnp.arange(s_q)[:, None] + q_offset
    kj = jnp.arange(s_k)[None, :]
    return (kj <= qi) & (kj > qi - window)


def pad_reset(pad_mask):
    """Scan-reset mask for a LEFT-padded batch: (B, S) valid-mask -> (B, S)
    bool that is True on every pad position AND on each row's first real
    token.  Feeding it to the reset-aware scan kernels zeroes the carried
    state through the pad run and again entering the first real token, so
    recurrent state can never leak from pad filler into real positions
    (belt and braces on top of the zeroed pad inputs)."""
    pads = ~pad_mask
    prev_pad = jnp.pad(pads[:, :-1], ((0, 0), (1, 0)), constant_values=False)
    return pads | prev_pad
