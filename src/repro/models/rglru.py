"""Griffin recurrent block: temporal conv + RG-LRU (arXiv:2402.19427).

Block:  x -> { gelu(W_gate x) } * RGLRU(conv1d(W_x x)) -> W_out
RG-LRU: r_t = sigmoid(W_r u_t); i_t = sigmoid(W_i u_t)
        log a_t = -c * softplus(Lambda) * r_t        (c = 8)
        h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * u_t)

The linear recurrence runs as an associative scan (rglru_scan kernel);
decode keeps (conv_state, h) -- constant memory in sequence length.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..kernels import ops
from .common import dense_init, dtype_of, pad_reset

_C = 8.0


class RglruCache(NamedTuple):
    conv: jax.Array   # (B, conv_width-1, R)
    h: jax.Array      # (B, R) fp32 recurrent state


def init_rglru(key, cfg):
    d, r = cfg.d_model, cfg.resolved_rnn_width
    dt = dtype_of(cfg.param_dtype)
    ks = jax.random.split(key, 6)
    return {
        "w_x": dense_init(ks[0], (d, r), dt),
        "w_gate": dense_init(ks[1], (d, r), dt),
        "conv": dense_init(ks[2], (cfg.conv_width, r), dt, scale=0.5),
        "w_r": dense_init(ks[3], (r, r), dt),
        "w_i": dense_init(ks[4], (r, r), dt),
        "lam": jnp.full((r,), 0.65, jnp.float32),   # a ~ 0.9..0.99 range
        "w_out": dense_init(ks[5], (r, d), dt),
    }


def _conv_full(params, u):
    w = params["conv"].astype(jnp.float32)
    k = w.shape[0]
    u32 = u.astype(jnp.float32)
    pad = jnp.pad(u32, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + u32.shape[1]] * w[i] for i in range(k))
    return out.astype(u.dtype)


def _gates(params, u):
    r = jax.nn.sigmoid((u @ params["w_r"]).astype(jnp.float32))
    i = jax.nn.sigmoid((u @ params["w_i"]).astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(params["lam"]) * r
    a = jnp.exp(log_a)
    scale = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-6))
    drive = scale * i * u.astype(jnp.float32)
    return a, drive


def apply_rglru(params, cfg, x, want_cache: bool = False, pad_mask=None):
    """Full-sequence Griffin recurrent mixer. x: (B,S,D) -> (B,S,D).

    ``pad_mask`` (B, S) bool marks valid (non-left-pad) positions of ragged
    serving batches: pad positions are zeroed AHEAD of the temporal conv (so
    the first real tokens' conv windows see the same zeros a solo run's left
    conv padding provides) and a reset mask threads into the RG-LRU scan so
    no recurrent state crosses from pad filler into real tokens.  A padded
    row's outputs and (conv, h) cache equal its solo run's.
    """
    u_pre = x @ params["w_x"]
    reset = None
    if pad_mask is not None:
        u_pre = jnp.where(pad_mask[:, :, None], u_pre, 0.0)
        reset = pad_reset(pad_mask)
    u = _conv_full(params, u_pre)
    a, drive = _gates(params, u)
    h = ops.rglru_scan(drive, a, reset=reset)
    gate = jax.nn.gelu((x @ params["w_gate"]).astype(jnp.float32))
    y = (gate * h.astype(jnp.float32)).astype(x.dtype)
    out = y @ params["w_out"]
    if not want_cache:
        return out
    k, s = cfg.conv_width, x.shape[1]
    conv_tail = u_pre[:, -(k - 1):, :] if s >= k - 1 else jnp.pad(
        u_pre, ((0, 0), (k - 1 - s, 0), (0, 0)))
    return out, RglruCache(conv=conv_tail,
                           h=h[:, -1].astype(jnp.float32))


def init_rglru_cache(cfg, batch: int, dtype) -> RglruCache:
    r = cfg.resolved_rnn_width
    return RglruCache(conv=jnp.zeros((batch, cfg.conv_width - 1, r), dtype),
                      h=jnp.zeros((batch, r), jnp.float32))


def apply_rglru_decode(params, cfg, x, cache: RglruCache):
    """Single-token step. x: (B,1,D)."""
    u_pre = (x[:, 0] @ params["w_x"])
    hist = jnp.concatenate([cache.conv, u_pre[:, None, :]], axis=1)
    w = params["conv"].astype(jnp.float32)
    u = jnp.einsum("bkr,kr->br", hist.astype(jnp.float32), w).astype(x.dtype)
    a, drive = _gates(params, u)
    h = a * cache.h + drive
    gate = jax.nn.gelu((x[:, 0] @ params["w_gate"]).astype(jnp.float32))
    y = (gate * h).astype(x.dtype) @ params["w_out"]
    return y[:, None, :], RglruCache(conv=hist[:, 1:], h=h)
