"""Unified model core for all 10 assigned architectures.

The layer stack is organized as ``n_units`` repetitions of
``cfg.block_pattern`` (+ an explicit ``tail_pattern``), scanned with
``lax.scan`` over stacked unit parameters — heterogeneous stacks (5:1
local:global, dense/MoE alternation, Griffin 1:2, interleaved cross-attn)
stay exact while the HLO stays one-unit-sized (DESIGN §4).

Three entry points:
  * ``forward_train``: teacher-forced logits (+ MoE aux loss)
  * ``prefill``:       builds the serving cache, returns last-token logits
  * ``decode_step``:   one token against the cache

Caches are pytrees mirroring the unit structure; "l" layers hold ring
buffers (window slots), "r"/"s" layers hold recurrent state — constant
memory in context length (why hybrid/ssm archs run long_500k).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from .. import shardctx
from . import attention as attn
from . import ffn as ffn_mod
from . import rglru as rglru_mod
from . import ssm as ssm_mod
from .common import dense_init, dtype_of, embed_init, rms_norm

Params = Any
Cache = Any


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_layer(key, cfg, kind: str) -> Params:
    ks = jax.random.split(key, 6)
    dt = dtype_of(cfg.param_dtype)
    d = cfg.d_model
    norm = lambda: jnp.zeros((d,), dt)
    if kind == "s":
        return {"ssm": ssm_mod.init_ssm(ks[0], cfg)}
    if kind == "r":
        return {"norm1": norm(), "rglru": rglru_mod.init_rglru(ks[0], cfg),
                "norm2": norm(), "ffn": ffn_mod.init_ffn(ks[1], cfg)}
    if kind == "m":
        return {"norm1": norm(), "attn": attn.init_attention(ks[0], cfg),
                "norm2": norm(), "moe": ffn_mod.init_moe(ks[1], cfg)}
    if kind == "x":
        return {"norm1": norm(),
                "xattn": attn.init_attention(ks[0], cfg, cross=True),
                "norm2": norm(), "ffn": ffn_mod.init_ffn(ks[1], cfg)}
    if kind == "d":
        return {"norm1": norm(), "attn": attn.init_attention(ks[0], cfg),
                "norm_x": norm(),
                "xattn": attn.init_attention(ks[1], cfg, cross=True),
                "norm2": norm(), "ffn": ffn_mod.init_ffn(ks[2], cfg)}
    # "g" | "l" | "e"
    return {"norm1": norm(), "attn": attn.init_attention(ks[0], cfg),
            "norm2": norm(), "ffn": ffn_mod.init_ffn(ks[1], cfg)}


def _init_stack(key, cfg, pattern, n: int) -> Params:
    """Stacked params: {"slot{i}": vmapped init over n copies}."""
    out = {}
    for i, kind in enumerate(pattern):
        key, sub = jax.random.split(key)
        keys = jax.random.split(sub, n)
        out[f"slot{i}"] = jax.vmap(
            functools.partial(_init_layer, cfg=cfg, kind=kind))(keys)
    return out


def init_params(key, cfg) -> Params:
    ks = jax.random.split(key, 6)
    params = {
        "embed": embed_init(ks[0], (cfg.vocab, cfg.d_model),
                            dtype_of(cfg.param_dtype)),
        "units": _init_stack(ks[1], cfg, cfg.block_pattern, cfg.n_units),
        "final_norm": jnp.zeros((cfg.d_model,), dtype_of(cfg.param_dtype)),
    }
    if cfg.tail_pattern:
        params["tail"] = [
            _init_layer(k, cfg, kind) for k, kind in
            zip(jax.random.split(ks[2], len(cfg.tail_pattern)),
                cfg.tail_pattern)]
    if not cfg.tie_embeddings:
        params["head"] = dense_init(ks[3], (cfg.d_model, cfg.vocab),
                                    dtype_of(cfg.param_dtype))
    if cfg.enc_layers:
        params["encoder"] = {
            "units": _init_stack(ks[4], cfg, ("e",), cfg.enc_layers),
            "final_norm": jnp.zeros((cfg.d_model,),
                                    dtype_of(cfg.param_dtype)),
        }
    return params


def param_count(params) -> int:
    return sum(int(p.size) for p in jax.tree.leaves(params))


# ---------------------------------------------------------------------------
# full-sequence layer application (train / prefill)
# ---------------------------------------------------------------------------

def _layer_full(p, cfg, kind, x, positions, ctx, want_cache: bool,
                s_max: int = 0, pad_mask=None):
    """Apply one layer to a full sequence.  Returns (x, aux, cache).

    ``pad_mask`` (B, S) marks valid (non-left-pad) positions of ragged
    serving batches, and EVERY kind honors it: attention layers mask pad
    keys, recurrent kinds ("r"/"s") zero pad inputs ahead of their causal
    convs and thread a reset mask through the scan kernels -- a left-padded
    row equals its solo run on any stack the engine can serve.
    """
    aux = jnp.zeros((), jnp.float32)
    cache = ()
    cdt = dtype_of(cfg.compute_dtype)
    if kind == "s":
        if want_cache:
            y, cache = ssm_mod.apply_ssm(p["ssm"], cfg, x, want_cache=True,
                                         pad_mask=pad_mask)
        else:
            y = ssm_mod.apply_ssm(p["ssm"], cfg, x, pad_mask=pad_mask)
        return x + y, aux, cache
    if kind == "r":
        normed = rms_norm(x, p["norm1"])
        if want_cache:
            h, cache = rglru_mod.apply_rglru(p["rglru"], cfg, normed,
                                             want_cache=True,
                                             pad_mask=pad_mask)
        else:
            h = rglru_mod.apply_rglru(p["rglru"], cfg, normed,
                                      pad_mask=pad_mask)
        x = x + h
        x = x + ffn_mod.apply_ffn(p["ffn"], cfg, rms_norm(x, p["norm2"]))
        return x, aux, cache
    if kind == "x":
        normed = rms_norm(x, p["norm1"])
        kv = attn.context_kv(p["xattn"], cfg, ctx)
        x = x + attn.cross_attention(p["xattn"], cfg, normed, kv)
        x = x + ffn_mod.apply_ffn(p["ffn"], cfg, rms_norm(x, p["norm2"]))
        if want_cache:
            cache = {"ctx_kv": kv}
        return x, aux, cache
    if kind == "d":
        normed = rms_norm(x, p["norm1"])
        out, (k, v) = attn.self_attention(p["attn"], cfg, normed,
                                          positions, kind="g",
                                          pad_mask=pad_mask)
        x = x + out
        kv = attn.context_kv(p["xattn"], cfg, ctx)
        x = x + attn.cross_attention(p["xattn"], cfg,
                                     rms_norm(x, p["norm_x"]), kv)
        x = x + ffn_mod.apply_ffn(p["ffn"], cfg, rms_norm(x, p["norm2"]))
        if want_cache:
            cache = {"self": _fill_kv(cfg, k, v, s_max, cdt), "ctx_kv": kv}
        return x, aux, cache

    # attention layers: g / l / e / m
    akind = "l" if kind == "l" else ("e" if kind == "e" else "g")
    normed = rms_norm(x, p["norm1"])
    out, (k, v) = attn.self_attention(p["attn"], cfg, normed, positions,
                                      kind=akind, pad_mask=pad_mask)
    x = x + out
    if kind == "m":
        y, aux = ffn_mod.apply_moe(p["moe"], cfg, rms_norm(x, p["norm2"]))
        x = x + y
    else:
        x = x + ffn_mod.apply_ffn(p["ffn"], cfg, rms_norm(x, p["norm2"]))
    if want_cache:
        if kind == "l":
            ring = attn.init_ring_cache(cfg, x.shape[0], cdt)
            cache = attn.prefill_into_ring(ring, k.astype(cdt),
                                           v.astype(cdt), k.shape[1])
        elif kind != "e":
            cache = _fill_kv(cfg, k, v, s_max, cdt)
    return x, aux, cache


def _fill_kv(cfg, k, v, s_max, dtype):
    full = attn.init_kv_cache(cfg, k.shape[0], s_max, dtype)
    return attn.prefill_into_kv(full, k.astype(dtype), v.astype(dtype))


def _run_stack(params, cfg, pattern, x, positions, ctx, want_cache,
               s_max=0, remat=False, pad_mask=None):
    """Scan over stacked units, then apply tail layers.  Returns
    (x, aux_sum, caches) with caches = {"units": ..., "tail": [...]}.
    """

    def unit_fn(x, unit_p):
        aux = jnp.zeros((), jnp.float32)
        caches = {}
        x = shardctx.constrain(x, "dp", "sp", None)
        for i, kind in enumerate(pattern):
            x, a, c = _layer_full(unit_p[f"slot{i}"], cfg, kind, x,
                                  positions, ctx, want_cache, s_max,
                                  pad_mask=pad_mask)
            x = shardctx.constrain(x, "dp", "sp", None)
            aux = aux + a
            caches[f"slot{i}"] = c
        return x, (aux, caches)

    if remat:
        if shardctx.remat_offload_active():
            # host-offloaded carry stacks: HBM holds one unit's activations,
            # the saved per-unit inputs stream to host DRAM (§Perf cell B).
            policy = jax.checkpoint_policies.save_and_offload_only_these_names(
                names_which_can_be_saved=[],
                names_which_can_be_offloaded=["unit_carry"],
                offload_src="device", offload_dst="pinned_host")
            inner = unit_fn

            def named_unit(x, unit_p):
                from jax.ad_checkpoint import checkpoint_name
                return inner(checkpoint_name(x, "unit_carry"), unit_p)

            unit_fn = jax.checkpoint(named_unit, policy=policy)
        else:
            unit_fn = jax.checkpoint(unit_fn)

    def scan_body(carry, unit_p):
        x, aux = carry
        x, (a, caches) = unit_fn(x, unit_p)
        return (x, aux + a), caches

    (x, aux), unit_caches = jax.lax.scan(
        scan_body, (x, jnp.zeros((), jnp.float32)), params["units"])

    tail_caches = []
    for tp, kind in zip(params.get("tail", []), cfg.tail_pattern):
        x, a, c = _layer_full(tp, cfg, kind, x, positions, ctx,
                              want_cache, s_max, pad_mask=pad_mask)
        aux = aux + a
        tail_caches.append(c)
    return x, aux, {"units": unit_caches, "tail": tail_caches}


def _encode(params, cfg, src_embeds):
    """Run the (bidirectional) encoder stack on frame embeddings."""
    enc = params["encoder"]
    pos = jnp.arange(src_embeds.shape[1])
    x = src_embeds.astype(dtype_of(cfg.compute_dtype))

    def unit_fn(x, unit_p):
        x, _, _ = _layer_full(unit_p["slot0"], cfg, "e", x, pos, None, False)
        return x, None

    x, _ = jax.lax.scan(lambda c, p: unit_fn(c, p), x, enc["units"])
    return rms_norm(x, enc["final_norm"])


def _context(params, cfg, batch):
    """Cross-attention context: image embeds (vlm) or encoder output (audio)."""
    if cfg.frontend == "vision":
        return batch["image_embeds"].astype(dtype_of(cfg.compute_dtype))
    if cfg.enc_layers:
        return _encode(params, cfg, batch["src_embeds"])
    return None


def _logits(params, cfg, x):
    x = rms_norm(x, params["final_norm"])
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    logits = (x @ head).astype(jnp.float32)
    return shardctx.constrain(logits, "dp", None, "tp")


# ---------------------------------------------------------------------------
# public entry points
# ---------------------------------------------------------------------------

def forward_train(params, cfg, batch):
    """Teacher-forced logits.  batch: tokens (B,S) [+ image_embeds /
    src_embeds].  Returns (logits (B,S,V) fp32, aux)."""
    tokens = batch["tokens"]
    x = params["embed"][tokens].astype(dtype_of(cfg.compute_dtype))
    x = shardctx.constrain(x, "dp", "sp", None)
    ctx = _context(params, cfg, batch)
    positions = jnp.arange(tokens.shape[1])
    x, aux, _ = _run_stack(params, cfg, cfg.block_pattern, x, positions, ctx,
                           want_cache=False, remat=cfg.remat)
    return _logits(params, cfg, x), aux


def prefill(params, cfg, batch, s_max: int, pad=None):
    """Build the serving cache from a prompt.  Returns (last-token logits
    (B,V), cache).  ``s_max`` sizes the KV buffers (prompt + decode budget).

    ``pad`` (B,) int32 gives each row's LEFT-pad token count for ragged
    batches: attention masks the pad positions and RoPE uses the shifted
    per-row positions; recurrent ("r"/"s") layers zero pad inputs ahead of
    their convs and reset the scan state at the pad boundary -- a padded
    prompt's logits, KV/ring caches, and recurrent state exactly equal its
    solo run on every stack kind.  The pad vector rides in the cache
    (``caches["pad"]``) so ``decode_step`` keeps masking those slots;
    padless calls leave the cache structure unchanged.
    """
    # named for profiler dumps (pairs with the host "prefill" span the
    # serving telemetry records; see docs/observability.md)
    with jax.named_scope("repro.prefill"):
        tokens = batch["tokens"]
        x = params["embed"][tokens].astype(dtype_of(cfg.compute_dtype))
        ctx = _context(params, cfg, batch)
        s = tokens.shape[1]
        if pad is None:
            positions = jnp.arange(s)
            pad_mask = None
        else:
            pad = jnp.asarray(pad, jnp.int32)
            # row i's first real token sits at index pad[i] -> position 0
            positions = jnp.maximum(jnp.arange(s)[None, :] - pad[:, None], 0)
            pad_mask = jnp.arange(s)[None, :] >= pad[:, None]  # (B, S) valid
        x, _, caches = _run_stack(params, cfg, cfg.block_pattern, x,
                                  positions, ctx, want_cache=True,
                                  s_max=s_max, remat=False,
                                  pad_mask=pad_mask)
        caches["pos"] = jnp.int32(s)
        if pad is not None:
            caches["pad"] = pad
        logits = _logits(params, cfg, x[:, -1:])[:, 0]
        return logits, caches


# -- decode -------------------------------------------------------------------

def _layer_decode(p, cfg, kind, x, cache, pos, pad=None):
    """Single-token layer step.  Returns (x, new_cache)."""
    if kind == "s":
        y, cache = ssm_mod.apply_ssm_decode(p["ssm"], cfg, x, cache)
        return x + y, cache
    if kind == "r":
        h, cache = rglru_mod.apply_rglru_decode(
            p["rglru"], cfg, rms_norm(x, p["norm1"]), cache)
        x = x + h
        x = x + ffn_mod.apply_ffn(p["ffn"], cfg, rms_norm(x, p["norm2"]))
        return x, cache
    if kind == "x":
        normed = rms_norm(x, p["norm1"])
        x = x + attn.decode_cross_attention(p["xattn"], cfg, normed,
                                            cache["ctx_kv"])
        x = x + ffn_mod.apply_ffn(p["ffn"], cfg, rms_norm(x, p["norm2"]))
        return x, cache
    if kind == "d":
        normed = rms_norm(x, p["norm1"])
        out, new_self = attn.decode_self_attention(p["attn"], cfg, normed,
                                                   cache["self"], pos,
                                                   kind="g", pad=pad)
        x = x + out
        x = x + attn.decode_cross_attention(p["xattn"], cfg,
                                            rms_norm(x, p["norm_x"]),
                                            cache["ctx_kv"])
        x = x + ffn_mod.apply_ffn(p["ffn"], cfg, rms_norm(x, p["norm2"]))
        return x, {"self": new_self, "ctx_kv": cache["ctx_kv"]}

    akind = "l" if kind == "l" else "g"
    normed = rms_norm(x, p["norm1"])
    out, cache = attn.decode_self_attention(p["attn"], cfg, normed, cache,
                                            pos, kind=akind, pad=pad)
    x = x + out
    if kind == "m":
        y, _ = ffn_mod.apply_moe(p["moe"], cfg, rms_norm(x, p["norm2"]))
        x = x + y
    else:
        x = x + ffn_mod.apply_ffn(p["ffn"], cfg, rms_norm(x, p["norm2"]))
    return x, cache


def decode_step(params, cfg, caches, tokens):
    """One decode step.  tokens: (B,) int32.  Returns (logits (B,V), caches).
    The write position comes from ``caches["pos"]`` (synchronized batch);
    a ``caches["pad"]`` vector (ragged prefill) keeps per-row RoPE positions
    shifted and pad cache slots masked."""
    pos = caches["pos"]
    pad = caches.get("pad")
    x = params["embed"][tokens][:, None, :].astype(dtype_of(cfg.compute_dtype))

    def scan_body(x, inp):
        unit_p, unit_c = inp
        new_c = {}
        for i, kind in enumerate(cfg.block_pattern):
            x, c = _layer_decode(unit_p[f"slot{i}"], cfg, kind, x,
                                 unit_c[f"slot{i}"], pos, pad)
            new_c[f"slot{i}"] = c
        return x, new_c

    x, new_unit_caches = jax.lax.scan(
        scan_body, x, (params["units"], caches["units"]))

    new_tail = []
    for tp, kind, tc in zip(params.get("tail", []), cfg.tail_pattern,
                            caches["tail"]):
        x, c = _layer_decode(tp, cfg, kind, x, tc, pos, pad)
        new_tail.append(c)

    logits = _logits(params, cfg, x)[:, 0]
    new_caches = {"units": new_unit_caches, "tail": new_tail,
                  "pos": pos + 1}
    if pad is not None:
        new_caches["pad"] = pad
    return logits, new_caches


def _layer_chunk(p, cfg, kind, x, cache, start, positions, ok):
    """One layer over a prefill chunk.  x (1, C, D) holds the chunk's tokens
    at absolute positions ``positions = start + arange(C)``; ``ok`` (C,) bool
    marks real (non-right-pad) tokens of the final partial chunk.

    Attention kinds ("g"/"m") run chunk-parallel against the dense scratch
    cache; stateful kinds ("l"/"r"/"s") scan the EXISTING single-token decode
    step across the chunk -- zero new recurrence math -- selecting the old
    cache carry on junk steps so right-pad never advances state.  Junk rows'
    activations are garbage by construction; callers slice the last valid
    row only.  Returns (x, new_cache).
    """
    def masked(step):
        # scan one decode-form step per chunk token; junk steps keep the
        # incoming cache so the final partial chunk is exact
        def body(c, inp):
            xt, pos_t, ok_t = inp
            y, new_c = step(xt[:, None, :], c, pos_t)
            new_c = jax.tree.map(lambda a, b: jnp.where(ok_t, a, b),
                                 new_c, c)
            return new_c, y[:, 0]
        return body

    if kind == "s":
        body = masked(lambda xt, c, _:
                      ssm_mod.apply_ssm_decode(p["ssm"], cfg, xt, c))
        cache, ys = jax.lax.scan(body, cache,
                                 (jnp.swapaxes(x, 0, 1), positions, ok))
        return x + jnp.swapaxes(ys, 0, 1), cache
    if kind == "r":
        normed = rms_norm(x, p["norm1"])
        body = masked(lambda xt, c, _:
                      rglru_mod.apply_rglru_decode(p["rglru"], cfg, xt, c))
        cache, hs = jax.lax.scan(body, cache,
                                 (jnp.swapaxes(normed, 0, 1), positions, ok))
        x = x + jnp.swapaxes(hs, 0, 1)
        x = x + ffn_mod.apply_ffn(p["ffn"], cfg, rms_norm(x, p["norm2"]))
        return x, cache
    if kind == "l":
        normed = rms_norm(x, p["norm1"])
        body = masked(lambda xt, c, pos_t: attn.decode_self_attention(
            p["attn"], cfg, xt, c, pos_t, kind="l"))
        cache, outs = jax.lax.scan(body, cache,
                                   (jnp.swapaxes(normed, 0, 1), positions, ok))
        x = x + jnp.swapaxes(outs, 0, 1)
        x = x + ffn_mod.apply_ffn(p["ffn"], cfg, rms_norm(x, p["norm2"]))
        return x, cache
    if kind != "g":
        # "m" is deliberately excluded: capacity-based MoE routing couples
        # every token in a dispatch group (cumsum capacity contention), so
        # a chunk-local pass cannot reproduce the whole-prompt dispatch
        # exactly -- the engine keeps whole-prompt prefill for MoE stacks
        # (ServingEngine disables prefill_chunk when the pattern has "m").
        # "x"/"d"/"e" are not continuously servable at all
        # (kvpool._check_pattern).
        raise NotImplementedError(
            f"chunked prefill does not serve kind {kind!r}")

    normed = rms_norm(x, p["norm1"])
    out, cache = attn.chunk_self_attention(p["attn"], cfg, normed, cache,
                                           start, positions)
    x = x + out
    x = x + ffn_mod.apply_ffn(p["ffn"], cfg, rms_norm(x, p["norm2"]))
    return x, cache


def prefill_chunk(params, cfg, caches, tokens, start, n_valid):
    """Advance a resumable chunked prefill by one chunk.

    ``caches`` is the {"units", "tail"} core of a batch-1 :func:`prefill`
    cache holding the first ``start`` prompt tokens (chunk 1 IS a plain
    ``prefill`` at the chunk width -- its KV scratch is already sized
    ``s_max``); ``tokens`` (1, C) carries the next chunk, right-padded past
    ``n_valid`` on the final partial chunk.  ``start`` and ``n_valid`` may be
    traced: the serving engine compiles ONE chunk program per chunk width.

    Returns (logits (1, V) of token ``start + n_valid - 1``, new caches with
    the same treedef) -- on the final chunk those logits ARE the whole-prompt
    prefill logits, exactly (attention kinds recompute the identical
    prefix-causal softmax; stateful kinds replay the decode-form recurrence).

    Named ``repro.prefill_chunk`` for profiler dumps (pairs with the host
    "prefill" span the serving telemetry records per chunk).
    """
    with jax.named_scope("repro.prefill_chunk"):
        c = tokens.shape[1]
        x = params["embed"][tokens].astype(dtype_of(cfg.compute_dtype))
        positions = start + jnp.arange(c)
        ok = jnp.arange(c) < n_valid

        def scan_body(x, inp):
            unit_p, unit_c = inp
            new_c = {}
            for i, kind in enumerate(cfg.block_pattern):
                x, cc = _layer_chunk(unit_p[f"slot{i}"], cfg, kind, x,
                                     unit_c[f"slot{i}"], start, positions, ok)
                new_c[f"slot{i}"] = cc
            return x, new_c

        x, new_unit_caches = jax.lax.scan(
            scan_body, x, (params["units"], caches["units"]))

        new_tail = []
        for tp, kind, tc in zip(params.get("tail", []), cfg.tail_pattern,
                                caches["tail"]):
            x, cc = _layer_chunk(tp, cfg, kind, x, tc, start, positions, ok)
            new_tail.append(cc)

        last = jax.lax.dynamic_slice_in_dim(x, n_valid - 1, 1, axis=1)
        logits = _logits(params, cfg, last)[:, 0]
        return logits, {"units": new_unit_caches, "tail": new_tail}


def _layer_decode_paged(p, cfg, kind, x, cache, block_table, seq_lens):
    """Single-token layer step with per-slot cache positions.  Recurrent
    kinds keep per-row O(1) state, so they are position-free and reuse the
    synchronized step; attention kinds go through the paged/per-slot path.
    """
    if kind in ("s", "r"):
        return _layer_decode(p, cfg, kind, x, cache, None)
    akind = "l" if kind == "l" else "g"
    normed = rms_norm(x, p["norm1"])
    out, cache = attn.decode_self_attention_paged(
        p["attn"], cfg, normed, cache, kind=akind,
        block_table=block_table, seq_lens=seq_lens)
    x = x + out
    if kind == "m":
        y, _ = ffn_mod.apply_moe(p["moe"], cfg, rms_norm(x, p["norm2"]))
        x = x + y
    else:
        x = x + ffn_mod.apply_ffn(p["ffn"], cfg, rms_norm(x, p["norm2"]))
    return x, cache


def decode_step_paged(params, cfg, caches, tokens, block_table, seq_lens):
    """One continuous-batching decode step.  tokens: (B,) int32; ``caches``
    is the pool state from ``serving.kvpool.init_decode_state`` (global KV
    paged, ring/recurrent per-slot); ``block_table`` (B, M) int32 and
    ``seq_lens`` (B,) int32 carry each slot's blocks and cache length --
    there is no shared ``pos`` frontier and no pad vector.  Returns
    (logits (B, V), caches).  Cross-attention kinds are not servable here
    (see ``kvpool._check_pattern``).

    Named ``repro.decode_paged`` for profiler dumps (pairs with the host
    "decode_tick" span the serving telemetry records)."""
    with jax.named_scope("repro.decode_paged"):
        seq_lens = jnp.asarray(seq_lens, jnp.int32)
        x = params["embed"][tokens][:, None, :].astype(
            dtype_of(cfg.compute_dtype))

        def scan_body(x, inp):
            unit_p, unit_c = inp
            new_c = {}
            for i, kind in enumerate(cfg.block_pattern):
                x, c = _layer_decode_paged(unit_p[f"slot{i}"], cfg, kind, x,
                                           unit_c[f"slot{i}"], block_table,
                                           seq_lens)
                new_c[f"slot{i}"] = c
            return x, new_c

        x, new_unit_caches = jax.lax.scan(
            scan_body, x, (params["units"], caches["units"]))

        new_tail = []
        for tp, kind, tc in zip(params.get("tail", []), cfg.tail_pattern,
                                caches["tail"]):
            x, c = _layer_decode_paged(tp, cfg, kind, x, tc, block_table,
                                       seq_lens)
            new_tail.append(c)

        logits = _logits(params, cfg, x)[:, 0]
        return logits, {"units": new_unit_caches, "tail": new_tail}
