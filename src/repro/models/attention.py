"""GQA attention: init + train/prefill/decode paths, KV + ring-buffer caches.

The dense softmax path here is the *reference* implementation; on TPU the
Pallas kernels (``repro.kernels.ops.flash_attention`` / ``decode_attention``)
replace the inner computation — see ``repro.kernels.ops.use_pallas``.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .common import dense_init, head_rms_norm, rope

_NEG = -1e30


class KVCache(NamedTuple):
    """Global-attention cache: full-length K/V plus the write position."""
    k: jax.Array   # (B, S_max, KV, hd)
    v: jax.Array


class RingCache(NamedTuple):
    """Sliding-window cache: fixed ``window`` slots + absolute positions."""
    k: jax.Array       # (B, W, KV, hd)
    v: jax.Array
    pos: jax.Array     # (B, W) int32 absolute position of each slot, -1 empty


def init_attention(key, cfg, *, cross: bool = False, prefix: str = ""):
    """Parameters for one attention sub-block (self or cross)."""
    d, hd = cfg.d_model, cfg.resolved_head_dim
    h, kv = cfg.n_heads, cfg.n_kv
    ks = jax.random.split(key, 4)
    dt = _pdtype(cfg)
    p = {
        "wq": dense_init(ks[0], (d, h * hd), dt),
        "wk": dense_init(ks[1], (d, kv * hd), dt),
        "wv": dense_init(ks[2], (d, kv * hd), dt),
        "wo": dense_init(ks[3], (h * hd, d), dt, scale=1.0 / jnp.sqrt(h * hd)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), dt)
        p["bk"] = jnp.zeros((kv * hd,), dt)
        p["bv"] = jnp.zeros((kv * hd,), dt)
    if cfg.qk_norm and not cross:
        p["q_norm"] = jnp.zeros((hd,), dt)
        p["k_norm"] = jnp.zeros((hd,), dt)
    return p


def _pdtype(cfg):
    from .common import dtype_of
    return dtype_of(cfg.param_dtype)


def _project_q(p, cfg, x):
    h, hd = cfg.n_heads, cfg.resolved_head_dim
    q = x @ p["wq"]
    if "bq" in p:
        q = q + p["bq"]
    q = q.reshape(*x.shape[:-1], h, hd)
    if "q_norm" in p:
        q = head_rms_norm(q, p["q_norm"])
    return q


def _project_kv(p, cfg, x):
    kv, hd = cfg.n_kv, cfg.resolved_head_dim
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bk" in p:
        k, v = k + p["bk"], v + p["bv"]
    k = k.reshape(*x.shape[:-1], kv, hd)
    v = v.reshape(*x.shape[:-1], kv, hd)
    if "k_norm" in p:
        k = head_rms_norm(k, p["k_norm"])
    return k, v


def self_attention(p, cfg, x, positions, *, kind: str, pad_mask=None):
    """Train/prefill full-sequence self-attention.  kind: g|l|e.

    ``positions`` may be (S,) or per-row (B, S) -- left-padded batches pass
    shifted positions so RoPE sees each row's true token index.  ``pad_mask``
    (B, S) marks valid (non-pad) positions; see ``ops.flash_attention``.
    """
    from ..kernels import ops
    q = _project_q(p, cfg, x)
    k, v = _project_kv(p, cfg, x)
    if cfg.rope_theta and kind != "e_nopos":
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    akind = {"l": "local", "e": "full"}.get(kind, "causal")
    out = ops.flash_attention(q, k, v, kind=akind, window=cfg.window,
                              pad_mask=pad_mask)
    out = out.reshape(*x.shape[:-1], -1)
    return out @ p["wo"], (k, v)


def cross_attention(p, cfg, x, context_kv):
    """Cross-attention against precomputed context K/V (no RoPE)."""
    from ..kernels import ops
    q = _project_q(p, cfg, x)
    k, v = context_kv
    out = ops.flash_attention(q, k, v, kind="full")
    out = out.reshape(*x.shape[:-1], -1)
    return out @ p["wo"]


def context_kv(p, cfg, context):
    """Precompute cross-attention K/V from context embeddings (prefill)."""
    return _project_kv(p, cfg, context)


# -- caches -----------------------------------------------------------------

def init_kv_cache(cfg, batch: int, s_max: int, dtype) -> KVCache:
    kv, hd = cfg.n_kv, cfg.resolved_head_dim
    return KVCache(k=jnp.zeros((batch, s_max, kv, hd), dtype),
                   v=jnp.zeros((batch, s_max, kv, hd), dtype))


def init_ring_cache(cfg, batch: int, dtype) -> RingCache:
    kv, hd, w = cfg.n_kv, cfg.resolved_head_dim, cfg.window
    return RingCache(k=jnp.zeros((batch, w, kv, hd), dtype),
                     v=jnp.zeros((batch, w, kv, hd), dtype),
                     pos=jnp.full((batch, w), -1, jnp.int32))


def prefill_into_kv(cache: KVCache, k, v) -> KVCache:
    return KVCache(k=jax.lax.dynamic_update_slice_in_dim(cache.k, k, 0, 1),
                   v=jax.lax.dynamic_update_slice_in_dim(cache.v, v, 0, 1))


def prefill_into_ring(cache: RingCache, k, v, length: int) -> RingCache:
    """Store the last ``window`` entries of a prefilled sequence, placed at
    their ring slots (slot = pos % window) so decode writes continue cleanly."""
    w = cache.k.shape[1]
    s = k.shape[1]
    take = min(w, s)
    pos = jnp.arange(s - take, s)                      # absolute positions
    slots = pos % w
    k_tail = k[:, s - take:]
    v_tail = v[:, s - take:]
    new_k = cache.k.at[:, slots].set(k_tail)
    new_v = cache.v.at[:, slots].set(v_tail)
    new_pos = cache.pos.at[:, slots].set(pos[None, :])
    return RingCache(k=new_k, v=new_v, pos=new_pos)


def decode_self_attention(p, cfg, x, cache, pos, *, kind: str, pad=None):
    """Single-token decode: x (B, 1, D); returns (out, new_cache).

    ``pos`` is the shared cache write position (synchronized batch).  For a
    left-padded batch, ``pad`` (B,) gives each row's pad count: RoPE uses the
    semantic position ``pos - pad`` and cache slots below ``pad`` (the pad
    filler K/V written during prefill) are masked invalid.
    """
    q = _project_q(p, cfg, x)               # (B, 1, H, hd)
    k_new, v_new = _project_kv(p, cfg, x)   # (B, 1, KV, hd)
    if cfg.rope_theta:
        if pad is None:
            pvec = jnp.asarray(pos)[None]           # (1,) shared position
        else:
            pvec = (pos - pad)[:, None]             # (B, 1) per-row position
        q = rope(q, pvec, cfg.rope_theta)
        k_new = rope(k_new, pvec, cfg.rope_theta)

    from ..kernels import ops
    k_new = k_new.astype(cache.k.dtype)
    v_new = v_new.astype(cache.v.dtype)
    if kind == "l":
        w = cache.k.shape[1]
        slot = pos % w
        k = jax.lax.dynamic_update_slice_in_dim(cache.k, k_new, slot, 1)
        v = jax.lax.dynamic_update_slice_in_dim(cache.v, v_new, slot, 1)
        pos_buf = jax.lax.dynamic_update_slice_in_dim(
            cache.pos, jnp.full((cache.pos.shape[0], 1), pos, jnp.int32), slot, 1)
        valid = (pos_buf >= 0) & (pos_buf >= pos - w + 1)   # (B, W)
        if pad is not None:
            valid = valid & (pos_buf >= pad[:, None])
        out = ops.decode_attention(q, k, v, valid_mask=valid)
        new_cache = RingCache(k=k, v=v, pos=pos_buf)
    else:
        k = jax.lax.dynamic_update_slice_in_dim(cache.k, k_new, pos, 1)
        v = jax.lax.dynamic_update_slice_in_dim(cache.v, v_new, pos, 1)
        slots = jnp.arange(k.shape[1])
        valid = (slots <= pos)[None, :]                     # (1, S_max)
        valid = jnp.broadcast_to(valid, (k.shape[0], k.shape[1]))
        if pad is not None:
            valid = valid & (slots[None, :] >= pad[:, None])
        out = ops.decode_attention(q, k, v, valid_mask=valid)
        new_cache = KVCache(k=k, v=v)
    out = out.reshape(*x.shape[:-1], -1)
    return out @ p["wo"], new_cache


def chunk_self_attention(p, cfg, x, cache: KVCache, start, positions):
    """Resumable chunked prefill for global attention: x (B, C, D) holds the
    chunk's C tokens at absolute positions ``positions = start + arange(C)``;
    ``cache`` is a dense (B, S_max, KV, hd) scratch already holding the first
    ``start`` tokens' K/V.  Writes the chunk's K/V at ``start`` and attends
    with the prefix-causal mask (see :func:`ops.chunk_attention`), so the
    result for every valid row matches the whole-prompt prefill exactly.

    ``start`` may be traced: one compiled program serves every chunk index.
    Rows past the prompt's true length (the right-padded final chunk) produce
    junk outputs and junk scratch entries beyond the prompt -- callers slice
    logits at the last valid row and never commit positions >= the prompt
    length (``kvpool.commit_chunk``).
    """
    from ..kernels import ops
    q = _project_q(p, cfg, x)               # (B, C, H, hd)
    k_new, v_new = _project_kv(p, cfg, x)   # (B, C, KV, hd)
    if cfg.rope_theta:
        q = rope(q, positions, cfg.rope_theta)
        k_new = rope(k_new, positions, cfg.rope_theta)
    k = jax.lax.dynamic_update_slice_in_dim(
        cache.k, k_new.astype(cache.k.dtype), start, 1)
    v = jax.lax.dynamic_update_slice_in_dim(
        cache.v, v_new.astype(cache.v.dtype), start, 1)
    out = ops.chunk_attention(q, k, v, start=start)
    out = out.reshape(*x.shape[:-1], -1)
    return out @ p["wo"], KVCache(k=k, v=v)


def decode_self_attention_paged(p, cfg, x, cache, *, kind: str,
                                block_table, seq_lens):
    """Single-token decode against per-slot caches (continuous batching).

    Unlike :func:`decode_self_attention` there is no shared write frontier
    and no pad vector: each row ``i`` carries its own cache length
    ``seq_lens[i]`` (the position being written) and the caches are
    pad-free (see ``serving.kvpool.commit_prefill``).

    * ``kind == "g"``: ``cache`` is a pool :class:`KVCache`
      ``(n_blocks, block_size, KV, hd)``; ``block_table`` (B, M) maps each
      row's logical block index to a pool block.  The new K/V scatters into
      block ``block_table[i, seq_lens[i] // bs]`` at offset
      ``seq_lens[i] % bs``; attention gathers the row's blocks back into a
      contiguous ``(B, M*bs)`` view with positions ``> seq_lens[i]`` masked.
      Idle rows (``seq_lens == 0``, table all zeros) write to the reserved
      dummy block 0 -- harmless garbage nobody gathers as valid beyond
      position 0, and their outputs are discarded by the engine.
    * ``kind == "l"``: ``cache`` is a per-slot :class:`RingCache`; row
      ``i`` writes its ring slot ``seq_lens[i] % window`` (semantic
      positions -- commit re-slots prefill entries).
    """
    from ..kernels import ops
    q = _project_q(p, cfg, x)               # (B, 1, H, hd)
    k_new, v_new = _project_kv(p, cfg, x)   # (B, 1, KV, hd)
    if cfg.rope_theta:
        pvec = seq_lens[:, None]                    # (B, 1) per-row position
        q = rope(q, pvec, cfg.rope_theta)
        k_new = rope(k_new, pvec, cfg.rope_theta)
    k_new = k_new.astype(cache.k.dtype)
    v_new = v_new.astype(cache.v.dtype)
    b = x.shape[0]
    rows = jnp.arange(b)

    if kind == "l":
        w = cache.k.shape[1]
        slot = seq_lens % w                                  # (B,)
        k = cache.k.at[rows, slot].set(k_new[:, 0])
        v = cache.v.at[rows, slot].set(v_new[:, 0])
        pos_buf = cache.pos.at[rows, slot].set(seq_lens)
        valid = (pos_buf >= 0) & (pos_buf >= (seq_lens - w + 1)[:, None])
        out = ops.decode_attention(q, k, v, valid_mask=valid)
        new_cache = RingCache(k=k, v=v, pos=pos_buf)
    else:
        bs = cache.k.shape[1]                                # block_size
        m = block_table.shape[1]
        blk = block_table[rows, seq_lens // bs]              # (B,) pool ids
        off = seq_lens % bs
        k = cache.k.at[blk, off].set(k_new[:, 0])
        v = cache.v.at[blk, off].set(v_new[:, 0])
        kvh, hd = k.shape[-2:]
        k_rows = k[block_table].reshape(b, m * bs, kvh, hd)
        v_rows = v[block_table].reshape(b, m * bs, kvh, hd)
        valid = jnp.arange(m * bs)[None, :] <= seq_lens[:, None]
        out = ops.decode_attention(q, k_rows, v_rows, valid_mask=valid)
        new_cache = KVCache(k=k, v=v)
    out = out.reshape(*x.shape[:-1], -1)
    return out @ p["wo"], new_cache


def decode_cross_attention(p, cfg, x, context_cache):
    q = _project_q(p, cfg, x)
    k, v = context_cache
    from ..kernels import ops
    valid = jnp.ones((k.shape[0], k.shape[1]), bool)
    out = ops.decode_attention(q, k, v, valid_mask=valid)
    out = out.reshape(*x.shape[:-1], -1)
    return out @ p["wo"]
