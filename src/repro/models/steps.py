"""Train / serve step factories over the unified transformer core.

``make_train_step(cfg)`` returns a pure function
    (params, opt_state, batch) -> (params, opt_state, metrics)
with CE loss over (optionally vocab-sharded) fp32 logits, MoE load-balance
aux loss, and hand-rolled AdamW (moment dtype per cfg.opt_state_dtype).

``make_prefill`` / ``make_decode_step`` wrap the serving paths.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..optim.adam import adam
from .common import dtype_of
from . import transformer

MOE_AUX_COEF = 0.01


def cross_entropy(logits, targets, mask=None):
    """Mean token CE.  logits fp32 (B,S,V); targets (B,S) int32."""
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def loss_fn(params, cfg, batch):
    logits, aux = transformer.forward_train(params, cfg, batch)
    ce = cross_entropy(logits, batch["targets"], batch.get("mask"))
    return ce + MOE_AUX_COEF * aux, (ce, aux)


def default_microbatches(cfg, global_batch: int) -> int:
    """Split the per-step batch so remat activation stacks fit HBM.
    The >=90B configs need deep splits on a single 256-chip pod."""
    if not cfg.fsdp:
        return 1
    target = {True: 16}.get(cfg.n_experts > 0, 8)
    return min(target, global_batch)


def make_train_step(cfg, lr: float = 3e-4, weight_decay: float = 0.1,
                    grad_clip: float = 1.0, microbatches: int = 1):
    """Returns (opt_init, train_step) with gradient accumulation.

    ``microbatches > 1`` scans over batch shards, accumulating grads
    (fp32 for <90B models, bf16 for the FSDP giants where the accumulator
    itself is HBM-significant) before a single optimizer update.
    """
    opt_init, opt_update = adam(lr, weight_decay=weight_decay,
                                grad_clip=grad_clip,
                                state_dtype=dtype_of(cfg.opt_state_dtype))
    acc_dtype = jnp.bfloat16 if cfg.fsdp else jnp.float32

    def grads_of(params, batch):
        return jax.value_and_grad(loss_fn, has_aux=True)(params, cfg, batch)

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            (loss, (ce, aux)), grads = grads_of(params, batch)
        else:
            def shard(x):
                b = x.shape[0]
                return x.reshape(microbatches, b // microbatches, *x.shape[1:])

            mb = jax.tree.map(shard, batch)

            def body(acc, micro):
                g_acc, loss_a, ce_a, aux_a = acc
                (l, (c, a)), g = grads_of(params, micro)
                g_acc = jax.tree.map(
                    lambda t, u: t + (u / microbatches).astype(acc_dtype),
                    g_acc, g)
                return (g_acc, loss_a + l / microbatches,
                        ce_a + c / microbatches, aux_a + a / microbatches), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, acc_dtype), params)
            (grads, loss, ce, aux), _ = jax.lax.scan(
                body, (zeros, 0.0, 0.0, 0.0), mb)
        params, opt_state = opt_update(grads, opt_state, params)
        metrics = {"loss": loss, "ce": ce, "aux": aux}
        return params, opt_state, metrics

    return opt_init, train_step


def make_prefill(cfg, s_max: int):
    return functools.partial(transformer.prefill, cfg=cfg, s_max=s_max)


def make_decode_step(cfg):
    return functools.partial(transformer.decode_step, cfg=cfg)


def make_serve_step(cfg):
    """The decode-shape dry-run target: one new token against a full cache."""
    def serve_step(params, caches, tokens):
        return transformer.decode_step(params, cfg, caches, tokens)
    return serve_step
