"""Mamba2 SSD block (arXiv:2405.21060), TPU-adapted.

The block: in_proj -> [z | x | B | C | dt]; short depthwise conv over
[x|B|C]; SSD scan (chunked dual form -- the Pallas kernel's domain); gated
RMSNorm by z; out_proj.  Decode keeps (conv_state, ssd_state) caches --
constant memory in sequence length, which is why mamba2 runs the
``long_500k`` cell (DESIGN §4).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..kernels import ops
from ..kernels.ref import ssd_step_ref
from .common import dense_init, dtype_of, pad_reset, rms_norm


class SsmCache(NamedTuple):
    conv: jax.Array    # (B, conv_width-1, conv_channels)
    state: jax.Array   # (B, H, N, P) fp32 SSD state


def _dims(cfg):
    d_in = cfg.ssm_expand * cfg.d_model
    p = cfg.ssm_headdim
    h = d_in // p
    n = cfg.ssm_state
    g = 1                      # single B/C group
    conv_ch = d_in + 2 * g * n
    return d_in, p, h, n, g, conv_ch


def init_ssm(key, cfg):
    d = cfg.d_model
    d_in, p, h, n, g, conv_ch = _dims(cfg)
    dt = dtype_of(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    proj_out = 2 * d_in + 2 * g * n + h   # z, x, B, C, dt
    params = {
        "norm": jnp.zeros((d,), dt),
        "in_proj": dense_init(ks[0], (d, proj_out), dt),
        "conv": dense_init(ks[1], (cfg.conv_width, conv_ch), dt, scale=0.5),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "d_skip": jnp.ones((h,), jnp.float32),
        "gate_norm": jnp.zeros((d_in,), dt),
        "out_proj": dense_init(ks[2], (d_in, d), dt),
    }
    return params


def _split_proj(cfg, proj):
    d_in, p, h, n, g, _ = _dims(cfg)
    z, xbc, dt_raw = jnp.split(proj, [d_in, 2 * d_in + 2 * g * n], axis=-1)
    return z, xbc, dt_raw


def _split_xbc(cfg, xbc):
    d_in, p, h, n, g, _ = _dims(cfg)
    x, b, c = jnp.split(xbc, [d_in, d_in + g * n], axis=-1)
    return x, b, c


def _conv_full(params, xbc):
    """Causal depthwise conv over the sequence axis. xbc: (B,S,C)."""
    w = params["conv"].astype(jnp.float32)         # (K, C)
    k = w.shape[0]
    x32 = xbc.astype(jnp.float32)
    pad = jnp.pad(x32, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + x32.shape[1]] * w[i] for i in range(k))
    return jax.nn.silu(out).astype(xbc.dtype)


def apply_ssm(params, cfg, x, want_cache: bool = False, pad_mask=None):
    """Full-sequence SSD block. x: (B,S,D) -> (B,S,D) [, SsmCache].

    ``pad_mask`` (B, S) bool marks valid (non-left-pad) positions of ragged
    serving batches.  Pad positions are zeroed AHEAD of the causal conv --
    the first real tokens' conv windows then see exactly the zeros a solo
    run's left conv padding provides, instead of pad-garbage embeddings --
    and a reset mask (pads + first real token) threads into the SSD scan so
    no carried state can cross from pad filler into real positions.  A
    padded row's outputs, final state, and conv cache tail equal its solo
    run's.
    """
    d_in, p, h, n, g, _ = _dims(cfg)
    normed = rms_norm(x, params["norm"])
    proj = normed @ params["in_proj"]
    z, xbc_pre, dt_raw = _split_proj(cfg, proj)
    reset = None
    if pad_mask is not None:
        xbc_pre = jnp.where(pad_mask[:, :, None], xbc_pre, 0.0)
        reset = pad_reset(pad_mask)
    xbc = _conv_full(params, xbc_pre)
    xs, b, c = _split_xbc(cfg, xbc)
    bsz, s = x.shape[0], x.shape[1]
    xh = xs.reshape(bsz, s, h, p)
    bh = b.reshape(bsz, s, g, n)
    ch = c.reshape(bsz, s, g, n)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
    y, final_state = ops.ssd_scan(xh, dt, params["a_log"], bh, ch,
                                  params["d_skip"], chunk=min(cfg.ssm_chunk, s),
                                  reset=reset)
    y = y.reshape(bsz, s, d_in)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                 params["gate_norm"])
    out = y @ params["out_proj"]
    if not want_cache:
        return out
    k = cfg.conv_width
    conv_tail = xbc_pre[:, -(k - 1):, :] if s >= k - 1 else jnp.pad(
        xbc_pre, ((0, 0), (k - 1 - s, 0), (0, 0)))
    return out, SsmCache(conv=conv_tail, state=final_state)


def init_ssm_cache(cfg, batch: int, dtype) -> SsmCache:
    d_in, p, h, n, g, conv_ch = _dims(cfg)
    return SsmCache(
        conv=jnp.zeros((batch, cfg.conv_width - 1, conv_ch), dtype),
        state=jnp.zeros((batch, h, n, p), jnp.float32))


def apply_ssm_decode(params, cfg, x, cache: SsmCache):
    """Single-token step. x: (B,1,D) -> (y (B,1,D), new cache)."""
    d_in, p, h, n, g, conv_ch = _dims(cfg)
    bsz = x.shape[0]
    normed = rms_norm(x[:, 0], params["norm"])
    proj = normed @ params["in_proj"]
    z, xbc, dt_raw = _split_proj(cfg, proj)
    # rolling conv state
    hist = jnp.concatenate([cache.conv, xbc[:, None, :]], axis=1)  # (B,K,C)
    w = params["conv"].astype(jnp.float32)
    conv_out = jnp.einsum("bkc,kc->bc", hist.astype(jnp.float32), w)
    xbc_t = jax.nn.silu(conv_out).astype(x.dtype)
    new_conv = hist[:, 1:]
    xs, b, c = _split_xbc(cfg, xbc_t)
    xh = xs.reshape(bsz, h, p)
    bh = b.reshape(bsz, g, n)
    ch = c.reshape(bsz, g, n)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
    y, new_state = ssd_step_ref(cache.state, xh, dt, params["a_log"], bh, ch,
                                params["d_skip"])
    y = y.reshape(bsz, d_in)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                 params["gate_norm"])
    out = (y @ params["out_proj"])[:, None, :]
    return out, SsmCache(conv=new_conv, state=new_state)
