"""Channel mixers: dense (optionally gated) FFN and GShard-style MoE.

MoE uses grouped top-k dispatch with capacity (tokens are grouped into
fixed-size groups aligned with the data sharding; experts shard over the
"model" mesh axis, so the dispatch/combine einsums lower to all-to-alls).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import shardctx
from .common import dense_init, dtype_of

MOE_GROUP = 1024          # tokens per dispatch group (DESIGN §4)


def init_ffn(key, cfg, d_ff: int | None = None):
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    dt = dtype_of(cfg.param_dtype)
    ks = jax.random.split(key, 3)
    p = {"w1": dense_init(ks[0], (d, f), dt),
         "w2": dense_init(ks[1], (f, d), dt)}
    if cfg.gated_ffn:
        p["w3"] = dense_init(ks[2], (d, f), dt)
    return p


FFN_CHUNK_SEQ = 8192      # chunk the token axis above this length
FFN_CHUNK = 2048


def _ffn_block(p, cfg, x):
    h = x @ p["w1"]
    if cfg.gated_ffn:
        h = jax.nn.silu(h) * (x @ p["w3"])
    else:
        h = jax.nn.gelu(h)
    # Pin the hidden's TP layout (w1/w3 are column-, w2 row-sharded on the
    # "model" axis): keeps the gate/activation elementwise ops partitioned
    # instead of letting GSPMD gather the (tokens, d_ff) hidden.
    h = shardctx.constrain(h, "dp", *([None] * (h.ndim - 2)), "tp")
    return h @ p["w2"]


def apply_ffn(p, cfg, x):
    """Dense FFN; long sequences run in token chunks so the (tokens, d_ff)
    hidden never materializes (it dwarfs HBM at 32k x 49k; two matmuls
    cannot fuse on any backend)."""
    s = x.shape[-2]
    if s < FFN_CHUNK_SEQ or s % FFN_CHUNK != 0:
        return _ffn_block(p, cfg, x)
    lead = x.shape[:-2]
    xc = x.reshape(*lead, s // FFN_CHUNK, FFN_CHUNK, x.shape[-1])
    xc = jnp.moveaxis(xc, -3, 0)

    def body(_, xt):
        return None, _ffn_block(p, cfg, xt)

    _, yc = jax.lax.scan(body, None, xc)
    return jnp.moveaxis(yc, 0, -3).reshape(*lead, s, x.shape[-1])


def init_moe(key, cfg):
    d, f, e = cfg.d_model, cfg.resolved_moe_dff, cfg.n_experts
    dt = dtype_of(cfg.param_dtype)
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d, e), jnp.float32),
        "wi": dense_init(ks[1], (e, d, f), dt),
        "wo": dense_init(ks[2], (e, f, d), dt),
    }
    if cfg.gated_ffn:
        p["wg"] = dense_init(ks[3], (e, d, f), dt)
    if cfg.shared_expert:
        p["shared"] = init_ffn(ks[4], cfg, d_ff=cfg.resolved_moe_dff)
    return p


def apply_moe(p, cfg, x):
    """x: (..., S, D) -> (y, aux_loss).  Flattens tokens into groups of
    MOE_GROUP, dispatches top-k with capacity, runs expert FFNs batched over
    the expert axis."""
    orig_shape = x.shape
    d = orig_shape[-1]
    tokens = x.reshape(-1, d)
    t = tokens.shape[0]
    gsize = min(MOE_GROUP, t)
    # pad to a group multiple (only hit by tiny smoke shapes)
    pad = (-t) % gsize
    if pad:
        tokens = jnp.concatenate([tokens, jnp.zeros((pad, d), tokens.dtype)])
    g = tokens.shape[0] // gsize
    xg = tokens.reshape(g, gsize, d)
    xg = shardctx.constrain(xg, "dp", None, None)

    e, k = cfg.n_experts, cfg.top_k
    cap = int(max(1, -(-gsize * k // e)) * cfg.capacity_factor)
    cap = min(cap, gsize)

    logits = (xg.astype(jnp.float32) @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)           # (G,S,E)

    # Switch/GShard-style load-balancing aux loss.
    density = jnp.mean(probs, axis=1)                                  # (G,E)
    top1 = jax.nn.one_hot(jnp.argmax(probs, -1), e, dtype=jnp.float32)
    usage = jnp.mean(top1, axis=1)
    aux = jnp.mean(jnp.sum(density * usage, axis=-1)) * (e ** 2) / e

    dispatch = jnp.zeros((g, gsize, e, cap), jnp.float32)
    combine = jnp.zeros((g, gsize, e, cap), jnp.float32)
    used = jnp.zeros((g, e), jnp.float32)            # capacity consumed
    masked = probs
    gate_sum = jnp.zeros((g, gsize), jnp.float32)
    slots = []
    for _ in range(k):
        idx = jnp.argmax(masked, axis=-1)                      # (G,S)
        onehot = jax.nn.one_hot(idx, e, dtype=jnp.float32)     # (G,S,E)
        gate = jnp.sum(probs * onehot, axis=-1)                # (G,S)
        pos = (jnp.cumsum(onehot, axis=1) - onehot
               + used[:, None, :])                             # (G,S,E)
        keep = (pos < cap).astype(jnp.float32) * onehot
        pos_tok = jnp.sum(pos * onehot, axis=-1)               # (G,S)
        cap_oh = jax.nn.one_hot(pos_tok.astype(jnp.int32), cap,
                                dtype=jnp.float32)             # (G,S,C)
        d_k = keep[..., None] * cap_oh[:, :, None, :]          # (G,S,E,C)
        dispatch = dispatch + d_k
        combine = combine + d_k * gate[:, :, None, None]
        gate_sum = gate_sum + gate * jnp.sum(keep, axis=-1)
        used = used + jnp.sum(keep, axis=1)
        masked = masked * (1.0 - onehot)
        slots.append(None)
    # renormalize combine weights over the selected experts
    combine = combine / jnp.maximum(gate_sum[:, :, None, None], 1e-9)

    cdt = dtype_of(cfg.compute_dtype)
    g_ax = shardctx.moe_group_axis()   # "dp", or None under expert_shard_dff
    xe = jnp.einsum("gsec,gsd->egcd", dispatch.astype(cdt), xg)   # (E,G,C,D)
    xe = shardctx.constrain(xe, "ep", g_ax, None, None)           # EP (x DP)
    h = jnp.einsum("egcd,edf->egcf", xe, p["wi"])
    if "wg" in p:
        h = jax.nn.silu(h) * jnp.einsum("egcd,edf->egcf", xe, p["wg"])
    else:
        h = jax.nn.gelu(h)
    ye = jnp.einsum("egcf,efd->egcd", h, p["wo"])                 # (E,G,C,D)
    ye = shardctx.constrain(ye, "ep", g_ax, None, None)
    y = jnp.einsum("gsec,egcd->gsd", combine.astype(cdt), ye)

    if "shared" in p:
        y = y + apply_ffn(p["shared"], cfg, xg)

    y = y.reshape(-1, d)
    if pad:
        y = y[:t]
    return y.reshape(orig_shape), aux
