"""Minimal pure-JAX MLP stack for the PPO actor/critic (Sec. V-A: two hidden
layers, 128 and 64 units)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def mlp_init(key, sizes, dtype=jnp.float32):
    """He/orthogonal-free init: normal * sqrt(2/fan_in), zero bias."""
    params = []
    for din, dout in zip(sizes[:-1], sizes[1:]):
        key, sub = jax.random.split(key)
        w = jax.random.normal(sub, (din, dout), dtype) * jnp.sqrt(2.0 / din)
        params.append({"w": w, "b": jnp.zeros((dout,), dtype)})
    return params


def mlp_apply(params, x, *, final_scale: float = 1.0):
    """tanh-activated MLP; final layer linear, optionally down-scaled
    (small-init trick for policy heads)."""
    for layer in params[:-1]:
        x = jnp.tanh(x @ layer["w"] + layer["b"])
    last = params[-1]
    return (x @ last["w"] + last["b"]) * final_scale
