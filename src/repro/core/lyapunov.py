"""Lyapunov virtual queues and drift-plus-penalty machinery (Sec. IV-A).

The long-term constraints C1 (energy) and C2 (memory) of P1 are absorbed into
virtual queues Q_n (energy) and W_n (memory):

    Q_n(t+1) = [Q_n(t) + nu_e (E_n - e_n)]^+        (eq. 8)
    W_n(t+1) = [W_n(t) + nu_c (C_n - eps_n)]^+      (eq. 9)

Minimizing the per-slot drift-plus-penalty objective (eq. 11)

    sum_n Q_n E_n + W_n C_n + V * T_n

then solves P1 up to the standard O(1/V) optimality / O(V) queue-backlog
Lyapunov trade-off (paper refs. [15], [16]).
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp


class VirtualQueues(NamedTuple):
    energy: jnp.ndarray  # Q(t), one per UE
    memory: jnp.ndarray  # W(t), one per UE

    @staticmethod
    def zeros(n: int, dtype=jnp.float32) -> "VirtualQueues":
        return VirtualQueues(jnp.zeros(n, dtype), jnp.zeros(n, dtype))


def update_queues(q: VirtualQueues, energy, mem_cost, e_budget, c_budget,
                  nu_e: float, nu_c: float) -> VirtualQueues:
    """Eqs. (8)-(9)."""
    return VirtualQueues(
        energy=jnp.maximum(q.energy + nu_e * (energy - e_budget), 0.0),
        memory=jnp.maximum(q.memory + nu_c * (mem_cost - c_budget), 0.0),
    )


def lyapunov_function(q: VirtualQueues):
    """L(Theta) = 1/2 sum_n (Q_n^2 + W_n^2)."""
    return 0.5 * (jnp.sum(jnp.square(q.energy)) + jnp.sum(jnp.square(q.memory)))


def per_slot_objective(q: VirtualQueues, energy, mem_cost, delay, v: float):
    """Eq. (11) / negative of reward (14): sum_n Q E + W C + V T."""
    return jnp.sum(q.energy * energy + q.memory * mem_cost + v * delay)


def reward(q: VirtualQueues, energy, mem_cost, delay, v: float):
    """Eq. (14)."""
    return -per_slot_objective(q, energy, mem_cost, delay, v)
