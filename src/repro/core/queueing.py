"""Serial-queue E2E delay model (paper Sec. II-B, eqs. 1-4).

All functions are pure, float32/float64-polymorphic, vectorized over UEs and
jit/vmap-safe.  Units: seconds, Hz (cycles/s), bytes (converted to bits at the
rate boundary), Watts.

Stability (C7): every function that divides by ``mu - lam`` expects the caller
to have enforced ``mu > lam`` (the environment projects partitioning actions
onto the feasible set); a ``safe`` epsilon keeps gradients finite if violated
transiently inside optimizer line searches.
"""
from __future__ import annotations

import jax.numpy as jnp

_EPS = 1e-12


def md1_sojourn(lam, mu):
    """Average M/D/1 sojourn time (eq. 2): service 1/mu + queue wait.

    T = 1/mu + lam / (2 mu (mu - lam)).
    """
    lam = jnp.asarray(lam)
    mu = jnp.asarray(mu)
    wait = lam / (2.0 * mu * jnp.maximum(mu - lam, _EPS))
    return 1.0 / jnp.maximum(mu, _EPS) + wait


def ue_sojourn(lam, f_ue, d_ue):
    """Local sojourn delay (eq. 2) with mu = f_ue / d_ue.

    ``d_ue = rho * sum_{l<=cut} M(l)`` is the per-task local cycle demand.
    A zero local portion (cut == 0) contributes zero delay.
    """
    d_ue = jnp.asarray(d_ue)
    mu = jnp.where(d_ue > 0, f_ue / jnp.maximum(d_ue, _EPS), jnp.inf)
    return jnp.where(d_ue > 0, md1_sojourn(lam, mu), 0.0)


def shannon_rate(alpha, w_hz, p_tx, gain, n0):
    """FDMA uplink rate (Sec. II-B2): R = alpha W log2(1 + p h / (alpha W N0)).

    ``alpha -> 0`` limits to 0 (handled explicitly so grads stay finite).
    """
    alpha = jnp.asarray(alpha)
    snr = p_tx * gain / (jnp.maximum(alpha, _EPS) * w_hz * n0)
    rate = alpha * w_hz * jnp.log2(1.0 + snr)
    return jnp.where(alpha > 0, rate, 0.0)


def trans_delay(psi_bytes, alpha, w_hz, p_tx, gain, n0):
    """Transmission delay (eq. 3).  psi given in BYTES, rate in bits/s."""
    bits = 8.0 * jnp.asarray(psi_bytes)
    rate = shannon_rate(alpha, w_hz, p_tx, gain, n0)
    return jnp.where(bits > 0, bits / jnp.maximum(rate, _EPS), 0.0)


def es_sojourn(f_es, d_es):
    """Edge sojourn (eq. 4): deterministic service, queuing neglected.

    ``d_es = rho * sum_{l>cut} M(l)``; zero edge portion -> zero delay.
    """
    d_es = jnp.asarray(d_es)
    return jnp.where(d_es > 0, d_es / jnp.maximum(f_es, _EPS), 0.0)


def es_sojourn_gd1(lam, f_es, d_es, rho_ue):
    """Beyond-paper: G/D/1-corrected edge sojourn following paper ref. [13].

    The arrival process at the ES is the UE departure process; for an M/D/1
    upstream with utilization ``rho_ue`` the departure SCV is
    ``ca2 = 1 - rho_ue**2``.  Kingman's approximation with deterministic
    service (cs2 = 0) gives  W ~= (ca2 / 2) * rho_es / (1 - rho_es) / mu_es.
    """
    d_es = jnp.asarray(d_es)
    mu = jnp.where(d_es > 0, f_es / jnp.maximum(d_es, _EPS), jnp.inf)
    rho_es = jnp.clip(lam / jnp.maximum(mu, _EPS), 0.0, 1.0 - 1e-6)
    ca2 = 1.0 - jnp.clip(rho_ue, 0.0, 1.0) ** 2
    wait = 0.5 * ca2 * rho_es / jnp.maximum(1.0 - rho_es, _EPS) / jnp.maximum(mu, _EPS)
    return jnp.where(d_es > 0, 1.0 / jnp.maximum(mu, _EPS) + wait, 0.0)


def e2e_delay(lam, f_ue, f_es, d_ue, d_es, psi_bytes, alpha, w_hz, p_tx, gain, n0,
              edge_queueing: bool = False):
    """End-to-end delay (eq. 1): T_ue + T_trans + T_es, per UE."""
    t_ue = ue_sojourn(lam, f_ue, d_ue)
    t_tx = trans_delay(psi_bytes, alpha, w_hz, p_tx, gain, n0)
    if edge_queueing:
        mu_ue = jnp.where(d_ue > 0, f_ue / jnp.maximum(d_ue, _EPS), jnp.inf)
        rho_ue = jnp.where(jnp.isinf(mu_ue), 0.0, lam / jnp.maximum(mu_ue, _EPS))
        t_es = es_sojourn_gd1(lam, f_es, d_es, rho_ue)
    else:
        t_es = es_sojourn(f_es, d_es)
    return t_ue + t_tx + t_es, (t_ue, t_tx, t_es)
