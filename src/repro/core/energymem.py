"""UE energy (eq. 5) and model-memory (eq. 6) models.

Canonical units: Joules (per 1-second slot, i.e. average power x 1 s) and
GIGABYTES for the memory-cost bookkeeping -- the paper's Table I constants
(nu_e = 100 with e_n ~ 0.04-0.06 J; nu_c = 10 with eps_n ~ 0.03-0.1 GB) only
produce commensurate virtual-queue drifts under J + GB scaling; see DESIGN.md.
"""
from __future__ import annotations

import jax.numpy as jnp

GB = 1e9


def compute_energy(f_ue, d_ue, lam, kappa):
    """Local computation power E^comp = kappa * f^2 * d * lam   [J/s].

    ``d = rho * sum M(l)`` cycles/task; ``kappa * f^2`` J/cycle; ``lam``
    tasks/s.  (Equivalent to the paper's kappa*rho*f^2*sum(M)*lam.)
    """
    return kappa * jnp.square(f_ue) * d_ue * lam


def trans_energy(p_tx, t_trans, lam):
    """Offloading transmission power E^trans = p * T_trans * lam   [J/s]."""
    return p_tx * t_trans * lam


def ue_energy(f_ue, d_ue, lam, kappa, p_tx, t_trans):
    """Total UE power draw for the slot (eq. 5)."""
    return compute_energy(f_ue, d_ue, lam, kappa) + trans_energy(p_tx, t_trans, lam)


def memory_cost(prefix_params, suffix_params, prefix_act_max, suffix_act_max,
                gamma_ue, gamma_es):
    """Deployment memory cost (eq. 6), in GB.

    cost = gamma_ue * (local params) + max local activation
         + gamma_es * (edge  params) + max edge  activation

    All four inputs are BYTES gathered at the current cut from the
    ProfileBatch prefix tables.
    """
    local = gamma_ue * prefix_params + prefix_act_max
    edge = gamma_es * suffix_params + suffix_act_max
    return (local + edge) / GB
