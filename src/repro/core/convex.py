"""Per-slot convex resource allocation (paper Sec. IV-C).

Given the partitioning decision ``cut`` (from the DRL policy), the remaining
continuous allocation decouples into three convex programs, solved exactly and
jit-compatibly:

* P3 (eq. 19)  local CPU frequency  f_ue  -- Fibonacci line search (paper) per UE
* P4 (eq. 20)  edge CPU frequency   f_es  -- closed-form KKT water-filling (eq. 23)
* P5 (eq. 24)  uplink bandwidth     alpha -- two-level KKT bisection (replaces CVX;
               see DESIGN.md "Hardware adaptation")

All solvers are fixed-iteration (`lax.fori_loop`) so they lower to TPU and
vectorize over UEs.  Log-domain comparisons keep P5 stable in float32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

_EPS = 1e-12

# ---------------------------------------------------------------------------
# P3: local computational resource (Fibonacci search, eq. 19)
# ---------------------------------------------------------------------------

_FIB_N = 40
_FIB = np.ones(_FIB_N + 3, dtype=np.float64)
for _i in range(2, _FIB_N + 3):
    _FIB[_i] = _FIB[_i - 1] + _FIB[_i - 2]
# ratio[k] = F_{n-k} / F_{n-k+2}: fraction of the interval probed at step k.
_FIB_RATIO_LO = np.array([_FIB[_FIB_N - k] / _FIB[_FIB_N - k + 2] for k in range(_FIB_N)])
_FIB_RATIO_HI = np.array([_FIB[_FIB_N - k + 1] / _FIB[_FIB_N - k + 2] for k in range(_FIB_N)])


def p3_objective(f, q_energy, kappa, d_ue, lam, v):
    """Eq. (19): Q*kappa*f^2*d*lam + V*(d/f + d^2 lam / (2 (f^2 - f d lam)))."""
    f = jnp.maximum(f, _EPS)
    energy = q_energy * kappa * jnp.square(f) * d_ue * lam
    proc = d_ue / f
    denom = jnp.maximum(jnp.square(f) - f * d_ue * lam, _EPS)
    queue = jnp.square(d_ue) * lam / (2.0 * denom)
    return energy + v * (proc + queue)


def solve_p3(q_energy, kappa, d_ue, lam, v, f_max, *, stability_margin=1e-3):
    """Fibonacci-search minimizer of (19) per UE on (d*lam, f_max].

    Vectorized over leading UE axis.  UEs with ``d_ue == 0`` (full offload)
    get f_ue = 0.  The caller guarantees feasibility ``d*lam < f_max`` (C7,
    enforced by action projection); if violated we clamp to f_max.
    """
    lo = d_ue * lam * (1.0 + stability_margin) + 1.0
    hi = jnp.full_like(lo, f_max)
    lo = jnp.minimum(lo, hi)

    obj = functools.partial(p3_objective, q_energy=q_energy, kappa=kappa,
                            d_ue=d_ue, lam=lam, v=v)

    ratio_lo = jnp.asarray(_FIB_RATIO_LO, dtype=lo.dtype)
    ratio_hi = jnp.asarray(_FIB_RATIO_HI, dtype=lo.dtype)

    def body(k, ab):
        a, b = ab
        span = b - a
        x1 = a + ratio_lo[k] * span
        x2 = a + ratio_hi[k] * span
        f1, f2 = obj(x1), obj(x2)
        take_left = f1 < f2
        return jnp.where(take_left, a, x1), jnp.where(take_left, x2, b)

    a, b = jax.lax.fori_loop(0, _FIB_N, body, (lo, hi))
    f_star = 0.5 * (a + b)
    # Also consider the upper boundary (optimum can sit at f_max when Q ~ 0).
    f_star = jnp.where(obj(hi) < obj(f_star), hi, f_star)
    return jnp.where(d_ue > 0, f_star, 0.0)


# ---------------------------------------------------------------------------
# P4: edge computational resource (closed form, eq. 23)
# ---------------------------------------------------------------------------

def solve_p4(d_es, f_max_es):
    """f_es* = f_max * sqrt(d_n) / sum_m sqrt(d_m)  (eq. 23).

    UEs with no edge portion receive 0 (sqrt(0) = 0 drops them naturally).
    If nobody offloads, return zeros.
    """
    root = jnp.sqrt(jnp.maximum(d_es, 0.0))
    total = jnp.sum(root)
    safe_total = jnp.where(total > 0, total, 1.0)
    return jnp.where(total > 0, f_max_es * root / safe_total, 0.0)


# ---------------------------------------------------------------------------
# P5: communication resource (two-level KKT bisection, eq. 24)
# ---------------------------------------------------------------------------

_ALPHA_MIN = 1e-7
# Bisection depths sized for float32: 2^-36 on alpha in [1e-7, 1] and
# 2^-42 on log-eta in [-80, 80] are both far below f32 resolution already;
# deeper loops were pure sequential overhead (the solver runs inside every
# per-slot step, and the batched multi-cell path pays per-iteration cost).
_INNER_ITERS = 36
_OUTER_ITERS = 42


def _log_rate_terms(alpha, s):
    """r(a) = a*log2(1+s/a); returns (log r, log r') computed stably."""
    a = jnp.maximum(alpha, _ALPHA_MIN * 1e-3)
    l2 = jnp.log2(1.0 + s / a)
    log_r = jnp.log(a) + jnp.log(jnp.maximum(l2, _EPS))
    # r'(a) = log2(1+s/a) - s / (ln2 * (a + s))  > 0
    rp = l2 - s / (jnp.log(2.0) * (a + s))
    log_rp = jnp.log(jnp.maximum(rp, _EPS))
    return log_r, log_rp


def _log_marginal(alpha, s, log_c):
    """log of m(a) = c * r'(a) / r(a)^2 -- the (negated) objective slope."""
    log_r, log_rp = _log_rate_terms(alpha, s)
    return log_c + log_rp - 2.0 * log_r


def solve_p5(q_energy, p_tx, lam, v, psi_bytes, w_hz, gain, n0):
    """Minimize eq. (24) s.t. sum(alpha) <= 1, alpha >= 0.

    KKT: the marginal m_n(alpha_n) is equalized across UEs with psi > 0 and
    the bandwidth constraint is tight.  m is strictly decreasing (convexity),
    so: inner bisection inverts m_n at a trial multiplier eta, outer bisection
    drives sum(alpha(eta)) -> 1.  Runs entirely in log domain.
    """
    bits = 8.0 * psi_bytes
    active = bits > 0
    n_active = jnp.sum(active)
    s = p_tx * gain / (w_hz * n0)                     # per-UE SNR coefficient
    coeff = (q_energy * p_tx * lam + v) * bits / w_hz  # c_n in DESIGN notation

    coeff_c = jnp.maximum(coeff, _EPS)
    ln2 = jnp.log(2.0)
    # Inner bisection runs in u-space, u = ln(1 + s/alpha) (monotone
    # DECREASING in alpha), because there r and r' are arithmetic in
    # (u, e^-u):  r = a*u/ln2,  r' = (u - (1 - e^-u))/ln2,  a = s*e^-u/(1-e^-u).
    # That leaves ONE transcendental (expm1) per bisection step -- the a-space
    # form needs a log2 per step, and scalar libm calls are what the solver's
    # wall time is made of once many cells are batched.
    u_lo0 = jnp.log1p(s)                  # alpha = 1
    u_hi0 = jnp.log1p(s / _ALPHA_MIN)     # alpha = ALPHA_MIN

    def alpha_of_eta(log_eta):
        # m(a) > eta  <=>  c * r'(a) > eta * r(a)^2, all in linear domain;
        # magnitudes stay in f32 range for |log_eta| <= 40 (m spans
        # ~e^-35..e^38 at the parameter extremes).
        eta = jnp.exp(log_eta)

        def a_of_u(u):
            em = -jnp.expm1(-u)           # 1 - e^-u, stable for small u
            return s * (1.0 - em) / jnp.maximum(em, _EPS), em

        def inner(_, uu):
            u_lo, u_hi = uu
            mid = 0.5 * (u_lo + u_hi)
            a, em = a_of_u(mid)
            # c * rp > eta * r^2  <=>  c*ln2*(u - em) > eta * a^2 * u^2
            too_steep = (coeff_c * ln2 * jnp.maximum(mid - em, _EPS)
                         > eta * a * a * mid * mid)
            # m(a) > eta -> alpha* > a -> u* < mid
            return jnp.where(too_steep, u_lo, mid), jnp.where(too_steep, mid, u_hi)

        u_lo, u_hi = jax.lax.fori_loop(0, _INNER_ITERS, inner, (u_lo0, u_hi0))
        alpha, _ = a_of_u(0.5 * (u_lo + u_hi))
        return jnp.where(active, jnp.clip(alpha, _ALPHA_MIN, 1.0), 0.0)

    def outer(_, bounds):
        e_lo, e_hi = bounds
        mid = 0.5 * (e_lo + e_hi)
        total = jnp.sum(alpha_of_eta(mid))
        # sum(alpha) decreasing in eta: too much bandwidth -> raise eta.
        over = total > 1.0
        return jnp.where(over, mid, e_lo), jnp.where(over, e_hi, mid)

    e_lo, e_hi = jax.lax.fori_loop(
        0, _OUTER_ITERS, outer,
        (jnp.asarray(-40.0, s.dtype), jnp.asarray(40.0, s.dtype)))
    alpha = alpha_of_eta(0.5 * (e_lo + e_hi))
    # Exactness: single active UE -> alpha = 1; none -> zeros.
    alpha = jnp.where(n_active == 1, jnp.where(active, 1.0, 0.0), alpha)
    # Normalize residual bisection slack onto active UEs.
    total = jnp.sum(alpha)
    alpha = jnp.where(n_active > 0, alpha / jnp.maximum(total, _EPS), 0.0)
    return alpha


def p5_objective(alpha, q_energy, p_tx, lam, v, psi_bytes, w_hz, gain, n0):
    """Eq. (24) objective value (for tests / oracle search)."""
    from .queueing import trans_delay

    t = trans_delay(psi_bytes, alpha, w_hz, p_tx, gain, n0)
    return jnp.sum((q_energy * p_tx * lam + v) * t)
