"""Device-sharded ScenarioGrid support: mesh placement over the cell axis.

A :class:`~repro.core.scenarios.ScenarioGrid` stacks B cells into one
``(B, ...)`` pytree and evaluates them with one vmap+scan program.  This
module spreads that program across a device mesh's ``"cells"`` axis
(built by :func:`repro.launch.mesh.make_cells_mesh`):

* :func:`plan` rounds B up to a multiple of the mesh's cell-shard count and
  records the split in a :class:`GridSharding`;
* :func:`pad_cells` edge-replicates the last real cell into the padded slots
  (their math stays finite -- no NaNs leak into reductions -- and
  :meth:`GridSharding.mask` marks them invalid so rollout summaries drop
  them);
* :func:`place` / :func:`constrain` put the padded pytree on the mesh with
  ``NamedSharding(P("cells", ...))`` -- under ``jit``, GSPMD then partitions
  the whole vmapped rollout over devices with no per-cell Python dispatch;
* :func:`cell_keys` derives per-cell PRNG keys from the cell *index* (not the
  batch width), so cell i draws identical randomness whether the grid runs
  padded on 8 devices or unpadded on one -- the invariant behind the
  sharded==unsharded parity tests (tests/test_gridshard.py).

On a 2-D ``("cells", "model")`` mesh (``make_cells_mesh(model=M)``) the plan
additionally spreads each cell's *interior* over the ``"model"`` axis: the
dim immediately after the cell axis -- the per-cell UE axis of the stacked
``MecParams``/``MecState`` tables, the row axis of the (B, N, C) objective
sweep -- shards M-way whenever it divides, and replicates otherwise (the
exact-sharding discipline of ``launch.sharding._shard_if``).  Layout only:
pad/mask/place/unpad semantics are unchanged and sharded(cells, model)
rollouts equal unsharded ones to 1e-5 for every registered scenario
(tests/test_gridshard.py's registry-wide parity suite).

Everything here is layout logic only; the per-cell physics stays the pure
``step_p`` / ``reset_p`` of :mod:`repro.core.env`.  That includes per-cell
traffic state riding inside ``MecParams.arrival`` (e.g. a ``(B, T, N)``
stacked trace/regime tensor of :mod:`repro.traffic.processes`): it pads,
places and shards along the same lead cell axis as every other leaf.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

CELL_AXIS = "cells"
MODEL_AXIS = "model"


@dataclasses.dataclass(frozen=True)
class GridSharding:
    """Placement plan for one stacked (B, ...) grid over a device mesh.

    ``b`` logical cells are padded to ``b_padded`` (a multiple of the mesh's
    ``axis`` size) so every device holds the same number of cells.

    ``model_axis`` names the per-cell tensor-parallel mesh axis (present on
    ``("cells", "model")`` meshes): each leaf's first post-cell dim shards
    over it when evenly divisible, giving every cell ``n_model``-way interior
    parallelism on top of the cell split.
    """

    mesh: Mesh
    b: int
    b_padded: int
    axis: str = CELL_AXIS
    model_axis: str | None = None

    def __post_init__(self):
        if self.b_padded < self.b:
            raise ValueError(f"b_padded={self.b_padded} < b={self.b}")
        if self.b_padded % self.n_shards:
            raise ValueError(
                f"b_padded={self.b_padded} not a multiple of the "
                f"{self.n_shards}-way {self.axis!r} axis")
        if self.model_axis is not None \
                and self.model_axis not in self.mesh.axis_names:
            raise ValueError(
                f"mesh has no {self.model_axis!r} axis; axes are "
                f"{self.mesh.axis_names}")

    @property
    def n_shards(self) -> int:
        return int(self.mesh.shape[self.axis])

    @property
    def n_model(self) -> int:
        """Per-cell tensor-parallel degree (1 on a cells-only mesh)."""
        if self.model_axis is None:
            return 1
        return int(self.mesh.shape[self.model_axis])

    @property
    def pad(self) -> int:
        """Number of padded (invalid) trailing cells."""
        return self.b_padded - self.b

    def mask(self) -> jax.Array:
        """(b_padded,) validity mask: True for real cells, False for padding.

        Any reduction that crosses the cell axis (or reports per-cell values
        of a padded rollout) must apply this before trusting the numbers.
        """
        return jnp.arange(self.b_padded) < self.b

    def spec(self, ndim: int, lead: int = 0, shape: tuple | None = None,
             *, model_dim: int | None = None) -> P:
        """PartitionSpec sharding dim ``lead`` over the cells axis.

        Leaves too small to carry a cell axis (0-d scalars riding in a
        pytree) replicate instead of indexing past their rank.

        When the plan carries a ``model_axis`` and the leaf ``shape`` is
        known, one interior dim additionally shards over it: ``lead + 1``
        by default (the per-cell UE axis of stacked MecParams/MecState
        tables), or ``model_dim`` when given (e.g. ``-1`` for arrival
        leaves, whose post-cell dim is a per-slot TIME axis that the hot
        loop indexes every step -- sharding it would gather across shards
        per slot).  Only evenly dividing dims shard (exact shardings,
        never GSPMD padding); everything else replicates across the
        model axis.
        """
        if ndim <= lead:
            return P()
        entries: list = [None] * ndim
        entries[lead] = self.axis
        if (self.model_axis is not None and shape is not None
                and self.n_model > 1):
            md = lead + 1 if model_dim is None else model_dim % ndim
            if md != lead and md < ndim and shape[md] % self.n_model == 0:
                entries[md] = self.model_axis
        return P(*entries)

    def sharding(self, ndim: int, lead: int = 0, shape: tuple | None = None,
                 *, model_dim: int | None = None) -> NamedSharding:
        return NamedSharding(self.mesh,
                             self.spec(ndim, lead, shape,
                                       model_dim=model_dim))


def plan(b: int, mesh: Mesh, *, axis: str = CELL_AXIS,
         pad_to: int | None = None) -> GridSharding:
    """Round ``b`` up to a device multiple and return the placement plan.

    ``pad_to`` forces a larger padded width (it must itself be a device
    multiple) -- used by tests to exercise the padding path on any device
    count, and available for aligning two grids to one layout.

    A mesh carrying a ``"model"`` axis (``make_cells_mesh(model=M)``)
    activates per-cell tensor parallelism: the plan records the axis and
    :meth:`GridSharding.spec` spreads each leaf's post-cell dim over it.
    """
    if axis not in mesh.axis_names:
        raise ValueError(
            f"mesh has no {axis!r} axis; axes are {mesh.axis_names}")
    if b < 1:
        raise ValueError("need at least one cell")
    n = int(mesh.shape[axis])
    b_padded = -(-b // n) * n
    if pad_to is not None:
        if pad_to < b_padded or pad_to % n:
            raise ValueError(
                f"pad_to={pad_to} must be a multiple of {n} and >= {b_padded}")
        b_padded = pad_to
    model_axis = MODEL_AXIS if MODEL_AXIS in mesh.axis_names else None
    return GridSharding(mesh=mesh, b=b, b_padded=b_padded, axis=axis,
                        model_axis=model_axis)


def pad_cells(tree, gs: GridSharding, *, lead: int = 0):
    """Pad every leaf's cell axis from b to b_padded by edge replication.

    Padded cells are copies of the last real cell: every downstream op stays
    finite (unlike zero padding, which would divide by zero in the queueing
    math), and ``gs.mask()`` keeps them out of reported results.
    """
    if gs.pad == 0:
        return tree

    def pad_leaf(x):
        if x.ndim <= lead:           # scalar rider: no cell axis to pad
            return x
        pads = [(0, 0)] * x.ndim
        pads[lead] = (0, gs.pad)
        return jnp.pad(x, pads, mode="edge")

    return jax.tree.map(pad_leaf, tree)


def place(tree, gs: GridSharding, *, lead: int = 0,
          model_dim: int | None = None):
    """``device_put`` every leaf with the cells-axis NamedSharding.

    Leaves must already be padded to ``gs.b_padded`` on axis ``lead``.
    ``model_dim`` overrides which dim takes the model axis (see
    :meth:`GridSharding.spec`).
    """
    return jax.tree.map(
        lambda x: jax.device_put(
            x, gs.sharding(x.ndim, lead, x.shape, model_dim=model_dim)),
        tree)


def constrain(tree, gs: GridSharding, *, lead: int = 0):
    """In-jit ``with_sharding_constraint`` pinning the cell axis.

    Applied to the rollout's state carry so GSPMD keeps the scan partitioned
    over cells instead of gathering between slots.
    """
    def f(x):
        return jax.lax.with_sharding_constraint(
            x, gs.sharding(x.ndim, lead, x.shape))

    return jax.tree.map(f, tree)


def unpad(tree, gs: GridSharding, *, lead: int = 0):
    """Slice the cell axis back to the logical b (inverse of pad_cells)."""
    if gs.pad == 0:
        return tree

    def f(x):
        if x.ndim <= lead:           # scalar rider: nothing was padded
            return x
        idx = [slice(None)] * x.ndim
        idx[lead] = slice(0, gs.b)
        return x[tuple(idx)]

    return jax.tree.map(f, tree)


def cell_keys(key: jax.Array, b: int, b_padded: int | None = None):
    """Per-cell PRNG keys: ``fold_in(key, cell_index)``, padded slots clamped.

    Cell i's key depends only on (key, i) -- never on the batch width -- so a
    padded b_padded-wide grid hands cells 0..b-1 exactly the keys an unpadded
    b-wide grid hands them.  That makes sharded and unsharded rollouts draw
    identical randomness per real cell (the 1e-5 parity contract).  Padded
    slots reuse the last real cell's key; their outputs are masked away.
    """
    n = b if b_padded is None else b_padded
    idx = jnp.minimum(jnp.arange(n), b - 1)
    return jax.vmap(lambda i: jax.random.fold_in(key, i))(idx)
