"""Device-sharded ScenarioGrid support: mesh placement over the cell axis.

A :class:`~repro.core.scenarios.ScenarioGrid` stacks B cells into one
``(B, ...)`` pytree and evaluates them with one vmap+scan program.  This
module spreads that program across a device mesh's ``"cells"`` axis
(built by :func:`repro.launch.mesh.make_cells_mesh`):

* :func:`plan` rounds B up to a multiple of the mesh's cell-shard count and
  records the split in a :class:`GridSharding`;
* :func:`pad_cells` edge-replicates the last real cell into the padded slots
  (their math stays finite -- no NaNs leak into reductions -- and
  :meth:`GridSharding.mask` marks them invalid so rollout summaries drop
  them);
* :func:`place` / :func:`constrain` put the padded pytree on the mesh with
  ``NamedSharding(P("cells", ...))`` -- under ``jit``, GSPMD then partitions
  the whole vmapped rollout over devices with no per-cell Python dispatch;
* :func:`cell_keys` derives per-cell PRNG keys from the cell *index* (not the
  batch width), so cell i draws identical randomness whether the grid runs
  padded on 8 devices or unpadded on one -- the invariant behind the
  sharded==unsharded parity tests (tests/test_gridshard.py).

Everything here is layout logic only; the per-cell physics stays the pure
``step_p`` / ``reset_p`` of :mod:`repro.core.env`.  That includes per-cell
traffic state riding inside ``MecParams.arrival`` (e.g. a ``(B, T, N)``
stacked trace/regime tensor of :mod:`repro.traffic.processes`): it pads,
places and shards along the same lead cell axis as every other leaf.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

CELL_AXIS = "cells"


@dataclasses.dataclass(frozen=True)
class GridSharding:
    """Placement plan for one stacked (B, ...) grid over a device mesh.

    ``b`` logical cells are padded to ``b_padded`` (a multiple of the mesh's
    ``axis`` size) so every device holds the same number of cells.
    """

    mesh: Mesh
    b: int
    b_padded: int
    axis: str = CELL_AXIS

    def __post_init__(self):
        if self.b_padded < self.b:
            raise ValueError(f"b_padded={self.b_padded} < b={self.b}")
        if self.b_padded % self.n_shards:
            raise ValueError(
                f"b_padded={self.b_padded} not a multiple of the "
                f"{self.n_shards}-way {self.axis!r} axis")

    @property
    def n_shards(self) -> int:
        return int(self.mesh.shape[self.axis])

    @property
    def pad(self) -> int:
        """Number of padded (invalid) trailing cells."""
        return self.b_padded - self.b

    def mask(self) -> jax.Array:
        """(b_padded,) validity mask: True for real cells, False for padding.

        Any reduction that crosses the cell axis (or reports per-cell values
        of a padded rollout) must apply this before trusting the numbers.
        """
        return jnp.arange(self.b_padded) < self.b

    def spec(self, ndim: int, lead: int = 0) -> P:
        """PartitionSpec sharding dim ``lead`` over the cells axis.

        Leaves too small to carry a cell axis (0-d scalars riding in a
        pytree) replicate instead of indexing past their rank.
        """
        if ndim <= lead:
            return P()
        entries: list = [None] * ndim
        entries[lead] = self.axis
        return P(*entries)

    def sharding(self, ndim: int, lead: int = 0) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(ndim, lead))


def plan(b: int, mesh: Mesh, *, axis: str = CELL_AXIS,
         pad_to: int | None = None) -> GridSharding:
    """Round ``b`` up to a device multiple and return the placement plan.

    ``pad_to`` forces a larger padded width (it must itself be a device
    multiple) -- used by tests to exercise the padding path on any device
    count, and available for aligning two grids to one layout.
    """
    if axis not in mesh.axis_names:
        raise ValueError(
            f"mesh has no {axis!r} axis; axes are {mesh.axis_names}")
    if b < 1:
        raise ValueError("need at least one cell")
    n = int(mesh.shape[axis])
    b_padded = -(-b // n) * n
    if pad_to is not None:
        if pad_to < b_padded or pad_to % n:
            raise ValueError(
                f"pad_to={pad_to} must be a multiple of {n} and >= {b_padded}")
        b_padded = pad_to
    return GridSharding(mesh=mesh, b=b, b_padded=b_padded, axis=axis)


def pad_cells(tree, gs: GridSharding, *, lead: int = 0):
    """Pad every leaf's cell axis from b to b_padded by edge replication.

    Padded cells are copies of the last real cell: every downstream op stays
    finite (unlike zero padding, which would divide by zero in the queueing
    math), and ``gs.mask()`` keeps them out of reported results.
    """
    if gs.pad == 0:
        return tree

    def pad_leaf(x):
        if x.ndim <= lead:           # scalar rider: no cell axis to pad
            return x
        pads = [(0, 0)] * x.ndim
        pads[lead] = (0, gs.pad)
        return jnp.pad(x, pads, mode="edge")

    return jax.tree.map(pad_leaf, tree)


def place(tree, gs: GridSharding, *, lead: int = 0):
    """``device_put`` every leaf with the cells-axis NamedSharding.

    Leaves must already be padded to ``gs.b_padded`` on axis ``lead``.
    """
    return jax.tree.map(
        lambda x: jax.device_put(x, gs.sharding(x.ndim, lead)), tree)


def constrain(tree, gs: GridSharding, *, lead: int = 0):
    """In-jit ``with_sharding_constraint`` pinning the cell axis.

    Applied to the rollout's state carry so GSPMD keeps the scan partitioned
    over cells instead of gathering between slots.
    """
    def f(x):
        return jax.lax.with_sharding_constraint(x, gs.sharding(x.ndim, lead))

    return jax.tree.map(f, tree)


def unpad(tree, gs: GridSharding, *, lead: int = 0):
    """Slice the cell axis back to the logical b (inverse of pad_cells)."""
    if gs.pad == 0:
        return tree

    def f(x):
        if x.ndim <= lead:           # scalar rider: nothing was padded
            return x
        idx = [slice(None)] * x.ndim
        idx[lead] = slice(0, gs.b)
        return x[tuple(idx)]

    return jax.tree.map(f, tree)


def cell_keys(key: jax.Array, b: int, b_padded: int | None = None):
    """Per-cell PRNG keys: ``fold_in(key, cell_index)``, padded slots clamped.

    Cell i's key depends only on (key, i) -- never on the batch width -- so a
    padded b_padded-wide grid hands cells 0..b-1 exactly the keys an unpadded
    b-wide grid hands them.  That makes sharded and unsharded rollouts draw
    identical randomness per real cell (the 1e-5 parity contract).  Padded
    slots reuse the last real cell's key; their outputs are masked away.
    """
    n = b if b_padded is None else b_padded
    idx = jnp.minimum(jnp.arange(n), b - 1)
    return jax.vmap(lambda i: jax.random.fold_in(key, i))(idx)
