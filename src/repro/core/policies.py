"""Partitioning-policy heads for PPO (Sec. IV-B).

Two interchangeable action representations:

* ``GaussianTanhPolicy`` -- the paper's design: the actor emits a real score
  y_n per UE; eq. (13) maps tanh(y) onto the integer cut.  The PPO ratio is
  computed on the Gaussian over y (the deterministic tanh/floor transform
  cancels in the ratio).  NOTE: the paper's floor(L*(tanh+1)/2) almost surely
  misses the fully-local cut L; we use span L+1 with a clip so the closed set
  {0..L} is reachable (DESIGN.md §8).
* ``CategoricalPolicy`` -- beyond-paper ablation: factored categorical over
  cuts with infeasible cuts masked; usually converges faster.

Both also provide the *joint* variant used by the paper's "PPO" baseline
(partitioning + all resources in one action vector, no convex assist).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .networks import mlp_apply, mlp_init

_LOG2PI = jnp.log(2.0 * jnp.pi)


def _gauss_logp(y, mean, log_std):
    var = jnp.exp(2.0 * log_std)
    return jnp.sum(-0.5 * (jnp.square(y - mean) / var + 2.0 * log_std + _LOG2PI),
                   axis=-1)


def map_cut(y, num_layers):
    """Eq. (13) with closed-range extension: cut in {0..L}."""
    frac = 0.5 * (jnp.tanh(y) + 1.0)
    return jnp.clip(jnp.floor((num_layers + 1) * frac), 0, num_layers).astype(jnp.int32)


class GaussianTanhPolicy:
    """Paper-faithful continuous head (one y per UE)."""

    def __init__(self, obs_dim: int, num_layers, hidden=(128, 64),
                 init_log_std: float = -0.5):
        self.obs_dim = obs_dim
        self.num_layers = jnp.asarray(num_layers)   # (N,) per-UE L_n
        self.act_dim = int(self.num_layers.shape[0])
        self.hidden = tuple(hidden)
        self.init_log_std = init_log_std

    def init(self, key):
        k1, = jax.random.split(key, 1)
        return {
            "mlp": mlp_init(k1, (self.obs_dim, *self.hidden, self.act_dim)),
            "log_std": jnp.full((self.act_dim,), self.init_log_std, jnp.float32),
        }

    def _mean(self, params, obs):
        return mlp_apply(params["mlp"], obs, final_scale=0.1)

    def sample(self, params, obs, key):
        mean = self._mean(params, obs)
        log_std = params["log_std"]
        y = mean + jnp.exp(log_std) * jax.random.normal(key, mean.shape)
        return y, _gauss_logp(y, mean, log_std)

    def logp(self, params, obs, y):
        return _gauss_logp(y, self._mean(params, obs), params["log_std"])

    def mean_action(self, params, obs):
        return self._mean(params, obs)

    def entropy(self, params, obs):
        del obs
        return jnp.sum(params["log_std"] + 0.5 * (_LOG2PI + 1.0))

    def to_cut(self, y):
        return map_cut(y, self.num_layers)


class CategoricalPolicy:
    """Factored categorical over cuts {0..L_n} per UE (beyond-paper)."""

    def __init__(self, obs_dim: int, num_layers, hidden=(128, 64)):
        self.obs_dim = obs_dim
        self.num_layers = jnp.asarray(num_layers)
        self.n_ue = int(self.num_layers.shape[0])
        self.num_cuts = int(self.num_layers.max()) + 1
        self.hidden = tuple(hidden)

    def init(self, key):
        out = self.n_ue * self.num_cuts
        return {"mlp": mlp_init(key, (self.obs_dim, *self.hidden, out))}

    def _logits(self, params, obs):
        raw = mlp_apply(params["mlp"], obs, final_scale=0.1)
        logits = raw.reshape(*raw.shape[:-1], self.n_ue, self.num_cuts)
        cuts = jnp.arange(self.num_cuts)
        mask = cuts[None, :] <= self.num_layers[:, None]
        return jnp.where(mask, logits, -1e9)

    def sample(self, params, obs, key):
        logits = self._logits(params, obs)
        cut = jax.random.categorical(key, logits, axis=-1)
        return cut, self._logp_from_logits(logits, cut)

    @staticmethod
    def _logp_from_logits(logits, cut):
        logp = jax.nn.log_softmax(logits, axis=-1)
        sel = jnp.take_along_axis(logp, cut[..., None], axis=-1)[..., 0]
        return jnp.sum(sel, axis=-1)

    def logp(self, params, obs, cut):
        return self._logp_from_logits(self._logits(params, obs), cut)

    def mean_action(self, params, obs):
        return jnp.argmax(self._logits(params, obs), axis=-1)

    def entropy(self, params, obs):
        logits = self._logits(params, obs)
        logp = jax.nn.log_softmax(logits, axis=-1)
        return -jnp.sum(jnp.exp(logp) * jnp.where(logp > -1e8, logp, 0.0))

    def to_cut(self, cut):
        return cut.astype(jnp.int32)


class JointGaussianPolicy(GaussianTanhPolicy):
    """The paper's "PPO" baseline head: 4N-dim action = {cut, alpha, f_ue,
    f_es} with no convex assist.  Mappings keep per-slot constraints C3-C6
    satisfiable: alpha via softmax (C4), frequencies via sigmoid/softmax caps
    (C3, C6); C7 is enforced by the same projection LyMDO uses.
    """

    def __init__(self, obs_dim: int, num_layers, f_max_ue: float,
                 f_max_es: float, hidden=(128, 64), init_log_std: float = -0.5):
        self._n = int(jnp.asarray(num_layers).shape[0])
        super().__init__(obs_dim, num_layers, hidden, init_log_std)
        self.act_dim = 4 * self._n          # overrides head width
        self.f_max_ue = f_max_ue
        self.f_max_es = f_max_es

    def split(self, y):
        """y (.., 4N) -> (cut, alpha, f_ue, f_es)."""
        y_cut, y_alpha, y_fue, y_fes = jnp.split(y, 4, axis=-1)
        cut = map_cut(y_cut, self.num_layers)
        alpha = jax.nn.softmax(y_alpha, axis=-1)
        f_ue = jax.nn.sigmoid(y_fue) * self.f_max_ue
        f_es = jax.nn.softmax(y_fes, axis=-1) * self.f_max_es
        return cut, alpha, f_ue, f_es
