"""Decoupled per-slot objective sweep over every (UE, cut) pair.

This is the controller's dense hot spot: evaluating the drift-plus-penalty
objective (eq. 11) for *all* candidate partitions at once.  It powers

* the ``Oracle`` baseline (per-slot argmin over cuts),
* PPO action-space pruning experiments,
* and it is the reference semantics for the ``partition_sweep`` Pallas kernel
  (``repro.kernels.partition_sweep`` computes the same table with in-VMEM
  prefix scans; ``repro.kernels.ref`` wraps this function).

Decoupling approximation: resources that couple UEs are split evenly
(alpha = 1/N, f_es = f_max_es/N); f_ue is solved exactly per cell (P3).  The
chosen cut is then re-evaluated with the exact convex allocators, so the
approximation only affects the argmin, not reported metrics.
"""
from __future__ import annotations

import jax.numpy as jnp

from . import convex, energymem, queueing

_BIG = 1e30


def objective_table(*, prefix_macs, suffix_macs, psi, prefix_params,
                    suffix_params, prefix_act_max, suffix_act_max, L,
                    lam, gain, q_energy, q_memory,
                    rho, kappa, p_tx, w_hz, n0, f_max_ue, f_max_es, v,
                    gamma_ue, gamma_es, stability_margin=1e-3):
    """Returns the (N, C) objective table; infeasible cells hold +BIG.

    All table args are (N, C); lam/gain/q_* are (N,).
    """
    n, c = prefix_macs.shape
    lam_ = lam[:, None]
    gain_ = gain[:, None]
    qe = q_energy[:, None]
    qm = q_memory[:, None]

    d_ue = rho * prefix_macs
    d_es = rho * suffix_macs

    # P3 per cell (broadcasts elementwise over the (N, C) grid).
    f_ue = convex.solve_p3(qe, kappa, d_ue, lam_, v, f_max_ue,
                           stability_margin=stability_margin)
    # Even-split decoupling for the coupled resources.
    alpha = jnp.where(psi > 0, 1.0 / n, 0.0)
    f_es = jnp.where(d_es > 0, f_max_es / n, 0.0)

    t_ue = queueing.ue_sojourn(lam_, f_ue, d_ue)
    t_tx = queueing.trans_delay(psi, alpha, w_hz, p_tx, gain_, n0)
    t_es = queueing.es_sojourn(f_es, d_es)
    delay = t_ue + t_tx + t_es

    energy = energymem.ue_energy(f_ue, d_ue, lam_, kappa, p_tx, t_tx)
    mem = energymem.memory_cost(prefix_params, suffix_params,
                                prefix_act_max, suffix_act_max,
                                gamma_ue, gamma_es)

    obj = qe * energy + qm * mem + v * delay

    cuts = jnp.arange(c)[None, :]
    feasible = (cuts <= L[:, None]) & (
        d_ue * lam_ * (1.0 + stability_margin) < f_max_ue)
    return jnp.where(feasible, obj, _BIG)


def objective_table_p(params, state):
    """Params-first wrapper over a ``MecParams`` pytree (vmap-friendly:
    ``jax.vmap(objective_table_p)`` evaluates B stacked cells at once)."""
    return objective_table(
        prefix_macs=params.prefix_macs, suffix_macs=params.suffix_macs,
        psi=params.psi, prefix_params=params.prefix_params,
        suffix_params=params.suffix_params,
        prefix_act_max=params.prefix_act_max,
        suffix_act_max=params.suffix_act_max,
        L=params.L, lam=state.lam, gain=state.gain,
        q_energy=state.queues.energy, q_memory=state.queues.memory,
        rho=params.rho, kappa=params.kappa, p_tx=params.p_tx,
        w_hz=params.w_hz, n0=params.n0,
        f_max_ue=params.f_max_ue, f_max_es=params.f_max_es, v=params.v,
        gamma_ue=params.gamma_ue, gamma_es=params.gamma_es,
        stability_margin=params.stability_margin)


def oracle_cut_p(params, state):
    """Per-slot decoupled-oracle partitioning decision (params-first)."""
    return jnp.argmin(objective_table_p(params, state), axis=1).astype(jnp.int32)


def env_objective_table(env, state):
    """Convenience wrapper binding an ``MecEnv``'s tables and scalars."""
    return objective_table_p(env.params, state)


def oracle_cut(env, state):
    """Per-slot decoupled-oracle partitioning decision."""
    return jnp.argmin(env_objective_table(env, state), axis=1).astype(jnp.int32)
