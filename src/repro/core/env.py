"""MEC cooperative-inference environment (paper Sec. II + V-A).

Pure-functional JAX environment: ``reset`` / ``step`` are jittable and
vmappable; one step = one time slot of the slotted system.  The step performs
the *entire* per-slot pipeline of LyMDO's inner loop given the partitioning
action: feasibility projection (C7), convex resource allocation (P3-P5),
delay/energy/memory evaluation (eqs. 1-6), reward (14) and virtual-queue
updates (8)-(9).

Two equivalent entry points:

* ``MecEnv`` -- the object API (holds constants, convenient for single-cell
  training/eval scripts and the seed tests);
* ``MecParams`` + the module-level ``*_p`` pure functions -- the params-first
  API.  ``MecParams`` is a registered pytree, so a stack of B cells is just a
  ``jax.tree.map(jnp.stack, ...)`` of per-cell params, and ``jax.vmap`` over
  ``step_p`` evaluates all cells at once (see ``repro.core.scenarios``).

Simulation constants default to the paper's Table I / Sec. V-A setup.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..profiling.profiles import LayerProfile, ProfileBatch
from ..traffic import processes as arrivals
from . import convex, energymem, queueing
from .lyapunov import VirtualQueues, reward as lyapunov_reward, update_queues

# Arrival-rate modes (MecConfig convenience enum).  These translate into the
# corresponding ``repro.traffic.processes`` pytree at ``make_params`` time;
# the env itself dispatches on the process object (``MecParams.arrival``),
# so any registered arrival process -- not just these four -- plugs in via
# ``MecConfig.arrival`` / the ``arrival=`` argument.
LAM_IID_UNIFORM = 0   # lambda ~ U(low, high) iid per UE/slot (training default)
LAM_FIXED = 1         # constant per-UE rate (Fig. 4 evaluation sweeps)
LAM_PEAK = 2          # constant base + peak window (Fig. 5 stability runs)
LAM_TRACE = 3         # replay a recorded (T, N) trace (needs arrival=...)


def free_space_gain(distance_m=150.0, antenna_gain=3.0, carrier_hz=915e6,
                    path_loss_exp=3.0):
    """Mean channel gain h_bar = A_d (c / 4 pi f_c d)^d_e  (Sec. V-A)."""
    wavelength_term = 3e8 / (4.0 * np.pi * carrier_hz * distance_m)
    return antenna_gain * wavelength_term ** path_loss_exp


@dataclasses.dataclass(frozen=True)
class MecConfig:
    """Scenario constants (defaults = paper Table I / Sec. V-A)."""

    w_hz: float = 5e6                 # uplink bandwidth W
    n0: float = 10 ** (-174.0 / 10.0) / 1000.0   # -174 dBm/Hz -> W/Hz
    p_tx: float = 0.1                 # UE transmit power [W]
    rho: float = 0.12                 # CPU cycles per MAC
    kappa: float = 1e-28              # energy coefficient
    f_max_ue: float = 1.5e9           # UE CPU cap [Hz]
    f_max_es: float = 15e9            # ES CPU cap [Hz]
    v: float = 10.0                   # Lyapunov penalty weight V
    nu_e: float = 100.0               # energy-queue step (eq. 8)
    nu_c: float = 10.0                # memory-queue step (eq. 9)
    gamma_ue: float = 0.2             # UE memory cost factor
    gamma_es: float = 0.8             # ES memory cost factor
    lam_low: float = 0.5              # request/s
    lam_high: float = 2.5
    lam_mode: int = LAM_IID_UNIFORM
    peak_start: int = 75              # Fig. 5 peak-workload window
    peak_stop: int = 110
    peak_boost: float = 1.0           # added req/s inside the window
    stability_margin: float = 1e-3    # C7 projection slack
    edge_queueing: bool = False       # eq. 4 (False) vs G/D/1 correction (True)
    queue_obs_scale: float = 1e-2     # observation scaling for Q/W entries
    arrival: Any = None               # explicit arrival process (overrides
                                      # lam_mode; see repro.traffic.processes)


# Scalar MecConfig fields carried into MecParams as traced 0-d arrays (so a
# stacked batch can vary them per cell).  ``edge_queueing`` stays static: it
# selects a Python-level branch in ``_evaluate_p``.
_FLOAT_FIELDS = ("w_hz", "n0", "p_tx", "rho", "kappa", "f_max_ue", "f_max_es",
                 "v", "nu_e", "nu_c", "gamma_ue", "gamma_es",
                 "stability_margin", "queue_obs_scale")

_PARAMS_DATA = (
    # raw per-layer tables, (N, C) -- kept for the Pallas sweep kernel route
    "macs", "param_bytes", "act_bytes",
    # per-cut tables, (N, C)
    "prefix_macs", "suffix_macs", "psi", "prefix_params", "suffix_params",
    "prefix_act_max", "suffix_act_max",
    # per-UE vectors, (N,)
    "L", "e_budget", "c_budget",
    # the arrival process (its own pytree; leaves (N,)/(T,N)/0-d)
    "arrival",
    # per-cell scalars, 0-d (stack to (B,))
    "mean_gain",
) + _FLOAT_FIELDS


@dataclasses.dataclass(frozen=True)
class MecParams:
    """Everything ``step_p`` reads, as one pytree of arrays.

    All leaves are per-cell: tables are (N, C), vectors (N,), scalars 0-d.
    ``jnp.stack``-ing B instances (``repro.core.scenarios.stack_params``)
    yields a (B, ...) batch that ``jax.vmap`` maps back to this layout.

    ``arrival`` is the per-slot arrival-rate process -- any registered
    pytree from :mod:`repro.traffic.processes` (``(key, t) -> (N,) lam``).
    Its *type* is part of the treedef, so cells of one stacked batch share
    the process kind while its array leaves vary per cell.
    """

    macs: jax.Array
    param_bytes: jax.Array
    act_bytes: jax.Array
    prefix_macs: jax.Array
    suffix_macs: jax.Array
    psi: jax.Array
    prefix_params: jax.Array
    suffix_params: jax.Array
    prefix_act_max: jax.Array
    suffix_act_max: jax.Array
    L: jax.Array
    e_budget: jax.Array
    c_budget: jax.Array
    arrival: Any
    mean_gain: jax.Array
    w_hz: jax.Array
    n0: jax.Array
    p_tx: jax.Array
    rho: jax.Array
    kappa: jax.Array
    f_max_ue: jax.Array
    f_max_es: jax.Array
    v: jax.Array
    nu_e: jax.Array
    nu_c: jax.Array
    gamma_ue: jax.Array
    gamma_es: jax.Array
    stability_margin: jax.Array
    queue_obs_scale: jax.Array
    edge_queueing: bool = False

    @property
    def n_ue(self) -> int:
        return self.L.shape[-1]

    @property
    def num_cuts(self) -> int:
        return self.prefix_macs.shape[-1]

    @property
    def obs_dim(self) -> int:
        return 4 * self.n_ue


jax.tree_util.register_dataclass(
    MecParams, data_fields=list(_PARAMS_DATA), meta_fields=["edge_queueing"])


def arrival_from_config(cfg: MecConfig, n: int,
                        lam_fixed: Sequence[float] | None = None):
    """Translate the MecConfig enum/knobs into an arrival-process pytree."""
    base = jnp.asarray(np.full(n, cfg.lam_high, np.float32)
                       if lam_fixed is None
                       else np.asarray(lam_fixed, np.float32))
    if cfg.lam_mode == LAM_IID_UNIFORM:
        return arrivals.IidUniform(low=arrivals.per_ue(cfg.lam_low, n),
                                   high=arrivals.per_ue(cfg.lam_high, n))
    if cfg.lam_mode == LAM_FIXED:
        return arrivals.FixedRate(lam=base)
    if cfg.lam_mode == LAM_PEAK:
        return arrivals.PeakWindow(base=base,
                                   boost=jnp.float32(cfg.peak_boost),
                                   start=jnp.int32(cfg.peak_start),
                                   stop=jnp.int32(cfg.peak_stop))
    if cfg.lam_mode == LAM_TRACE:
        raise ValueError(
            "LAM_TRACE needs an explicit process: pass arrival="
            "repro.traffic.TraceArrivals(...) (e.g. Trace.load(p).process())")
    raise ValueError(f"unknown lam_mode {cfg.lam_mode!r}")


def make_params(profiles: Sequence[LayerProfile], cfg: MecConfig,
                e_budget: Sequence[float], c_budget: Sequence[float],
                mean_gain: float | None = None,
                lam_fixed: Sequence[float] | None = None,
                arrival=None) -> MecParams:
    """Build a single-cell MecParams from profiles + scenario constants.

    The arrival process resolves in priority order: the ``arrival`` argument,
    then ``cfg.arrival``, then the classic ``cfg.lam_mode`` enum translation
    (with ``lam_fixed`` seeding the fixed/peak base rates).
    """
    batch = ProfileBatch(profiles)
    n = batch.n
    as_f32 = lambda a: jnp.asarray(a, jnp.float32)
    e_budget = as_f32(e_budget)
    c_budget = as_f32(c_budget)
    if e_budget.shape != (n,) or c_budget.shape != (n,):
        raise ValueError("budgets must have one entry per UE")
    if arrival is None:
        arrival = cfg.arrival
    if arrival is None:
        arrival = arrival_from_config(cfg, n, lam_fixed)
    fields = dict(
        macs=as_f32(batch.macs),
        param_bytes=as_f32(batch.param_bytes),
        act_bytes=as_f32(batch.act_bytes),
        prefix_macs=as_f32(batch.prefix_macs),
        suffix_macs=as_f32(batch.suffix_macs),
        psi=as_f32(batch.psi),
        prefix_params=as_f32(batch.prefix_params),
        suffix_params=as_f32(batch.suffix_params),
        prefix_act_max=as_f32(batch.prefix_act_max),
        suffix_act_max=as_f32(batch.suffix_act_max),
        L=jnp.asarray(batch.L, jnp.int32),
        e_budget=e_budget,
        c_budget=c_budget,
        arrival=arrival,
        mean_gain=jnp.float32(free_space_gain() if mean_gain is None
                              else mean_gain),
        edge_queueing=cfg.edge_queueing,
    )
    for f in _FLOAT_FIELDS:
        fields[f] = jnp.float32(getattr(cfg, f))
    return MecParams(**fields)


class MecState(NamedTuple):
    key: jax.Array
    t: jax.Array            # slot index, int32
    gain: jax.Array         # (N,) current channel gains h
    lam: jax.Array          # (N,) current arrival rates
    queues: VirtualQueues   # Q(t), W(t)


class SlotResult(NamedTuple):
    """Everything the algorithms/benchmarks need from one slot."""

    reward: jax.Array
    delay: jax.Array        # (N,) T_E2E
    t_ue: jax.Array
    t_tx: jax.Array
    t_es: jax.Array
    energy: jax.Array       # (N,) E_ue [J/slot]
    mem_cost: jax.Array     # (N,) C_tot [GB]
    cut: jax.Array          # (N,) projected partition decision
    alpha: jax.Array
    f_ue: jax.Array
    f_es: jax.Array
    q_energy: jax.Array     # Q(t) used in the reward (pre-update)
    q_memory: jax.Array


# ---------------------------------------------------------------------------
# Params-first pure API (the batched / vmap path)
# ---------------------------------------------------------------------------

def observe_p(p: MecParams, state: MecState) -> jax.Array:
    """s^t = {h, lambda, Q, W} (Sec. IV-B1), scaled to O(1)."""
    return jnp.concatenate([
        state.gain / p.mean_gain,
        state.lam,
        p.queue_obs_scale * state.queues.energy,
        p.queue_obs_scale * state.queues.memory,
    ])


def _draw_p(p: MecParams, key, t):
    k_gain, k_lam = jax.random.split(key)
    beta = jax.random.exponential(k_gain, (p.n_ue,), jnp.float32)
    gain = beta * p.mean_gain  # Rayleigh fading power
    # Static dispatch on the arrival-process type (no lax.switch over dead
    # branches): any repro.traffic process -- synthetic or trace replay --
    # supplies this slot's per-UE rates.
    lam = p.arrival(k_lam, t)
    return gain, lam


def reset_p(p: MecParams, key: jax.Array) -> MecState:
    key, sub = jax.random.split(key)
    gain, lam = _draw_p(p, sub, jnp.int32(0))
    return MecState(key=key, t=jnp.int32(0), gain=gain, lam=lam,
                    queues=VirtualQueues.zeros(p.n_ue))


def max_feasible_cut_p(p: MecParams, lam: jax.Array) -> jax.Array:
    """Largest cut whose local queue is stable: rho*prefix*lam < f_max (C7)."""
    demand = p.rho * p.prefix_macs * lam[:, None] * (1.0 + p.stability_margin)
    feasible = demand < p.f_max_ue          # (N, C); monotone in cut
    return jnp.minimum(jnp.sum(feasible, axis=1) - 1, p.L)


def project_cut_p(p: MecParams, cut: jax.Array, lam: jax.Array) -> jax.Array:
    return jnp.clip(cut, 0, max_feasible_cut_p(p, lam)).astype(jnp.int32)


def _gather(table: jax.Array, cut: jax.Array) -> jax.Array:
    return jnp.take_along_axis(table, cut[:, None], axis=1)[:, 0]


def step_p(p: MecParams, state: MecState,
           cut: jax.Array) -> tuple[MecState, SlotResult]:
    """LyMDO inner loop: partitioning action + exact convex allocation."""
    cut = project_cut_p(p, cut, state.lam)
    d_ue = p.rho * _gather(p.prefix_macs, cut)
    d_es = p.rho * _gather(p.suffix_macs, cut)
    psi = _gather(p.psi, cut)

    q = state.queues
    f_es = convex.solve_p4(d_es, p.f_max_es)
    f_ue = convex.solve_p3(q.energy, p.kappa, d_ue, state.lam, p.v,
                           p.f_max_ue, stability_margin=p.stability_margin)
    alpha = convex.solve_p5(q.energy, p.p_tx, state.lam, p.v, psi,
                            p.w_hz, state.gain, p.n0)
    return _evaluate_p(p, state, cut, alpha, f_ue, f_es, d_ue, d_es, psi)


def step_joint_p(p: MecParams, state: MecState, cut: jax.Array,
                 alpha: jax.Array, f_ue: jax.Array,
                 f_es: jax.Array) -> tuple[MecState, SlotResult]:
    """Paper's "PPO" baseline: all four decisions come from the agent.

    Only hard physics is enforced: C7 projection on the cut and a clamp of
    f_ue into the stable band (a near-boundary f_ue still yields the huge
    queuing delays the paper describes in Fig. 3's discussion).
    """
    cut = project_cut_p(p, cut, state.lam)
    d_ue = p.rho * _gather(p.prefix_macs, cut)
    d_es = p.rho * _gather(p.suffix_macs, cut)
    psi = _gather(p.psi, cut)
    lo = jnp.where(d_ue > 0,
                   d_ue * state.lam * (1.0 + p.stability_margin) + 1.0, 0.0)
    f_ue = jnp.clip(f_ue, lo, p.f_max_ue)
    f_ue = jnp.where(d_ue > 0, f_ue, 0.0)
    f_es = jnp.where(d_es > 0, f_es, 0.0)
    alpha = jnp.where(psi > 0, alpha, 0.0)
    return _evaluate_p(p, state, cut, alpha, f_ue, f_es, d_ue, d_es, psi)


def _evaluate_p(p: MecParams, state, cut, alpha, f_ue, f_es, d_ue, d_es, psi):
    q = state.queues
    delay, (t_ue, t_tx, t_es) = queueing.e2e_delay(
        state.lam, f_ue, f_es, d_ue, d_es, psi, alpha,
        p.w_hz, p.p_tx, state.gain, p.n0, edge_queueing=p.edge_queueing)

    energy = energymem.ue_energy(f_ue, d_ue, state.lam, p.kappa, p.p_tx, t_tx)
    mem = energymem.memory_cost(
        _gather(p.prefix_params, cut),
        _gather(p.suffix_params, cut),
        _gather(p.prefix_act_max, cut),
        _gather(p.suffix_act_max, cut),
        p.gamma_ue, p.gamma_es)

    rew = lyapunov_reward(q, energy, mem, delay, p.v)
    new_queues = update_queues(q, energy, mem, p.e_budget, p.c_budget,
                               p.nu_e, p.nu_c)

    key, sub = jax.random.split(state.key)
    t_next = state.t + 1
    gain, lam = _draw_p(p, sub, t_next)
    new_state = MecState(key=key, t=t_next, gain=gain, lam=lam,
                         queues=new_queues)
    result = SlotResult(
        reward=rew, delay=delay, t_ue=t_ue, t_tx=t_tx, t_es=t_es,
        energy=energy, mem_cost=mem, cut=cut, alpha=alpha,
        f_ue=f_ue, f_es=f_es,
        q_energy=q.energy, q_memory=q.memory)
    return new_state, result


# ---------------------------------------------------------------------------
# Object API (thin wrapper; single-cell scripts and the seed tests use this)
# ---------------------------------------------------------------------------

class MecEnv:
    """N-UE cooperative-inference environment over a ProfileBatch.

    All methods are pure; the instance only holds constants (a ``MecParams``
    pytree), so jitting ``env.step`` (or closing over it in a scan) is safe.
    """

    def __init__(self, profiles: Sequence[LayerProfile], cfg: MecConfig,
                 e_budget: Sequence[float], c_budget: Sequence[float],
                 mean_gain: float | None = None,
                 lam_fixed: Sequence[float] | None = None,
                 arrival=None):
        self.cfg = cfg
        self.batch = ProfileBatch(profiles)
        self.params = make_params(profiles, cfg, e_budget, c_budget,
                                  mean_gain=mean_gain, lam_fixed=lam_fixed,
                                  arrival=arrival)
        # Max feasible cut per (UE, lambda) is recomputed each slot (C7).
        # Tables/budgets are exposed as read-only properties onto
        # self.params (below) so they can never diverge from what step()
        # actually uses; mutate via e.g. ``env.lam_fixed = ...`` (setter)
        # or ``dataclasses.replace(env.params, ...)``.

    @property
    def arrival(self):
        return self.params.arrival

    @arrival.setter
    def arrival(self, process):
        self.params = dataclasses.replace(self.params, arrival=process)

    @property
    def lam_fixed(self) -> jax.Array:
        """Base rate of a fixed/peak arrival process (back-compat view)."""
        arr = self.params.arrival
        if isinstance(arr, arrivals.FixedRate):
            return arr.lam
        if isinstance(arr, arrivals.PeakWindow):
            return arr.base
        raise AttributeError(
            f"lam_fixed is only defined for fixed/peak arrivals, not "
            f"{type(arr).__name__}; mutate env.arrival instead")

    @lam_fixed.setter
    def lam_fixed(self, value):
        arr = self.params.arrival
        value = jnp.asarray(value, jnp.float32)
        if isinstance(arr, arrivals.FixedRate):
            arr = dataclasses.replace(arr, lam=value)
        elif isinstance(arr, arrivals.PeakWindow):
            arr = dataclasses.replace(arr, base=value)
        else:
            raise AttributeError(
                f"lam_fixed is only defined for fixed/peak arrivals, not "
                f"{type(arr).__name__}; set env.arrival instead")
        self.params = dataclasses.replace(self.params, arrival=arr)

    # -- observation ------------------------------------------------------

    @property
    def obs_dim(self) -> int:
        return 4 * self.n_ue

    @property
    def action_dim(self) -> int:
        return self.n_ue

    def observe(self, state: MecState) -> jax.Array:
        return observe_p(self.params, state)

    def reset(self, key: jax.Array) -> MecState:
        return reset_p(self.params, key)

    # -- feasibility (C7) --------------------------------------------------

    def max_feasible_cut(self, lam: jax.Array) -> jax.Array:
        return max_feasible_cut_p(self.params, lam)

    def project_cut(self, cut: jax.Array, lam: jax.Array) -> jax.Array:
        return project_cut_p(self.params, cut, lam)

    # -- one slot -----------------------------------------------------------

    def step(self, state: MecState, cut: jax.Array) -> tuple[MecState, SlotResult]:
        return step_p(self.params, state, cut)

    def step_joint(self, state: MecState, cut: jax.Array, alpha: jax.Array,
                   f_ue: jax.Array, f_es: jax.Array) -> tuple[MecState, SlotResult]:
        return step_joint_p(self.params, state, cut, alpha, f_ue, f_es)


def _delegate(name):
    return property(lambda self: getattr(self.params, name),
                    doc=f"Read-only view of ``params.{name}``.")


for _f in ("n_ue", "num_cuts", "L", "prefix_macs", "suffix_macs", "psi",
           "prefix_params", "suffix_params", "prefix_act_max",
           "suffix_act_max", "e_budget", "c_budget", "mean_gain"):
    setattr(MecEnv, _f, _delegate(_f))


def paper_env(cfg: MecConfig = MecConfig(), n_alexnet: int = 2,
              n_resnet: int = 3) -> MecEnv:
    """The paper's Sec. V-A scenario: 5 UEs = 2x AlexNet + 3x ResNet18,
    e = (40, 60) mJ, eps = (100, 30) MB (J / GB canonical units)."""
    from ..profiling.convnets import alexnet_profile, resnet18_profile

    profiles = [alexnet_profile()] * n_alexnet + [resnet18_profile()] * n_resnet
    e_budget = [0.040] * n_alexnet + [0.060] * n_resnet
    c_budget = [0.100] * n_alexnet + [0.030] * n_resnet
    return MecEnv(profiles, cfg, e_budget, c_budget)
