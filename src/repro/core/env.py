"""MEC cooperative-inference environment (paper Sec. II + V-A).

Pure-functional JAX environment: ``reset`` / ``step`` are jittable and
vmappable; one step = one time slot of the slotted system.  The step performs
the *entire* per-slot pipeline of LyMDO's inner loop given the partitioning
action: feasibility projection (C7), convex resource allocation (P3-P5),
delay/energy/memory evaluation (eqs. 1-6), reward (14) and virtual-queue
updates (8)-(9).

Simulation constants default to the paper's Table I / Sec. V-A setup.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..profiling.profiles import LayerProfile, ProfileBatch
from . import convex, energymem, queueing
from .lyapunov import VirtualQueues, reward as lyapunov_reward, update_queues

# Arrival-rate processes
LAM_IID_UNIFORM = 0   # lambda ~ U(low, high) iid per UE/slot (training default)
LAM_FIXED = 1         # constant per-UE rate (Fig. 4 evaluation sweeps)
LAM_PEAK = 2          # constant base + peak window (Fig. 5 stability runs)


def free_space_gain(distance_m=150.0, antenna_gain=3.0, carrier_hz=915e6,
                    path_loss_exp=3.0):
    """Mean channel gain h_bar = A_d (c / 4 pi f_c d)^d_e  (Sec. V-A)."""
    wavelength_term = 3e8 / (4.0 * np.pi * carrier_hz * distance_m)
    return antenna_gain * wavelength_term ** path_loss_exp


@dataclasses.dataclass(frozen=True)
class MecConfig:
    """Scenario constants (defaults = paper Table I / Sec. V-A)."""

    w_hz: float = 5e6                 # uplink bandwidth W
    n0: float = 10 ** (-174.0 / 10.0) / 1000.0   # -174 dBm/Hz -> W/Hz
    p_tx: float = 0.1                 # UE transmit power [W]
    rho: float = 0.12                 # CPU cycles per MAC
    kappa: float = 1e-28              # energy coefficient
    f_max_ue: float = 1.5e9           # UE CPU cap [Hz]
    f_max_es: float = 15e9            # ES CPU cap [Hz]
    v: float = 10.0                   # Lyapunov penalty weight V
    nu_e: float = 100.0               # energy-queue step (eq. 8)
    nu_c: float = 10.0                # memory-queue step (eq. 9)
    gamma_ue: float = 0.2             # UE memory cost factor
    gamma_es: float = 0.8             # ES memory cost factor
    lam_low: float = 0.5              # request/s
    lam_high: float = 2.5
    lam_mode: int = LAM_IID_UNIFORM
    peak_start: int = 75              # Fig. 5 peak-workload window
    peak_stop: int = 110
    peak_boost: float = 1.0           # added req/s inside the window
    stability_margin: float = 1e-3    # C7 projection slack
    edge_queueing: bool = False       # eq. 4 (False) vs G/D/1 correction (True)
    queue_obs_scale: float = 1e-2     # observation scaling for Q/W entries


class MecState(NamedTuple):
    key: jax.Array
    t: jax.Array            # slot index, int32
    gain: jax.Array         # (N,) current channel gains h
    lam: jax.Array          # (N,) current arrival rates
    queues: VirtualQueues   # Q(t), W(t)


class SlotResult(NamedTuple):
    """Everything the algorithms/benchmarks need from one slot."""

    reward: jax.Array
    delay: jax.Array        # (N,) T_E2E
    t_ue: jax.Array
    t_tx: jax.Array
    t_es: jax.Array
    energy: jax.Array       # (N,) E_ue [J/slot]
    mem_cost: jax.Array     # (N,) C_tot [GB]
    cut: jax.Array          # (N,) projected partition decision
    alpha: jax.Array
    f_ue: jax.Array
    f_es: jax.Array
    q_energy: jax.Array     # Q(t) used in the reward (pre-update)
    q_memory: jax.Array


class MecEnv:
    """N-UE cooperative-inference environment over a ProfileBatch.

    All methods are pure; the instance only holds constants, so jitting
    ``env.step`` (or closing over it in a scan) is safe.
    """

    def __init__(self, profiles: Sequence[LayerProfile], cfg: MecConfig,
                 e_budget: Sequence[float], c_budget: Sequence[float],
                 mean_gain: float | None = None,
                 lam_fixed: Sequence[float] | None = None):
        self.cfg = cfg
        self.batch = ProfileBatch(profiles)
        n = self.batch.n
        as_f32 = lambda a: jnp.asarray(a, jnp.float32)
        self.n_ue = n
        self.num_cuts = self.batch.Lmax + 1
        self.L = jnp.asarray(self.batch.L, jnp.int32)
        self.prefix_macs = as_f32(self.batch.prefix_macs)
        self.suffix_macs = as_f32(self.batch.suffix_macs)
        self.psi = as_f32(self.batch.psi)
        self.prefix_params = as_f32(self.batch.prefix_params)
        self.suffix_params = as_f32(self.batch.suffix_params)
        self.prefix_act_max = as_f32(self.batch.prefix_act_max)
        self.suffix_act_max = as_f32(self.batch.suffix_act_max)
        self.e_budget = as_f32(e_budget)
        self.c_budget = as_f32(c_budget)
        if self.e_budget.shape != (n,) or self.c_budget.shape != (n,):
            raise ValueError("budgets must have one entry per UE")
        self.mean_gain = jnp.float32(
            free_space_gain() if mean_gain is None else mean_gain)
        self.lam_fixed = as_f32(
            np.full(n, cfg.lam_high) if lam_fixed is None else lam_fixed)
        # Max feasible cut per (UE, lambda) is recomputed each slot (C7).

    # -- observation ------------------------------------------------------

    @property
    def obs_dim(self) -> int:
        return 4 * self.n_ue

    @property
    def action_dim(self) -> int:
        return self.n_ue

    def observe(self, state: MecState) -> jax.Array:
        """s^t = {h, lambda, Q, W} (Sec. IV-B1), scaled to O(1)."""
        c = self.cfg
        return jnp.concatenate([
            state.gain / self.mean_gain,
            state.lam,
            c.queue_obs_scale * state.queues.energy,
            c.queue_obs_scale * state.queues.memory,
        ])

    # -- exogenous processes ----------------------------------------------

    def _draw(self, key, t):
        c = self.cfg
        k_gain, k_lam = jax.random.split(key)
        beta = jax.random.exponential(k_gain, (self.n_ue,), jnp.float32)
        gain = beta * self.mean_gain  # Rayleigh fading power
        u = jax.random.uniform(k_lam, (self.n_ue,), jnp.float32,
                               c.lam_low, c.lam_high)
        in_peak = jnp.logical_and(t >= c.peak_start, t < c.peak_stop)
        peak = self.lam_fixed + jnp.where(in_peak, c.peak_boost, 0.0)
        lam = jax.lax.switch(
            jnp.int32(c.lam_mode),
            [lambda: u, lambda: self.lam_fixed, lambda: peak])
        return gain, lam

    def reset(self, key: jax.Array) -> MecState:
        key, sub = jax.random.split(key)
        gain, lam = self._draw(sub, jnp.int32(0))
        return MecState(key=key, t=jnp.int32(0), gain=gain, lam=lam,
                        queues=VirtualQueues.zeros(self.n_ue))

    # -- feasibility (C7) --------------------------------------------------

    def max_feasible_cut(self, lam: jax.Array) -> jax.Array:
        """Largest cut whose local queue is stable: rho*prefix*lam < f_max."""
        c = self.cfg
        demand = c.rho * self.prefix_macs * lam[:, None] * (1.0 + c.stability_margin)
        feasible = demand < c.f_max_ue          # (N, C); monotone in cut
        return jnp.minimum(jnp.sum(feasible, axis=1) - 1, self.L)

    def project_cut(self, cut: jax.Array, lam: jax.Array) -> jax.Array:
        return jnp.clip(cut, 0, self.max_feasible_cut(lam)).astype(jnp.int32)

    # -- per-cut gathers ----------------------------------------------------

    def _gather(self, table: jax.Array, cut: jax.Array) -> jax.Array:
        return jnp.take_along_axis(table, cut[:, None], axis=1)[:, 0]

    # -- one slot -----------------------------------------------------------

    def step(self, state: MecState, cut: jax.Array) -> tuple[MecState, SlotResult]:
        """LyMDO inner loop: partitioning action + exact convex allocation."""
        c = self.cfg
        cut = self.project_cut(cut, state.lam)
        d_ue = c.rho * self._gather(self.prefix_macs, cut)
        d_es = c.rho * self._gather(self.suffix_macs, cut)
        psi = self._gather(self.psi, cut)

        q = state.queues
        f_es = convex.solve_p4(d_es, c.f_max_es)
        f_ue = convex.solve_p3(q.energy, c.kappa, d_ue, state.lam, c.v,
                               c.f_max_ue, stability_margin=c.stability_margin)
        alpha = convex.solve_p5(q.energy, c.p_tx, state.lam, c.v, psi,
                                c.w_hz, state.gain, c.n0)
        return self._evaluate(state, cut, alpha, f_ue, f_es, d_ue, d_es, psi)

    def step_joint(self, state: MecState, cut: jax.Array, alpha: jax.Array,
                   f_ue: jax.Array, f_es: jax.Array) -> tuple[MecState, SlotResult]:
        """Paper's "PPO" baseline: all four decisions come from the agent.

        Only hard physics is enforced: C7 projection on the cut and a clamp of
        f_ue into the stable band (a near-boundary f_ue still yields the huge
        queuing delays the paper describes in Fig. 3's discussion).
        """
        c = self.cfg
        cut = self.project_cut(cut, state.lam)
        d_ue = c.rho * self._gather(self.prefix_macs, cut)
        d_es = c.rho * self._gather(self.suffix_macs, cut)
        psi = self._gather(self.psi, cut)
        lo = jnp.where(d_ue > 0,
                       d_ue * state.lam * (1.0 + c.stability_margin) + 1.0, 0.0)
        f_ue = jnp.clip(f_ue, lo, c.f_max_ue)
        f_ue = jnp.where(d_ue > 0, f_ue, 0.0)
        f_es = jnp.where(d_es > 0, f_es, 0.0)
        alpha = jnp.where(psi > 0, alpha, 0.0)
        return self._evaluate(state, cut, alpha, f_ue, f_es, d_ue, d_es, psi)

    def _evaluate(self, state, cut, alpha, f_ue, f_es, d_ue, d_es, psi):
        c = self.cfg
        q = state.queues
        delay, (t_ue, t_tx, t_es) = queueing.e2e_delay(
            state.lam, f_ue, f_es, d_ue, d_es, psi, alpha,
            c.w_hz, c.p_tx, state.gain, c.n0, edge_queueing=c.edge_queueing)

        energy = energymem.ue_energy(f_ue, d_ue, state.lam, c.kappa, c.p_tx, t_tx)
        mem = energymem.memory_cost(
            self._gather(self.prefix_params, cut),
            self._gather(self.suffix_params, cut),
            self._gather(self.prefix_act_max, cut),
            self._gather(self.suffix_act_max, cut),
            c.gamma_ue, c.gamma_es)

        rew = lyapunov_reward(q, energy, mem, delay, c.v)
        new_queues = update_queues(q, energy, mem, self.e_budget, self.c_budget,
                                   c.nu_e, c.nu_c)

        key, sub = jax.random.split(state.key)
        t_next = state.t + 1
        gain, lam = self._draw(sub, t_next)
        new_state = MecState(key=key, t=t_next, gain=gain, lam=lam,
                             queues=new_queues)
        result = SlotResult(
            reward=rew, delay=delay, t_ue=t_ue, t_tx=t_tx, t_es=t_es,
            energy=energy, mem_cost=mem, cut=cut, alpha=alpha,
            f_ue=f_ue, f_es=f_es,
            q_energy=q.energy, q_memory=q.memory)
        return new_state, result


def paper_env(cfg: MecConfig = MecConfig(), n_alexnet: int = 2,
              n_resnet: int = 3) -> MecEnv:
    """The paper's Sec. V-A scenario: 5 UEs = 2x AlexNet + 3x ResNet18,
    e = (40, 60) mJ, eps = (100, 30) MB (J / GB canonical units)."""
    from ..profiling.convnets import alexnet_profile, resnet18_profile

    profiles = [alexnet_profile()] * n_alexnet + [resnet18_profile()] * n_resnet
    e_budget = [0.040] * n_alexnet + [0.060] * n_resnet
    c_budget = [0.100] * n_alexnet + [0.030] * n_resnet
    return MecEnv(profiles, cfg, e_budget, c_budget)
