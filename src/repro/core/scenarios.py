"""Scenario registry + batched multi-cell evaluation engine.

The paper evaluates one cell (N UEs, one ES, Table I constants).  Scaling the
reproduction to "many cells x many UE populations x many arrival processes"
needs two things:

1. **A registry of named scenario constructors** -- each returns a
   :class:`Scenario` (profiles + budgets + ``MecConfig`` + channel geometry)
   so sweeps are declared by name/knobs instead of hand-built envs.  See
   ``docs/scenarios.md`` for the catalogue and how to add one.

2. **A batched engine** -- a :class:`ScenarioGrid` stacks B single-cell
   ``MecParams`` pytrees into one (B, ...) pytree (``stack_params``) and
   evaluates all cells with ``jax.vmap`` over the pure ``step_p`` /
   ``objective_table_p`` functions, wrapped in a single ``lax.scan`` over
   time slots.  One jitted program replaces the per-cell Python loop.

The batched Oracle's hot inner loop (the (B, N, C) objective table) routes
through the ``partition_sweep`` Pallas kernel on TPU (one launch for all
cells, ``n_total`` pinned to the per-cell UE count) and falls back to the
checked ``kernels.ref`` / pure-lax path elsewhere.

3. **A device-sharded grid** -- ``ScenarioGrid.use_mesh`` places the stacked
   (B, ...) pytree over a ``("cells",)`` device mesh
   (``repro.launch.mesh.make_cells_mesh``) with ``NamedSharding``; uneven B
   is padded to a device multiple with a validity mask
   (``repro.core.gridshard``).  ``use_mesh(model=M)`` activates the 2-D
   ``("cells", "model")`` mesh: M-way per-cell tensor parallelism over each
   cell's UE axis on top of the cell split.  The jitted rollout is
   unchanged -- GSPMD partitions the vmap+scan over devices -- and sharded
   rollouts (1-D or 2-D) match single-device ones to 1e-5 for EVERY
   registered scenario (padded cells never pollute summaries; pinned by
   tests/test_gridshard.py's registry-wide parity suite).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..profiling.profiles import LayerProfile
from ..traffic import processes as traffic
from . import gridshard, sweep
from .env import (LAM_FIXED, LAM_PEAK, LAM_TRACE, MecConfig,
                  MecEnv, MecParams, MecState, SlotResult, free_space_gain,
                  make_params, reset_p, step_p)

# Scalars the Pallas sweep kernel bakes in at compile time; the kernel route
# is only available when these agree across every cell of a grid.
_SWEEP_SCALARS = ("rho", "kappa", "p_tx", "w_hz", "n0", "f_max_ue",
                  "f_max_es", "v", "gamma_ue", "gamma_es", "stability_margin")


# ---------------------------------------------------------------------------
# Scenario spec
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Scenario:
    """Declarative single-cell scenario: everything needed to build an env."""

    name: str
    cfg: MecConfig
    profiles: tuple[LayerProfile, ...]
    e_budget: tuple[float, ...]
    c_budget: tuple[float, ...]
    mean_gain: float | None = None          # None -> paper free-space default
    lam_fixed: tuple[float, ...] | None = None
    arrival: object | None = None           # explicit repro.traffic process
                                            # (overrides cfg.lam_mode)
    description: str = ""

    @property
    def n_ue(self) -> int:
        return len(self.profiles)

    def build(self) -> MecEnv:
        return MecEnv(list(self.profiles), self.cfg, list(self.e_budget),
                      list(self.c_budget), mean_gain=self.mean_gain,
                      lam_fixed=None if self.lam_fixed is None
                      else list(self.lam_fixed), arrival=self.arrival)

    def params(self) -> MecParams:
        return make_params(list(self.profiles), self.cfg, list(self.e_budget),
                           list(self.c_budget), mean_gain=self.mean_gain,
                           lam_fixed=None if self.lam_fixed is None
                           else list(self.lam_fixed), arrival=self.arrival)

    def sweep_scalars(self) -> dict:
        """Host-side constants for the Pallas partition-sweep route."""
        return {k: float(getattr(self.cfg, k)) for k in _SWEEP_SCALARS}


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, Callable[..., Scenario]] = {}


def register(name: str):
    """Decorator: register a named scenario constructor."""
    def deco(fn):
        if name in _REGISTRY:
            raise ValueError(f"scenario {name!r} already registered")
        _REGISTRY[name] = fn
        fn.scenario_name = name
        return fn
    return deco


def names() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def make(name: str, **knobs) -> Scenario:
    """Build a registered scenario by name (knobs forwarded verbatim)."""
    try:
        ctor = _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown scenario {name!r}; have {names()}") from None
    return ctor(**knobs)


def describe() -> str:
    lines = []
    for name in names():
        doc = (_REGISTRY[name].__doc__ or "").strip().splitlines()
        lines.append(f"{name}: {doc[0] if doc else ''}")
    return "\n".join(lines)


# ---------------------------------------------------------------------------
# Built-in scenario constructors
# ---------------------------------------------------------------------------

def _paper_fleet(n_alexnet: int, n_resnet: int):
    from ..profiling.convnets import alexnet_profile, resnet18_profile
    profiles = ([alexnet_profile()] * n_alexnet
                + [resnet18_profile()] * n_resnet)
    e = (0.040,) * n_alexnet + (0.060,) * n_resnet
    c = (0.100,) * n_alexnet + (0.030,) * n_resnet
    return tuple(profiles), e, c


@register("paper_table1")
def paper_table1(n_alexnet: int = 2, n_resnet: int = 3,
                 cfg: MecConfig = MecConfig()) -> Scenario:
    """Paper Sec. V-A / Table I: 2x AlexNet + 3x ResNet18, iid-uniform rates."""
    profiles, e, c = _paper_fleet(n_alexnet, n_resnet)
    return Scenario(name="paper_table1", cfg=cfg, profiles=profiles,
                    e_budget=e, c_budget=c,
                    description="paper Table I single cell")


@register("fixed_rate")
def fixed_rate(rate: float = 2.5, n_alexnet: int = 2,
               n_resnet: int = 3) -> Scenario:
    """Fig. 4 sweep point: constant per-UE arrival rate (req/s)."""
    profiles, e, c = _paper_fleet(n_alexnet, n_resnet)
    n = len(profiles)
    return Scenario(name=f"fixed_rate[{rate:g}]",
                    cfg=MecConfig(lam_mode=LAM_FIXED),
                    profiles=profiles, e_budget=e, c_budget=c,
                    lam_fixed=(float(rate),) * n,
                    description=f"Fig. 4 fixed-rate cell @ {rate:g} req/s")


@register("peak_window")
def peak_window(base_rate: float = 2.5, boost: float = 1.0, start: int = 75,
                stop: int = 110) -> Scenario:
    """Fig. 5 stability run: constant base rate + a peak-workload window."""
    profiles, e, c = _paper_fleet(2, 3)
    n = len(profiles)
    cfg = MecConfig(lam_mode=LAM_PEAK, peak_start=int(start),
                    peak_stop=int(stop), peak_boost=float(boost))
    return Scenario(name=f"peak_window[{base_rate:g}+{boost:g}]",
                    cfg=cfg, profiles=profiles, e_budget=e, c_budget=c,
                    lam_fixed=(float(base_rate),) * n,
                    description="Fig. 5 peak-workload cell")


@register("hetero_fleet")
def hetero_fleet(n_ue: int = 8, seed: int = 0,
                 rate_range: tuple[float, float] = (0.5, 2.5)) -> Scenario:
    """Heterogeneous fleet: random AlexNet/ResNet mix, budgets and rates."""
    from ..profiling.convnets import alexnet_profile, resnet18_profile
    rng = np.random.default_rng(seed)
    pool = (alexnet_profile(), resnet18_profile())
    picks = rng.integers(0, len(pool), n_ue)
    profiles = tuple(pool[i] for i in picks)
    e = tuple(float(x) for x in rng.uniform(0.030, 0.080, n_ue))
    c = tuple(float(x) for x in rng.uniform(0.025, 0.120, n_ue))
    lam = tuple(float(x) for x in rng.uniform(*rate_range, n_ue))
    return Scenario(name=f"hetero_fleet[{n_ue}@{seed}]",
                    cfg=MecConfig(lam_mode=LAM_FIXED),
                    profiles=profiles, e_budget=e, c_budget=c,
                    lam_fixed=lam,
                    description="random device/budget/rate mix")


@register("mmpp_burst")
def mmpp_burst(seed: int = 0, rates: tuple[float, ...] = (0.5, 3.0),
               p_stay: float = 0.92, horizon: int = 400,
               n_alexnet: int = 2, n_resnet: int = 3) -> Scenario:
    """Bursty cell: per-UE Markov-modulated (MMPP) rates over the paper fleet."""
    profiles, e, c = _paper_fleet(n_alexnet, n_resnet)
    arrival = traffic.make_mmpp(len(profiles), seed=seed, rates=rates,
                                p_stay=p_stay, horizon=horizon)
    return Scenario(name=f"mmpp_burst[{seed}]", cfg=MecConfig(),
                    profiles=profiles, e_budget=e, c_budget=c,
                    arrival=arrival,
                    description="Markov-modulated bursty arrivals "
                                f"(regimes {rates}, p_stay={p_stay:g})")


@register("diurnal")
def diurnal(base: float = 1.5, amp: float = 1.0, period: float = 200.0,
            phase: float = 0.0, n_alexnet: int = 2,
            n_resnet: int = 3) -> Scenario:
    """Day/night cell: sinusoidal arrival rates around a base load."""
    profiles, e, c = _paper_fleet(n_alexnet, n_resnet)
    n = len(profiles)
    arrival = traffic.Diurnal(base=traffic.per_ue(base, n),
                              amp=traffic.per_ue(amp, n),
                              period=jnp.float32(period),
                              phase=jnp.float32(phase))
    return Scenario(name=f"diurnal[{base:g}±{amp:g}]", cfg=MecConfig(),
                    profiles=profiles, e_budget=e, c_budget=c,
                    arrival=arrival,
                    description=f"sinusoidal load, period {period:g} slots")


@register("flash_crowd")
def flash_crowd(base: float = 1.0, spike: float = 2.5, t0: int = 100,
                decay: float = 30.0, n_alexnet: int = 2,
                n_resnet: int = 3) -> Scenario:
    """Flash-crowd cell: base load + an exponentially decaying spike at t0."""
    profiles, e, c = _paper_fleet(n_alexnet, n_resnet)
    n = len(profiles)
    arrival = traffic.FlashCrowd(base=traffic.per_ue(base, n),
                                 spike=jnp.float32(spike),
                                 t0=jnp.int32(t0), decay=jnp.float32(decay))
    return Scenario(name=f"flash_crowd[{spike:g}@{t0}]", cfg=MecConfig(),
                    profiles=profiles, e_budget=e, c_budget=c,
                    arrival=arrival,
                    description=f"flash crowd +{spike:g} req/s at slot {t0}")


@register("trace_replay")
def trace_replay(trace=None, path: str | None = None, offset: int = 0,
                 seed: int = 0, rate_range: tuple[float, float] = (0.5, 2.5),
                 ) -> Scenario:
    """Replay a recorded arrival trace (repro.traffic.Trace) as the cell load.

    ``trace`` is a :class:`repro.traffic.Trace` (or ``path`` names a saved
    ``.npz``); the cell's fleet is a ``hetero_fleet`` sized to the trace's UE
    count.  ``offset`` rotates the trace so B cells built from one recording
    replay de-phased copies (per-cell diversity without per-cell recordings).

    With neither ``trace`` nor ``path``, a small deterministic MMPP demo
    trace is materialized (every registry constructor must build with zero
    args -- the contract the registry-wide parity suite relies on; see
    docs/scenarios.md).
    """
    from ..traffic.trace import Trace, from_process
    if trace is None:
        if path is None:
            proc = traffic.make_mmpp(4, seed=seed, horizon=64)
            trace = from_process(proc, 64)
        else:
            trace = Trace.load(path)
    if offset:
        trace = trace.shifted(offset)
    cell = hetero_fleet(n_ue=trace.n_ue, seed=seed, rate_range=rate_range)
    return dataclasses.replace(
        cell, name=f"trace_replay[{trace.n_ue}ue+{offset}]",
        cfg=MecConfig(lam_mode=LAM_TRACE), arrival=trace.process(),
        lam_fixed=None,
        description=f"replays a {trace.n_slots}-slot recorded trace "
                    f"(offset {offset})")


def multicell_grid(cells: int = 16, ues: int = 8, seed: int = 0,
                   d_min_m: float = 60.0, d_max_m: float = 300.0,
                   rate_range: tuple[float, float] = (0.5, 2.5),
                   uniform_scalars: bool = True) -> list[Scenario]:
    """B independent cells for one batched grid: each cell is a heterogeneous
    fleet at its own ES distance (per-cell mean channel gain).

    ``uniform_scalars=True`` keeps every ``MecConfig`` scalar at Table I
    values so the grid qualifies for the single-launch Pallas sweep route.
    """
    rng = np.random.default_rng(seed)
    out = []
    for b in range(cells):
        cell = hetero_fleet(n_ue=ues, seed=seed * 10_007 + b,
                            rate_range=rate_range)
        dist = float(rng.uniform(d_min_m, d_max_m))
        cfg = cell.cfg
        if not uniform_scalars:
            cfg = dataclasses.replace(cfg, v=float(rng.uniform(5.0, 20.0)))
        out.append(dataclasses.replace(
            cell, name=f"cell[{b}]@{dist:.0f}m", cfg=cfg,
            mean_gain=free_space_gain(distance_m=dist),
            description=f"grid cell {b}, ES distance {dist:.0f} m"))
    return out


# ---------------------------------------------------------------------------
# Stacking
# ---------------------------------------------------------------------------

def _pad_cuts(p: MecParams, cmax: int) -> MecParams:
    """Pad a cell's cut axis to ``cmax`` columns.

    Per-cut tables are constant for c >= L_n (cumsum/max of zero padding), so
    edge replication preserves semantics; raw per-layer tables get zeros
    (there is no layer there), and psi's edge value is already 0.
    """
    c = p.num_cuts
    if c == cmax:
        return p
    pad_edge = lambda t: jnp.pad(t, ((0, 0), (0, cmax - c)), mode="edge")
    pad_zero = lambda t: jnp.pad(t, ((0, 0), (0, cmax - c)))
    return dataclasses.replace(
        p,
        macs=pad_zero(p.macs), param_bytes=pad_zero(p.param_bytes),
        act_bytes=pad_zero(p.act_bytes),
        prefix_macs=pad_edge(p.prefix_macs),
        suffix_macs=pad_edge(p.suffix_macs),
        psi=pad_zero(p.psi),
        prefix_params=pad_edge(p.prefix_params),
        suffix_params=pad_edge(p.suffix_params),
        prefix_act_max=pad_edge(p.prefix_act_max),
        suffix_act_max=pad_edge(p.suffix_act_max))


def stack_params(params_list: Sequence[MecParams]) -> MecParams:
    """Stack B single-cell param pytrees into one (B, ...) pytree.

    Cells must share the UE count; the cut axis is padded to the widest cell.
    ``edge_queueing`` (a static field) must agree across cells, and so must
    the arrival-process *type* (and its array shapes, e.g. trace horizons) --
    the process class is part of the treedef the vmap dispatches on.
    """
    if not params_list:
        raise ValueError("need at least one cell")
    n_ues = {p.n_ue for p in params_list}
    if len(n_ues) != 1:
        raise ValueError(f"cells must share the UE count, got {sorted(n_ues)}")
    eq = {p.edge_queueing for p in params_list}
    if len(eq) != 1:
        raise ValueError("cells must share edge_queueing (static field)")
    kinds = {type(p.arrival) for p in params_list}
    if len(kinds) != 1:
        raise ValueError(
            "cells must share the arrival-process type (it is static "
            "treedef, like edge_queueing); got "
            f"{sorted(k.__name__ for k in kinds)}")
    cmax = max(p.num_cuts for p in params_list)
    padded = [_pad_cuts(p, cmax) for p in params_list]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *padded)


# ---------------------------------------------------------------------------
# Batched policies (per-cell signature; the grid vmaps them over cells)
# ---------------------------------------------------------------------------

def oracle_policy(params: MecParams, state: MecState, key) -> jax.Array:
    """Decoupled per-slot argmin over the (N, C) objective table (lax path)."""
    del key
    return sweep.oracle_cut_p(params, state)


def local_policy(params: MecParams, state: MecState, key) -> jax.Array:
    del state, key
    return params.L


def edge_policy(params: MecParams, state: MecState, key) -> jax.Array:
    del state
    return jnp.zeros((params.n_ue,), jnp.int32)


def random_policy(params: MecParams, state: MecState, key) -> jax.Array:
    return jax.random.randint(key, (params.n_ue,), 0, params.L + 1)


POLICIES: dict[str, Callable] = {
    "oracle": oracle_policy,
    "local": local_policy,
    "edge": edge_policy,
    "random": random_policy,
}


# ---------------------------------------------------------------------------
# Batched engine
# ---------------------------------------------------------------------------

class ScenarioGrid:
    """B independent cells evaluated as one program.

    ``params`` is the stacked (B, ...) ``MecParams`` pytree; ``reset`` /
    ``step`` are vmapped over cells; ``make_rollout`` returns one jitted
    ``lax.scan`` over time slots that advances every cell per iteration.

    ``use_mesh`` (or the ``mesh=`` constructor arg) additionally shards the
    grid over a device mesh's ``"cells"`` axis: params are padded to a device
    multiple and placed with ``NamedSharding``, and the same rollout program
    then runs partitioned across devices.  ``params`` always stays the
    logical unpadded stack; the padded/placed copy lives in ``_run_params``
    and is selected automatically from a state batch's width.
    """

    def __init__(self, scenarios: Sequence[Scenario], mesh=None):
        self.scenarios = tuple(scenarios)
        if not self.scenarios:
            raise ValueError("empty grid")
        self.params = stack_params([s.params() for s in self.scenarios])
        self.b = len(self.scenarios)
        self.n_ue = self.scenarios[0].n_ue
        self.num_cuts = int(self.params.num_cuts)
        # Host-side kernel scalars, shared across cells or None.
        per_cell = [s.sweep_scalars() for s in self.scenarios]
        self.sweep_scalars = per_cell[0] if all(
            s == per_cell[0] for s in per_cell) else None
        self.gridshard: gridshard.GridSharding | None = None
        self._run_params = self.params
        if mesh is not None:
            self.use_mesh(mesh)

    # -- device sharding ----------------------------------------------------

    @property
    def b_run(self) -> int:
        """Cell-axis width the jitted programs run at (b, or b padded to a
        device multiple when sharded)."""
        return self.b if self.gridshard is None else self.gridshard.b_padded

    def use_mesh(self, mesh=None, *, model: int = 1,
                 pad_to: int | None = None):
        """Shard the stacked grid over ``mesh``'s ``"cells"`` axis.

        ``mesh=None`` builds a mesh over every live device
        (``repro.launch.mesh.make_cells_mesh``); ``model=M > 1`` makes it
        the 2-D ``("cells", "model")`` mesh -- M-way per-cell tensor
        parallelism, spreading the post-cell dim of every stacked table
        (the UE axis of params/states, hence the rows of the (B, N, C)
        objective sweep) over the model axis where divisible.  A mesh passed
        explicitly must agree with a non-default ``model``.

        B is padded up to a multiple of the cell-shard count (``pad_to``
        forces a wider pad -- mainly for tests); padded cells replicate the
        last real cell and are masked out of every rollout summary.
        Sharded rollouts -- 1-D or 2-D -- equal unsharded ones to 1e-5.
        Returns ``self``.
        """
        if mesh is None:
            from ..launch.mesh import make_cells_mesh
            mesh = make_cells_mesh(model=model)
        elif model != 1:
            have = dict(mesh.shape).get(gridshard.MODEL_AXIS, 1)
            if have != model:
                raise ValueError(
                    f"use_mesh(model={model}) but the given mesh has a "
                    f"{have}-way {gridshard.MODEL_AXIS!r} axis; pass "
                    "mesh=None to build a matching one (make_cells_mesh)")
        gs = gridshard.plan(self.b, mesh, pad_to=pad_to)
        padded = gridshard.pad_cells(self.params, gs)
        placed = gridshard.place(padded, gs)
        if gs.n_model > 1 and jax.tree.leaves(padded.arrival):
            # Arrival leaves put the model axis on their LAST dim (the UE
            # axis): their post-cell dim is per-slot time -- e.g. a
            # (B, T, N) trace -- which every step indexes, and sharding it
            # would gather across model shards once per slot.
            placed = dataclasses.replace(
                placed, arrival=gridshard.place(padded.arrival, gs,
                                                model_dim=-1))
        self._run_params = placed
        self.gridshard = gs
        return self

    def _params_for(self, states: MecState) -> MecParams:
        """Pick the params stack matching a state batch's cell-axis width."""
        lead = states.t.shape[0]
        if lead == self.b_run:
            return self._run_params
        if lead == self.b:
            return self.params
        raise ValueError(
            f"state batch {lead} matches neither b={self.b} nor the padded "
            f"width {self.b_run}")

    # -- per-slot primitives ------------------------------------------------

    def reset(self, key: jax.Array) -> MecState:
        """Stacked (b_run, ...) states from one key.

        Per-cell keys come from ``gridshard.cell_keys`` (fold_in over the
        cell index), so cell i draws the same randomness at any padding.
        """
        keys = gridshard.cell_keys(key, self.b, self.b_run)
        states = jax.vmap(reset_p)(self._run_params, keys)
        if self.gridshard is not None:
            states = gridshard.constrain(states, self.gridshard)
        return states

    def step(self, states: MecState,
             cuts: jax.Array) -> tuple[MecState, SlotResult]:
        """(B, N) cuts -> stacked next states + (B, N) slot results."""
        return jax.vmap(step_p)(self._params_for(states), states, cuts)

    # -- batched oracle sweep ----------------------------------------------

    def objective_tables(self, states: MecState, *, backend: str = "auto",
                         interpret: bool | None = None) -> jax.Array:
        """(B, N, C) drift-plus-penalty tables for every cell at once.

        backend:
          * ``"pallas"`` -- one ``partition_sweep`` kernel launch over the
            flattened (B*N, C) grid (requires uniform kernel scalars across
            cells; ``interpret=True`` off-TPU).
          * ``"ref"``    -- ``kernels.ref`` checked fallback (vmapped).
          * ``"lax"``    -- vmapped ``sweep.objective_table_p``.
          * ``"auto"``   -- pallas on TPU when eligible, else lax.
        """
        p = self._params_for(states)
        if backend == "auto":
            backend = ("pallas" if self.sweep_scalars is not None
                       and jax.default_backend() == "tpu" else "lax")
        if backend == "lax":
            return jax.vmap(sweep.objective_table_p)(p, states)
        if self.sweep_scalars is None:
            raise ValueError(
                "kernel scalars differ across cells; use backend='lax'")
        args = (p.macs, p.param_bytes, p.act_bytes, p.psi, p.L,
                states.lam, states.gain, states.queues.energy,
                states.queues.memory, self.sweep_scalars)
        if backend == "ref":
            from ..kernels.ref import partition_sweep_batched_ref
            return partition_sweep_batched_ref(*args)
        if backend == "pallas":
            from ..kernels.ops import partition_sweep_batched
            if interpret is None:
                interpret = jax.default_backend() != "tpu"
            return partition_sweep_batched(*args, interpret=interpret)
        raise ValueError(f"unknown backend {backend!r}")

    def oracle_cuts(self, states: MecState, *, backend: str = "auto",
                    interpret: bool | None = None) -> jax.Array:
        """Batched Oracle decision: argmin over each cell's objective table."""
        table = self.objective_tables(states, backend=backend,
                                      interpret=interpret)
        return jnp.argmin(table, axis=-1).astype(jnp.int32)

    # -- rollout ------------------------------------------------------------

    def make_rollout(self, policy: str | Callable = "oracle",
                     steps: int = 200, oracle_backend: str = "auto"):
        """One jitted program: reset all cells, scan ``steps`` slots.

        ``policy`` is a registry name or a per-cell callable
        ``(params, state, key) -> (N,) cuts`` (vmapped over cells here).
        The ``"oracle"`` policy's per-slot sweep goes through
        ``oracle_cuts``/``objective_tables`` with ``oracle_backend`` --
        i.e. the single-launch Pallas kernel on TPU, lax elsewhere.
        Returns ``fn(key) -> (final_states, results, summary)`` with results
        stacked (steps, B, N) and summary per-cell (B,) means.

        On a sharded grid the identical program runs at the padded width
        with GSPMD partitioning the cell axis; padded cells are masked out
        of the summary and sliced off results/states before returning, so
        callers always see the logical B.
        """
        if policy == "oracle":
            if oracle_backend == "auto":
                oracle_backend = ("pallas" if self.sweep_scalars is not None
                                  and jax.default_backend() == "tpu"
                                  else "lax")
            act = None  # batched below; the sweep kernel wants whole-grid args
        else:
            act = POLICIES[policy] if isinstance(policy, str) else policy
        params = self._run_params
        b, b_run, gs = self.b, self.b_run, self.gridshard

        def rollout(key):
            key, k0 = jax.random.split(key)
            states = self.reset(k0)

            def body(carry, _):
                # named so profiler dumps attribute per-slot cost to the
                # grid scan (pairs with the host "grid_rollout" span)
                with jax.named_scope("repro.grid_scan_step"):
                    sts, k = carry
                    k, k_act = jax.random.split(k)
                    if act is None:
                        cuts = self.oracle_cuts(sts, backend=oracle_backend)
                    else:
                        cuts = jax.vmap(act)(
                            params, sts,
                            gridshard.cell_keys(k_act, b, b_run))
                    sts2, res = jax.vmap(step_p)(params, sts, cuts)
                    if gs is not None:
                        sts2 = gridshard.constrain(sts2, gs)
                    return (sts2, k), res

            (states, _), results = jax.lax.scan(
                body, (states, key), None, length=steps)
            summary = {
                "reward": jnp.mean(results.reward, axis=0),       # (B,)
                "delay": jnp.mean(results.delay, axis=(0, 2)),
                "energy": jnp.mean(results.energy, axis=(0, 2)),
                "mem": jnp.mean(results.mem_cost, axis=(0, 2)),
                "q_energy_final": jnp.mean(results.q_energy[-1], axis=-1),
                "q_memory_final": jnp.mean(results.q_memory[-1], axis=-1),
                "cut_mean": jnp.mean(results.cut.astype(jnp.float32),
                                     axis=(0, 2)),
            }
            if gs is not None:
                # Padded cells must not pollute anything the caller sees.
                # All summary reductions above are per-cell, so applying the
                # validity mask IS the [:b] slice (gs.mask() stays available
                # for callers doing their own cross-cell aggregation on
                # padded arrays).
                summary = {name: v[:b] for name, v in summary.items()}
                results = gridshard.unpad(results, gs, lead=1)
                states = gridshard.unpad(states, gs)
            return states, results, summary

        return jax.jit(rollout)

    def rollout(self, policy: str | Callable = "oracle", steps: int = 200,
                seed: int = 0, oracle_backend: str = "auto",
                telemetry=None):
        """Convenience one-shot: build + run the jitted rollout.

        ``telemetry=`` (a :class:`repro.obs.Telemetry`) wraps the run in a
        ``grid_rollout`` span and records throughput gauges --
        ``grid_slots_per_s`` (one slot = one (cell, time-slot) advance of
        all N UEs, the benchmarks/scenario_grid.py unit) and
        ``grid_cells_per_s`` -- from one host-side ``block_until_ready``
        timing around the whole program (no extra syncs inside the scan).
        """
        fn = self.make_rollout(policy, steps, oracle_backend=oracle_backend)
        if telemetry is None:
            return fn(jax.random.PRNGKey(seed))
        import time
        m = telemetry.metrics
        with telemetry.tracer.span("grid_rollout", device=True,
                                   cells=self.b, steps=steps):
            t0 = time.perf_counter()
            out = jax.block_until_ready(fn(jax.random.PRNGKey(seed)))
            dt = time.perf_counter() - t0
        m.counter("grid_rollouts_total", "jitted grid rollouts run").inc()
        m.gauge("grid_slots_per_s", "cell x time-slot advances per second "
                "(all N UEs), last rollout").set(self.b * steps / dt)
        m.gauge("grid_cells_per_s", "whole-episode cell throughput, last "
                "rollout").set(self.b / dt)
        return out


def grid_from_names(specs: Sequence[str | tuple[str, dict]]) -> ScenarioGrid:
    """Build a grid from registry names, e.g. ``[("fixed_rate", {"rate": r})
    for r in (0.5, 1.0, 1.5, 2.0, 2.5)]`` evaluates a whole Fig. 4 sweep in
    one program."""
    cells = []
    for spec in specs:
        if isinstance(spec, str):
            cells.append(make(spec))
        else:
            name, knobs = spec
            cells.append(make(name, **knobs))
    return ScenarioGrid(cells)
