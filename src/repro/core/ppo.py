"""Pure-JAX PPO (Sec. IV-B, Algorithm 1).

Matches the paper's setup: actor + critic MLPs with hidden sizes (128, 64),
Adam at 3e-4, clip eps = 0.2, replay memory of one episode (K slots) that is
consumed and cleared on every fill.  The advantage estimator is GAE(gamma,
lambda); ``gae_lambda = 1.0`` (default) reproduces the paper's discounted
estimator (eq. 16/17), with a terminal (non-bootstrapped) episode end.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from ..optim.adam import adam
from .networks import mlp_apply, mlp_init


@dataclasses.dataclass(frozen=True)
class PPOConfig:
    lr: float = 3e-4
    gamma: float = 0.95
    gae_lambda: float = 1.0        # 1.0 == paper's estimator
    clip_eps: float = 0.2          # paper Sec. V-A
    epochs: int = 8                # passes over the filled memory
    value_coef: float = 0.5
    entropy_coef: float = 0.0      # paper uses none; ablations may set >0
    reward_scale: float = 0.02     # conditions the value target only
    adv_norm: bool = True
    bootstrap_last: bool = False   # paper sums to the episode end
    grad_clip: float = 0.5
    critic_hidden: tuple = (128, 64)


class Trajectory(NamedTuple):
    obs: jax.Array       # (K, obs_dim)
    action: jax.Array    # (K, ...) policy-native representation
    logp: jax.Array      # (K,)
    reward: jax.Array    # (K,) raw environment rewards (eq. 14)
    value: jax.Array     # (K,) critic at collection time
    last_value: jax.Array  # () critic at s_K


class TrainState(NamedTuple):
    params: Any
    opt_state: Any


class PPO:
    """Policy-agnostic PPO: works with any head from ``policies.py``."""

    def __init__(self, policy, obs_dim: int, cfg: PPOConfig = PPOConfig()):
        self.policy = policy
        self.obs_dim = obs_dim
        self.cfg = cfg
        self._opt_init, self._opt_update = adam(cfg.lr, grad_clip=cfg.grad_clip)

    # -- parameters --------------------------------------------------------

    def init(self, key) -> TrainState:
        k_pi, k_v = jax.random.split(key)
        params = {
            "pi": self.policy.init(k_pi),
            "v": mlp_init(k_v, (self.obs_dim, *self.cfg.critic_hidden, 1)),
        }
        return TrainState(params=params, opt_state=self._opt_init(params))

    def value(self, params, obs):
        return mlp_apply(params["v"], obs)[..., 0]

    def act(self, params, obs, key):
        """Sample action + diagnostics for rollout collection."""
        action, logp = self.policy.sample(params["pi"], obs, key)
        return action, logp, self.value(params, obs)

    # -- advantage estimation ----------------------------------------------

    def gae(self, traj: Trajectory):
        cfg = self.cfg
        r = traj.reward * cfg.reward_scale
        v = traj.value
        last_v = jnp.where(cfg.bootstrap_last, traj.last_value, 0.0)
        v_next = jnp.concatenate([v[1:], last_v[None]])
        deltas = r + cfg.gamma * v_next - v

        def scan_fn(carry, delta):
            adv = delta + cfg.gamma * cfg.gae_lambda * carry
            return adv, adv

        _, adv = jax.lax.scan(scan_fn, jnp.zeros(()), deltas, reverse=True)
        returns = adv + v
        return adv, returns

    # -- update -------------------------------------------------------------

    def update(self, state: TrainState, traj: Trajectory):
        cfg = self.cfg
        adv, returns = self.gae(traj)
        if cfg.adv_norm:
            adv = (adv - adv.mean()) / (adv.std() + 1e-8)

        def loss_fn(params):
            logp = self.policy.logp(params["pi"], traj.obs, traj.action)
            ratio = jnp.exp(logp - traj.logp)
            surrogate = jnp.minimum(
                ratio * adv,
                jnp.clip(ratio, 1.0 - cfg.clip_eps, 1.0 + cfg.clip_eps) * adv)
            actor_loss = -jnp.mean(surrogate)                      # eq. (15)
            v = self.value(params, traj.obs)
            critic_loss = jnp.mean(jnp.square(v - returns))        # eq. (18)
            ent = self.policy.entropy(params["pi"], traj.obs)
            loss = (actor_loss + cfg.value_coef * critic_loss
                    - cfg.entropy_coef * ent)
            return loss, (actor_loss, critic_loss, ratio)

        def epoch(carry, _):
            st = carry
            (loss, (al, cl, ratio)), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(st.params)
            new_params, new_opt = self._opt_update(grads, st.opt_state, st.params)
            metrics = {
                "loss": loss, "actor_loss": al, "critic_loss": cl,
                "ratio_max": jnp.max(ratio),
            }
            return TrainState(new_params, new_opt), metrics

        state, metrics = jax.lax.scan(epoch, state, None, length=cfg.epochs)
        return state, jax.tree.map(lambda m: m[-1], metrics)
