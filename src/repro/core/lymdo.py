"""LyMDO training/evaluation driver (Algorithm 1) and baseline runners.

An *episode* = K time slots (paper: K = 200); virtual queues reset at episode
start (Algorithm 1 line 5).  The replay memory holds exactly one episode and
is consumed by a PPO update when filled (lines 16-27).  Rollout + update are
one jitted program; episodes run under ``lax.scan`` in chunks so multi-
thousand-episode training (paper: 2000) takes seconds on CPU.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from .env import MecEnv, MecState, SlotResult
from .policies import JointGaussianPolicy
from .ppo import PPO, Trajectory, TrainState
from . import sweep


@dataclasses.dataclass(frozen=True)
class RunConfig:
    episodes: int = 500
    steps: int = 200           # K, slots per episode
    seed: int = 0
    chunk: int = 25            # episodes per jitted scan chunk (logging cadence)
    log: bool = True


def _summarize(results: SlotResult) -> dict:
    """Per-episode means/finals used by the paper's figures."""
    return {
        "reward": jnp.mean(results.reward),
        "delay": jnp.mean(jnp.mean(results.delay, axis=-1)),
        "energy": jnp.mean(jnp.mean(results.energy, axis=-1)),
        "mem": jnp.mean(jnp.mean(results.mem_cost, axis=-1)),
        "q_energy_final": jnp.mean(results.q_energy[-1]),
        "q_memory_final": jnp.mean(results.q_memory[-1]),
        "cut_mean": jnp.mean(results.cut.astype(jnp.float32)),
    }


class Runner:
    """Binds (env, agent) into jitted episode/train/eval programs.

    ``mode``:
      * "lymdo": agent picks the cut; convex optimization allocates resources
        (the paper's algorithm).
      * "joint": agent picks cut + alpha + f_ue + f_es (the paper's "PPO"
        baseline); requires a ``JointGaussianPolicy``.
    """

    def __init__(self, env: MecEnv, agent: PPO, steps: int = 200,
                 mode: str = "lymdo"):
        self.env, self.agent, self.steps, self.mode = env, agent, steps, mode
        if mode == "joint" and not isinstance(agent.policy, JointGaussianPolicy):
            raise ValueError("joint mode needs JointGaussianPolicy")
        self._train_chunk = jax.jit(self._make_train_chunk(), static_argnames="n")
        self._eval_episode = jax.jit(self._make_episode(deterministic=True))

    # -- inner programs ------------------------------------------------------

    def _apply(self, state: MecState, action):
        if self.mode == "joint":
            cut, alpha, f_ue, f_es = self.agent.policy.split(action)
            return self.env.step_joint(state, cut, alpha, f_ue, f_es)
        return self.env.step(state, self.agent.policy.to_cut(action))

    def _make_episode(self, deterministic: bool = False):
        env, agent = self.env, self.agent

        def episode(params, key):
            key, k0 = jax.random.split(key)
            st0 = env.reset(k0)

            def body(carry, _):
                st, key = carry
                key, k_act = jax.random.split(key)
                obs = env.observe(st)
                action, logp, value = agent.act(params, obs, k_act)
                if deterministic:
                    # mean/argmax action: Fig. 4 evaluates "well-trained
                    # offline" policies without exploration noise.
                    action = agent.policy.mean_action(params["pi"], obs)
                st2, res = self._apply(st, action)
                return (st2, key), (obs, action, logp, value, res)

            (st_end, _), (obs, action, logp, value, results) = jax.lax.scan(
                body, (st0, key), None, length=self.steps)
            last_value = agent.value(params, env.observe(st_end))
            traj = Trajectory(obs=obs, action=action, logp=logp,
                              reward=results.reward, value=value,
                              last_value=last_value)
            return traj, _summarize(results), results

        return episode

    def _make_train_chunk(self):
        episode = self._make_episode()

        def chunk(state: TrainState, key, n: int):
            def one(carry, k):
                st = carry
                traj, metrics, _ = episode(st.params, k)
                st, upd_metrics = self.agent.update(st, traj)
                metrics.update(upd_metrics)
                return st, metrics

            keys = jax.random.split(key, n)
            return jax.lax.scan(one, state, keys)

        return chunk

    # -- public API ----------------------------------------------------------

    def train(self, cfg: RunConfig = RunConfig()):
        key = jax.random.PRNGKey(cfg.seed)
        key, k_init = jax.random.split(key)
        state = self.agent.init(k_init)
        history: dict[str, list] = {}
        done = 0
        t0 = time.time()
        while done < cfg.episodes:
            n = min(cfg.chunk, cfg.episodes - done)
            key, k_chunk = jax.random.split(key)
            state, metrics = self._train_chunk(state, k_chunk, n=n)
            metrics = jax.tree.map(np.asarray, metrics)
            for k, val in metrics.items():
                history.setdefault(k, []).append(val)
            done += n
            if cfg.log:
                print(f"  ep {done:5d}/{cfg.episodes} "
                      f"reward {metrics['reward'][-1]:9.3f} "
                      f"delay {metrics['delay'][-1]:7.4f}s "
                      f"({time.time() - t0:5.1f}s)")
        history = {k: np.concatenate(v) for k, v in history.items()}
        return state, history

    def evaluate(self, state: TrainState, episodes: int = 10, seed: int = 1234):
        """Deterministic-policy evaluation; returns per-episode metric means
        and the full last-episode SlotResult (for Fig. 5-style traces)."""
        key = jax.random.PRNGKey(seed)
        all_metrics: dict[str, list] = {}
        results = None
        for _ in range(episodes):
            key, k = jax.random.split(key)
            _, metrics, results = self._eval_episode(state.params, k)
            for name, val in metrics.items():
                all_metrics.setdefault(name, []).append(float(val))
        return {k: float(np.mean(v)) for k, v in all_metrics.items()}, results


# ---------------------------------------------------------------------------
# Non-learning baselines (paper Sec. V-B: Local / Edge / Random + our Oracle).
# All reuse the exact convex allocators via env.step.
# ---------------------------------------------------------------------------

def run_fixed(env: MecEnv, cut_fn: Callable, episodes: int, steps: int,
              seed: int = 0):
    """cut_fn(state, key) -> (N,) int cuts.  Returns (metrics, last_results)."""

    def episode(key):
        key, k0 = jax.random.split(key)
        st0 = env.reset(k0)

        def body(carry, _):
            st, key = carry
            key, k = jax.random.split(key)
            st2, res = env.step(st, cut_fn(st, k))
            return (st2, key), res

        (_, _), results = jax.lax.scan(body, (st0, key), None, length=steps)
        return _summarize(results), results

    episode = jax.jit(episode)
    key = jax.random.PRNGKey(seed)
    agg: dict[str, list] = {}
    results = None
    for _ in range(episodes):
        key, k = jax.random.split(key)
        metrics, results = episode(k)
        for name, val in metrics.items():
            agg.setdefault(name, []).append(float(val))
    return {k: float(np.mean(v)) for k, v in agg.items()}, results


def local_cut_fn(env: MecEnv):
    return lambda st, key: env.L


def edge_cut_fn(env: MecEnv):
    return lambda st, key: jnp.zeros((env.n_ue,), jnp.int32)


def random_cut_fn(env: MecEnv):
    return lambda st, key: jax.random.randint(key, (env.n_ue,), 0, env.L + 1)


def oracle_cut_fn(env: MecEnv):
    return lambda st, key: sweep.oracle_cut(env, st)


# ---------------------------------------------------------------------------
# Batched multi-cell runners (see repro.core.scenarios): B cells x N UEs in a
# single jitted lax.scan program instead of one Python loop per cell.
# ---------------------------------------------------------------------------

def run_fixed_batched(grid, policy="oracle", episodes: int = 1,
                      steps: int = 200, seed: int = 0):
    """Batched analogue of :func:`run_fixed` over a ``ScenarioGrid``.

    ``policy`` is a ``scenarios.POLICIES`` name or a per-cell callable
    ``(params, state, key) -> (N,) cuts``.  Returns (metrics, last_results):
    metrics maps each summary name to a (B,) per-cell mean over episodes;
    last_results is the final episode's (steps, B, N) SlotResult stack.

    A device-sharded grid (``grid.use_mesh(...)``; see repro.core.gridshard)
    is accepted transparently: the rollout runs partitioned over the mesh's
    "cells" axis -- and, on a ``("cells", "model")`` mesh
    (``use_mesh(model=M)``), with M-way per-cell tensor parallelism -- and
    still returns logical-B outputs that match the single-device run to
    1e-5.
    """
    rollout = grid.make_rollout(policy, steps)
    key = jax.random.PRNGKey(seed)
    agg: dict[str, list] = {}
    results = None
    for _ in range(episodes):
        key, k = jax.random.split(key)
        _, results, summary = rollout(k)
        for name, val in summary.items():
            agg.setdefault(name, []).append(np.asarray(val))
    return {k: np.mean(np.stack(v), axis=0) for k, v in agg.items()}, results


def eval_policy_batched(grid, agent: PPO, train_state: TrainState,
                        episodes: int = 1, steps: int = 200, seed: int = 1234):
    """Deterministic-policy LyMDO evaluation across every cell of a grid.

    The single trained agent (shared weights) acts per cell on that cell's
    observation; all cells advance in one scan (device-sharded grids work
    transparently, as in :func:`run_fixed_batched`).  Cells must share the
    agent's obs/action dims (guaranteed by ScenarioGrid's common UE count)
    AND the per-UE layer counts the policy head was built with: ``to_cut``
    maps actions onto the policy's own L, so a grid cell with deeper
    profiles would silently never receive the deep cuts.
    """
    from .env import observe_p

    pol_L = np.asarray(agent.policy.num_layers)
    grid_L = np.asarray(grid.params.L)
    if not np.array_equal(np.broadcast_to(pol_L, grid_L.shape), grid_L):
        raise ValueError(
            f"policy layer counts {pol_L} do not match every grid cell's L "
            f"{grid_L}; eval_policy_batched needs cells with the profiles "
            "the policy was trained for")

    pi_params = train_state.params["pi"]

    def act(params, state, key):
        del key
        obs = observe_p(params, state)
        y = agent.policy.mean_action(pi_params, obs)
        return agent.policy.to_cut(y)

    return run_fixed_batched(grid, act, episodes=episodes, steps=steps,
                             seed=seed)
