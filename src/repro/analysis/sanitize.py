"""Layer 4: the sanitizer runtime -- KV-pool memory safety + checkify
guards for the serving engine.

Static checks (layers 1-4) cannot see *scheduler interleavings*: a block
handed to two slots, a free of a block another request still decodes
from, or a live slot whose next KV write lands in the reserved dummy
block 0 only happen at runtime, under a particular admission/preemption
order.  ``ServingEngine(sanitize=True)`` turns on two guards:

* :class:`KVSanitizer` -- a shadow block-ownership map updated at every
  allocator handoff.  It raises :class:`SanitizerError` on double frees,
  frees of blocks the freeing slot does not own, cross-slot block
  aliasing, block-table rows that disagree with the ownership record,
  live slots whose ``seq_len`` outruns their owned blocks (the write
  would silently corrupt dummy block 0), and blocks still owned when the
  engine drains (leaks).  Every check is host-side integer bookkeeping
  over state the engine already holds -- no device syncs.

* ``checkify`` guards -- the jitted prefill / commit / paged-decode
  programs are wrapped with :func:`checkify_wrap`, so a NaN produced
  anywhere inside the model or an out-of-bounds gather/scatter (e.g. a
  corrupt block-table index) raises at the dispatch site instead of
  silently corrupting logits.

Both guards are DEBUG machinery: ``sanitize=False`` (the default) costs
one ``is None`` check per lifecycle edge (the A/B number rides in
``BENCH_9.json``; the off-mode delta is gated <= 1%).

:func:`run_sanitize` is the CLI/CI entry (``python -m repro.analysis
--sanitize``): it drives a sanitized engine through a short flash-crowd
schedule sized to force block growth AND preemption, so the allocator
churns through every code path while the guards watch.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np


class SanitizerError(RuntimeError):
    """A KV-pool memory-safety invariant was violated."""


@dataclasses.dataclass(frozen=True)
class SanitizeFailure:
    check: str
    message: str

    def render(self) -> str:
        return f"sanitize [{self.check}]: {self.message}"


@dataclasses.dataclass(frozen=True)
class SanitizeReport:
    failures: tuple
    ticks: int
    requests: int
    preemptions: int
    block_churn: int          # total alloc+free events observed
    elapsed_s: float

    @property
    def ok(self) -> bool:
        return not self.failures


class KVSanitizer:
    """Shadow ownership tracking for the paged KV pool.

    The engine calls :meth:`on_alloc` / :meth:`on_free` at every block
    handoff and :meth:`check_tick` / :meth:`check_drain` at tick/drain
    boundaries; any inconsistency between the shadow map, the engine's
    per-slot ``owned`` lists + block tables, and the allocator's own
    free/handed sets raises :class:`SanitizerError` immediately (fail
    fast: the corrupted state is the evidence).
    """

    def __init__(self, engine):
        self.eng = engine
        self.owner: dict[int, int] = {}          # block id -> owning slot
        self.events = 0                           # alloc/free churn counter

    # -- handoff hooks (called by the engine) -------------------------------

    def on_alloc(self, slot: int, blocks) -> None:
        self.events += len(blocks)
        for b in blocks:
            if b == 0:
                raise SanitizerError(
                    f"allocator handed out reserved dummy block 0 "
                    f"(slot {slot})")
            if b in self.owner:
                raise SanitizerError(
                    f"block {b} handed to slot {slot} while still owned by "
                    f"slot {self.owner[b]} (cross-slot aliasing)")
            self.owner[b] = slot

    def on_free(self, slot: int, blocks) -> None:
        self.events += len(blocks)
        for b in blocks:
            got = self.owner.get(b)
            if got is None:
                raise SanitizerError(
                    f"slot {slot} freed block {b} that no slot owns "
                    f"(double free or free-of-unowned)")
            if got != slot:
                raise SanitizerError(
                    f"slot {slot} freed block {b} owned by slot {got}")
            del self.owner[b]

    # -- boundary invariants ------------------------------------------------

    def check_tick(self) -> None:
        """Full cross-check at the end of one engine tick: engine block
        tables vs ``owned`` lists vs the shadow map vs the allocator."""
        eng = self.eng
        seen: dict[int, int] = {}
        for slot, blocks in enumerate(eng.owned):
            for b in blocks:
                if b in seen:
                    raise SanitizerError(
                        f"block {b} aliased: owned by slots {seen[b]} "
                        f"and {slot}")
                seen[b] = slot
                if self.owner.get(b) != slot:
                    raise SanitizerError(
                        f"shadow ownership of block {b} "
                        f"({self.owner.get(b)}) disagrees with engine slot "
                        f"{slot}")
            row = eng.block_tables[slot]
            if list(row[:len(blocks)]) != list(blocks):
                raise SanitizerError(
                    f"slot {slot} block table {row[:len(blocks)].tolist()} "
                    f"disagrees with owned blocks {blocks}")
            if np.any(row[len(blocks):]):
                raise SanitizerError(
                    f"slot {slot} table references block(s) "
                    f"{row[len(blocks):][row[len(blocks):] != 0].tolist()} "
                    f"past its {len(blocks)} owned blocks (stale entries)")
            if (eng.active[slot] is not None
                    and int(eng.seq_lens[slot]) > len(blocks) * eng.kv_block):
                raise SanitizerError(
                    f"slot {slot} seq_len {int(eng.seq_lens[slot])} outruns "
                    f"its {len(blocks)} owned blocks "
                    f"(x{eng.kv_block} tokens): next KV write lands in "
                    f"reserved dummy block 0")
        extra = set(self.owner) - set(seen)
        if extra:
            raise SanitizerError(
                f"blocks {sorted(extra)} in the shadow map but owned by no "
                f"slot (lost handoff)")
        al = eng.allocator
        free = set(al._free)
        both = free & set(seen)
        if both:
            raise SanitizerError(
                f"blocks {sorted(both)} simultaneously free and slot-owned")
        handed = al.handed_out()
        if handed != set(seen):
            raise SanitizerError(
                f"allocator handed-out set {sorted(handed)} disagrees with "
                f"slot ownership {sorted(seen)} (leak or lost handoff)")

    def check_drain(self) -> None:
        """An idle engine (no active slots, empty queue) must hold zero
        allocated blocks: anything still owned leaked."""
        if any(r is not None for r in self.eng.active):
            return
        if self.owner:
            raise SanitizerError(
                f"leak at drain: blocks {sorted(self.owner)} still owned "
                f"after all requests completed")
        al = self.eng.allocator
        if al.n_free != al.capacity:
            raise SanitizerError(
                f"leak at drain: allocator reports {al.n_free} free of "
                f"{al.capacity} capacity with no active requests")


def checkify_wrap(fn):
    """jit ``fn`` under checkify NaN + index-OOB guards.

    Returns a callable with ``fn``'s signature that raises
    ``jax.errors.JaxRuntimeError`` at the dispatch site when the program
    produced a NaN or indexed out of bounds.  The per-call ``err.throw()``
    is a host sync -- sanitize mode trades throughput for immediate,
    attributable failure (debug only; never on the shipping path).

    NaN + OOB only (not the full ``float_checks``): masked attention
    lanes legitimately produce ``-inf``-adjacent values that ``inf``
    checks would false-positive on, while a NaN anywhere or an OOB
    gather is always a bug.
    """
    import jax
    from jax.experimental import checkify

    errs = checkify.nan_checks | checkify.index_checks
    checked = jax.jit(checkify.checkify(fn, errors=errs))

    def run(*args):
        err, out = checked(*args)
        err.throw()
        return out
    return run


# ---------------------------------------------------------------------------
# the --sanitize schedule
# ---------------------------------------------------------------------------

def _flash_crowd_schedule(vocab: int, seed: int, n_requests: int):
    """(tick -> [Request]) map: an opening burst that over-subscribes the
    slots, then a second wave mid-decode -- the interleaving that forces
    block growth, pool exhaustion, and youngest-request preemption."""
    from ..serving.engine import Request

    rng = np.random.default_rng(seed)
    lens = rng.integers(3, 20, n_requests)
    news = rng.integers(4, 16, n_requests)
    sched: dict[int, list] = {}
    for i in range(n_requests):
        tick = 0 if i < (2 * n_requests) // 3 else 6
        sched.setdefault(tick, []).append(Request(
            rid=i, prompt=rng.integers(0, vocab, int(lens[i])).astype(np.int32),
            max_new=int(news[i]), ue=i % 4))
    return sched


def run_sanitize(arch: str = "qwen3-0.6b", *, n_requests: int = 10,
                 seed: int = 0, n_layers: int = 2,
                 max_steps: int = 2_000) -> SanitizeReport:
    """Drive a sanitized continuous engine through a flash-crowd schedule.

    The pool is deliberately undersized (every slot can NOT reach
    ``s_max`` simultaneously) so growth hits the dry-pool path and
    preemption fires; the sanitizer + checkify guards watch every tick.
    Returns a report whose ``failures`` is empty iff the engine is
    memory- and NaN-clean under this interleaving.
    """
    import jax

    from ..configs.base import get_config, reduced
    from ..models import transformer
    from ..serving.engine import ServingEngine

    t0 = time.perf_counter()
    cfg = reduced(get_config(arch), n_layers=n_layers)
    params = transformer.init_params(jax.random.PRNGKey(seed), cfg)
    # kv_blocks: big enough for the worst single request (the admission fit
    # check), far too small for 3 slots at full stretch -- growth hits the
    # dry pool and preemption must fire
    kv_block = 8
    s_max = 64
    eng = ServingEngine(cfg, params, slots=3, s_max=s_max, kv_block=kv_block,
                        kv_blocks=7, sanitize=True)
    sched = _flash_crowd_schedule(cfg.vocab, seed, n_requests)
    failures: list[SanitizeFailure] = []
    ticks = 0
    try:
        for tick in range(max_steps):
            for req in sched.pop(tick, ()):
                eng.submit(req)
            alive = eng.step()
            ticks += 1
            if not alive and not sched:
                break
        else:
            failures.append(SanitizeFailure(
                "schedule", f"engine did not drain in {max_steps} ticks"))
    except SanitizerError as e:
        failures.append(SanitizeFailure("kv-pool", str(e)))
    except Exception as e:                        # checkify throws et al.
        failures.append(SanitizeFailure("checkify", repr(e)))
    done = eng.pop_completed()
    if not failures and len(done) != n_requests:
        failures.append(SanitizeFailure(
            "schedule", f"{len(done)}/{n_requests} requests completed"))
    if not failures and eng.preemptions == 0:
        failures.append(SanitizeFailure(
            "schedule", "schedule exercised no preemption: the dry-pool "
                        "path went unchecked (shrink kv_blocks)"))
    churn = eng._san.events if eng._san is not None else 0
    return SanitizeReport(
        failures=tuple(failures), ticks=ticks, requests=len(done),
        preemptions=int(eng.preemptions), block_churn=churn,
        elapsed_s=time.perf_counter() - t0)
