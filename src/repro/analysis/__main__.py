"""``python -m repro.analysis`` -- the static-analysis CLI and CI gate.

Modes (combinable; ``--check`` is the union CI runs):

  --lint        reprolint AST rules over src/repro, benchmarks, scripts,
                examples (suppressions + baseline applied)
  --contracts   eval_shape sweep: every registry config x every serving
                path + pspec divisibility
  --shardcheck  abstract sharding/dtype verification: walks the pspec
                policies over every registry config x model degrees
                {1,2,4,8} on a shape-only mesh (no arrays built)
  --retrace     compile-count probes (steady-state serving, grid rollouts)
  --sanitize    run the sanitized serving engine through a flash-crowd
                schedule (KV-pool shadow ownership + checkify guards)
  --check       all of the above; exit 1 on any unsuppressed finding
                (also fails baseline entries whose note is still the
                --write-baseline placeholder)

Baseline workflow:

  --write-baseline        grandfather current lint findings into
                          analysis_baseline.json (then justify each note)
  --baseline PATH         use a different baseline file

Exit status: 0 clean, 1 findings/failures, 2 usage error.
"""
from __future__ import annotations

import argparse
import json
import sys

from . import findings as F
from .linter import (BASELINE_NAME, DEFAULT_PATHS, apply_baseline,
                     lint_paths, repo_root)
from .rules import RULES


def _parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="reprolint + eval_shape contract harness")
    p.add_argument("--check", action="store_true",
                   help="run everything; nonzero exit on any finding "
                        "(the CI gate)")
    p.add_argument("--lint", action="store_true", help="AST rules only")
    p.add_argument("--contracts", action="store_true",
                   help="eval_shape registry sweep only")
    p.add_argument("--shardcheck", action="store_true",
                   help="abstract sharding/dtype verification only")
    p.add_argument("--retrace", action="store_true",
                   help="compile-count probes only")
    p.add_argument("--sanitize", action="store_true",
                   help="sanitized-engine flash-crowd run only")
    p.add_argument("--paths", nargs="*", default=None,
                   help=f"files/dirs to lint (default: "
                        f"{' '.join(DEFAULT_PATHS)})")
    p.add_argument("--rules", default=None,
                   help="comma-separated rule subset")
    p.add_argument("--baseline", default=None,
                   help=f"baseline file (default: <repo>/{BASELINE_NAME})")
    p.add_argument("--write-baseline", action="store_true",
                   help="grandfather current lint findings")
    p.add_argument("--list-rules", action="store_true")
    p.add_argument("--json", action="store_true", dest="as_json",
                   help="machine-readable output")
    p.add_argument("--verbose", action="store_true")
    return p


def main(argv=None) -> int:
    args = _parser().parse_args(argv)
    if args.list_rules:
        for name, rule in sorted(RULES.items()):
            print(f"{name:18s} {rule.description}")
        return 0

    rules = None
    if args.rules:
        rules = [r.strip() for r in args.rules.split(",") if r.strip()]
        unknown = set(rules) - set(RULES)
        if unknown:
            print(f"unknown rules: {sorted(unknown)}; have {sorted(RULES)}",
                  file=sys.stderr)
            return 2

    do_lint = args.lint or args.check or args.write_baseline
    do_contracts = args.contracts or args.check
    do_shardcheck = args.shardcheck or args.check
    do_retrace = args.retrace or args.check
    do_sanitize = args.sanitize or args.check
    if not (do_lint or do_contracts or do_shardcheck or do_retrace
            or do_sanitize):
        do_lint = True                   # bare invocation: lint + report

    rc = 0
    report: dict = {}

    if do_lint:
        found = lint_paths(paths=args.paths or DEFAULT_PATHS, rules=rules)
        root = repo_root()
        baseline_path = args.baseline or root / BASELINE_NAME
        if args.write_baseline:
            F.write_baseline(baseline_path, found)
            print(f"baseline written: {len(found)} finding(s) -> "
                  f"{baseline_path}")
            print("justify every 'note' entry or fix the finding "
                  "(docs/analysis.md)")
            return 0
        new, old, baseline = apply_baseline(found, root=root,
                                            baseline_path=baseline_path)
        stale = F.placeholder_entries(baseline) if args.check else []
        report["lint"] = {"new": [f.render() for f in new],
                          "baselined": [f.render() for f in old],
                          "placeholder_notes": [
                              f"{e.get('path', '?')} [{e.get('rule', '?')}] "
                              f"{e.get('fingerprint', '?')}" for e in stale]}
        if not args.as_json:
            for f in new:
                print(f.render())
            if old and args.verbose:
                for f in old:
                    print(f"{f.render()}  [baselined]")
            for line in report["lint"]["placeholder_notes"]:
                print(f"baseline entry never justified (note is still the "
                      f"placeholder): {line}")
            print(f"reprolint: {len(new)} finding(s), "
                  f"{len(old)} baselined")
        if new or stale:
            rc = 1

    if do_contracts:
        from .contracts import run_contracts
        r = run_contracts(verbose=args.verbose and not args.as_json)
        report["contracts"] = {
            "covered": len(r.covered), "elapsed_s": round(r.elapsed_s, 2),
            "skipped": [list(s) for s in r.skipped],
            "failures": [f.render() for f in r.failures]}
        if not args.as_json:
            for f in r.failures:
                print(f.render())
            print(f"contracts: {len(r.covered)} arch-path legs in "
                  f"{r.elapsed_s:.1f}s, {len(r.failures)} failure(s), "
                  f"{len(r.skipped)} contract skip(s)")
        if r.failures:
            rc = 1

    if do_shardcheck:
        from .shardcheck import run_shardcheck
        r = run_shardcheck(verbose=args.verbose and not args.as_json)
        report["shardcheck"] = {
            "covered": len(r.covered), "elapsed_s": round(r.elapsed_s, 2),
            "skipped": [list(s) for s in r.skipped],
            "failures": [f.render() for f in r.failures]}
        if not args.as_json:
            for f in r.failures:
                print(f.render())
            print(f"shardcheck: {len(r.covered)} arch-degree legs in "
                  f"{r.elapsed_s:.1f}s, {len(r.failures)} failure(s), "
                  f"{len(r.skipped)} skip(s)")
        if r.failures:
            rc = 1

    if do_retrace:
        from .retrace import run_retrace
        fails = run_retrace()
        report["retrace"] = {"failures": [f.render() for f in fails]}
        if not args.as_json:
            for f in fails:
                print(f.render())
            print(f"retrace: {len(fails)} failure(s)")
        if fails:
            rc = 1

    if do_sanitize:
        from .sanitize import run_sanitize
        r = run_sanitize()
        report["sanitize"] = {
            "ticks": r.ticks, "requests": r.requests,
            "preemptions": r.preemptions, "block_churn": r.block_churn,
            "elapsed_s": round(r.elapsed_s, 2),
            "failures": [f.render() for f in r.failures]}
        if not args.as_json:
            for f in r.failures:
                print(f.render())
            print(f"sanitize: {r.ticks} ticks, {r.requests} request(s), "
                  f"{r.preemptions} preemption(s), {r.block_churn} block "
                  f"event(s) in {r.elapsed_s:.1f}s, "
                  f"{len(r.failures)} failure(s)")
        if r.failures:
            rc = 1

    if args.as_json:
        print(json.dumps(report, indent=2))
    return rc


if __name__ == "__main__":
    sys.exit(main())
