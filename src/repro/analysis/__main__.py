"""``python -m repro.analysis`` -- the static-analysis CLI and CI gate.

Modes (combinable; ``--check`` is the union CI runs):

  --lint        reprolint AST rules over src/repro, benchmarks, scripts,
                examples (suppressions + baseline applied)
  --contracts   eval_shape sweep: every registry config x every serving
                path + pspec divisibility
  --retrace     compile-count probes (steady-state serving, grid rollouts)
  --check       all of the above; exit 1 on any unsuppressed finding

Baseline workflow:

  --write-baseline        grandfather current lint findings into
                          analysis_baseline.json (then justify each note)
  --baseline PATH         use a different baseline file

Exit status: 0 clean, 1 findings/failures, 2 usage error.
"""
from __future__ import annotations

import argparse
import json
import sys

from . import findings as F
from .linter import (BASELINE_NAME, DEFAULT_PATHS, apply_baseline,
                     lint_paths, repo_root)
from .rules import RULES


def _parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="reprolint + eval_shape contract harness")
    p.add_argument("--check", action="store_true",
                   help="run everything; nonzero exit on any finding "
                        "(the CI gate)")
    p.add_argument("--lint", action="store_true", help="AST rules only")
    p.add_argument("--contracts", action="store_true",
                   help="eval_shape registry sweep only")
    p.add_argument("--retrace", action="store_true",
                   help="compile-count probes only")
    p.add_argument("--paths", nargs="*", default=None,
                   help=f"files/dirs to lint (default: "
                        f"{' '.join(DEFAULT_PATHS)})")
    p.add_argument("--rules", default=None,
                   help="comma-separated rule subset")
    p.add_argument("--baseline", default=None,
                   help=f"baseline file (default: <repo>/{BASELINE_NAME})")
    p.add_argument("--write-baseline", action="store_true",
                   help="grandfather current lint findings")
    p.add_argument("--list-rules", action="store_true")
    p.add_argument("--json", action="store_true", dest="as_json",
                   help="machine-readable output")
    p.add_argument("--verbose", action="store_true")
    return p


def main(argv=None) -> int:
    args = _parser().parse_args(argv)
    if args.list_rules:
        for name, rule in sorted(RULES.items()):
            print(f"{name:18s} {rule.description}")
        return 0

    rules = None
    if args.rules:
        rules = [r.strip() for r in args.rules.split(",") if r.strip()]
        unknown = set(rules) - set(RULES)
        if unknown:
            print(f"unknown rules: {sorted(unknown)}; have {sorted(RULES)}",
                  file=sys.stderr)
            return 2

    do_lint = args.lint or args.check or args.write_baseline
    do_contracts = args.contracts or args.check
    do_retrace = args.retrace or args.check
    if not (do_lint or do_contracts or do_retrace):
        do_lint = True                   # bare invocation: lint + report

    rc = 0
    report: dict = {}

    if do_lint:
        found = lint_paths(paths=args.paths or DEFAULT_PATHS, rules=rules)
        root = repo_root()
        baseline_path = args.baseline or root / BASELINE_NAME
        if args.write_baseline:
            F.write_baseline(baseline_path, found)
            print(f"baseline written: {len(found)} finding(s) -> "
                  f"{baseline_path}")
            print("justify every 'note' entry or fix the finding "
                  "(docs/analysis.md)")
            return 0
        new, old, _ = apply_baseline(found, root=root,
                                     baseline_path=baseline_path)
        report["lint"] = {"new": [f.render() for f in new],
                          "baselined": [f.render() for f in old]}
        if not args.as_json:
            for f in new:
                print(f.render())
            if old and args.verbose:
                for f in old:
                    print(f"{f.render()}  [baselined]")
            print(f"reprolint: {len(new)} finding(s), "
                  f"{len(old)} baselined")
        if new:
            rc = 1

    if do_contracts:
        from .contracts import run_contracts
        r = run_contracts(verbose=args.verbose and not args.as_json)
        report["contracts"] = {
            "covered": len(r.covered), "elapsed_s": round(r.elapsed_s, 2),
            "skipped": [list(s) for s in r.skipped],
            "failures": [f.render() for f in r.failures]}
        if not args.as_json:
            for f in r.failures:
                print(f.render())
            print(f"contracts: {len(r.covered)} arch-path legs in "
                  f"{r.elapsed_s:.1f}s, {len(r.failures)} failure(s), "
                  f"{len(r.skipped)} contract skip(s)")
        if r.failures:
            rc = 1

    if do_retrace:
        from .retrace import run_retrace
        fails = run_retrace()
        report["retrace"] = {"failures": [f.render() for f in fails]}
        if not args.as_json:
            for f in fails:
                print(f.render())
            print(f"retrace: {len(fails)} failure(s)")
        if fails:
            rc = 1

    if args.as_json:
        print(json.dumps(report, indent=2))
    return rc


if __name__ == "__main__":
    sys.exit(main())
