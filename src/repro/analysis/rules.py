"""reprolint rules: AST checks for the JAX failure modes this codebase hits.

Five rules, each encoding a contract the test suite can only catch
indirectly (a numeric parity test happens to trip) or not at all (a silent
retrace).  See docs/analysis.md for the catalogue with examples.

``key-reuse``         a PRNG key consumed twice with no split/fold_in between
``jit-branch``        Python ``if``/``while`` on values flowing from a jitted
                      function's (non-static) array arguments
``recompile-hazard``  jit objects built per call / inside loops, unhashable
                      static_argnums, shape-varying values reaching jit call
                      sites outside the bucketing helpers
``host-sync``         ``.item()`` / ``float()`` / ``np.asarray()`` on device
                      values inside serving-tick / decode hot loops
``pallas-wrapper``    Pallas kernel modules imported anywhere but
                      ``kernels/ops.py`` (the wrapper that owns tile padding)

All rules share one `FileContext` that resolves import aliases
(``import jax.numpy as jnp`` etc.) so matching is on canonical dotted names.
"""
from __future__ import annotations

import ast
import dataclasses

from .findings import Finding

# ---------------------------------------------------------------------------
# shared per-file context
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class FileContext:
    path: str                       # repo-relative, posix
    source_lines: list[str]
    tree: ast.Module
    aliases: dict[str, str] = dataclasses.field(default_factory=dict)
    jit_bound: set[str] = dataclasses.field(default_factory=set)

    def __post_init__(self):
        self.aliases = _collect_aliases(self.tree)
        self.jit_bound = _collect_jit_bound(self)

    def snippet(self, line: int) -> str:
        if 1 <= line <= len(self.source_lines):
            return self.source_lines[line - 1].strip()
        return ""

    def finding(self, rule: str, node: ast.AST, message: str) -> Finding:
        line = getattr(node, "lineno", 1)
        return Finding(rule=rule, path=self.path, line=line,
                       col=getattr(node, "col_offset", 0) + 1,
                       message=message, snippet=self.snippet(line))

    def dotted(self, node) -> str | None:
        """Canonical dotted name of an expression, alias-resolved
        (``jnp.argmax`` -> ``jax.numpy.argmax``), or None."""
        parts = []
        while isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
        if isinstance(node, ast.Name):
            parts.append(self.aliases.get(node.id, node.id))
        elif isinstance(node, ast.Call):
            return None
        else:
            return None
        return ".".join(reversed(parts))

    def is_call_to(self, node, *names: str) -> bool:
        return (isinstance(node, ast.Call)
                and self.dotted(node.func) in names)


def _collect_aliases(tree: ast.Module) -> dict[str, str]:
    aliases: dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                aliases[a.asname or a.name.split(".")[0]] = (
                    a.name if a.asname else a.name.split(".")[0])
        elif isinstance(node, ast.ImportFrom) and node.module:
            for a in node.names:
                aliases[a.asname or a.name] = f"{node.module}.{a.name}"
    return aliases


def _collect_jit_bound(ctx: FileContext) -> set[str]:
    """Names/attrs anywhere in the module bound to a ``jax.jit(...)`` result
    (possibly through a wrapper call like ``shard_ctx(mesh, jax.jit(f))``)."""
    bound: set[str] = set()
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Assign):
            continue
        has_jit = any(ctx.is_call_to(sub, "jax.jit")
                      for sub in ast.walk(node.value))
        if not has_jit:
            continue
        for tgt in node.targets:
            if isinstance(tgt, ast.Name):
                bound.add(tgt.id)
            elif (isinstance(tgt, ast.Attribute)
                  and isinstance(tgt.value, ast.Name)):
                bound.add(f"{tgt.value.id}.{tgt.attr}")
    return bound


def _func_defs(tree: ast.Module):
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node


def _terminates(body: list[ast.stmt]) -> bool:
    return bool(body) and isinstance(
        body[-1], (ast.Return, ast.Raise, ast.Continue, ast.Break))


# ---------------------------------------------------------------------------
# rule: key-reuse
# ---------------------------------------------------------------------------

_KEY_FACTORIES = ("jax.random.PRNGKey", "jax.random.key",
                  "jax.random.fold_in", "jax.random.wrap_key_data")
_KEY_SPLIT = "jax.random.split"
# calls that *derive from* a key without consuming it
_NON_CONSUMING = ("jax.random.fold_in", "jax.random.key_data",
                  "jax.random.clone", "jax.random.key_impl")


class _KeyScope:
    """Linear abstract interpreter over one function body tracking which
    names hold unconsumed PRNG keys (or arrays of keys from ``split``)."""

    def __init__(self, ctx: FileContext, rule: "KeyReuseRule"):
        self.ctx = ctx
        self.rule = rule
        self.findings: list[Finding] = []
        self.keys: dict[str, int | None] = {}       # name -> consuming line
        self.elems: dict[str, dict[str, int]] = {}  # array name -> idx -> line

    # -- expression side: consumption ------------------------------------

    def use(self, expr: ast.expr | None):
        if expr is None:
            return
        for node in ast.walk(expr):
            if not isinstance(node, ast.Call):
                continue
            callee = self.ctx.dotted(node.func)
            if callee in _NON_CONSUMING:
                continue
            args = list(node.args) + [kw.value for kw in node.keywords]
            for a in args:
                self._consume_arg(a, node)

    def _consume_arg(self, arg, call):
        if isinstance(arg, ast.Name) and arg.id in self.keys:
            prev = self.keys[arg.id]
            if prev is not None:
                self.findings.append(self.ctx.finding(
                    self.rule.name, call,
                    f"PRNG key '{arg.id}' reused: already consumed at line "
                    f"{prev} with no split/fold_in in between"))
            else:
                self.keys[arg.id] = call.lineno
        elif (isinstance(arg, ast.Subscript)
              and isinstance(arg.value, ast.Name)
              and arg.value.id in self.elems):
            idx = _const_index(arg.slice)
            if idx is None:
                return                     # dynamic index: can't track
            seen = self.elems[arg.value.id]
            if idx in seen:
                self.findings.append(self.ctx.finding(
                    self.rule.name, call,
                    f"PRNG key '{arg.value.id}[{idx}]' reused: already "
                    f"consumed at line {seen[idx]}"))
            else:
                seen[idx] = call.lineno

    # -- binding side ----------------------------------------------------

    def _kind(self, expr) -> str | None:
        """'key' | 'array' | None for an RHS expression."""
        if self.ctx.is_call_to(expr, *_KEY_FACTORIES):
            return "key"
        if self.ctx.is_call_to(expr, _KEY_SPLIT):
            return "array"
        if isinstance(expr, ast.Name) and expr.id in self.keys:
            return "key"
        if (isinstance(expr, ast.Subscript)
                and isinstance(expr.value, ast.Name)
                and expr.value.id in self.elems):
            return "key"
        return None

    def bind_name(self, name: str, kind: str | None):
        self.keys.pop(name, None)
        self.elems.pop(name, None)
        if kind == "key":
            self.keys[name] = None
        elif kind == "array":
            self.elems[name] = {}

    def assign(self, targets, value):
        self.use(value)
        kind = self._kind(value)
        for tgt in targets:
            if isinstance(tgt, ast.Name):
                self.bind_name(tgt.id, kind)
            elif isinstance(tgt, (ast.Tuple, ast.List)):
                # `k1, k2 = jax.random.split(key)` -> each elt a fresh key
                elt_kind = "key" if kind == "array" else None
                for elt in tgt.elts:
                    if isinstance(elt, ast.Name):
                        self.bind_name(elt.id, elt_kind)
            # attribute/subscript targets: no tracking

    # -- statements ------------------------------------------------------

    def run(self, body: list[ast.stmt]):
        for stmt in body:
            self.stmt(stmt)

    def copy(self) -> "_KeyScope":
        s = _KeyScope.__new__(_KeyScope)
        s.ctx, s.rule, s.findings = self.ctx, self.rule, self.findings
        s.keys = dict(self.keys)
        s.elems = {k: dict(v) for k, v in self.elems.items()}
        return s

    def merge(self, branches: list["_KeyScope"]):
        for b in branches:
            for name, line in b.keys.items():
                if name in self.keys and line is not None:
                    if self.keys[name] is None:
                        self.keys[name] = line
            for name, seen in b.elems.items():
                if name in self.elems:
                    for idx, line in seen.items():
                        self.elems[name].setdefault(idx, line)

    def stmt(self, stmt: ast.stmt):
        if isinstance(stmt, ast.Assign):
            self.assign(stmt.targets, stmt.value)
        elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
            self.assign([stmt.target], stmt.value)
        elif isinstance(stmt, ast.AugAssign):
            self.use(stmt.value)
        elif isinstance(stmt, (ast.Expr, ast.Delete, ast.Assert)):
            self.use(getattr(stmt, "value", None) or getattr(stmt, "test", None))
        elif isinstance(stmt, ast.Return):
            pass                       # returning a key hands off ownership
        elif isinstance(stmt, ast.If):
            self.use(stmt.test)
            taken = []
            for branch in (stmt.body, stmt.orelse):
                scope = self.copy()
                scope.run(branch)
                if not _terminates(branch):
                    taken.append(scope)
            self.merge(taken)
        elif isinstance(stmt, (ast.For, ast.AsyncFor)):
            self.use(stmt.iter)
            iter_kind = self._kind(stmt.iter)
            # two passes over the body to catch cross-iteration reuse;
            # the loop target rebinds fresh each pass
            for _ in range(2):
                if isinstance(stmt.target, ast.Name):
                    self.bind_name(
                        stmt.target.id,
                        "key" if iter_kind == "array" else None)
                elif isinstance(stmt.target, (ast.Tuple, ast.List)):
                    for elt in stmt.target.elts:
                        if isinstance(elt, ast.Name):
                            self.bind_name(elt.id, None)
                self.run(stmt.body)
            self.run(stmt.orelse)
        elif isinstance(stmt, ast.While):
            for _ in range(2):
                self.use(stmt.test)
                self.run(stmt.body)
            self.run(stmt.orelse)
        elif isinstance(stmt, ast.With):
            for item in stmt.items:
                self.use(item.context_expr)
            self.run(stmt.body)
        elif isinstance(stmt, ast.Try):
            self.run(stmt.body)
            for h in stmt.handlers:
                self.run(h.body)
            self.run(stmt.orelse)
            self.run(stmt.finalbody)
        elif isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                               ast.ClassDef)):
            pass                       # nested scopes analyzed separately


def _const_index(node) -> str | None:
    if isinstance(node, ast.Constant):
        return repr(node.value)
    if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub) \
            and isinstance(node.operand, ast.Constant):
        return f"-{node.operand.value!r}"
    return None


class KeyReuseRule:
    name = "key-reuse"
    description = ("a PRNG key is passed to two consumers with no "
                   "split/fold_in between them")

    def check(self, ctx: FileContext) -> list[Finding]:
        findings: list[Finding] = []
        scopes = [ctx.tree.body] + [f.body for f in _func_defs(ctx.tree)]
        for body in scopes:
            scope = _KeyScope(ctx, self)
            scope.run(body)
            findings.extend(scope.findings)
        # the module-body scope re-walks nothing (nested defs skipped), but
        # dedupe anyway in case of overlapping scopes
        out, seen = [], set()
        for f in findings:
            key = (f.line, f.col, f.message)
            if key not in seen:
                seen.add(key)
                out.append(f)
        return out


# ---------------------------------------------------------------------------
# rule: jit-branch
# ---------------------------------------------------------------------------

# attribute/function forms that turn a traced value into static Python data
_SHAPE_ATTRS = {"shape", "ndim", "dtype", "size"}
_STATIC_CALLS = ("len", "isinstance", "type")


def _jitted_functions(ctx: FileContext):
    """Yield (FunctionDef-or-Lambda, static_param_names) for every function
    the module hands to ``jax.jit`` -- by decorator, by ``jax.jit(f)``
    wrapping of a local def, or as an inline lambda."""
    local_defs = {f.name: f for f in _func_defs(ctx.tree)}
    seen: set[int] = set()

    def statics(call: ast.Call | None, fn) -> set[str]:
        names: set[str] = set()
        if call is None:
            return names
        posargs = [a.arg for a in fn.args.args]
        for kw in call.keywords:
            vals = []
            if isinstance(kw.value, ast.Constant):
                vals = [kw.value.value]
            elif isinstance(kw.value, (ast.Tuple, ast.List)):
                vals = [e.value for e in kw.value.elts
                        if isinstance(e, ast.Constant)]
            if kw.arg == "static_argnames":
                names.update(v for v in vals if isinstance(v, str))
            elif kw.arg == "static_argnums":
                for v in vals:
                    if isinstance(v, int) and v < len(posargs):
                        names.add(posargs[v])
        return names

    for node in ast.walk(ctx.tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for deco in node.decorator_list:
                call = deco if isinstance(deco, ast.Call) else None
                target = call.func if call else deco
                if ctx.dotted(target) == "jax.jit" and id(node) not in seen:
                    seen.add(id(node))
                    yield node, statics(call, node)
        elif ctx.is_call_to(node, "jax.jit") and node.args:
            fn = node.args[0]
            if isinstance(fn, ast.Lambda) and id(fn) not in seen:
                seen.add(id(fn))
                yield fn, statics(node, fn)
            elif isinstance(fn, ast.Name) and fn.id in local_defs:
                target = local_defs[fn.id]
                if id(target) not in seen:
                    seen.add(id(target))
                    yield target, statics(node, target)


def _prune_static(expr: ast.expr) -> ast.expr | None:
    """Copy ``expr`` with statically-safe subtrees removed: ``.shape`` /
    ``.ndim`` / ``.dtype`` / ``.size`` chains, len()/isinstance()/type()
    calls, and ``x is None`` comparisons."""

    class Pruner(ast.NodeTransformer):
        def visit_Attribute(self, node):
            if node.attr in _SHAPE_ATTRS:
                return None
            return self.generic_visit(node)

        def visit_Call(self, node):
            func = node.func
            if isinstance(func, ast.Name) and func.id in _STATIC_CALLS:
                return None
            return self.generic_visit(node)

        def visit_Compare(self, node):
            if all(isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops) \
                    and all(isinstance(c, ast.Constant) and c.value is None
                            for c in node.comparators):
                return None
            return self.generic_visit(node)

    import copy
    return Pruner().visit(copy.deepcopy(expr))


def _names_in(expr: ast.expr | None) -> set[str]:
    if expr is None:
        return set()
    return {n.id for n in ast.walk(expr) if isinstance(n, ast.Name)}


class _TaintScope:
    def __init__(self, ctx: FileContext, rule, tainted: set[str]):
        self.ctx, self.rule = ctx, rule
        self.tainted = set(tainted)
        self.findings: list[Finding] = []

    def rhs_tainted(self, expr) -> bool:
        return bool(_names_in(_prune_static(expr)) & self.tainted)

    def run(self, body):
        for stmt in body:
            self.stmt(stmt)

    def _bind(self, targets, tainted: bool):
        for tgt in targets:
            if isinstance(tgt, ast.Name):
                (self.tainted.add if tainted
                 else self.tainted.discard)(tgt.id)
            elif isinstance(tgt, (ast.Tuple, ast.List)):
                self._bind(tgt.elts, tainted)

    def _check_test(self, node, test, kind: str):
        pruned = _prune_static(test)
        hit = _names_in(pruned) & self.tainted
        if hit:
            self.findings.append(self.ctx.finding(
                self.rule.name, node,
                f"Python `{kind}` branches on {sorted(hit)} which flows from "
                f"a jitted function's array arguments (tracer leak: use "
                f"lax.cond/where, or mark the argument static)"))

    def stmt(self, stmt):
        if isinstance(stmt, ast.Assign):
            self._bind(stmt.targets, self.rhs_tainted(stmt.value))
        elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
            self._bind([stmt.target], self.rhs_tainted(stmt.value))
        elif isinstance(stmt, ast.AugAssign):
            if isinstance(stmt.target, ast.Name) \
                    and self.rhs_tainted(stmt.value):
                self.tainted.add(stmt.target.id)
        elif isinstance(stmt, ast.If):
            self._check_test(stmt, stmt.test, "if")
            self.run(stmt.body)
            self.run(stmt.orelse)
        elif isinstance(stmt, ast.While):
            self._check_test(stmt, stmt.test, "while")
            self.run(stmt.body)
            self.run(stmt.orelse)
        elif isinstance(stmt, ast.Assert):
            self._check_test(stmt, stmt.test, "assert")
        elif isinstance(stmt, (ast.For, ast.AsyncFor)):
            self._bind([stmt.target], self.rhs_tainted(stmt.iter))
            self.run(stmt.body)
            self.run(stmt.orelse)
        elif isinstance(stmt, ast.With):
            self.run(stmt.body)
        elif isinstance(stmt, ast.Try):
            self.run(stmt.body)
            for h in stmt.handlers:
                self.run(h.body)
            self.run(stmt.orelse)
            self.run(stmt.finalbody)
        elif isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            # nested defs (scan bodies etc.) run traced too: their params
            # are traced values, and they close over the outer taint
            inner = _TaintScope(self.ctx, self.rule, self.tainted | {
                a.arg for a in stmt.args.args})
            inner.findings = self.findings
            inner.run(stmt.body)
        elif isinstance(stmt, ast.Expr) and isinstance(stmt.value, ast.IfExp):
            pass                       # value-level select: harmless


class JitBranchRule:
    name = "jit-branch"
    description = ("Python if/while branches on a value flowing from a "
                   "jitted function's array arguments")

    def check(self, ctx: FileContext) -> list[Finding]:
        findings: list[Finding] = []
        for fn, static_names in _jitted_functions(ctx):
            if isinstance(fn, ast.Lambda):
                continue               # lambdas cannot contain statements
            params = {a.arg for a in fn.args.args} - static_names - {"self"}
            scope = _TaintScope(ctx, self, params)
            scope.run(fn.body)
            findings.extend(scope.findings)
        return findings


# ---------------------------------------------------------------------------
# rule: recompile-hazard
# ---------------------------------------------------------------------------

# numpy/jnp constructors whose non-constant size/width argument makes the
# result's SHAPE vary call to call
_SHAPE_MAKERS = ("numpy.pad", "jax.numpy.pad", "numpy.zeros", "numpy.full",
                 "numpy.empty", "numpy.stack", "jax.numpy.zeros",
                 "jax.numpy.full")


def _has_nonconst_dims(call: ast.Call) -> bool:
    """First positional arg (shape / pad-width) is not a plain constant."""
    if not call.args:
        return False
    arg = call.args[0]
    for node in ast.walk(arg):
        if isinstance(node, ast.Name):
            return True
    return False


class RecompileHazardRule:
    name = "recompile-hazard"
    description = ("jit objects rebuilt per call or per loop iteration; "
                   "shape-varying values reaching jit call sites outside "
                   "the bucketing helpers")

    # a function that routes widths through `*_bucket*` is a sanctioned
    # bucketing helper: its shape variation is bounded by the bucket ladder
    def _is_bucketing_helper(self, fn) -> bool:
        if "_bucket" in fn.name:
            return True
        for node in ast.walk(fn):
            if isinstance(node, ast.Call):
                callee = node.func
                if isinstance(callee, ast.Attribute) \
                        and "_bucket" in callee.attr:
                    return True
                if isinstance(callee, ast.Name) and "_bucket" in callee.id:
                    return True
        return False

    def check(self, ctx: FileContext) -> list[Finding]:
        findings: list[Finding] = []
        for node in ast.walk(ctx.tree):
            # (a) jax.jit(...)(...) built and invoked inline
            if isinstance(node, ast.Call) \
                    and ctx.is_call_to(node.func, "jax.jit"):
                findings.append(ctx.finding(
                    self.name, node,
                    "jax.jit(...) created and called inline: every call "
                    "retraces -- bind the jitted function once"))
            # (c) unhashable static_argnums/static_argnames values
            if ctx.is_call_to(node, "jax.jit"):
                for kw in node.keywords:
                    if kw.arg in ("static_argnums", "static_argnames") \
                            and isinstance(kw.value, (ast.List, ast.Dict,
                                                      ast.Set)):
                        findings.append(ctx.finding(
                            self.name, kw.value,
                            f"{kw.arg} uses an unhashable "
                            f"{type(kw.value).__name__.lower()} literal -- "
                            f"use a tuple"))
            # (b) jit object created inside a loop body
            if isinstance(node, (ast.For, ast.While)):
                for sub in ast.walk(node):
                    if sub is not node and ctx.is_call_to(sub, "jax.jit"):
                        findings.append(ctx.finding(
                            self.name, sub,
                            "jax.jit(...) created inside a loop: hoist it "
                            "out (each construction starts a fresh trace "
                            "cache)"))
        # (d) shape-varying args at jit call sites outside bucketing helpers
        for fn in _func_defs(ctx.tree):
            if self._is_bucketing_helper(fn):
                continue
            varying: set[str] = set()
            for node in ast.walk(fn):
                if isinstance(node, ast.Assign) and isinstance(
                        node.value, ast.Call):
                    callee = ctx.dotted(node.value.func)
                    if callee in _SHAPE_MAKERS \
                            and _has_nonconst_dims(node.value):
                        for tgt in node.targets:
                            if isinstance(tgt, ast.Name):
                                varying.add(tgt.id)
                elif isinstance(node, ast.Call):
                    callee = ctx.dotted(node.func)
                    if callee in ctx.jit_bound and varying:
                        used = set()
                        for a in list(node.args) + [k.value
                                                    for k in node.keywords]:
                            used |= _names_in(a) & varying
                        if used:
                            findings.append(ctx.finding(
                                self.name, node,
                                f"shape-varying value {sorted(used)} reaches "
                                f"jitted call '{callee}' outside a bucketing "
                                f"helper: every distinct width recompiles"))
        return findings


# ---------------------------------------------------------------------------
# rule: host-sync
# ---------------------------------------------------------------------------

# (path-suffix, function names): the serving tick/admission hot path, where
# one stray device->host round trip serializes every slot's decode step.
# The telemetry read sites (repro/obs) are held to the same bar: they run
# inside sampled ticks of the same loop, and their contract is to read
# only host state the engine already materialized -- a device sync hiding
# in a "metrics read" would stall the pipeline exactly like one in the
# step function itself.
HOT_ZONES = (
    ("serving/engine.py", ("_step_continuous", "_step_sync",
                           "_admit_continuous", "_admit_sync",
                           "_solo_prefill", "_grow_blocks", "step")),
    ("obs/enginehooks.py", ("on_prefill", "on_decode_tick", "sample")),
)

_SYNC_WRAPPERS = ("float", "int", "bool", "numpy.asarray", "numpy.array",
                  "jax.device_get")
_DEVICE_PRODUCERS = ("jax.", "jax.numpy.")


class HostSyncRule:
    name = "host-sync"
    description = (".item()/float()/np.asarray() on device values inside "
                   "the serving tick / decode / rollout hot loops")

    def _hot_functions(self, ctx: FileContext):
        for suffix, names in HOT_ZONES:
            if ctx.path.endswith(suffix):
                for fn in _func_defs(ctx.tree):
                    if fn.name in names:
                        yield fn, f"hot zone {suffix}:{fn.name}"
        # auto zones: any loop body that dispatches to a jit-bound callable
        # is a steady-state loop; syncs inside it stall the pipeline
        for fn in _func_defs(ctx.tree):
            for node in ast.walk(fn):
                if not isinstance(node, (ast.For, ast.While)):
                    continue
                calls_jit = any(
                    isinstance(sub, ast.Call)
                    and ctx.dotted(sub.func) in ctx.jit_bound
                    for sub in ast.walk(node))
                if calls_jit:
                    yield node, f"loop in {fn.name} dispatching jitted work"

    def _device_expr(self, ctx, expr, tainted: set[str]) -> bool:
        for node in ast.walk(expr):
            if isinstance(node, ast.Name) and node.id in tainted:
                return True
            if isinstance(node, ast.Call):
                callee = ctx.dotted(node.func)
                if callee and (callee in ctx.jit_bound
                               or callee.startswith(_DEVICE_PRODUCERS)):
                    return True
        return False

    def check(self, ctx: FileContext) -> list[Finding]:
        findings: list[Finding] = []
        reported: set[int] = set()
        for zone, where in self._hot_functions(ctx):
            tainted: set[str] = set()
            for node in ast.walk(zone):
                # taint: names assigned from jitted dispatch / jnp ops
                if isinstance(node, ast.Assign):
                    is_dev = self._device_expr(ctx, node.value, tainted)
                    is_sync = self._sync_call(ctx, node.value, tainted)
                    for tgt in node.targets:
                        names = [tgt] if isinstance(tgt, ast.Name) else [
                            e for e in getattr(tgt, "elts", [])
                            if isinstance(e, ast.Name)]
                        for n in names:
                            if is_dev and not is_sync:
                                tainted.add(n.id)
                            else:
                                tainted.discard(n.id)
                if isinstance(node, ast.Call) and node.lineno not in reported:
                    if self._sync_call(ctx, node, tainted):
                        reported.add(node.lineno)
                        findings.append(ctx.finding(
                            self.name, node,
                            f"host-device sync "
                            f"('{ctx.snippet(node.lineno)[:48]}') inside "
                            f"{where}: forces the device pipeline to drain "
                            f"every tick"))
        return findings

    def _sync_call(self, ctx, expr, tainted) -> bool:
        """Is ``expr`` (or its outermost call) a blocking host transfer of a
        device value?"""
        if not isinstance(expr, ast.Call):
            return False
        func = expr.func
        if isinstance(func, ast.Attribute) and func.attr == "item":
            return self._device_expr(ctx, func.value, tainted)
        callee = ctx.dotted(func)
        if callee in _SYNC_WRAPPERS and expr.args:
            return self._device_expr(ctx, expr.args[0], tainted)
        return False


# ---------------------------------------------------------------------------
# rule: pallas-wrapper
# ---------------------------------------------------------------------------

_KERNEL_MODULES = ("flash_attention", "decode_attention", "ssd_scan",
                   "rglru_scan", "partition_sweep")


class PallasWrapperRule:
    name = "pallas-wrapper"
    description = ("Pallas kernels must be reached through kernels/ops.py "
                   "(the wrapper owns tile padding); direct kernel-module "
                   "or pallas imports elsewhere are flagged")

    def check(self, ctx: FileContext) -> list[Finding]:
        if "kernels/" in ctx.path and not ctx.path.endswith("kernels/ref.py"):
            return []
        findings = []
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    if a.name.startswith("jax.experimental.pallas"):
                        findings.append(ctx.finding(
                            self.name, node,
                            "direct Pallas import outside kernels/: route "
                            "through a repro.kernels.ops wrapper"))
            elif isinstance(node, ast.ImportFrom) and node.module:
                mod = node.module
                if mod.startswith("jax.experimental") and "pallas" in mod \
                        or mod == "jax.experimental" and any(
                            a.name == "pallas" for a in node.names):
                    findings.append(ctx.finding(
                        self.name, node,
                        "direct Pallas import outside kernels/: route "
                        "through a repro.kernels.ops wrapper"))
                    continue
                tail = mod.rsplit(".", 1)[-1]
                if tail in _KERNEL_MODULES and (
                        "kernels" in mod or node.level > 0):
                    findings.append(ctx.finding(
                        self.name, node,
                        f"kernel module '{tail}' imported directly: its "
                        f"entry points assume tile-aligned shapes -- import "
                        f"the padded wrapper from repro.kernels.ops"))
        return findings


RULES = {r.name: r for r in (KeyReuseRule(), JitBranchRule(),
                             RecompileHazardRule(), HostSyncRule(),
                             PallasWrapperRule())}
