"""Layer 3: shardcheck -- static sharding + dtype-flow verification.

An abstract-evaluation pass over the ENTIRE sharding policy surface:
``launch/sharding.py`` (``param_spec`` / ``batch_spec`` / ``cache_spec``)
and ``serving/kvpool.py`` (``decode_state_specs``), walked for every
registry config x model degree in :data:`MODEL_DEGREES` on the contracts
layer's :class:`~repro.analysis.contracts.ShapeOnlyMesh` -- no arrays are
built, no devices needed, the whole registry checks in seconds.

Spec invariants (check ``spec`` / ``batch`` / ``cache`` / ``pool``):

* every sharded dim divides the product of its mesh axes, no mesh axis is
  consumed twice in one spec, no spec outranks its leaf
  (``launch.sharding.validate_spec``);
* attention projections shard HEAD-granularly: if a wq/wk/wv/wo/bias leaf
  carries ``"model"``, the relevant head count must divide the degree --
  the exact bug class PR 5 fixed (check ``kv-heads``);
* batch inputs never shard over ``"model"`` (tokens are replicated across
  tensor-parallel shards by contract);
* paged-pool leaves: only KV ``k``/``v`` tensors may shard, only on their
  kv-head dim; integer bookkeeping (ring positions -- and, by the same
  contract, the block tables / ``seq_lens`` the engine passes alongside)
  stays replicated; block-count / block-size axes never split;
* prefill-cache vs paged-pool CONSISTENCY: for each KV leaf, both
  policies must agree on whether the kv-head dim shards -- a mismatch
  means ``commit_prefill`` reshards every admission (check
  ``consistency``).

Dtype flow (check ``dtype``): ``eval_shape`` propagation over
``MecParams``, the serving prefill/decode-state programs, and the paged
per-tick update, flagging float64/complex128 leaves and weak-typed floats
(silent upcast fuel + retrace churn), and asserting the paged decode
returns its state with bit-identical dtypes (no tick-to-tick promotion
drift).

Donation (check ``donation``): the one check that builds a real (tiny)
engine -- it lowers the per-tick paged-decode update and the
commit-prefill bridge and asserts the input pool state is donated
(``donate_argnums``); without donation every tick holds two full KV
pools live.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp

from ..configs import base as config_base
from .contracts import ShapeOnlyMesh, _batch_struct, _params_struct

MODEL_DEGREES = (1, 2, 4, 8)

_B, _S, _SMAX = 2, 24, 48
_SLOTS, _BLOCK = 4, 8

# attention-projection leaves and which head count guards their "model" use
_Q_NAMES = ("wq", "bq", "wo")
_KV_NAMES = ("wk", "wv", "bk", "bv")


@dataclasses.dataclass(frozen=True)
class ShardFailure:
    arch: str
    check: str
    message: str

    def render(self) -> str:
        return f"{self.arch} [shardcheck:{self.check}]: {self.message}"


@dataclasses.dataclass(frozen=True)
class ShardcheckReport:
    covered: tuple            # (arch, check) pairs actually walked
    skipped: tuple            # (arch, check, reason)
    failures: tuple
    elapsed_s: float

    @property
    def ok(self) -> bool:
        return not self.failures


def _axes_of(entry) -> tuple:
    if entry is None:
        return ()
    return (entry,) if isinstance(entry, str) else tuple(entry)


def _spec_axes(spec) -> set:
    out: set = set()
    for entry in tuple(spec):
        out.update(_axes_of(entry))
    return out


def _leaf_name(pstr: str) -> str:
    return pstr.rsplit("/", 1)[-1]


# ---------------------------------------------------------------------------
# param specs
# ---------------------------------------------------------------------------

def _check_param_specs(cfg, params, mesh, m: int, failures: list):
    from ..launch import sharding
    arch = cfg.name
    leaves = jax.tree_util.tree_flatten_with_path(params)[0]
    for path, leaf in leaves:
        pstr = sharding._path_str(path)
        spec = sharding.param_spec(mesh, cfg, pstr, leaf.shape)
        for err in sharding.validate_spec(mesh, leaf.shape, spec):
            failures.append(ShardFailure(
                arch, "spec", f"model={m} {pstr}: {err}"))
        # head-granular TP: "model" on an attention projection is only
        # legal when the head count divides the degree -- flat-dim
        # divisibility alone would split a head across shards
        name = _leaf_name(pstr)
        if "model" in _spec_axes(spec):
            heads = None
            if name in _Q_NAMES and len(leaf.shape) <= 3:
                heads = cfg.n_heads
            elif name in _KV_NAMES:
                heads = cfg.n_kv or cfg.n_heads
            if heads is not None and heads % m:
                failures.append(ShardFailure(
                    arch, "kv-heads",
                    f"model={m} {pstr}: spec {spec} splits {heads} head(s) "
                    f"across a {m}-way model axis (head-granular TP "
                    f"contract; docs/serving.md)"))


# ---------------------------------------------------------------------------
# batch / cache specs
# ---------------------------------------------------------------------------

def _check_batch_specs(cfg, mesh, m: int, failures: list):
    from ..launch import sharding
    arch = cfg.name
    batch = _batch_struct(cfg, _B, _S)
    for pstr, leaf in sorted(
            (k, v) for k, v in batch.items()):
        spec = sharding.batch_spec(mesh, leaf)
        for err in sharding.validate_spec(mesh, leaf.shape, spec):
            failures.append(ShardFailure(
                arch, "batch", f"model={m} {pstr}: {err}"))
        if "model" in _spec_axes(spec):
            failures.append(ShardFailure(
                arch, "batch",
                f"model={m} {pstr}: batch inputs replicate across the "
                f"model axis (got {spec})"))


def _kv_dim_axes(leaf_ndim: int, spec) -> tuple:
    """Axes on the kv-head dim (index -2) of a (…, S-or-block, KV, hd)
    leaf, given specs are leading-aligned."""
    entries = tuple(spec)
    kv_dim = leaf_ndim - 2
    if kv_dim < len(entries):
        return _axes_of(entries[kv_dim])
    return ()


def _check_cache_specs(cfg, cache, mesh, m: int, failures: list) -> dict:
    """Validate prefill-cache specs; returns {path: kv-dim-sharded?} for
    the consistency check."""
    from ..launch import sharding
    arch = cfg.name
    kv_sharded: dict[str, bool] = {}
    leaves = jax.tree_util.tree_flatten_with_path(cache)[0]
    for path, leaf in leaves:
        pstr = sharding._path_str(path)
        spec = sharding.cache_spec(mesh, path, leaf, _B)
        for err in sharding.validate_spec(mesh, leaf.shape, spec):
            failures.append(ShardFailure(
                arch, "cache", f"model={m} {pstr}: {err}"))
        name = _leaf_name(pstr)
        if name in ("k", "v") and leaf.ndim >= 4:
            kv_axes = _kv_dim_axes(leaf.ndim, spec)
            kv_sharded[pstr] = "model" in kv_axes
            if "model" in kv_axes and leaf.shape[-2] % m:
                failures.append(ShardFailure(
                    arch, "cache",
                    f"model={m} {pstr}: kv-head dim {leaf.shape[-2]} "
                    f"split {m} ways"))
        elif "model" in _spec_axes(spec):
            failures.append(ShardFailure(
                arch, "cache",
                f"model={m} {pstr}: non-KV cache leaf shards over "
                f"'model' (got {spec})"))
    return kv_sharded


# ---------------------------------------------------------------------------
# paged-pool specs + prefill/pool consistency
# ---------------------------------------------------------------------------

def _check_pool_specs(cfg, state, mesh, m: int,
                      cache_kv: dict, failures: list):
    from ..launch import sharding
    from ..serving import kvpool
    arch = cfg.name
    for pstr, shape, spec in kvpool.decode_state_specs(mesh, state):
        for err in sharding.validate_spec(mesh, shape, spec):
            failures.append(ShardFailure(
                arch, "pool", f"model={m} {pstr}: {err}"))
        name = _leaf_name(pstr)
        axes_used = _spec_axes(spec)
        if name in ("k", "v") and len(shape) >= 4:
            kv_axes = _kv_dim_axes(len(shape), spec)
            bad = axes_used - set(kv_axes)
            if bad:
                failures.append(ShardFailure(
                    arch, "pool",
                    f"model={m} {pstr}: pool KV leaf shards non-kv-head "
                    f"dim(s) over {sorted(bad)} -- the block axis must "
                    f"stay whole (block tables index it on every shard)"))
            if "model" in kv_axes and shape[-2] % m:
                failures.append(ShardFailure(
                    arch, "pool",
                    f"model={m} {pstr}: kv-head dim {shape[-2]} split "
                    f"{m} ways"))
            # consistency with the prefill cache policy: commit_prefill
            # copies solo-prefill KV into the pool every admission; the
            # two policies disagreeing on the kv-head dim means a
            # reshard per admitted request
            want = cache_kv.get(pstr)
            got = "model" in kv_axes
            if want is not None and want != got:
                failures.append(ShardFailure(
                    arch, "consistency",
                    f"model={m} {pstr}: prefill cache "
                    f"{'shards' if want else 'replicates'} the kv-head "
                    f"dim but the paged pool "
                    f"{'shards' if got else 'replicates'} it -- "
                    f"commit_prefill reshards every admission"))
        elif axes_used:
            failures.append(ShardFailure(
                arch, "pool",
                f"model={m} {pstr}: non-KV pool leaf (bookkeeping / "
                f"recurrent state) must replicate, got {spec}"))


# ---------------------------------------------------------------------------
# dtype flow
# ---------------------------------------------------------------------------

_BAD_DTYPES = ("float64", "complex128")


def dtype_failures(tree, *, arch: str, what: str,
                   check: str = "dtype") -> list[ShardFailure]:
    """Flag f64/complex128 leaves and weak-typed floats anywhere in an
    ``eval_shape`` (or concrete) pytree."""
    failures: list[ShardFailure] = []
    from ..launch.sharding import _path_str
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        dt = jnp.dtype(leaf.dtype)
        pstr = _path_str(path)
        if dt.name in _BAD_DTYPES:
            failures.append(ShardFailure(
                arch, check,
                f"{what}/{pstr}: dtype {dt.name} (silent x64 promotion; "
                f"the stack is f32-sized end to end)"))
        if (getattr(leaf, "weak_type", False)
                and jnp.issubdtype(dt, jnp.floating)):
            failures.append(ShardFailure(
                arch, check,
                f"{what}/{pstr}: weak-typed {dt.name} leaf (promotes on "
                f"contact with narrower dtypes and retraces per weakness "
                f"pattern)"))
    return failures


def _check_dtype_flow(cfg, params, cache, state, failures: list):
    """Prefill cache, pool state, and the per-tick paged update must hold
    strong f32/int32 dtypes, and the paged update must return its state
    bit-identically typed (no promotion drift tick to tick)."""
    from ..models import transformer
    arch = cfg.name
    failures.extend(dtype_failures(cache, arch=arch, what="prefill-cache"))
    if state is None:
        return
    failures.extend(dtype_failures(state, arch=arch, what="pool-state"))
    table = jax.ShapeDtypeStruct((_SLOTS, -(-_SMAX // _BLOCK)), jnp.int32)
    lens = jax.ShapeDtypeStruct((_SLOTS,), jnp.int32)
    toks = jax.ShapeDtypeStruct((_SLOTS,), jnp.int32)
    logits, state2 = jax.eval_shape(
        lambda p, st, t, bt, sl: transformer.decode_step_paged(
            p, cfg, st, t, bt, sl),
        params, state, toks, table, lens)
    failures.extend(dtype_failures(logits, arch=arch, what="paged-logits"))
    in_leaves = jax.tree.leaves(state)
    out_leaves = jax.tree.leaves(state2)
    for i, (a, b) in enumerate(zip(in_leaves, out_leaves)):
        if a.dtype != b.dtype:
            failures.append(ShardFailure(
                arch, "dtype",
                f"paged decode promotes state leaf {i}: "
                f"{a.dtype} -> {b.dtype} (tick-to-tick drift; a weak "
                f"scalar in the update path?)"))


def mec_params_dtype_failures() -> list[ShardFailure]:
    """MecParams (the scenario-side pytree every rollout threads) must be
    f32/int32 throughout -- one f64 leaf doubles every cell's state and
    desyncs the jitted rollout dtype contract."""
    from ..core import scenarios
    params = scenarios.make("fixed_rate", rate=1.0).params()
    return dtype_failures(params, arch="mec-params", what="MecParams")


# ---------------------------------------------------------------------------
# donation probe
# ---------------------------------------------------------------------------

def donation_failures(fn, args, *, arch: str, what: str,
                      argnum: int = 0) -> list[ShardFailure]:
    """Lower a jitted callable with the given args and assert every array
    in ``args[argnum]`` is donated.  Traces only (no compile, no
    execute)."""
    failures: list[ShardFailure] = []
    try:
        lowered = fn.lower(*args)
    except AttributeError:
        return [ShardFailure(
            arch, "donation",
            f"{what}: not introspectable (no .lower -- wrapped "
            f"non-jit callable?)")]
    arg_info = lowered.args_info[0][argnum]
    not_donated = [i for i, leaf in enumerate(jax.tree.leaves(arg_info))
                   if not leaf.donated]
    if not_donated:
        failures.append(ShardFailure(
            arch, "donation",
            f"{what}: {len(not_donated)} state leaf/leaves not donated "
            f"(donate_argnums missing?) -- every tick holds two full KV "
            f"pools live"))
    return failures


def _check_donation(arch: str = "qwen3-0.6b") -> list[ShardFailure]:
    """Build ONE tiny real engine and verify its per-tick decode update
    and commit bridge donate their input pool state."""
    from ..configs.base import get_config, reduced
    from ..models import transformer
    from ..serving.engine import Request, ServingEngine

    import numpy as np
    cfg = reduced(get_config(arch), n_layers=1)
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(cfg, params, slots=2, s_max=32)
    failures = donation_failures(
        eng._decode_paged,
        (eng._pool_state, jnp.zeros((eng.slots,), jnp.int32),
         jnp.asarray(eng.block_tables), jnp.asarray(eng.seq_lens)),
        arch=cfg.name, what="decode_step_paged tick update")
    # the commit bridge: same donation contract on its state argument
    req = Request(rid=0, prompt=np.arange(5, dtype=np.int32), max_new=2)
    _, cache, pad = eng._solo_prefill(req)
    solo = {"units": cache["units"], "tail": cache["tail"]}
    ids = jnp.zeros((1,), jnp.int32)
    failures += donation_failures(
        eng._commit,
        (eng._pool_state, solo, jnp.int32(pad), jnp.int32(0), ids),
        arch=cfg.name, what="commit_prefill admission bridge")
    # chunked-prefill commit: same contract (auto chunking is off at this
    # s_max, so ask for it explicitly)
    eng_c = ServingEngine(cfg, params, slots=2, s_max=32, prefill_chunk=8)
    ids_full = jnp.zeros((eng_c.table_width,), jnp.int32)
    failures += donation_failures(
        eng_c._commit_chunk,
        (eng_c._pool_state, solo, jnp.int32(0), jnp.int32(5), jnp.int32(0),
         ids_full),
        arch=cfg.name, what="commit_chunk streaming bridge")
    return failures


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

def run_shardcheck(arch_names=None, *, model_degrees=MODEL_DEGREES,
                   donation: bool = True,
                   verbose: bool = False) -> ShardcheckReport:
    from ..models import transformer
    from ..serving import kvpool

    configs = config_base.load_all()
    if arch_names:
        configs = {n: configs[n] for n in arch_names}
    t0 = time.perf_counter()
    failures: list[ShardFailure] = []
    covered: list[tuple[str, str]] = []
    skipped: list[tuple[str, str, str]] = []

    for name, cfg in sorted(configs.items()):
        t1 = time.perf_counter()
        try:
            params = _params_struct(cfg)
        except Exception as e:
            failures.append(ShardFailure(name, "init", repr(e)))
            continue
        # one trace each for the prefill cache and (plain decoders) the pool
        try:
            batch = _batch_struct(cfg, _B, _S)
            _, cache = jax.eval_shape(
                lambda p, b: transformer.prefill(p, cfg, b, s_max=_SMAX),
                params, batch)
        except Exception as e:
            failures.append(ShardFailure(name, "cache-trace", repr(e)))
            continue
        state = None
        try:
            kvpool._check_pattern(cfg)
            n_blocks = _SLOTS * (_SMAX // _BLOCK) + 1
            state = jax.eval_shape(
                lambda p: kvpool.init_decode_state(cfg, p, _SLOTS, n_blocks,
                                                   _BLOCK),
                params)
        except ValueError as e:
            skipped.append((name, "pool", str(e).split(";")[0]))

        for m in model_degrees:
            mesh = ShapeOnlyMesh(cells=1, model=m)
            _check_param_specs(cfg, params, mesh, m, failures)
            _check_batch_specs(cfg, mesh, m, failures)
            cache_kv = _check_cache_specs(cfg, cache, mesh, m, failures)
            if state is not None:
                _check_pool_specs(cfg, state, mesh, m, cache_kv, failures)
        covered.extend((name, c) for c in ("spec", "batch", "cache"))
        if state is not None:
            covered.extend((name, c) for c in ("pool", "consistency"))
        _check_dtype_flow(cfg, params, cache, state, failures)
        covered.append((name, "dtype"))
        if verbose:
            print(f"  {name}: {time.perf_counter() - t1:.2f}s")

    failures.extend(mec_params_dtype_failures())
    covered.append(("mec-params", "dtype"))
    if donation:
        failures.extend(_check_donation())
        covered.append(("qwen3-0.6b", "donation"))
    else:
        skipped.append(("qwen3-0.6b", "donation", "disabled by caller"))
    return ShardcheckReport(covered=tuple(covered), skipped=tuple(skipped),
                            failures=tuple(failures),
                            elapsed_s=time.perf_counter() - t0)
