"""Finding records, suppression comments, and the grandfather baseline.

A :class:`Finding` is one rule hit at one source location.  Its
``fingerprint`` hashes (repo-relative path, rule name, *stripped source
line*) rather than the line number, so baselined findings survive edits
that merely shift code up or down -- the classic "baseline churn" failure
of line-keyed lint baselines.

Suppressions are in-source: a ``# reprolint: ignore[rule-a,rule-b]``
comment on the offending line (or a bare ``# reprolint: ignore`` for all
rules) silences that line.  The baseline is a checked-in JSON file
(``analysis_baseline.json`` at the repo root) of fingerprints with
human-written justification notes; ``python -m repro.analysis
--write-baseline`` regenerates it from the current findings.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import re

_SUPPRESS_RE = re.compile(
    r"#\s*reprolint:\s*ignore(?:\[([A-Za-z0-9_,\- ]+)\])?")

# The note ``--write-baseline`` stamps on every grandfathered entry.  A
# baseline entry is only legitimate once a human replaces this with an
# actual justification; ``--check`` fails on any entry still carrying it.
PLACEHOLDER_NOTE = "TODO: justify or fix (see docs/analysis.md)"


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str
    path: str                   # repo-relative, posix separators
    line: int                   # 1-based
    col: int
    message: str
    snippet: str = ""           # stripped source line (fingerprint input)

    @property
    def fingerprint(self) -> str:
        raw = f"{self.path}::{self.rule}::{self.snippet}"
        return hashlib.sha1(raw.encode()).hexdigest()[:16]

    def render(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: [{self.rule}] {self.message}"


def suppressions(source_lines: list[str]) -> dict[int, set[str] | None]:
    """Map 1-based line number -> suppressed rule names (None == all)."""
    out: dict[int, set[str] | None] = {}
    for i, text in enumerate(source_lines, start=1):
        m = _SUPPRESS_RE.search(text)
        if not m:
            continue
        if m.group(1) is None:
            out[i] = None
        else:
            out[i] = {r.strip() for r in m.group(1).split(",") if r.strip()}
    return out


def is_suppressed(finding: Finding,
                  supp: dict[int, set[str] | None]) -> bool:
    rules = supp.get(finding.line, ())
    return rules is None or finding.rule in rules


# ---------------------------------------------------------------------------
# baseline file
# ---------------------------------------------------------------------------

def load_baseline(path) -> dict[str, dict]:
    """Fingerprint -> entry.  Missing file == empty baseline."""
    try:
        with open(path) as f:
            data = json.load(f)
    except FileNotFoundError:
        return {}
    if data.get("version") != 1:
        raise ValueError(f"unsupported baseline version in {path}: "
                         f"{data.get('version')!r}")
    return {e["fingerprint"]: e for e in data.get("findings", [])}


def write_baseline(path, findings: list[Finding]) -> None:
    entries = []
    seen = set()
    for f in sorted(findings, key=lambda f: (f.path, f.line)):
        if f.fingerprint in seen:
            continue
        seen.add(f.fingerprint)
        entries.append({
            "fingerprint": f.fingerprint,
            "rule": f.rule,
            "path": f.path,
            "snippet": f.snippet,
            "note": PLACEHOLDER_NOTE,
        })
    with open(path, "w") as fh:
        json.dump({"version": 1, "findings": entries}, fh, indent=2)
        fh.write("\n")


def placeholder_entries(baseline: dict[str, dict]) -> list[dict]:
    """Baseline entries nobody ever justified: the note is still the
    ``--write-baseline`` placeholder (or blank).  A baseline is a debt
    ledger, not an amnesty -- ``--check`` fails on these."""
    stale = [e for e in baseline.values()
             if str(e.get("note", "")).strip() in ("", PLACEHOLDER_NOTE)]
    return sorted(stale, key=lambda e: (e.get("path", ""), e.get("rule", "")))


def split_baselined(findings: list[Finding], baseline: dict[str, dict]):
    """Partition into (new, grandfathered) against the baseline."""
    new, old = [], []
    for f in findings:
        (old if f.fingerprint in baseline else new).append(f)
    return new, old
