"""Layer 2: abstract-interpretation contract harness.

``jax.eval_shape`` traces every registered arch config through every
serving path -- prefill, decode, paged decode, ragged prefill+decode,
chunked prefill (the streaming-admission step + its incremental pool
commit) -- without allocating a single parameter or running any numerics,
so the whole registry's shape/dtype contracts check in seconds on CPU.  A
further leg sweeps the tensor-parallel ``param_spec`` policy over degrees
{1, 2, 4, 8} on a shape-only stand-in mesh and verifies every sharded
dimension actually divides (the head-splitting bug class PR 5 fixed).

``run_contracts()`` returns a list of :class:`ContractFailure`; empty
means the registry is clean.  The paged leg skips archs the paged pool
rejects by contract (cross-attention / encoder-decoder stacks serve via
``sync_batching=True``) and records the skip reason instead of faking
coverage.
"""
from __future__ import annotations

import dataclasses
import math
import time

import jax
import jax.numpy as jnp

from ..configs import base as config_base

PATHS = ("prefill", "decode", "paged", "ragged", "chunked", "pspec")
MODEL_DEGREES = (1, 2, 4, 8)

_B, _S, _SMAX = 2, 24, 48              # batch, prompt width, cache budget
_SLOTS, _BLOCK = 4, 8                  # paged-pool geometry


@dataclasses.dataclass(frozen=True)
class ContractFailure:
    arch: str
    path: str
    message: str

    def render(self) -> str:
        return f"{self.arch} [{self.path}]: {self.message}"


@dataclasses.dataclass(frozen=True)
class ContractReport:
    covered: tuple            # (arch, path) pairs actually traced
    skipped: tuple            # (arch, path, reason)
    failures: tuple
    elapsed_s: float

    @property
    def ok(self) -> bool:
        return not self.failures


class ShapeOnlyMesh:
    """Stand-in mesh for ``param_spec``: the sharding policy only reads
    ``axis_names`` and ``shape``, so pspec divisibility checks need no
    devices at all."""

    def __init__(self, **axes: int):
        self.axis_names = tuple(axes)
        self.shape = dict(axes)


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _batch_struct(cfg, batch: int, width: int):
    out = {"tokens": _sds((batch, width), jnp.int32)}
    if cfg.frontend == "vision":
        out["image_embeds"] = _sds((batch, 8, cfg.d_model), jnp.float32)
    if cfg.enc_layers:
        out["src_embeds"] = _sds((batch, 16, cfg.d_model), jnp.float32)
    return out


def _params_struct(cfg):
    from ..models import transformer
    key = _sds((2,), jnp.uint32)
    return jax.eval_shape(lambda k: transformer.init_params(k, cfg), key)


def _expect_logits(got, batch: int, vocab: int, arch: str, path: str,
                   failures: list):
    if tuple(got.shape) != (batch, vocab):
        failures.append(ContractFailure(
            arch, path, f"logits shape {tuple(got.shape)} != "
                        f"({batch}, {vocab})"))
    if got.dtype != jnp.float32:
        failures.append(ContractFailure(
            arch, path, f"logits dtype {got.dtype} != float32 (serving "
                        f"contract: fp32 logits regardless of "
                        f"compute_dtype)"))


def _check_model_paths(cfg, params, failures: list) -> list[str]:
    """prefill / decode / ragged / paged legs for one arch.  Returns the
    list of (path, reason) skips."""
    from ..models import transformer
    from ..serving import kvpool
    arch = cfg.name
    skips: list[tuple[str, str]] = []

    # -- prefill (dense) + decode ------------------------------------------
    batch = _batch_struct(cfg, _B, _S)
    logits, cache = jax.eval_shape(
        lambda p, b: transformer.prefill(p, cfg, b, s_max=_SMAX),
        params, batch)
    _expect_logits(logits, _B, cfg.vocab, arch, "prefill", failures)
    toks = _sds((_B,), jnp.int32)
    logits_d, _ = jax.eval_shape(
        lambda p, c, t: transformer.decode_step(p, cfg, c, t),
        params, cache, toks)
    _expect_logits(logits_d, _B, cfg.vocab, arch, "decode", failures)

    # -- ragged prefill + decode (left-pad vector rides in the cache) ------
    pad = _sds((_B,), jnp.int32)
    logits_r, cache_r = jax.eval_shape(
        lambda p, b, pd: transformer.prefill(p, cfg, b, s_max=_SMAX, pad=pd),
        params, batch, pad)
    _expect_logits(logits_r, _B, cfg.vocab, arch, "ragged", failures)
    jax.eval_shape(lambda p, c, t: transformer.decode_step(p, cfg, c, t),
                   params, cache_r, toks)

    # -- paged decode + the commit_prefill admission bridge ----------------
    try:
        kvpool._check_pattern(cfg)
    except ValueError as e:
        reason = str(e).split(";")[0]
        skips.append(("paged", reason))
        skips.append(("chunked", reason))
        return skips
    n_blocks = _SLOTS * (_SMAX // _BLOCK) + 1
    state = jax.eval_shape(
        lambda p: kvpool.init_decode_state(cfg, p, _SLOTS, n_blocks, _BLOCK),
        params)
    table = _sds((_SLOTS, -(-_SMAX // _BLOCK)), jnp.int32)
    lens = _sds((_SLOTS,), jnp.int32)
    toks_s = _sds((_SLOTS,), jnp.int32)
    logits_p, state2 = jax.eval_shape(
        lambda p, st, t, bt, sl: transformer.decode_step_paged(
            p, cfg, st, t, bt, sl),
        params, state, toks_s, table, lens)
    _expect_logits(logits_p, _SLOTS, cfg.vocab, arch, "paged", failures)
    if jax.tree.structure(state2) != jax.tree.structure(state):
        failures.append(ContractFailure(
            arch, "paged", "decode_step_paged changed the pool-state "
                           "treedef (engine threads it tick to tick)"))

    # admission: a solo (batch-1) bucketed prefill commits into the pool
    solo_batch = _batch_struct(cfg, 1, 16)
    _, solo = jax.eval_shape(
        lambda p, b, pd: transformer.prefill(p, cfg, b, s_max=16, pad=pd),
        params, solo_batch, _sds((1,), jnp.int32))
    solo_core = {"units": solo["units"], "tail": solo["tail"]}
    ids = _sds((-(-16 // _BLOCK),), jnp.int32)
    scalar = _sds((), jnp.int32)
    committed = jax.eval_shape(
        lambda st, so, pd, sl, bi: kvpool.commit_prefill(
            st, so, pd, sl, bi, block_size=_BLOCK),
        state, solo_core, scalar, scalar, ids)
    if jax.tree.structure(committed) != jax.tree.structure(state):
        failures.append(ContractFailure(
            arch, "paged", "commit_prefill changed the pool-state treedef"))

    # -- chunked prefill (streaming admission) -----------------------------
    # traced-scalar start/n_valid: the engine compiles ONE chunk-step and
    # ONE chunk-commit program regardless of the chunk index
    if "m" in (*cfg.block_pattern, *cfg.tail_pattern):
        skips.append(("chunked", "MoE capacity routing couples tokens "
                                 "across a dispatch group; the engine falls "
                                 "back to whole-prompt prefill"))
        return skips
    chunk_toks = _sds((1, _BLOCK), jnp.int32)
    logits_c, cache_c = jax.eval_shape(
        lambda p, cc, t, s, nv: transformer.prefill_chunk(p, cfg, cc, t,
                                                          s, nv),
        params, solo_core, chunk_toks, scalar, scalar)
    _expect_logits(logits_c, 1, cfg.vocab, arch, "chunked", failures)
    if jax.tree.structure(cache_c) != jax.tree.structure(solo_core):
        failures.append(ContractFailure(
            arch, "chunked", "prefill_chunk changed the stream-cache "
                             "treedef (the engine threads it chunk to "
                             "chunk)"))
    ids_full = _sds((-(-_SMAX // _BLOCK),), jnp.int32)
    committed_c = jax.eval_shape(
        lambda st, so, s, nv, sl, bi: kvpool.commit_chunk(
            st, so, s, nv, sl, bi, block_size=_BLOCK),
        state, solo_core, scalar, scalar, scalar, ids_full)
    if jax.tree.structure(committed_c) != jax.tree.structure(state):
        failures.append(ContractFailure(
            arch, "chunked", "commit_chunk changed the pool-state treedef"))
    return skips


def _check_pspecs(cfg, params, failures: list):
    """Every param leaf x every model degree: named axes must divide."""
    from ..launch.sharding import _path_str, param_spec
    arch = cfg.name
    leaves = jax.tree_util.tree_flatten_with_path(params)[0]
    for m in MODEL_DEGREES:
        mesh = ShapeOnlyMesh(cells=1, model=m)
        for path, leaf in leaves:
            pstr = _path_str(path)
            spec = param_spec(mesh, cfg, pstr, leaf.shape)
            for dim, axes in enumerate(tuple(spec)):
                if axes is None:
                    continue
                names = axes if isinstance(axes, tuple) else (axes,)
                total = math.prod(mesh.shape[a] for a in names)
                if dim >= len(leaf.shape) or leaf.shape[dim] % total:
                    failures.append(ContractFailure(
                        arch, "pspec",
                        f"{pstr}: dim {dim} of shape {tuple(leaf.shape)} "
                        f"not divisible by {names}={total} (model={m})"))


def run_contracts(arch_names=None, *, verbose: bool = False) -> ContractReport:
    configs = config_base.load_all()
    if arch_names:
        configs = {n: configs[n] for n in arch_names}
    t0 = time.perf_counter()
    failures: list[ContractFailure] = []
    covered: list[tuple[str, str]] = []
    skipped: list[tuple[str, str, str]] = []
    for name, cfg in sorted(configs.items()):
        t1 = time.perf_counter()
        try:
            params = _params_struct(cfg)
        except Exception as e:           # an arch that cannot even build
            failures.append(ContractFailure(name, "init", repr(e)))
            continue
        try:
            skips = _check_model_paths(cfg, params, failures)
        except Exception as e:
            failures.append(ContractFailure(name, "trace", repr(e)))
            skips = []
        skip_paths = {p for p, _ in skips}
        covered.extend((name, p) for p in ("prefill", "decode", "ragged"))
        covered.extend((name, p) for p in ("paged", "chunked")
                       if p not in skip_paths)
        skipped.extend((name, p, why) for p, why in skips)
        try:
            _check_pspecs(cfg, params, failures)
            covered.append((name, "pspec"))
        except Exception as e:
            failures.append(ContractFailure(name, "pspec", repr(e)))
        if verbose:
            print(f"  {name}: {time.perf_counter() - t1:.2f}s")
    return ContractReport(covered=tuple(covered), skipped=tuple(skipped),
                          failures=tuple(failures),
                          elapsed_s=time.perf_counter() - t0)
