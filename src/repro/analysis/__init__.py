"""Static-analysis subsystem: reprolint (AST rules) + contract harness.

Layer 1 -- :mod:`repro.analysis.rules` / :mod:`repro.analysis.linter` --
lints the shipping tree for the JAX failure modes this codebase hits
(PRNG key reuse, tracer branching, recompile hazards, hot-loop host
syncs, raw-kernel imports).  Layer 2 -- :mod:`repro.analysis.contracts` /
:mod:`repro.analysis.retrace` -- checks the whole config registry's
shape/dtype/pspec contracts with ``jax.eval_shape`` and pins compile
counts for steady-state serving and grid rollouts.

CLI: ``python -m repro.analysis --check`` (the CI gate); see
docs/analysis.md.
"""
from .findings import Finding
from .linter import lint_paths, lint_source

__all__ = ["Finding", "lint_paths", "lint_source"]
