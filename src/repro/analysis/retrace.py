"""Retrace counter: assert steady-state programs compile a bounded number
of times.

Three probes, all on smoke-size configs so the whole check stays
CPU-cheap:

* **Serving**: drive a continuous-batching :class:`ServingEngine` through
  two waves of mixed-length prompts.  Wave one may compile (one prefill
  per touched bucket, one paged decode, one commit per bucket); wave two
  must compile NOTHING -- ``prefill_compiles`` stays flat and the paged
  decode jit cache stays at one entry.

* **Chunked prefill**: the same engine shape with a small
  ``prefill_chunk`` and prompts long enough to stream.  The chunk index
  rides as a TRACED scalar, so the chunk-step and chunk-commit jits must
  each hold exactly ONE compiled program no matter how many chunks or
  prompt lengths the waves push through.

* **ScenarioGrid rollouts**: a jitted ``make_rollout`` program invoked
  with three different keys must hold exactly one cache entry (keys are
  data, not shape).

Both rely on ``jax.jit``'s ``_cache_size()`` introspection; if a future
jax drops it the probes report a skip rather than a false pass.
"""
from __future__ import annotations

import dataclasses

import numpy as np

import jax


@dataclasses.dataclass(frozen=True)
class RetraceFailure:
    probe: str
    message: str

    def render(self) -> str:
        return f"{self.probe}: {self.message}"


def _cache_size(fn) -> int | None:
    try:
        return fn._cache_size()
    except AttributeError:
        return None


def serving_retraces(arch: str = "qwen3-0.6b") -> list[RetraceFailure]:
    from ..configs.base import get_config, reduced
    from ..models import transformer
    from ..serving.engine import Request, ServingEngine

    failures: list[RetraceFailure] = []
    cfg = reduced(get_config(arch))
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(cfg, params, slots=2, s_max=64)
    rng = np.random.default_rng(0)

    def wave(lengths, base_rid):
        for i, n in enumerate(lengths):
            eng.submit(Request(
                rid=base_rid + i,
                prompt=rng.integers(0, cfg.vocab, n).astype(np.int32),
                max_new=4))
        eng.run_until_idle()

    wave([5, 9, 17, 12, 3], 0)           # buckets 8, 16, 32 (all ragged)
    first = eng.prefill_compiles
    buckets_touched = 3
    if first > buckets_touched:
        failures.append(RetraceFailure(
            "serving", f"wave 1 compiled {first} prefill signatures for "
                       f"{buckets_touched} buckets"))
    wave([6, 11, 20, 4, 13], 100)        # same buckets, new lengths
    if eng.prefill_compiles != first:
        failures.append(RetraceFailure(
            "serving", f"steady state recompiled prefill: "
                       f"{first} -> {eng.prefill_compiles} signatures on "
                       f"identical buckets"))
    for name in ("_decode_paged", "_commit"):
        size = _cache_size(getattr(eng, name))
        if size is None:
            failures.append(RetraceFailure(
                "serving", f"jit cache introspection unavailable for "
                           f"{name} (jax dropped _cache_size?)"))
        elif name == "_decode_paged" and size != 1:
            failures.append(RetraceFailure(
                "serving", f"paged decode holds {size} compiled programs; "
                           f"steady state must hold exactly 1"))
        elif name == "_commit" and size > buckets_touched:
            failures.append(RetraceFailure(
                "serving", f"commit holds {size} compiled programs for "
                           f"{buckets_touched} buckets"))
    return failures


def chunked_retraces(arch: str = "qwen3-0.6b") -> list[RetraceFailure]:
    from ..configs.base import get_config, reduced
    from ..models import transformer
    from ..serving.engine import Request, ServingEngine

    failures: list[RetraceFailure] = []
    cfg = reduced(get_config(arch))
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(cfg, params, slots=2, s_max=64, prefill_chunk=16)
    rng = np.random.default_rng(1)

    def wave(lengths, base_rid):
        for i, n in enumerate(lengths):
            eng.submit(Request(
                rid=base_rid + i,
                prompt=rng.integers(0, cfg.vocab, n).astype(np.int32),
                max_new=4))
        eng.run_until_idle()

    wave([40, 20, 7], 0)                 # 40 and 20 stream; 7 prefills whole
    first = eng.prefill_compiles
    wave([45, 18, 6], 100)               # new lengths, same programs
    if eng.prefill_compiles != first:
        failures.append(RetraceFailure(
            "chunked", f"steady state recompiled prefill: {first} -> "
                       f"{eng.prefill_compiles} signatures on identical "
                       f"chunk/bucket shapes"))
    for name in ("_chunk_step", "_commit_chunk"):
        size = _cache_size(getattr(eng, name))
        if size is None:
            failures.append(RetraceFailure(
                "chunked", f"jit cache introspection unavailable for "
                           f"{name} (jax dropped _cache_size?)"))
        elif size != 1:
            failures.append(RetraceFailure(
                "chunked", f"{name} holds {size} compiled programs; the "
                           f"traced chunk cursor must keep it at exactly "
                           f"1"))
    return failures


def rollout_retraces() -> list[RetraceFailure]:
    from ..core.scenarios import grid_from_names

    failures: list[RetraceFailure] = []
    grid = grid_from_names([("fixed_rate", {"rate": 0.5}),
                            ("fixed_rate", {"rate": 1.0}),
                            ("fixed_rate", {"rate": 2.5})])
    fn = grid.make_rollout("oracle", steps=4)
    key = jax.random.PRNGKey(0)
    for i in range(3):
        jax.block_until_ready(fn(jax.random.fold_in(key, i)))
    size = _cache_size(fn)
    if size is None:
        failures.append(RetraceFailure(
            "rollout", "jit cache introspection unavailable "
                       "(jax dropped _cache_size?)"))
    elif size != 1:
        failures.append(RetraceFailure(
            "rollout", f"ScenarioGrid rollout holds {size} compiled "
                       f"programs after 3 same-shape calls; keys are data, "
                       f"not shape -- expected exactly 1"))
    return failures


def run_retrace() -> list[RetraceFailure]:
    return serving_retraces() + chunked_retraces() + rollout_retraces()
