"""reprolint driver: walk the linted tree, run rules, apply suppressions
and the baseline.

The linted surface is everything that ships behavior -- ``src/repro``,
``benchmarks``, ``scripts``, ``examples`` -- but not ``tests/`` (tests
intentionally poke failure modes the rules exist to flag).
"""
from __future__ import annotations

import ast
import pathlib

from . import findings as F
from .rules import RULES, FileContext

DEFAULT_PATHS = ("src/repro", "benchmarks", "scripts", "examples")
BASELINE_NAME = "analysis_baseline.json"


def repo_root() -> pathlib.Path:
    """The repository root: three levels up from this package
    (src/repro/analysis -> repo)."""
    return pathlib.Path(__file__).resolve().parents[3]


def iter_py_files(paths, root: pathlib.Path):
    for p in paths:
        p = (root / p) if not pathlib.Path(p).is_absolute() \
            else pathlib.Path(p)
        if p.is_file() and p.suffix == ".py":
            yield p
        elif p.is_dir():
            yield from sorted(p.rglob("*.py"))


def lint_source(source: str, path: str,
                rules=None) -> list[F.Finding]:
    """Lint one source string; ``path`` is the repo-relative label.
    Suppression comments apply; the baseline does not (caller's job)."""
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as e:
        return [F.Finding(rule="parse-error", path=path,
                          line=e.lineno or 1, col=e.offset or 1,
                          message=f"syntax error: {e.msg}")]
    lines = source.splitlines()
    ctx = FileContext(path=path, source_lines=lines, tree=tree)
    supp = F.suppressions(lines)
    out: list[F.Finding] = []
    for rule in (rules or RULES.values()):
        for f in rule.check(ctx):
            if not F.is_suppressed(f, supp):
                out.append(f)
    out.sort(key=lambda f: (f.line, f.col, f.rule))
    return out


def lint_paths(paths=DEFAULT_PATHS, root=None,
               rules=None) -> list[F.Finding]:
    root = pathlib.Path(root) if root else repo_root()
    selected = None
    if rules:
        selected = [RULES[name] for name in rules]
    out: list[F.Finding] = []
    for file in iter_py_files(paths, root):
        rel = file.relative_to(root).as_posix() \
            if file.is_relative_to(root) else file.as_posix()
        out.extend(lint_source(file.read_text(), rel, rules=selected))
    return out


def apply_baseline(found: list[F.Finding], root=None,
                   baseline_path=None):
    """Returns (new_findings, grandfathered, baseline_dict)."""
    root = pathlib.Path(root) if root else repo_root()
    path = pathlib.Path(baseline_path) if baseline_path \
        else root / BASELINE_NAME
    baseline = F.load_baseline(path)
    new, old = F.split_baselined(found, baseline)
    return new, old, baseline
