"""Pallas TPU kernel for the RG-LRU gated linear recurrence
h_t = a_t * h_{t-1} + x_t  (Griffin / RecurrentGemma).

Grid (B, nR, nS): channel tiles (lanes) x sequence chunks; the sequence dim
iterates last (sequentially) with the running h carried in VMEM scratch.
Within a chunk the recurrence is evaluated with a log2(chunk) Blelloch-style
doubling pass built from jnp.roll-shifted multiplies — O(Q log Q) lane-wise
VPU work instead of a Q-step serial loop, the TPU-friendly formulation of
the GPU kernel's warp scan (DESIGN §3).

Reset support: an optional (B, S) mask zeroes the carried state entering the
flagged steps (h_t = x_t there).  Zeroing a_t at reset positions expresses
this exactly inside the unchanged doubling scan — the zero annihilates every
cross-reset product, including the carried-state fold at chunk boundaries —
so left-padded serving rows cannot leak pad state into real tokens.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(*refs, nchunks: int, chunk: int, has_reset: bool):
    if has_reset:
        x_ref, a_ref, reset_ref, y_ref, h_ref = refs
    else:
        x_ref, a_ref, y_ref, h_ref = refs
        reset_ref = None
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0].astype(jnp.float32)     # (Q, R)
    a = a_ref[0].astype(jnp.float32)
    if reset_ref is not None:
        # a_t = 0 at reset steps: h_t = x_t, no history crosses the reset
        a = jnp.where(reset_ref[0] > 0, 0.0, a)     # (Q, 1) lane-broadcast

    # inclusive scan via logarithmic doubling:
    #   (A, X)_t <- (A_t * A_{t-2^k}, X_t + A_t * X_{t-2^k})
    acc_a, acc_x = a, x
    shift = 1
    while shift < chunk:
        rows = jax.lax.broadcasted_iota(jnp.int32, acc_a.shape, 0)
        valid = rows >= shift
        a_prev = jnp.where(valid, jnp.roll(acc_a, shift, axis=0), 1.0)
        x_prev = jnp.where(valid, jnp.roll(acc_x, shift, axis=0), 0.0)
        acc_x = acc_x + acc_a * x_prev
        acc_a = acc_a * a_prev
        shift *= 2

    # fold in the carried state: h_t = acc_x_t + acc_a_t * h_in
    h_in = h_ref[...]                    # (1, R)
    y = acc_x + acc_a * h_in
    y_ref[...] = y[None].astype(y_ref.dtype)
    h_ref[...] = y[chunk - 1:chunk, :]


def rglru_scan_pallas(x, a, *, reset=None, chunk: int = 256,
                      interpret: bool = False):
    """x, a: (B, S, R) -> h (B, S, R) with h_t = a_t h_{t-1} + x_t.
    ``reset`` (B, S) bool: True zeroes the state entering step t.
    S need not be a chunk multiple: the tail is right-padded with
    (a=0, x=0) no-op steps and the padded rows are sliced off."""
    b, s, r = x.shape
    chunk = min(chunk, s)
    tail = (-s) % chunk
    if tail:
        x = jnp.pad(x, ((0, 0), (0, tail), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, tail), (0, 0)))
        if reset is not None:
            reset = jnp.pad(reset, ((0, 0), (0, tail)))
        s += tail
    nchunks = s // chunk
    r_block = min(r, 512)
    assert r % r_block == 0
    nr = r // r_block

    seq_spec = lambda blk: pl.BlockSpec((1, chunk, blk),
                                        lambda b_, ir, ic: (b_, ic, ir))
    in_specs = [seq_spec(r_block), seq_spec(r_block)]
    operands = [x, a]
    if reset is not None:
        # (B, S, 1) f32 column; the kernel lane-broadcasts it over channels
        operands.append(reset.astype(jnp.float32)[..., None])
        in_specs.append(pl.BlockSpec((1, chunk, 1),
                                     lambda b_, ir, ic: (b_, ic, 0)))

    kernel = functools.partial(_kernel, nchunks=nchunks, chunk=chunk,
                               has_reset=reset is not None)
    h = pl.pallas_call(
        kernel,
        grid=(b, nr, nchunks),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, chunk, r_block),
                               lambda b_, ir, ic: (b_, ic, ir)),
        out_shape=jax.ShapeDtypeStruct((b, s, r), x.dtype),
        scratch_shapes=[pltpu.VMEM((1, r_block), jnp.float32)],
        interpret=interpret,
    )(*operands)
    return h[:, :s - tail] if tail else h
