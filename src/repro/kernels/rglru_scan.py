"""Pallas TPU kernel for the RG-LRU gated linear recurrence
h_t = a_t * h_{t-1} + x_t  (Griffin / RecurrentGemma).

Grid (B, nR, nS): channel tiles (lanes) x sequence chunks; the sequence dim
iterates last (sequentially) with the running h carried in VMEM scratch.
Within a chunk the recurrence is evaluated with a log2(chunk) Blelloch-style
doubling pass built from jnp.roll-shifted multiplies — O(Q log Q) lane-wise
VPU work instead of a Q-step serial loop, the TPU-friendly formulation of
the GPU kernel's warp scan (DESIGN §3).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, a_ref, y_ref, h_ref, *, nchunks: int, chunk: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0].astype(jnp.float32)     # (Q, R)
    a = a_ref[0].astype(jnp.float32)

    # inclusive scan via logarithmic doubling:
    #   (A, X)_t <- (A_t * A_{t-2^k}, X_t + A_t * X_{t-2^k})
    acc_a, acc_x = a, x
    shift = 1
    while shift < chunk:
        rows = jax.lax.broadcasted_iota(jnp.int32, acc_a.shape, 0)
        valid = rows >= shift
        a_prev = jnp.where(valid, jnp.roll(acc_a, shift, axis=0), 1.0)
        x_prev = jnp.where(valid, jnp.roll(acc_x, shift, axis=0), 0.0)
        acc_x = acc_x + acc_a * x_prev
        acc_a = acc_a * a_prev
        shift *= 2

    # fold in the carried state: h_t = acc_x_t + acc_a_t * h_in
    h_in = h_ref[...]                    # (1, R)
    y = acc_x + acc_a * h_in
    y_ref[...] = y[None].astype(y_ref.dtype)
    h_ref[...] = y[chunk - 1:chunk, :]


def rglru_scan_pallas(x, a, *, chunk: int = 256, interpret: bool = False):
    """x, a: (B, S, R) -> h (B, S, R) with h_t = a_t h_{t-1} + x_t."""
    b, s, r = x.shape
    chunk = min(chunk, s)
    assert s % chunk == 0
    nchunks = s // chunk
    r_block = min(r, 512)
    assert r % r_block == 0
    nr = r // r_block

    kernel = functools.partial(_kernel, nchunks=nchunks, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=(b, nr, nchunks),
        in_specs=[
            pl.BlockSpec((1, chunk, r_block), lambda b_, ir, ic: (b_, ic, ir)),
            pl.BlockSpec((1, chunk, r_block), lambda b_, ir, ic: (b_, ic, ir)),
        ],
        out_specs=pl.BlockSpec((1, chunk, r_block),
                               lambda b_, ir, ic: (b_, ic, ir)),
        out_shape=jax.ShapeDtypeStruct((b, s, r), x.dtype),
        scratch_shapes=[pltpu.VMEM((1, r_block), jnp.float32)],
        interpret=interpret,
    )(x, a)
