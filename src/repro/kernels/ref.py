"""Pure-jnp oracles for every kernel (the reference semantics the Pallas
kernels must reproduce; also the lowering path for the CPU dry-run)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

_NEG = -1e30


def attention_ref(q, k, v, mask=None):
    """GQA attention reference (dense scores; small shapes / kernel oracle).

    q: (B, Sq, H, hd); k, v: (B, Sk, KV, hd); mask (Sq, Sk) -- shared across
    the batch -- or (B, Sq, Sk) for per-row (ragged/padded) masking.
    Softmax in fp32; output in q.dtype; returns (B, Sq, H, hd).
    """
    b, sq, h, hd = q.shape
    kvh = k.shape[2]
    g = h // kvh                       # query heads per kv head
    qg = q.reshape(b, sq, kvh, g, hd)
    scale = 1.0 / jnp.sqrt(hd)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if mask is not None:
        m = (mask[:, None, None, :, :] if mask.ndim == 3
             else mask[None, None, None, :, :])
        scores = jnp.where(m, scores, _NEG)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", probs, v.astype(jnp.float32))
    return out.reshape(b, sq, h, hd).astype(q.dtype)


def build_mask(kind: str, sq: int, sk: int, window: int = 0):
    """Dense mask for the small-path / oracle.  kind: causal|local|full."""
    if kind == "full":
        return None
    qi = jnp.arange(sq)[:, None]
    kj = jnp.arange(sk)[None, :]
    if kind == "causal":
        return kj <= qi
    if kind == "local":
        return (kj <= qi) & (kj > qi - window)
    raise ValueError(kind)


def attention_blocked(q, k, v, *, kind: str, window: int = 0,
                      q_block: int = 0):
    """Memory-bounded attention: scan over query blocks.

    This is the lowering path for long sequences on every backend and the
    exact semantic blueprint of the Pallas flash kernel: scores materialize
    only as (B, KV, G, Qb, Sk') tiles.  "local" additionally slices a static
    (window + Qb)-wide K/V band per query block, so sliding-window layers
    execute band-linear FLOPs, not S^2 (DESIGN §6).

    Per-block computation is rematerialized in the backward pass
    (jax.checkpoint) so training memory stays O(S * d) + one tile.
    """
    b, s, h, hd = q.shape
    kvh = k.shape[2]
    g = h // kvh
    scale = 1.0 / jnp.sqrt(hd)
    if not q_block:
        # keep the live (B,KV,G,Qb,Sk) f32 score tile ~1 GB
        q_block = 512 if k.shape[1] < 16384 else 128
    qb = min(q_block, s)
    pad = (-s) % qb
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nq = q.shape[1] // qb
    qtiles = q.reshape(b, nq, qb, h, hd).transpose(1, 0, 2, 3, 4)

    sk = k.shape[1]
    k32 = k.astype(jnp.float32)
    v32 = v.astype(jnp.float32)

    use_band = kind == "local" and window > 0 and window + qb < sk
    band = min(window + qb, sk) if use_band else sk

    def block(i, qt):
        """One query tile: (B, qb, H, hd) against its K/V view."""
        q_pos = i * qb + jnp.arange(qb)
        if use_band:
            start = jnp.clip(i * qb - window, 0, sk - band)
            kt = jax.lax.dynamic_slice_in_dim(k32, start, band, axis=1)
            vt = jax.lax.dynamic_slice_in_dim(v32, start, band, axis=1)
            k_pos = start + jnp.arange(band)
        else:
            kt, vt = k32, v32
            k_pos = jnp.arange(sk)
        qg = qt.reshape(b, qb, kvh, g, hd)
        scores = jnp.einsum("bqkgh,bskh->bkgqs", qg.astype(jnp.float32),
                            kt) * scale
        if kind == "causal":
            m = k_pos[None, :] <= q_pos[:, None]
        elif kind == "local":
            m = ((k_pos[None, :] <= q_pos[:, None])
                 & (k_pos[None, :] > q_pos[:, None] - window))
        else:
            m = None
        if m is not None:
            scores = jnp.where(m[None, None, None], scores, _NEG)
        probs = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bkgqs,bskh->bqkgh", probs, vt)
        return out.reshape(b, qb, h, hd).astype(q.dtype)

    block = jax.checkpoint(block)

    def body(_, inp):
        i, qt = inp
        return None, block(i, qt)

    _, tiles = jax.lax.scan(body, None, (jnp.arange(nq), qtiles))
    out = tiles.transpose(1, 0, 2, 3, 4).reshape(b, nq * qb, h, hd)
    return out[:, :s]


def decode_attention_ref(q, k, v, valid_mask):
    """Single-token GQA attention vs a (possibly ring) cache.

    q: (B, 1, H, hd); k, v: (B, S, KV, hd); valid_mask: (B, S) bool.
    """
    b, _, h, hd = q.shape
    kvh = k.shape[2]
    g = h // kvh
    qg = q.reshape(b, kvh, g, hd)
    scale = 1.0 / jnp.sqrt(hd)
    scores = jnp.einsum("bkgh,bskh->bkgs", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    scores = jnp.where(valid_mask[:, None, None, :], scores, _NEG)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bskh->bkgh", probs, v.astype(jnp.float32))
    return out.reshape(b, 1, h, hd).astype(q.dtype)


def ssd_scan_ref(x, dt, a_log, b, c, d_skip, chunk: int, reset=None):
    """Mamba2 SSD (state-space dual) reference, chunked scan.

    x:  (B, S, H, P)   inputs per head
    dt: (B, S, H)      softplus-activated step sizes (>0)
    a_log: (H,)        log decay rate (A = -exp(a_log))
    b, c: (B, S, G, N) input/output projections (G groups broadcast to H)
    d_skip: (H,)       skip connection
    reset: (B, S) bool, optional -- True zeroes the state ENTERING step t
           (t's own contribution survives); left-padded serving rows pass
           pad positions + the first real token here so pad garbage can
           never reach real positions.
    Returns (y (B, S, H, P), final_state (B, H, N, P) fp32).

    Semantics (per head h, state M in R^{N x P}):
        M_t = [reset_t ? 0 : exp(A_h dt_t) M_{t-1}] + dt_t b_t x_t^T
        y_t = c_t M_t + D_h x_t

    Reset handling stays in the LINEAR domain (segment-id masks), never the
    log domain: cumsum'ing a -inf/-1e30 log-decay would absorb every later
    within-segment decay term (catastrophic cancellation), so instead the
    decay table is masked to same-segment (q, r) pairs and the inter-chunk /
    boundary terms are gated on "no reset since" indicators.
    """
    bsz, s, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    assert s % chunk == 0, "seq must be chunk-multiple"
    reps = h // g
    bh = jnp.repeat(b, reps, axis=2)     # (B,S,H,N)
    ch = jnp.repeat(c, reps, axis=2)

    a = -jnp.exp(a_log.astype(jnp.float32))          # (H,)
    dt32 = dt.astype(jnp.float32)
    la = a[None, None, :] * dt32                     # (B,S,H) log decay/step

    nchunks = s // chunk
    xc = x.reshape(bsz, nchunks, chunk, h, p).astype(jnp.float32)
    bc = bh.reshape(bsz, nchunks, chunk, h, n).astype(jnp.float32)
    cc = ch.reshape(bsz, nchunks, chunk, h, n).astype(jnp.float32)
    dtc = dt32.reshape(bsz, nchunks, chunk, h)
    lac = la.reshape(bsz, nchunks, chunk, h)

    # within-chunk cumulative log decays
    cum = jnp.cumsum(lac, axis=2)                    # (B,C,Q,H)
    total = cum[:, :, -1]                            # (B,C,H)

    if reset is None:
        same_seg = to_end_ok = no_reset_yet = chunk_clear = None
    else:
        # within-chunk segment ids: seg[q] = #resets at positions <= q
        resetc = reset.reshape(bsz, nchunks, chunk).astype(jnp.int32)
        seg = jnp.cumsum(resetc, axis=2)                       # (B,C,Q)
        same_seg = seg[:, :, :, None] == seg[:, :, None, :]    # (B,C,Q,R): no
        #   reset in (r, q] -- token r's state survives to token q
        to_end_ok = seg == seg[:, :, -1:]          # no reset after r in chunk
        no_reset_yet = seg == 0                    # carried state alive at q
        chunk_clear = (seg[:, :, -1] == 0)         # (B,C) state crosses chunk

    # intra-chunk (triangular) term: y_intra[q] = sum_{r<=q} decay(q,r) *
    #   (c_q . b_r) dt_r x_r   with decay(q,r) = exp(cum_q - cum_r).
    # The causal (and same-segment) mask is applied in LOG domain: for r > q
    # the exponent is positive and exp() overflows to inf before a post-hoc
    # mask could zero it (inf * 0 = NaN).
    scores = jnp.einsum("bcqhn,bcrhn->bchqr", cc, bc)
    ldecay = (cum[:, :, :, None, :].transpose(0, 1, 4, 2, 3)
              - cum[:, :, None, :, :].transpose(0, 1, 4, 2, 3))
    keep = jnp.tril(jnp.ones((chunk, chunk), bool))[None, None, None]
    if same_seg is not None:
        keep = keep & same_seg[:, :, None]           # (B,C,1|H,Q,R)
    ldecay = jnp.where(keep, ldecay, -jnp.inf)
    w = scores * jnp.exp(ldecay)
    y_intra = jnp.einsum("bchqr,bcrh,bcrhp->bcqhp", w, dtc, xc)

    # chunk-boundary states: S_c = sum_r exp(total - cum_r) dt_r b_r x_r^T
    decay_to_end = jnp.exp(total[:, :, None, :] - cum)        # (B,C,Q,H)
    if to_end_ok is not None:                # r crosses a reset -> dropped
        decay_to_end = decay_to_end * to_end_ok[..., None]
    contrib = jnp.einsum("bcqh,bcqh,bcqhn,bcqhp->bchnp",
                         decay_to_end, dtc, bc, xc)

    chunk_gate = (jnp.ones((bsz, nchunks), jnp.float32) if chunk_clear is None
                  else chunk_clear.astype(jnp.float32))

    def scan_fn(m_prev, inp):
        contrib_c, total_c, gate_c = inp
        m_in = m_prev
        m_out = (m_in * jnp.exp(total_c)[..., None, None]
                 * gate_c[:, None, None, None] + contrib_c)
        return m_out, m_in

    m0 = jnp.zeros((bsz, h, n, p), jnp.float32)
    contrib_t = contrib.transpose(1, 0, 2, 3, 4)     # (C,B,H,N,P)
    total_t = total.transpose(1, 0, 2)               # (C,B,H)
    m_final, m_starts = jax.lax.scan(
        scan_fn, m0, (contrib_t, total_t, chunk_gate.T))
    m_starts = m_starts.transpose(1, 0, 2, 3, 4)     # (B,C,H,N,P) state at chunk start

    # inter-chunk term: y_inter[q] = exp(cum_q) c_q . M_start
    inter_decay = jnp.exp(cum)
    if no_reset_yet is not None:             # a reset at <= q kills M_start
        inter_decay = inter_decay * no_reset_yet[..., None]
    y_inter = jnp.einsum("bcqh,bcqhn,bchnp->bcqhp",
                         inter_decay, cc, m_starts)

    y = (y_intra + y_inter).reshape(bsz, s, h, p)
    y = y + d_skip.astype(jnp.float32)[None, None, :, None] * x.astype(jnp.float32)
    return y.astype(x.dtype), m_final


def ssd_step_ref(state, x_t, dt_t, a_log, b_t, c_t, d_skip):
    """Single decode step of the SSD recurrence.

    state: (B, H, N, P); x_t: (B, H, P); dt_t: (B, H); b_t/c_t: (B, G, N).
    Returns (y_t (B, H, P), new_state).
    """
    h = x_t.shape[1]
    g = b_t.shape[1]
    reps = h // g
    bh = jnp.repeat(b_t, reps, axis=1).astype(jnp.float32)   # (B,H,N)
    ch = jnp.repeat(c_t, reps, axis=1).astype(jnp.float32)
    a = -jnp.exp(a_log.astype(jnp.float32))
    decay = jnp.exp(a[None, :] * dt_t.astype(jnp.float32))   # (B,H)
    x32 = x_t.astype(jnp.float32)
    new_state = (state * decay[..., None, None]
                 + jnp.einsum("bh,bhn,bhp->bhnp", dt_t.astype(jnp.float32), bh, x32))
    y = jnp.einsum("bhnp,bhn->bhp", new_state, ch)
    y = y + d_skip.astype(jnp.float32)[None, :, None] * x32
    return y.astype(x_t.dtype), new_state


def rglru_scan_ref(x, a, reset=None):
    """Linear recurrence h_t = a_t * h_{t-1} + x_t via associative scan.

    x, a: (B, S, R) with a in (0, 1).  Returns h: (B, S, R).
    ``reset`` (B, S) bool zeroes the state entering step t (h_t = x_t there):
    a reset position contributes its own input but receives no history --
    exactly "zero the carried state where reset fires", expressed as a_t := 0
    so the associative combine stays unchanged and exact.
    """
    def combine(left, right):
        a_l, x_l = left
        a_r, x_r = right
        return a_l * a_r, x_l * a_r + x_r

    a32, x32 = a.astype(jnp.float32), x.astype(jnp.float32)
    if reset is not None:
        a32 = jnp.where(reset[:, :, None], 0.0, a32)
    _, h = jax.lax.associative_scan(combine, (a32, x32), axis=1)
    return h.astype(x.dtype)


def partition_sweep_ref(macs, params_b, acts, psi, L, lam, gain, q_energy,
                        q_memory, scalars):
    """Reference for the partition-sweep kernel: builds the prefix tables
    from RAW per-layer arrays, then delegates to repro.core.sweep."""
    from ..core import sweep

    prefix_macs = jnp.cumsum(macs, axis=1)
    prefix_params = jnp.cumsum(params_b, axis=1)
    suffix_macs = prefix_macs[:, -1:] - prefix_macs
    suffix_params = prefix_params[:, -1:] - prefix_params
    c = macs.shape[1]
    idx = jnp.arange(c)[None, :]
    acts_r = jnp.where(idx <= L[:, None], acts, 0.0)
    acts_masked = jnp.where(idx >= 1, acts_r, 0.0)
    prefix_act_max = jax.lax.associative_scan(jnp.maximum, acts_masked, axis=1)
    rev = jnp.flip(jnp.where(idx >= 1, acts_r, 0.0), axis=1)
    suffix_inc = jnp.flip(jax.lax.associative_scan(jnp.maximum, rev, axis=1), axis=1)
    suffix_act_max = jnp.concatenate(
        [suffix_inc[:, 1:], jnp.zeros((macs.shape[0], 1), macs.dtype)], axis=1)
    return sweep.objective_table(
        prefix_macs=prefix_macs, suffix_macs=suffix_macs, psi=psi,
        prefix_params=prefix_params, suffix_params=suffix_params,
        prefix_act_max=prefix_act_max, suffix_act_max=suffix_act_max,
        L=L, lam=lam, gain=gain, q_energy=q_energy, q_memory=q_memory,
        **scalars)


def partition_sweep_batched_ref(macs, params_b, acts, psi, L, lam, gain,
                                q_energy, q_memory, scalars):
    """Checked fallback for ``partition_sweep_batched``: vmap the per-cell
    reference over the leading cell axis (tables (B, N, C), vectors (B, N))."""
    per_cell = lambda *args: partition_sweep_ref(*args, scalars)
    return jax.vmap(per_cell)(macs, params_b, acts, psi, L, lam, gain,
                              q_energy, q_memory)
