"""Kernel entry points: jit-friendly wrappers that dispatch between the
pure-jnp reference implementations (``repro.kernels.ref``) and the Pallas TPU
kernels.

Dispatch policy:
* ``set_impl("pallas")`` / ``set_impl("reference")`` / ``set_impl("auto")``.
* "auto" (default) picks Pallas on TPU backends and the reference elsewhere —
  the CPU dry-run lowers the reference path (compute-identical HLO; a Mosaic
  custom call cannot compile on the CPU backend), real-TPU runs lower Pallas.
* Tests force "pallas" with interpret=True to validate kernel bodies on CPU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import ref

_IMPL = "auto"
_INTERPRET = False


def set_impl(impl: str, interpret: bool = False):
    global _IMPL, _INTERPRET
    assert impl in ("auto", "pallas", "reference")
    _IMPL = impl
    _INTERPRET = interpret


def _pallas_active() -> bool:
    if _IMPL == "reference":
        return False
    if _IMPL == "pallas":
        return True
    return jax.default_backend() == "tpu"


# Above this many score elements per (batch x head) the reference switches to
# the blocked formulation (bounded memory; the flash kernel's blueprint).
_BLOCKED_THRESHOLD = 2048 * 2048


def flash_attention(q, k, v, *, kind: str = "causal", window: int = 0,
                    pad_mask=None):
    """GQA attention. q (B,Sq,H,hd), k/v (B,Sk,KV,hd).
    kind: "causal" | "local" (sliding window) | "full".

    ``pad_mask`` (B, Sk) bool marks VALID key positions per row (False =
    left-pad filler): the serving engine's ragged prompt batches.  The
    ragged path runs the dense reference with the combined causal+pad mask
    -- prefill widths are engine-bucket sized, so the dense score tile is
    small; the Pallas kernel has no ragged-batch support yet.
    """
    if pad_mask is not None:
        sq, sk = q.shape[1], k.shape[1]
        base = ref.build_mask(kind, sq, sk, window)     # (Sq, Sk) or None
        mask = jnp.broadcast_to(pad_mask[:, None, :],
                                (q.shape[0], sq, sk))
        if base is not None:
            mask = mask & base[None]
        return ref.attention_ref(q, k, v, mask=mask)
    if _pallas_active():
        from .flash_attention import flash_attention_pallas
        return flash_attention_pallas(q, k, v, kind=kind, window=window,
                                      interpret=_INTERPRET)
    sq, sk = q.shape[1], k.shape[1]
    if sq * sk <= _BLOCKED_THRESHOLD:
        return ref.attention_ref(q, k, v,
                                 mask=ref.build_mask(kind, sq, sk, window))
    return ref.attention_blocked(q, k, v, kind=kind, window=window)


def decode_attention(q, k, v, valid_mask):
    """Single-token GQA attention. q (B,1,H,hd), k/v (B,S,KV,hd),
    valid_mask (B,S) bool."""
    if _pallas_active():
        from .decode_attention import decode_attention_pallas
        return decode_attention_pallas(q, k, v, valid_mask=valid_mask,
                                       interpret=_INTERPRET)
    return ref.decode_attention_ref(q, k, v, valid_mask=valid_mask)


def ssd_scan(x, dt, a_log, b, c, d_skip, chunk: int):
    """Mamba2 SSD. x (B,S,H,P), dt (B,S,H), a_log (H,), b/c (B,S,G,N).
    Returns (y (B,S,H,P), final_state (B,H,N,P))."""
    if _pallas_active():
        from .ssd_scan import ssd_scan_pallas
        return ssd_scan_pallas(x, dt, a_log, b, c, d_skip, chunk=chunk,
                               interpret=_INTERPRET)
    return ref.ssd_scan_ref(x, dt, a_log, b, c, d_skip, chunk=chunk)


def rglru_scan(x, a, reset=None):
    """Gated linear recurrence h_t = a_t * h_{t-1} + x_t.  x, a: (B,S,R)."""
    if _pallas_active():
        from .rglru_scan import rglru_scan_pallas
        return rglru_scan_pallas(x, a, interpret=_INTERPRET)
    return ref.rglru_scan_ref(x, a)


def partition_sweep(macs, params_b, acts, psi, L, lam, gain, q_energy,
                    q_memory, scalars):
    """Per-(UE, cut) drift-plus-penalty objective table (paper eq. 11).
    See repro.core.sweep for semantics; scalars is a dict of MEC constants."""
    if _pallas_active():
        from .partition_sweep import partition_sweep_pallas
        return partition_sweep_pallas(macs, params_b, acts, psi, L, lam, gain,
                                      q_energy, q_memory, scalars,
                                      interpret=_INTERPRET)
    return ref.partition_sweep_ref(macs, params_b, acts, psi, L, lam, gain,
                                   q_energy, q_memory, scalars)
