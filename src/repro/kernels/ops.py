"""Kernel entry points: jit-friendly wrappers that dispatch between the
pure-jnp reference implementations (``repro.kernels.ref``) and the Pallas TPU
kernels.

Dispatch policy:
* ``set_impl("pallas")`` / ``set_impl("reference")`` / ``set_impl("auto")``.
* "auto" (default) picks Pallas on TPU backends and the reference elsewhere —
  the CPU dry-run lowers the reference path (compute-identical HLO; a Mosaic
  custom call cannot compile on the CPU backend), real-TPU runs lower Pallas.
* Tests force "pallas" with interpret=True to validate kernel bodies on CPU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import ref

_IMPL = "auto"
_INTERPRET = False


def set_impl(impl: str, interpret: bool = False):
    global _IMPL, _INTERPRET
    assert impl in ("auto", "pallas", "reference")
    _IMPL = impl
    _INTERPRET = interpret


def _pallas_active() -> bool:
    if _IMPL == "reference":
        return False
    if _IMPL == "pallas":
        return True
    return jax.default_backend() == "tpu"


# Above this many score elements per (batch x head) the reference switches to
# the blocked formulation (bounded memory; the flash kernel's blueprint).
_BLOCKED_THRESHOLD = 2048 * 2048


def flash_attention(q, k, v, *, kind: str = "causal", window: int = 0,
                    pad_mask=None):
    """GQA attention. q (B,Sq,H,hd), k/v (B,Sk,KV,hd).
    kind: "causal" | "local" (sliding window) | "full".

    ``pad_mask`` (B, Sk) bool marks VALID key positions per row, False =
    LEFT-pad filler (contiguous from position 0: the serving engine's ragged
    prompt batches).  With Pallas active the mask folds into the flash
    kernel as a per-row pad-count vector (``k_pos >= pad[b]``), keeping the
    blocked path; otherwise the dense reference runs with the combined
    causal+pad mask.  Sequence lengths need not be block multiples -- the
    Pallas wrapper pads to the tile grid internally.
    """
    if pad_mask is not None:
        if _pallas_active():
            from .flash_attention import flash_attention_pallas
            # left-contiguous pads by construction -> a count per row
            pad = jnp.sum(~pad_mask, axis=1).astype(jnp.int32)
            return flash_attention_pallas(q, k, v, kind=kind, window=window,
                                          pad=pad, interpret=_INTERPRET)
        sq, sk = q.shape[1], k.shape[1]
        base = ref.build_mask(kind, sq, sk, window)     # (Sq, Sk) or None
        mask = jnp.broadcast_to(pad_mask[:, None, :],
                                (q.shape[0], sq, sk))
        if base is not None:
            mask = mask & base[None]
        return ref.attention_ref(q, k, v, mask=mask)
    if _pallas_active():
        from .flash_attention import flash_attention_pallas
        return flash_attention_pallas(q, k, v, kind=kind, window=window,
                                      interpret=_INTERPRET)
    sq, sk = q.shape[1], k.shape[1]
    if sq * sk <= _BLOCKED_THRESHOLD:
        return ref.attention_ref(q, k, v,
                                 mask=ref.build_mask(kind, sq, sk, window))
    return ref.attention_blocked(q, k, v, kind=kind, window=window)


def decode_attention(q, k, v, valid_mask):
    """Single-token GQA attention. q (B,1,H,hd), k/v (B,S,KV,hd),
    valid_mask (B,S) bool."""
    if _pallas_active():
        from .decode_attention import decode_attention_pallas
        return decode_attention_pallas(q, k, v, valid_mask=valid_mask,
                                       interpret=_INTERPRET)
    return ref.decode_attention_ref(q, k, v, valid_mask=valid_mask)


def chunk_attention(q, k, v, *, start):
    """Chunked-prefill GQA attention: q (B,C,H,hd) carries the C tokens at
    absolute positions ``start .. start+C-1``; k/v (B,S,KV,hd) are dense
    scratch caches whose entries below ``start+C`` are real (everything
    beyond is junk that the prefix-causal mask zeroes out).  Query row ``i``
    attends key position ``j`` iff ``j <= start + i``.

    ``start`` may be traced -- the chunk engine compiles ONE program for
    all chunk indices.  Scores are chunk x s_max (small), so both dispatch
    arms run the dense reference; a flash chunk kernel is a follow-on once
    real-TPU baselines exist.
    """
    sq, sk = q.shape[1], k.shape[1]
    mask = jnp.arange(sk)[None, :] <= (start + jnp.arange(sq))[:, None]
    return ref.attention_ref(q, k, v, mask=mask)


def ssd_scan(x, dt, a_log, b, c, d_skip, chunk: int, reset=None):
    """Mamba2 SSD. x (B,S,H,P), dt (B,S,H), a_log (H,), b/c (B,S,G,N).
    ``reset`` (B,S) bool zeroes the carried state entering flagged steps
    (ragged serving batches; threaded to both dispatch arms).
    Returns (y (B,S,H,P), final_state (B,H,N,P)).

    S need not be a chunk multiple: the tail is right-padded with dt=0
    steps (decay exp(a*0)=1 and contribution dt*b*x = 0, so the final state
    is untouched) and the padded y rows are sliced off.
    """
    s = x.shape[1]
    tail = (-s) % chunk
    if tail:
        pad_s = lambda t: jnp.pad(t, [(0, 0), (0, tail)]
                                  + [(0, 0)] * (t.ndim - 2))
        x, dt, b, c = pad_s(x), pad_s(dt), pad_s(b), pad_s(c)
        if reset is not None:
            reset = pad_s(reset)
    if _pallas_active():
        from .ssd_scan import ssd_scan_pallas
        y, state = ssd_scan_pallas(x, dt, a_log, b, c, d_skip, chunk=chunk,
                                   reset=reset, interpret=_INTERPRET)
    else:
        y, state = ref.ssd_scan_ref(x, dt, a_log, b, c, d_skip, chunk=chunk,
                                    reset=reset)
    return (y[:, :s] if tail else y), state


def rglru_scan(x, a, reset=None):
    """Gated linear recurrence h_t = a_t * h_{t-1} + x_t.  x, a: (B,S,R).
    ``reset`` (B,S) bool zeroes the carried state entering flagged steps
    (h_t = x_t there); threaded to both dispatch arms."""
    if _pallas_active():
        from .rglru_scan import rglru_scan_pallas
        return rglru_scan_pallas(x, a, reset=reset, interpret=_INTERPRET)
    return ref.rglru_scan_ref(x, a, reset=reset)


def partition_sweep(macs, params_b, acts, psi, L, lam, gain, q_energy,
                    q_memory, scalars):
    """Per-(UE, cut) drift-plus-penalty objective table (paper eq. 11).
    See repro.core.sweep for semantics; scalars is a dict of MEC constants."""
    if _pallas_active():
        from .partition_sweep import partition_sweep_pallas
        return partition_sweep_pallas(macs, params_b, acts, psi, L, lam, gain,
                                      q_energy, q_memory, scalars,
                                      interpret=_INTERPRET)
    return ref.partition_sweep_ref(macs, params_b, acts, psi, L, lam, gain,
                                   q_energy, q_memory, scalars)


def partition_sweep_batched(macs, params_b, acts, psi, L, lam, gain,
                            q_energy, q_memory, scalars, *,
                            interpret: bool = False):
    """Batched (B, N, C) sweep: one kernel launch over every cell of a grid.

    The wrapper seam for callers outside kernels/ (scenario grids pick the
    backend explicitly, so this dispatches on ``interpret`` alone rather
    than the module-level ``set_impl`` switch)."""
    from .partition_sweep import partition_sweep_batched as _impl
    return _impl(macs, params_b, acts, psi, L, lam, gain, q_energy,
                 q_memory, scalars, interpret=interpret)
