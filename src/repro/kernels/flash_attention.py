"""Pallas TPU flash attention (GQA, causal/local/full).

Grid (B, KV, nq, nk): each program handles one (batch, kv-head) pair, one
query tile, one key tile; the last grid dim iterates sequentially so the
online-softmax state (m, l, acc) lives in VMEM scratch across key tiles.
Query heads sharing a kv head (G = H/KV) are folded into the tile's row
dimension so the score matmul is a single (G*Qb, hd) x (hd, Kb) MXU op.

Block skipping: key tiles strictly above the causal diagonal (or outside the
sliding-window band) are skipped with @pl.when -- this is where the kernel
beats the XLA reference path, which executes masked-out FLOPs (DESIGN §6).

VMEM budget per program (f32): q tile G*Qb*hd + k/v tiles 2*Kb*hd + acc
G*Qb*hd + stats 2*G*Qb  ~= 6 MB at G=8, Qb=Kb=512, hd=128.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            kind: str, window: int, q_block: int, k_block: int,
            g: int, nk: int, scale: float):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = iq * q_block
    k_start = ik * k_block
    if kind == "causal":
        relevant = k_start <= q_start + q_block - 1
    elif kind == "local":
        relevant = ((k_start <= q_start + q_block - 1)
                    & (k_start + k_block - 1 > q_start - window))
    else:
        relevant = True

    @pl.when(relevant)
    def _compute():
        q = q_ref[0, 0].reshape(g * q_block, q_ref.shape[-1])
        k = k_ref[0, 0]                        # (Kb, hd)
        v = v_ref[0, 0]
        s = jax.lax.dot_general(
            q.astype(jnp.float32), k.astype(jnp.float32),
            (((1,), (1,)), ((), ()))) * scale   # (G*Qb, Kb)
        if kind != "full":
            rows = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            cols = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            q_pos = q_start + jax.lax.rem(rows, q_block)
            k_pos = k_start + cols
            mask = k_pos <= q_pos
            if kind == "local":
                mask = mask & (k_pos > q_pos - window)
            s = jnp.where(mask, s, _NEG)
        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = alpha * l_prev + jnp.sum(p, axis=1, keepdims=True)
        pv = jax.lax.dot_general(p, v.astype(jnp.float32),
                                 (((1,), (0,)), ((), ())))
        acc_ref[...] = alpha * acc_ref[...] + pv
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-20)
        out = (acc_ref[...] / l).reshape(1, 1, g, q_block, o_ref.shape[-1])
        o_ref[...] = out.astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, kind: str = "causal", window: int = 0,
                           q_block: int = 512, k_block: int = 512,
                           interpret: bool = False):
    """q (B,Sq,H,hd), k/v (B,Sk,KV,hd) -> (B,Sq,H,hd)."""
    b, sq, h, hd = q.shape
    _, sk, kv, _ = k.shape
    g = h // kv
    q_block = min(q_block, sq)
    k_block = min(k_block, sk)
    assert sq % q_block == 0 and sk % k_block == 0, "pad seq to block multiple"
    nq, nk = sq // q_block, sk // k_block

    qr = q.reshape(b, sq, kv, g, hd).transpose(0, 2, 3, 1, 4)  # (B,KV,G,Sq,hd)
    kr = k.transpose(0, 2, 1, 3)                               # (B,KV,Sk,hd)
    vr = v.transpose(0, 2, 1, 3)

    kernel = functools.partial(
        _kernel, kind=kind, window=window, q_block=q_block, k_block=k_block,
        g=g, nk=nk, scale=1.0 / (hd ** 0.5))

    out = pl.pallas_call(
        kernel,
        grid=(b, kv, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, g, q_block, hd),
                         lambda b_, k_, iq, ik: (b_, k_, 0, iq, 0)),
            pl.BlockSpec((1, 1, k_block, hd),
                         lambda b_, k_, iq, ik: (b_, k_, ik, 0)),
            pl.BlockSpec((1, 1, k_block, hd),
                         lambda b_, k_, iq, ik: (b_, k_, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, q_block, hd),
                               lambda b_, k_, iq, ik: (b_, k_, 0, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kv, g, sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g * q_block, hd), jnp.float32),
            pltpu.VMEM((g * q_block, 1), jnp.float32),
            pltpu.VMEM((g * q_block, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, hd)
