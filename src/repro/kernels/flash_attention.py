"""Pallas TPU flash attention (GQA, causal/local/full).

Grid (B, KV, nq, nk): each program handles one (batch, kv-head) pair, one
query tile, one key tile; the last grid dim iterates sequentially so the
online-softmax state (m, l, acc) lives in VMEM scratch across key tiles.
Query heads sharing a kv head (G = H/KV) are folded into the tile's row
dimension so the score matmul is a single (G*Qb, hd) x (hd, Kb) MXU op.

Block skipping: key tiles strictly above the causal diagonal (or outside the
sliding-window band) are skipped with @pl.when -- this is where the kernel
beats the XLA reference path, which executes masked-out FLOPs (DESIGN §6).

Ragged (left-padded) batches: ``pad`` gives each row's left-pad key count;
``k_pos >= pad[b]`` folds into the in-kernel mask and key tiles that end
before ``pad[b]`` extend the @pl.when skip -- pad columns cost zero FLOPs,
not just zero weight.  Fully-masked query rows (the pad rows themselves)
come out as finite zeros via the l==0 guard in ``_finish``.

Sequence lengths need NOT be block multiples: the wrapper right-pads q/k/v
up to the tile grid (the same trick as ``ref.attention_blocked``) and
slices the result; a ``k_len`` bound masks the phantom key columns wherever
the causal mask alone would not (full/local kinds, padded K).

VMEM budget per program (f32): q tile G*Qb*hd + k/v tiles 2*Kb*hd + acc
G*Qb*hd + stats 2*G*Qb  ~= 6 MB at G=8, Qb=Kb=512, hd=128.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG = -1e30


def _kernel(*refs, kind: str, window: int, q_block: int, k_block: int,
            g: int, nk: int, scale: float, k_len: int, has_pad: bool):
    if has_pad:
        q_ref, k_ref, v_ref, pad_ref, o_ref, acc_ref, m_ref, l_ref = refs
    else:
        q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref = refs
        pad_ref = None
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = iq * q_block
    k_start = ik * k_block
    # phantom key tiles (wrapper right-padding) are dropped statically
    tile_live = k_start < k_len
    if kind == "causal":
        relevant = tile_live & (k_start <= q_start + q_block - 1)
    elif kind == "local":
        relevant = (tile_live & (k_start <= q_start + q_block - 1)
                    & (k_start + k_block - 1 > q_start - window))
    else:
        relevant = tile_live
    if pad_ref is not None:
        # key tile entirely inside this row's left pad: skip it outright
        relevant = relevant & (k_start + k_block > pad_ref[0])

    @pl.when(relevant)
    def _compute():
        q = q_ref[0, 0].reshape(g * q_block, q_ref.shape[-1])
        k = k_ref[0, 0]                        # (Kb, hd)
        v = v_ref[0, 0]
        s = jax.lax.dot_general(
            q.astype(jnp.float32), k.astype(jnp.float32),
            (((1,), (1,)), ((), ()))) * scale   # (G*Qb, Kb)
        rows = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        cols = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        q_pos = q_start + jax.lax.rem(rows, q_block)
        k_pos = k_start + cols
        mask = None
        if kind != "full":
            mask = k_pos <= q_pos
            if kind == "local":
                mask = mask & (k_pos > q_pos - window)
        if k_len % k_block:          # static: wrapper right-padded K -- the
            bound = k_pos < k_len    # last live tile has phantom columns
            mask = bound if mask is None else mask & bound
        if pad_ref is not None:
            valid = k_pos >= pad_ref[0]
            mask = valid if mask is None else mask & valid
        if mask is not None:
            s = jnp.where(mask, s, _NEG)
        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        if mask is not None:
            # fully-masked rows: every score sits at _NEG == m_new, so
            # exp(s - m_new) = 1 would weigh masked keys; zero them instead
            p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = alpha * l_prev + jnp.sum(p, axis=1, keepdims=True)
        pv = jax.lax.dot_general(p, v.astype(jnp.float32),
                                 (((1,), (0,)), ((), ())))
        acc_ref[...] = alpha * acc_ref[...] + pv
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-20)
        out = (acc_ref[...] / l).reshape(1, 1, g, q_block, o_ref.shape[-1])
        o_ref[...] = out.astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, kind: str = "causal", window: int = 0,
                           q_block: int = 512, k_block: int = 512,
                           pad=None, interpret: bool = False):
    """q (B,Sq,H,hd), k/v (B,Sk,KV,hd) -> (B,Sq,H,hd).

    ``pad`` (B,) int32: per-row LEFT-pad key count for ragged batches --
    keys below ``pad[b]`` are masked out of row b (the serving engine's
    bucketed prompt widths).  Sq/Sk may be any length: non-block-multiple
    sequences are right-padded to the tile grid internally and sliced back.
    """
    b, sq, h, hd = q.shape
    _, sk, kv, _ = k.shape
    g = h // kv
    q_block = min(q_block, sq)
    k_block = min(k_block, sk)
    sq_pad = (-sq) % q_block
    sk_pad = (-sk) % k_block
    if sq_pad:
        q = jnp.pad(q, ((0, 0), (0, sq_pad), (0, 0), (0, 0)))
    if sk_pad:
        k = jnp.pad(k, ((0, 0), (0, sk_pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, sk_pad), (0, 0), (0, 0)))
    sq_p, sk_p = sq + sq_pad, sk + sk_pad
    nq, nk = sq_p // q_block, sk_p // k_block

    qr = q.reshape(b, sq_p, kv, g, hd).transpose(0, 2, 3, 1, 4)  # (B,KV,G,Sq,hd)
    kr = k.transpose(0, 2, 1, 3)                                 # (B,KV,Sk,hd)
    vr = v.transpose(0, 2, 1, 3)

    in_specs = [
        pl.BlockSpec((1, 1, g, q_block, hd),
                     lambda b_, k_, iq, ik: (b_, k_, 0, iq, 0)),
        pl.BlockSpec((1, 1, k_block, hd),
                     lambda b_, k_, iq, ik: (b_, k_, ik, 0)),
        pl.BlockSpec((1, 1, k_block, hd),
                     lambda b_, k_, iq, ik: (b_, k_, ik, 0)),
    ]
    operands = [qr, kr, vr]
    if pad is not None:
        operands.append(jnp.asarray(pad, jnp.int32))
        in_specs.append(pl.BlockSpec((1,), lambda b_, k_, iq, ik: (b_,)))

    kernel = functools.partial(
        _kernel, kind=kind, window=window, q_block=q_block, k_block=k_block,
        g=g, nk=nk, scale=1.0 / (hd ** 0.5), k_len=sk, has_pad=pad is not None)

    out = pl.pallas_call(
        kernel,
        grid=(b, kv, nq, nk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, g, q_block, hd),
                               lambda b_, k_, iq, ik: (b_, k_, 0, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kv, g, sq_p, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g * q_block, hd), jnp.float32),
            pltpu.VMEM((g * q_block, 1), jnp.float32),
            pltpu.VMEM((g * q_block, 1), jnp.float32),
        ],
        interpret=interpret,
    )(*operands)
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, sq_p, h, hd)
    return out[:, :sq] if sq_pad else out
