"""Pallas TPU decode attention: one query token vs a long (ring/linear) KV
cache, blocked over the cache length.

Grid (B, KV, nk): the single query row per (batch, kv-head) is tiny, so the
kernel is purely memory-bound -- each program streams one (Kb, hd) key tile
and one value tile through VMEM and maintains online-softmax state in
scratch.  ``valid_mask`` (B, S) carries both the causal frontier and ring-
buffer validity (models/attention.py), so one kernel serves linear caches,
sliding-window rings, and cross-attention.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, mask_ref, o_ref, acc_ref, m_ref, l_ref, *,
            g: int, nk: int, scale: float):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)           # (G, hd)
    k = k_ref[0, 0].astype(jnp.float32)           # (Kb, hd)
    v = v_ref[0, 0].astype(jnp.float32)
    valid = mask_ref[0]                           # (Kb,)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale  # (G, Kb)
    s = jnp.where(valid[None, :], s, _NEG)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = alpha * acc_ref[...] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())))
    m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-20)
        o_ref[...] = (acc_ref[...] / l)[None, None].astype(o_ref.dtype)


def decode_attention_pallas(q, k, v, *, valid_mask, k_block: int = 512,
                            interpret: bool = False):
    """q (B,1,H,hd), k/v (B,S,KV,hd), valid_mask (B,S) -> (B,1,H,hd).

    Any cache length S is accepted: a ragged tail (S not a k_block
    multiple) is padded wrapper-side up to the next block boundary with
    ``valid_mask=False`` entries, which the in-kernel mask turns into
    ``exp(-inf) == 0`` softmax terms -- same discipline the flash/scan
    kernels use for pad columns, so paged caches with per-slot lengths
    (serving/kvpool.py) need no host-side repacking.
    """
    b, _, h, hd = q.shape
    _, s, kv, _ = k.shape
    g = h // kv
    k_block = min(k_block, s)
    if s % k_block:
        tail = k_block - s % k_block
        wid = [(0, 0), (0, tail), (0, 0), (0, 0)]
        k = jnp.pad(k, wid)
        v = jnp.pad(v, wid)
        valid_mask = jnp.pad(valid_mask, [(0, 0), (0, tail)])   # False tail
        s += tail
    nk = s // k_block

    qr = q.reshape(b, kv, g, hd)
    kr = k.transpose(0, 2, 1, 3)     # (B,KV,S,hd)
    vr = v.transpose(0, 2, 1, 3)

    kernel = functools.partial(_kernel, g=g, nk=nk, scale=1.0 / (hd ** 0.5))
    out = pl.pallas_call(
        kernel,
        grid=(b, kv, nk),
        in_specs=[
            pl.BlockSpec((1, 1, g, hd), lambda b_, k_, ik: (b_, k_, 0, 0)),
            pl.BlockSpec((1, 1, k_block, hd),
                         lambda b_, k_, ik: (b_, k_, ik, 0)),
            pl.BlockSpec((1, 1, k_block, hd),
                         lambda b_, k_, ik: (b_, k_, ik, 0)),
            pl.BlockSpec((1, k_block), lambda b_, k_, ik: (b_, ik)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, hd), lambda b_, k_, ik: (b_, k_, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kv, g, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, hd), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr, valid_mask)
    return out.reshape(b, 1, h, hd)
