"""Pallas TPU kernel for the Mamba2 SSD (state-space dual) chunked scan.

Grid (B, H, nchunks): the last dim iterates chunks sequentially per
(batch, head); the running state M (N x P) lives in VMEM scratch across
chunk iterations — exactly the TPU-native reformulation of the SSD
recurrence: per chunk, the quadratic "attention-like" intra-chunk term is
two MXU matmuls (C·B^T weighted tri-matmul against X), and the inter-chunk
term applies the carried state.  This adapts Mamba2's GPU kernel (warp-level
scans) to the TPU memory hierarchy: chunk tiles in VMEM, state in VMEM
scratch, MXU for all O(Q^2)/O(QN) contractions (DESIGN §3/§6).

Reset support (ragged serving batches): an optional (B, S) mask zeroes the
carried state ENTERING the flagged steps.  Implemented with within-chunk
segment ids (cumsum of the reset column) in the LINEAR domain: the
triangular decay table is additionally masked to same-segment (q, r) pairs,
chunk-boundary contributions drop tokens with a later in-chunk reset, the
inter-chunk term is gated on "no reset yet", and the VMEM-carried M is
zeroed across any chunk containing a reset.  (A log-domain -inf reset would
be absorbed by the cumsum and corrupt every later same-segment decay.)

Semantics == repro.kernels.ref.ssd_scan_ref (the oracle).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(*refs, nchunks: int, chunk: int, has_reset: bool):
    if has_reset:
        (x_ref, dt_ref, alog_ref, b_ref, c_ref, dskip_ref, reset_ref,
         y_ref, state_out_ref, m_ref) = refs
    else:
        (x_ref, dt_ref, alog_ref, b_ref, c_ref, dskip_ref,
         y_ref, state_out_ref, m_ref) = refs
        reset_ref = None
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        m_ref[...] = jnp.zeros_like(m_ref)

    x = x_ref[0, 0].astype(jnp.float32)         # (Q, P)
    dt = dt_ref[0, 0].astype(jnp.float32)       # (Q, 1) -> column
    bmat = b_ref[0, 0].astype(jnp.float32)      # (Q, N)
    cmat = c_ref[0, 0].astype(jnp.float32)      # (Q, N)
    a = -jnp.exp(alog_ref[0].astype(jnp.float32))   # scalar A_h
    d = dskip_ref[0].astype(jnp.float32)

    la = a * dt                                  # (Q,1) log decay per step
    cum = jnp.cumsum(la, axis=0)                 # (Q,1)
    total = cum[chunk - 1:chunk, :]              # (1,1)

    # intra-chunk triangular term (log-domain masked decay)
    scores = jax.lax.dot_general(cmat, bmat, (((1,), (1,)), ((), ())))  # (Q,Q)
    ldecay = cum - cum.T                         # (Q,Q) = cum_q - cum_r
    rows = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    keep = cols <= rows
    if reset_ref is not None:
        # seg[q] = #resets at in-chunk positions <= q; decay(q, r) survives
        # only when no reset lies in (r, q] -- i.e. seg_q == seg_r.
        seg = jnp.cumsum(reset_ref[0].astype(jnp.int32), axis=0)   # (Q,1)
        keep = keep & (seg == seg.T)
    ldecay = jnp.where(keep, ldecay, -jnp.inf)
    w = scores * jnp.exp(ldecay)                 # (Q,Q)
    xdt = x * dt                                 # (Q,P)
    y_intra = jax.lax.dot_general(w, xdt, (((1,), (0,)), ((), ())))

    # inter-chunk term from carried state M (N,P)
    inter_decay = jnp.exp(cum)                   # (Q,1)
    if reset_ref is not None:
        inter_decay = jnp.where(seg == 0, inter_decay, 0.0)
    y_inter = inter_decay * jax.lax.dot_general(
        cmat, m_ref[...], (((1,), (0,)), ((), ())))

    y_ref[...] = ((y_intra + y_inter + d * x)[None, None]).astype(y_ref.dtype)

    # state update: M <- exp(total) M + sum_r exp(total-cum_r) dt_r b_r x_r^T
    decay_to_end = jnp.exp(total - cum)          # (Q,1)
    carry = jnp.exp(total)                       # (1,1)
    if reset_ref is not None:
        # tokens with a later in-chunk reset never reach the chunk boundary;
        # M itself survives the chunk only when the chunk has no reset.
        decay_to_end = jnp.where(seg == seg[chunk - 1:chunk, :],
                                 decay_to_end, 0.0)
        carry = jnp.where(seg[chunk - 1:chunk, :] == 0, carry, 0.0)
    contrib = jax.lax.dot_general(bmat * (decay_to_end * dt), x,
                                  (((0,), (0,)), ((), ())))   # (N,P)
    m_ref[...] = m_ref[...] * carry + contrib

    @pl.when(ic == nchunks - 1)
    def _finish():
        state_out_ref[...] = m_ref[...][None, None]


def ssd_scan_pallas(x, dt, a_log, b, c, d_skip, *, chunk: int,
                    reset=None, interpret: bool = False):
    """x (B,S,H,P), dt (B,S,H), a_log (H,), b/c (B,S,G,N), d_skip (H,).
    ``reset`` (B, S) bool: True zeroes the state entering step t.
    Returns (y (B,S,H,P), final_state (B,H,N,P))."""
    bsz, s, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    assert s % chunk == 0
    nchunks = s // chunk
    reps = h // g
    bh = jnp.repeat(b, reps, axis=2)
    ch = jnp.repeat(c, reps, axis=2)

    xr = x.transpose(0, 2, 1, 3)          # (B,H,S,P)
    dtr = dt.transpose(0, 2, 1)[..., None]  # (B,H,S,1)
    br = bh.transpose(0, 2, 1, 3)
    cr = ch.transpose(0, 2, 1, 3)

    in_specs = [
        pl.BlockSpec((1, 1, chunk, p), lambda b_, h_, c_: (b_, h_, c_, 0)),
        pl.BlockSpec((1, 1, chunk, 1), lambda b_, h_, c_: (b_, h_, c_, 0)),
        pl.BlockSpec((1,), lambda b_, h_, c_: (h_,)),
        pl.BlockSpec((1, 1, chunk, n), lambda b_, h_, c_: (b_, h_, c_, 0)),
        pl.BlockSpec((1, 1, chunk, n), lambda b_, h_, c_: (b_, h_, c_, 0)),
        pl.BlockSpec((1,), lambda b_, h_, c_: (h_,)),
    ]
    operands = [xr, dtr, a_log, br, cr, d_skip]
    if reset is not None:
        operands.append(reset.astype(jnp.float32)[:, :, None])   # (B,S,1)
        in_specs.append(pl.BlockSpec((1, chunk, 1),
                                     lambda b_, h_, c_: (b_, c_, 0)))

    kernel = functools.partial(_kernel, nchunks=nchunks, chunk=chunk,
                               has_reset=reset is not None)
    y, state = pl.pallas_call(
        kernel,
        grid=(bsz, h, nchunks),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, 1, chunk, p), lambda b_, h_, c_: (b_, h_, c_, 0)),
            pl.BlockSpec((1, 1, n, p), lambda b_, h_, c_: (b_, h_, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, h, s, p), x.dtype),
            jax.ShapeDtypeStruct((bsz, h, n, p), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        interpret=interpret,
    )(*operands)
    return y.transpose(0, 2, 1, 3), state
