"""Pallas TPU kernel for the LyMDO partition sweep (paper eq. 11 over every
(UE, cut) pair) -- the controller's dense hot spot (DESIGN §6).

TPU adaptation of the paper's per-slot search:
  * layer prefix sums  -> one (C x C) upper-triangular ones matmul on the MXU
    (instead of a serial scan),
  * running activation maxima -> log2(C) doubling passes on the VPU,
  * the P3 Fibonacci line search -> 40 data-parallel iterations over the
    whole (UE-block x cut) tile at once,
so evaluating ALL cuts costs two small matmuls + elementwise work, and the
argmin over cuts (the Oracle policy / PPO action pruning) reads one table.

Oracle semantics == repro.kernels.ref.partition_sweep_ref.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

_BIG = 1e30
_FIB_ITERS = 40


def _fib_ratios():
    fib = np.ones(_FIB_ITERS + 3)
    for i in range(2, _FIB_ITERS + 3):
        fib[i] = fib[i - 1] + fib[i - 2]
    lo = np.array([fib[_FIB_ITERS - k] / fib[_FIB_ITERS - k + 2]
                   for k in range(_FIB_ITERS)], np.float32)
    hi = np.array([fib[_FIB_ITERS - k + 1] / fib[_FIB_ITERS - k + 2]
                   for k in range(_FIB_ITERS)], np.float32)
    return lo, hi


_RLO, _RHI = _fib_ratios()


def _kernel(macs_ref, params_ref, acts_ref, psi_ref, l_ref, lam_ref,
            gain_ref, qe_ref, qm_ref, out_ref, *, c: int, n_total: int,
            rho, kappa, p_tx, w_hz, n0, f_max_ue, f_max_es, v,
            gamma_ue, gamma_es, stability_margin):
    macs = macs_ref[...].astype(jnp.float32)        # (Nb, C)
    params = params_ref[...].astype(jnp.float32)
    acts = acts_ref[...].astype(jnp.float32)
    psi = psi_ref[...].astype(jnp.float32)
    l_n = l_ref[...].astype(jnp.float32)            # (Nb, 1)
    lam = lam_ref[...].astype(jnp.float32)
    gain = gain_ref[...].astype(jnp.float32)
    qe = qe_ref[...].astype(jnp.float32)
    qm = qm_ref[...].astype(jnp.float32)

    cols = jax.lax.broadcasted_iota(jnp.float32, macs.shape, 1)
    in_range = cols <= l_n                          # valid cuts per UE

    # -- prefix sums via upper-triangular ones matmul (MXU) ------------------
    rows_t = jax.lax.broadcasted_iota(jnp.float32, (c, c), 0)
    cols_t = jax.lax.broadcasted_iota(jnp.float32, (c, c), 1)
    tri = (rows_t <= cols_t).astype(jnp.float32)    # T[j,c] = 1 iff j <= c
    prefix_macs = jax.lax.dot_general(macs, tri, (((1,), (0,)), ((), ())))
    prefix_params = jax.lax.dot_general(params, tri, (((1,), (0,)), ((), ())))
    total_macs = prefix_macs[:, c - 1:c]
    total_params = prefix_params[:, c - 1:c]
    suffix_macs = total_macs - prefix_macs
    suffix_params = total_params - prefix_params

    # -- running activation maxima via doubling (VPU) ------------------------
    acts_m = jnp.where((cols >= 1.0) & in_range, acts, 0.0)
    pmax = acts_m
    shift = 1
    while shift < c:
        prev = jnp.roll(pmax, shift, axis=1)
        prev = jnp.where(cols >= shift, prev, 0.0)
        pmax = jnp.maximum(pmax, prev)
        shift *= 2
    smax_incl = acts_m
    shift = 1
    while shift < c:
        nxt = jnp.roll(smax_incl, -shift, axis=1)
        nxt = jnp.where(cols < c - shift, nxt, 0.0)
        smax_incl = jnp.maximum(smax_incl, nxt)
        shift *= 2
    smax = jnp.where(cols < c - 1, jnp.roll(smax_incl, -1, axis=1), 0.0)

    # -- per-cut demands ------------------------------------------------------
    d_ue = rho * prefix_macs
    d_es = rho * suffix_macs

    # -- P3 Fibonacci search over the whole tile -----------------------------
    lo = d_ue * lam * (1.0 + stability_margin) + 1.0
    hi = jnp.full_like(lo, f_max_ue)
    lo = jnp.minimum(lo, hi)

    def obj(f):
        f = jnp.maximum(f, 1e-12)
        energy = qe * kappa * f * f * d_ue * lam
        proc = d_ue / f
        denom = jnp.maximum(f * f - f * d_ue * lam, 1e-12)
        queue = d_ue * d_ue * lam / (2.0 * denom)
        return energy + v * (proc + queue)

    a_, b_ = lo, hi
    for k in range(_FIB_ITERS):
        span = b_ - a_
        x1 = a_ + _RLO[k] * span
        x2 = a_ + _RHI[k] * span
        take_left = obj(x1) < obj(x2)
        a_ = jnp.where(take_left, a_, x1)
        b_ = jnp.where(take_left, x2, b_)
    f_ue = 0.5 * (a_ + b_)
    f_ue = jnp.where(obj(hi) < obj(f_ue), hi, f_ue)
    f_ue = jnp.where(d_ue > 0, f_ue, 0.0)

    # -- delays ---------------------------------------------------------------
    mu = jnp.where(d_ue > 0, f_ue / jnp.maximum(d_ue, 1e-12), 1e30)
    wait = lam / (2.0 * mu * jnp.maximum(mu - lam, 1e-12))
    t_ue = jnp.where(d_ue > 0, 1.0 / mu + wait, 0.0)

    alpha = 1.0 / n_total
    snr = p_tx * gain / (alpha * w_hz * n0)
    rate = alpha * w_hz * (jnp.log(1.0 + snr) / jnp.log(2.0))
    t_tx = jnp.where(psi > 0, 8.0 * psi / jnp.maximum(rate, 1e-12), 0.0)

    f_es = f_max_es / n_total
    t_es = jnp.where(d_es > 0, d_es / f_es, 0.0)

    # -- energy / memory / objective -----------------------------------------
    energy = (kappa * f_ue * f_ue * d_ue * lam) + p_tx * t_tx * lam
    mem = (gamma_ue * prefix_params + pmax
           + gamma_es * suffix_params + smax) / 1e9
    objv = qe * energy + qm * mem + v * (t_ue + t_tx + t_es)

    feasible = in_range & (d_ue * lam * (1.0 + stability_margin) < f_max_ue)
    out_ref[...] = jnp.where(feasible, objv, _BIG)


def partition_sweep_pallas(macs, params_b, acts, psi, L, lam, gain, q_energy,
                           q_memory, scalars, *, ue_block: int = 8,
                           interpret: bool = False, n_total: int | None = None):
    """All args (N, C) / (N,); scalars: dict of MEC constants.
    Returns the (N, C) objective table (infeasible cells = 1e30).

    ``n_total`` overrides the UE count used for the even-split decoupling
    (alpha = 1/n_total, f_es = f_max_es/n_total).  It defaults to N, but a
    batched caller that flattens B independent cells of N UEs each into one
    (B*N, C) problem must pass the per-cell N so the splits stay per-cell
    (see ``partition_sweep_batched``).
    """
    n, c = macs.shape
    if n_total is None:
        n_total = n
    pad = (-n) % ue_block
    if pad:
        padded = lambda t: jnp.pad(t, ((0, pad),) + ((0, 0),) * (t.ndim - 1))
        macs, params_b, acts, psi = map(padded, (macs, params_b, acts, psi))
        L, lam, gain = map(padded, (L, lam, gain))
        q_energy, q_memory = map(padded, (q_energy, q_memory))
    nb = macs.shape[0] // ue_block

    col = lambda t: t.reshape(-1, 1).astype(jnp.float32)
    kernel = functools.partial(
        _kernel, c=c, n_total=n_total,
        rho=scalars["rho"], kappa=scalars["kappa"], p_tx=scalars["p_tx"],
        w_hz=scalars["w_hz"], n0=scalars["n0"],
        f_max_ue=scalars["f_max_ue"], f_max_es=scalars["f_max_es"],
        v=scalars["v"], gamma_ue=scalars["gamma_ue"],
        gamma_es=scalars["gamma_es"],
        stability_margin=scalars.get("stability_margin", 1e-3))

    row_spec = pl.BlockSpec((ue_block, c), lambda i: (i, 0))
    vec_spec = pl.BlockSpec((ue_block, 1), lambda i: (i, 0))
    out = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[row_spec, row_spec, row_spec, row_spec,
                  vec_spec, vec_spec, vec_spec, vec_spec, vec_spec],
        out_specs=row_spec,
        out_shape=jax.ShapeDtypeStruct((macs.shape[0], c), jnp.float32),
        interpret=interpret,
    )(macs.astype(jnp.float32), params_b.astype(jnp.float32),
      acts.astype(jnp.float32), psi.astype(jnp.float32),
      col(L), col(lam), col(gain), col(q_energy), col(q_memory))
    return out[:n]


def partition_sweep_batched(macs, params_b, acts, psi, L, lam, gain, q_energy,
                            q_memory, scalars, *, ue_block: int = 8,
                            interpret: bool = False):
    """Batched sweep over B independent cells in ONE kernel launch.

    Tables are (B, N, C), vectors (B, N); scalars are shared across cells
    (they are baked into the kernel as compile-time constants).  The B*N UE
    rows are flattened onto the kernel's UE-block grid -- cells never
    interact row-wise, and the even-split decoupling stays per-cell via
    ``n_total=N``.  Returns the (B, N, C) objective table.
    """
    b, n, c = macs.shape
    flat = lambda t: t.reshape((b * n,) + t.shape[2:])
    out = partition_sweep_pallas(
        flat(macs), flat(params_b), flat(acts), flat(psi),
        flat(L), flat(lam), flat(gain), flat(q_energy), flat(q_memory),
        scalars, ue_block=ue_block, interpret=interpret, n_total=n)
    return out.reshape(b, n, c)
