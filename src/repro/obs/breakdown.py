"""Per-request E2E delay breakdown: serving ticks -> the paper's serial
queueing stages.

The paper evaluates end-to-end delay as a serial queue (UE compute ->
uplink -> ES queue -> ES compute).  The serving engine measures the same
request journey in *ticks* (one ``ServingEngine.step()`` == one tick), and
this module partitions each completed request's E2E tick count into stages
that sum EXACTLY -- no tick is lost or double-counted, pinned by
tests/test_obs.py on both engine modes including preemption:

========== ==================================== ==========================
stage      serving definition (ticks)           paper-stage analog
========== ==================================== ==========================
queue_wait ticks spent queued, excluding each   ES queue wait (the arrival
           admission tick; re-queues after      backlog A_i(t) draining)
           preemption count here too
prefill    one tick per admission (the prompt   UE-side compute + uplink
           is prefilled and its first token     (the request's input
           sampled at the admit tick); >1 only  reaching ES service)
           after preemption = recompute
decode     complete - last admit: decode        ES compute (ES-side
           dispatches the request rode          inference service)
preempted  ticks decoded then discarded by a    recompute overhead -- the
           preemption (output cleared, KV       price of contention; no
           freed, re-queued)                    paper analog (the paper's
                                                queues never evict)
========== ==================================== ==========================

Identity (per request): ``queue_wait + prefill + decode + preempted ==
complete - submit``.  Derivation: with enqueue times ``q_0 = submit, q_i =
preempt_{i-1}`` and admissions ``a_0..a_k``, the stage sums telescope --
``sum(a_i - q_i - 1) + (k+1) + sum(p_i - a_i) + (complete - a_k)`` collapses
to ``complete - submit``.

The raw events come from :class:`repro.traffic.recorder.TrafficRecorder`
(which grew ``record_preempt`` alongside submit/admit/complete); use
``TrafficRecorder.delay_breakdowns()`` for the recorder-facing entry point.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Mapping


@dataclasses.dataclass(frozen=True)
class DelayBreakdown:
    """One completed request's E2E ticks split into paper stages."""

    rid: int
    queue_wait: int     # queued ticks (initial + every post-preempt requeue)
    prefill: int        # admission ticks: 1 + one recompute per preemption
    decode: int         # decode ticks after the final admission
    preempted: int      # decoded-then-discarded ticks
    n_admits: int
    n_preempts: int

    @property
    def e2e(self) -> int:
        """Stage sum == ``complete - submit`` exactly (see module doc)."""
        return self.queue_wait + self.prefill + self.decode + self.preempted

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["e2e"] = self.e2e
        return d


def from_events(rid: int, submit, admits, preempts,
                complete) -> DelayBreakdown | None:
    """Build a breakdown from raw lifecycle ticks; None while the request
    is still in flight (no submit/admit/complete yet)."""
    admits, preempts = list(admits), list(preempts)
    if submit is None or complete is None or not admits:
        return None
    if len(admits) != len(preempts) + 1:
        raise ValueError(
            f"request {rid}: {len(admits)} admissions vs {len(preempts)} "
            f"preemptions -- a completed request must have exactly one more "
            f"admit than preempt")
    enqueues = [submit] + preempts
    queue_wait = sum(a - q - 1 for a, q in zip(admits, enqueues))
    preempted = sum(p - a for p, a in zip(preempts, admits))
    if queue_wait < 0 or preempted < 0 or complete < admits[-1]:
        raise ValueError(f"request {rid}: non-causal event order "
                         f"(submit={submit}, admits={admits}, "
                         f"preempts={preempts}, complete={complete})")
    return DelayBreakdown(rid=rid, queue_wait=queue_wait,
                          prefill=len(admits),
                          decode=complete - admits[-1],
                          preempted=preempted,
                          n_admits=len(admits), n_preempts=len(preempts))


STAGES = ("queue_wait", "prefill", "decode", "preempted", "e2e")


def stage_summary(breakdowns: Mapping[int, DelayBreakdown]
                  | Iterable[DelayBreakdown]) -> dict[str, dict]:
    """Per-stage {n, mean, p50, p90, p99, max} over completed requests
    (ticks) -- the ``python -m repro.obs`` summary table's data."""
    import numpy as np
    if isinstance(breakdowns, Mapping):
        breakdowns = breakdowns.values()
    bds = list(breakdowns)
    out: dict[str, dict] = {}
    for stage in STAGES:
        vals = np.asarray([getattr(b, stage) for b in bds], np.int64)
        if not len(vals):
            out[stage] = {"n": 0}
            continue
        out[stage] = {"n": int(len(vals)),
                      "mean": float(np.mean(vals)),
                      "p50": float(np.percentile(vals, 50)),
                      "p90": float(np.percentile(vals, 90)),
                      "p99": float(np.percentile(vals, 99)),
                      "max": int(np.max(vals))}
    return out
