"""Per-request E2E delay breakdown: serving ticks -> the paper's serial
queueing stages.

The paper evaluates end-to-end delay as a serial queue (UE compute ->
uplink -> ES queue -> ES compute).  The serving engine measures the same
request journey in *ticks* (one ``ServingEngine.step()`` == one tick), and
this module partitions each completed request's E2E tick count into stages
that sum EXACTLY -- no tick is lost or double-counted, pinned by
tests/test_obs.py on both engine modes including preemption:

========== ==================================== ==========================
stage      serving definition (ticks)           paper-stage analog
========== ==================================== ==========================
queue_wait ticks spent queued, excluding each   ES queue wait (the arrival
           admission tick; re-queues after      backlog A_i(t) draining)
           preemption count here too
prefill    admit tick through prefill-done      UE-side compute + uplink
           tick, inclusive, per admission       (the request's input
           window: 1 tick for whole-prompt      reaching ES service)
           prefill (first token sampled at the
           admit tick), several for chunked
           prefill; a preempted-mid-prefill
           window counts admit..preempt here
decode     complete - last prefill-done:        ES compute (ES-side
           decode dispatches the request rode   inference service)
preempted  ticks decoded then discarded by a    recompute overhead -- the
           preemption (output cleared, KV       price of contention; no
           freed, re-queued)                    paper analog (the paper's
                                                queues never evict)
========== ==================================== ==========================

Identity (per request): ``queue_wait + prefill + decode + preempted ==
complete - submit``.  Derivation: with enqueue times ``q_0 = submit, q_i =
preempt_{i-1}``, admissions ``a_0..a_k`` and per-window prefill-done ticks
``f_i`` (``a_i <= f_i <= p_i``; ``f_i = p_i`` when window ``i`` was
preempted mid-prefill, ``f_k <= complete``), the stage sums telescope --
``sum(a_i - q_i - 1) + sum(f_i - a_i + 1) + sum_{i<k}(p_i - f_i) +
(complete - f_k)`` collapses to ``complete - submit`` because ``q_{i+1} =
p_i``.  The identity holds for ANY in-window choice of ``f_i``, so legacy
event streams without prefill-done ticks still sum exactly under the
``f_i = a_i`` fallback (the pre-chunked one-tick-per-admission accounting).

The raw events come from :class:`repro.traffic.recorder.TrafficRecorder`
(which grew ``record_preempt`` and ``record_prefill_done`` alongside
submit/admit/complete); use ``TrafficRecorder.delay_breakdowns()`` for the
recorder-facing entry point.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Mapping


@dataclasses.dataclass(frozen=True)
class DelayBreakdown:
    """One completed request's E2E ticks split into paper stages."""

    rid: int
    queue_wait: int     # queued ticks (initial + every post-preempt requeue)
    prefill: int        # admit..prefill-done ticks, summed over admissions
    decode: int         # decode ticks after the final prefill completed
    preempted: int      # decoded-then-discarded ticks
    n_admits: int
    n_preempts: int

    @property
    def e2e(self) -> int:
        """Stage sum == ``complete - submit`` exactly (see module doc)."""
        return self.queue_wait + self.prefill + self.decode + self.preempted

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["e2e"] = self.e2e
        return d


def from_events(rid: int, submit, admits, preempts, complete,
                prefill_dones=None) -> DelayBreakdown | None:
    """Build a breakdown from raw lifecycle ticks; None while the request
    is still in flight (no submit/admit/complete yet).

    ``prefill_dones`` are the prefill-completion ticks (one per admission
    window that finished its prompt, in order).  Each done tick is matched
    to the admission window ``[a_i, p_i]`` (final window: ``[a_k,
    complete]``) containing it -- the windows are disjoint because a
    re-admission always follows its preemption.  A non-final window with
    no done was preempted mid-prefill: its whole residency ``a_i..p_i``
    counts as prefill (``f_i = p_i``) and contributes zero preempted
    ticks.  ``None`` (legacy streams) falls back to ``f_i = a_i``: one
    prefill tick per admission, the whole-prompt accounting.
    """
    admits, preempts = list(admits), list(preempts)
    if submit is None or complete is None or not admits:
        return None
    if len(admits) != len(preempts) + 1:
        raise ValueError(
            f"request {rid}: {len(admits)} admissions vs {len(preempts)} "
            f"preemptions -- a completed request must have exactly one more "
            f"admit than preempt")
    ends = preempts + [complete]
    if prefill_dones is None:
        dones = list(admits)            # legacy: prefill done at admit tick
    else:
        pool = sorted(prefill_dones)
        dones = []
        for i, (a, e) in enumerate(zip(admits, ends)):
            hit = next((d for d in pool if a <= d <= e), None)
            if hit is not None:
                pool.remove(hit)
            elif i < len(preempts):
                hit = e                 # preempted mid-prefill: all prefill
            else:
                hit = a                 # completed without a done: legacy
            dones.append(hit)
        if pool:
            raise ValueError(
                f"request {rid}: prefill_done ticks {pool} fall outside "
                f"every admission window (admits={admits}, "
                f"preempts={preempts}, complete={complete})")
    enqueues = [submit] + preempts
    queue_wait = sum(a - q - 1 for a, q in zip(admits, enqueues))
    prefill = sum(f - a + 1 for f, a in zip(dones, admits))
    preempted = sum(p - f for p, f in zip(preempts, dones))
    if queue_wait < 0 or preempted < 0 or complete < dones[-1]:
        raise ValueError(f"request {rid}: non-causal event order "
                         f"(submit={submit}, admits={admits}, "
                         f"preempts={preempts}, "
                         f"prefill_dones={prefill_dones}, "
                         f"complete={complete})")
    return DelayBreakdown(rid=rid, queue_wait=queue_wait,
                          prefill=prefill,
                          decode=complete - dones[-1],
                          preempted=preempted,
                          n_admits=len(admits), n_preempts=len(preempts))


STAGES = ("queue_wait", "prefill", "decode", "preempted", "e2e")


def stage_summary(breakdowns: Mapping[int, DelayBreakdown]
                  | Iterable[DelayBreakdown]) -> dict[str, dict]:
    """Per-stage {n, mean, p50, p90, p99, max} over completed requests
    (ticks) -- the ``python -m repro.obs`` summary table's data."""
    import numpy as np
    if isinstance(breakdowns, Mapping):
        breakdowns = breakdowns.values()
    bds = list(breakdowns)
    out: dict[str, dict] = {}
    for stage in STAGES:
        vals = np.asarray([getattr(b, stage) for b in bds], np.int64)
        if not len(vals):
            out[stage] = {"n": 0}
            continue
        out[stage] = {"n": int(len(vals)),
                      "mean": float(np.mean(vals)),
                      "p50": float(np.percentile(vals, 50)),
                      "p90": float(np.percentile(vals, 90)),
                      "p99": float(np.percentile(vals, 99)),
                      "max": int(np.max(vals))}
    return out
