"""Engine-facing telemetry hooks: metric handles + lifecycle callbacks.

One :class:`EngineHooks` instance per :class:`ServingEngine` (built when
the engine is handed a ``telemetry=`` object; ``engine.obs is None``
otherwise, so the disabled path costs one attribute check per call site).

Every callback reads ONLY host-side state the engine already materialized
-- its numpy arrays (``seq_lens``, ``remaining``), Python containers
(``queue``, ``owned``), the allocator free list, and the already-synced
int token ids.  Telemetry must never add a device->host round trip to the
tick path, so the per-tick sampling functions here (``on_prefill``,
``on_decode_tick``, ``sample``) are registered as reprolint ``host-sync``
hot zones (``analysis/rules.py::HOT_ZONES``) and linted to the same bar as
the engine's own step functions; tests/test_analysis.py carries the
near-miss fixture proving a device sync here WOULD be flagged.

Compile-count gauges reuse the same ``jax.jit`` introspection as
``analysis/retrace.py``'s probes (``_cache_size``): reading a jit cache
size is pure host bookkeeping, not a dispatch.
"""
from __future__ import annotations

from ..analysis.retrace import _cache_size
from .metrics import log_buckets

# tick-latency histograms: 1..4096 ticks, x2 resolution
TICK_BUCKETS = log_buckets(1.0, 4096.0, base=2.0)
# wall-seconds histograms: 100us..~1.6s, x2 resolution
SECONDS_BUCKETS = log_buckets(1e-4, 1.6, base=2.0)


class EngineHooks:
    """Metric handles + per-edge callbacks for one engine instance."""

    def __init__(self, telemetry, engine):
        self.tracer = telemetry.tracer
        m = telemetry.metrics
        self.metrics = m
        mode = "sync" if engine.sync_batching else "continuous"
        lbl = {"engine": mode}
        self.submitted = m.counter(
            "serving_submitted_total", "requests entering the queue", **lbl)
        self.admitted = m.counter(
            "serving_admitted_total",
            "admissions (one bucketed prefill each; re-admissions after "
            "preemption count again)", **lbl)
        self.completed = m.counter(
            "serving_completed_total", "requests finished decoding", **lbl)
        self.preempted = m.counter(
            "serving_preemptions_total",
            "youngest-request evictions back to the queue head", **lbl)
        self.decode_ticks = m.counter(
            "serving_decode_steps_total", "jitted decode dispatches", **lbl)
        self.tokens = m.counter(
            "serving_tokens_total", "tokens delivered by completed requests",
            **lbl)
        self.chunk_steps = m.counter(
            "serving_prefill_chunks_total",
            "chunked-prefill chunk dispatches (whole-prompt prefills do "
            "not count here)", **lbl)
        self.block_grows = m.counter(
            "kvpool_block_grows_total",
            "KV blocks appended to active slots mid-decode", **lbl)
        self.queue_depth = m.gauge(
            "serving_queue_depth", "requests waiting in the queue", **lbl)
        self.active_slots = m.gauge(
            "serving_active_slots", "decode slots holding a request", **lbl)
        self.prefill_compiles = m.gauge(
            "serving_prefill_compiles",
            "distinct prefill signatures traced (== jit compilations)",
            **lbl)
        self.decode_compiles = m.gauge(
            "serving_decode_compiles",
            "decode jit cache entries (steady state: 1)", **lbl)
        self.pool_free = m.gauge(
            "kvpool_blocks_free", "allocatable KV blocks", **lbl)
        self.pool_util = m.gauge(
            "kvpool_utilization", "allocated / capacity blocks", **lbl)
        self.pool_frag = m.gauge(
            "kvpool_fragmentation",
            "wasted token slots in allocated blocks / allocated token "
            "capacity (internal fragmentation)", **lbl)
        self.e2e_hist = m.histogram(
            "serving_e2e_ticks", "submit->complete latency",
            buckets=TICK_BUCKETS, **lbl)
        self.wait_hist = m.histogram(
            "serving_queue_wait_ticks",
            "queued ticks before each admission (excluding the admit tick)",
            buckets=TICK_BUCKETS, **lbl)
        self.prefill_hist = m.histogram(
            "serving_prefill_seconds", "wall time of one bucketed prefill "
            "dispatch (incl. its sanctioned sync)",
            buckets=SECONDS_BUCKETS, **lbl)
        self.tick_hist = m.histogram(
            "serving_decode_tick_seconds", "wall time of one decode "
            "dispatch (incl. its sanctioned sync)",
            buckets=SECONDS_BUCKETS, **lbl)
        # rid -> tick of first submit / latest enqueue (submit or preempt)
        self._submit_tick: dict[int, int] = {}
        self._enqueue_tick: dict[int, int] = {}
        # per-tick sampling stride, read by the engine's step functions as
        # an inline `clock % sample_every` check (even an early-returning
        # method call costs us-scale on the cold post-dispatch path);
        # lifecycle-edge callbacks fire on every edge regardless
        self.sample_every = max(1, int(getattr(telemetry,
                                               "sample_every", 16)))
        self._last_steps = engine.decode_steps
        self._engine = engine

    def now(self) -> float:
        """Tracer-clock stamp (us); pass back into on_prefill/on_decode_tick
        as the region start."""
        return self.tracer.now_us()

    # -- lifecycle edges -----------------------------------------------------

    def on_submit(self, req, tick: int) -> None:
        self.submitted.inc()
        self._submit_tick.setdefault(req.rid, tick)
        self._enqueue_tick[req.rid] = tick
        self.tracer.instant("submit", cat="lifecycle", rid=req.rid)

    def on_admit(self, req, tick: int) -> None:
        self.admitted.inc()
        enq = self._enqueue_tick.get(req.rid, tick)
        self.wait_hist.observe(max(tick - enq - 1, 0))
        self.tracer.instant("admit", cat="lifecycle", rid=req.rid)

    def on_prefill_done(self, rid: int, tick: int) -> None:
        """Prompt fully prefilled, first token sampled.  Same tick as the
        admit for whole-prompt prefill; the close of the multi-tick
        admit..done window for chunked prefill (breakdown.py's prefill
        stage)."""
        self.tracer.instant("prefill_done", cat="lifecycle", rid=rid)

    def on_preempt(self, req, tick: int) -> None:
        self.preempted.inc()
        self._enqueue_tick[req.rid] = tick
        self.tracer.instant("preempt", cat="lifecycle", rid=req.rid)

    def on_block_grow(self, n: int = 1) -> None:
        self.block_grows.inc(n)

    def on_complete(self, req, tick: int) -> None:
        self.completed.inc()
        self.tokens.inc(len(req.out))
        # completions are rare: flush the sampled decode-step delta here so
        # the counter is exact once a batch drains, not sample_every behind
        self.decode_ticks.inc(self._engine.decode_steps - self._last_steps)
        self._last_steps = self._engine.decode_steps
        sub = self._submit_tick.pop(req.rid, tick)
        self._enqueue_tick.pop(req.rid, None)
        self.e2e_hist.observe(tick - sub)
        self.tracer.instant("complete", cat="lifecycle", rid=req.rid,
                            e2e_ticks=tick - sub)

    # -- per-tick sampling (reprolint host-sync hot zones) -------------------

    def on_prefill(self, engine, t0_us: float, *, batch: int,
                   width: int, chunked: bool = False) -> None:
        """After a prefill dispatch + its sanctioned int sync: span + wall
        histogram + compile-count gauge (host-side jit introspection).
        ``chunked=True`` marks one chunk dispatch of a streaming prefill
        (width == the chunk size, not the prompt)."""
        t1 = self.tracer.now_us()
        self.prefill_hist.observe((t1 - t0_us) / 1e6)
        self.prefill_compiles.set(engine.prefill_compiles)
        if chunked:
            self.chunk_steps.inc()
        self.tracer.complete("prefill", t0_us, t1, batch=batch, width=width,
                             chunked=chunked)

    def on_decode_tick(self, engine, t0_us: float, live: int) -> None:
        """After a decode dispatch + its sanctioned (slots,) int sync.

        The engine calls this on SAMPLED ticks only (clock stride
        ``sample_every``): the wall-time histogram takes an exemplar
        observation, the tracer records a ``decode_tick`` span, and
        ``serving_decode_steps_total`` catches up exactly by delta against
        ``engine.decode_steps`` (the engine's own dispatch counter,
        incremented before this hook) -- exact at every sampled tick and
        at every completion (``on_complete`` flushes) despite the stride.
        ``Telemetry(sample_every=1)`` makes every tick a sampled tick.
        """
        t1 = self.tracer.now_us()
        self.decode_ticks.inc(engine.decode_steps - self._last_steps)
        self._last_steps = engine.decode_steps
        self.tick_hist.observe((t1 - t0_us) / 1e6)
        self.tracer.complete("decode_tick", t0_us, t1, live=live)

    def sample(self, engine) -> None:
        """Point-in-time gauges from state the engine already holds on
        host; the engine calls this on sampled ticks only (clock stride
        ``sample_every``, default 16).  Gauges are point-in-time reads --
        decimating them loses nothing the histograms/counters don't keep
        -- and even pure host reads cost real per-tick wall time when the
        decode step is a few hundred us (cold caches after each device
        dispatch), so the stride is what keeps the enabled-mode p50
        inside the overhead gate."""
        depth = len(engine.queue)
        busy = sum(1 for r in engine.active if r is not None)
        self.queue_depth.set(depth)
        self.active_slots.set(busy)
        self.tracer.counter("queue_depth", depth)
        if engine.sync_batching:
            sz = _cache_size(engine._decode)
        else:
            sz = _cache_size(engine._decode_paged)
            from ..serving.kvpool import pool_stats
            st = pool_stats(engine.allocator, engine.seq_lens, engine.owned)
            self.pool_free.set(st["n_free"])
            self.pool_util.set(st["utilization"])
            self.pool_frag.set(st["fragmentation"])
        if sz is not None:
            self.decode_compiles.set(sz)
