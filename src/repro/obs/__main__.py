"""``python -m repro.obs`` -- telemetry smoke CLI + the overhead gate.

Default action: replay a small deterministic bursty schedule through a
telemetry-enabled :class:`ServingEngine` (reduced attention stack) and
print the per-request delay-breakdown summary table -- serving ticks
partitioned onto the paper's serial-queue stages (queue wait / prefill /
decode / preemption-recompute), stage sums exactly equal to E2E.  Add:

  --prom PATH      dump the metrics registry in Prometheus text exposition
                   format ("-" for stdout)
  --trace PATH     write the span ring buffer as Chrome-trace JSON (open
                   in https://ui.perfetto.dev)
  --jsonl PATH     same events as JSONL
  --grid           also run a small ScenarioGrid rollout (slots/sec,
                   cells/sec gauges + grid_rollout span)
  --sync           use the synchronized-batch compat engine
  --overhead       run the overhead gate instead: one jit-warmed engine
                   replays a decode-heavy schedule with hooks toggled
                   off/on in interleaved repeats, asserting the pooled
                   enabled per-tick p50 is within --gate (default 5%) of
                   disabled -- instrumentation cost, not compile noise.

Exit status: 0 ok, 1 gate/exactness failure.
"""
from __future__ import annotations

import argparse
import time

import numpy as np


def make_schedule(requests: int, n_ue: int, seed: int, vocab: int,
                  rid_base: int = 0, new_range: tuple = (2, 9)):
    """Deterministic flash-crowd-ish schedule: ~60% of requests burst in
    at ticks 0-1, the rest straggle -- the mix that exercises queueing,
    per-tick admission, and (with a small pool) preemption.  ``new_range``
    is the half-open ``max_new`` draw range (long = decode-heavy)."""
    rng = np.random.default_rng(seed)
    sched = []
    for i in range(requests):
        tick = int(rng.integers(0, 2)) if i < requests * 0.6 \
            else int(rng.integers(2, 12))
        n = int(rng.integers(4, 11))
        sched.append((tick, rid_base + i,
                      rng.integers(0, vocab, n).astype(np.int32),
                      int(rng.integers(*new_range)), i % n_ue))
    sched.sort(key=lambda s: (s[0], s[1]))
    return sched


def replay(cfg, params, schedule, *, sync: bool, slots: int, s_max: int,
           kv_blocks=None, telemetry=None, recorder=None, engine=None,
           max_ticks: int = 5000):
    """Drive one engine through the schedule; returns (engine, recorder,
    per-tick wall durations in seconds).  Pass ``engine=`` to reuse a
    previous replay's engine (jit caches stay warm -- the overhead gate
    measures instrumentation cost, not compiles); schedule rids must be
    fresh then."""
    from ..serving.engine import Request, ServingEngine
    from ..traffic import TrafficRecorder

    if engine is not None:
        eng, rec = engine, engine.recorder
    else:
        rec = TrafficRecorder() if recorder is None else recorder
        eng = ServingEngine(cfg, params, slots=slots, s_max=s_max,
                            recorder=rec, sync_batching=sync,
                            telemetry=telemetry,
                            **({} if kv_blocks is None
                               else {"kv_blocks": kv_blocks}))
    reqs = [Request(rid=rid, prompt=p, max_new=m, ue=ue)
            for _, rid, p, m, ue in schedule]
    base = eng.clock                     # reused engines: shift the schedule
    pending = list(zip((t + base for t, *_ in schedule), reqs))
    ticks = []
    i = 0
    for _ in range(max_ticks):
        while i < len(pending) and pending[i][0] <= eng.clock:
            eng.submit(pending[i][1])
            i += 1
        t0 = time.perf_counter()
        busy = eng.step()
        ticks.append(time.perf_counter() - t0)
        if i == len(pending) and not busy:
            break
    assert all(r.done for r in reqs), "schedule did not drain"
    return eng, rec, ticks


def _build_model(arch: str, n_layers: int, seed: int):
    import jax
    from ..configs.base import get_config, reduced
    from ..models import transformer
    cfg = reduced(get_config(arch), n_layers=n_layers)
    params = transformer.init_params(jax.random.PRNGKey(seed), cfg)
    return cfg, params


def print_summary(rec, eng, telemetry) -> bool:
    """Stage table + exactness check + headline metrics; True when every
    request's stage sum equals its recorded E2E latency."""
    from .breakdown import STAGES, stage_summary

    bds = rec.delay_breakdowns()
    summ = stage_summary(bds)
    print(f"\nper-request delay breakdown over {len(bds)} completed "
          f"requests (engine ticks; paper-stage mapping in "
          f"docs/observability.md):\n")
    hdr = f"{'stage':<11} {'n':>4} {'mean':>8} {'p50':>7} {'p90':>7} " \
          f"{'p99':>7} {'max':>6}"
    print(hdr)
    print("-" * len(hdr))
    for stage in STAGES:
        s = summ[stage]
        if not s["n"]:
            print(f"{stage:<11} {0:>4}")
            continue
        print(f"{stage:<11} {s['n']:>4} {s['mean']:>8.2f} {s['p50']:>7.1f} "
              f"{s['p90']:>7.1f} {s['p99']:>7.1f} {s['max']:>6d}")

    lats = {rid: int(lat) for (rid, lat) in zip(sorted(
        r for r, e in rec.events.items()
        if e.submit is not None and e.complete is not None),
        rec.latencies())}
    exact = sum(1 for rid, b in bds.items() if b.e2e == lats.get(rid))
    ok = exact == len(bds) and len(bds) > 0
    print(f"\nexactness: stage sums == recorded E2E for {exact}/{len(bds)} "
          f"requests {'OK' if ok else 'FAIL'}")

    snap = telemetry.metrics.snapshot()
    picks = [k for k in sorted(snap)
             if k.split("{")[0] in (
                 "serving_preemptions_total", "serving_tokens_total",
                 "serving_prefill_compiles", "serving_decode_compiles",
                 "kvpool_block_grows_total", "kvpool_utilization",
                 "kvpool_fragmentation", "grid_slots_per_s",
                 "grid_cells_per_s")]
    if picks:
        print("\nkey metrics:")
        for k in picks:
            v = snap[k]
            print(f"  {k} = {v:.4g}" if isinstance(v, float)
                  else f"  {k} = {v}")
    print(f"\nspans buffered: {len(telemetry.tracer.events())} "
          f"(capacity {telemetry.tracer.capacity})")
    return ok


def overhead_gate(cfg, params, *, sync: bool, slots: int, s_max: int,
                  requests: int, n_ue: int, seed: int, repeats: int,
                  gate: float) -> int:
    """Enabled-vs-disabled per-tick p50 comparison on jit-warm engines.

    ONE engine serves both modes: it is built with telemetry, jit-warmed
    once, then each repeat replays a fresh schedule twice with ``eng.obs``
    toggled off/on.  Toggling the same engine (rather than comparing two
    separately-built engines) measures exactly the instrumentation cost --
    two engines differ by compile-cache placement and allocator state by
    more than the hooks cost.

    The statistic is POOLED: every repeat contributes its per-tick wall
    times to one pool per mode, the mode order flips every repeat (so a
    sustained noise burst lands on both modes), and the gate compares the
    pooled p50s -- ~repeats x 100 ticks per side, so a single noisy
    repeat shifts the median far less than any per-repeat statistic.

    The gate schedule is decode-heavy (few requests, long ``max_new``):
    the default bursty mix leaves p50 straddling the bimodal gap between
    plain decode ticks and admission ticks (solo prefill), where a
    one-tick shift swings p50 by the whole gap and the comparison is
    noise.  With decode ticks in the clear majority, p50 sits inside the
    decode mass on both sides and measures what the gate is for: the
    per-tick instrumentation cost.
    """
    from . import Telemetry

    tel = Telemetry()
    n_req = max(4, requests // 4)
    s_max = max(s_max, 64)
    kw = dict(sync=sync, slots=slots, s_max=s_max)
    sched = make_schedule(n_req, n_ue, seed, cfg.vocab, new_range=(40, 49))
    eng, _, _ = replay(cfg, params, sched, telemetry=tel,
                       **kw)               # compile warmup
    hooks = eng.obs
    pools = {False: [], True: []}
    for r in range(1, repeats + 1):
        order = (False, True) if r % 2 else (True, False)
        for enabled in order:
            eng.obs = hooks if enabled else None
            sched = make_schedule(
                n_req, n_ue, seed, cfg.vocab, new_range=(40, 49),
                rid_base=(2 * r + int(enabled)) * 100_000)
            eng, _, ticks = replay(cfg, params, sched, engine=eng, **kw)
            pools[enabled].extend(ticks)
    eng.obs = hooks
    p50s = {e: float(np.percentile(pools[e], 50)) for e in pools}
    delta = (p50s[True] - p50s[False]) / p50s[False]
    ok = delta <= gate
    print(f"overhead gate: per-tick p50 disabled={p50s[False]*1e6:.0f}us "
          f"enabled={p50s[True]*1e6:.0f}us delta={delta*100:+.1f}% "
          f"(pooled over {repeats} interleaved repeats, "
          f"{len(pools[True])} ticks/side; gate {gate*100:.0f}%) "
          f"{'OK' if ok else 'FAIL'}")
    return 0 if ok else 1


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.obs",
                                 description=__doc__)
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--s-max", type=int, default=32)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--ues", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--sync", action="store_true",
                    help="synchronized-batch compat engine")
    ap.add_argument("--prom", default=None, metavar="PATH",
                    help='Prometheus text exposition ("-" for stdout)')
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="Chrome-trace JSON (Perfetto-openable)")
    ap.add_argument("--jsonl", default=None, metavar="PATH")
    ap.add_argument("--grid", action="store_true",
                    help="also run a small ScenarioGrid rollout")
    ap.add_argument("--overhead", action="store_true",
                    help="run the enabled-vs-disabled overhead gate")
    ap.add_argument("--gate", type=float, default=0.05,
                    help="max allowed enabled/disabled p50 delta")
    ap.add_argument("--repeats", type=int, default=10,
                    help="overhead gate: pooled interleaved repeats")
    args = ap.parse_args(argv)

    cfg, params = _build_model(args.arch, args.layers, args.seed)

    if args.overhead:
        return overhead_gate(cfg, params, sync=args.sync, slots=args.slots,
                             s_max=args.s_max, requests=args.requests,
                             n_ue=args.ues, seed=args.seed,
                             repeats=args.repeats, gate=args.gate)

    from . import Telemetry
    tel = Telemetry()
    sched = make_schedule(args.requests, args.ues, args.seed, cfg.vocab)
    eng, rec, ticks = replay(cfg, params, sched, sync=args.sync,
                             slots=args.slots, s_max=args.s_max,
                             telemetry=tel)
    print(f"replayed {len(sched)} requests over {eng.clock} ticks "
          f"(engine={'sync' if args.sync else 'continuous'}, "
          f"decode_steps={eng.decode_steps}, "
          f"preemptions={eng.preemptions})")

    if args.grid:
        from ..core.scenarios import ScenarioGrid, multicell_grid
        grid = ScenarioGrid(multicell_grid(cells=4, ues=3, seed=args.seed))
        grid.rollout("local", steps=8, seed=args.seed, telemetry=tel)

    ok = print_summary(rec, eng, tel)

    if args.prom == "-":
        print("\n" + tel.metrics.to_prometheus(), end="")
    elif args.prom:
        with open(args.prom, "w") as f:
            f.write(tel.metrics.to_prometheus())
        print(f"wrote {args.prom}")
    if args.trace:
        tel.tracer.export_chrome(args.trace)
        print(f"wrote {args.trace} (open in https://ui.perfetto.dev)")
    if args.jsonl:
        tel.tracer.export_jsonl(args.jsonl)
        print(f"wrote {args.jsonl}")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
