"""Process-local metrics registry: counters, gauges, log-bucketed histograms.

Pure host-side bookkeeping (stdlib only -- no jax, no numpy): every
``inc``/``set``/``observe`` is a couple of Python float ops on values the
caller already holds, so instrumented hot paths never pay a device->host
sync for telemetry (the ``host-sync`` reprolint rule lints the engine-side
read sites; see ``repro.obs.enginehooks`` and ``analysis/rules.py``).

Naming and exposition follow Prometheus conventions:

* counters end in ``_total`` and only go up;
* gauges hold the last sampled value;
* histograms keep per-bucket counts with *inclusive* upper bounds
  (Prometheus ``le`` semantics: a value exactly on a boundary lands in that
  boundary's bucket) plus ``_sum``/``_count``, default boundaries from
  :func:`log_buckets` -- geometric, so tick latencies spanning orders of
  magnitude keep constant relative resolution.

``MetricsRegistry.to_prometheus()`` renders the whole registry in the text
exposition format (scrapeable / diffable); ``snapshot()`` gives the same
numbers as a plain dict for JSON artifacts like ``BENCH_9.json``.
"""
from __future__ import annotations

import bisect
from typing import Iterable


def log_buckets(lo: float = 1.0, hi: float = 1024.0,
                base: float = 2.0) -> tuple[float, ...]:
    """Geometric bucket boundaries ``lo, lo*base, ... >= hi`` (inclusive of
    the first boundary >= hi).  Constant *relative* resolution: the right
    shape for latencies, where p99 can sit orders of magnitude above p50."""
    if lo <= 0 or base <= 1 or hi < lo:
        raise ValueError(f"need lo > 0, base > 1, hi >= lo; got "
                         f"lo={lo}, hi={hi}, base={base}")
    out = [float(lo)]
    while out[-1] < hi:
        out.append(out[-1] * base)
    return tuple(out)


def _fmt(v: float) -> str:
    """Prometheus-style number: integers without a trailing .0."""
    f = float(v)
    return str(int(f)) if f == int(f) else repr(f)


def _label_str(labels: dict[str, str]) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{labels[k]}"' for k in sorted(labels))
    return "{" + inner + "}"


class Counter:
    """Monotonic counter (Prometheus ``counter``)."""

    kind = "counter"

    def __init__(self, name: str, help: str = "", labels=None):
        self.name, self.help = name, help
        self.labels = dict(labels or {})
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        if n < 0:
            raise ValueError(f"{self.name}: counters only go up (inc {n})")
        self.value += n


class Gauge:
    """Last-sampled value (Prometheus ``gauge``)."""

    kind = "gauge"

    def __init__(self, name: str, help: str = "", labels=None):
        self.name, self.help = name, help
        self.labels = dict(labels or {})
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Log-bucketed histogram with inclusive upper bounds (``le``)."""

    kind = "histogram"

    def __init__(self, name: str, help: str = "", labels=None,
                 buckets: Iterable[float] | None = None):
        self.name, self.help = name, help
        self.labels = dict(labels or {})
        self.bounds = tuple(sorted(float(b) for b in (buckets
                                                      or log_buckets())))
        if not self.bounds:
            raise ValueError(f"{self.name}: need at least one bucket bound")
        # one slot per finite bound + the +Inf overflow slot
        self.counts = [0] * (len(self.bounds) + 1)
        self.sum = 0.0
        self.count = 0

    def observe(self, v: float) -> None:
        v = float(v)
        # first bound >= v -> that bucket (le is inclusive); past the last
        # finite bound -> +Inf
        self.counts[bisect.bisect_left(self.bounds, v)] += 1
        self.sum += v
        self.count += 1

    def cumulative(self) -> list[tuple[str, int]]:
        """(le, cumulative count) pairs, Prometheus-style."""
        out, running = [], 0
        for bound, c in zip(self.bounds, self.counts):
            running += c
            out.append((_fmt(bound), running))
        out.append(("+Inf", running + self.counts[-1]))
        return out


class MetricsRegistry:
    """Get-or-create registry keyed by (name, labels); one per process or
    per :class:`repro.obs.Telemetry` instance."""

    def __init__(self):
        self._metrics: dict[tuple, Counter | Gauge | Histogram] = {}

    def _get(self, cls, name: str, help: str, labels: dict, **kw):
        key = (name, tuple(sorted(labels.items())))
        m = self._metrics.get(key)
        if m is None:
            m = cls(name, help, labels, **kw)
            self._metrics[key] = m
        elif not isinstance(m, cls):
            raise ValueError(f"metric {name!r} already registered as "
                             f"{m.kind}, requested {cls.kind}")
        return m

    def counter(self, name: str, help: str = "", **labels) -> Counter:
        return self._get(Counter, name, help, labels)

    def gauge(self, name: str, help: str = "", **labels) -> Gauge:
        return self._get(Gauge, name, help, labels)

    def histogram(self, name: str, help: str = "", buckets=None,
                  **labels) -> Histogram:
        return self._get(Histogram, name, help, labels, buckets=buckets)

    def __iter__(self):
        return iter(self._metrics.values())

    def __len__(self):
        return len(self._metrics)

    def snapshot(self) -> dict:
        """Plain-dict view (JSON-ready): scalars for counters/gauges,
        ``{sum, count, buckets}`` for histograms."""
        out: dict = {}
        for m in self._metrics.values():
            key = m.name + _label_str(m.labels)
            if isinstance(m, Histogram):
                out[key] = {"sum": m.sum, "count": m.count,
                            "buckets": {le: n for le, n in m.cumulative()}}
            else:
                out[key] = m.value
        return out

    def to_prometheus(self) -> str:
        """Text exposition format (one HELP/TYPE header per metric name)."""
        lines: list[str] = []
        seen_headers: set[str] = set()
        for m in self._metrics.values():
            if m.name not in seen_headers:
                seen_headers.add(m.name)
                if m.help:
                    lines.append(f"# HELP {m.name} {m.help}")
                lines.append(f"# TYPE {m.name} {m.kind}")
            lbl = _label_str(m.labels)
            if isinstance(m, Histogram):
                for le, c in m.cumulative():
                    blbl = dict(m.labels, le=le)
                    lines.append(f"{m.name}_bucket{_label_str(blbl)} {c}")
                lines.append(f"{m.name}_sum{lbl} {_fmt(m.sum)}")
                lines.append(f"{m.name}_count{lbl} {m.count}")
            else:
                lines.append(f"{m.name}{lbl} {_fmt(m.value)}")
        return "\n".join(lines) + ("\n" if lines else "")
