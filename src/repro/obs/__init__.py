"""Observability subsystem: metrics, spans, and the E2E delay breakdown.

Three layers, all host-side and zero-cost when absent:

* :mod:`repro.obs.metrics` -- a Prometheus-flavoured registry (counters,
  gauges, log-bucketed histograms) with text-exposition output;
* :mod:`repro.obs.tracer` -- a bounded ring buffer of spans/instants that
  exports Chrome-trace JSON (Perfetto-openable) and JSONL, optionally
  entering ``jax.profiler.TraceAnnotation`` so host spans line up with
  device profiles;
* :mod:`repro.obs.breakdown` -- per-request serving ticks partitioned onto
  the paper's serial-queue stages (queue wait / prefill / decode /
  preemption-recompute), summing exactly to E2E latency.

Wiring: build one :class:`Telemetry` and hand it to the engine --

    from repro.obs import Telemetry
    tel = Telemetry()
    eng = ServingEngine(cfg, params, recorder=rec, telemetry=tel)
    ...
    print(tel.metrics.to_prometheus())
    tel.tracer.export_chrome("trace.json")

Without ``telemetry=`` the engine's ``obs`` attribute stays None and every
instrumentation site is a single falsy attribute check; with it, every
callback reads only host state the engine already materialized (never an
extra device->host sync -- the ``host-sync`` reprolint rule lints the
sampling functions; see ``repro.obs.enginehooks``).  ``python -m
repro.obs`` replays a bursty schedule and prints the stage table, dumps
Prometheus text / Chrome traces, or runs the enabled-vs-disabled overhead
gate.  Full catalog: docs/observability.md.
"""
from .breakdown import STAGES, DelayBreakdown, from_events, stage_summary
from .metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                      log_buckets)
from .tracer import SpanTracer


class Telemetry:
    """One metrics registry + one span tracer, handed around together.

    ``sample_every`` is the gauge-sampling stride in engine ticks (see
    ``EngineHooks.sample``): counters and histograms stay exact, only the
    point-in-time gauges are decimated.  1 = sample every tick (tests).
    """

    def __init__(self, *, trace_capacity: int = 65536,
                 sample_every: int = 16):
        self.metrics = MetricsRegistry()
        self.tracer = SpanTracer(capacity=trace_capacity)
        self.sample_every = sample_every

    def span(self, name: str, **kw):
        return self.tracer.span(name, **kw)


__all__ = ["Telemetry", "MetricsRegistry", "Counter", "Gauge", "Histogram",
           "log_buckets", "SpanTracer", "DelayBreakdown", "from_events",
           "stage_summary", "STAGES"]
