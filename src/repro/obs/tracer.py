"""Span/event tracer: a bounded ring buffer of host-side timing events,
exportable as Chrome-trace JSON (drop the file into https://ui.perfetto.dev
or ``chrome://tracing``) or JSONL (one event per line, grep/jq-friendly).

Timestamps are microseconds since tracer construction (``perf_counter_ns``
based -- monotonic, never wall clock), which is exactly the unit the Chrome
trace format wants in ``ts``/``dur``.  The buffer is a ``deque(maxlen=...)``:
long serving runs keep the most recent ``capacity`` events and never grow
unbounded; recording an event is an O(1) dict append, cheap enough to sit
on the engine tick path (the overhead gate in ``python -m repro.obs
--overhead`` pins enabled-vs-disabled p50 within 5%).

``span(..., device=True)`` additionally enters a
``jax.profiler.TraceAnnotation`` so host spans line up with device traces
when a jax profile is being captured; the jitted programs themselves carry
``jax.named_scope`` annotations (prefill, paged decode, ``commit_prefill``,
the grid scan) for the same alignment inside XLA dumps.
"""
from __future__ import annotations

import contextlib
import json
import time
from collections import deque


class SpanTracer:
    """Bounded in-memory trace buffer (Chrome trace event format)."""

    def __init__(self, capacity: int = 65536, pid: int = 0):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self.pid = pid
        self._events: deque[dict] = deque(maxlen=capacity)
        self._t0_ns = time.perf_counter_ns()

    # -- clock ---------------------------------------------------------------

    def now_us(self) -> float:
        """Microseconds since tracer construction (monotonic)."""
        return (time.perf_counter_ns() - self._t0_ns) / 1e3

    # -- recording -----------------------------------------------------------

    def _push(self, ev: dict) -> None:
        self._events.append(ev)

    def instant(self, name: str, cat: str = "event", tid: int = 0,
                **args) -> None:
        """Zero-duration marker (``ph: "i"``) -- lifecycle edges like
        submit/admit/preempt/complete."""
        self._push({"name": name, "cat": cat, "ph": "i", "s": "t",
                    "ts": self.now_us(), "pid": self.pid, "tid": tid,
                    "args": args})

    def complete(self, name: str, start_us: float, end_us: float,
                 cat: str = "span", tid: int = 0, **args) -> None:
        """Complete event (``ph: "X"``) from explicit start/end stamps --
        the caller timed the region itself (e.g. around a jitted dispatch
        plus its sanctioned host sync)."""
        self._push({"name": name, "cat": cat, "ph": "X",
                    "ts": start_us, "dur": max(end_us - start_us, 0.0),
                    "pid": self.pid, "tid": tid, "args": args})

    def counter(self, name: str, value: float, tid: int = 0) -> None:
        """Counter track (``ph: "C"``) -- e.g. queue depth over time."""
        self._push({"name": name, "cat": "counter", "ph": "C",
                    "ts": self.now_us(), "pid": self.pid, "tid": tid,
                    "args": {"value": float(value)}})

    @contextlib.contextmanager
    def span(self, name: str, cat: str = "span", device: bool = False,
             tid: int = 0, **args):
        """Context manager recording a complete event around its body.

        ``device=True`` also enters ``jax.profiler.TraceAnnotation`` so a
        concurrently-captured jax device profile shows the same region.
        """
        t0 = self.now_us()
        if device:
            import jax
            cm: contextlib.AbstractContextManager = \
                jax.profiler.TraceAnnotation(name)
        else:
            cm = contextlib.nullcontext()
        try:
            with cm:
                yield
        finally:
            self.complete(name, t0, self.now_us(), cat=cat, tid=tid, **args)

    # -- export --------------------------------------------------------------

    def events(self) -> list[dict]:
        return list(self._events)

    def to_chrome(self) -> dict:
        """Chrome trace object (Perfetto/chrome://tracing-loadable)."""
        return {"traceEvents": self.events(), "displayTimeUnit": "ms"}

    def export_chrome(self, path) -> None:
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f)

    def export_jsonl(self, path) -> None:
        with open(path, "w") as f:
            for ev in self._events:
                f.write(json.dumps(ev, sort_keys=True) + "\n")

    @staticmethod
    def load_chrome(path) -> list[dict]:
        """Events back out of an :meth:`export_chrome` file (round-trip
        pinned by tests/test_obs.py)."""
        with open(path) as f:
            obj = json.load(f)
        if not isinstance(obj, dict) or "traceEvents" not in obj:
            raise ValueError(f"{path}: not a Chrome trace object")
        return obj["traceEvents"]

    @staticmethod
    def load_jsonl(path) -> list[dict]:
        with open(path) as f:
            return [json.loads(line) for line in f if line.strip()]
