"""Traffic recorder: turn a live ServingEngine run into an arrival Trace.

:class:`TrafficRecorder` is the observer half of the serving->trace->MEC
loop.  Attach one to a :class:`~repro.serving.engine.ServingEngine`
(``ServingEngine(..., recorder=rec)``) and the engine reports, in units of
its own step clock (one ``step()`` == one tick):

* ``record_submit(rid, t, ue)``   -- request entered the queue;
* ``record_admit(rid, t)``        -- request entered a decode slot (called
  again on every re-admission after a preemption);
* ``record_prefill_done(rid, t)`` -- prompt fully prefilled and first token
  sampled; same tick as the admit for whole-prompt prefill, later for
  chunked prefill (the engine probes for it with ``getattr``, so older
  recorders keep working);
* ``record_preempt(rid, t)``      -- request evicted back to the queue
  head, output discarded (continuous mode only);
* ``record_complete(rid, t)``     -- request finished decoding.

``to_trace`` then bins one of those event streams into the canonical
slot-indexed ``(T, N)`` rate tensor (:class:`repro.traffic.trace.Trace`),
which replays into the MEC environment as a
:class:`~repro.traffic.processes.TraceArrivals` process.  The recorder is
duck-typed -- the engine never imports this module -- so any object with
the ``record_*`` methods can stand in (``record_preempt`` is optional: the
engine probes for it with ``getattr``).

``delay_breakdowns`` maps the recorded ticks onto the paper's serial-queue
stages (queue wait / prefill / decode / preemption-recompute) via
:mod:`repro.obs.breakdown`; per-request stage sums equal E2E latency
exactly (pinned by tests/test_obs.py).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .trace import Trace


@dataclasses.dataclass
class RequestEvents:
    """Lifecycle timestamps (engine ticks) of one request.

    ``ue`` is the originating UE when the caller declared one
    (``Request.ue``); None falls back to ``rid % n_ue`` round-robin at
    trace-binning time.  ``admits``/``preempts`` hold EVERY admission /
    preemption tick (a preempted request is re-admitted later, so it can
    have several); ``admit`` exposes the first admission for the common
    no-preemption case.  ``prefill_dones`` holds the prefill-completion
    tick of each admission window that finished its prompt (chunked
    prefill spends several ticks between admit and done; a preemption
    mid-prefill leaves that window without a done entry).
    """

    rid: int
    ue: int | None = None
    submit: int | None = None
    complete: int | None = None
    admits: list[int] = dataclasses.field(default_factory=list)
    preempts: list[int] = dataclasses.field(default_factory=list)
    prefill_dones: list[int] = dataclasses.field(default_factory=list)

    @property
    def admit(self) -> int | None:
        """First admission tick (time-to-first-service)."""
        return self.admits[0] if self.admits else None

    @property
    def last_admit(self) -> int | None:
        return self.admits[-1] if self.admits else None

    @property
    def queueing_ticks(self) -> int | None:
        """Submit -> first admission (initial queue wait)."""
        if self.submit is None or not self.admits:
            return None
        return self.admits[0] - self.submit

    @property
    def service_ticks(self) -> int | None:
        """Final admission -> complete (the service that counted)."""
        if not self.admits or self.complete is None:
            return None
        return self.complete - self.admits[-1]


class TrafficRecorder:
    """Collects per-request lifecycle events and bins them into a Trace."""

    def __init__(self):
        self.events: dict[int, RequestEvents] = {}

    # -- engine-facing hooks -------------------------------------------------

    def record_submit(self, rid: int, t: int, ue: int | None = None) -> None:
        if ue is not None and ue < 0:
            raise ValueError(f"request {rid}: ue must be >= 0, got {ue}")
        ev = self.events.setdefault(rid, RequestEvents(rid=rid, ue=ue))
        if ue is not None:
            # a resubmit without ue= must not wipe the UE declared earlier
            # (the request would silently fall back to rid % n_ue binning)
            ev.ue = ue
        ev.submit = t

    def record_admit(self, rid: int, t: int) -> None:
        self.events.setdefault(rid, RequestEvents(rid=rid)).admits.append(t)

    def record_preempt(self, rid: int, t: int) -> None:
        self.events.setdefault(rid, RequestEvents(rid=rid)).preempts.append(t)

    def record_prefill_done(self, rid: int, t: int) -> None:
        self.events.setdefault(rid,
                               RequestEvents(rid=rid)).prefill_dones.append(t)

    def record_complete(self, rid: int, t: int) -> None:
        self.events.setdefault(rid, RequestEvents(rid=rid)).complete = t

    # -- analysis ------------------------------------------------------------

    def timestamps(self, which: str = "submit") -> list[tuple[int, int]]:
        """(tick, rid) pairs of the chosen event, in rid order; unseen events
        are skipped (e.g. requests still in flight have no ``complete``)."""
        if which not in ("submit", "admit", "complete"):
            raise ValueError(f"unknown event {which!r}")
        out = []
        for rid in sorted(self.events):
            t = getattr(self.events[rid], which)
            if t is not None:
                out.append((int(t), rid))
        return out

    def latencies(self, start: str = "submit",
                  end: str = "complete") -> np.ndarray:
        """Tick deltas ``end - start`` for every request that has both
        events, in rid order.  The default pair is E2E latency
        (submit->complete ticks) -- the paper's end-to-end delay in units
        of the engine clock."""
        for which in (start, end):
            if which not in ("submit", "admit", "complete"):
                raise ValueError(f"unknown event {which!r}")
        out = []
        for rid in sorted(self.events):
            ev = self.events[rid]
            a, b = getattr(ev, start), getattr(ev, end)
            if a is not None and b is not None:
                out.append(b - a)
        return np.asarray(out, np.int64)

    def latency_stats(self, start: str = "submit",
                      end: str = "complete") -> dict:
        """Summary stats of :meth:`latencies`: count, mean, p50, p90, p99,
        max, plus ``mean_queue_wait``.

        Units are ENGINE TICKS throughout (one ``ServingEngine.step()`` ==
        one tick; idle ticks advance the clock too), not wall seconds --
        tick stats are deterministic across machines, wall time is not.
        ``mean_queue_wait`` averages the queue-wait stage of
        :meth:`delay_breakdowns` (total queued ticks including post-
        preemption requeues, excluding each admission tick) over the
        requests with a full lifecycle; it is omitted when none completed.
        Safe on empty (``{"n": 0}``) and single-event sets -- no numpy
        warnings either way.
        """
        lat = self.latencies(start, end)
        if not len(lat):
            return {"n": 0}
        out = {"n": int(len(lat)),
               "mean": float(np.mean(lat)),
               "p50": float(np.percentile(lat, 50)),
               "p90": float(np.percentile(lat, 90)),
               "p99": float(np.percentile(lat, 99)),
               "max": int(np.max(lat))}
        waits = [b.queue_wait for b in self.delay_breakdowns().values()]
        if waits:
            out["mean_queue_wait"] = float(np.mean(waits))
        return out

    def delay_breakdowns(self) -> dict:
        """rid -> :class:`repro.obs.DelayBreakdown` for every request with
        a full lifecycle (submit + >=1 admit + complete): E2E ticks split
        onto the paper's serial-queue stages, summing exactly (see
        ``repro/obs/breakdown.py`` for the stage table and proof)."""
        from ..obs.breakdown import from_events
        out = {}
        for rid in sorted(self.events):
            ev = self.events[rid]
            b = from_events(rid, ev.submit, ev.admits, ev.preempts,
                            ev.complete,
                            prefill_dones=ev.prefill_dones or None)
            if b is not None:
                out[rid] = b
        return out

    def to_trace(self, n_ue: int, *, bin_ticks: int = 1, slot_s: float = 1.0,
                 which: str = "submit", horizon: int | None = None) -> Trace:
        """Bin events into a (T, N) rate trace.

        One trace slot aggregates ``bin_ticks`` engine ticks and spans
        ``slot_s`` seconds of MEC time, so ``rate = count / slot_s`` req/s.
        Requests that declared no ``ue`` spread round-robin (``rid %
        n_ue``); a declared ``ue >= n_ue`` folds onto ``ue % n_ue``.
        ``horizon`` pads/truncates to a fixed slot count (replay wraps, so
        padding with zero-rate slots models an idle tail).
        """
        if bin_ticks < 1:
            raise ValueError("bin_ticks must be >= 1")
        stamps = self.timestamps(which)
        if not stamps and horizon is None:
            raise ValueError(f"no {which!r} events recorded")
        last = max((t for t, _ in stamps), default=0)
        n_slots = horizon if horizon is not None else last // bin_ticks + 1
        counts = np.zeros((n_slots, n_ue), np.float32)
        for t, rid in stamps:
            ue = self.events[rid].ue
            if ue is None:
                ue = rid
            slot = t // bin_ticks
            if slot < n_slots:
                counts[slot, ue % n_ue] += 1.0
        return Trace(rates=counts / np.float32(slot_s), slot_s=slot_s,
                     meta={"source": "serving_recorder", "event": which,
                           "bin_ticks": int(bin_ticks),
                           "n_requests": len(self.events)})
