"""Canonical arrival-trace format: slot-indexed per-UE rate tensors on disk.

A :class:`Trace` is the interchange point of the serving->trace->MEC loop:

* ``rates`` -- float32 ``(T, N)``: per-slot, per-UE arrival rates [req/s];
* ``slot_s`` -- the slot length the rates were binned at [seconds];
* ``meta`` -- free-form JSON-able provenance (source, seed, bin width, ...).

``save``/``load`` round-trip **bit-exactly** through one ``.npz`` file
(float32 in, float32 out -- pinned by tests/test_traffic.py), so a trace
recorded from a live :class:`~repro.serving.engine.ServingEngine` (via
:class:`repro.traffic.recorder.TrafficRecorder`) replays identically on any
machine.  ``process()`` wraps the tensor in a
:class:`~repro.traffic.processes.TraceArrivals` pytree for the env.
"""
from __future__ import annotations

import dataclasses
import json

import jax.numpy as jnp
import numpy as np

from .processes import TraceArrivals

_FORMAT_VERSION = 1


@dataclasses.dataclass(frozen=True)
class Trace:
    """Slot-indexed per-UE arrival-rate trace (see module docstring)."""

    rates: np.ndarray                      # (T, N) float32 req/s
    slot_s: float = 1.0
    meta: dict = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        rates = np.asarray(self.rates, np.float32)
        if rates.ndim != 2:
            raise ValueError(f"rates must be (T, N), got {rates.shape}")
        object.__setattr__(self, "rates", rates)

    @property
    def n_slots(self) -> int:
        return self.rates.shape[0]

    @property
    def n_ue(self) -> int:
        return self.rates.shape[1]

    def process(self) -> TraceArrivals:
        """The env-side arrival process replaying this trace (wraps at T)."""
        return TraceArrivals(rates=jnp.asarray(self.rates))

    def shifted(self, offset: int) -> "Trace":
        """Rotate the trace by ``offset`` slots (per-cell diversity from one
        recording: cell b replays ``trace.shifted(b * stride)``)."""
        return dataclasses.replace(
            self, rates=np.roll(self.rates, -int(offset), axis=0),
            meta={**self.meta, "shifted_by": int(offset)})

    def save(self, path) -> None:
        np.savez(path, rates=self.rates,
                 slot_s=np.float64(self.slot_s),
                 version=np.int64(_FORMAT_VERSION),
                 meta=np.bytes_(json.dumps(self.meta).encode()))

    @staticmethod
    def load(path) -> "Trace":
        with np.load(path, allow_pickle=False) as z:
            version = int(z["version"])
            if version > _FORMAT_VERSION:
                raise ValueError(f"trace format v{version} is newer than "
                                 f"this reader (v{_FORMAT_VERSION})")
            return Trace(rates=z["rates"], slot_s=float(z["slot_s"]),
                         meta=json.loads(z["meta"].item().decode()))


def from_process(process, horizon: int, key=None, slot_s: float = 1.0,
                 meta: dict | None = None) -> Trace:
    """Materialize any arrival process into a Trace (see
    :func:`repro.traffic.processes.materialize`)."""
    from .processes import materialize
    rates = materialize(process, horizon, key)
    base = {"source": f"process:{getattr(process, 'kind', type(process).__name__)}"}
    return Trace(rates=rates, slot_s=slot_s, meta={**base, **(meta or {})})
