"""CLI for the traffic subsystem.

  python -m repro.traffic --list           # generator + scenario catalogue
  python -m repro.traffic --show trace.npz # inspect a saved trace
"""
from __future__ import annotations

import argparse

import numpy as np


def _list() -> None:
    from . import processes
    print("Arrival processes (repro.traffic.processes):")
    for line in processes.describe().splitlines():
        print(f"  {line}")
    from ..core import scenarios as sc
    traffic_names = [n for n in sc.names()
                     if n in ("mmpp_burst", "diurnal", "flash_crowd",
                              "trace_replay", "peak_window", "fixed_rate")]
    print("\nTraffic-driven scenarios (repro.core.scenarios):")
    for name in traffic_names:
        doc = (sc._REGISTRY[name].__doc__ or "").strip().splitlines()
        print(f"  {name}: {doc[0] if doc else ''}")
    print("\nSee docs/traffic.md for the trace format and the "
          "serving->trace->MEC replay walkthrough.")


def _show(path: str) -> None:
    from .trace import Trace
    tr = Trace.load(path)
    print(f"{path}: T={tr.n_slots} slots x N={tr.n_ue} UEs, "
          f"slot_s={tr.slot_s:g}")
    print(f"  mean rate {np.mean(tr.rates):.3f} req/s, "
          f"peak {np.max(tr.rates):.3f} req/s")
    print(f"  meta: {tr.meta}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.traffic",
                                 description=__doc__)
    ap.add_argument("--list", action="store_true",
                    help="print the generator/scenario catalogue")
    ap.add_argument("--show", metavar="TRACE_NPZ",
                    help="summarize a saved trace file")
    args = ap.parse_args(argv)
    if args.show:
        _show(args.show)
        return 0
    _list()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
