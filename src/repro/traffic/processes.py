"""Stochastic arrival-process library: traffic models for the MEC environment.

The paper's premise is *continuous AI task arrivals* (Sec. II serial queuing
model); its simulations only exercise three synthetic rate modes.  This module
turns the arrival rate into an extensible axis of scenario diversity: each
process is a **registered-pytree dataclass** whose ``__call__(key, t)``
returns the per-UE arrival-rate vector ``lam`` (req/s) for time slot ``t``.

Design contract (what :func:`repro.core.env.step_p` relies on):

* **Pure and jittable** -- ``__call__`` is a pure function of ``(key, t)``
  and the process's own array leaves; no Python-level state.
* **Pytree** -- all numeric attributes are array leaves, so a process rides
  inside :class:`repro.core.env.MecParams`, ``jnp.stack``-s across B cells
  (``repro.core.scenarios.stack_params``), vmaps over the cell axis, and
  shards over the ``("cells",)`` mesh (``repro.core.gridshard``) exactly like
  every other env constant.  Per-UE attributes are shaped ``(N,)`` so the
  same definition broadcasts over UEs.
* **Static type** -- the process *class* is part of the pytree treedef, so
  every cell of one stacked grid must use the same process type (mirroring
  the static ``edge_queueing`` flag).

The MMPP's modulating Markov chain is materialized at construction
(:func:`make_mmpp`) and stored as a ``(T, N)`` regime leaf indexed by
``t % T``: the chain stays genuinely Markov (geometric dwell times, arbitrary
transition matrix) while ``__call__`` stays a pure function of ``t`` --
carrying the chain state through ``MecState`` would leak process internals
into every consumer of the env.  :class:`TraceArrivals` replays a
``(T, N)`` rate tensor the same way (see :mod:`repro.traffic.trace` for the
on-disk format and :mod:`repro.traffic.recorder` for recording one from a
live :class:`~repro.serving.engine.ServingEngine`).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

# name -> process class; the CLI catalogue (python -m repro.traffic --list)
PROCESSES: dict[str, type] = {}


def arrival_process(name: str):
    """Class decorator: register a pytree arrival process under ``name``."""
    def deco(cls):
        cls = dataclasses.dataclass(frozen=True)(cls)
        fields = [f.name for f in dataclasses.fields(cls)]
        jax.tree_util.register_dataclass(cls, data_fields=fields,
                                         meta_fields=[])
        if name in PROCESSES:
            raise ValueError(f"arrival process {name!r} already registered")
        PROCESSES[name] = cls
        cls.kind = name
        return cls
    return deco


def _f32(x):
    return jnp.asarray(x, jnp.float32)


def per_ue(x, n: int) -> jax.Array:
    """Broadcast a scalar or (N,) array-like to a (N,) float32 leaf."""
    a = np.broadcast_to(np.asarray(x, np.float32), (n,))
    return jnp.asarray(a)


# ---------------------------------------------------------------------------
# Deterministic-in-t processes (key unused)
# ---------------------------------------------------------------------------

@arrival_process("fixed")
class FixedRate:
    """Constant per-UE rate (the paper's Fig. 4 sweep points)."""

    lam: jax.Array          # (N,) req/s

    def __call__(self, key, t) -> jax.Array:
        del key, t
        return self.lam


@arrival_process("peak_window")
class PeakWindow:
    """Constant base rate + an additive peak inside [start, stop) (Fig. 5)."""

    base: jax.Array         # (N,) req/s
    boost: jax.Array        # 0-d, added req/s inside the window
    start: jax.Array        # 0-d int32 slot
    stop: jax.Array         # 0-d int32 slot

    def __call__(self, key, t) -> jax.Array:
        del key
        in_peak = jnp.logical_and(t >= self.start, t < self.stop)
        return self.base + jnp.where(in_peak, self.boost, 0.0)


@arrival_process("diurnal")
class Diurnal:
    """Sinusoidal day/night load: lam = max(0, base + amp*sin(2pi(t+phase)/period))."""

    base: jax.Array         # (N,) req/s
    amp: jax.Array          # (N,) req/s swing
    period: jax.Array       # 0-d, slots per cycle
    phase: jax.Array        # 0-d, slot offset

    def __call__(self, key, t) -> jax.Array:
        del key
        ang = 2.0 * jnp.pi * (t + self.phase) / self.period
        return jnp.maximum(self.base + self.amp * jnp.sin(ang), 0.0)


@arrival_process("flash_crowd")
class FlashCrowd:
    """Base load + a flash-crowd spike at t0 with exponential decay."""

    base: jax.Array         # (N,) req/s
    spike: jax.Array        # 0-d, peak added req/s at t0
    t0: jax.Array           # 0-d int32, event slot
    decay: jax.Array        # 0-d, e-folding time of the spike [slots]

    def __call__(self, key, t) -> jax.Array:
        del key
        dt = jnp.maximum(t - self.t0, 0).astype(jnp.float32)
        burst = self.spike * jnp.exp(-dt / self.decay)
        return self.base + jnp.where(t >= self.t0, burst, 0.0)


# ---------------------------------------------------------------------------
# Stochastic processes (per-slot draws from ``key``)
# ---------------------------------------------------------------------------

@arrival_process("iid_uniform")
class IidUniform:
    """lam ~ U(low, high) iid per UE and slot (the paper's training default)."""

    low: jax.Array          # (N,) req/s
    high: jax.Array         # (N,) req/s

    def __call__(self, key, t) -> jax.Array:
        del t
        return jax.random.uniform(key, self.low.shape, jnp.float32,
                                  self.low, self.high)


@arrival_process("poisson")
class PoissonArrivals:
    """Empirical rate of a Poisson arrival count: N_t ~ Pois(lam * slot_s).

    Models discrete request counts (the serving tier's reality) rather than a
    fluid rate: the per-slot empirical rate N_t / slot_s is integer-granular
    and fluctuates around ``lam`` with variance lam / slot_s.
    """

    lam: jax.Array          # (N,) nominal req/s
    slot_s: jax.Array       # 0-d, slot length in seconds

    def __call__(self, key, t) -> jax.Array:
        del t
        counts = jax.random.poisson(key, self.lam * self.slot_s,
                                    self.lam.shape)
        return counts.astype(jnp.float32) / self.slot_s


@arrival_process("mmpp")
class MMPP:
    """Markov-modulated (bursty) process: a K-state chain picks the rate.

    ``regimes`` holds the pre-simulated modulating chains (one independent
    chain per UE, wrapped at the horizon T); see :func:`make_mmpp`.
    """

    rates: jax.Array        # (K,) req/s per regime
    regimes: jax.Array      # (T, N) int32 regime index per slot and UE

    def __call__(self, key, t) -> jax.Array:
        del key
        horizon = self.regimes.shape[0]
        reg = jax.lax.dynamic_index_in_dim(
            self.regimes, jnp.mod(t, horizon), keepdims=False)
        return self.rates[reg]


@arrival_process("trace")
class TraceArrivals:
    """Replay a slot-indexed (T, N) rate tensor, wrapping at the horizon.

    The replay half of the serving->trace->MEC loop: build one from a
    :class:`repro.traffic.trace.Trace` (``trace.process()``), which in turn
    can come from ``Trace.load`` or a :class:`~repro.traffic.recorder.
    TrafficRecorder` attached to a live ServingEngine.
    """

    rates: jax.Array        # (T, N) req/s

    def __call__(self, key, t) -> jax.Array:
        del key
        horizon = self.rates.shape[0]
        return jax.lax.dynamic_index_in_dim(
            self.rates, jnp.mod(t, horizon), keepdims=False)


# ---------------------------------------------------------------------------
# Constructors (host-side; deterministic in their seed)
# ---------------------------------------------------------------------------

def make_mmpp(n_ue: int, seed: int = 0, rates=(0.5, 3.0), p_stay: float = 0.92,
              horizon: int = 400, trans: np.ndarray | None = None) -> MMPP:
    """Simulate per-UE modulating Markov chains and wrap them in an MMPP.

    ``p_stay`` builds the default transition matrix (stay with p_stay, else
    jump uniformly to another regime -- geometric dwell ~ 1/(1-p_stay)
    slots); pass ``trans`` (K, K, rows summing to 1) for arbitrary chains.
    Deterministic in ``seed`` (numpy Philox on the host).
    """
    k = len(rates)
    if trans is None:
        if k == 1:
            trans = np.ones((1, 1))
        else:
            off = (1.0 - p_stay) / (k - 1)
            trans = np.full((k, k), off)
            np.fill_diagonal(trans, p_stay)
    trans = np.asarray(trans, np.float64)
    if trans.shape != (k, k) or not np.allclose(trans.sum(1), 1.0):
        raise ValueError(f"trans must be ({k},{k}) with rows summing to 1")
    rng = np.random.default_rng(seed)
    regimes = np.empty((horizon, n_ue), np.int32)
    state = rng.integers(0, k, n_ue)
    cdf = np.cumsum(trans, axis=1)
    for t in range(horizon):
        regimes[t] = state
        u = rng.random(n_ue)
        state = (u[:, None] > cdf[state]).sum(axis=1)
    return MMPP(rates=_f32(rates), regimes=jnp.asarray(regimes))


def materialize(process, horizon: int, key=None) -> np.ndarray:
    """Evaluate a process over slots 0..horizon-1 -> (T, N) float32 rates.

    Per-slot keys are ``fold_in(key, t)`` -- the same stream an env rollout
    would not see (rollouts split from ``MecState.key``), so this is for
    converting processes into traces, not for reproducing a rollout's draws.
    """
    if key is None:
        key = jax.random.PRNGKey(0)

    def at(t):
        return process(jax.random.fold_in(key, t), t)

    rates = jax.vmap(at)(jnp.arange(horizon, dtype=jnp.int32))
    return np.asarray(rates, np.float32)


def describe() -> str:
    """One line per registered process (the --list catalogue)."""
    lines = []
    for name in sorted(PROCESSES):
        doc = (PROCESSES[name].__doc__ or "").strip().splitlines()
        lines.append(f"{name}: {doc[0] if doc else ''}")
    return "\n".join(lines)
