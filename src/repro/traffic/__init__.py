"""Traffic subsystem: arrival-process library + trace record/replay.

Three pieces close the serving->trace->MEC loop:

* :mod:`repro.traffic.processes` -- pure jittable arrival processes
  (``(key, t) -> lam`` pytrees) that plug into ``MecParams.arrival``;
* :mod:`repro.traffic.trace`     -- the canonical slot-indexed ``(T, N)``
  rate-trace format (bit-exact ``.npz`` round-trip) and its replay process;
* :mod:`repro.traffic.recorder`  -- records request lifecycles from a live
  ``ServingEngine`` and bins them into that trace format.

``python -m repro.traffic --list`` prints the generator/scenario catalogue;
see ``docs/traffic.md`` for the full tour.
"""
from .processes import (Diurnal, FixedRate, FlashCrowd, IidUniform, MMPP,
                        PROCESSES, PeakWindow, PoissonArrivals, TraceArrivals,
                        arrival_process, make_mmpp, materialize, per_ue)
from .recorder import RequestEvents, TrafficRecorder
from .trace import Trace, from_process

__all__ = [
    "Diurnal", "FixedRate", "FlashCrowd", "IidUniform", "MMPP", "PROCESSES",
    "PeakWindow", "PoissonArrivals", "TraceArrivals", "arrival_process",
    "make_mmpp", "materialize", "per_ue", "RequestEvents", "TrafficRecorder",
    "Trace", "from_process",
]
