"""Hand-rolled Adam/AdamW on pytrees (no optax in this container).

Used by both the DRL control plane (PPO actor/critic) and the LM data plane
(train_step).  Optimizer-state dtype is configurable: fp32 for <10B models,
bf16 moments for the 90B-400B configs so the dry-run memory analysis fits
(see DESIGN.md §5).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamState(NamedTuple):
    step: jax.Array
    mu: Any       # first moment, pytree like params
    nu: Any       # second moment, pytree like params


def adam(lr: float, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
         weight_decay: float = 0.0, grad_clip: float | None = None,
         state_dtype: jnp.dtype | None = None):
    """Returns (init_fn, update_fn).

    ``update_fn(grads, state, params) -> (new_params, new_state)``.
    ``weight_decay`` applies decoupled (AdamW) decay; ``grad_clip`` is a
    global-norm clip applied before the moment updates.
    """

    def _cast(x):
        return x.astype(state_dtype) if state_dtype is not None else x

    def init_fn(params) -> AdamState:
        zeros = lambda p: _cast(jnp.zeros_like(p))
        return AdamState(step=jnp.zeros((), jnp.int32),
                         mu=jax.tree.map(zeros, params),
                         nu=jax.tree.map(zeros, params))

    def update_fn(grads, state: AdamState, params):
        if grad_clip is not None:
            gnorm = global_norm(grads)
            scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-12))
            grads = jax.tree.map(lambda g: g * scale, grads)
        step = state.step + 1
        b1t = 1.0 - b1 ** step.astype(jnp.float32)
        b2t = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(g, m, v, p):
            g32 = g.astype(jnp.float32)
            m32 = m.astype(jnp.float32) * b1 + (1.0 - b1) * g32
            v32 = v.astype(jnp.float32) * b2 + (1.0 - b2) * jnp.square(g32)
            update = (m32 / b1t) / (jnp.sqrt(v32 / b2t) + eps)
            if weight_decay:
                update = update + weight_decay * p.astype(jnp.float32)
            new_p = p.astype(jnp.float32) - lr * update
            return new_p.astype(p.dtype), _cast(m32), _cast(v32)

        flat = jax.tree.map(upd, grads, state.mu, state.nu, params)
        new_params = jax.tree.map(lambda t: t[0], flat,
                                  is_leaf=lambda t: isinstance(t, tuple))
        new_mu = jax.tree.map(lambda t: t[1], flat,
                              is_leaf=lambda t: isinstance(t, tuple))
        new_nu = jax.tree.map(lambda t: t[2], flat,
                              is_leaf=lambda t: isinstance(t, tuple))
        return new_params, AdamState(step=step, mu=new_mu, nu=new_nu)

    return init_fn, update_fn


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))
