"""Gradient compression for the data-parallel sync (DESIGN §5).

Explicit shard_map data-parallel step with wire compression:

* ``bf16`` mode: the psum operand is bfloat16 -- halves ICI bytes (visible
  as bf16 all-reduces in the dry-run HLO).
* ``int8`` mode: per-tensor symmetric quantization; int32-accumulated psum
  (4x wire reduction) + a scalar psum-max for the scale.
* optional error feedback: the per-device quantization residual is added to
  the next step's gradient, eliminating compression bias over time
  (Seide et al. 2014 / Karimireddy et al. 2019 semantics).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def _quantize_int8(x):
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def make_grad_sync(mesh, axis: str = "data", mode: str = "bf16",
                   error_feedback: bool = True):
    """Returns ``sync(grads, residual) -> (mean_grads, new_residual)`` meant
    to run INSIDE shard_map (operates on local shards, uses lax.psum)."""
    n = mesh.shape[axis]

    def sync_leaf(g, r):
        local = g + (r if error_feedback else 0.0)
        if mode == "bf16":
            wire = local.astype(jnp.bfloat16)
            synced = jax.lax.psum(wire, axis).astype(jnp.float32) / n
            residual = (local - wire.astype(jnp.float32)) if error_feedback \
                else jnp.zeros_like(local)
        elif mode == "int8":
            q, scale = _quantize_int8(local)
            gscale = jax.lax.pmax(scale, axis)
            # requantize against the global scale so psum is exact in int32
            q = jnp.clip(jnp.round(local / gscale), -127, 127).astype(jnp.int32)
            synced = (jax.lax.psum(q, axis).astype(jnp.float32) * gscale) / n
            residual = (local - q.astype(jnp.float32) * gscale) \
                if error_feedback else jnp.zeros_like(local)
        elif mode == "none":
            synced = jax.lax.psum(local, axis) / n
            residual = jnp.zeros_like(local)
        else:
            raise ValueError(mode)
        return synced, residual

    def sync(grads, residual):
        pairs = jax.tree.map(sync_leaf, grads, residual)
        synced = jax.tree.map(lambda t: t[0], pairs,
                              is_leaf=lambda t: isinstance(t, tuple))
        new_res = jax.tree.map(lambda t: t[1], pairs,
                               is_leaf=lambda t: isinstance(t, tuple))
        return synced, new_res

    return sync


def make_dp_train_step(mesh, loss_fn, opt_update, axis: str = "data",
                       mode: str = "bf16", error_feedback: bool = True):
    """Explicit data-parallel train step under shard_map: params replicated,
    batch sharded on ``axis``, gradient sync through the compressor.

    loss_fn(params, batch) -> scalar;  opt_update(grads, opt_state, params).
    State: (params, opt_state, residual) with residual like params.
    """
    sync = make_grad_sync(mesh, axis, mode, error_feedback)

    def local_step(params, opt_state, residual, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        grads, residual = sync(grads, residual)
        params, opt_state = opt_update(grads, opt_state, params)
        return params, opt_state, residual, jax.lax.pmean(loss, axis)

    from jax.experimental.shard_map import shard_map
    rep = P()
    return shard_map(
        local_step, mesh=mesh,
        in_specs=(rep, rep, rep, P(axis)),
        out_specs=(rep, rep, rep, rep),
        check_rep=False)
