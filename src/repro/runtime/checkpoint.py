"""Fault-tolerant checkpointing (no orbax in this container).

Design for 1000+ nodes (DESIGN §5):
  * checkpoints are MESH-AGNOSTIC: host-side full arrays, keyed by tree path
    -- restore can reshard onto any live mesh (elastic restart)
  * ATOMIC: write to a temp dir, fsync, rename; a crashed writer never
    corrupts the latest checkpoint
  * ASYNC: a background thread drains a queue so the training loop never
    blocks on IO (the step only pays for device->host transfer)
  * keep-last-k with a JSON manifest storing step, timestamp and data-stream
    position (the synthetic pipeline is index-based, so restart resumes
    mid-stream exactly)
"""
from __future__ import annotations

import json
import os
import queue
import shutil
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np


def jnp_astype(arr, dtype):
    """dtype cast that understands ml_dtypes (bf16) on both sides."""
    return np.asarray(jnp.asarray(arr).astype(dtype))


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(_key_str(p) for p in path)
        out[key] = np.asarray(leaf)
    return out, treedef


def _key_str(p):
    if hasattr(p, "key"):
        return f"k:{p.key}"
    if hasattr(p, "name"):
        return f"a:{p.name}"
    if hasattr(p, "idx"):
        return f"i:{p.idx}"
    return str(p)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._q: queue.Queue = queue.Queue()
        self._err = None
        self._thread = None
        if async_save:
            self._thread = threading.Thread(target=self._worker, daemon=True)
            self._thread.start()

    # -- save ---------------------------------------------------------------

    def save(self, step: int, tree, *, extra: dict | None = None,
             blocking: bool = False):
        """Snapshot to host memory immediately; write in the background."""
        arrays, _ = _flatten(jax.tree.map(np.asarray, tree))
        payload = (step, arrays, extra or {})
        if self._thread is None or blocking:
            self._write(*payload)
        else:
            self._q.put(payload)

    def _worker(self):
        while True:
            item = self._q.get()
            try:
                self._write(*item)
            except Exception as e:  # surfaced on next wait()
                self._err = e
            finally:
                self._q.task_done()

    def wait(self):
        """Block until queued saves land (call before shutdown).

        ``Queue.join`` (paired with ``task_done`` in the worker) waits for
        in-flight writes too; polling ``empty()`` raced with a write that had
        been popped but not yet published.
        """
        if self._thread is not None:
            self._q.join()
        if self._err:
            raise self._err

    def _write(self, step: int, arrays: dict, extra: dict):
        final = os.path.join(self.dir, f"step_{step:010d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        # npz cannot store ml_dtypes (bf16 etc.): persist as raw-bits views
        # with the true dtype recorded in the manifest.
        dtypes = {}
        storable = {}
        for k, v in arrays.items():
            dtypes[k] = str(v.dtype)
            if v.dtype.kind == "V" or str(v.dtype) == "bfloat16":
                v = v.view(np.uint16) if v.dtype.itemsize == 2 \
                    else v.view(np.uint8)
            storable[k] = v
        np.savez(os.path.join(tmp, "arrays.npz"), **storable)
        manifest = {"step": step, "time": time.time(), "extra": extra,
                    "keys": sorted(arrays.keys()), "dtypes": dtypes}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, final)          # atomic publish
        self._gc()

    def _gc(self):
        steps = self.list_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:010d}"),
                          ignore_errors=True)

    # -- restore --------------------------------------------------------------

    def list_steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self):
        steps = self.list_steps()
        return steps[-1] if steps else None

    def restore(self, like_tree, step: int | None = None,
                shardings=None):
        """Restore into the structure of ``like_tree``.  ``shardings`` (a
        matching pytree of NamedShardings) re-shards onto the live mesh --
        the elastic-restart path: the checkpoint does not care what mesh it
        was written from."""
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:010d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(path, "arrays.npz"))
        dtypes = manifest.get("dtypes", {})
        flat, treedef = jax.tree_util.tree_flatten_with_path(like_tree)
        leaves = []
        for pathk, leaf in flat:
            key = "/".join(_key_str(p) for p in pathk)
            arr = data[key]
            want = dtypes.get(key)
            if want == "bfloat16":
                import ml_dtypes
                arr = arr.view(ml_dtypes.bfloat16)
            if hasattr(leaf, "dtype") and str(arr.dtype) != str(leaf.dtype):
                arr = np.asarray(jnp_astype(arr, leaf.dtype))
            leaves.append(arr)
        tree = jax.tree_util.tree_unflatten(treedef, leaves)
        if shardings is not None:
            tree = jax.tree.map(jax.device_put, tree, shardings)
        return tree, manifest

    def restore_or_none(self, like_tree, shardings=None):
        try:
            return self.restore(like_tree, shardings=shardings)
        except FileNotFoundError:
            return None, None
