"""Straggler mitigation + elastic restart policies (DESIGN §5).

Pure control-plane logic, unit-testable with a fake clock:

* ``StragglerMonitor`` -- per-step deadline derived from a running median;
  steps exceeding ``threshold x median`` are flagged; repeated offenders
  trigger a re-dispatch recommendation (on a real cluster: swap the slow
  host out of the mesh and resume from the last checkpoint).
* ``ElasticPolicy`` -- given the live device count, decide the next mesh and
  whether a restore-and-reshard is needed (checkpoints are mesh-agnostic,
  runtime/checkpoint.py).
* ``RestartLoop`` -- the driver wrapper: run step fn, on failure restore
  latest checkpoint and continue; bounded retries.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable


@dataclasses.dataclass
class StragglerEvent:
    step: int
    duration: float
    median: float


class StragglerMonitor:
    def __init__(self, threshold: float = 2.0, window: int = 50,
                 patience: int = 3, clock: Callable[[], float] = time.monotonic):
        self.threshold = threshold
        self.window: deque = deque(maxlen=window)
        self.patience = patience
        self.clock = clock
        self.consecutive_slow = 0
        self.events: list[StragglerEvent] = []
        self._t0 = None
        self._step = 0

    def start_step(self, step: int):
        self._step = step
        self._t0 = self.clock()

    def end_step(self) -> bool:
        """Returns True if this step was a straggler."""
        dt = self.clock() - self._t0
        median = self.median()
        self.window.append(dt)
        if median is not None and dt > self.threshold * median:
            self.consecutive_slow += 1
            self.events.append(StragglerEvent(self._step, dt, median))
            return True
        self.consecutive_slow = 0
        return False

    def median(self):
        if len(self.window) < 5:
            return None
        s = sorted(self.window)
        return s[len(s) // 2]

    @property
    def should_redispatch(self) -> bool:
        """Persistent slowness -> recommend swapping hardware + restore."""
        return self.consecutive_slow >= self.patience

    def deadline(self) -> float | None:
        m = self.median()
        return None if m is None else self.threshold * m


class ElasticPolicy:
    """Largest (data, model) mesh the live device pool supports, preferring
    to keep the model axis intact (resharding params across a changed model
    axis is the expensive path)."""

    def __init__(self, target_model: int):
        self.target_model = target_model

    def plan(self, live_devices: int, current_shape: tuple | None = None):
        model = min(self.target_model, live_devices)
        while live_devices % model:
            model -= 1
        shape = (live_devices // model, model)
        changed = current_shape is not None and shape != tuple(current_shape)
        return {"shape": shape, "axes": ("data", "model"),
                "reshard_required": changed}


class RestartLoop:
    """run(step_fn) with restore-on-failure semantics.

    ``step_fn(state, step) -> state``;  ``save_fn(state, step)``;
    ``restore_fn() -> (state, step) | None``.
    """

    def __init__(self, save_fn, restore_fn, checkpoint_every: int = 100,
                 max_restarts: int = 3):
        self.save_fn = save_fn
        self.restore_fn = restore_fn
        self.every = checkpoint_every
        self.max_restarts = max_restarts
        self.restarts = 0

    def run(self, step_fn, state, n_steps: int, start_step: int = 0):
        step = start_step
        while step < n_steps:
            try:
                state = step_fn(state, step)
                step += 1
                if step % self.every == 0:
                    self.save_fn(state, step)
            except Exception:
                self.restarts += 1
                if self.restarts > self.max_restarts:
                    raise
                restored = self.restore_fn()
                if restored is None:
                    raise
                state, step = restored
        return state, step
