"""Qwen3-0.6B: dense, GQA kv=8, QK-norm.  [hf:Qwen/Qwen3-8B; hf]"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="qwen3-0.6b",
    family="dense",
    n_layers=28,
    d_model=1024,
    n_heads=16,
    n_kv=8,
    head_dim=128,
    d_ff=3072,
    vocab=151936,
    rope_theta=1e6,
    qk_norm=True,
    block_pattern=("g",),
    source="hf:Qwen/Qwen3-0.6B family",
))
