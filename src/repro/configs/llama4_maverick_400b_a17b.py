"""Llama-4 Maverick 400B-A17B: alternating dense/MoE layers, 128 experts
top-1 + shared expert, GQA kv=8, early-fusion multimodal (text backbone here).
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv=8,
    head_dim=128,
    d_ff=8192,
    vocab=202048,
    rope_theta=5e5,
    # 24 x (dense layer, MoE layer): the interleave that lands total params
    # at ~400B with 128 routed experts (d_ff = 8192 for both halves).
    block_pattern=("g", "m"),
    n_experts=128,
    top_k=1,
    shared_expert=True,
    capacity_factor=1.25,
    opt_state_dtype="bfloat16",   # 400B: fp32 moments cannot fit 256x16GB
    fsdp=True,
    source="hf:meta-llama/Llama-4-Scout-17B-16E (scaled per assignment)",
))
