"""Architecture config schema + registry for the 10 assigned architectures.

Heterogeneous layer stacks are expressed as a repeating ``block_pattern`` of
layer *kinds* plus an optional ``tail_pattern`` (DESIGN.md §4, "block-scan"):

    kind  mixer                      channel mixer
    "g"   global self-attention      dense FFN
    "l"   sliding-window attention   dense FFN
    "m"   global self-attention      MoE FFN
    "x"   cross-attention            dense FFN      (VLM image layers)
    "r"   RG-LRU recurrent block     dense FFN      (Griffin)
    "s"   Mamba2 SSD block           (none; the SSD block is the layer)
    "e"   encoder self-attention     dense FFN      (non-causal; enc-dec)
    "d"   self-attn + cross-attn     dense FFN      (enc-dec decoder layer)

``n_layers * [pattern]`` must tile as  len(pattern) * n_units + len(tail).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Tuple

REGISTRY: dict[str, "ArchConfig"] = {}

_ARCH_MODULES = [
    "llama4_maverick_400b_a17b",
    "moonshot_v1_16b_a3b",
    "recurrentgemma_2b",
    "qwen3_0_6b",
    "qwen1_5_110b",
    "starcoder2_7b",
    "gemma3_1b",
    "mamba2_1_3b",
    "llama_3_2_vision_90b",
    "seamless_m4t_large_v2",
]


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                       # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int                     # decoder layers (enc-dec: decoder side)
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: int = 0                 # 0 -> d_model // n_heads
    # attention flavor
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 1e4
    window: int = 0                   # sliding-window size for "l" layers
    # stack pattern
    block_pattern: Tuple[str, ...] = ("g",)
    tail_pattern: Tuple[str, ...] = ()
    # FFN
    gated_ffn: bool = True
    # MoE
    n_experts: int = 0
    top_k: int = 0
    shared_expert: bool = False
    moe_dff: int = 0                  # expert hidden dim (defaults to d_ff)
    capacity_factor: float = 1.25
    # SSM (mamba2)
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    conv_width: int = 4
    # RG-LRU (recurrentgemma)
    rnn_width: int = 0                # 0 -> d_model
    # encoder (enc-dec archs)
    enc_layers: int = 0
    enc_causal: bool = False
    # modality frontend stub
    frontend: str | None = None       # "vision" | "audio"
    n_frontend_tokens: int = 0
    # numerics / training
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    opt_state_dtype: str = "float32"  # bf16 for the >=90B configs (DESIGN §5)
    remat: bool = True
    tie_embeddings: bool = True
    # distribution
    fsdp: bool = False                # shard params/opt over the data axis
    # notes for DESIGN/EXPERIMENTS
    source: str = ""

    def __post_init__(self):
        unit = len(self.block_pattern)
        tail = len(self.tail_pattern)
        if (self.n_layers - tail) % unit != 0:
            raise ValueError(
                f"{self.name}: n_layers={self.n_layers} != "
                f"{unit}*k + {tail} (pattern {self.block_pattern} + tail)")

    @property
    def n_units(self) -> int:
        return (self.n_layers - len(self.tail_pattern)) // len(self.block_pattern)

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def resolved_moe_dff(self) -> int:
        return self.moe_dff or self.d_ff

    @property
    def resolved_rnn_width(self) -> int:
        return self.rnn_width or self.d_model

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k decode (DESIGN §4 skip rule)."""
        kinds = set(self.block_pattern) | set(self.tail_pattern)
        return ("g" not in kinds and "m" not in kinds and "d" not in kinds) or (
            "l" in kinds and self.window > 0)


def register(cfg: ArchConfig) -> ArchConfig:
    REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ArchConfig:
    if not REGISTRY:
        load_all()
    key = name.replace("_", "-")
    if key not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(REGISTRY)}")
    return REGISTRY[key]


def load_all() -> dict[str, ArchConfig]:
    for mod in _ARCH_MODULES:
        importlib.import_module(f"repro.configs.{mod}")
    return dict(REGISTRY)


def reduced(cfg: ArchConfig, **overrides) -> ArchConfig:
    """Tiny same-family config for CPU smoke tests: keeps the layer *pattern*
    and every architectural flag, shrinks all dimensions."""
    unit = len(cfg.block_pattern)
    tail = len(cfg.tail_pattern)
    defaults = dict(
        name=cfg.name + "-smoke",
        n_layers=2 * unit + tail,
        d_model=64,
        n_heads=4,
        n_kv=min(cfg.n_kv, 4) if cfg.n_kv else 0,
        head_dim=16,
        d_ff=128,
        moe_dff=32 if cfg.moe_dff else 0,
        vocab=256,
        n_experts=min(cfg.n_experts, 8),
        ssm_state=min(cfg.ssm_state, 16),
        ssm_chunk=8,
        rnn_width=32 if cfg.rnn_width or cfg.family == "hybrid" else 0,
        window=min(cfg.window, 8),
        enc_layers=2 if cfg.enc_layers else 0,
        n_frontend_tokens=8 if cfg.n_frontend_tokens else 0,
        param_dtype="float32",
        compute_dtype="float32",
        opt_state_dtype="float32",
        remat=False,
        fsdp=False,
    )
    defaults.update(overrides)
    return dataclasses.replace(cfg, **defaults)
