"""SeamlessM4T-large-v2 text backbone: encoder-decoder transformer, MHA,
non-gated FFN.  The speech frontend is a STUB: ``input_specs`` provides
precomputed frame embeddings for the encoder.  [arXiv:2308.11596; hf]

Shape convention (DESIGN.md §4): for *_Sk shapes the encoder consumes S
frame embeddings and the decoder S//4 text tokens.
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    n_layers=24,               # decoder layers
    d_model=1024,
    n_heads=16,
    n_kv=16,
    head_dim=64,
    d_ff=8192,
    vocab=256206,
    gated_ffn=False,
    block_pattern=("d",),      # decoder: self + cross + FFN
    enc_layers=24,
    frontend="audio",
    n_frontend_tokens=0,       # encoder length comes from the shape spec
    tie_embeddings=True,
    source="arXiv:2308.11596",
))
