"""Llama-3.2-Vision-90B text backbone with interleaved cross-attention image
layers (every 5th layer).  The vision encoder is a STUB: ``input_specs``
provides precomputed patch embeddings.  [hf:meta-llama/Llama-3.2-11B-Vision;
unverified]"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    n_layers=100,
    d_model=8192,
    n_heads=64,
    n_kv=8,
    head_dim=128,
    d_ff=28672,
    vocab=128256,
    rope_theta=5e5,
    # (self x4, cross) x 20 = 100 layers.
    block_pattern=("g", "g", "g", "g", "x"),
    frontend="vision",
    n_frontend_tokens=1024,   # patch embeddings per example (stub frontend)
    opt_state_dtype="bfloat16",
    fsdp=True,
    source="hf:meta-llama/Llama-3.2-90B-Vision (backbone only)",
))
