"""StarCoder2-7B: dense, GQA kv=4, RoPE, non-gated MLP.
[arXiv:2402.19173; hf]"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="starcoder2-7b",
    family="dense",
    n_layers=32,
    d_model=4608,
    n_heads=36,
    n_kv=4,
    head_dim=128,
    d_ff=18432,
    vocab=49152,
    rope_theta=1e5,
    qkv_bias=True,
    gated_ffn=False,       # classic gelu MLP (lands at ~7B)
    block_pattern=("g",),
    source="arXiv:2402.19173",
))
