"""Mamba2-1.3B: attention-free SSD (state-space duality) stack.
[arXiv:2405.21060; unverified]"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="mamba2-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=0,
    n_kv=0,
    d_ff=0,                # SSD block is the whole layer (assignment: d_ff=0)
    vocab=50280,
    block_pattern=("s",),
    ssm_state=128,
    ssm_headdim=64,
    ssm_expand=2,
    ssm_chunk=256,
    conv_width=4,
    tie_embeddings=True,
    source="arXiv:2405.21060",
))
