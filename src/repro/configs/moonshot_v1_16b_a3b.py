"""Moonshot v1 16B-A3B (Moonlight-style): fine-grained MoE, 64 experts top-6,
MHA (kv=16).  [hf:moonshotai/Moonlight-16B-A3B; hf]

Note: with the assigned dims (48L, all-MoE, 64 x d_ff=1408 experts) total
params land at ~27B with ~3.3B active; we implement the assignment exactly
(DESIGN.md §4).
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv=16,
    head_dim=128,
    d_ff=1408,            # expert hidden dim
    moe_dff=1408,
    vocab=163840,
    rope_theta=5e4,
    block_pattern=("m",),
    n_experts=64,
    top_k=6,
    shared_expert=True,   # Moonlight keeps shared experts
    capacity_factor=1.25,
    fsdp=True,
    source="hf:moonshotai/Moonlight-16B-A3B",
))
