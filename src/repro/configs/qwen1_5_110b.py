"""Qwen1.5-110B: dense, GQA kv=8, QKV bias.  [hf:Qwen/Qwen1.5-110B; hf]"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="qwen1.5-110b",
    family="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv=8,
    head_dim=128,
    d_ff=49152,
    vocab=152064,
    rope_theta=1e6,
    qkv_bias=True,
    block_pattern=("g",),
    opt_state_dtype="bfloat16",
    fsdp=True,
    source="hf:Qwen/Qwen1.5-110B",
))
