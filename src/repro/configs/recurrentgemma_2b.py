"""RecurrentGemma-2B (Griffin): RG-LRU recurrent blocks + local attention,
1 attention per 2 recurrent layers, MQA kv=1.  [arXiv:2402.19427; hf]"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv=1,
    head_dim=256,
    d_ff=7680,
    vocab=256000,
    window=2048,
    # Griffin pattern (R, R, A) x 8 + trailing (R, R) = 26 layers exactly.
    block_pattern=("r", "r", "l"),
    tail_pattern=("r", "r"),
    rnn_width=2560,
    conv_width=4,
    source="arXiv:2402.19427",
))
