"""Gemma3-1B: 5:1 local:global attention, MQA kv=1, 128k context.
[hf:google/gemma-3-1b-pt; unverified]"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="gemma3-1b",
    family="dense",
    n_layers=26,
    d_model=1152,
    n_heads=4,
    n_kv=1,
    head_dim=256,
    d_ff=6912,
    vocab=262144,
    rope_theta=1e6,
    qk_norm=True,
    window=1024,
    # 5 local : 1 global -> (l,l,l,l,l,g) x 4 + (l,l) = 26 layers.
    block_pattern=("l", "l", "l", "l", "l", "g"),
    tail_pattern=("l", "l"),
    source="hf:google/gemma-3-1b-pt",
))
