"""Deterministic synthetic data pipeline.

Produces seeded token streams (and stub modality embeddings) shaped exactly
like the dry-run specs, with an index-based ``get_batch(step)`` API so
restarts resume mid-stream without replaying (checkpoint stores only the
step counter) — the property fault-tolerant training needs.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class DataConfig:
    batch: int
    seq: int
    vocab: int
    seed: int = 0
    # modality stubs
    image_tokens: int = 0
    d_model: int = 0
    src_frames: int = 0


class SyntheticStream:
    """Markov-ish synthetic tokens: deterministic per (seed, step)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def get_batch(self, step: int) -> dict:
        c = self.cfg
        key = jax.random.fold_in(jax.random.PRNGKey(c.seed), step)
        ks = jax.random.split(key, 4)
        # token stream with local correlation (so the loss is learnable)
        base = jax.random.randint(ks[0], (c.batch, c.seq + 1), 0, c.vocab)
        drift = jnp.cumsum(
            jax.random.randint(ks[1], (c.batch, c.seq + 1), 0, 3), axis=1)
        tokens = (base + drift) % c.vocab
        batch = {"tokens": tokens[:, :-1].astype(jnp.int32),
                 "targets": tokens[:, 1:].astype(jnp.int32)}
        if c.image_tokens:
            batch["image_embeds"] = jax.random.normal(
                ks[2], (c.batch, c.image_tokens, c.d_model), jnp.float32) * 0.02
        if c.src_frames:
            batch["src_embeds"] = jax.random.normal(
                ks[3], (c.batch, c.src_frames, c.d_model), jnp.float32) * 0.02
        return batch


def for_arch(arch_cfg, batch: int, seq: int, seed: int = 0) -> SyntheticStream:
    """Stream shaped for an architecture (modality stubs included)."""
    dec_seq = seq // 4 if arch_cfg.enc_layers else seq
    return SyntheticStream(DataConfig(
        batch=batch,
        seq=max(dec_seq, 8),
        vocab=arch_cfg.vocab,
        seed=seed,
        image_tokens=arch_cfg.n_frontend_tokens if arch_cfg.frontend == "vision" else 0,
        d_model=arch_cfg.d_model,
        src_frames=seq if arch_cfg.enc_layers else 0,
    ))
