"""Activation-sharding context: logical constraints the model code can emit
without knowing the mesh.

The launcher (dryrun/train/serve) activates the context under ``with mesh:``;
model code calls ``constrain(x, "dp", None, "tp")`` at key activation
boundaries (embedding output, scan-body entry, MoE dispatch, logits).  When
inactive (CPU smoke tests) it is a no-op.  Constraints are skipped for any
dim not divisible by its mesh axes, keeping them exact.
"""
from __future__ import annotations

import contextlib

import jax
from jax.sharding import PartitionSpec as P

_CTX: dict = {"active": False, "dp": None, "tp": None, "dp_n": 1, "tp_n": 1,
              "sp": None, "sp_n": 1, "moe_dp": True, "remat_offload": False,
              "ep": "model", "ep_n": 1}


@contextlib.contextmanager
def activation_sharding(mesh, *, seq_shard: bool = False,
                        moe_dp_groups: bool = True,
                        remat_offload: bool = False,
                        expert_axis: str = "model"):
    """Activate logical axes: dp = ("pod","data") portion, tp = "model".

    ``seq_shard=True`` additionally maps the logical "sp" axis (the sequence
    dim of residual activations) onto "model" -- context parallelism for
    prefill (EXPERIMENTS §Perf cell C).

    ``moe_dp_groups=False`` stops sharding MoE dispatch groups over the data
    axis -- required when expert F-dims shard over "data"
    (ShardingOptions.expert_shard_dff), otherwise the dispatched tokens and
    the expert contraction fight over the same mesh axis (§Perf cell B)."""
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    tp = "model" if "model" in mesh.axis_names else None
    dp_n = 1
    for a in dp:
        dp_n *= mesh.shape[a]
    old = dict(_CTX)
    _CTX.update(active=True, dp=dp, tp=tp, dp_n=dp_n,
                tp_n=mesh.shape.get("model", 1),
                sp=tp if seq_shard else None,
                sp_n=mesh.shape.get("model", 1),
                moe_dp=moe_dp_groups, remat_offload=remat_offload,
                ep=expert_axis if expert_axis in mesh.axis_names else None,
                ep_n=mesh.shape.get(expert_axis, 1))
    try:
        yield
    finally:
        _CTX.update(old)


def moe_group_axis() -> str | None:
    """Logical axis for MoE dispatch-group dims ("dp" or None)."""
    return "dp" if _CTX["moe_dp"] else None


def remat_offload_active() -> bool:
    """Host-offloaded remat carries (EXPERIMENTS §Perf cell B iter 3)."""
    return bool(_CTX["remat_offload"])


def constrain(x, *logical):
    """logical: one of "dp", "tp", "sp", None per dim of x."""
    if not _CTX["active"]:
        return x
    axes = []
    for dim, name in zip(x.shape, logical):
        if name == "dp" and _CTX["dp"] and dim % _CTX["dp_n"] == 0:
            axes.append(_CTX["dp"])
        elif name == "tp" and _CTX["tp"] and dim % _CTX["tp_n"] == 0:
            axes.append(_CTX["tp"])
        elif name == "sp" and _CTX["sp"] and dim % _CTX["sp_n"] == 0:
            axes.append(_CTX["sp"])
        elif name == "ep" and _CTX["ep"] and dim % _CTX["ep_n"] == 0:
            axes.append(_CTX["ep"])
        else:
            axes.append(None)
    if all(a is None for a in axes):
        return x
    return jax.lax.with_sharding_constraint(x, P(*axes))
