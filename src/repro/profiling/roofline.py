"""Roofline-term estimators (deliverable g).

Three terms per (arch x shape x mesh), all in seconds per step:

  compute    = executed_FLOPs / (chips * PEAK_FLOPS)
  memory     = HBM_bytes      / (chips * HBM_BW)
  collective = wire_bytes_per_device / LINK_BW

``executed_FLOPs`` and ``HBM_bytes`` are ANALYTIC: XLA's
``compiled.cost_analysis()`` counts while-loop bodies exactly once, so for
scan-based models it underestimates by ~n_layers (measured in EXPERIMENTS.md
§Dry-run); the estimators below are derived from the architecture configs
and cross-checked against per-layer HLO numbers.  Collective bytes come from
the partitioned HLO (launch/dryrun.py) with ring-cost weights.

Hardware constants: TPU v5e-class, per the assignment.
"""
from __future__ import annotations

import dataclasses

from ..configs.base import ArchConfig

PEAK_FLOPS = 197e12        # bf16 FLOP/s per chip
HBM_BW = 819e9             # bytes/s per chip
LINK_BW = 50e9             # bytes/s per ICI link

# ring-cost weights applied to per-device HLO result bytes
COLLECTIVE_WEIGHT = {"all-gather": 1.0, "all-reduce": 2.0,
                     "reduce-scatter": 1.0, "all-to-all": 1.0,
                     "collective-permute": 1.0}


# ---------------------------------------------------------------------------
# parameter / per-token-FLOP accounting
# ---------------------------------------------------------------------------

def _kinds(cfg: ArchConfig):
    kinds = list(cfg.block_pattern) * cfg.n_units + list(cfg.tail_pattern)
    if cfg.enc_layers:
        kinds = ["e"] * cfg.enc_layers + kinds
    return kinds


def _attn_params(cfg):
    if not cfg.n_heads:
        return 0
    d, hd = cfg.d_model, cfg.resolved_head_dim
    return (d * cfg.n_heads * hd + 2 * d * cfg.n_kv * hd
            + cfg.n_heads * hd * d)


def _ffn_params(cfg, d_ff):
    return (3 if cfg.gated_ffn else 2) * cfg.d_model * d_ff


def _layer_params(cfg, kind, active_only: bool):
    d = cfg.d_model
    if kind == "s":
        d_in = cfg.ssm_expand * d
        n = cfg.ssm_state
        h = d_in // cfg.ssm_headdim
        return d * (2 * d_in + 2 * n + h) + d_in * d
    if kind == "r":
        r = cfg.resolved_rnn_width
        return 2 * d * r + 2 * r * r + r * d + _ffn_params(cfg, cfg.d_ff)
    if kind == "m":
        n_e = (cfg.top_k + (1 if cfg.shared_expert else 0)) if active_only \
            else (cfg.n_experts + (1 if cfg.shared_expert else 0))
        return (_attn_params(cfg) + n_e * _ffn_params(cfg, cfg.resolved_moe_dff)
                + d * cfg.n_experts)
    if kind == "d":
        return 2 * _attn_params(cfg) + _ffn_params(cfg, cfg.d_ff)
    return _attn_params(cfg) + _ffn_params(cfg, cfg.d_ff)


def param_count(cfg: ArchConfig, active_only: bool = False) -> float:
    total = cfg.vocab * cfg.d_model * (1 if cfg.tie_embeddings else 2)
    for kind in _kinds(cfg):
        total += _layer_params(cfg, kind, active_only)
    return float(total)


def _attn_flops_per_seq(cfg, kind, s, decode_cache=0):
    """Score+AV FLOPs for one sequence (TPU kernel path: causal skip)."""
    if kind in ("s", "r") or not cfg.n_heads:
        return 0.0
    h, hd = cfg.n_heads, cfg.resolved_head_dim
    if decode_cache:                       # one token vs cache
        span = min(cfg.window, decode_cache) if kind == "l" and cfg.window \
            else decode_cache
        return 4.0 * span * h * hd
    if kind == "l" and cfg.window:
        span = min(cfg.window, s)
        return 4.0 * s * span * h * hd
    if kind in ("e",):                     # bidirectional full
        return 4.0 * s * s * h * hd
    if kind == "x":
        return 4.0 * s * cfg.n_frontend_tokens * h * hd
    if kind == "d":                        # causal self + full cross(enc s)
        return 2.0 * s * s * h * hd + 4.0 * s * (4 * s) * h * hd
    return 2.0 * s * s * h * hd            # causal: s^2/2 pairs x 4


def fwd_flops(cfg: ArchConfig, batch: int, seq: int) -> float:
    """Forward FLOPs for a (batch, seq) step, kernel-executed counts."""
    dec_seq = seq // 4 if cfg.enc_layers else seq
    total = 0.0
    for kind in _kinds(cfg):
        s = seq if kind == "e" else dec_seq
        total += 2.0 * _layer_params(cfg, kind, active_only=True) * s
        total += _attn_flops_per_seq(cfg, kind, s)
    total += 2.0 * cfg.d_model * cfg.vocab * dec_seq   # lm head
    return total * batch


def step_flops(cfg: ArchConfig, shape, kind: str) -> dict:
    """Returns {"executed": F, "model": MODEL_FLOPS} for the cell."""
    n_active = param_count(cfg, active_only=True)
    if kind == "train":
        tokens = shape.batch * (shape.seq // 4 if cfg.enc_layers else shape.seq)
        fwd = fwd_flops(cfg, shape.batch, shape.seq)
        mult = 3.0 + (1.0 if cfg.remat else 0.0)
        return {"executed": mult * fwd, "model": 6.0 * n_active * tokens}
    if kind == "prefill":
        tokens = shape.batch * (shape.seq // 4 if cfg.enc_layers else shape.seq)
        return {"executed": fwd_flops(cfg, shape.batch, shape.seq),
                "model": 2.0 * n_active * tokens}
    # decode: one token against a shape.seq cache
    per_tok = 0.0
    for k in _kinds(cfg):
        if k == "e":
            continue
        per_tok += 2.0 * _layer_params(cfg, k, active_only=True)
        per_tok += _attn_flops_per_seq(cfg, k, 1, decode_cache=shape.seq)
    per_tok += 2.0 * cfg.d_model * cfg.vocab
    return {"executed": per_tok * shape.batch,
            "model": 2.0 * n_active * shape.batch}


# ---------------------------------------------------------------------------
# HBM traffic
# ---------------------------------------------------------------------------

def _cache_bytes(cfg: ArchConfig, batch: int, seq: int) -> float:
    """Serving-cache footprint for a seq-length context."""
    total = 0.0
    hd = cfg.resolved_head_dim
    for kind in _kinds(cfg):
        if kind in ("g", "m"):
            total += 2 * seq * cfg.n_kv * hd * 2
        elif kind == "d":
            total += 2 * seq * cfg.n_kv * hd * 2       # self cache
            total += 2 * (4 * seq) * cfg.n_kv * hd * 2  # enc memory
        elif kind == "x":
            total += 2 * cfg.n_frontend_tokens * cfg.n_kv * hd * 2
        elif kind == "l":
            total += 2 * min(cfg.window or seq, seq) * cfg.n_kv * hd * 2
        elif kind == "r":
            r = cfg.resolved_rnn_width
            total += r * 4 + (cfg.conv_width - 1) * r * 2
        elif kind == "s":
            d_in = cfg.ssm_expand * cfg.d_model
            h = d_in // cfg.ssm_headdim
            total += h * cfg.ssm_state * cfg.ssm_headdim * 4
            total += (cfg.conv_width - 1) * (d_in + 2 * cfg.ssm_state) * 2
    return total * batch


def step_hbm_bytes(cfg: ArchConfig, shape, kind: str,
                   microbatches: int = 1) -> float:
    """Whole-step HBM traffic (GLOBAL, divide by chips for per-chip)."""
    p_total = param_count(cfg)
    p_bytes = p_total * 2                     # bf16 resident params
    opt_bytes = p_total * (2 if cfg.opt_state_dtype == "bfloat16" else 4) * 2
    d = cfg.d_model
    dec_seq = shape.seq // 4 if cfg.enc_layers else shape.seq
    tokens = shape.batch * dec_seq
    act_rw = 8.0                              # r/w passes per layer activation
    if kind == "train":
        acts = len(_kinds(cfg)) * tokens * d * 2 * act_rw
        # params re-read fwd+bwd(+remat) per microbatch; grads + opt once
        reads = (2 + (1 if cfg.remat else 0)) * microbatches
        return p_bytes * reads + p_bytes + opt_bytes + acts
    if kind == "prefill":
        acts = len(_kinds(cfg)) * tokens * d * 2 * 2
        return p_bytes + acts + _cache_bytes(cfg, shape.batch, shape.seq)
    # decode: params once + full cache read + tiny writes
    return (p_bytes + _cache_bytes(cfg, shape.batch, shape.seq)
            + len(_kinds(cfg)) * shape.batch * d * 2 * 4)


# ---------------------------------------------------------------------------
# term assembly
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    executed_flops: float
    model_flops: float
    hbm_bytes: float
    wire_bytes_per_dev: float
    chips: int = 256

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Roofline step time: max of the three (perfect overlap bound)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_fraction(self) -> float:
        return self.model_flops / max(self.executed_flops, 1.0)

    @property
    def roofline_fraction(self) -> float:
        """MODEL_FLOPS-achieving fraction of peak at the roofline bound
        (a.k.a. the best MFU this program shape can reach)."""
        return (self.model_flops / self.step_time_s) / (PEAK_FLOPS * self.chips) \
            if self.step_time_s > 0 else 0.0


def terms_for(cfg, shape, kind, collectives_by_kind: dict, chips: int,
              microbatches: int = 1) -> RooflineTerms:
    fl = step_flops(cfg, shape, kind)
    hbm = step_hbm_bytes(cfg, shape, kind, microbatches)
    wire = sum(COLLECTIVE_WEIGHT.get(k, 1.0) * v
               for k, v in collectives_by_kind.items())
    return RooflineTerms(
        compute_s=fl["executed"] / (chips * PEAK_FLOPS),
        memory_s=hbm / (chips * HBM_BW),
        collective_s=wire / LINK_BW,
        executed_flops=fl["executed"],
        model_flops=fl["model"],
        hbm_bytes=hbm,
        wire_bytes_per_dev=wire,
        chips=chips,
    )
