"""Logical-layer cost profiles for the 10 assigned architectures.

This extends the paper's CNN profiling (Sec. II-A) to modern LM stacks so the
LyMDO controller can partition *any* assigned arch between a device tier and
the edge/pod tier.  A "task" is one inference request of ``prompt_tokens``
tokens (default 128, an edge-assistant-sized request).

Logical layers:  [input] + [per-transformer-layer blocks...] + [lm head].
Per layer l:
  M(l)  = MACs to run the layer on the request (active params x tokens for
          MoE: only top-k experts count, the paper's M is *executed* compute)
  C(l)  = parameter bytes that must be resident (MoE: ALL experts -- memory
          is where MoE partitioning bites, DESIGN §4)
  psi(l)= boundary transfer bytes if we cut after l:
            attention archs: hidden states (tokens x d_model)
            + any state the edge side needs (SSM state / window cache for
              hybrid archs -- constant in sequence length)
          psi is what the paper transmits in eq. (3).
"""
from __future__ import annotations

import numpy as np

from ..configs.base import ArchConfig
from .profiles import LayerProfile

_ACT_BYTES = 2  # bf16 activations on the wire


def _attn_macs(cfg: ArchConfig, s: int) -> float:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    h, kv = cfg.n_heads, cfg.n_kv
    proj = s * (d * h * hd + 2 * d * kv * hd + h * hd * d)
    scores = s * s * h * hd  # causal ~ /2; keep upper bound like ref [4]
    return float(proj + scores)


def _attn_params(cfg: ArchConfig) -> float:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    return float(d * cfg.n_heads * hd + 2 * d * cfg.n_kv * hd
                 + cfg.n_heads * hd * d)


def _ffn_macs(cfg: ArchConfig, s: int, d_ff: int) -> float:
    mult = 3 if cfg.gated_ffn else 2
    return float(s * mult * cfg.d_model * d_ff)


def _ffn_params(cfg: ArchConfig, d_ff: int) -> float:
    mult = 3 if cfg.gated_ffn else 2
    return float(mult * cfg.d_model * d_ff)


def _layer_costs(cfg: ArchConfig, kind: str, s: int) -> tuple[float, float, float]:
    """(macs, param_bytes, extra_psi_bytes) for one layer of ``kind``."""
    d = cfg.d_model
    pbytes = 2.0  # bf16 params
    extra_psi = 0.0
    if kind == "s":
        d_in = cfg.ssm_expand * d
        n, g = cfg.ssm_state, 1
        h = d_in // cfg.ssm_headdim
        proj = 2 * d_in + 2 * g * n + h
        macs = s * (d * proj + d_in * d) + s * d_in * n * 2   # proj + scan
        params = d * proj + d_in * d
        extra_psi = h * cfg.ssm_headdim * n * 4               # fp32 SSD state
        return float(macs), params * pbytes, extra_psi
    if kind == "r":
        r = cfg.resolved_rnn_width
        macs = s * (2 * d * r + 2 * r * r + r * d) + _ffn_macs(cfg, s, cfg.d_ff)
        params = (2 * d * r + 2 * r * r + r * d
                  + _ffn_params(cfg, cfg.d_ff))
        extra_psi = r * 4 + (cfg.conv_width - 1) * r * 2      # h state + conv
        return float(macs), params * pbytes, extra_psi
    if kind == "m":
        active_ff = cfg.top_k * cfg.resolved_moe_dff
        if cfg.shared_expert:
            active_ff += cfg.resolved_moe_dff
        macs = _attn_macs(cfg, s) + _ffn_macs(cfg, s, active_ff) \
            + s * d * cfg.n_experts
        n_ff = cfg.n_experts + (1 if cfg.shared_expert else 0)
        params = (_attn_params(cfg) + n_ff * _ffn_params(cfg, cfg.resolved_moe_dff)
                  + d * cfg.n_experts)
        return float(macs), params * pbytes, 0.0
    if kind == "x":
        macs = _attn_macs(cfg, s) + _ffn_macs(cfg, s, cfg.d_ff)
        params = _attn_params(cfg) + _ffn_params(cfg, cfg.d_ff)
        # cutting before a cross layer means shipping the image/frame context
        extra_psi = cfg.n_frontend_tokens * d * _ACT_BYTES
        return float(macs), params * pbytes, extra_psi
    if kind == "d":
        macs = 2 * _attn_macs(cfg, s) + _ffn_macs(cfg, s, cfg.d_ff)
        params = 2 * _attn_params(cfg) + _ffn_params(cfg, cfg.d_ff)
        extra_psi = 0.0   # encoder memory accounted at the encoder boundary
        return float(macs), params * pbytes, extra_psi
    if kind == "l":
        w = min(cfg.window or s, s)
        proj = s * (_attn_params(cfg))
        scores = s * w * cfg.n_heads * cfg.resolved_head_dim
        macs = proj + scores + _ffn_macs(cfg, s, cfg.d_ff)
        params = _attn_params(cfg) + _ffn_params(cfg, cfg.d_ff)
        extra_psi = min(w, s) * cfg.n_kv * cfg.resolved_head_dim * 2 * _ACT_BYTES
        return float(macs), params * pbytes, extra_psi
    # "g" / "e"
    macs = _attn_macs(cfg, s) + _ffn_macs(cfg, s, cfg.d_ff)
    params = _attn_params(cfg) + _ffn_params(cfg, cfg.d_ff)
    # cutting after a global layer ships its KV prefix for the edge to reuse?
    # No: layers after the cut run entirely on the edge; only hidden states
    # cross the boundary.  KV of *local* (already-run) layers stays local.
    return float(macs), params * pbytes, 0.0


def lm_profile(cfg: ArchConfig, prompt_tokens: int = 128) -> LayerProfile:
    """Build the paper's (M, C, psi) arrays for an assigned architecture."""
    s = prompt_tokens
    d = cfg.d_model
    kinds: list[str] = []
    if cfg.enc_layers:
        kinds.extend(["e"] * cfg.enc_layers)
    kinds.extend(list(cfg.block_pattern) * cfg.n_units + list(cfg.tail_pattern))

    names = ["input"]
    macs, params_b, acts = [0.0], [0.0], [float(s * 4)]  # raw token ids (int32)
    if cfg.frontend == "vision":
        acts[0] += cfg.n_frontend_tokens * d * _ACT_BYTES
    if cfg.frontend == "audio":
        acts[0] += s * d * _ACT_BYTES                    # frame embeddings

    # embedding logical layer
    names.append("embed")
    macs.append(0.0)
    params_b.append(float(cfg.vocab * d * 2))
    acts.append(float(s * d * _ACT_BYTES))

    hidden = float(s * d * _ACT_BYTES)
    for i, kind in enumerate(kinds):
        m, p, extra = _layer_costs(cfg, kind, s)
        names.append(f"{kind}{i}")
        macs.append(m)
        params_b.append(p)
        acts.append(hidden + extra)

    # lm head (decode next token: 1 x d x vocab; tied weights add no memory)
    names.append("head")
    macs.append(float(d * cfg.vocab))
    params_b.append(0.0 if cfg.tie_embeddings else float(d * cfg.vocab * 2))
    acts.append(float(cfg.vocab * 2))   # final logits (never shipped: last)

    return LayerProfile(name=cfg.name, macs=np.array(macs),
                        param_bytes=np.array(params_b),
                        act_bytes=np.array(acts), layer_names=tuple(names))


def all_lm_profiles(prompt_tokens: int = 128) -> dict[str, LayerProfile]:
    from ..configs.base import load_all
    return {name: lm_profile(cfg, prompt_tokens)
            for name, cfg in load_all().items()}
