"""Logical-layer cost profiles (paper Sec. II-A).

A DNN ``phi_n`` is abstracted as a sequence of ``L`` logical layers.  For each
layer ``l`` we track

* ``macs[l]``        -- multiply-accumulate ops to execute layer ``l`` (M_n(l))
* ``param_bytes[l]`` -- bytes of parameters that must be resident to run it (C_n(l))
* ``act_bytes[l]``   -- bytes of the layer's output feature map (psi_n(l))

Index ``0`` is the *input pseudo-layer*: zero MACs / params, and
``act_bytes[0]`` is the raw input size (so a cut at 0 == full edge offload,
shipping the raw input).  A *cut* ``c`` in ``{0, ..., L}`` executes layers
``1..c`` locally and ``c+1..L`` on the edge server, transmitting
``act_bytes[c]`` over the uplink (``c == L`` means fully local; the result
return is neglected per the paper).

Note: the paper's C8 writes ``l in {1..L}``, while its own Edge baseline is a
cut at 0.  We use the closed set ``{0..L}`` which strictly contains both.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

__all__ = ["LayerProfile", "ProfileBatch"]


@dataclasses.dataclass(frozen=True)
class LayerProfile:
    """Per-logical-layer cost profile of one DNN."""

    name: str
    macs: np.ndarray          # (L+1,) float64, macs[0] == 0
    param_bytes: np.ndarray   # (L+1,) float64, param_bytes[0] == 0
    act_bytes: np.ndarray     # (L+1,) float64, act_bytes[0] == input bytes
    layer_names: tuple = ()   # optional (L+1,) labels

    def __post_init__(self):
        L = self.num_layers
        for arr in (self.macs, self.param_bytes, self.act_bytes):
            if arr.shape != (L + 1,):
                raise ValueError(f"profile arrays must share shape (L+1,), got {arr.shape}")
        if self.macs[0] != 0 or self.param_bytes[0] != 0:
            raise ValueError("input pseudo-layer must have zero MACs/params")

    @property
    def num_layers(self) -> int:
        return len(self.macs) - 1

    @property
    def total_macs(self) -> float:
        return float(self.macs.sum())

    @property
    def total_param_bytes(self) -> float:
        return float(self.param_bytes.sum())

    def summary(self) -> str:
        return (
            f"{self.name}: L={self.num_layers} "
            f"MACs={self.total_macs / 1e9:.3f}G "
            f"params={self.total_param_bytes / 1e6:.1f}MB "
            f"max_act={self.act_bytes.max() / 1e6:.2f}MB"
        )


class ProfileBatch:
    """N user profiles padded to a common layer count, as dense arrays.

    Precomputes every per-cut quantity the per-slot problem P2 needs, so the
    jitted MEC step only does O(1) gathers:

    * ``prefix_macs[n, c]``  = sum_{l<=c} M_n(l)           (local MACs at cut c)
    * ``suffix_macs[n, c]``  = sum_{l>c}  M_n(l)           (edge MACs at cut c)
    * ``psi[n, c]``          = transmit bytes at cut c (0 at c == L_n: result
                               return neglected, paper Sec. II-B)
    * ``prefix_params`` / ``suffix_params``                 (bytes, eq. 6)
    * ``prefix_act_max`` / ``suffix_act_max``               (bytes, eq. 6)

    Cuts ``c > L_n`` for padded entries alias the fully-local cut ``L_n`` so
    any integer action in ``{0..Lmax}`` is well defined for every UE.
    """

    def __init__(self, profiles: Sequence[LayerProfile]):
        self.profiles = tuple(profiles)
        self.n = len(profiles)
        self.L = np.array([p.num_layers for p in profiles], dtype=np.int32)
        self.Lmax = int(self.L.max())
        C = self.Lmax + 1

        def pad(field: str) -> np.ndarray:
            out = np.zeros((self.n, C), dtype=np.float64)
            for i, p in enumerate(profiles):
                arr = getattr(p, field)
                out[i, : len(arr)] = arr
            return out

        macs = pad("macs")
        params = pad("param_bytes")
        act = pad("act_bytes")

        self.macs, self.param_bytes, self.act_bytes = macs, params, act
        self.prefix_macs = np.cumsum(macs, axis=1)
        self.prefix_params = np.cumsum(params, axis=1)
        total_macs = self.prefix_macs[:, -1:]
        total_params = self.prefix_params[:, -1:]
        self.total_macs = total_macs[:, 0]
        self.total_params = total_params[:, 0]
        self.suffix_macs = total_macs - self.prefix_macs
        self.suffix_params = total_params - self.prefix_params

        # Activation-footprint running maxima (eq. 6).  Local term covers
        # layers 1..c; edge term covers layers c+1..L_n.
        act_real = act.copy()
        idx = np.arange(C)[None, :]
        valid = idx <= self.L[:, None]
        act_real[~valid] = 0.0
        local_max = np.zeros((self.n, C))
        running = np.zeros(self.n)
        for c in range(1, C):
            running = np.maximum(running, act_real[:, c])
            local_max[:, c] = running
        edge_max = np.zeros((self.n, C))
        running = np.zeros(self.n)
        for c in range(C - 1, 0, -1):
            edge_max[:, c - 1] = np.maximum(running, act_real[:, c])
            running = edge_max[:, c - 1]
        self.prefix_act_max = local_max      # max act of layers 1..c (0 at c=0)
        self.suffix_act_max = edge_max       # max act of layers c+1..L (0 at c=L)

        # Transmit bytes: psi(c), but 0 at the fully-local cut (and beyond,
        # for padded cuts).
        psi = act_real.copy()
        psi[idx >= self.L[:, None]] = 0.0
        self.psi = psi

        # For cuts beyond L_n (padding), every per-cut array must alias the
        # c == L_n value.  cumsum/max already hold constant beyond L_n because
        # padded entries are zero, and psi is zeroed above; nothing else to do.

    def clip_cut(self, cut: np.ndarray) -> np.ndarray:
        return np.clip(cut, 0, self.L)
