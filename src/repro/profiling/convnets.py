"""Exact per-logical-layer profiles for the paper's workloads.

The paper evaluates two task types: AlexNet (type I) and ResNet18 (type II),
abstracted as sequential *logical layers* (Sec. II-A): straight-line layers
(conv/fc) map 1:1; ResNet basic blocks (parallel residual units) collapse to
one logical layer, following ref. [11].

MACs use the standard conv arithmetic ``k*k*Cin*Cout*Hout*Wout`` (per-example,
batch 1 — one task == one inference).  Parameter and activation sizes are
float32 (4 B), the framework the paper's numbers are consistent with.
"""
from __future__ import annotations

import numpy as np

from .profiles import LayerProfile

_BYTES = 4  # float32 activations/params, per the paper's MB-scale constants


def _conv(cin, h, w, cout, k, stride=1, pad=0, pool=1):
    """Conv (+ optional following maxpool) -> (macs, params, out_{c,h,w})."""
    ho = (h + 2 * pad - k) // stride + 1
    wo = (w + 2 * pad - k) // stride + 1
    macs = k * k * cin * cout * ho * wo
    params = k * k * cin * cout + cout
    if pool > 1:
        ho //= pool
        wo //= pool
    return macs, params, (cout, ho, wo)


def _fc(din, dout):
    return din * dout, din * dout + dout, (dout,)


def alexnet_profile() -> LayerProfile:
    """AlexNet (ungrouped), 227x227x3 input, 8 logical layers."""
    names = ["input"]
    macs, params, acts = [0.0], [0.0], [227 * 227 * 3 * _BYTES]
    shape = (3, 227, 227)

    def push(name, m, p, out):
        names.append(name)
        macs.append(float(m))
        params.append(float(p * _BYTES))
        acts.append(float(np.prod(out) * _BYTES))
        return out

    c, h, w = shape
    m, p, out = _conv(c, h, w, 96, 11, stride=4, pad=0, pool=2)
    shape = push("conv1+pool", m, p, out)
    m, p, out = _conv(*_chw(shape), 256, 5, stride=1, pad=2, pool=2)
    shape = push("conv2+pool", m, p, out)
    m, p, out = _conv(*_chw(shape), 384, 3, stride=1, pad=1)
    shape = push("conv3", m, p, out)
    m, p, out = _conv(*_chw(shape), 384, 3, stride=1, pad=1)
    shape = push("conv4", m, p, out)
    m, p, out = _conv(*_chw(shape), 256, 3, stride=1, pad=1, pool=2)
    shape = push("conv5+pool", m, p, out)
    m, p, out = _fc(int(np.prod(shape)), 4096)
    shape = push("fc6", m, p, out)
    m, p, out = _fc(4096, 4096)
    shape = push("fc7", m, p, out)
    m, p, out = _fc(4096, 1000)
    shape = push("fc8", m, p, out)

    return LayerProfile(
        name="alexnet",
        macs=np.array(macs),
        param_bytes=np.array(params),
        act_bytes=np.array(acts),
        layer_names=tuple(names),
    )


def _chw(shape):
    c, h, w = shape
    return c, h, w


def _basic_block(cin, h, w, cout, stride):
    """ResNet basic block (2x conv3x3 + optional 1x1 downsample) as one
    logical layer."""
    m1, p1, (c1, h1, w1) = _conv(cin, h, w, cout, 3, stride=stride, pad=1)
    m2, p2, out = _conv(c1, h1, w1, cout, 3, stride=1, pad=1)
    macs, params = m1 + m2, p1 + p2
    if stride != 1 or cin != cout:
        md, pd, _ = _conv(cin, h, w, cout, 1, stride=stride, pad=0)
        macs += md
        params += pd
    return macs, params, out


def resnet18_profile() -> LayerProfile:
    """ResNet18, 224x224x3 input, 10 logical layers (stem + 8 blocks + fc)."""
    names = ["input"]
    macs, params, acts = [0.0], [0.0], [224 * 224 * 3 * _BYTES]

    def push(name, m, p, out):
        names.append(name)
        macs.append(float(m))
        params.append(float(p * _BYTES))
        acts.append(float(np.prod(out) * _BYTES))
        return out

    m, p, out = _conv(3, 224, 224, 64, 7, stride=2, pad=3, pool=2)
    shape = push("stem", m, p, out)
    plan = [(64, 1), (64, 1), (128, 2), (128, 1), (256, 2), (256, 1), (512, 2), (512, 1)]
    for i, (cout, stride) in enumerate(plan):
        c, h, w = shape
        m, p, out = _basic_block(c, h, w, cout, stride)
        shape = push(f"block{i + 1}", m, p, out)
    # global average pool collapses to (512,); fold into the fc logical layer
    m, p, out = _fc(512, 1000)
    push("fc", m, p, out)

    return LayerProfile(
        name="resnet18",
        macs=np.array(macs),
        param_bytes=np.array(params),
        act_bytes=np.array(acts),
        layer_names=tuple(names),
    )
