"""Paged KV-cache pool for continuous-batching serving.

The ES tier serves *continuous* arrivals (the paper's serial queuing model),
so decode slots come and go independently -- one shared dense
``(slots, s_max)`` KV buffer per layer would tie every slot to one write
frontier.  Instead the global-attention KV cache lives in a **block pool**:

* ``k``/``v`` pool arrays of ``n_blocks`` fixed-size blocks
  (``block_size`` token slots each), stacked over scanned units --
  ``(U, n_blocks, block_size, KV, hd)`` -- or unstacked for tail layers;
* a host-side :class:`BlockAllocator` (free-list, O(1) alloc/free) whose
  **block 0 is a reserved dummy**: idle decode rows scatter their garbage
  KV there, so one jitted decode step serves any mix of live/idle slots;
* per-slot **block tables** ``(slots, ceil(s_max/block_size))`` int32 kept
  by the engine and passed into the jitted decode step, which gathers each
  row's blocks back into a contiguous view for ``kernels/decode_attention``
  with a per-row ragged ``valid_mask`` (position ``<= seq_len``).

Only global-attention KV pages: sliding-window rings ("l") are fixed
``window`` slots and recurrent state ("r"/"s") is O(1) per slot, so those
live as plain per-slot rows (batch dim = slots).

:func:`commit_prefill` is the admission bridge: a request prefills SOLO
(batch=1 at its bucket width, left-padded -- the PR-3/PR-4 ragged
machinery keeps it exact), then the jitted commit strips the pad (rolling
the token axis so real tokens sit at positions ``0..len-1``), writes the
KV into the slot's allocated blocks, re-slots the ring caches to semantic
positions, and inserts the recurrent state at the slot row.  The paged
cache is therefore **pad-free**: decode positions are plain per-slot
``seq_lens``, no pad vector rides along.

Under a ``("cells", "model")`` mesh, :func:`place_decode_state` shards the
pool's kv-head dim over ``"model"`` (when divisible) and replicates block
tables -- every model shard holds the same table, each gathers only its
head shard (the "model-sharded block tables" contract of docs/serving.md).
"""
from __future__ import annotations

from collections import deque

import jax
import jax.numpy as jnp

from ..models import transformer
from ..models.attention import KVCache, RingCache
from ..models.rglru import RglruCache
from ..models.ssm import SsmCache

_CACHE_TYPES = (KVCache, RingCache, SsmCache, RglruCache)


class BlockAllocator:
    """Host-side free-list over the KV block pool.

    Block 0 is reserved as the dummy block (idle decode rows write there);
    ``capacity`` is therefore ``n_blocks - 1``.

    Every block is either in the free list or in the handed-out set -- an
    invariant the allocator itself enforces: ``free()`` of a block it never
    handed out raises (not just double frees of blocks sitting in the free
    list), both paths validate their whole argument before mutating
    anything (a bad batch leaves the allocator untouched), and ``alloc()``
    rolls its pops back if it detects free-list corruption mid-way.  The
    sanitizer runtime (``analysis.sanitize``) layers per-slot ownership
    tracking on top of these checks.
    """

    def __init__(self, n_blocks: int, block_size: int):
        if n_blocks < 2:
            raise ValueError(f"need >= 2 blocks (one is the reserved dummy), "
                             f"got {n_blocks}")
        if block_size < 1:
            raise ValueError(f"block_size must be >= 1, got {block_size}")
        self.n_blocks = n_blocks
        self.block_size = block_size
        self._free: deque[int] = deque(range(1, n_blocks))
        self._handed: set[int] = set()       # blocks currently checked out

    @property
    def capacity(self) -> int:
        return self.n_blocks - 1

    @property
    def n_free(self) -> int:
        return len(self._free)

    def handed_out(self) -> frozenset[int]:
        """Blocks currently checked out (sanitizer cross-check surface)."""
        return frozenset(self._handed)

    def alloc(self, n: int) -> list[int] | None:
        """Pop ``n`` blocks, or None (and no side effect) if unavailable."""
        if n < 0:
            raise ValueError(f"cannot allocate {n} blocks")
        if n > len(self._free):
            return None
        got: list[int] = []
        for _ in range(n):
            b = self._free.popleft()
            if b in self._handed:            # corrupted free list: roll back
                self._free.extendleft(reversed(got + [b]))
                raise ValueError(f"free list corrupted: block {b} is both "
                                 f"free and handed out")
            got.append(b)
        self._handed.update(got)
        return got

    def free(self, blocks) -> None:
        """Return blocks to the free list.  Validates the WHOLE batch before
        mutating: a double free, a free of a block never handed out, or a
        duplicate within the batch raises with the allocator unchanged."""
        blocks = list(blocks)
        seen: set[int] = set()
        for b in blocks:
            if not 1 <= b < self.n_blocks:
                raise ValueError(f"block {b} outside pool (dummy block 0 is "
                                 f"never allocated)")
            if b in seen:
                raise ValueError(f"double free of block {b} (duplicated "
                                 f"within one free() batch)")
            if b not in self._handed:
                if b in self._free:
                    raise ValueError(f"double free of block {b}")
                raise ValueError(f"free of block {b} that was never handed "
                                 f"out")
            seen.add(b)
        for b in blocks:
            self._handed.discard(b)
            self._free.append(b)


def blocks_for(tokens: int, block_size: int) -> int:
    """Blocks needed to hold ``tokens`` KV entries (at least one)."""
    return max(1, -(-tokens // block_size))


def pool_stats(allocator: BlockAllocator, seq_lens, owned) -> dict:
    """Host-side pool gauges for telemetry (``repro.obs``): free blocks,
    utilization (allocated / capacity), and internal fragmentation (wasted
    token slots inside allocated blocks / allocated token capacity).

    Pure host arithmetic over state the engine already holds -- the
    allocator free list, the per-slot ``owned`` block lists, and the numpy
    ``seq_lens`` row -- so sampling it each tick never touches the device.
    """
    cap = allocator.capacity
    allocated = sum(len(blocks) for blocks in owned)
    used_tokens = sum(int(seq_lens[i]) for i in range(len(owned))
                      if owned[i])
    alloc_tokens = allocated * allocator.block_size
    return {
        "n_free": allocator.n_free,
        "capacity": cap,
        "allocated": allocated,
        "utilization": allocated / cap if cap else 0.0,
        "fragmentation": (1.0 - used_tokens / alloc_tokens
                          if alloc_tokens else 0.0),
    }


def _check_pattern(cfg) -> None:
    bad = set("xde") & (set(cfg.block_pattern) | set(cfg.tail_pattern or ()))
    if bad or cfg.enc_layers:
        raise ValueError(
            f"continuous batching serves plain decoder stacks (g/l/m/r/s); "
            f"{cfg.name} has {sorted(bad) or 'encoder layers'} -- "
            f"use ServingEngine(sync_batching=True)")


def _is_stacked(path) -> bool:
    """Unit caches come out of the block scan stacked (U, B, ...); tail
    caches are per-layer (B, ...).  The path tells which."""
    first = path[0]
    key = getattr(first, "key", getattr(first, "idx", None))
    return str(key) == "units"


def _batch_axis(path) -> int:
    return 1 if _is_stacked(path) else 0


def init_decode_state(cfg, params, slots: int, n_blocks: int,
                      block_size: int):
    """Build the zeroed continuous-decode cache pytree.

    Mirrors the structure ``transformer.prefill`` returns (minus the
    ``pos``/``pad`` bookkeeping), with global-attention KV leaves replaced
    by block pools and every other leaf's batch dim widened to ``slots``.
    """
    _check_pattern(cfg)

    def shape_fn(p):
        dummy = {"tokens": jnp.zeros((1, 8), jnp.int32)}
        _, caches = transformer.prefill(p, cfg, dummy, s_max=8)
        return {"units": caches["units"], "tail": caches["tail"]}

    template = jax.eval_shape(shape_fn, params)

    def build(path, node):
        ax = _batch_axis(path)
        if isinstance(node, KVCache):
            lead = node.k.shape[:ax]            # () or (U,)
            kvh, hd = node.k.shape[-2:]
            shp = (*lead, n_blocks, block_size, kvh, hd)
            return KVCache(k=jnp.zeros(shp, node.k.dtype),
                           v=jnp.zeros(shp, node.v.dtype))
        if isinstance(node, RingCache):
            def widen(leaf):
                s = list(leaf.shape)
                s[ax] = slots
                return tuple(s)
            return RingCache(k=jnp.zeros(widen(node.k), node.k.dtype),
                             v=jnp.zeros(widen(node.v), node.v.dtype),
                             pos=jnp.full(widen(node.pos), -1, jnp.int32))
        if isinstance(node, (SsmCache, RglruCache)):
            def widen(leaf):
                s = list(leaf.shape)
                s[ax] = slots
                return jnp.zeros(tuple(s), leaf.dtype)
            return type(node)(*[widen(f) for f in node])
        raise ValueError(f"unsupported cache node {type(node)} at {path}")

    return jax.tree_util.tree_map_with_path(build, template,
                                            is_leaf=_cache_leaf)


def _cache_leaf(x) -> bool:
    return isinstance(x, _CACHE_TYPES)


def commit_prefill(state, solo, pad, slot, block_ids, *, block_size: int):
    """Insert one solo-prefilled request into the continuous decode state.

    ``solo`` is the cache of a batch-1 bucketed prefill (``pos``/``pad``
    stripped), ``pad`` its scalar left-pad count, ``slot`` the target decode
    row, ``block_ids`` (ceil(width/block_size),) the slot's allocated pool
    blocks -- entries past the owned count point at the dummy block 0 and
    absorb the rolled pad garbage.  jit-compatible: ``pad``/``slot`` are
    traced scalars (no recompile per request), only the prefill width
    changes the signature (one compile per bucket, like prefill itself).

    The whole insert runs under ``jax.named_scope("repro.commit_prefill")``
    so profiler dumps attribute the scatter cost to admission, not decode.
    """
    nb = block_ids.shape[0]

    def insert(path, cont, one):
        ax = _batch_axis(path)
        if isinstance(cont, KVCache):
            def paged(pool, leaf):
                # the solo cache holds s_max token slots (prompt at
                # 0..width-1, zeros beyond); roll the pad out, then cut the
                # token axis to exactly nb*block_size entries
                tok = leaf.shape[ax + 1]
                x = jnp.squeeze(leaf, axis=ax)           # (L..., s_max, KV, hd)
                x = jnp.roll(x, -pad, axis=ax)           # real tokens first
                want = nb * block_size
                if want < tok:
                    x = jax.lax.slice_in_dim(x, 0, want, axis=ax)
                elif want > tok:
                    wid = [(0, 0)] * x.ndim
                    wid[ax] = (0, want - tok)
                    x = jnp.pad(x, wid)
                kvh, hd = x.shape[-2:]
                x = x.reshape(*x.shape[:ax], nb, block_size, kvh, hd)
                if ax:
                    return pool.at[:, block_ids].set(x)
                return pool.at[block_ids].set(x)
            return KVCache(k=paged(cont.k, one.k), v=paged(cont.v, one.v))
        if isinstance(cont, RingCache):
            # prefill stored entries at ABSOLUTE (padded) ring slots; shift
            # to semantic slots (pos - pad) and invalidate pad entries so
            # decode's per-row ``seq_len % window`` writes continue cleanly.
            rk = jnp.roll(jnp.squeeze(one.k, axis=ax), -pad, axis=ax)
            rv = jnp.roll(jnp.squeeze(one.v, axis=ax), -pad, axis=ax)
            rp = jnp.roll(jnp.squeeze(one.pos, axis=ax), -pad, axis=ax)
            rp = jnp.where(rp >= pad, rp - pad, -1)
            if ax:
                return RingCache(k=cont.k.at[:, slot].set(rk),
                                 v=cont.v.at[:, slot].set(rv),
                                 pos=cont.pos.at[:, slot].set(rp))
            return RingCache(k=cont.k.at[slot].set(rk),
                             v=cont.v.at[slot].set(rv),
                             pos=cont.pos.at[slot].set(rp))
        if isinstance(cont, (SsmCache, RglruCache)):
            def row(c, o):
                o = jnp.squeeze(o, axis=ax)
                if ax:
                    return c.at[:, slot].set(o)
                return c.at[slot].set(o)
            return type(cont)(*[row(c, o) for c, o in zip(cont, one)])
        raise ValueError(f"unsupported cache node {type(cont)} at {path}")

    with jax.named_scope("repro.commit_prefill"):
        return jax.tree_util.tree_map_with_path(insert, state, solo,
                                                is_leaf=_cache_leaf)


def commit_chunk(state, solo, chunk_start, n_new, slot, block_ids, *,
                 block_size: int):
    """Incremental sibling of :func:`commit_prefill`: commit ONE prefill
    chunk of a streaming request into the continuous decode state.

    ``solo`` is the batch-1 chunk-stream scratch cache (``transformer.
    prefill`` at the chunk width, then ``prefill_chunk`` per chunk; no pad
    -- chunked prompts are never left-padded).  Global-attention K/V rows
    ``chunk_start .. chunk_start + n_new - 1`` gather out of the dense
    scratch and scatter into the slot's pool blocks; ring/recurrent rows
    rewrite WHOLESALE each chunk (they are tiny, and the engine's decode
    dispatch garbage-steps the streaming slot's rows every tick -- see
    ``ServingEngine._advance_stream``).  jit-compatible: ``chunk_start`` /
    ``n_new`` / ``slot`` are traced scalars and ``block_ids`` is the slot's
    FULL table-width row, so one program serves every chunk of every
    request.  Junk lanes (past ``n_new``, i.e. the right-padded final
    chunk) redirect to the reserved dummy block 0.
    """
    nb = block_ids.shape[0]

    def insert(path, cont, one):
        ax = _batch_axis(path)
        if isinstance(cont, KVCache):
            chunk = None

            def paged(pool, leaf):
                nonlocal chunk
                tok = leaf.shape[ax + 1]
                if chunk is None:
                    # lane -> (pool block, offset); junk lanes hit block 0
                    pos = chunk_start + jnp.arange(tok)
                    ok = jnp.arange(tok) < n_new
                    blk = jnp.where(
                        ok, block_ids[jnp.minimum(pos // block_size, nb - 1)],
                        0)
                    chunk = (jnp.minimum(pos, tok - 1), blk, pos % block_size)
                pos, blk, off = chunk
                x = jnp.squeeze(leaf, axis=ax)       # (L..., s_max, KV, hd)
                x = jnp.take(x, pos, axis=ax)
                if ax:
                    return pool.at[:, blk, off].set(x)
                return pool.at[blk, off].set(x)
            return KVCache(k=paged(cont.k, one.k), v=paged(cont.v, one.v))
        if isinstance(cont, RingCache):
            # chunk streams are pad-free: ring slots/positions already
            # semantic, copy the whole row
            rk = jnp.squeeze(one.k, axis=ax)
            rv = jnp.squeeze(one.v, axis=ax)
            rp = jnp.squeeze(one.pos, axis=ax)
            if ax:
                return RingCache(k=cont.k.at[:, slot].set(rk),
                                 v=cont.v.at[:, slot].set(rv),
                                 pos=cont.pos.at[:, slot].set(rp))
            return RingCache(k=cont.k.at[slot].set(rk),
                             v=cont.v.at[slot].set(rv),
                             pos=cont.pos.at[slot].set(rp))
        if isinstance(cont, (SsmCache, RglruCache)):
            def row(c, o):
                o = jnp.squeeze(o, axis=ax)
                if ax:
                    return c.at[:, slot].set(o)
                return c.at[slot].set(o)
            return type(cont)(*[row(c, o) for c, o in zip(cont, one)])
        raise ValueError(f"unsupported cache node {type(cont)} at {path}")

    with jax.named_scope("repro.commit_chunk"):
        return jax.tree_util.tree_map_with_path(insert, state, solo,
                                                is_leaf=_cache_leaf)


def _pool_leaf_spec(mesh, path, leaf):
    """Placement policy for one decode-state leaf: pool/ring kv-head dims
    shard over ``"model"`` when divisible, everything else (block-shaped
    axes, ring positions, recurrent state) replicates."""
    from jax.sharding import PartitionSpec as P

    if "model" not in mesh.axis_names:
        return P()
    m = mesh.shape["model"]
    last = path[-1]
    name = str(getattr(last, "name", getattr(last, "key", "")))
    if name in ("k", "v") and leaf.ndim >= 4 and leaf.shape[-2] % m == 0:
        return P(*([None] * (leaf.ndim - 2)), "model", None)
    return P()


def decode_state_specs(mesh, state) -> list[tuple]:
    """``[(path_str, shape, PartitionSpec)]`` for every decode-state leaf --
    the exact policy :func:`place_decode_state` applies, exported so
    ``analysis.shardcheck`` can verify it statically (``state`` may be an
    ``eval_shape`` pytree and ``mesh`` a shape-only stand-in; no devices or
    arrays needed)."""
    from ..launch.sharding import _path_str

    leaves = jax.tree_util.tree_flatten_with_path(state)[0]
    return [(_path_str(path), tuple(leaf.shape),
             _pool_leaf_spec(mesh, path, leaf)) for path, leaf in leaves]


def place_decode_state(mesh, state):
    """Device-put the decode state under a mesh: pool/ring kv-head dims
    shard over ``"model"`` when divisible, block tables and everything else
    replicate (each model shard reads the same table, gathers its own head
    shard)."""
    from jax.sharding import NamedSharding

    def place(path, leaf):
        return jax.device_put(
            leaf, NamedSharding(mesh, _pool_leaf_spec(mesh, path, leaf)))

    return jax.tree_util.tree_map_with_path(place, state)
