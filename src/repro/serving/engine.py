"""Batched serving engine for the edge tier: continuous batching over fixed
decode slots, KV-cache managed through the transformer cache pytree.

The ES side of the paper's system: requests (prompts) arrive continuously;
the engine prefills them into free slots and steps all active slots together
(synchronized decode).  Finished sequences free their slot for the next
queued request.  Works on any decoder-only arch config.

Mixed-length prompt batches are EXACT on every stack kind: ``_admit``
left-pads shorter prompts and hands the per-row pad counts to
``transformer.prefill``, which masks the pad positions out of attention,
shifts RoPE to each row's true token index, and (for recurrent "r"/"s"
blocks) zeroes pad inputs ahead of the causal convs and resets the scan
state at the pad boundary -- a padded prompt's tokens equal its solo run
(pinned by tests/test_serving.py::test_engine_mixed_lengths_match_solo and
tests/test_ragged.py for hybrid/SSM stacks on both dispatch paths).  See
docs/serving.md for the full ragged-semantics contract.

Prefill shapes are BUCKETED: prompts pad up to the next power-of-two width
(``prefill_buckets``), so the jitted prefill compiles once per bucket --
steady-state serving triggers no recompiles regardless of prompt-length mix
(pinned by tests/test_serving.py::test_prefill_bucketing_avoids_recompiles).
The pad mask makes the extra bucket padding semantics-free, and bucket
selection never eats the decode budget (``bucket + max_new <= s_max``; see
``_bucket_width``).  Pad-free batches skip the mask entirely and keep the
dense/Pallas kernel prefill path.

A traffic recorder (duck-typed; see ``repro.traffic.recorder``) can observe
the request lifecycle: the engine reports submit/admit/complete in units of
its step clock (one ``step()`` call == one tick), which
``TrafficRecorder.to_trace`` bins into a replayable arrival trace.
"""
from __future__ import annotations

import dataclasses
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from ..models import transformer


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # (S,) int32
    max_new: int = 16
    ue: int | None = None       # originating UE (traffic-trace binning);
                                # None -> recorder falls back to rid % n_ue
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


def _bucket_ladder(s_max: int, lo: int = 8) -> tuple[int, ...]:
    """Power-of-two prompt-width buckets up to s_max (always includes s_max)."""
    buckets = []
    w = lo
    while w < s_max:
        buckets.append(w)
        w *= 2
    buckets.append(s_max)
    return tuple(buckets)


class ServingEngine:
    """``mesh=`` (any mesh with a ``"model"`` axis, e.g.
    ``launch.mesh.make_cells_mesh(model=M)``) turns on tensor parallelism:
    params are placed with the ``launch.sharding`` policy and the jitted
    prefill/decode trace under the mesh's activation-sharding context, so
    GSPMD splits attention heads / FFN hidden / vocab M ways.  Model-sharded
    serving produces the same greedy tokens as the unsharded engine
    (tests/test_model_axis.py pins it, ragged batches included)."""

    def __init__(self, cfg, params, *, slots: int = 4, s_max: int = 128,
                 prefill_buckets=None, recorder=None, mesh=None):
        self.mesh = mesh
        if mesh is not None:
            from ..launch.sharding import place_params
            params = place_params(mesh, cfg, params)
        self.cfg, self.params = cfg, params
        self.slots = slots
        self.s_max = s_max
        self.prefill_buckets = tuple(sorted(
            _bucket_ladder(s_max) if prefill_buckets is None
            else prefill_buckets))
        if not self.prefill_buckets or self.prefill_buckets[-1] > s_max:
            raise ValueError(f"prefill buckets {self.prefill_buckets} must be "
                             f"non-empty and <= s_max={s_max}")
        self.recorder = recorder
        self.clock = 0                       # engine ticks (step() calls)
        self.queue: deque[Request] = deque()
        self.active: list[Request | None] = [None] * slots
        self._completed: list[Request] = []
        self.remaining = np.zeros(slots, np.int32)
        self.cache = None
        # (slots, width, ragged?) triples traced so far == jit compilations
        self._prefill_shapes: set[tuple] = set()
        from ..launch.sharding import shard_ctx
        self._decode = shard_ctx(mesh, jax.jit(
            lambda cache, toks: transformer.decode_step(params, cfg, cache, toks)))
        self._prefill = shard_ctx(mesh, jax.jit(
            lambda batch, pad: transformer.prefill(params, cfg, batch,
                                                   s_max=s_max, pad=pad)))

    @property
    def prefill_compiles(self) -> int:
        """Distinct prefill signatures traced so far (== jit compilations):
        one per (slots, bucket width, ragged-or-not) combination."""
        return len(self._prefill_shapes)

    def submit(self, req: Request):
        self.queue.append(req)
        if self.recorder is not None:
            self.recorder.record_submit(req.rid, self.clock, ue=req.ue)

    def _bucket_width(self, width: int, max_new: int) -> int:
        """Smallest bucket >= width that still leaves ``max_new`` KV slots.

        Bucket slack must never eat the decode budget: prefill starts the
        cache position at the bucket width, so ``bucket + max_new`` KV slots
        are written overall and must fit in ``s_max`` (decode's
        dynamic_update_slice would silently clamp past the end otherwise).
        When every bucket that fits is narrower than needed, fall back to
        the exact width (one extra compiled shape beats corrupt output);
        if even that cannot fit, the request is genuinely oversized.
        """
        limit = self.s_max - max_new
        if width > limit:
            raise ValueError(
                f"prompt width {width} + decode budget {max_new} exceeds "
                f"s_max={self.s_max}")
        for b in self.prefill_buckets:
            if b >= width and b <= limit:
                return b
        return width

    def _admit(self):
        """Fill free slots with queued requests (batch prefill).

        Synchronized-batch simplification: admission happens when ALL slots
        are free (prompts share one prefill); a production engine would use
        per-slot position tracking -- noted in DESIGN.md.

        Shorter prompts are LEFT-padded to the batch's bucket width; the pad
        counts flow into ``transformer.prefill`` as an attention mask +
        position shift, so padding (mixed lengths AND bucket slack) never
        changes any row's logits.
        """
        if any(r is not None for r in self.active) or not self.queue:
            return
        batch = []
        while self.queue and len(batch) < self.slots:
            batch.append(self.queue.popleft())
        while len(batch) < self.slots:       # pad with a copy (masked out)
            batch.append(Request(rid=-1, prompt=batch[0].prompt, max_new=0))
        width = self._bucket_width(max(len(r.prompt) for r in batch),
                                   max(r.max_new for r in batch))
        toks = np.stack([np.pad(r.prompt, (width - len(r.prompt), 0))
                         for r in batch])    # left-pad to the bucket width
        pad = np.asarray([width - len(r.prompt) for r in batch], np.int32)
        # A pad-free batch (all prompts exactly bucket-width) skips the mask
        # entirely: prefill keeps its dense/Pallas kernel path and the cache
        # carries no "pad" entry (the decode fast path).
        pad_arg = jnp.asarray(pad) if pad.any() else None
        self._prefill_shapes.add(toks.shape + (pad_arg is not None,))
        logits, cache = self._prefill({"tokens": jnp.asarray(toks, jnp.int32)},
                                      pad_arg)
        self.cache = cache
        nxt = np.asarray(jnp.argmax(logits, -1))
        for i, r in enumerate(batch):
            self.active[i] = r if r.rid >= 0 else None
            self.remaining[i] = r.max_new
            if r.rid >= 0:
                if self.recorder is not None:
                    self.recorder.record_admit(r.rid, self.clock)
                if r.max_new > 0:
                    r.out.append(int(nxt[i]))
                    self.remaining[i] -= 1
        self._last = nxt

    def step(self) -> bool:
        """One engine iteration (one clock tick).  Returns False when idle.

        The clock advances on every call -- idle ticks included -- so a
        driver that interleaves ``submit`` with ``step`` produces lifecycle
        timestamps on one monotonic time base for the traffic recorder.
        """
        self.clock += 1
        self._admit()
        if self.cache is None or all(r is None for r in self.active):
            return False
        logits, self.cache = self._decode(self.cache,
                                          jnp.asarray(self._last, jnp.int32))
        nxt = np.asarray(jnp.argmax(logits, -1))
        self._last = nxt
        alive = False
        for i, r in enumerate(self.active):
            if r is None:
                continue
            if self.remaining[i] > 0:
                r.out.append(int(nxt[i]))
                self.remaining[i] -= 1
            if self.remaining[i] <= 0:
                r.done = True
                self.active[i] = None
                self._completed.append(r)
                if self.recorder is not None:
                    self.recorder.record_complete(r.rid, self.clock)
            else:
                alive = True
        if not alive and not self.queue:
            self.cache = None
        return True

    def pop_completed(self) -> list[Request]:
        """Drain and return requests finished since the last drain, in
        completion order.  Callers driving the engine through ``step()``
        directly should call this each tick -- completions are held until
        drained, so an undrained engine retains every finished Request.
        """
        finished, self._completed = self._completed, []
        return finished

    def run_until_idle(self, max_steps: int = 10_000) -> list[Request]:
        """Step until the queue and all slots drain (or ``max_steps``).

        Returns every request that completed during (or before, via manual
        ``step`` calls) this run, in completion order.
        """
        for _ in range(max_steps):
            if not self.step():
                break
        return self.pop_completed()
