"""Serving engine for the edge tier: continuous batching over a paged KV
cache, with the old synchronized-batch engine kept as a compat mode.

The ES side of the paper's system: requests (prompts) arrive continuously
and the paper's E2E-delay objective is a *serial queuing model* -- so the
default engine admits **per tick**: a queued request prefills into any free
decode slot while the other slots keep decoding, and its KV lands in
fixed-size blocks handed out by ``serving.kvpool.BlockAllocator`` (per-slot
block tables, free-list reuse).  Each slot carries its own cache length
(``seq_lens``) -- there is no shared write frontier -- and one jitted
``transformer.decode_step_paged`` call advances every active slot, gathering
each row's blocks through ``kernels/decode_attention`` with a per-row ragged
``valid_mask``.  When a slot outgrows its blocks and the pool is exhausted,
the **youngest** admitted request is preempted back to the front of the
queue (its blocks freed, its output discarded); greedy decode is
deterministic, so re-admission reproduces the same tokens and preemption is
invisible to parity.  ``sync_batching=True`` restores the old engine --
admission waits for ALL slots to drain and prompts share one batched
prefill -- kept for A/B latency baselines and parity tests.

Mixed-length prompts are EXACT in both modes.  Continuous mode prefills
each request SOLO (batch=1 at its bucket width, left-padded); the ragged
machinery (attention pad mask + shifted RoPE + reset-aware recurrent scans)
makes the bucket slack semantics-free, and ``kvpool.commit_prefill`` strips
the pad when writing the KV blocks, so the paged cache holds only real
tokens and decode needs no pad vector.  Sync mode batches the admitted
prompts into one left-padded prefill whose pad vector rides in the cache.
Either way a request's greedy tokens equal its solo run on every stack kind
(tests/test_serving.py, tests/test_ragged.py, tests/test_model_axis.py).

Prefill shapes are BUCKETED: prompts pad up to the next power-of-two width
(``prefill_buckets``), so the jitted prefill compiles once per bucket --
steady-state serving triggers no recompiles regardless of prompt-length mix
(pinned by tests/test_serving.py::test_prefill_bucketing_avoids_recompiles).
Bucket selection never eats the decode budget (``bucket + max_new - 1 <=
s_max``; see ``_bucket_width``).  Pad-free prompts skip the mask entirely
and keep the dense/Pallas kernel prefill path.

Long prompts prefill in CHUNKS (``prefill_chunk``; "auto" picks 32 when
``s_max`` allows): instead of stalling every decoding slot for one full
bucket-width prefill, admission streams the head request through
``transformer.prefill_chunk`` one chunk per tick, committing each chunk
incrementally into the slot's blocks (``kvpool.commit_chunk``) while the
other slots keep decoding -- decode-tick latency stays bounded by one chunk
regardless of prompt length.  At most one request streams at a time
(admission order is still strict FIFO), a mid-prefill slot can be preempted
like any other (the stream restarts from chunk 1 on re-admission), and the
final chunk's logits equal the whole-prompt prefill logits exactly, so
chunked == whole-prompt == solo greedy tokens on every servable stack kind
(tests/test_serving.py, tests/test_model_axis.py).

A traffic recorder (duck-typed; see ``repro.traffic.recorder``) can observe
the request lifecycle: the engine reports submit/admit/complete in units of
its step clock (one ``step()`` call == one tick), which
``TrafficRecorder.to_trace`` bins into a replayable arrival trace and
``TrafficRecorder.latency_stats`` turns into p50/p99 E2E latency.  A
request whose budget is exhausted at admission (``max_new <= 1``: one token
comes straight from the prefill logits, zero means none) completes AT its
admission tick in both modes -- it neither occupies a slot nor triggers a
decode dispatch.

A telemetry object (``telemetry=``, see :class:`repro.obs.Telemetry`) adds
metrics + spans at every lifecycle edge (submit/admit/prefill/decode-tick/
block-grow/preempt/complete) plus per-tick queue/KV-pool gauges.  Disabled
(the default) it costs one ``self.obs is None`` check per site; enabled it
reads only host state the engine already materialized -- never an extra
device->host sync (docs/observability.md).

See docs/serving.md for the full contract.
"""
from __future__ import annotations

import dataclasses
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from ..models import transformer
from . import kvpool


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # (S,) int32
    max_new: int = 16
    ue: int | None = None       # originating UE (traffic-trace binning);
                                # None -> recorder falls back to rid % n_ue
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


def _bucket_ladder(s_max: int, lo: int = 8) -> tuple[int, ...]:
    """Power-of-two prompt-width buckets up to s_max (always includes s_max)."""
    buckets = []
    w = lo
    while w < s_max:
        buckets.append(w)
        w *= 2
    buckets.append(s_max)
    return tuple(buckets)


class ServingEngine:
    """``mesh=`` (any mesh with a ``"model"`` axis, e.g.
    ``launch.mesh.make_cells_mesh(model=M)``) turns on tensor parallelism:
    params are placed with the ``launch.sharding`` policy and the jitted
    prefill/decode trace under the mesh's activation-sharding context, so
    GSPMD splits attention heads / FFN hidden / vocab M ways.  The KV block
    pool shards its kv-head dim the same way while the block tables stay
    replicated (every shard indexes the same table, gathers its own head
    shard).  Model-sharded serving produces the same greedy tokens as the
    unsharded engine (tests/test_model_axis.py pins it, ragged batches
    included).

    ``sync_batching=False`` (default): continuous batching -- per-tick
    admission into free slots, paged KV (``kv_block`` tokens per block,
    ``kv_blocks`` pool blocks; default sized so every slot can reach
    ``s_max``), youngest-request preemption when the pool runs dry.
    ``sync_batching=True``: the synchronized-batch compat engine.

    ``sanitize=True`` (debug; ``python -m repro.analysis --sanitize``)
    turns on the memory-safety layer: a :class:`analysis.sanitize.
    KVSanitizer` shadows every block handoff (double-free, free-of-
    unowned, cross-slot aliasing, dummy-block writes, leak-at-drain) and
    the jitted prefill/commit/decode programs run under ``checkify``
    NaN/index-OOB guards.  Off (the default) the only cost is one
    ``self._san is None`` check per lifecycle edge -- the same zero-cost
    discipline as telemetry (docs/serving.md, "Sanitizer runtime").
    """

    def __init__(self, cfg, params, *, slots: int = 4, s_max: int = 128,
                 prefill_buckets=None, recorder=None, mesh=None,
                 sync_batching: bool = False, kv_block: int = 16,
                 kv_blocks: int | None = None, telemetry=None,
                 sanitize: bool = False, prefill_chunk="auto"):
        self.mesh = mesh
        if mesh is not None:
            from ..launch.sharding import place_params
            params = place_params(mesh, cfg, params)
        self.cfg, self.params = cfg, params
        self.slots = slots
        self.s_max = s_max
        self.sync_batching = sync_batching
        self.prefill_buckets = tuple(sorted(
            _bucket_ladder(s_max) if prefill_buckets is None
            else prefill_buckets))
        if not self.prefill_buckets or self.prefill_buckets[-1] > s_max:
            raise ValueError(f"prefill buckets {self.prefill_buckets} must be "
                             f"non-empty and <= s_max={s_max}")
        # chunked prefill (continuous mode): prompts LONGER than this stream
        # through admission one chunk per tick instead of whole-prompt
        # prefilling in a single tick ("auto": 32 when s_max allows, else
        # off; None disables).  Sync mode ignores it (the compat engine IS
        # the head-of-line baseline).
        if prefill_chunk == "auto":
            prefill_chunk = 32 if s_max > 32 else None
        if prefill_chunk is not None and not 0 < int(prefill_chunk) <= s_max:
            raise ValueError(f"prefill_chunk={prefill_chunk} must be in "
                             f"[1, s_max={s_max}], None, or 'auto'")
        if "m" in (*cfg.block_pattern, *cfg.tail_pattern):
            # capacity-based MoE routing couples every token in a dispatch
            # group, so chunk-local prefill cannot match the whole-prompt
            # dispatch exactly -- MoE stacks keep whole-prompt prefill
            # (see transformer._layer_chunk)
            prefill_chunk = None
        self.prefill_chunk = None if prefill_chunk is None \
            else int(prefill_chunk)
        self.recorder = recorder
        self.clock = 0                       # engine ticks (step() calls)
        self.queue: deque[Request] = deque()
        self.active: list[Request | None] = [None] * slots
        self._completed: list[Request] = []
        self.remaining = np.zeros(slots, np.int32)
        self.decode_steps = 0                # jitted decode dispatches
        self.preemptions = 0                 # continuous mode only
        self.cache = None                    # sync mode's shared cache
        # (batch, width, ragged?) triples traced so far == jit compilations
        self._prefill_shapes: set[tuple] = set()
        # telemetry (repro.obs.Telemetry): every instrumentation site below
        # is one `self.obs is not None` check when disabled, and reads only
        # already-materialized host state when enabled (docs/observability.md)
        self.obs = None
        if telemetry is not None:
            from ..obs.enginehooks import EngineHooks
            self.obs = EngineHooks(telemetry, self)
        from ..launch.sharding import shard_ctx

        # sanitizer runtime (analysis.sanitize): OFF by default, costing one
        # `self._san is None` check per lifecycle edge -- same zero-cost
        # discipline as telemetry.  On, every jitted program gains checkify
        # NaN/index-OOB guards and the KV pool gets shadow ownership checks.
        self._san = None
        self.sanitize = sanitize
        if sanitize:
            from ..analysis.sanitize import checkify_wrap

        def _jit(fn, donate=None):
            """jit one engine program; in sanitize mode wrap it with
            checkify guards instead (no donation there: the checkified
            signature threads an error value, and sanitize is a debug
            mode)."""
            if sanitize:
                return shard_ctx(mesh, checkify_wrap(fn))
            jitted = jax.jit(fn) if donate is None else \
                jax.jit(fn, donate_argnums=donate)
            return shard_ctx(mesh, jitted)

        # Greedy argmax happens INSIDE the jitted programs: only the (B,)
        # int32 next-token ids ever cross to the host, never the (B, vocab)
        # logits, and the argmax fuses into the decode dispatch instead of
        # running as a separate eager op every tick (reprolint: host-sync).
        def greedy(out):
            logits, cache = out
            return jnp.argmax(logits, -1).astype(jnp.int32), cache

        self._prefill = _jit(
            lambda batch, pad: greedy(transformer.prefill(
                params, cfg, batch, s_max=s_max, pad=pad)))
        if sync_batching:
            self._decode = _jit(
                lambda cache, toks: greedy(transformer.decode_step(
                    params, cfg, cache, toks)))
            return

        # -- continuous-batching state ------------------------------------
        self.kv_block = kv_block
        self.table_width = -(-s_max // kv_block)            # blocks per slot
        if kv_blocks is None:
            # every slot can page out to s_max, plus the reserved dummy
            kv_blocks = slots * self.table_width + 1
        self.allocator = kvpool.BlockAllocator(kv_blocks, kv_block)
        state = kvpool.init_decode_state(cfg, params, slots, kv_blocks,
                                         kv_block)
        if mesh is not None:
            state = kvpool.place_decode_state(mesh, state)
        self._pool_state = state
        self.block_tables = np.zeros((slots, self.table_width), np.int32)
        self.seq_lens = np.zeros(slots, np.int32)
        self.last_tokens = np.zeros(slots, np.int32)
        self.owned: list[list[int]] = [[] for _ in range(slots)]
        self._admit_seq = np.full(slots, -1, np.int64)      # admission order
        self._admit_counter = 0
        # The per-tick state updates DONATE their input pool (argnum 0):
        # the engine always rebinds self._pool_state to the result, and
        # without donation every tick/commit briefly holds TWO full KV
        # pools live -- the exact peak-memory hazard
        # `analysis.shardcheck`'s donation probe gates.
        self._commit = _jit(
            lambda state, solo, pad, slot, ids: kvpool.commit_prefill(
                state, solo, pad, slot, ids, block_size=kv_block),
            donate=0)
        self._decode_paged = _jit(
            lambda state, toks, table, lens: greedy(
                transformer.decode_step_paged(params, cfg, state, toks,
                                              table, lens)),
            donate=0)
        # -- chunked-prefill stream state: at most ONE request mid-prefill
        # (see _start_stream / _advance_stream).  Both chunk programs take
        # traced scalars and a full table-width id row, so each compiles
        # exactly ONCE regardless of prompt length or chunk index
        # (analysis.retrace pins it).
        self._stream_req: Request | None = None
        self._stream_slot = -1
        self._stream_cache = None            # device {units, tail} scratch
        self._stream_done = 0                # prompt tokens advanced so far
        self._stream_ids = None              # device (table_width,) block row
        if self.prefill_chunk is not None:
            self._chunk_step = _jit(
                lambda cache, toks, start, n_valid: greedy(
                    transformer.prefill_chunk(params, cfg, cache, toks,
                                              start, n_valid)),
                donate=0)
            self._commit_chunk = _jit(
                lambda state, solo, start, n_new, slot, ids:
                    kvpool.commit_chunk(state, solo, start, n_new, slot,
                                        ids, block_size=kv_block),
                donate=0)
        if sanitize:
            from ..analysis.sanitize import KVSanitizer
            self._san = KVSanitizer(self)

    @property
    def prefill_compiles(self) -> int:
        """Distinct prefill signatures traced so far (== jit compilations):
        one per (batch, bucket width, ragged-or-not) combination."""
        return len(self._prefill_shapes)

    def submit(self, req: Request):
        if req.ue is not None and req.ue < 0:
            raise ValueError(f"request {req.rid}: ue must be >= 0, got "
                             f"{req.ue} (negative UEs would fold into valid "
                             f"trace columns)")
        # Budget check up front: the prompt plus max_new - 1 decode writes
        # (the first token comes from the prefill logits) must fit s_max.
        # Rejecting HERE -- not mid-admission, after blocks were allocated
        # and the request popped -- is what keeps an oversized request from
        # leaking KV blocks and vanishing from the queue.
        n = len(req.prompt)
        if n + max(req.max_new, 1) - 1 > self.s_max:
            raise ValueError(
                f"request {req.rid}: prompt width {n} + decode budget "
                f"{req.max_new} exceeds s_max={self.s_max}")
        self.queue.append(req)
        if self.recorder is not None:
            self.recorder.record_submit(req.rid, self.clock, ue=req.ue)
        if self.obs is not None:
            self.obs.on_submit(req, self.clock)

    def _bucket_width(self, width: int, max_new: int) -> int:
        """Smallest bucket >= width that still leaves ``max_new`` tokens.

        Bucket slack must never eat the decode budget: prefill starts the
        cache position at the bucket width and the first decode token comes
        from the prefill logits, so ``bucket + max_new - 1`` KV slots are
        written overall and must fit in ``s_max`` (decode's
        dynamic_update_slice would silently clamp past the end otherwise).
        When every bucket that fits is narrower than needed, fall back to
        the exact width (one extra compiled shape beats corrupt output);
        if even that cannot fit, the request is genuinely oversized.
        """
        limit = self.s_max - max_new + 1
        if width > limit:
            raise ValueError(
                f"prompt width {width} + decode budget {max_new} exceeds "
                f"s_max={self.s_max}")
        for b in self.prefill_buckets:
            if b >= width and b <= limit:
                return b
        return width

    # -- shared lifecycle helpers -------------------------------------------

    def _complete(self, req: Request):
        req.done = True
        self._completed.append(req)
        if self.recorder is not None:
            self.recorder.record_complete(req.rid, self.clock)
        if self.obs is not None:
            self.obs.on_complete(req, self.clock)

    def _complete_at_admission(self, req: Request):
        """Budget exhausted at admit time (max_new <= 1): the single token
        (if any) came from the prefill logits, so the request completes AT
        its admission tick -- no slot, no decode dispatch."""
        if self.recorder is not None:
            self.recorder.record_admit(req.rid, self.clock)
        if self.obs is not None:
            self.obs.on_admit(req, self.clock)
        self._record_prefill_done(req.rid)
        self._complete(req)

    def _solo_prefill(self, req: Request):
        """Batch-1 bucketed prefill.  Returns (next-token int, cache, pad)."""
        n = len(req.prompt)
        width = self._bucket_width(n, max(req.max_new, 1))
        toks = np.pad(np.asarray(req.prompt), (width - n, 0))[None]
        pad = width - n
        pad_arg = jnp.asarray([pad], jnp.int32) if pad else None
        self._prefill_shapes.add((1, width, pad_arg is not None))
        t0 = self.obs.now() if self.obs is not None else 0.0
        tok, cache = self._prefill(
            {"tokens": jnp.asarray(toks, jnp.int32)}, pad_arg)
        # admission's one sanctioned sync: a single int32 per admitted request
        nxt = int(np.asarray(tok)[0])    # reprolint: ignore[host-sync]
        if self.obs is not None:         # host state only: span + compile gauge
            self.obs.on_prefill(self, t0, batch=1, width=width)
        return nxt, cache, pad

    # -- continuous batching ------------------------------------------------

    def _record_prefill_done(self, rid: int):
        """Duck-typed like the other record_* hooks; older recorders
        without the method (or recorder=None) are skipped."""
        rec = getattr(self.recorder, "record_prefill_done", None)
        if rec is not None:
            rec(rid, self.clock)
        if self.obs is not None:
            self.obs.on_prefill_done(rid, self.clock)

    def _admit_continuous(self):
        """Admit from the queue head into free slots, one request per solo
        prefill, until slots or KV blocks run out (FIFO: a request that
        cannot be placed blocks the ones behind it).  While a chunked
        prefill is streaming, THIS tick's admission work is the stream's
        next chunk and nothing else -- strict FIFO, bounded tick cost
        (see _advance_stream)."""
        if self._stream_req is not None:
            self._advance_stream()
            return
        while self.queue:
            req = self.queue[0]
            n = len(req.prompt)
            if req.max_new <= 0:
                self.queue.popleft()
                self._complete_at_admission(req)
                continue
            if req.max_new == 1:
                self.queue.popleft()
                nxt, _, _ = self._solo_prefill(req)
                req.out.append(nxt)
                self._complete_at_admission(req)
                continue
            free = [i for i, r in enumerate(self.active) if r is None]
            if not free:
                return
            # worst case the request holds len + max_new - 1 KV tokens; a
            # request that could never fit the pool must fail loudly, not
            # preempt-loop forever
            total = kvpool.blocks_for(n + req.max_new - 1, self.kv_block)
            if total > self.allocator.capacity:
                raise ValueError(
                    f"request {req.rid} needs {total} KV blocks "
                    f"({n} prompt + {req.max_new} decode tokens) but the "
                    f"pool holds {self.allocator.capacity}")
            blocks = self.allocator.alloc(kvpool.blocks_for(n, self.kv_block))
            if blocks is None:
                return                       # pool full: wait for completions
            self.queue.popleft()
            slot = free[0]
            try:
                if self.prefill_chunk is not None and n > self.prefill_chunk:
                    self._start_stream(req, slot, blocks)
                    return               # one chunk of prefill work per tick
                nxt, cache, pad = self._solo_prefill(req)
            except Exception:
                # belt: submit() validates the budget up front, but any
                # raise past alloc/popleft must neither leak the blocks nor
                # silently drop the request
                self.allocator.free(blocks)
                self.queue.appendleft(req)
                raise
            width = len(req.prompt) + pad
            # ids length is the bucket width in blocks: one compile per
            # bucket, exactly like prefill itself
            ids = np.zeros(-(-width // self.kv_block), np.int32)
            ids[:len(blocks)] = blocks       # slack blocks -> dummy block 0
            solo = {"units": cache["units"], "tail": cache["tail"]}
            self._pool_state = self._commit(   # reprolint: ignore[recompile-hazard]
                self._pool_state, solo, jnp.int32(pad), jnp.int32(slot),
                jnp.asarray(ids))
            req.out.append(nxt)
            self.active[slot] = req
            self.owned[slot] = list(blocks)
            self.block_tables[slot, :] = 0
            self.block_tables[slot, :len(blocks)] = blocks
            self.seq_lens[slot] = n
            self.last_tokens[slot] = nxt
            self.remaining[slot] = req.max_new - 1
            self._admit_seq[slot] = self._admit_counter
            self._admit_counter += 1
            if self._san is not None:
                self._san.on_alloc(slot, blocks)
            if self.recorder is not None:
                self.recorder.record_admit(req.rid, self.clock)
            if self.obs is not None:
                self.obs.on_admit(req, self.clock)
            self._record_prefill_done(req.rid)

    def _start_stream(self, req: Request, slot: int, blocks):
        """Begin a chunked prefill: run chunk 1 (a plain batch-1 prefill at
        the chunk width -- its KV scratch is already ``s_max``-sized, so it
        doubles as the stream's resumable cache) and commit it into the
        slot's blocks.  The slot is admitted -- it owns its blocks and holds
        the request -- but stays OUT of the decode dispatch (``seq_lens`` 0
        plus a dummy-masked table row) until the final chunk lands; see
        :meth:`_advance_stream`."""
        c = self.prefill_chunk
        toks = np.asarray(req.prompt, np.int32)[None, :c]
        self._prefill_shapes.add((1, c, False))
        t0 = self.obs.now() if self.obs is not None else 0.0
        _, cache = self._prefill({"tokens": jnp.asarray(toks)}, None)
        cache = {"units": cache["units"], "tail": cache["tail"]}
        if self.obs is not None:
            self.obs.on_prefill(self, t0, batch=1, width=c, chunked=True)
        # the FULL table-width id row (slack -> dummy block 0): one
        # compiled chunk-commit signature for every request shape
        ids = np.zeros(self.table_width, np.int32)
        ids[:len(blocks)] = blocks
        self._stream_ids = jnp.asarray(ids)
        self._pool_state = self._commit_chunk(
            self._pool_state, cache, jnp.int32(0), jnp.int32(c),
            jnp.int32(slot), self._stream_ids)
        self._stream_req, self._stream_slot = req, slot
        self._stream_cache, self._stream_done = cache, c
        self.active[slot] = req
        self.owned[slot] = list(blocks)
        self.block_tables[slot, :] = 0
        self.block_tables[slot, :len(blocks)] = blocks
        self.seq_lens[slot] = 0
        self.last_tokens[slot] = 0
        self.remaining[slot] = req.max_new - 1
        self._admit_seq[slot] = self._admit_counter
        self._admit_counter += 1
        if self._san is not None:
            self._san.on_alloc(slot, blocks)
        if self.recorder is not None:
            self.recorder.record_admit(req.rid, self.clock)
        if self.obs is not None:
            self.obs.on_admit(req, self.clock)

    def _advance_stream(self):
        """One chunk of the streaming request's prefill -- one per tick, so
        every other slot's decode latency stays bounded by a chunk, never a
        whole prompt.  Each chunk commits incrementally into the slot's
        blocks (``kvpool.commit_chunk``); the final chunk's logits ARE the
        whole-prompt prefill logits, so its argmax is the request's first
        token and the slot joins THIS tick's decode dispatch, exactly like
        a whole-prefill admission."""
        req, slot, c = self._stream_req, self._stream_slot, self.prefill_chunk
        n = len(req.prompt)
        start = self._stream_done
        n_valid = min(c, n - start)
        chunk = np.zeros((1, c), np.int32)
        chunk[0, :n_valid] = req.prompt[start:start + n_valid]
        t0 = self.obs.now() if self.obs is not None else 0.0
        tok, cache = self._chunk_step(self._stream_cache, jnp.asarray(chunk),
                                      jnp.int32(start), jnp.int32(n_valid))
        self._pool_state = self._commit_chunk(
            self._pool_state, cache, jnp.int32(start), jnp.int32(n_valid),
            jnp.int32(slot), self._stream_ids)
        if self.obs is not None:
            self.obs.on_prefill(self, t0, batch=1, width=c, chunked=True)
        self._stream_cache = cache
        self._stream_done = start + n_valid
        if self._stream_done < n:
            return
        # the stream's one sanctioned sync: a single int32 at the final chunk
        nxt = int(np.asarray(tok)[0])    # reprolint: ignore[host-sync]
        req.out.append(nxt)
        self.seq_lens[slot] = n
        self.last_tokens[slot] = nxt
        self._end_stream()
        self._record_prefill_done(req.rid)

    def _end_stream(self):
        self._stream_req, self._stream_slot = None, -1
        self._stream_cache, self._stream_done = None, 0
        self._stream_ids = None

    def _release_slot(self, slot: int):
        if self._san is not None:
            self._san.on_free(slot, self.owned[slot])
        self.allocator.free(self.owned[slot])
        self.owned[slot] = []
        self.block_tables[slot, :] = 0
        self.seq_lens[slot] = 0
        self.last_tokens[slot] = 0
        self.remaining[slot] = 0
        self._admit_seq[slot] = -1
        self.active[slot] = None

    def _preempt(self, slot: int):
        """Evict the request in ``slot`` back to the FRONT of the queue,
        discarding its output and KV (recompute-style preemption: greedy
        decode is deterministic, so re-admission regenerates the same
        tokens)."""
        req = self.active[slot]
        if slot == self._stream_slot:
            # mid-prefill evict: drop the chunk cursor + scratch; the
            # stream restarts from chunk 1 on re-admission (recompute
            # preemption, same as a decoding slot)
            self._end_stream()
        req.out.clear()
        self._release_slot(slot)
        self.queue.appendleft(req)
        self.preemptions += 1
        # duck-typed like the other record_* hooks; older recorders without
        # the method (or recorder=None) are skipped
        rec_preempt = getattr(self.recorder, "record_preempt", None)
        if rec_preempt is not None:
            rec_preempt(req.rid, self.clock)
        if self.obs is not None:
            self.obs.on_preempt(req, self.clock)

    def _grow_blocks(self):
        """Before a decode tick, make sure every active slot owns the block
        its next KV write lands in.  Oldest slots grow first; when the pool
        is dry, the YOUNGEST active request is preempted until the
        allocation succeeds (head-of-line requests always make progress --
        the admission fit check guarantees a lone request can reach its
        full budget)."""
        order = sorted((i for i, r in enumerate(self.active) if r is not None),
                       key=lambda i: self._admit_seq[i])
        for slot in order:
            if self.active[slot] is None:    # preempted below, mid-loop
                continue
            bidx = int(self.seq_lens[slot]) // self.kv_block
            if bidx < len(self.owned[slot]):
                continue
            while True:
                got = self.allocator.alloc(1)
                if got is not None:
                    self.owned[slot].append(got[0])
                    self.block_tables[slot, bidx] = got[0]
                    if self._san is not None:
                        self._san.on_alloc(slot, got)
                    if self.obs is not None:
                        self.obs.on_block_grow()
                    break
                victim = max(
                    (j for j, r in enumerate(self.active) if r is not None),
                    key=lambda j: self._admit_seq[j])
                self._preempt(victim)
                if victim == slot:
                    break                    # this slot went back to queue

    def _step_continuous(self) -> bool:
        self._admit_continuous()
        self._grow_blocks()
        live = [i for i, r in enumerate(self.active)
                if r is not None and i != self._stream_slot]
        # per-tick telemetry is SAMPLED by clock stride: even an
        # early-returning method call costs us-scale on the cold post-
        # dispatch path, so the stride check is inline int arithmetic and
        # non-sampled ticks skip the calls entirely (sample_every=1 for
        # exact per-tick reads)
        obs = self.obs
        sampled = obs is not None and self.clock % obs.sample_every == 0
        if sampled:                      # host-state gauges (queue, KV pool)
            obs.sample(self)
        if not live:
            return self._stream_req is not None or bool(self.queue)
        t0 = obs.now() if sampled else 0.0
        table = self.block_tables
        if self._stream_req is not None:
            # a mid-prefill slot rides the dispatch as an idle row: the
            # zeroed table row routes its garbage "g" writes to dummy block
            # 0, and the garbage stepping of its ring/recurrent pool rows is
            # erased by the next chunk's wholesale commit BEFORE the slot's
            # first real decode (see kvpool.commit_chunk)
            table = table.copy()
            table[self._stream_slot] = 0
        toks, self._pool_state = self._decode_paged(
            self._pool_state, jnp.asarray(self.last_tokens),
            jnp.asarray(table), jnp.asarray(self.seq_lens))
        self.decode_steps += 1
        # the tick's one sanctioned sync: (slots,) int32 token ids
        nxt = np.asarray(toks)           # reprolint: ignore[host-sync]
        if sampled:
            obs.on_decode_tick(self, t0, len(live))
        for i in live:
            req = self.active[i]
            self.seq_lens[i] += 1
            self.last_tokens[i] = nxt[i]
            req.out.append(int(nxt[i]))
            self.remaining[i] -= 1
            if self.remaining[i] <= 0:
                self._release_slot(i)
                self._complete(req)
        if self._san is not None:
            self._san.check_tick()
        return True

    # -- synchronized-batch compat mode -------------------------------------

    def _admit_sync(self):
        """Compat-mode admission: wait until ALL slots are free, then prefill
        the next wave as one left-padded batch (pad counts ride in the cache
        so decode keeps masking them).  This is the architecture whose
        head-of-line blocking the continuous engine removes -- kept only for
        A/B baselines and parity tests (``sync_batching=True``)."""
        if any(r is not None for r in self.active) or not self.queue:
            return
        # Greedy wave build under PER-REQUEST budgets: the shared prefill
        # width w must cover every prompt AND leave every member its decode
        # room (w + max_new - 1 <= s_max per request -- row r decodes
        # max_new - 1 KV writes past the shared width).  Folding the wave's
        # budgets into one max(prompt) vs max(max_new) pair falsely
        # rejected individually-valid mixes (a long prompt with a short
        # budget + a short prompt with a long budget); instead a request
        # joins the wave only while a feasible width exists, and otherwise
        # starts the next wave.
        batch = []
        need, cap = 0, self.s_max + 1
        while self.queue and len(batch) < self.slots:
            r = self.queue[0]
            r_need = max(need, len(r.prompt))
            r_cap = min(cap, self.s_max + 1 - max(r.max_new, 1))
            if batch and r_need > r_cap:
                break                        # r starts the next wave
            batch.append(self.queue.popleft())
            need, cap = r_need, r_cap
        while len(batch) < self.slots:       # pad with a copy (masked out)
            batch.append(Request(rid=-1, prompt=batch[0].prompt, max_new=0))
        width = self._bucket_width(need, self.s_max + 1 - cap)
        toks = np.stack([np.pad(r.prompt, (width - len(r.prompt), 0))
                         for r in batch])    # left-pad to the bucket width
        pad = np.asarray([width - len(r.prompt) for r in batch], np.int32)
        # A pad-free batch (all prompts exactly bucket-width) skips the mask
        # entirely: prefill keeps its dense/Pallas kernel path and the cache
        # carries no "pad" entry (the decode fast path).
        pad_arg = jnp.asarray(pad) if pad.any() else None
        self._prefill_shapes.add(toks.shape + (pad_arg is not None,))
        t0 = self.obs.now() if self.obs is not None else 0.0
        tok_ids, cache = self._prefill(
            {"tokens": jnp.asarray(toks, jnp.int32)}, pad_arg)
        self.cache = cache
        # admission's one sanctioned sync (batch x int32)
        nxt = np.asarray(tok_ids)        # reprolint: ignore[host-sync]
        if self.obs is not None:
            self.obs.on_prefill(self, t0, batch=len(batch), width=width)
        for i, r in enumerate(batch):
            self.active[i] = r if r.rid >= 0 else None
            self.remaining[i] = r.max_new
            if r.rid < 0:
                continue
            if self.recorder is not None:
                self.recorder.record_admit(r.rid, self.clock)
            if self.obs is not None:
                self.obs.on_admit(r, self.clock)
            self._record_prefill_done(r.rid)
            if r.max_new > 0:
                r.out.append(int(nxt[i]))
                self.remaining[i] -= 1
            if self.remaining[i] <= 0:
                # budget exhausted by the prefill logits alone: complete at
                # the admission tick, don't ride through a decode step
                self.active[i] = None
                self._complete(r)
        self._last = nxt

    def _step_sync(self) -> bool:
        self._admit_sync()
        # sampled per-tick telemetry; see _step_continuous
        obs = self.obs
        sampled = obs is not None and self.clock % obs.sample_every == 0
        if sampled:                      # host-state gauges (queue, slots)
            obs.sample(self)
        if self.cache is None or all(r is None for r in self.active):
            self.cache = None
            return bool(self.queue)
        live = sum(1 for r in self.active if r is not None)
        t0 = obs.now() if sampled else 0.0
        toks, self.cache = self._decode(self.cache,
                                        jnp.asarray(self._last, jnp.int32))
        self.decode_steps += 1
        # the tick's one sanctioned sync: (slots,) int32 token ids
        nxt = np.asarray(toks)           # reprolint: ignore[host-sync]
        if sampled:
            obs.on_decode_tick(self, t0, live)
        self._last = nxt
        alive = False
        for i, r in enumerate(self.active):
            if r is None:
                continue
            if self.remaining[i] > 0:
                r.out.append(int(nxt[i]))
                self.remaining[i] -= 1
            if self.remaining[i] <= 0:
                self.active[i] = None
                self._complete(r)
            else:
                alive = True
        if not alive and not self.queue:
            self.cache = None
        return True

    # -- driver interface ----------------------------------------------------

    def step(self) -> bool:
        """One engine iteration (one clock tick).  Returns False when idle.

        The clock advances on every call -- idle ticks included -- so a
        driver that interleaves ``submit`` with ``step`` produces lifecycle
        timestamps on one monotonic time base for the traffic recorder.
        """
        self.clock += 1
        if self.sync_batching:
            return self._step_sync()
        alive = self._step_continuous()
        if self._san is not None and not alive:
            self._san.check_drain()         # idle engine: pool fully drained
        return alive

    def pop_completed(self) -> list[Request]:
        """Drain and return requests finished since the last drain, in
        completion order.  Callers driving the engine through ``step()``
        directly should call this each tick -- completions are held until
        drained, so an undrained engine retains every finished Request.
        """
        finished, self._completed = self._completed, []
        return finished

    def run_until_idle(self, max_steps: int = 10_000) -> list[Request]:
        """Step until the queue and all slots drain.

        Returns every request that completed during (or before, via manual
        ``step`` calls) this run, in completion order.  Raises RuntimeError
        when ``max_steps`` ticks pass with work still pending -- returning
        partial completions would be indistinguishable from a clean drain
        (callers that want bounded partial progress should drive ``step()``
        themselves and ``pop_completed()`` what finished).
        """
        for _ in range(max_steps):
            if not self.step():
                return self.pop_completed()
        raise RuntimeError(
            f"engine did not drain within max_steps={max_steps}: "
            f"{len(self.queue)} request(s) still queued, "
            f"{sum(r is not None for r in self.active)} slot(s) active")
