"""Batched serving engine for the edge tier: continuous batching over fixed
decode slots, KV-cache managed through the transformer cache pytree.

The ES side of the paper's system: requests (prompts) arrive continuously;
the engine prefills them into free slots and steps all active slots together
(synchronized decode).  Finished sequences free their slot for the next
queued request.  Works on any decoder-only arch config.

Known limitation -- mixed-length prompt batches are approximate.  ``_admit``
left-pads shorter prompts with token 0, but ``transformer.prefill`` applies
a plain causal mask with positions ``arange(S)`` and takes no padding mask:
real tokens attend the pad positions (and sit at shifted RoPE positions), so
a padded prompt's logits differ slightly from its solo run.  Equal-length
prompt batches involve no padding and are EXACT -- engine outputs match the
monolithic prefill+decode token-for-token (pinned by
tests/test_serving.py::test_engine_batch_matches_solo_equal_lengths).
Masking padding properly needs an attention-mask argument threaded through
``models.attention``; until then, callers that need exactness should submit
equal-length batches (or slots=1).
"""
from __future__ import annotations

import dataclasses
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from ..models import transformer


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # (S,) int32
    max_new: int = 16
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServingEngine:
    def __init__(self, cfg, params, *, slots: int = 4, s_max: int = 128):
        self.cfg, self.params = cfg, params
        self.slots = slots
        self.s_max = s_max
        self.queue: deque[Request] = deque()
        self.active: list[Request | None] = [None] * slots
        self._completed: list[Request] = []
        self.remaining = np.zeros(slots, np.int32)
        self.cache = None
        self._decode = jax.jit(
            lambda cache, toks: transformer.decode_step(params, cfg, cache, toks))
        self._prefill = jax.jit(
            lambda batch: transformer.prefill(params, cfg, batch, s_max=s_max))

    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        """Fill free slots with queued requests (batch prefill).

        Synchronized-batch simplification: admission happens when ALL slots
        are free (prompts share one prefill); a production engine would use
        per-slot position tracking -- noted in DESIGN.md.

        Shorter prompts are LEFT-padded with token 0 and the prefill gets no
        padding mask, so mixed-length batches are approximate (see the module
        docstring); equal-length batches are exact.
        """
        if any(r is not None for r in self.active) or not self.queue:
            return
        batch = []
        while self.queue and len(batch) < self.slots:
            batch.append(self.queue.popleft())
        while len(batch) < self.slots:       # pad with a copy (masked out)
            batch.append(Request(rid=-1, prompt=batch[0].prompt, max_new=0))
        width = max(len(r.prompt) for r in batch)
        toks = np.stack([np.pad(r.prompt, (width - len(r.prompt), 0))
                         for r in batch])    # left-pad to common width
        logits, cache = self._prefill({"tokens": jnp.asarray(toks, jnp.int32)})
        self.cache = cache
        nxt = np.asarray(jnp.argmax(logits, -1))
        for i, r in enumerate(batch):
            self.active[i] = r if r.rid >= 0 else None
            self.remaining[i] = r.max_new
            if r.rid >= 0 and r.max_new > 0:
                r.out.append(int(nxt[i]))
                self.remaining[i] -= 1
        self._last = nxt

    def step(self) -> bool:
        """One engine iteration.  Returns False when idle."""
        self._admit()
        if self.cache is None or all(r is None for r in self.active):
            return False
        logits, self.cache = self._decode(self.cache,
                                          jnp.asarray(self._last, jnp.int32))
        nxt = np.asarray(jnp.argmax(logits, -1))
        self._last = nxt
        alive = False
        for i, r in enumerate(self.active):
            if r is None:
                continue
            if self.remaining[i] > 0:
                r.out.append(int(nxt[i]))
                self.remaining[i] -= 1
            if self.remaining[i] <= 0:
                r.done = True
                self.active[i] = None
                self._completed.append(r)
            else:
                alive = True
        if not alive and not self.queue:
            self.cache = None
        return True

    def pop_completed(self) -> list[Request]:
        """Drain and return requests finished since the last drain, in
        completion order.  Callers driving the engine through ``step()``
        directly should call this each tick -- completions are held until
        drained, so an undrained engine retains every finished Request.
        """
        finished, self._completed = self._completed, []
        return finished

    def run_until_idle(self, max_steps: int = 10_000) -> list[Request]:
        """Step until the queue and all slots drain (or ``max_steps``).

        Returns every request that completed during (or before, via manual
        ``step`` calls) this run, in completion order.
        """
        for _ in range(max_steps):
            if not self.step():
                break
        return self.pop_completed()
