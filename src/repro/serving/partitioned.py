"""Partitioned-model execution: the paper's Fig. 1 on the LM stack.

A ``PartitionedLM`` splits a decoder-only arch at a *unit* boundary: units
``0..cut_unit-1`` run on the device tier ("UE"), the rest on the edge tier
("ES"), with the boundary hidden state (psi in the paper) crossing between.
The two halves are independent jitted programs, so on real hardware they
land on different meshes/hosts; the LyMDO controller picks ``cut_unit`` per
slot from the arch's layer profile (profiling/lmprofiles.py).

Cuts are restricted to unit boundaries (the block-scan granularity);
``layer_cut_to_unit`` maps a profile-layer cut onto the nearest unit cut.

``mesh=`` activates intra-tier tensor parallelism: on a mesh with a
``"model"`` axis (e.g. ``launch.mesh.make_cells_mesh(model=M)``) each
half's weights are placed with the ``launch.sharding`` policy -- attention
heads and FFN hidden dims split M ways -- so the UE and ES halves both
exploit per-cell model parallelism while the boundary activation (psi)
stays replicated across the model axis.  Model-sharded inference matches
the unsharded single-device result (tests/test_model_axis.py).

The ES tier also serves *continuous* token traffic: at the full-offload
cut (``cut_unit == 0``) the ES half holds the complete stack, and
:meth:`PartitionedLM.es_engine` stands up a continuous-batching
:class:`~repro.serving.engine.ServingEngine` on it -- per-tick admission
over the paged KV pool (``serving/kvpool.py``).  Under a model mesh the
pool's kv-head dim shards M ways while the per-slot block tables stay
replicated, mirroring psi's replication here: control state (tables,
seq_lens, psi) is tiny and shared, tensor state (KV, weights) splits.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..models import transformer
from ..models.common import dtype_of, rms_norm


def split_params(params, cut_unit: int):
    """Slice the stacked unit params into (ue_half, es_half)."""
    ue_units = jax.tree.map(lambda a: a[:cut_unit], params["units"])
    es_units = jax.tree.map(lambda a: a[cut_unit:], params["units"])
    ue = {"embed": params["embed"], "units": ue_units}
    es = {k: v for k, v in params.items() if k != "units"}
    es["units"] = es_units
    return ue, es


def layer_cut_to_unit(cfg: ArchConfig, layer_cut: int) -> int:
    """Map a profile-layer cut (0..L) to a unit boundary (0..n_units).

    Profile layers: [input, embed, stack..., head]; stack layer i sits in
    unit i // len(pattern)."""
    stack_cut = max(0, layer_cut - 2 + 1)    # layers executed locally
    unit = min(stack_cut // len(cfg.block_pattern), cfg.n_units)
    return unit


class PartitionedLM:
    """Two-tier forward pass for decoder-only archs (no tail/enc support --
    the controller keeps those archs at unit-boundary cuts of the main
    stack; DESIGN §4)."""

    def __init__(self, cfg: ArchConfig, params, cut_unit: int, *, mesh=None):
        assert not cfg.enc_layers and not cfg.tail_pattern, \
            "partitioned demo supports plain-stack archs"
        self.cfg = cfg
        self.cut_unit = int(cut_unit)
        self.mesh = mesh
        self.ue_params, self.es_params = split_params(params, self.cut_unit)
        if mesh is not None:
            from ..launch.sharding import place_params
            self.ue_params = place_params(mesh, cfg, self.ue_params)
            self.es_params = place_params(mesh, cfg, self.es_params)
        from ..launch.sharding import shard_ctx
        self._ue = shard_ctx(mesh, jax.jit(
            functools.partial(self._ue_half, cfg=cfg)))
        self._es = shard_ctx(mesh, jax.jit(
            functools.partial(self._es_half, cfg=cfg)))

    @staticmethod
    def _run_units(units, cfg, x, positions):
        def body(carry, unit_p):
            x = carry
            for i, kind in enumerate(cfg.block_pattern):
                x, _, _ = transformer._layer_full(
                    unit_p[f"slot{i}"], cfg, kind, x, positions, None, False)
            return x, None
        x, _ = jax.lax.scan(body, x, units)
        return x

    @staticmethod
    def _ue_half(params, tokens, *, cfg):
        x = params["embed"][tokens].astype(dtype_of(cfg.compute_dtype))
        positions = jnp.arange(tokens.shape[1])
        return PartitionedLM._run_units(params["units"], cfg, x, positions)

    @staticmethod
    def _es_half(params, hidden, *, cfg):
        positions = jnp.arange(hidden.shape[1])
        x = PartitionedLM._run_units(params["units"], cfg, hidden, positions)
        x = rms_norm(x, params["final_norm"])
        head = params["embed"].T if cfg.tie_embeddings else params["head"]
        return (x @ head).astype(jnp.float32)

    def boundary_bytes(self, batch: int, seq: int) -> int:
        """psi: what crosses the uplink (eq. 3's payload)."""
        if self.cut_unit == 0:
            return batch * seq * 4                      # raw tokens
        return batch * seq * self.cfg.d_model * 2        # bf16 hidden

    def es_engine(self, **engine_kwargs):
        """A continuous-batching :class:`~repro.serving.engine.ServingEngine`
        on the ES half (same mesh, same placement policy).

        Full-offload cuts only: with ``cut_unit == 0`` the ES params are the
        complete stack, exactly what the token-serving engine needs.
        Partial cuts split single *forward passes* across tiers -- their
        per-request schedule belongs to the MEC controller, not the ES
        decode loop -- so asking for an engine there is a usage error.
        """
        if self.cut_unit != 0:
            raise ValueError(
                f"es_engine needs the full-offload cut (cut_unit=0, the "
                f"whole stack on the ES tier); got cut_unit="
                f"{self.cut_unit}")
        from .engine import ServingEngine
        return ServingEngine(self.cfg, self.es_params, mesh=self.mesh,
                             **engine_kwargs)

    def infer(self, tokens):
        """Returns (logits, boundary_activation) -- the latter is what the
        transmission model charges for."""
        if self.cut_unit == 0:
            # full offload: raw tokens cross the uplink, ES does everything
            x = self.es_params["embed"][tokens].astype(
                dtype_of(self.cfg.compute_dtype))
            positions = jnp.arange(tokens.shape[1])
            x = self._run_units(self.es_params["units"], self.cfg, x, positions)
            x = rms_norm(x, self.es_params["final_norm"])
            head = (self.es_params["embed"].T if self.cfg.tie_embeddings
                    else self.es_params["head"])
            return (x @ head).astype(jnp.float32), tokens
        hidden = self._ue(self.ue_params, tokens)
        logits = self._es(self.es_params, hidden)
        return logits, hidden
