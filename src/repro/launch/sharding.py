"""Sharding policy: logical-axis rules -> PartitionSpecs for every leaf.

Policy (DESIGN §5):
  * batch                -> all DP axes ("pod","data")
  * attention heads, FFN hidden, vocab, experts  -> "model" (TP / EP)
  * params + optimizer moments additionally over "data" (FSDP/ZeRO-3) when
    ``cfg.fsdp`` (the >=27B archs) — with experts keeping E on "model" and
    FSDP applied to their d_model axis so a scanned unit's transient
    all-gather stays bounded
  * KV caches: batch -> "data", kv-heads -> "model" when divisible, else the
    *sequence* axis -> "model" (the long-cache decode cells)
  * anything non-divisible by the mesh axis stays replicated (e.g. gemma3's
    4 query heads on a 16-way model axis)

Rules are path-pattern driven so they apply uniformly to the stacked
block-scan params, the tail layers, and the optimizer state (which mirrors
the param tree).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any

import jax
from jax.sharding import NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ShardingOptions:
    """Hillclimb knobs (EXPERIMENTS.md §Perf).  Defaults = the paper-faithful
    baseline (naive TP x DP everywhere)."""

    tp_mode: str = "full"          # "full" | "vocab-only" | "moe-only"
    expert_shard_dff: bool = False  # experts: shard F over data (keep EP resident)
    seq_shard: bool = False        # context parallelism: activations S -> model
    microbatches: int | None = None  # override models.steps.default_microbatches
    fsdp_override: bool | None = None  # force ZeRO-3 on/off (None = per-arch cfg)
    remat_offload: bool = False    # host-offload the remat carry stacks
    expert_mesh: str = "model"     # expert-parallel axis: "model" | "data"
                                   # ("data" => tokens a2a over data, expert
                                   #  F over model: fully-resident weights)


BASELINE = ShardingOptions()


def recommended_options(cfg, shape_kind: str) -> ShardingOptions:
    """Beyond-paper defaults distilled from the §Perf hillclimb AND the
    framework-wide measurement pass (EXPERIMENTS.md §Perf "global policy";
    first-draft recipes that regressed cells were reverted per-family):

    * decode: ALWAYS baseline TP — ZeRO'd weights re-gather the whole model
      every token (measured 10-30x regressions); TP keeps weights resident.
    * MoE: resident-expert layout only when expert params dominate
      (llama4: 16 B/layer yes; moonshot: 0.55 B/layer no — token gathers
      outweigh weight movement there).
    * enc-dec (seamless): baseline for TRAIN (the 4k-frame encoder's bwd
      favors TP; pure-DP regressed 5x) but pure-DP for prefill (2.9x win).
    * <8B dense/ssm/hybrid train+prefill: pure-DP layers + ZeRO over data,
      mb=2 for train (cell A).
    * >=90B dense: train keeps TP (d >= 8k amortizes); prefill pure-DP +
      ZeRO-2D (cell C).
    """
    from ..profiling.roofline import param_count
    if shape_kind == "decode":
        return BASELINE
    if cfg.n_experts:
        expert_params = cfg.n_experts * (3 if cfg.gated_ffn else 2)             * cfg.d_model * cfg.resolved_moe_dff
        if expert_params * 2 > 8e9:        # bytes: resident layout pays off
            return ShardingOptions(
                tp_mode="moe-only", expert_shard_dff=True, remat_offload=True,
                microbatches=4 if shape_kind == "train" else None)
        return BASELINE
    if cfg.enc_layers and shape_kind == "train":
        return BASELINE
    n = param_count(cfg)
    if n < 8e9:
        return ShardingOptions(tp_mode="vocab-only", fsdp_override=True,
                               microbatches=2 if shape_kind == "train" else None)
    if shape_kind == "prefill":
        return ShardingOptions(tp_mode="vocab-only", fsdp_override=True)
    return ShardingOptions(microbatches=8)   # big-dense training: baseline TP


def _axis_size(mesh, name: str) -> int:
    if name not in mesh.axis_names:
        return 1
    return mesh.shape[name]


def _shard_if(mesh, dim: int, axis):
    """Use ``axis`` (a mesh axis name or tuple of names) only if the dim
    divides evenly (GSPMD could pad, but we keep shardings exact so memory
    analysis is honest)."""
    if axis is None:
        return None
    axes = (axis,) if isinstance(axis, str) else tuple(axis)
    n = 1
    for a in axes:
        if a not in mesh.axis_names:
            return None
        n *= _axis_size(mesh, a)
    if dim % n != 0:
        return None
    return axis if isinstance(axis, str) else tuple(axes)


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "name"):
            parts.append(str(p.name))   # GetAttrKey (NamedTuple fields)
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def param_spec(mesh, cfg, path: str, shape: tuple,
               opts: ShardingOptions = BASELINE) -> P:
    """PartitionSpec for one parameter identified by its tree path."""
    use_fsdp = cfg.fsdp if opts.fsdp_override is None else opts.fsdp_override
    fsdp = "data" if (use_fsdp and "data" in mesh.axis_names) else None
    stacked = bool(re.search(r"units/slot\d+", path)) and len(shape) >= 1
    lead: tuple = (None,) if stacked else ()
    body = shape[1:] if stacked else shape

    def spec(*axes):
        return P(*lead, *axes)

    name = path.rsplit("/", 1)[-1]
    layer_tp = opts.tp_mode == "full"        # TP on layer weights?
    moe_tp = opts.tp_mode in ("full", "moe-only")


    if name == "embed" or path.endswith("embed"):
        return P(_shard_if(mesh, shape[0], "model"),
                 _shard_if(mesh, shape[1], fsdp) if fsdp else None)
    if name == "head":
        return P(_shard_if(mesh, shape[0], fsdp) if fsdp else None,
                 _shard_if(mesh, shape[1], "model"))

    # Without layer TP, ZeRO-3 *storage* for layer weights can use BOTH axes
    # (256-way; vocab tensors above keep "model" for their vocab dim):
    if fsdp and not layer_tp:
        fsdp = ("data", "model")

    if len(body) == 0:
        return spec()
    # MoE expert tensors: (E, D, F) / (E, F, D) -- E on the expert axis
    if name in ("wi", "wg") and len(body) == 3:
        if opts.expert_mesh == "data":   # EP over data, F over model: resident
            return spec(_shard_if(mesh, body[0], "data"), None,
                        _shard_if(mesh, body[2], "model"))
        e_ax = _shard_if(mesh, body[0], "model") if moe_tp else None
        if opts.expert_shard_dff:   # keep weights resident, shard F over data
            return spec(e_ax, None, _shard_if(mesh, body[2], "data"))
        return spec(e_ax,
                    _shard_if(mesh, body[1], fsdp) if fsdp else None, None)
    if name == "wo" and len(body) == 3:
        if opts.expert_mesh == "data":
            return spec(_shard_if(mesh, body[0], "data"),
                        _shard_if(mesh, body[1], "model"), None)
        e_ax = _shard_if(mesh, body[0], "model") if moe_tp else None
        if opts.expert_shard_dff:
            return spec(e_ax, _shard_if(mesh, body[1], "data"), None)
        return spec(e_ax, None,
                    _shard_if(mesh, body[2], fsdp) if fsdp else None)
    if name == "router":
        return spec(_shard_if(mesh, body[0], fsdp) if fsdp else None, None)

    # attention / dense FFN 2D weights.  Attention projections shard on
    # "model" only when the HEAD COUNT divides the axis: a flat-dim check
    # alone would split a head across shards (e.g. 2 kv heads x hd=16 on a
    # 4-way axis), breaking the head-granular TP contract in the module
    # docstring -- per-head ops (RoPE, qk-norm, GQA grouping) then straddle
    # shard boundaries and reshard through every reshape.
    if name in ("wq", "wk", "wv", "w1", "w3", "w_x", "w_gate", "in_proj"):
        tp_ax = "model" if layer_tp else None
        if name in ("wq", "wk", "wv"):
            heads = cfg.n_heads if name == "wq" else (cfg.n_kv or cfg.n_heads)
            if heads % _axis_size(mesh, "model"):
                tp_ax = None
        return spec(_shard_if(mesh, body[0], fsdp) if fsdp else None,
                    _shard_if(mesh, body[1], tp_ax))
    if name in ("wo", "w2", "w_out", "out_proj"):
        tp_ax = "model" if layer_tp else None
        if name == "wo" and cfg.n_heads % _axis_size(mesh, "model"):
            tp_ax = None                     # head-granular TP (see above)
        return spec(_shard_if(mesh, body[0], tp_ax),
                    _shard_if(mesh, body[1], fsdp) if fsdp else None)
    if name in ("w_r", "w_i"):   # RG-LRU channel-coupling gates
        return spec(None, _shard_if(mesh, body[1], "model") if layer_tp else None)
    if name in ("bq", "bk", "bv"):
        heads = cfg.n_heads if name == "bq" else (cfg.n_kv or cfg.n_heads)
        b_ax = ("model" if layer_tp
                and heads % _axis_size(mesh, "model") == 0 else None)
        return spec(_shard_if(mesh, body[0], b_ax))
    if name == "conv":
        return spec(None, _shard_if(mesh, body[1], "model") if layer_tp else None)
    if name in ("lam", "a_log", "dt_bias", "d_skip"):
        return spec(_shard_if(mesh, body[0], "model") if layer_tp else None)
    # norms / scalars / anything else: replicated (beyond the stack axis)
    return spec(*([None] * len(body)))


def params_shardings(mesh, cfg, params_shape: Any,
                     opts: ShardingOptions = BASELINE):
    """Map a params (or optimizer-moment) shape-pytree to NamedShardings.

    Axis rules are membership-checked (``_shard_if``), so the same policy
    serves every mesh family: the production ``("data", "model")`` /
    ``("pod", "data", "model")`` meshes AND the scenario-grid
    ``("cells", "model")`` mesh -- on the latter, weights replicate across
    the cells axis (each cell group holds a full replica) while their
    head/FFN/vocab dims split over the per-cell model axis.
    """
    def fn(path, leaf):
        spec = param_spec(mesh, cfg, _path_str(path), leaf.shape, opts)
        return NamedSharding(mesh, spec)
    return jax.tree_util.tree_map_with_path(fn, params_shape)


def place_params(mesh, cfg, params, opts: ShardingOptions = BASELINE):
    """``device_put`` a live params pytree with its policy shardings.

    The serving stack's entry into tensor parallelism: placing the weights
    once is enough for GSPMD to propagate the model axis through jitted
    prefill/decode (activation constraints via ``repro.shardctx`` refine
    the layout but are not required for correctness).
    """
    return jax.tree.map(jax.device_put, params,
                        params_shardings(mesh, cfg, params, opts))


def shard_ctx(mesh, fn):
    """Wrap a jitted entry point so every call runs under ``mesh`` and its
    activation-sharding context (``repro.shardctx``) -- the constraints
    bake in at trace time, i.e. the first call per input shape.

    ``mesh=None`` returns ``fn`` unchanged, so callers can thread an
    optional mesh without branching.  Shared by the serving stack
    (ServingEngine, PartitionedLM).
    """
    if mesh is None:
        return fn
    from ..shardctx import activation_sharding

    def wrapped(*args):
        with mesh, activation_sharding(mesh):
            return fn(*args)
    return wrapped


def batch_spec(mesh, leaf, *, shard_batch=True) -> P:
    """PartitionSpec for one token/embedding input leaf: batch over all DP
    axes when divisible, replicated otherwise.  Pure policy (no
    NamedSharding built), so ``analysis.shardcheck`` can walk it over a
    shape-only mesh."""
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    # 0-d leaves (e.g. step counters riding along in an input tree) have
    # no batch dim to shard: replicate instead of indexing shape[0].
    if (not shard_batch or leaf.ndim == 0
            or leaf.shape[0] % _mesh_prod(mesh, dp) != 0):
        return P()
    return P(dp, *([None] * (len(leaf.shape) - 1)))


def batch_shardings(mesh, cfg, batch_shape: Any, *, shard_batch=True):
    """Token/embedding inputs: batch over all DP axes (when divisible)."""
    return jax.tree.map(
        lambda l: NamedSharding(mesh, batch_spec(mesh, l,
                                                 shard_batch=shard_batch)),
        batch_shape)


def _mesh_prod(mesh, axes) -> int:
    n = 1
    for a in axes:
        n *= _axis_size(mesh, a)
    return n


def cache_shardings(mesh, cfg, cache_shape: Any, batch: int):
    """Serving-cache shardings.

    KV tensors are (units, B, S, KV, hd) (stacked) or (B, S, KV, hd) (tail).
    batch shards over DP when divisible; otherwise (long_500k, B=1) the
    SEQUENCE axis shards over "data".  kv-heads shard over "model" when
    divisible; for kv-head counts < model size the sequence axis takes
    "model" instead (the 1.37TB qwen110 decode cache needs 256-way sharding).
    """
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(
            mesh, cache_spec(mesh, path, leaf, batch)),
        cache_shape)


def cache_spec(mesh, path, leaf, batch: int) -> P:
    """PartitionSpec for one serving-cache leaf (policy of
    :func:`cache_shardings`, exported for ``analysis.shardcheck``)."""
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp_n = _mesh_prod(mesh, dp)
    shape = leaf.shape
    p = _path_str(path)
    # Exact leaf-name match: the KV tensors live under leaves literally
    # named "k"/"v".  A substring/suffix match is a trap -- "conv" ends
    # with "v", and a suffix match hands the (units, B, ksize, d) conv
    # cache the (B, S, KV, hd) KV layout, sharding its BATCH dim over
    # "model" (caught by analysis.shardcheck).
    if leaf.ndim >= 4 and p.rsplit("/", 1)[-1] in ("k", "v"):
        stacked = leaf.ndim == 5
        lead = (None,) if stacked else ()
        b, s, kv, hd = shape[-4:]
        batch_ax = dp if b % dp_n == 0 else None
        seq_ax = None
        kv_ax = _shard_if(mesh, kv, "model")
        if kv_ax is None:
            seq_ax = _shard_if(mesh, s, "model")
        if batch_ax is None and seq_ax is None:
            seq_ax = _shard_if(mesh, s, "data")
        elif batch_ax is None:
            # combine: seq carries model; nothing else shardable
            pass
        return P(*lead, batch_ax, seq_ax, kv_ax, None)
    # Recurrent states / ring positions / conv tails: shard the batch dim
    # only where the cache layout puts it -- leading for tail leaves
    # (B, ...), second for stacked leaves (units, B, ...).  Matching B at
    # arbitrary positions would shard dims that merely coincide with the
    # batch size (e.g. a (heads, d, B)-shaped tensor's last dim).
    if dp and batch % dp_n == 0 and leaf.ndim >= 1:
        if shape[0] == batch:
            return P(dp, *[None] * (leaf.ndim - 1))
        if leaf.ndim >= 2 and shape[1] == batch:
            return P(None, dp, *[None] * (leaf.ndim - 2))
    return P()


def replicated(mesh, tree: Any):
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)


def validate_spec(mesh, shape: tuple, spec: P) -> list[str]:
    """Static invariants for one leaf's PartitionSpec; returns error strings.

    A spec is valid iff every entry names axes that exist on the mesh, no
    mesh axis is consumed by more than one dimension, the spec is no longer
    than the leaf's rank, and every sharded dimension divides the product
    of its axis sizes (the exact-sharding discipline: GSPMD would silently
    pad a non-dividing dim, breaking the memory model and -- for kv heads
    -- numerics).  Works on any object exposing ``axis_names``/``shape``
    (a real Mesh or ``analysis.contracts.ShapeOnlyMesh``), so
    ``analysis.shardcheck`` runs it with no devices at all.
    """
    errs: list[str] = []
    entries = tuple(spec)
    if len(entries) > len(shape):
        return [f"spec {spec} has {len(entries)} entries for a "
                f"rank-{len(shape)} leaf"]
    used: dict[str, int] = {}
    for dim, axes in enumerate(entries):
        if axes is None:
            continue
        names = (axes,) if isinstance(axes, str) else tuple(axes)
        total = 1
        for a in names:
            if a not in mesh.axis_names:
                errs.append(f"dim {dim}: unknown mesh axis {a!r}")
                continue
            if a in used:
                errs.append(f"mesh axis {a!r} consumed twice "
                            f"(dims {used[a]} and {dim})")
            else:
                used[a] = dim
            total *= mesh.shape[a]
        if total > 1 and shape[dim] % total:
            errs.append(f"dim {dim} of shape {tuple(shape)} not divisible "
                        f"by {names} (={total})")
    return errs
