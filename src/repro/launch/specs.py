"""Assigned input-shape table + ShapeDtypeStruct builders (deliverables e/f).

``input_specs(cfg, shape_name)`` returns weak-type-correct, shardable,
allocation-free stand-ins for every model input of that cell, exactly like
the dry-run requires.  Decode cells derive their cache specs via
``jax.eval_shape`` over the prefill path (no allocation).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..models import transformer
from ..models.common import dtype_of


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # "train" | "prefill" | "decode"
    seq: int
    batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}

# decode cells write at pos=seq; cache holds seq+margin.  128 keeps the
# padded cache length divisible by the 16-way mesh axes (32768+128 = 32896
# = 16*2056) so sequence-sharded caches stay exact.
DECODE_MARGIN = 128


def cell_supported(cfg: ArchConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """DESIGN §4 skip rules.  Returns (supported, reason_if_not)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("pure full attention; 512k-KV decode needs "
                       "sub-quadratic structure (DESIGN §4)")
    return True, ""


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs(cfg: ArchConfig, shape: ShapeSpec, *, train: bool) -> dict:
    """Token/embedding ShapeDtypeStructs for train or prefill."""
    cdt = dtype_of(cfg.compute_dtype)
    seq = shape.seq
    dec_seq = seq // 4 if cfg.enc_layers else seq
    out = {"tokens": sds((shape.batch, dec_seq), jnp.int32)}
    if train:
        out["targets"] = sds((shape.batch, dec_seq), jnp.int32)
    if cfg.frontend == "vision":
        out["image_embeds"] = sds(
            (shape.batch, cfg.n_frontend_tokens, cfg.d_model), cdt)
    if cfg.enc_layers:
        out["src_embeds"] = sds((shape.batch, seq, cfg.d_model), cdt)
    return out


def params_specs(cfg: ArchConfig) -> dict:
    """Abstract parameter tree (no allocation) via eval_shape over init."""
    return jax.eval_shape(
        functools.partial(transformer.init_params, cfg=cfg),
        jax.random.PRNGKey(0))


def cache_specs(cfg: ArchConfig, shape: ShapeSpec):
    """Abstract serving cache for a ``seq``-length context (no allocation)."""
    params = params_specs(cfg)
    prompt = batch_specs(cfg, shape, train=False)
    s_max = (shape.seq // 4 if cfg.enc_layers else shape.seq) + DECODE_MARGIN

    def run(p, b):
        return transformer.prefill(p, cfg, b, s_max=s_max)

    _, cache = jax.eval_shape(run, params, prompt)
    return cache


def input_specs(cfg: ArchConfig, shape_name: str) -> dict:
    """Everything the lowered step consumes, as ShapeDtypeStructs."""
    shape = SHAPES[shape_name]
    params = params_specs(cfg)
    if shape.kind == "train":
        from ..models.steps import make_train_step
        opt_init, _ = make_train_step(cfg)
        opt = jax.eval_shape(opt_init, params)
        return {"params": params, "opt_state": opt,
                "batch": batch_specs(cfg, shape, train=True)}
    if shape.kind == "prefill":
        return {"params": params,
                "batch": batch_specs(cfg, shape, train=False)}
    # decode
    return {"params": params,
            "cache": cache_specs(cfg, shape),
            "tokens": sds((shape.batch,), jnp.int32)}
