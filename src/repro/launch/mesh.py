"""Production mesh construction (MULTI-POD DRY-RUN spec).

A FUNCTION, not a module-level constant: importing this module never touches
jax device state (device count locks on first jax init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod; multi-pod adds a leading 2-pod axis (512)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Degenerate 1x1 mesh for CPU smoke runs of the same launch code."""
    return jax.make_mesh((1, 1), ("data", "model"))


def data_axes(mesh) -> tuple:
    """Mesh axes that carry the batch (DP): ("pod","data") when present."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def make_cells_mesh(n_devices: int | None = None, *, model: int = 1):
    """1-D ``("cells",)`` mesh for sharding a ScenarioGrid's stacked cell
    axis (see repro.core.gridshard).

    ``n_devices=None`` uses every live device (on CPU, force several with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` *before* jax
    initializes).  ``model > 1`` reserves a trailing "model" axis --
    ``("cells", "model")`` -- so a future per-cell tensor-parallel dimension
    can slot in without relayout; cells then get ``n_devices // model``
    shards.
    """
    n = len(jax.devices()) if n_devices is None else int(n_devices)
    if n < 1:
        raise ValueError("need at least one device")
    if model > 1:
        if n % model:
            raise ValueError(f"{n} devices not divisible by model={model}")
        return jax.make_mesh((n // model, model), ("cells", "model"))
    return jax.make_mesh((n,), ("cells",))


def elastic_mesh(target_model: int = 16):
    """Elastic variant: builds the largest (data, model) mesh the *live*
    device set supports -- used by the runtime's restart-after-failure path
    (runtime/elastic.py).  model axis shrinks only if devices < target."""
    n = len(jax.devices())
    model = min(target_model, n)
    while n % model:
        model -= 1
    return jax.make_mesh((n // model, model), ("data", "model"))
