"""Production mesh construction (MULTI-POD DRY-RUN spec).

A FUNCTION, not a module-level constant: importing this module never touches
jax device state (device count locks on first jax init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod; multi-pod adds a leading 2-pod axis (512)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Degenerate 1x1 mesh for CPU smoke runs of the same launch code."""
    return jax.make_mesh((1, 1), ("data", "model"))


def data_axes(mesh) -> tuple:
    """Mesh axes that carry the batch (DP): ("pod","data") when present."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def make_cells_mesh(n_devices: int | None = None, *, model: int = 1):
    """Mesh for sharding a ScenarioGrid's stacked cell axis (see
    repro.core.gridshard): 1-D ``("cells",)``, or 2-D ``("cells", "model")``
    when ``model > 1`` -- the trailing axis carries per-cell tensor
    parallelism (grid tables shard their post-cell dim, served LM weights
    shard their head/FFN dims via ``launch.sharding.param_spec``).

    ``n_devices=None`` uses every live device (on CPU, force several with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` *before* jax
    initializes).  With ``model > 1`` cells get ``n_devices // model``
    shards.

    Every layout precondition is validated HERE, with an actionable message,
    so callers (benchmarks, tests, grids) never surface an opaque XLA
    device-assignment error.
    """
    avail = len(jax.devices())
    n = avail if n_devices is None else int(n_devices)
    model = int(model)
    if n < 1:
        raise ValueError(f"need at least one device, got n_devices={n}")
    if n > avail:
        raise ValueError(
            f"requested a {n}-device cells mesh but only {avail} device(s) "
            f"are live; on CPU force more with XLA_FLAGS="
            f"--xla_force_host_platform_device_count={n} before jax "
            "initializes (anything that touches jax arrays locks the count)")
    if model < 1:
        raise ValueError(f"model axis size must be >= 1, got model={model}")
    if n % model:
        raise ValueError(
            f"model={model} does not divide the {n}-device mesh; pick a "
            f"model-axis size from the divisors of {n} "
            f"(e.g. {[d for d in (1, 2, 4, 8) if n % d == 0]})")
    if model > 1:
        return jax.make_mesh((n // model, model), ("cells", "model"))
    return jax.make_mesh((n,), ("cells",))


def elastic_mesh(target_model: int = 16):
    """Elastic variant: builds the largest (data, model) mesh the *live*
    device set supports -- used by the runtime's restart-after-failure path
    (runtime/elastic.py).  model axis shrinks only if devices < target."""
    n = len(jax.devices())
    model = min(target_model, n)
    while n % model:
        model -= 1
    return jax.make_mesh((n // model, model), ("data", "model"))
