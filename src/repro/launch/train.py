"""LM training launcher.

On real hardware this runs under the production mesh with the recommended
sharding policy; on CPU (this container) pass ``--smoke`` to train the
reduced config of the same family end-to-end with checkpointing, straggler
monitoring, and restart-from-latest — the full driver path.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --smoke \
      --steps 50 --ckpt-dir /tmp/run1
"""
from __future__ import annotations

import argparse
import time

import jax

from ..configs.base import get_config, reduced
from ..data.pipeline import for_arch
from ..models import transformer
from ..models.steps import default_microbatches, make_train_step
from ..runtime.checkpoint import CheckpointManager
from ..runtime.resilience import StragglerMonitor
from .mesh import make_host_mesh, make_production_mesh
from . import sharding


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config on the host mesh (CPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduced(cfg)
        mesh = make_host_mesh()
    else:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
    opts = sharding.recommended_options(cfg, "train")

    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    stream = for_arch(cfg, batch=args.batch, seq=args.seq)
    mb = opts.microbatches or default_microbatches(cfg, args.batch)
    mb = min(mb, args.batch)
    opt_init, train_step = make_train_step(cfg, lr=args.lr, microbatches=mb)
    opt = opt_init(params)
    print(f"[train] {cfg.name}: {transformer.param_count(params)/1e6:.2f}M "
          f"params, mesh {dict(mesh.shape)}, microbatches {mb}")

    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    start = 0
    if mgr and mgr.latest_step() is not None:
        (params, opt), manifest = mgr.restore((params, opt))
        start = manifest["step"]
        print(f"[restore] resuming at step {start}")

    from ..shardctx import activation_sharding
    mon = StragglerMonitor()
    with mesh, activation_sharding(mesh):
        step_fn = jax.jit(train_step)
        t0 = time.time()
        for step in range(start, args.steps):
            mon.start_step(step)
            params, opt, metrics = step_fn(params, opt,
                                           stream.get_batch(step))
            slow = mon.end_step()
            if step % 10 == 0 or step == args.steps - 1:
                # logging-cadence sync (every 10th step), not per-step
                print(f"step {step:5d} loss {float(metrics['loss']):.4f}"  # reprolint: ignore[host-sync]
                      f" ({time.time()-t0:.1f}s)"
                      + ("  [straggler]" if slow else ""), flush=True)
            if mgr and (step + 1) % args.ckpt_every == 0:
                mgr.save(step + 1, (params, opt), extra={"data_step": step + 1})
        if mgr:
            mgr.wait()
    if mon.events:
        print(f"[stragglers] {len(mon.events)} slow steps flagged")


if __name__ == "__main__":
    main()
