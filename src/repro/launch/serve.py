"""Serving launcher: the ES-side engine under the LyMDO controller.

``--smoke`` serves the reduced config on CPU with synthetic requests and
prints per-request latency; on hardware the same code path runs the full
config under the production mesh.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --smoke \
      --requests 6
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..configs.base import get_config, reduced
from ..models import transformer
from ..serving.engine import Request, ServingEngine
from .mesh import make_host_mesh, make_production_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--sync-batching", action="store_true",
                    help="use the synchronized-batch compat engine instead "
                         "of continuous batching (A/B baseline)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduced(cfg)
        mesh = make_host_mesh()
    else:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
    if cfg.enc_layers:
        raise SystemExit("enc-dec serving needs src embeddings; use "
                         "examples/serve_partitioned.py patterns")

    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    print(f"[serve] {cfg.name}: {transformer.param_count(params)/1e6:.2f}M "
          f"params, {args.slots} slots")

    from ..shardctx import activation_sharding
    with mesh, activation_sharding(mesh):
        eng = ServingEngine(cfg, params, slots=args.slots,
                            s_max=args.prompt_len + args.max_new + 8,
                            sync_batching=args.sync_batching)
        rng = np.random.default_rng(0)
        t_submit = {}
        reqs = []
        for rid in range(args.requests):
            r = Request(rid=rid,
                        prompt=rng.integers(0, cfg.vocab,
                                            args.prompt_len).astype(np.int32),
                        max_new=args.max_new)
            reqs.append(r)
            eng.submit(r)
            t_submit[rid] = time.time()
        steps = 0
        t_done = {}
        while eng.step():
            steps += 1
            for r in reqs:
                if r.done and r.rid not in t_done:
                    t_done[r.rid] = time.time()
        for r in reqs:
            lat = (t_done.get(r.rid, time.time()) - t_submit[r.rid]) * 1e3
            print(f"  req {r.rid}: {len(r.out)} tokens, {lat:7.1f} ms, "
                  f"out[:4]={r.out[:4]}")
        mode = "sync" if args.sync_batching else "continuous"
        print(f"[serve] {len(reqs)} requests in {steps} engine steps "
              f"({mode}: {eng.decode_steps} decode dispatches, "
              f"{eng.prefill_compiles} prefill compiles, "
              f"{eng.preemptions} preemptions)")


if __name__ == "__main__":
    main()
