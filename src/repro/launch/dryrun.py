import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run (deliverable e).

Lowers + compiles every (architecture x input-shape) cell against the
production meshes -- (16,16) single-pod and (2,16,16) multi-pod -- and
records memory analysis, cost analysis, and the HLO collective schedule for
the roofline (deliverable g).

The two lines above MUST precede any jax import: jax locks the device count
on first init, and only the dry-run sees 512 placeholder host devices.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --out benchmarks/out/dryrun
"""
import argparse
import json
import re
import time
import traceback

import jax
import numpy as np

from ..configs.base import get_config, load_all
from ..models.steps import make_train_step
from ..models import transformer
from . import sharding, specs
from .mesh import make_production_mesh

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}


# ---------------------------------------------------------------------------
# HLO collective-bytes accounting
# ---------------------------------------------------------------------------

def _shape_bytes(sig: str) -> int:
    """Bytes of one HLO shape literal like 'bf16[16,4096,128]{2,1,0}'."""
    m = re.match(r"([a-z0-9]+)\[([\d,]*)\]", sig)
    if not m:
        return 0
    dt, dims = m.groups()
    if dt not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dt]


def _result_bytes(line: str) -> int:
    """Sum the result-shape bytes of one HLO op line (handles tuples)."""
    m = re.search(r"=\s+((?:\([^)]*\))|(?:[a-z0-9]+\[[^\]]*\]\S*))\s+\S", line)
    if not m:
        return 0
    sig = m.group(1)
    return sum(_shape_bytes(s) for s in
               re.findall(r"[a-z0-9]+\[[\d,]*\]", sig))


def _parse_computations(hlo_text: str):
    """Split HLO text into computation blocks.  Returns
    {comp_name: [op lines]} plus the entry computation name."""
    comps: dict[str, list[str]] = {}
    entry = None
    current = None
    for line in hlo_text.splitlines():
        s = line.strip()
        m = re.match(r"(ENTRY\s+)?%([\w.\-]+)\s*\(", s)
        if m and s.endswith("{") and "->" in s:
            current = m.group(2)
            comps[current] = []
            if m.group(1):
                entry = current
            continue
        if current is not None:
            comps[current].append(s)
    return comps, entry


def collective_bytes(hlo_text: str, loop_trips: list) -> dict:
    """Per-kind collective bytes with loop-nesting-aware trip counts.

    ``loop_trips[d]`` is the trip count assigned to while-loop bodies at
    nesting depth d (0 = loops in ENTRY).  For the programs here the loop
    structure is known statically: train = [microbatches, n_units, ...],
    serve = [n_units, ...]; deeper loops (blocked-attention scans) carry no
    collectives and default to 1.
    """
    comps, entry = _parse_computations(hlo_text)
    # map: body computation -> (parent computation) via while ops
    while_bodies: dict[str, str] = {}
    called: dict[str, set] = {c: set() for c in comps}
    for cname, lines in comps.items():
        for s in lines:
            for attr in ("body", "to_apply", "true_computation",
                         "false_computation", "branch_computations",
                         "called_computations", "calls"):
                for m in re.finditer(attr + r"=\{?%?([\w.\-]+)", s):
                    tgt = m.group(1)
                    if tgt in comps:
                        if attr == "body":
                            while_bodies[tgt] = cname
                        else:
                            called[cname].add(tgt)

    # effective multiplier per computation (BFS from entry)
    mult: dict[str, float] = {}

    def assign(c, m, depth):
        if c in mult and mult[c] >= m:
            return
        mult[c] = m
        for tgt in called.get(c, ()):   # same-depth calls (fusions, conds)
            assign(tgt, m, depth)
        for body, parent in while_bodies.items():
            if parent == c:
                trip = loop_trips[depth] if depth < len(loop_trips) else 1
                assign(body, m * trip, depth + 1)

    if entry:
        assign(entry, 1.0, 0)

    per_kind = {k: 0.0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    f32_bytes = 0.0
    for cname, lines in comps.items():
        m = mult.get(cname, 1.0)
        for s in lines:
            for kind in _COLLECTIVES:
                if re.search(rf"\b{kind}(-start)?\(", s):
                    if f"{kind}-done" in s:
                        continue
                    b = _result_bytes(s)
                    per_kind[kind] += b * m
                    counts[kind] += int(m)
                    if re.search(r"=\s+\(?f32\[", s):
                        f32_bytes += b * m
                    break
    total = float(sum(per_kind.values()))
    # XLA:CPU upcasts bf16 compute to f32, dragging collectives to f32 with
    # it; TPU lowering keeps bf16 on the wire.  The corrected figure halves
    # f32 collective bytes (approximation: genuine f32 reductions -- logits,
    # fp32 grads -- are a small minority in these bf16 models).
    return {"bytes_by_kind": per_kind,
            "ops_by_kind": counts,
            "total_bytes": total,
            "f32_bytes": float(f32_bytes),
            "bf16_wire_corrected_bytes": float(total - 0.5 * f32_bytes)}


# ---------------------------------------------------------------------------
# cell lowering
# ---------------------------------------------------------------------------

def build_step(cfg, shape: specs.ShapeSpec,
               opts: sharding.ShardingOptions = sharding.BASELINE):
    """Returns (fn, arg_specs_tuple, donate) for the cell's step program."""
    cell = specs.input_specs(cfg, shape.name)
    if shape.kind == "train":
        from ..models.steps import default_microbatches
        mb = opts.microbatches or default_microbatches(cfg, shape.batch)
        _, train_step = make_train_step(cfg, microbatches=mb)
        return (train_step,
                (cell["params"], cell["opt_state"], cell["batch"]), (0, 1))
    if shape.kind == "prefill":
        s_max = ((shape.seq // 4 if cfg.enc_layers else shape.seq)
                 + specs.DECODE_MARGIN)

        def prefill_step(params, batch):
            return transformer.prefill(params, cfg, batch, s_max=s_max)
        return prefill_step, (cell["params"], cell["batch"]), ()

    def serve_step(params, cache, tokens):
        return transformer.decode_step(params, cfg, cache, tokens)
    return serve_step, (cell["params"], cell["cache"], cell["tokens"]), (1,)


def arg_shardings(mesh, cfg, shape: specs.ShapeSpec, args,
                  opts: sharding.ShardingOptions = sharding.BASELINE):
    if shape.kind == "train":
        params_sh = sharding.params_shardings(mesh, cfg, args[0], opts)
        opt_sh = _opt_shardings(mesh, cfg, args[1], opts)
        batch_sh = sharding.batch_shardings(mesh, cfg, args[2])
        return (params_sh, opt_sh, batch_sh)
    if shape.kind == "prefill":
        return (sharding.params_shardings(mesh, cfg, args[0], opts),
                sharding.batch_shardings(mesh, cfg, args[1]))
    return (sharding.params_shardings(mesh, cfg, args[0], opts),
            sharding.cache_shardings(mesh, cfg, args[1], batch=shape.batch),
            sharding.replicated(mesh, args[2]))


def _opt_shardings(mesh, cfg, opt_spec,
                   opts: sharding.ShardingOptions = sharding.BASELINE):
    from jax.sharding import NamedSharding, PartitionSpec as P
    mu = sharding.params_shardings(mesh, cfg, opt_spec.mu, opts)
    nu = sharding.params_shardings(mesh, cfg, opt_spec.nu, opts)
    return type(opt_spec)(step=NamedSharding(mesh, P()), mu=mu, nu=nu)


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             opts: sharding.ShardingOptions = sharding.BASELINE) -> dict:
    cfg = get_config(arch)
    shape = specs.SHAPES[shape_name]
    ok, reason = specs.cell_supported(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "status": "skipped", "reason": reason}

    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    fn, args, donate = build_step(cfg, shape, opts)
    shardings_in = arg_shardings(mesh, cfg, shape, args, opts)

    out_shardings = None
    if shape.kind == "prefill":
        # the cache leaves prefill in the decode pipeline's layout
        # (seq over "model" for small kv-head counts) instead of occupying
        # ~11 GB/device batch-sharded.
        from jax.sharding import NamedSharding, PartitionSpec as P
        _, cache_spec = jax.eval_shape(fn, *args)
        cache_sh = sharding.cache_shardings(mesh, cfg, cache_spec,
                                            batch=shape.batch)
        out_shardings = (NamedSharding(mesh, P()), cache_sh)

    from ..shardctx import activation_sharding
    moe_dp = not (opts.expert_shard_dff or opts.expert_mesh == "data")
    with mesh, activation_sharding(mesh, seq_shard=opts.seq_shard,
                                   moe_dp_groups=moe_dp,
                                   remat_offload=opts.remat_offload,
                                   expert_axis=opts.expert_mesh):
        jitted = jax.jit(fn, in_shardings=shardings_in,
                         donate_argnums=donate,
                         out_shardings=out_shardings)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    try:
        mem = compiled.memory_analysis()
        mem_info = {
            "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
            "alias_bytes": int(getattr(mem, "alias_size_in_bytes", 0)),
        } if mem is not None else None
    except Exception as e:  # CPU backend may not implement it
        mem_info = {"error": str(e)}
    try:
        cost = compiled.cost_analysis()
        cost_info = {k: float(v) for k, v in cost.items()
                     if np.isscalar(v) and k in
                     ("flops", "bytes accessed", "transcendentals",
                      "utilization operand 0 {}", "bytes accessed output {}")}
        flops = float(cost.get("flops", 0.0))
        bytes_accessed = float(cost.get("bytes accessed", 0.0))
    except Exception as e:
        cost_info, flops, bytes_accessed = {"error": str(e)}, 0.0, 0.0

    if shape.kind == "train":
        from ..models.steps import default_microbatches
        mb = opts.microbatches or default_microbatches(cfg, shape.batch)
        loop_trips = [mb, cfg.n_units, 1] if mb > 1 else [cfg.n_units, 1]
    else:
        loop_trips = [cfg.n_units, 1]
    coll = collective_bytes(compiled.as_text(), loop_trips=loop_trips)

    return {
        "arch": arch, "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "devices": int(np.prod(mesh.devices.shape)),
        "status": "ok",
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": mem_info,
        "flops": flops,
        "bytes_accessed": bytes_accessed,
        "cost_raw": cost_info,
        "collectives": coll,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default=None,
                    choices=list(specs.SHAPES) + [None])
    ap.add_argument("--mesh", type=str, default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", type=str, default="benchmarks/out/dryrun")
    # §Perf hillclimb knobs
    ap.add_argument("--tp-mode", default="full",
                    choices=["full", "vocab-only", "moe-only"])
    ap.add_argument("--expert-dff", action="store_true")
    ap.add_argument("--seq-shard", action="store_true")
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--fsdp", type=int, default=None, choices=[0, 1],
                    help="force ZeRO-3 on/off (default: per-arch cfg)")
    ap.add_argument("--offload", action="store_true",
                    help="host-offload remat carry stacks")
    ap.add_argument("--expert-mesh", default="model", choices=["model", "data"])
    ap.add_argument("--recommended", action="store_true",
                    help="per-arch beyond-paper defaults (sharding.recommended_options)")
    ap.add_argument("--tag", default="", help="suffix for output filenames")
    args = ap.parse_args()
    opts = sharding.ShardingOptions(
        tp_mode=args.tp_mode, expert_shard_dff=args.expert_dff,
        seq_shard=args.seq_shard, microbatches=args.microbatches,
        fsdp_override=None if args.fsdp is None else bool(args.fsdp),
        remat_offload=args.offload, expert_mesh=args.expert_mesh)

    archs = sorted(load_all()) if (args.all or args.arch is None) else [args.arch]
    shapes = list(specs.SHAPES) if args.shape is None else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    os.makedirs(args.out, exist_ok=True)
    for arch in archs:
        for shape_name in shapes:
            for multi in meshes:
                tag = f"{arch}__{shape_name}__{'multi' if multi else 'single'}"
                if args.tag:
                    tag += f"__{args.tag}"
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path):
                    print(f"[skip-existing] {tag}", flush=True)
                    continue
                print(f"[run] {tag}", flush=True)
                try:
                    cell_opts = opts
                    if args.recommended:
                        cell_opts = sharding.recommended_options(
                            get_config(arch), specs.SHAPES[shape_name].kind)
                    result = run_cell(arch, shape_name, multi, cell_opts)
                except Exception:
                    result = {"arch": arch, "shape": shape_name,
                              "mesh": "multi" if multi else "single",
                              "status": "error",
                              "traceback": traceback.format_exc()}
                with open(path, "w") as f:
                    json.dump(result, f, indent=1)
                status = result["status"]
                extra = ""
                if status == "ok":
                    extra = (f" flops={result['flops']:.3e}"
                             f" coll={result['collectives']['total_bytes']:.3e}B"
                             f" compile={result['compile_s']}s")
                print(f"[done] {tag}: {status}{extra}", flush=True)


if __name__ == "__main__":
    main()
