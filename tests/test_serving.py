"""Serving layer: partitioned execution correctness + engine behavior."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, reduced
from repro.models import transformer
from repro.serving.engine import Request, ServingEngine
from repro.serving.partitioned import (PartitionedLM, layer_cut_to_unit,
                                       split_params)


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(get_config("qwen3-0.6b"), n_layers=6)
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


@pytest.mark.slow
def test_partitioned_equals_monolithic_all_cuts(setup):
    """UE half + ES half == full model, at EVERY unit cut (the paper's
    correctness requirement: partitioning must not change the function)."""
    cfg, params = setup
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab)
    want, _ = transformer.forward_train(params, cfg, {"tokens": tokens})
    for cut in range(cfg.n_units + 1):
        plm = PartitionedLM(cfg, params, cut)
        got, boundary = plm.infer(tokens)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4, err_msg=f"cut={cut}")


def test_boundary_payload_semantics(setup):
    cfg, params = setup
    plm0 = PartitionedLM(cfg, params, 0)
    plm3 = PartitionedLM(cfg, params, 3)
    assert plm0.boundary_bytes(2, 12) == 2 * 12 * 4           # raw tokens
    assert plm3.boundary_bytes(2, 12) == 2 * 12 * cfg.d_model * 2


def test_layer_cut_to_unit_mapping(setup):
    cfg, _ = setup
    assert layer_cut_to_unit(cfg, 0) == 0      # full edge
    assert layer_cut_to_unit(cfg, 1) == 0      # embed only -> still edge
    assert layer_cut_to_unit(cfg, cfg.n_layers + 2) == cfg.n_units


def test_split_params_partition(setup):
    cfg, params = setup
    ue, es = split_params(params, 2)
    stacked = jax.tree.leaves(params["units"])[0].shape[0]
    assert jax.tree.leaves(ue["units"])[0].shape[0] == 2
    assert jax.tree.leaves(es["units"])[0].shape[0] == stacked - 2
    assert "final_norm" in es and "final_norm" not in ue


def test_engine_serves_all_requests(setup):
    cfg, params = setup
    eng = ServingEngine(cfg, params, slots=2, s_max=64)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, 8).astype(np.int32),
                    max_new=5) for i in range(5)]
    for r in reqs:
        eng.submit(r)
    steps = 0
    while eng.step():
        steps += 1
        assert steps < 200
    assert all(r.done for r in reqs)
    assert all(len(r.out) == 5 for r in reqs)


def test_run_until_idle_returns_completed(setup):
    """Regression: run_until_idle used to return [] unconditionally.  With
    more requests than slots, every request must come back done, with its
    full output, in completion order."""
    cfg, params = setup
    eng = ServingEngine(cfg, params, slots=2, s_max=64)
    rng = np.random.default_rng(1)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab, 8).astype(np.int32),
                    max_new=3) for i in range(5)]
    for r in reqs:
        eng.submit(r)
    finished = eng.run_until_idle()
    assert [r.rid for r in finished] == [0, 1, 2, 3, 4]
    assert all(r.done and len(r.out) == 3 for r in finished)
    # a second call finds nothing new
    assert eng.run_until_idle() == []


def test_engine_batch_matches_solo_equal_lengths(setup):
    """Equal-length prompts involve no ragged padding: each request's greedy
    tokens equal a solo (slots=1) run of the same prompt."""
    cfg, params = setup
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab, 8).astype(np.int32)
               for _ in range(2)]
    eng = ServingEngine(cfg, params, slots=2, s_max=64)
    reqs = [Request(rid=i, prompt=p, max_new=4)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    finished = eng.run_until_idle()
    assert len(finished) == 2
    for p, r in zip(prompts, reqs):
        solo_eng = ServingEngine(cfg, params, slots=1, s_max=64)
        solo = Request(rid=0, prompt=p, max_new=4)
        solo_eng.submit(solo)
        solo_eng.run_until_idle()
        assert r.out == solo.out


def test_engine_mixed_lengths_match_solo(setup):
    """Mixed-length batches are EXACT: the pad counts flow into
    transformer.prefill as an attention mask + RoPE position shift, so each
    padded row's greedy tokens equal its solo run (the left-pad limitation
    the engine used to document is gone)."""
    cfg, params = setup
    rng = np.random.default_rng(9)
    prompts = [rng.integers(0, cfg.vocab, n).astype(np.int32)
               for n in (5, 9, 12)]
    eng = ServingEngine(cfg, params, slots=3, s_max=64)
    reqs = [Request(rid=i, prompt=p, max_new=4)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    finished = eng.run_until_idle()
    assert len(finished) == 3
    for p, r in zip(prompts, reqs):
        solo_eng = ServingEngine(cfg, params, slots=1, s_max=64)
        solo = Request(rid=0, prompt=p, max_new=4)
        solo_eng.submit(solo)
        solo_eng.run_until_idle()
        assert r.out == solo.out, f"prompt len {len(p)}"


def test_prefill_bucketing_avoids_recompiles(setup):
    """Steady-state serving must not churn the prefill jit cache: admitted
    batches pad to power-of-two width buckets, so every prompt-length mix
    inside one bucket shares one compiled shape."""
    cfg, params = setup
    eng = ServingEngine(cfg, params, slots=2, s_max=64)
    rng = np.random.default_rng(3)
    # 4 admission waves x mixed lengths 9..15 -> all land in the 16 bucket
    # (always ragged: lengths stay below the bucket width)
    for wave in range(4):
        for i in range(2):
            n = int(rng.integers(9, 16))
            eng.submit(Request(rid=wave * 2 + i,
                               prompt=rng.integers(0, cfg.vocab, n)
                               .astype(np.int32), max_new=2))
        eng.run_until_idle()
    assert eng.prefill_compiles == 1
    assert eng._prefill_shapes == {(2, 16, True)}
    # a longer prompt moves to the next bucket: exactly one more compile
    eng.submit(Request(rid=99, prompt=rng.integers(0, cfg.vocab, 20)
                       .astype(np.int32), max_new=2))
    eng.run_until_idle()
    assert eng.prefill_compiles == 2
    # a pad-free batch (prompts exactly bucket-width) takes the maskless
    # kernel path: same width, separate signature
    for i in range(2):
        eng.submit(Request(rid=200 + i,
                           prompt=rng.integers(0, cfg.vocab, 16)
                           .astype(np.int32), max_new=2))
    eng.run_until_idle()
    assert (2, 16, False) in eng._prefill_shapes


def test_bucket_respects_decode_budget(setup):
    """Bucket slack must never eat the KV decode budget: with s_max=24 a
    13-token prompt cannot round up to the 16 bucket when max_new=10
    (16 + 10 > 24) -- the engine falls back to the exact width and the
    request still matches its solo run; a genuinely oversized request
    raises instead of silently clamping cache writes."""
    cfg, params = setup
    rng = np.random.default_rng(11)
    prompt = rng.integers(0, cfg.vocab, 13).astype(np.int32)
    eng = ServingEngine(cfg, params, slots=1, s_max=24)
    req = Request(rid=0, prompt=prompt, max_new=10)
    eng.submit(req)
    eng.run_until_idle()
    assert len(req.out) == 10
    assert eng._prefill_shapes == {(1, 13, False)}   # exact-width fallback
    solo = ServingEngine(cfg, params, slots=1, s_max=64)
    ref = Request(rid=0, prompt=prompt, max_new=10)
    solo.submit(ref)
    solo.run_until_idle()
    assert req.out == ref.out
    # prompt + decode budget > s_max: loud failure, not silent corruption
    eng2 = ServingEngine(cfg, params, slots=1, s_max=24)
    eng2.submit(Request(rid=1, prompt=rng.integers(0, cfg.vocab, 20)
                        .astype(np.int32), max_new=10))
    with pytest.raises(ValueError, match="exceeds s_max"):
        eng2.run_until_idle()


@pytest.mark.slow
def test_engine_greedy_matches_manual_decode(setup):
    """Engine tokens == hand-rolled prefill+argmax decode for one request."""
    cfg, params = setup
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab, 8).astype(np.int32)
    eng = ServingEngine(cfg, params, slots=1, s_max=64)
    req = Request(rid=0, prompt=prompt, max_new=4)
    eng.submit(req)
    while eng.step():
        pass
    logits, cache = transformer.prefill(params, cfg,
                                        {"tokens": jnp.asarray(prompt)[None]},
                                        s_max=64)
    toks = []
    nxt = int(jnp.argmax(logits, -1)[0])
    toks.append(nxt)
    for _ in range(3):
        logits, cache = transformer.decode_step(params, cfg, cache,
                                                jnp.asarray([nxt], jnp.int32))
        nxt = int(jnp.argmax(logits, -1)[0])
        toks.append(nxt)
    assert req.out == toks
