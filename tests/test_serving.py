"""Serving layer: partitioned execution correctness + engine behavior."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, reduced
from repro.models import transformer
from repro.serving.engine import Request, ServingEngine
from repro.serving.partitioned import (PartitionedLM, layer_cut_to_unit,
                                       split_params)


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(get_config("qwen3-0.6b"), n_layers=6)
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


@pytest.mark.slow
def test_partitioned_equals_monolithic_all_cuts(setup):
    """UE half + ES half == full model, at EVERY unit cut (the paper's
    correctness requirement: partitioning must not change the function)."""
    cfg, params = setup
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab)
    want, _ = transformer.forward_train(params, cfg, {"tokens": tokens})
    for cut in range(cfg.n_units + 1):
        plm = PartitionedLM(cfg, params, cut)
        got, boundary = plm.infer(tokens)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4, err_msg=f"cut={cut}")


def test_boundary_payload_semantics(setup):
    cfg, params = setup
    plm0 = PartitionedLM(cfg, params, 0)
    plm3 = PartitionedLM(cfg, params, 3)
    assert plm0.boundary_bytes(2, 12) == 2 * 12 * 4           # raw tokens
    assert plm3.boundary_bytes(2, 12) == 2 * 12 * cfg.d_model * 2


def test_layer_cut_to_unit_mapping(setup):
    cfg, _ = setup
    assert layer_cut_to_unit(cfg, 0) == 0      # full edge
    assert layer_cut_to_unit(cfg, 1) == 0      # embed only -> still edge
    assert layer_cut_to_unit(cfg, cfg.n_layers + 2) == cfg.n_units


def test_split_params_partition(setup):
    cfg, params = setup
    ue, es = split_params(params, 2)
    stacked = jax.tree.leaves(params["units"])[0].shape[0]
    assert jax.tree.leaves(ue["units"])[0].shape[0] == 2
    assert jax.tree.leaves(es["units"])[0].shape[0] == stacked - 2
    assert "final_norm" in es and "final_norm" not in ue


def test_engine_serves_all_requests(setup):
    cfg, params = setup
    eng = ServingEngine(cfg, params, slots=2, s_max=64)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, 8).astype(np.int32),
                    max_new=5) for i in range(5)]
    for r in reqs:
        eng.submit(r)
    steps = 0
    while eng.step():
        steps += 1
        assert steps < 200
    assert all(r.done for r in reqs)
    assert all(len(r.out) == 5 for r in reqs)


def test_run_until_idle_returns_completed(setup):
    """Regression: run_until_idle used to return [] unconditionally.  With
    more requests than slots, every request must come back done, with its
    full output, in completion order."""
    cfg, params = setup
    eng = ServingEngine(cfg, params, slots=2, s_max=64)
    rng = np.random.default_rng(1)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab, 8).astype(np.int32),
                    max_new=3) for i in range(5)]
    for r in reqs:
        eng.submit(r)
    finished = eng.run_until_idle()
    assert [r.rid for r in finished] == [0, 1, 2, 3, 4]
    assert all(r.done and len(r.out) == 3 for r in finished)
    # a second call finds nothing new
    assert eng.run_until_idle() == []


def test_engine_batch_matches_solo_equal_lengths(setup):
    """Equal-length prompts need no padding, so the batched prefill path is
    exact: each request's greedy tokens equal a solo (slots=1) run of the
    same prompt.  (Mixed lengths are approximate -- see the engine module
    docstring: left-pad positions are attended and shift RoPE.)"""
    cfg, params = setup
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab, 8).astype(np.int32)
               for _ in range(2)]
    eng = ServingEngine(cfg, params, slots=2, s_max=64)
    reqs = [Request(rid=i, prompt=p, max_new=4)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    finished = eng.run_until_idle()
    assert len(finished) == 2
    for p, r in zip(prompts, reqs):
        solo_eng = ServingEngine(cfg, params, slots=1, s_max=64)
        solo = Request(rid=0, prompt=p, max_new=4)
        solo_eng.submit(solo)
        solo_eng.run_until_idle()
        assert r.out == solo.out


def test_engine_mixed_lengths_complete(setup):
    """Mixed-length batches still run to completion (the engine pads and
    serves them; only token-level exactness is out of scope)."""
    cfg, params = setup
    rng = np.random.default_rng(9)
    eng = ServingEngine(cfg, params, slots=2, s_max=64)
    reqs = [Request(rid=0, prompt=rng.integers(0, cfg.vocab, 5)
                    .astype(np.int32), max_new=3),
            Request(rid=1, prompt=rng.integers(0, cfg.vocab, 9)
                    .astype(np.int32), max_new=3)]
    for r in reqs:
        eng.submit(r)
    finished = eng.run_until_idle()
    assert len(finished) == 2
    assert all(r.done and len(r.out) == 3 for r in reqs)


@pytest.mark.slow
def test_engine_greedy_matches_manual_decode(setup):
    """Engine tokens == hand-rolled prefill+argmax decode for one request."""
    cfg, params = setup
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab, 8).astype(np.int32)
    eng = ServingEngine(cfg, params, slots=1, s_max=64)
    req = Request(rid=0, prompt=prompt, max_new=4)
    eng.submit(req)
    while eng.step():
        pass
    logits, cache = transformer.prefill(params, cfg,
                                        {"tokens": jnp.asarray(prompt)[None]},
                                        s_max=64)
    toks = []
    nxt = int(jnp.argmax(logits, -1)[0])
    toks.append(nxt)
    for _ in range(3):
        logits, cache = transformer.decode_step(params, cfg, cache,
                                                jnp.asarray([nxt], jnp.int32))
        nxt = int(jnp.argmax(logits, -1)[0])
        toks.append(nxt)
    assert req.out == toks
