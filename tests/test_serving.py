"""Serving layer: partitioned execution correctness + engine behavior."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, reduced
from repro.models import transformer
from repro.serving.engine import Request, ServingEngine
from repro.serving.partitioned import (PartitionedLM, layer_cut_to_unit,
                                       split_params)


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(get_config("qwen3-0.6b"), n_layers=6)
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


@pytest.mark.slow
def test_partitioned_equals_monolithic_all_cuts(setup):
    """UE half + ES half == full model, at EVERY unit cut (the paper's
    correctness requirement: partitioning must not change the function)."""
    cfg, params = setup
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab)
    want, _ = transformer.forward_train(params, cfg, {"tokens": tokens})
    for cut in range(cfg.n_units + 1):
        plm = PartitionedLM(cfg, params, cut)
        got, boundary = plm.infer(tokens)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4, err_msg=f"cut={cut}")


def test_boundary_payload_semantics(setup):
    cfg, params = setup
    plm0 = PartitionedLM(cfg, params, 0)
    plm3 = PartitionedLM(cfg, params, 3)
    assert plm0.boundary_bytes(2, 12) == 2 * 12 * 4           # raw tokens
    assert plm3.boundary_bytes(2, 12) == 2 * 12 * cfg.d_model * 2


def test_layer_cut_to_unit_mapping(setup):
    cfg, _ = setup
    assert layer_cut_to_unit(cfg, 0) == 0      # full edge
    assert layer_cut_to_unit(cfg, 1) == 0      # embed only -> still edge
    assert layer_cut_to_unit(cfg, cfg.n_layers + 2) == cfg.n_units


def test_split_params_partition(setup):
    cfg, params = setup
    ue, es = split_params(params, 2)
    stacked = jax.tree.leaves(params["units"])[0].shape[0]
    assert jax.tree.leaves(ue["units"])[0].shape[0] == 2
    assert jax.tree.leaves(es["units"])[0].shape[0] == stacked - 2
    assert "final_norm" in es and "final_norm" not in ue


def test_engine_serves_all_requests(setup):
    cfg, params = setup
    eng = ServingEngine(cfg, params, slots=2, s_max=64)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, 8).astype(np.int32),
                    max_new=5) for i in range(5)]
    for r in reqs:
        eng.submit(r)
    steps = 0
    while eng.step():
        steps += 1
        assert steps < 200
    assert all(r.done for r in reqs)
    assert all(len(r.out) == 5 for r in reqs)


@pytest.mark.slow
def test_engine_greedy_matches_manual_decode(setup):
    """Engine tokens == hand-rolled prefill+argmax decode for one request."""
    cfg, params = setup
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab, 8).astype(np.int32)
    eng = ServingEngine(cfg, params, slots=1, s_max=64)
    req = Request(rid=0, prompt=prompt, max_new=4)
    eng.submit(req)
    while eng.step():
        pass
    logits, cache = transformer.prefill(params, cfg,
                                        {"tokens": jnp.asarray(prompt)[None]},
                                        s_max=64)
    toks = []
    nxt = int(jnp.argmax(logits, -1)[0])
    toks.append(nxt)
    for _ in range(3):
        logits, cache = transformer.decode_step(params, cfg, cache,
                                                jnp.asarray([nxt], jnp.int32))
        nxt = int(jnp.argmax(logits, -1)[0])
        toks.append(nxt)
    assert req.out == toks
