"""Serving layer: partitioned execution correctness + engine behavior."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, reduced
from repro.models import transformer
from repro.serving.engine import Request, ServingEngine
from repro.serving.partitioned import (PartitionedLM, layer_cut_to_unit,
                                       split_params)


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(get_config("qwen3-0.6b"), n_layers=6)
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


@pytest.mark.slow
def test_partitioned_equals_monolithic_all_cuts(setup):
    """UE half + ES half == full model, at EVERY unit cut (the paper's
    correctness requirement: partitioning must not change the function)."""
    cfg, params = setup
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab)
    want, _ = transformer.forward_train(params, cfg, {"tokens": tokens})
    for cut in range(cfg.n_units + 1):
        plm = PartitionedLM(cfg, params, cut)
        got, boundary = plm.infer(tokens)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4, err_msg=f"cut={cut}")


def test_boundary_payload_semantics(setup):
    cfg, params = setup
    plm0 = PartitionedLM(cfg, params, 0)
    plm3 = PartitionedLM(cfg, params, 3)
    assert plm0.boundary_bytes(2, 12) == 2 * 12 * 4           # raw tokens
    assert plm3.boundary_bytes(2, 12) == 2 * 12 * cfg.d_model * 2


def test_layer_cut_to_unit_mapping(setup):
    cfg, _ = setup
    assert layer_cut_to_unit(cfg, 0) == 0      # full edge
    assert layer_cut_to_unit(cfg, 1) == 0      # embed only -> still edge
    assert layer_cut_to_unit(cfg, cfg.n_layers + 2) == cfg.n_units


def test_split_params_partition(setup):
    cfg, params = setup
    ue, es = split_params(params, 2)
    stacked = jax.tree.leaves(params["units"])[0].shape[0]
    assert jax.tree.leaves(ue["units"])[0].shape[0] == 2
    assert jax.tree.leaves(es["units"])[0].shape[0] == stacked - 2
    assert "final_norm" in es and "final_norm" not in ue


def test_engine_serves_all_requests(setup):
    cfg, params = setup
    eng = ServingEngine(cfg, params, slots=2, s_max=64)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, 8).astype(np.int32),
                    max_new=5) for i in range(5)]
    for r in reqs:
        eng.submit(r)
    steps = 0
    while eng.step():
        steps += 1
        assert steps < 200
    assert all(r.done for r in reqs)
    assert all(len(r.out) == 5 for r in reqs)


def test_run_until_idle_returns_completed(setup):
    """Regression: run_until_idle used to return [] unconditionally.  With
    more requests than slots, every request must come back done, with its
    full output, in completion order."""
    cfg, params = setup
    eng = ServingEngine(cfg, params, slots=2, s_max=64)
    rng = np.random.default_rng(1)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab, 8).astype(np.int32),
                    max_new=3) for i in range(5)]
    for r in reqs:
        eng.submit(r)
    finished = eng.run_until_idle()
    assert [r.rid for r in finished] == [0, 1, 2, 3, 4]
    assert all(r.done and len(r.out) == 3 for r in finished)
    # a second call finds nothing new
    assert eng.run_until_idle() == []


def test_engine_batch_matches_solo_equal_lengths(setup):
    """Equal-length prompts involve no ragged padding: each request's greedy
    tokens equal a solo (slots=1) run of the same prompt."""
    cfg, params = setup
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab, 8).astype(np.int32)
               for _ in range(2)]
    eng = ServingEngine(cfg, params, slots=2, s_max=64)
    reqs = [Request(rid=i, prompt=p, max_new=4)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    finished = eng.run_until_idle()
    assert len(finished) == 2
    for p, r in zip(prompts, reqs):
        solo_eng = ServingEngine(cfg, params, slots=1, s_max=64)
        solo = Request(rid=0, prompt=p, max_new=4)
        solo_eng.submit(solo)
        solo_eng.run_until_idle()
        assert r.out == solo.out


def test_engine_mixed_lengths_match_solo(setup):
    """Mixed-length batches are EXACT: the pad counts flow into
    transformer.prefill as an attention mask + RoPE position shift, so each
    padded row's greedy tokens equal its solo run (the left-pad limitation
    the engine used to document is gone)."""
    cfg, params = setup
    rng = np.random.default_rng(9)
    prompts = [rng.integers(0, cfg.vocab, n).astype(np.int32)
               for n in (5, 9, 12)]
    eng = ServingEngine(cfg, params, slots=3, s_max=64)
    reqs = [Request(rid=i, prompt=p, max_new=4)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    finished = eng.run_until_idle()
    assert len(finished) == 3
    for p, r in zip(prompts, reqs):
        solo_eng = ServingEngine(cfg, params, slots=1, s_max=64)
        solo = Request(rid=0, prompt=p, max_new=4)
        solo_eng.submit(solo)
        solo_eng.run_until_idle()
        assert r.out == solo.out, f"prompt len {len(p)}"


def test_prefill_bucketing_avoids_recompiles(setup):
    """Steady-state serving must not churn the prefill jit cache: prompts
    pad to power-of-two width buckets, so every prompt-length mix inside
    one bucket shares one compiled shape.  Continuous mode prefills each
    request solo, so signatures are (batch=1, bucket, ragged?)."""
    cfg, params = setup
    eng = ServingEngine(cfg, params, slots=2, s_max=64)
    rng = np.random.default_rng(3)
    # 4 waves x mixed lengths 9..15 -> all land in the 16 bucket
    # (always ragged: lengths stay below the bucket width)
    for wave in range(4):
        for i in range(2):
            n = int(rng.integers(9, 16))
            eng.submit(Request(rid=wave * 2 + i,
                               prompt=rng.integers(0, cfg.vocab, n)
                               .astype(np.int32), max_new=2))
        eng.run_until_idle()
    assert eng.prefill_compiles == 1
    assert eng._prefill_shapes == {(1, 16, True)}
    # a longer prompt moves to the next bucket: exactly one more compile
    eng.submit(Request(rid=99, prompt=rng.integers(0, cfg.vocab, 20)
                       .astype(np.int32), max_new=2))
    eng.run_until_idle()
    assert eng.prefill_compiles == 2
    # a pad-free prompt (exactly bucket-width) takes the maskless kernel
    # path: same width, separate signature
    eng.submit(Request(rid=200, prompt=rng.integers(0, cfg.vocab, 16)
                       .astype(np.int32), max_new=2))
    eng.run_until_idle()
    assert (1, 16, False) in eng._prefill_shapes


def test_prefill_bucketing_sync_mode(setup):
    """Compat mode batches the admitted wave into ONE prefill: signatures
    are (slots, bucket, ragged?) exactly as before the continuous engine."""
    cfg, params = setup
    eng = ServingEngine(cfg, params, slots=2, s_max=64, sync_batching=True)
    rng = np.random.default_rng(3)
    for wave in range(3):
        for i in range(2):
            n = int(rng.integers(9, 16))
            eng.submit(Request(rid=wave * 2 + i,
                               prompt=rng.integers(0, cfg.vocab, n)
                               .astype(np.int32), max_new=2))
        eng.run_until_idle()
    assert eng._prefill_shapes == {(2, 16, True)}


def test_bucket_respects_decode_budget(setup):
    """Bucket slack must never eat the KV decode budget: with s_max=24 a
    13-token prompt cannot round up to the 16 bucket when max_new=10
    (16 + 10 > 24) -- the engine falls back to the exact width and the
    request still matches its solo run; a genuinely oversized request
    raises instead of silently clamping cache writes."""
    cfg, params = setup
    rng = np.random.default_rng(11)
    prompt = rng.integers(0, cfg.vocab, 13).astype(np.int32)
    eng = ServingEngine(cfg, params, slots=1, s_max=24)
    req = Request(rid=0, prompt=prompt, max_new=10)
    eng.submit(req)
    eng.run_until_idle()
    assert len(req.out) == 10
    assert eng._prefill_shapes == {(1, 13, False)}   # exact-width fallback
    solo = ServingEngine(cfg, params, slots=1, s_max=64)
    ref = Request(rid=0, prompt=prompt, max_new=10)
    solo.submit(ref)
    solo.run_until_idle()
    assert req.out == ref.out
    # prompt + decode budget > s_max: loud failure AT SUBMIT, before the
    # request enters the queue (admission-time rejection leaked its blocks)
    eng2 = ServingEngine(cfg, params, slots=1, s_max=24)
    with pytest.raises(ValueError, match="exceeds s_max"):
        eng2.submit(Request(rid=1, prompt=rng.integers(0, cfg.vocab, 20)
                            .astype(np.int32), max_new=10))
    assert not eng2.queue


def _solo_tokens(cfg, params, prompt, max_new, s_max=64):
    eng = ServingEngine(cfg, params, slots=1, s_max=s_max,
                        prefill_chunk=None)   # whole-prompt reference
    req = Request(rid=0, prompt=prompt, max_new=max_new)
    eng.submit(req)
    eng.run_until_idle()
    return req.out


def test_continuous_matches_sync_and_solo(setup):
    """The two engine modes may only differ in WHEN, never WHAT: identical
    request streams produce identical per-request greedy tokens, each equal
    to its solo run."""
    cfg, params = setup
    rng = np.random.default_rng(17)
    spec = [(rng.integers(0, cfg.vocab, n).astype(np.int32), m)
            for n, m in ((5, 6), (11, 3), (8, 4), (14, 2), (6, 5))]
    outs = {}
    for sync in (False, True):
        eng = ServingEngine(cfg, params, slots=2, s_max=64,
                            sync_batching=sync)
        reqs = [Request(rid=i, prompt=p, max_new=m)
                for i, (p, m) in enumerate(spec)]
        for r in reqs:
            eng.submit(r)
        assert len(eng.run_until_idle()) == 5
        outs[sync] = [r.out for r in reqs]
    assert outs[False] == outs[True]
    for (p, m), got in zip(spec, outs[False]):
        assert got == _solo_tokens(cfg, params, p, m), f"len {len(p)}"


@pytest.mark.parametrize("sync", [False, True], ids=["continuous", "sync"])
def test_budget_exhausted_at_admission_completes_same_tick(setup, sync):
    """Regression (off-by-one completion tick): max_new<=1 requests exhaust
    their budget at admit time (the single token comes from the prefill
    logits) -- they must complete AT the admission tick, not ride a wasted
    decode step, and must trigger NO decode dispatch."""
    from repro.traffic import TrafficRecorder
    cfg, params = setup
    rec = TrafficRecorder()
    eng = ServingEngine(cfg, params, slots=2, s_max=64, recorder=rec,
                        sync_batching=sync)
    rng = np.random.default_rng(7)
    one = Request(rid=0, prompt=rng.integers(0, cfg.vocab, 6)
                  .astype(np.int32), max_new=1)
    zero = Request(rid=1, prompt=rng.integers(0, cfg.vocab, 6)
                   .astype(np.int32), max_new=0)
    eng.submit(one)
    eng.submit(zero)
    assert eng.step() in (False, True)
    assert one.done and zero.done
    assert len(one.out) == 1 and len(zero.out) == 0
    assert eng.decode_steps == 0           # nothing to decode
    # pinned timestamps: submitted at tick 0, admitted AND completed at 1
    for rid in (0, 1):
        ev = rec.events[rid]
        assert (ev.submit, ev.admit, ev.complete) == (0, 1, 1), rid
    # the single token matches the solo run's first token
    assert one.out == _solo_tokens(cfg, params, one.prompt, 4)[:1]
    # and the engine is genuinely idle afterwards
    assert not eng.step()


def test_bucket_width_fallback_and_oversize(setup):
    """Direct unit coverage of the _bucket_width branches: the "no bucket
    fits -> exact width" fallback and the oversized-prompt ValueError, plus
    _bucket_ladder when s_max < lo."""
    from repro.serving.engine import _bucket_ladder
    cfg, params = setup
    eng = ServingEngine(cfg, params, slots=1, s_max=24)
    assert eng.prefill_buckets == (8, 16, 24)
    # 13 + 10: the 16 bucket violates 16 + 10 <= 24 -> exact width
    assert eng._bucket_width(13, 10) == 13
    # 13 + 2: the 16 bucket fits
    assert eng._bucket_width(13, 2) == 16
    with pytest.raises(ValueError, match="exceeds s_max"):
        eng._bucket_width(20, 10)
    assert _bucket_ladder(4) == (4,)       # s_max below the smallest bucket
    assert _bucket_ladder(8) == (8,)
    assert _bucket_ladder(33) == (8, 16, 32, 33)


def test_submit_rejects_negative_ue(setup):
    cfg, params = setup
    eng = ServingEngine(cfg, params, slots=1, s_max=32)
    with pytest.raises(ValueError, match="ue must be >= 0"):
        eng.submit(Request(rid=0, prompt=np.zeros(4, np.int32), ue=-3))


def test_preemption_under_small_pool(setup):
    """A pool too small for all slots at once forces youngest-preemption --
    and preemption must be INVISIBLE to outputs: every request still equals
    its solo run."""
    cfg, params = setup
    rng = np.random.default_rng(23)
    prompts = [rng.integers(0, cfg.vocab, n).astype(np.int32)
               for n in (9, 10, 12)]
    # 3 slots x (prompt + 8 new tokens) needs ~9 blocks of 4; give it 6
    eng = ServingEngine(cfg, params, slots=3, s_max=32, kv_block=4,
                        kv_blocks=7)
    reqs = [Request(rid=i, prompt=p, max_new=8)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    finished = eng.run_until_idle()
    assert len(finished) == 3
    assert eng.preemptions > 0, "pool was sized to force preemption"
    for p, r in zip(prompts, reqs):
        assert r.out == _solo_tokens(cfg, params, p, 8, s_max=32), \
            f"prompt len {len(p)}"
    # all blocks returned to the free list at drain
    assert eng.allocator.n_free == eng.allocator.capacity


def test_oversized_request_rejected_by_pool(setup):
    """A request whose worst-case KV footprint can never fit the pool fails
    loudly at admission instead of preempt-looping forever."""
    cfg, params = setup
    eng = ServingEngine(cfg, params, slots=1, s_max=32, kv_block=4,
                        kv_blocks=3)      # capacity: 2 blocks = 8 tokens
    eng.submit(Request(rid=0, prompt=np.zeros(12, np.int32), max_new=8))
    with pytest.raises(ValueError, match="KV blocks"):
        eng.run_until_idle()


def test_continuous_beats_sync_p99_flash_crowd(setup):
    """Acceptance pin: replaying a flash-crowd burst through the continuous
    engine strictly improves p99 submit->complete latency vs the
    synchronized compat mode at equal slot count -- with identical
    per-request tokens (tick-deterministic, no wall clocks involved)."""
    from repro.traffic import TrafficRecorder
    cfg, params = setup
    rng = np.random.default_rng(31)
    # burst of 8 heterogeneous requests at t=0, stragglers later
    sched = [(0, rng.integers(0, cfg.vocab, int(rng.integers(4, 10)))
              .astype(np.int32), int(rng.integers(2, 8))) for _ in range(8)]
    sched += [(6, rng.integers(0, cfg.vocab, 5).astype(np.int32), 3),
              (8, rng.integers(0, cfg.vocab, 7).astype(np.int32), 2)]
    stats, outs = {}, {}
    for sync in (False, True):
        rec = TrafficRecorder()
        eng = ServingEngine(cfg, params, slots=2, s_max=32, recorder=rec,
                            sync_batching=sync)
        reqs = [Request(rid=i, prompt=p, max_new=m)
                for i, (_, p, m) in enumerate(sched)]
        i = 0
        for _ in range(500):
            while i < len(sched) and sched[i][0] <= eng.clock:
                eng.submit(reqs[i])
                i += 1
            if not eng.step() and i == len(sched):
                break
        assert all(r.done for r in reqs)
        stats[sync] = rec.latency_stats()
        outs[sync] = [r.out for r in reqs]
    assert outs[False] == outs[True]
    assert stats[False]["p99"] < stats[True]["p99"], stats
    assert stats[False]["p50"] <= stats[True]["p50"], stats


def test_kvpool_block_allocator():
    from repro.serving.kvpool import BlockAllocator, blocks_for
    al = BlockAllocator(5, 4)
    assert al.capacity == 4 and al.n_free == 4     # block 0 reserved
    got = al.alloc(3)
    assert got is not None and 0 not in got
    assert al.alloc(2) is None                     # only 1 left: no effect
    assert al.n_free == 1
    al.free(got)
    assert al.n_free == 4
    with pytest.raises(ValueError, match="double free"):
        al.free([got[0]])                          # already back in the list
    with pytest.raises(ValueError, match="outside pool"):
        al.free([0])                               # the dummy block
    with pytest.raises(ValueError, match="reserved dummy"):
        BlockAllocator(1, 4)
    assert blocks_for(0, 4) == 1                   # at least one block
    assert blocks_for(8, 4) == 2
    assert blocks_for(9, 4) == 3


def test_kvpool_allocator_free_of_never_handed_block():
    from repro.serving.kvpool import BlockAllocator
    al = BlockAllocator(8, 4)
    al.alloc(2)
    with pytest.raises(ValueError, match="double free"):
        al.free([6])                    # never allocated: still in the list
    al._free.remove(6)                  # vanished block: in NEITHER set
    with pytest.raises(ValueError, match="never handed out"):
        al.free([6])


def test_kvpool_allocator_free_batch_is_atomic():
    """A bad free() batch must leave the allocator untouched -- a partial
    free would strand the valid blocks in limbo (neither free nor owned)."""
    from repro.serving.kvpool import BlockAllocator
    al = BlockAllocator(8, 4)
    got = al.alloc(3)
    n_free, handed = al.n_free, al.handed_out()
    with pytest.raises(ValueError, match="double free"):
        al.free([got[0], 6])            # 6 is still free
    assert al.n_free == n_free and al.handed_out() == handed
    with pytest.raises(ValueError, match="duplicated within"):
        al.free([got[1], got[1]])
    assert al.n_free == n_free and al.handed_out() == handed
    al.free(got)                        # the clean batch still drains fully
    assert al.n_free == al.capacity and al.handed_out() == frozenset()


def test_kvpool_allocator_corrupted_free_list_rolls_back():
    from repro.serving.kvpool import BlockAllocator
    al = BlockAllocator(6, 4)
    got = al.alloc(2)
    al._free.appendleft(got[0])         # simulate external corruption
    before = list(al._free)
    with pytest.raises(ValueError, match="corrupted"):
        al.alloc(3)
    assert list(al._free) == before     # pops rolled back


def test_kvpool_rejects_cross_attention_stacks(setup):
    """Cross-attention kinds have no paged path; the engine must reject
    them up front (pointing at the sync compat mode), before touching any
    cache state."""
    import dataclasses
    cfg, params = setup
    bad = dataclasses.replace(cfg, block_pattern=("g", "d"))
    with pytest.raises(ValueError, match="sync_batching"):
        ServingEngine(bad, params, slots=1, s_max=32)


def test_partitioned_es_engine_full_offload(setup):
    """cut_unit=0 hands the full stack to the ES tier; its continuous
    engine serves tokens identical to an engine on the original params."""
    cfg, params = setup
    rng = np.random.default_rng(41)
    prompt = rng.integers(0, cfg.vocab, 9).astype(np.int32)
    eng = PartitionedLM(cfg, params, 0).es_engine(slots=1, s_max=64)
    req = Request(rid=0, prompt=prompt, max_new=4)
    eng.submit(req)
    eng.run_until_idle()
    assert req.out == _solo_tokens(cfg, params, prompt, 4)
    with pytest.raises(ValueError, match="full-offload"):
        PartitionedLM(cfg, params, 2).es_engine(slots=1, s_max=64)


# -- chunked prefill ---------------------------------------------------------

def _chunk_archs():
    import dataclasses
    return [
        ("attention", reduced(get_config("qwen3-0.6b"), n_layers=4)),
        ("hybrid-grs", dataclasses.replace(
            reduced(get_config("mamba2-1.3b")),
            name="hybrid-grs-chunk", block_pattern=("g", "r", "s"),
            n_layers=6, n_heads=4, n_kv=2, head_dim=16, d_ff=128,
            rnn_width=32)),
        ("local-lg", dataclasses.replace(
            reduced(get_config("qwen3-0.6b"), n_layers=4),
            name="local-lg-chunk", block_pattern=("l", "g"), window=12)),
    ]


CHUNK_ARCHS = _chunk_archs()


@pytest.fixture(scope="module", params=[a[0] for a in CHUNK_ARCHS])
def chunk_arch(request):
    cfg = dict(CHUNK_ARCHS)[request.param]
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_chunked_prefill_matches_whole_and_solo(chunk_arch):
    """Tentpole exactness pin: admitting prompts in fixed-size prefill
    chunks must be invisible to outputs -- chunked == whole-prompt ==
    solo greedy tokens on attention, hybrid (g/r/s), and local-window
    stacks, with ragged prompt lengths spanning chunk boundaries."""
    cfg, params = chunk_arch
    rng = np.random.default_rng(51)
    spec = [(rng.integers(0, cfg.vocab, n).astype(np.int32), m)
            for n, m in ((20, 5), (11, 4), (41, 6), (5, 3))]
    outs = {}
    for chunk in (8, None):
        eng = ServingEngine(cfg, params, slots=2, s_max=64,
                            prefill_chunk=chunk)
        reqs = [Request(rid=i, prompt=p, max_new=m)
                for i, (p, m) in enumerate(spec)]
        for r in reqs:
            eng.submit(r)
        assert len(eng.run_until_idle()) == len(reqs)
        assert eng.allocator.n_free == eng.allocator.capacity
        outs[chunk] = [r.out for r in reqs]
    assert outs[8] == outs[None]
    for (p, m), got in zip(spec, outs[8]):
        assert got == _solo_tokens(cfg, params, p, m), f"len {len(p)}"


def test_chunked_preempt_mid_prefill_resumes_exact(setup):
    """A streaming prefill evicted mid-chunk restarts from scratch on
    re-admission and still produces exact tokens, with the KV sanitizer
    cross-checking every block handoff and the final drain."""
    from repro.traffic import TrafficRecorder
    cfg, params = setup
    rng = np.random.default_rng(53)
    pa = rng.integers(0, cfg.vocab, 10).astype(np.int32)
    pb = rng.integers(0, cfg.vocab, 21).astype(np.int32)
    rec = TrafficRecorder()
    # 9 allocatable blocks of 4: A (10 prompt + 20 new) grows past its
    # initial 3 blocks while B's 21-token prompt is mid-stream at 6 -- the
    # growth preempts B, the youngest, before its prefill completes
    eng = ServingEngine(cfg, params, slots=2, s_max=64, kv_block=4,
                        kv_blocks=10, prefill_chunk=8, sanitize=True,
                        recorder=rec)
    a = Request(rid=0, prompt=pa, max_new=20)
    b = Request(rid=1, prompt=pb, max_new=4)
    eng.submit(a)
    eng.submit(b)
    eng.run_until_idle()
    assert eng.preemptions > 0, "pool was sized to evict the stream"
    ev = rec.events[1]
    # the evicted window finished no prefill: the single done tick belongs
    # to the SECOND admission, so the eviction really hit mid-prefill
    assert len(ev.admits) == 2 and len(ev.prefill_dones) == 1
    assert ev.prefill_dones[0] >= ev.admits[1]
    assert a.out == _solo_tokens(cfg, params, pa, 20)
    assert b.out == _solo_tokens(cfg, params, pb, 4)
    assert eng.allocator.n_free == eng.allocator.capacity
    eng._san.check_drain()


def test_oversized_submit_no_block_leak(setup):
    """Regression (admission-path leak): an oversized request used to pass
    submit, then raise mid-admission AFTER allocating its prompt blocks --
    leaking them and dropping the request.  submit now rejects it up front
    and traffic behind it is untouched."""
    cfg, params = setup
    eng = ServingEngine(cfg, params, slots=1, s_max=32, kv_block=4,
                        kv_blocks=9, sanitize=True)
    with pytest.raises(ValueError, match="exceeds s_max"):
        eng.submit(Request(rid=0, prompt=np.zeros(30, np.int32), max_new=8))
    assert not eng.queue
    assert eng.allocator.n_free == eng.allocator.capacity
    ok = Request(rid=1, prompt=np.arange(6, dtype=np.int32), max_new=4)
    eng.submit(ok)
    eng.run_until_idle()
    assert len(ok.out) == 4
    assert eng.allocator.n_free == eng.allocator.capacity
    eng._san.check_drain()


def test_sync_wave_per_request_budgets(setup):
    """Regression (sync-mode false rejection): the wave used to validate
    the joint width bucket against the batch's LARGEST max_new, so a
    (101-prompt, 4-new) + (8-prompt, 28-new) pair at s_max=128 was
    rejected even though each request fits its own budget.  The wave
    builder now tracks per-request budgets and serves the pair exactly."""
    cfg, params = setup
    rng = np.random.default_rng(59)
    pa = rng.integers(0, cfg.vocab, 101).astype(np.int32)
    pb = rng.integers(0, cfg.vocab, 8).astype(np.int32)
    eng = ServingEngine(cfg, params, slots=2, s_max=128, sync_batching=True)
    a = Request(rid=0, prompt=pa, max_new=4)
    b = Request(rid=1, prompt=pb, max_new=28)
    eng.submit(a)
    eng.submit(b)
    assert len(eng.run_until_idle()) == 2
    assert a.out == _solo_tokens(cfg, params, pa, 4, s_max=128)
    assert b.out == _solo_tokens(cfg, params, pb, 28, s_max=128)


def test_run_until_idle_raises_on_max_steps(setup):
    """Regression: hitting max_steps used to return normally with requests
    still in flight -- silent truncation, callers saw a short result list.
    Now it raises, naming the stuck work."""
    cfg, params = setup
    eng = ServingEngine(cfg, params, slots=1, s_max=64)
    for i in range(3):
        eng.submit(Request(rid=i, prompt=np.arange(5, dtype=np.int32) + i,
                           max_new=30))
    with pytest.raises(RuntimeError, match="did not drain"):
        eng.run_until_idle(max_steps=3)


def test_chunked_rejects_bad_chunk_and_moe(setup):
    """prefill_chunk validation: out-of-range sizes raise; MoE stacks
    silently fall back to whole-prompt prefill (capacity routing couples
    tokens across a dispatch group, so chunked prefill cannot be exact)."""
    cfg, params = setup
    with pytest.raises(ValueError, match="prefill_chunk"):
        ServingEngine(cfg, params, slots=1, s_max=64, prefill_chunk=0)
    with pytest.raises(ValueError, match="prefill_chunk"):
        ServingEngine(cfg, params, slots=1, s_max=64, prefill_chunk=65)
    moe = reduced(get_config("moonshot-v1-16b-a3b"))
    assert "m" in moe.block_pattern
    moe_params = transformer.init_params(jax.random.PRNGKey(0), moe)
    eng = ServingEngine(moe, moe_params, slots=1, s_max=64, prefill_chunk=8)
    assert eng.prefill_chunk is None


@pytest.mark.slow
def test_engine_greedy_matches_manual_decode(setup):
    """Engine tokens == hand-rolled prefill+argmax decode for one request."""
    cfg, params = setup
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab, 8).astype(np.int32)
    eng = ServingEngine(cfg, params, slots=1, s_max=64)
    req = Request(rid=0, prompt=prompt, max_new=4)
    eng.submit(req)
    while eng.step():
        pass
    logits, cache = transformer.prefill(params, cfg,
                                        {"tokens": jnp.asarray(prompt)[None]},
                                        s_max=64)
    toks = []
    nxt = int(jnp.argmax(logits, -1)[0])
    toks.append(nxt)
    for _ in range(3):
        logits, cache = transformer.decode_step(params, cfg, cache,
                                                jnp.asarray([nxt], jnp.int32))
        nxt = int(jnp.argmax(logits, -1)[0])
        toks.append(nxt)
    assert req.out == toks
