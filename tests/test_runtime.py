"""Fault-tolerance runtime: checkpoint/restart, straggler, elastic,
compression (deliverable: large-scale runnability)."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.runtime.checkpoint import CheckpointManager
from repro.runtime.resilience import ElasticPolicy, RestartLoop, StragglerMonitor


@pytest.fixture
def tree():
    return {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "nested": {"b": jnp.ones((4,), jnp.bfloat16)},
            "lst": [jnp.zeros((2,), jnp.int32)]}


def test_checkpoint_roundtrip(tmp_path, tree):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(7, tree, extra={"data_step": 7})
    restored, manifest = mgr.restore(tree)
    assert manifest["step"] == 7 and manifest["extra"]["data_step"] == 7
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype


def test_checkpoint_keep_k_and_latest(tmp_path, tree):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    for s in (1, 2, 3, 4):
        mgr.save(s, tree)
    assert mgr.list_steps() == [3, 4]
    assert mgr.latest_step() == 4


def test_checkpoint_async(tmp_path, tree):
    mgr = CheckpointManager(str(tmp_path), async_save=True)
    mgr.save(1, tree)
    mgr.wait()
    assert mgr.latest_step() == 1


def test_checkpoint_atomicity(tmp_path, tree):
    """A leftover .tmp dir from a crashed writer is invisible to restore."""
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(1, tree)
    os.makedirs(tmp_path / "step_0000000009.tmp")
    assert mgr.latest_step() == 1


def test_checkpoint_restore_with_sharding(tmp_path, tree):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(3, tree)
    mesh = jax.make_mesh((1,), ("data",))
    from jax.sharding import NamedSharding, PartitionSpec as P
    sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)
    restored, _ = mgr.restore(tree, shardings=sh)
    assert all(hasattr(l, "sharding") for l in jax.tree.leaves(restored))


def test_straggler_monitor_flags_and_redispatch():
    t = {"now": 0.0}
    mon = StragglerMonitor(threshold=2.0, patience=2, clock=lambda: t["now"])
    for step in range(10):        # healthy steps of 1.0s
        mon.start_step(step)
        t["now"] += 1.0
        assert mon.end_step() is False
    mon.start_step(10)
    t["now"] += 5.0               # straggler
    assert mon.end_step() is True
    assert not mon.should_redispatch
    mon.start_step(11)
    t["now"] += 5.0
    assert mon.end_step() is True
    assert mon.should_redispatch  # patience=2 reached
    assert mon.deadline() == pytest.approx(2.0, rel=0.3)


def test_elastic_policy():
    pol = ElasticPolicy(target_model=16)
    assert pol.plan(256)["shape"] == (16, 16)
    # lose a host (8 devices): biggest valid mesh keeps model=8
    plan = pol.plan(248, current_shape=(16, 16))
    assert plan["shape"][0] * plan["shape"][1] == 248
    assert plan["reshard_required"]
    assert pol.plan(256, current_shape=(16, 16))["reshard_required"] is False


def test_restart_loop_recovers_from_failure():
    saves = {}

    def save_fn(state, step):
        saves["latest"] = (state, step)

    def restore_fn():
        return saves.get("latest")

    crashed = {"done": False}

    def step_fn(state, step):
        if step == 7 and not crashed["done"]:
            crashed["done"] = True
            raise RuntimeError("simulated node failure")
        return state + 1

    loop = RestartLoop(save_fn, restore_fn, checkpoint_every=5)
    state, step = loop.run(step_fn, 0, n_steps=10)
    assert step == 10
    assert loop.restarts == 1
    # steps 5..7 were replayed after restore from step 5
    assert state == 10


def test_restart_loop_gives_up():
    loop = RestartLoop(lambda s, i: None, lambda: None, max_restarts=1)

    def bad(state, step):
        raise RuntimeError("permanent failure")

    with pytest.raises(RuntimeError):
        loop.run(bad, 0, n_steps=3)


# ---------------------------------------------------------------------------
# gradient compression (multi-device via subprocess with 8 host devices)
# ---------------------------------------------------------------------------

_DP_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.runtime.compression import make_grad_sync
from jax.experimental.shard_map import shard_map

mesh = jax.make_mesh((8,), ("data",))
g = {"w": jnp.linspace(-1.0, 1.0, 64).reshape(8, 8)}
r = {"w": jnp.zeros((8, 8))}

for mode in ("none", "bf16", "int8"):
    sync = make_grad_sync(mesh, "data", mode=mode, error_feedback=True)
    f = shard_map(lambda gg, rr: sync(gg, rr), mesh=mesh,
                  in_specs=(P("data"), P("data")), out_specs=(P("data"), P("data")),
                  check_rep=False)
    out, res = f(g, r)
    # psum over identical shards at different rows -> compare vs numpy mean
    got = np.asarray(out["w"])
    want = np.tile(np.asarray(g["w"]).reshape(8, 1, 8).mean(0), (8, 1)).reshape(8,8)
    err = np.abs(got - want).max()
    tol = {"none": 1e-6, "bf16": 5e-3, "int8": 2e-2}[mode]
    assert err < tol, (mode, err)
    print(mode, "ok", err)

# error feedback drives the MEAN quantization bias to zero over steps
sync = make_grad_sync(mesh, "data", mode="int8", error_feedback=True)
f = shard_map(lambda gg, rr: sync(gg, rr), mesh=mesh,
              in_specs=(P("data"), P("data")), out_specs=(P("data"), P("data")),
              check_rep=False)
accum = np.zeros((8, 8)); res = {"w": jnp.zeros((8, 8))}
for i in range(50):
    out, res = f(g, res)
    accum += np.asarray(out["w"])
want = np.tile(np.asarray(g["w"]).reshape(8, 1, 8).mean(0), (8, 1)).reshape(8,8)
bias = np.abs(accum / 50 - want).max()
assert bias < 2e-3, bias
print("error-feedback ok", bias)
"""


@pytest.mark.slow
def test_compressed_grad_sync_multidevice():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    proc = subprocess.run([sys.executable, "-c", _DP_SCRIPT], env=env,
                          capture_output=True, text=True, timeout=300,
                          cwd=os.path.dirname(os.path.dirname(__file__)))
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "error-feedback ok" in proc.stdout
