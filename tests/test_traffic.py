"""Traffic subsystem: generators, trace format, recorder, and the
serving->trace->MEC replay loop (repro.traffic + LAM_TRACE integration).

The batched/sharded parity tests mirror tests/test_gridshard.py: trace-driven
grids must match the per-cell loop to 1e-5, including an uneven
B-not-multiple-of-devices sharded case.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import traffic
from repro.core import gridshard
from repro.core import scenarios as sc
from repro.core.env import (LAM_TRACE, MecConfig, make_params, reset_p,
                            step_p)
from repro.core.lymdo import run_fixed_batched
from repro.launch.mesh import make_cells_mesh

N_DEV = len(jax.devices())
_STEP = jax.jit(step_p)


def _cell(tree, b):
    return jax.tree.map(lambda x: x[b], tree)


def _forced_pad_to(b: int) -> int | None:
    natural = -(-b // N_DEV) * N_DEV
    return natural + N_DEV if natural == b else None


# ---------------------------------------------------------------------------
# Generators: empirical rates match nominal rates
# ---------------------------------------------------------------------------

def _empirical_mean(proc, horizon=2000, seed=0):
    rates = traffic.materialize(proc, horizon, jax.random.PRNGKey(seed))
    return rates.mean(axis=0)


def test_iid_uniform_mean():
    p = traffic.IidUniform(low=traffic.per_ue(0.5, 3),
                           high=traffic.per_ue(2.5, 3))
    np.testing.assert_allclose(_empirical_mean(p), 1.5, atol=0.05)


def test_poisson_mean_and_granularity():
    lam = np.array([0.8, 2.0, 4.0], np.float32)
    p = traffic.PoissonArrivals(lam=jnp.asarray(lam),
                                slot_s=jnp.float32(1.0))
    np.testing.assert_allclose(_empirical_mean(p), lam, rtol=0.1)
    # counts per 1s slot are integers -> rates are integer-valued
    rates = traffic.materialize(p, 50, jax.random.PRNGKey(1))
    np.testing.assert_array_equal(rates, np.round(rates))


def test_diurnal_mean_and_swing():
    p = traffic.Diurnal(base=traffic.per_ue(1.5, 2),
                        amp=traffic.per_ue(1.0, 2),
                        period=jnp.float32(100.0), phase=jnp.float32(0.0))
    rates = traffic.materialize(p, 400)      # 4 whole periods
    np.testing.assert_allclose(rates.mean(axis=0), 1.5, atol=1e-3)
    np.testing.assert_allclose(rates.max(axis=0), 2.5, atol=1e-3)
    np.testing.assert_allclose(rates.min(axis=0), 0.5, atol=1e-3)


def test_flash_crowd_shape():
    p = traffic.FlashCrowd(base=traffic.per_ue(1.0, 2),
                           spike=jnp.float32(3.0), t0=jnp.int32(50),
                           decay=jnp.float32(10.0))
    rates = traffic.materialize(p, 120)
    np.testing.assert_allclose(rates[:50], 1.0)          # quiet before t0
    np.testing.assert_allclose(rates[50], 4.0, rtol=1e-6)  # base + spike
    assert rates[60, 0] < rates[50, 0]                   # decaying
    np.testing.assert_allclose(rates[110], 1.0, atol=0.02)  # ~6 e-foldings


def test_mmpp_rates_and_dwell():
    """Regime rates are drawn from the declared set; long-run occupancy of a
    symmetric 2-state chain is ~50/50; mean dwell ~ 1/(1-p_stay)."""
    p = traffic.make_mmpp(4, seed=0, rates=(0.5, 3.0), p_stay=0.9,
                          horizon=4000)
    rates = traffic.materialize(p, 4000)
    assert set(np.unique(rates)) <= {np.float32(0.5), np.float32(3.0)}
    frac_high = (rates == 3.0).mean()
    assert 0.4 < frac_high < 0.6
    switches = (np.diff(np.asarray(p.regimes), axis=0) != 0).mean()
    np.testing.assert_allclose(switches, 0.1, atol=0.03)  # 1 - p_stay
    # deterministic in seed, distinct across seeds
    p2 = traffic.make_mmpp(4, seed=0, rates=(0.5, 3.0), p_stay=0.9,
                           horizon=4000)
    np.testing.assert_array_equal(np.asarray(p.regimes),
                                  np.asarray(p2.regimes))
    p3 = traffic.make_mmpp(4, seed=1, rates=(0.5, 3.0), p_stay=0.9,
                           horizon=4000)
    assert not np.array_equal(np.asarray(p.regimes), np.asarray(p3.regimes))


def test_mmpp_rejects_bad_transition_matrix():
    with pytest.raises(ValueError):
        traffic.make_mmpp(2, trans=np.array([[0.5, 0.4], [0.5, 0.5]]))


# ---------------------------------------------------------------------------
# Trace format: save -> load -> replay round-trips bit-exactly
# ---------------------------------------------------------------------------

def test_trace_roundtrip_bit_exact(tmp_path):
    rng = np.random.default_rng(7)
    rates = rng.uniform(0.0, 3.0, (37, 5)).astype(np.float32)
    tr = traffic.Trace(rates=rates, slot_s=0.25, meta={"source": "test"})
    path = tmp_path / "trace.npz"
    tr.save(path)
    tr2 = traffic.Trace.load(path)
    assert tr2.rates.dtype == np.float32
    np.testing.assert_array_equal(tr2.rates, rates)      # bit-exact
    assert tr2.slot_s == 0.25 and tr2.meta == {"source": "test"}
    # replay through the process is also bit-exact (and wraps at T)
    proc = tr2.process()
    for t in (0, 11, 36, 37, 80):
        np.testing.assert_array_equal(
            np.asarray(proc(None, jnp.int32(t))), rates[t % 37])


def test_trace_validation_and_shift():
    with pytest.raises(ValueError):
        traffic.Trace(rates=np.zeros((5,), np.float32))
    tr = traffic.Trace(rates=np.arange(12, dtype=np.float32).reshape(6, 2))
    sh = tr.shifted(2)
    np.testing.assert_array_equal(sh.rates, np.roll(tr.rates, -2, axis=0))
    assert sh.meta["shifted_by"] == 2


def test_from_process_materializes():
    p = traffic.FixedRate(lam=traffic.per_ue(1.25, 3))
    tr = traffic.from_process(p, horizon=9)
    assert tr.rates.shape == (9, 3)
    np.testing.assert_allclose(tr.rates, 1.25)
    assert tr.meta["source"] == "process:fixed"


# ---------------------------------------------------------------------------
# Recorder: request lifecycles -> binned trace
# ---------------------------------------------------------------------------

def test_recorder_bins_submissions():
    rec = traffic.TrafficRecorder()
    # 2 UEs; submits at ticks 0,0,1,4,4,4; one still-in-flight request
    for rid, (t, ue) in enumerate([(0, 0), (0, 1), (1, 0), (4, 1), (4, 1),
                                   (4, 0)]):
        rec.record_submit(rid, t, ue=ue)
        rec.record_admit(rid, t + 1)
        if rid != 5:
            rec.record_complete(rid, t + 3)
    tr = rec.to_trace(n_ue=2, bin_ticks=1, slot_s=0.5)
    assert tr.rates.shape == (5, 2)
    np.testing.assert_array_equal(tr.rates[:, 0] * 0.5, [1, 1, 0, 0, 1])
    np.testing.assert_array_equal(tr.rates[:, 1] * 0.5, [1, 0, 0, 0, 2])
    # completions bin separately; the in-flight rid=5 is skipped
    tr_c = rec.to_trace(n_ue=2, which="complete", horizon=8)
    assert tr_c.rates.sum() == 5
    ev = rec.events[0]
    assert ev.queueing_ticks == 1 and ev.service_ticks == 2
    with pytest.raises(ValueError):
        rec.to_trace(n_ue=2, which="nope")


def test_recorder_round_robin_when_ue_unset():
    """Requests that never declared a UE spread rid % n_ue instead of all
    landing on column 0."""
    rec = traffic.TrafficRecorder()
    for rid in range(6):
        rec.record_submit(rid, rid)          # no ue argument
    tr = rec.to_trace(n_ue=3, horizon=6)
    np.testing.assert_allclose(tr.rates.sum(axis=0), [2, 2, 2])


def test_recorder_resubmit_preserves_declared_ue():
    """Regression: a resubmit WITHOUT ue= (e.g. a preempted request re-
    entering the queue) must not wipe the UE declared at first submit --
    the request would silently fall back to rid % n_ue binning."""
    rec = traffic.TrafficRecorder()
    rec.record_submit(0, 0, ue=2)
    rec.record_submit(0, 5)                  # resubmit, no ue argument
    assert rec.events[0].ue == 2
    assert rec.events[0].submit == 5         # timestamp does update
    rec.record_submit(0, 6, ue=1)            # explicit ue still overrides
    assert rec.events[0].ue == 1
    with pytest.raises(ValueError, match="ue must be >= 0"):
        rec.record_submit(1, 0, ue=-1)


def test_recorder_latency_stats():
    rec = traffic.TrafficRecorder()
    assert rec.latency_stats() == {"n": 0}
    for rid, (sub, comp) in enumerate([(0, 4), (1, 3), (2, 12)]):
        rec.record_submit(rid, sub, ue=0)
        rec.record_admit(rid, sub + 1)
        rec.record_complete(rid, comp)
    rec.record_submit(9, 5, ue=0)            # in flight: excluded
    np.testing.assert_array_equal(rec.latencies(), [4, 2, 10])
    st = rec.latency_stats()
    assert st["n"] == 3 and st["max"] == 10
    np.testing.assert_allclose(st["p50"], 4.0)
    np.testing.assert_allclose(st["p90"], 8.8)
    # mean of the breakdown queue-wait stage: every request waited
    # admit - submit - 1 = 0 ticks here
    np.testing.assert_allclose(st["mean_queue_wait"], 0.0)
    # queueing-only view through the same API
    np.testing.assert_array_equal(rec.latencies("submit", "admit"), [1, 1, 1])
    with pytest.raises(ValueError, match="unknown event"):
        rec.latency_stats(end="nope")


def test_recorder_latency_stats_edge_cases(recwarn):
    """Empty and single-event sets: no numpy warnings, stable keys, and
    mean_queue_wait only appears once a request has a full lifecycle."""
    rec = traffic.TrafficRecorder()
    assert rec.latency_stats() == {"n": 0}
    rec.record_submit(0, 2, ue=0)
    rec.record_complete(0, 9)               # complete without admit:
    st = rec.latency_stats()                # latency counts, breakdown can't
    assert st["n"] == 1
    assert st["p50"] == st["p90"] == st["p99"] == 7.0
    assert st["max"] == 7 and "mean_queue_wait" not in st
    rec.record_admit(0, 5)                  # full lifecycle now
    st = rec.latency_stats()
    np.testing.assert_allclose(st["mean_queue_wait"], 2.0)   # 5 - 2 - 1
    assert not [w for w in recwarn if "RuntimeWarning"
                in str(w.category)], "numpy warned on small input"


def test_recorder_delay_breakdowns_with_preemption():
    """record_preempt feeds the breakdown: stage sums telescope to E2E."""
    rec = traffic.TrafficRecorder()
    rec.record_submit(0, 0, ue=1)
    rec.record_admit(0, 2)
    rec.record_preempt(0, 5)
    rec.record_admit(0, 6)
    rec.record_complete(0, 9)
    ev = rec.events[0]
    assert ev.admit == 2 and ev.last_admit == 6
    assert ev.queueing_ticks == 2 and ev.service_ticks == 3
    (b,) = rec.delay_breakdowns().values()
    assert (b.queue_wait, b.prefill, b.decode, b.preempted) == (1, 2, 3, 3)
    assert b.e2e == 9 and b.n_preempts == 1


def test_recorder_horizon_and_binning():
    rec = traffic.TrafficRecorder()
    for rid, t in enumerate([0, 3, 5, 9, 11]):
        rec.record_submit(rid, t, ue=rid % 3)
    tr = rec.to_trace(n_ue=3, bin_ticks=4, slot_s=2.0, horizon=3)
    assert tr.rates.shape == (3, 3)
    # bin 0 holds ticks 0-3 (2 events), bin 1 ticks 4-7 (1), bin 2 ticks 8-11 (2)
    np.testing.assert_allclose(tr.rates.sum(axis=1) * 2.0, [2, 1, 2])


# ---------------------------------------------------------------------------
# Env integration: the arrival process drives state.lam
# ---------------------------------------------------------------------------

def _tiny_params(arrival=None, cfg=None):
    from repro.profiling.convnets import alexnet_profile
    profiles = [alexnet_profile()] * 2
    return make_params(profiles, cfg or MecConfig(), [0.04, 0.04],
                       [0.1, 0.1], arrival=arrival)


def test_trace_arrival_drives_env_lam():
    rates = np.arange(8, dtype=np.float32).reshape(4, 2) * 0.3 + 0.5
    p = _tiny_params(arrival=traffic.Trace(rates=rates).process())
    st = reset_p(p, jax.random.PRNGKey(0))
    np.testing.assert_allclose(np.asarray(st.lam), rates[0], rtol=1e-6)
    for t in range(1, 6):
        st, _ = _STEP(p, st, jnp.zeros((2,), jnp.int32))
        np.testing.assert_allclose(np.asarray(st.lam), rates[t % 4],
                                   rtol=1e-6)


def test_lam_trace_mode_requires_process():
    with pytest.raises(ValueError):
        _tiny_params(cfg=MecConfig(lam_mode=LAM_TRACE))


def test_cfg_arrival_field_is_used():
    arr = traffic.FixedRate(lam=traffic.per_ue(1.75, 2))
    p = _tiny_params(cfg=MecConfig(arrival=arr))
    st = reset_p(p, jax.random.PRNGKey(3))
    np.testing.assert_allclose(np.asarray(st.lam), 1.75)


def test_stack_params_rejects_mixed_arrival_types():
    pa = _tiny_params(arrival=traffic.FixedRate(lam=traffic.per_ue(1.0, 2)))
    pb = _tiny_params(arrival=traffic.Diurnal(
        base=traffic.per_ue(1.0, 2), amp=traffic.per_ue(0.5, 2),
        period=jnp.float32(50.0), phase=jnp.float32(0.0)))
    with pytest.raises(ValueError, match="arrival-process type"):
        sc.stack_params([pa, pb])


# ---------------------------------------------------------------------------
# Batched / sharded replay parity (the 1e-5 contract, LAM_TRACE edition)
# ---------------------------------------------------------------------------

def _trace_cells(b: int, n_ue: int = 4, horizon: int = 24, seed: int = 5):
    mm = traffic.make_mmpp(n_ue, seed=seed, rates=(0.5, 2.5), horizon=horizon)
    tr = traffic.from_process(mm, horizon)
    return [sc.make("trace_replay", trace=tr, offset=3 * i, seed=seed + i)
            for i in range(b)]


def test_trace_grid_batched_equals_per_cell_loop():
    """LAM_TRACE ScenarioGrid rollout == per-cell loop to 1e-5 (full
    results), using the rollout's own key discipline."""
    grid = sc.ScenarioGrid(_trace_cells(4))
    steps, seed = 10, 3
    _, res_b, sum_b = grid.rollout("oracle", steps=steps, seed=seed)

    key = jax.random.PRNGKey(seed)
    key, k0 = jax.random.split(key)
    cell_keys = gridshard.cell_keys(k0, grid.b)
    for b in range(grid.b):
        params = _cell(grid.params, b)
        st = reset_p(params, cell_keys[b])
        rewards = []
        for t in range(steps):
            from repro.core import sweep
            st, res = _STEP(params, st, sweep.oracle_cut_p(params, st))
            rewards.append(float(res.reward))
            np.testing.assert_allclose(
                np.asarray(res_b.reward[t, b]), rewards[-1],
                rtol=1e-5, atol=1e-7)
        np.testing.assert_allclose(np.asarray(sum_b["reward"][b]),
                                   np.mean(rewards), rtol=1e-5, atol=1e-7)


def test_trace_grid_sharded_parity_uneven_b():
    """Sharded trace replay at B not a multiple of the device count: padded
    (B, T, N) trace tensors must not perturb real cells."""
    b = 6
    cells = _trace_cells(b)
    plain = sc.ScenarioGrid(cells)
    shard = sc.ScenarioGrid(cells).use_mesh(make_cells_mesh(),
                                            pad_to=_forced_pad_to(b))
    assert shard.gridshard.pad > 0
    _, res_p, sum_p = plain.rollout("oracle", steps=8, seed=11)
    _, res_s, sum_s = shard.rollout("oracle", steps=8, seed=11)
    for name in sum_p:
        np.testing.assert_allclose(np.asarray(sum_s[name]),
                                   np.asarray(sum_p[name]),
                                   rtol=1e-5, atol=1e-7, err_msg=name)
    for got, want in zip(jax.tree.leaves(res_s), jax.tree.leaves(res_p)):
        assert got.shape == want.shape
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-7)


def test_mmpp_and_diurnal_grids_run():
    grid = sc.ScenarioGrid([sc.make("mmpp_burst", seed=i) for i in range(2)]
                           + [])
    m, _ = run_fixed_batched(grid, "local", episodes=1, steps=6)
    assert np.all(np.isfinite(m["delay"]))
    grid2 = sc.ScenarioGrid([sc.make("diurnal", base=1.0 + 0.2 * i)
                             for i in range(2)])
    m2, _ = run_fixed_batched(grid2, "oracle", episodes=1, steps=6)
    assert np.all(np.isfinite(m2["reward"]))


# ---------------------------------------------------------------------------
# End-to-end: ServingEngine -> recorder -> trace -> MEC grid replay
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_serving_trace_replay_end_to_end(tmp_path):
    """The full loop: serve prompts under a bursty schedule, record the
    lifecycle, bin it into a trace, save/load it, and replay it as the
    arrival process of a batched multi-cell rollout."""
    from repro.configs.base import get_config, reduced
    from repro.models import transformer
    from repro.serving.engine import Request, ServingEngine

    cfg = reduced(get_config("qwen3-0.6b"), n_layers=4)
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    rec = traffic.TrafficRecorder()
    eng = ServingEngine(cfg, params, slots=2, s_max=32, recorder=rec)

    rng = np.random.default_rng(0)
    schedule = {0: 2, 3: 1, 7: 3, 12: 2}      # tick -> submissions
    rid = 0
    for tick in range(20):
        for _ in range(schedule.get(tick, 0)):
            eng.submit(Request(rid=rid,
                               prompt=rng.integers(0, cfg.vocab, 6)
                               .astype(np.int32),
                               max_new=2, ue=rid % 3))
            rid += 1
        eng.step()
    eng.run_until_idle()
    assert len(rec.events) == rid
    assert all(ev.complete is not None for ev in rec.events.values())

    tr = rec.to_trace(n_ue=3, bin_ticks=2, slot_s=1.0, horizon=12)
    assert tr.rates.sum() == rid              # every submission binned
    path = tmp_path / "serving.npz"
    tr.save(path)

    cells = [sc.make("trace_replay", path=str(path), offset=i, seed=i)
             for i in range(3)]
    grid = sc.ScenarioGrid(cells)
    m, res = run_fixed_batched(grid, "oracle", episodes=1, steps=12)
    assert res.reward.shape == (12, 3)
    assert np.all(np.isfinite(m["reward"]))
