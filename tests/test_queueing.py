"""Queueing-model correctness: analytical eq. (2) vs discrete-event simulation."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import queueing


def simulate_md1(lam: float, mu: float, n_tasks: int, seed: int = 0) -> float:
    """Discrete-event M/D/1: Poisson arrivals, deterministic service 1/mu."""
    rng = np.random.default_rng(seed)
    inter = rng.exponential(1.0 / lam, n_tasks)
    arrivals = np.cumsum(inter)
    service = 1.0 / mu
    finish = np.empty(n_tasks)
    prev_finish = 0.0
    for i in range(n_tasks):
        start = max(arrivals[i], prev_finish)
        prev_finish = start + service
        finish[i] = prev_finish
    return float(np.mean(finish - arrivals))


@pytest.mark.parametrize("lam,mu", [(0.5, 2.0), (2.5, 4.0), (1.0, 10.0)])
def test_md1_matches_simulation(lam, mu):
    analytical = float(queueing.md1_sojourn(lam, mu))
    simulated = simulate_md1(lam, mu, n_tasks=200_000)
    assert analytical == pytest.approx(simulated, rel=0.03)


def test_md1_components():
    # service-only limit: lam -> 0 gives pure processing delay 1/mu
    assert float(queueing.md1_sojourn(1e-9, 4.0)) == pytest.approx(0.25, rel=1e-4)
    # heavy traffic blows up
    assert float(queueing.md1_sojourn(3.999, 4.0)) > 100.0


@given(lam=st.floats(0.1, 3.0), d=st.floats(1e6, 5e8), f=st.floats(1e9, 3e9))
@settings(max_examples=50, deadline=None)
def test_ue_sojourn_positive_and_monotone_in_f(lam, d, f):
    if f / d <= lam * 1.05:  # keep the queue stable
        return
    t1 = float(queueing.ue_sojourn(lam, f, d))
    t2 = float(queueing.ue_sojourn(lam, f * 1.1, d))
    assert t1 > 0 and t2 > 0 and t2 < t1  # more CPU -> strictly less delay


def test_zero_portions_cost_nothing():
    assert float(queueing.ue_sojourn(1.0, 0.0, 0.0)) == 0.0
    assert float(queueing.es_sojourn(0.0, 0.0)) == 0.0
    assert float(queueing.trans_delay(0.0, 0.5, 5e6, 0.1, 1e-11, 4e-21)) == 0.0


def test_shannon_rate_alpha_zero():
    assert float(queueing.shannon_rate(0.0, 5e6, 0.1, 1e-11, 4e-21)) == 0.0
    r1 = float(queueing.shannon_rate(0.3, 5e6, 0.1, 1e-11, 4e-21))
    r2 = float(queueing.shannon_rate(0.6, 5e6, 0.1, 1e-11, 4e-21))
    assert 0 < r1 < r2  # more bandwidth -> more rate


def test_rate_concavity_in_alpha():
    alphas = np.linspace(0.05, 1.0, 20)
    rates = np.array([float(queueing.shannon_rate(a, 5e6, 0.1, 1.6e-11, 4e-21))
                      for a in alphas])
    second_diff = np.diff(rates, 2)
    assert np.all(second_diff < 1e-3)  # concave (convexity basis of P5)


def test_gd1_correction_exceeds_deterministic():
    """The beyond-paper G/D/1 edge model adds a nonnegative queueing term."""
    lam, f_es, d_es = 2.0, 3e9, 1e9
    base = float(queueing.es_sojourn(f_es, d_es))
    corrected = float(queueing.es_sojourn_gd1(lam, f_es, d_es, rho_ue=0.5))
    assert corrected >= base


# ---------------------------------------------------------------------------
# Stability edge cases (property tests via the hypothesis-compat shim):
# lam -> mu, cut == 0, alpha -> 0 must stay finite with non-negative delays.
# ---------------------------------------------------------------------------

@given(mu=st.floats(0.5, 10.0), eps=st.floats(1e-9, 1e-3))
@settings(max_examples=30, deadline=None)
def test_md1_near_critical_stays_finite(mu, eps):
    lam = mu * (1.0 - eps)  # approach the stability boundary from below
    t = float(queueing.md1_sojourn(lam, mu))
    assert np.isfinite(t)
    assert t >= 1.0 / mu - 1e-6  # never below the pure service time


@given(lam=st.floats(0.1, 3.0), d=st.floats(1e6, 5e8), slack=st.floats(1e-6, 1e-2))
@settings(max_examples=30, deadline=None)
def test_ue_sojourn_near_critical_stays_finite(lam, d, slack):
    f = d * lam * (1.0 + slack)  # mu = f/d -> lam as slack -> 0
    t = float(queueing.ue_sojourn(lam, f, d))
    assert np.isfinite(t) and t >= 0.0


@given(lam=st.floats(0.1, 3.0), f=st.floats(1e8, 3e9), psi=st.floats(0.0, 5e6))
@settings(max_examples=30, deadline=None)
def test_cut_zero_full_offload_delay(lam, f, psi):
    """cut == 0: no local portion -> zero local delay, full e2e still finite."""
    delay, (t_ue, t_tx, t_es) = queueing.e2e_delay(
        lam, 0.0, 15e9, 0.0, 1e9 * 0.12, psi, 0.2, 5e6, 0.1, 1.6e-11, 4e-21)
    assert float(t_ue) == 0.0
    assert np.isfinite(float(delay)) and float(delay) >= 0.0
    assert float(t_tx) >= 0.0 and float(t_es) >= 0.0


@given(alpha=st.floats(0.0, 1e-6), psi=st.floats(1.0, 5e6))
@settings(max_examples=30, deadline=None)
def test_alpha_to_zero_stays_finite(alpha, psi):
    """alpha -> 0: rate -> 0 smoothly; delay blows up but never to inf/nan."""
    rate = float(queueing.shannon_rate(alpha, 5e6, 0.1, 1.6e-11, 4e-21))
    assert np.isfinite(rate) and rate >= 0.0
    t = float(queueing.trans_delay(psi, alpha, 5e6, 0.1, 1.6e-11, 4e-21))
    assert np.isfinite(t) and t >= 0.0
    if alpha == 0.0:
        assert rate == 0.0


@given(lam=st.floats(0.1, 3.0), rho_ue=st.floats(0.0, 1.0))
@settings(max_examples=30, deadline=None)
def test_gd1_near_saturation_stays_finite(lam, rho_ue):
    """G/D/1 correction at edge utilizations up to (clipped) saturation."""
    d_es = 1e9
    f_es = d_es * lam * 1.0001  # rho_es -> 1
    t = float(queueing.es_sojourn_gd1(lam, f_es, d_es, rho_ue))
    assert np.isfinite(t) and t >= 0.0
