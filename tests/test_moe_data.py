"""MoE dispatch semantics, data-pipeline determinism, roofline estimators."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, reduced
from repro.data.pipeline import DataConfig, SyntheticStream, for_arch
from repro.launch import specs
from repro.models import ffn
from repro.profiling import roofline as rl


# ---------------------------------------------------------------------------
# MoE dispatch
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def moe_setup():
    cfg = reduced(get_config("moonshot-v1-16b-a3b"), capacity_factor=8.0)
    params = ffn.init_moe(jax.random.PRNGKey(0), cfg)
    return cfg, params


@pytest.mark.slow
def test_moe_no_drop_matches_dense_mixture(moe_setup):
    """With no-drop capacity, the GShard dispatch must equal the explicit
    per-token mixture of its top-k experts."""
    cfg, p = moe_setup
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 8, cfg.d_model))
    y, aux = ffn.apply_moe(p, cfg, x)

    # explicit dense computation
    tokens = x.reshape(-1, cfg.d_model)
    logits = tokens.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    k = cfg.top_k
    top_idx = jnp.argsort(-probs, axis=-1)[:, :k]
    top_p = jnp.take_along_axis(probs, top_idx, -1)
    top_p = top_p / jnp.sum(top_p, -1, keepdims=True)
    outs = []
    for e in range(cfg.n_experts):
        h = tokens @ p["wi"][e]
        h = jax.nn.silu(h) * (tokens @ p["wg"][e])
        outs.append(h @ p["wo"][e])
    outs = jnp.stack(outs, 1)                      # (T, E, D)
    want = jnp.zeros_like(tokens)
    for j in range(k):
        sel = jnp.take_along_axis(
            outs, top_idx[:, j][:, None, None].repeat(cfg.d_model, -1), 1)[:, 0]
        want = want + top_p[:, j:j + 1] * sel
    if "shared" in p:
        want = want + ffn.apply_ffn(p["shared"], cfg, tokens)
    np.testing.assert_allclose(np.asarray(y.reshape(-1, cfg.d_model)),
                               np.asarray(want), rtol=2e-3, atol=2e-3)
    assert float(aux) > 0


@pytest.mark.slow
def test_moe_capacity_drops_tokens():
    """Tight capacity must drop overflow tokens (output != no-drop output)."""
    base = reduced(get_config("llama4-maverick-400b-a17b"))
    tight = dataclasses.replace(base, capacity_factor=0.25)
    loose = dataclasses.replace(base, capacity_factor=8.0)
    p = ffn.init_moe(jax.random.PRNGKey(0), loose)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, base.d_model))
    y_tight, _ = ffn.apply_moe(p, tight, x)
    y_loose, _ = ffn.apply_moe(p, loose, x)
    assert float(jnp.max(jnp.abs(y_tight - y_loose))) > 1e-4


@pytest.mark.slow
def test_moe_aux_loss_prefers_balance(moe_setup):
    """Uniform routing yields the minimal load-balance loss (= 1)."""
    cfg, p = moe_setup
    # force a router that sends everything to expert 0
    p_skew = dict(p)
    p_skew["router"] = jnp.zeros_like(p["router"]).at[:, 0].set(10.0)
    # positive inputs so the skewed router's logit for expert 0 dominates
    x = jnp.abs(jax.random.normal(jax.random.PRNGKey(2),
                                  (2, 64, cfg.d_model))) + 0.1
    _, aux_skew = ffn.apply_moe(p_skew, cfg, x)
    _, aux_learn = ffn.apply_moe(p, cfg, x)
    assert float(aux_skew) > float(aux_learn)
    assert float(aux_skew) == pytest.approx(cfg.n_experts, rel=0.05)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_stream_deterministic_per_step():
    s1 = SyntheticStream(DataConfig(batch=4, seq=16, vocab=97, seed=3))
    s2 = SyntheticStream(DataConfig(batch=4, seq=16, vocab=97, seed=3))
    b1, b2 = s1.get_batch(42), s2.get_batch(42)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    b3 = s1.get_batch(43)
    assert not np.array_equal(np.asarray(b1["tokens"]), np.asarray(b3["tokens"]))


def test_stream_targets_shifted():
    s = SyntheticStream(DataConfig(batch=2, seq=8, vocab=50, seed=0))
    b = s.get_batch(0)
    assert b["tokens"].shape == b["targets"].shape == (2, 8)
    assert int(b["tokens"].max()) < 50


def test_stream_modality_stubs():
    vlm = for_arch(get_config("llama-3.2-vision-90b"), batch=2, seq=16)
    b = vlm.get_batch(0)
    assert b["image_embeds"].shape == (2, 1024, 8192)
    audio = for_arch(get_config("seamless-m4t-large-v2"), batch=2, seq=16)
    b = audio.get_batch(0)
    assert b["src_embeds"].shape == (2, 16, 1024)
    assert b["tokens"].shape[1] == max(16 // 4, 8)


# ---------------------------------------------------------------------------
# roofline estimators
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_param_count_matches_model_zoo():
    """Analytic param counts == actual init() counts on reduced configs."""
    from repro.models import transformer
    for name in ("qwen3-0.6b", "moonshot-v1-16b-a3b", "mamba2-1.3b",
                 "recurrentgemma-2b", "seamless-m4t-large-v2"):
        cfg = reduced(get_config(name))
        params = transformer.init_params(jax.random.PRNGKey(0), cfg)
        actual = transformer.param_count(params)
        est = rl.param_count(cfg)
        # estimator ignores norms/small biases: within 6%
        assert abs(est - actual) / actual < 0.06, (name, est, actual)


def test_moe_active_less_than_total():
    cfg = get_config("llama4-maverick-400b-a17b")
    total = rl.param_count(cfg)
    active = rl.param_count(cfg, active_only=True)
    assert total == pytest.approx(400e9, rel=0.05)
    assert active == pytest.approx(18e9, rel=0.35)
    assert active < total / 15


def test_flops_scaling_laws():
    cfg = get_config("qwen3-0.6b")
    s1 = rl.step_flops(cfg, specs.SHAPES["train_4k"], "train")
    # 6ND within sanity for dense train
    n = rl.param_count(cfg, active_only=True)
    d = 256 * 4096
    assert s1["model"] == pytest.approx(6 * n * d, rel=1e-6)
    assert s1["executed"] > s1["model"] / 2  # remat+attention bounded waste
    # decode executed flops: >= weight term 2N/token; cache attention adds
    # 4*S*h*hd per layer (dominant for a small model at a 32k cache)
    sd = rl.step_flops(cfg, specs.SHAPES["decode_32k"], "decode")
    weight_term = 2 * n * 128
    attn_term = 4 * 32768 * cfg.n_heads * cfg.resolved_head_dim \
        * cfg.n_layers * 128
    assert sd["executed"] == pytest.approx(
        weight_term + attn_term + 2 * cfg.d_model * cfg.vocab * 128, rel=0.05)


def test_decode_memory_dominated_by_weights_and_cache():
    cfg = get_config("qwen1.5-110b")
    hbm = rl.step_hbm_bytes(cfg, specs.SHAPES["decode_32k"], "decode")
    p_bytes = rl.param_count(cfg) * 2
    assert hbm > p_bytes                      # weights read at least once
    assert hbm < p_bytes * 10                 # but not absurdly more
