"""Per-architecture smoke tests (deliverable f): reduced same-family configs,
one forward/train step on CPU, shape + finiteness asserts, and the serving
invariant decode(cache) == teacher-forced logits."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, load_all, reduced
from repro.data.pipeline import for_arch
from repro.models import transformer
from repro.models.steps import make_train_step

pytestmark = pytest.mark.slow  # end-to-end; deselected in tier-1

ARCHS = sorted(load_all().keys())


@pytest.fixture(scope="module")
def key():
    return jax.random.PRNGKey(0)


def _setup(name, key, **overrides):
    cfg = reduced(get_config(name), **overrides)
    params = transformer.init_params(key, cfg)
    stream = for_arch(cfg, batch=2, seq=16)
    return cfg, params, stream


@pytest.mark.parametrize("name", ARCHS)
def test_forward_shapes_finite(name, key):
    cfg, params, stream = _setup(name, key)
    batch = stream.get_batch(0)
    logits, aux = transformer.forward_train(params, cfg, batch)
    s = batch["tokens"].shape[1]
    assert logits.shape == (2, s, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("name", ARCHS)
def test_train_step(name, key):
    cfg, params, stream = _setup(name, key)
    opt_init, train_step = make_train_step(cfg)
    opt = opt_init(params)
    p2, o2, m = jax.jit(train_step)(params, opt, stream.get_batch(0))
    assert np.isfinite(float(m["loss"]))
    # params actually changed
    delta = max(float(jnp.max(jnp.abs(a - b)))
                for a, b in zip(jax.tree.leaves(p2), jax.tree.leaves(params)))
    assert delta > 0


@pytest.mark.parametrize("name", ARCHS)
def test_decode_matches_teacher_forcing(name, key):
    """Serving-cache correctness: prefill + step-by-step decode reproduces
    the teacher-forced logits.  MoE archs run with no-drop capacity (token
    dropping legitimately differs between batch sizes; DESIGN §4)."""
    over = {"capacity_factor": 8.0} if get_config(name).n_experts else {}
    cfg, params, stream = _setup(name, key, **over)
    batch = stream.get_batch(0)
    s = batch["tokens"].shape[1]
    half = s // 2
    full_logits, _ = transformer.forward_train(params, cfg, batch)
    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, :half]
    logits, cache = transformer.prefill(params, cfg, pre, s_max=s + 4)
    np.testing.assert_allclose(np.asarray(logits),
                               np.asarray(full_logits[:, half - 1]),
                               rtol=2e-4, atol=2e-4)
    step = jax.jit(lambda c, t: transformer.decode_step(params, cfg, c, t))
    for t in range(half, min(half + 3, s)):
        logits, cache = step(cache, batch["tokens"][:, t])
        np.testing.assert_allclose(np.asarray(logits),
                                   np.asarray(full_logits[:, t]),
                                   rtol=2e-4, atol=2e-4)


def test_loss_learns():
    """End-to-end learnability: a tiny dense model fits the synthetic stream."""
    key = jax.random.PRNGKey(1)
    cfg = reduced(get_config("qwen3-0.6b"), n_layers=2, d_model=32, d_ff=64,
                  n_heads=2, n_kv=2, head_dim=16, vocab=64)
    params = transformer.init_params(key, cfg)
    stream = for_arch(cfg, batch=4, seq=32)
    opt_init, train_step = make_train_step(cfg, lr=1e-2)
    opt = opt_init(params)
    step = jax.jit(train_step)
    losses = []
    for i in range(30):
        params, opt, m = step(params, opt, stream.get_batch(i % 4))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.2, losses[::10]


def test_param_counts_match_assignment():
    """FULL configs land within 15% of their nameplate sizes (spot checks
    computed analytically -- no allocation)."""
    import math

    def analytic(cfg):
        d, hd = cfg.d_model, cfg.resolved_head_dim
        attn = (d * cfg.n_heads * hd + 2 * d * cfg.n_kv * hd
                + cfg.n_heads * hd * d) if cfg.n_heads else 0
        ffn_mult = 3 if cfg.gated_ffn else 2
        total = cfg.vocab * d * (1 if cfg.tie_embeddings else 2)
        kinds = list(cfg.block_pattern) * cfg.n_units + list(cfg.tail_pattern)
        if cfg.enc_layers:
            kinds += ["e"] * cfg.enc_layers
        for kind in kinds:
            if kind == "m":
                e = cfg.n_experts + (1 if cfg.shared_expert else 0)
                total += attn + e * ffn_mult * d * cfg.resolved_moe_dff
            elif kind == "s":
                d_in = cfg.ssm_expand * d
                n = cfg.ssm_state
                h = d_in // cfg.ssm_headdim
                total += d * (2 * d_in + 2 * n + h) + d_in * d
            elif kind == "r":
                r = cfg.resolved_rnn_width
                total += 2 * d * r + 2 * r * r + r * d + ffn_mult * d * cfg.d_ff
            elif kind == "d":
                total += 2 * attn + ffn_mult * d * cfg.d_ff
            else:
                total += attn + ffn_mult * d * cfg.d_ff
        return total

    expected = {
        "llama4-maverick-400b-a17b": 400e9,
        "qwen1.5-110b": 110e9,
        "llama-3.2-vision-90b": 90e9,
        "starcoder2-7b": 7e9,
        "qwen3-0.6b": 0.6e9,
        "gemma3-1b": 1e9,
        "mamba2-1.3b": 1.3e9,
        "recurrentgemma-2b": 2.5e9,
    }
    for name, want in expected.items():
        got = analytic(get_config(name))
        assert math.isclose(got, want, rel_tol=0.35), (name, got / 1e9)
