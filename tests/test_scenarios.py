"""Scenario registry + batched multi-cell engine (repro.core.scenarios)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import scenarios as sc
from repro.core import sweep
from repro.core.env import LAM_FIXED, MecConfig, step_p
from repro.core.lymdo import run_fixed_batched

_BIG = 1e29  # anything above this is an infeasible-cell sentinel

# shared across tests so each (params, state, cut) shape compiles once
_STEP = jax.jit(step_p)


def _cell(tree, b):
    return jax.tree.map(lambda x: x[b], tree)


# ---------------------------------------------------------------------------
# Registry round-trip
# ---------------------------------------------------------------------------

def test_registry_names_and_make():
    have = sc.names()
    for name in ("paper_table1", "fixed_rate", "peak_window", "hetero_fleet"):
        assert name in have
    s = sc.make("fixed_rate", rate=1.5)
    assert s.cfg.lam_mode == LAM_FIXED
    assert np.allclose(s.lam_fixed, 1.5)
    env = s.build()
    st = env.reset(jax.random.PRNGKey(0))
    _, res = env.step(st, jnp.zeros((s.n_ue,), jnp.int32))
    assert np.isfinite(float(res.reward))


def test_registry_matches_paper_env():
    """paper_table1 must reproduce paper_env()'s tables and constants."""
    from repro.core.env import paper_env
    p_reg = sc.make("paper_table1").params()
    p_env = paper_env().params
    for leaf_a, leaf_b in zip(jax.tree.leaves(p_reg), jax.tree.leaves(p_env)):
        np.testing.assert_array_equal(np.asarray(leaf_a), np.asarray(leaf_b))


def test_registry_unknown_and_duplicate():
    with pytest.raises(KeyError):
        sc.make("no_such_scenario")
    with pytest.raises(ValueError):
        @sc.register("paper_table1")
        def clash():  # pragma: no cover
            pass


def test_hetero_fleet_deterministic_in_seed():
    a = sc.make("hetero_fleet", n_ue=6, seed=3)
    b = sc.make("hetero_fleet", n_ue=6, seed=3)
    c = sc.make("hetero_fleet", n_ue=6, seed=4)
    assert a.e_budget == b.e_budget and a.lam_fixed == b.lam_fixed
    assert a.e_budget != c.e_budget or a.lam_fixed != c.lam_fixed


# ---------------------------------------------------------------------------
# Stacking
# ---------------------------------------------------------------------------

def test_stack_params_requires_common_n():
    p4 = sc.make("hetero_fleet", n_ue=4).params()
    p5 = sc.make("hetero_fleet", n_ue=5).params()
    with pytest.raises(ValueError):
        sc.stack_params([p4, p5])


def test_stack_params_pads_cut_axis():
    """Cells with different layer counts stack via edge-padding; padded cuts
    stay infeasible so they never win the argmin."""
    from repro.profiling.convnets import alexnet_profile, resnet18_profile
    alex = sc.Scenario(name="alex", cfg=MecConfig(lam_mode=LAM_FIXED),
                       profiles=(alexnet_profile(),) * 3,
                       e_budget=(0.04,) * 3, c_budget=(0.1,) * 3)
    res = sc.Scenario(name="res", cfg=MecConfig(lam_mode=LAM_FIXED),
                      profiles=(resnet18_profile(),) * 3,
                      e_budget=(0.06,) * 3, c_budget=(0.03,) * 3)
    pa, pr = alex.params(), res.params()
    assert pa.num_cuts != pr.num_cuts  # the padding path is exercised
    stacked = sc.stack_params([pa, pr])
    assert stacked.num_cuts == max(pa.num_cuts, pr.num_cuts)

    grid = sc.ScenarioGrid([alex, res])
    states = grid.reset(jax.random.PRNGKey(0))
    table = np.asarray(grid.objective_tables(states, backend="lax"))
    # every cut beyond a cell's L is infeasible
    L = np.asarray(stacked.L)
    cols = np.arange(stacked.num_cuts)[None, None, :]
    assert np.all(table[cols > L[:, :, None]] > _BIG)
    # narrow cell's step == its own unpadded step (padding is semantics-free)
    st_a = _cell(states, 0)
    cuts = jnp.full((3,), 5, jnp.int32)
    _, res_pad = _STEP(_cell(grid.params, 0), st_a, cuts)
    _, res_raw = _STEP(pa, st_a, cuts)
    np.testing.assert_allclose(np.asarray(res_pad.reward),
                               np.asarray(res_raw.reward), rtol=1e-6)


# ---------------------------------------------------------------------------
# Batched-vs-looped equivalence
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def grid():
    return sc.ScenarioGrid(sc.multicell_grid(cells=4, ues=4, seed=7))


@pytest.fixture(scope="module")
def states(grid):
    return grid.reset(jax.random.PRNGKey(42))


def test_batched_step_equals_per_cell_loop(grid, states):
    """vmap-over-cells step == per-cell loop to 1e-5 (results AND next state)."""
    key = jax.random.PRNGKey(9)
    cuts = jax.random.randint(key, (grid.b, grid.n_ue), 0, grid.num_cuts)
    nxt_b, res_b = jax.jit(grid.step)(states, cuts)
    for b in range(grid.b):
        nxt_1, res_1 = _STEP(_cell(grid.params, b), _cell(states, b), cuts[b])
        for a, ref in zip(jax.tree.leaves(res_b), jax.tree.leaves(res_1)):
            np.testing.assert_allclose(np.asarray(a)[b], np.asarray(ref),
                                       rtol=1e-5, atol=1e-7)
        for a, ref in zip(jax.tree.leaves(nxt_b), jax.tree.leaves(nxt_1)):
            np.testing.assert_allclose(np.asarray(a)[b], np.asarray(ref),
                                       rtol=1e-5, atol=1e-7)


def test_batched_oracle_equals_per_cell_oracle(grid, states):
    cuts_b = np.asarray(grid.oracle_cuts(states, backend="lax"))
    oracle_1 = jax.jit(sweep.oracle_cut_p)
    for b in range(grid.b):
        cut_1 = np.asarray(oracle_1(_cell(grid.params, b), _cell(states, b)))
        np.testing.assert_array_equal(cuts_b[b], cut_1)


def test_batched_rollout_runs_and_summarizes(grid):
    metrics, results = run_fixed_batched(grid, "oracle", episodes=1, steps=5)
    assert results.reward.shape == (5, grid.b)
    assert results.delay.shape == (5, grid.b, grid.n_ue)
    for name in ("reward", "delay", "energy", "q_energy_final"):
        assert metrics[name].shape == (grid.b,)
        assert np.all(np.isfinite(metrics[name]))
    assert np.all(metrics["delay"] > 0)


# ---------------------------------------------------------------------------
# Pallas kernel vs reference on a scenario-grid batch
# ---------------------------------------------------------------------------

def test_partition_sweep_pallas_matches_ref_on_grid(grid, states):
    tab_ref = np.asarray(grid.objective_tables(states, backend="ref"))
    tab_pal = np.asarray(
        grid.objective_tables(states, backend="pallas", interpret=True))
    tab_lax = np.asarray(grid.objective_tables(states, backend="lax"))
    # the ref backend IS the lax semantics, batched
    np.testing.assert_allclose(tab_ref, tab_lax, rtol=1e-6)
    feas = tab_ref < _BIG
    assert feas.any()
    np.testing.assert_allclose(tab_pal[feas], tab_ref[feas], rtol=2e-4)
    # infeasible cells agree exactly on the sentinel
    assert np.all(tab_pal[~feas] > _BIG)
    # and the argmin decisions (the Oracle) agree everywhere
    np.testing.assert_array_equal(tab_pal.argmin(-1), tab_ref.argmin(-1))


def test_objective_tables_mixed_scalars_rejects_kernel_route():
    cells = sc.multicell_grid(cells=2, ues=3, seed=0, uniform_scalars=False)
    g = sc.ScenarioGrid(cells)
    assert g.sweep_scalars is None
    states = g.reset(jax.random.PRNGKey(0))
    with pytest.raises(ValueError):
        g.objective_tables(states, backend="pallas", interpret=True)
    # but the lax route handles per-cell scalars fine
    table = g.objective_tables(states, backend="lax")
    assert np.isfinite(np.asarray(table)).all()
