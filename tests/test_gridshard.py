"""Device-sharded ScenarioGrid (repro.core.gridshard): placement, padding
mask, and sharded-vs-unsharded rollout parity.

Tier-1 runs these on one device (padding forced via ``pad_to``); CI adds a
forced-multi-device CPU leg (``XLA_FLAGS=--xla_force_host_platform_
device_count=8``) where the same tests exercise real 8-way partitioning,
including an uneven B=6 grid.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding

from repro.core import gridshard
from repro.core import scenarios as sc
from repro.core.lymdo import eval_policy_batched, run_fixed_batched
from repro.launch.mesh import make_cells_mesh

N_DEV = len(jax.devices())


def _forced_pad_to(b: int) -> int | None:
    """Padded width that guarantees pad > 0 on any device count."""
    natural = -(-b // N_DEV) * N_DEV
    return natural + N_DEV if natural == b else None


# ---------------------------------------------------------------------------
# Plan / pad / mask units
# ---------------------------------------------------------------------------

def test_plan_rounds_up_to_device_multiple():
    mesh = make_cells_mesh()
    gs = gridshard.plan(3 * N_DEV, mesh)
    assert gs.b_padded == 3 * N_DEV and gs.pad == 0
    gs = gridshard.plan(3 * N_DEV + 1, mesh)
    assert gs.b_padded == 4 * N_DEV
    assert gs.pad == N_DEV - 1
    assert gs.b_padded % gs.n_shards == 0


def test_plan_validates():
    mesh = make_cells_mesh()
    with pytest.raises(ValueError):
        gridshard.plan(2, mesh, axis="nope")
    with pytest.raises(ValueError):
        gridshard.plan(0, mesh)
    with pytest.raises(ValueError):           # pad_to below the natural width
        gridshard.plan(2, mesh, pad_to=1)
    with pytest.raises(ValueError):           # b_padded < b
        gridshard.GridSharding(mesh=mesh, b=2 * N_DEV, b_padded=N_DEV)


def test_pad_unpad_roundtrip_and_mask():
    mesh = make_cells_mesh()
    b_padded = (-(-3 // N_DEV) + 1) * N_DEV   # one extra shard of padding
    gs = gridshard.GridSharding(mesh=mesh, b=3, b_padded=b_padded)
    pad = gs.pad
    assert pad > 0
    tree = {"a": jnp.arange(6.0).reshape(3, 2), "b": jnp.arange(3)}
    padded = gridshard.pad_cells(tree, gs)
    assert padded["a"].shape == (b_padded, 2)
    # edge replication: padded cells copy the last real cell
    np.testing.assert_array_equal(np.asarray(padded["a"][3:]),
                                  np.tile(np.asarray(tree["a"][2:]),
                                          (pad, 1)))
    back = gridshard.unpad(padded, gs)
    for k in tree:
        np.testing.assert_array_equal(np.asarray(back[k]),
                                      np.asarray(tree[k]))
    mask = np.asarray(gs.mask())
    assert mask.shape == (b_padded,)
    assert mask[:3].all() and not mask[3:].any()


def test_scalar_leaves_replicate_through_pad_place_unpad():
    """0-d riders in a pytree must not crash the layout helpers (the same
    bug class batch_shardings had): they replicate and pass through."""
    mesh = make_cells_mesh()
    gs = gridshard.GridSharding(mesh=mesh, b=2, b_padded=2 * N_DEV)
    assert gs.spec(0) == gridshard.P()
    tree = {"vec": jnp.arange(2.0), "scalar": jnp.float32(3.5)}
    padded = gridshard.pad_cells(tree, gs)
    assert padded["scalar"].ndim == 0
    placed = gridshard.place(padded, gs)
    assert placed["scalar"].sharding.spec == ()
    constrained = gridshard.constrain(placed, gs)
    back = gridshard.unpad(constrained, gs)
    assert float(back["scalar"]) == 3.5
    np.testing.assert_array_equal(np.asarray(back["vec"]),
                                  np.asarray(tree["vec"]))


def test_cell_keys_independent_of_padding():
    key = jax.random.PRNGKey(7)
    k_plain = jax.random.key_data(gridshard.cell_keys(key, 5))
    k_pad = jax.random.key_data(gridshard.cell_keys(key, 5, 5 + N_DEV))
    np.testing.assert_array_equal(np.asarray(k_pad[:5]), np.asarray(k_plain))
    # padded slots clamp to the last real cell's key
    np.testing.assert_array_equal(
        np.asarray(k_pad[5:]), np.tile(np.asarray(k_plain[4:5]), (N_DEV, 1)))


# ---------------------------------------------------------------------------
# Grid placement
# ---------------------------------------------------------------------------

def _grid_pair(b: int, pad_to=None, ues: int = 3, seed: int = 5):
    cells = sc.multicell_grid(cells=b, ues=ues, seed=seed)
    plain = sc.ScenarioGrid(cells)
    shard = sc.ScenarioGrid(cells).use_mesh(make_cells_mesh(), pad_to=pad_to)
    return plain, shard


def test_use_mesh_places_params_on_cells_axis():
    _, g = _grid_pair(3, pad_to=_forced_pad_to(3))
    gs = g.gridshard
    assert gs is not None and g.b_run == gs.b_padded >= g.b
    for leaf in jax.tree.leaves(g._run_params):
        assert leaf.shape[0] == g.b_run
        assert isinstance(leaf.sharding, NamedSharding)
        assert leaf.sharding.spec[0] == "cells"
    # the logical stack is untouched
    assert g.params.L.shape[0] == g.b


def test_params_for_rejects_unknown_width():
    _, g = _grid_pair(3, pad_to=_forced_pad_to(3))
    states = g.reset(jax.random.PRNGKey(0))
    assert states.t.shape[0] == g.b_run
    bad = jax.tree.map(lambda x: jnp.concatenate([x, x]), states)
    with pytest.raises(ValueError):
        g.step(bad, jnp.zeros((2 * g.b_run, g.n_ue), jnp.int32))


def test_objective_tables_on_padded_states():
    _, g = _grid_pair(4, pad_to=_forced_pad_to(4))
    states = g.reset(jax.random.PRNGKey(1))
    table = np.asarray(g.objective_tables(states, backend="lax"))
    assert table.shape == (g.b_run, g.n_ue, g.num_cuts)
    assert np.isfinite(table[table < 1e29]).all()


# ---------------------------------------------------------------------------
# Sharded == unsharded parity (the 1e-5 contract)
# ---------------------------------------------------------------------------

def _assert_parity(b: int, pad_to, policy: str, steps: int = 12):
    g_plain, g_shard = _grid_pair(b, pad_to=pad_to)
    st_p, res_p, sum_p = g_plain.rollout(policy, steps=steps, seed=3)
    st_s, res_s, sum_s = g_shard.rollout(policy, steps=steps, seed=3)
    assert set(sum_p) == set(sum_s)
    for name in sum_p:
        assert np.asarray(sum_s[name]).shape == (b,)
        np.testing.assert_allclose(np.asarray(sum_s[name]),
                                   np.asarray(sum_p[name]),
                                   rtol=1e-5, atol=1e-7, err_msg=name)
    for got, want in zip(jax.tree.leaves(res_s), jax.tree.leaves(res_p)):
        assert got.shape == want.shape     # logical B, padding sliced off
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-7)
    for got, want in zip(jax.tree.leaves(st_s), jax.tree.leaves(st_p)):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-7)
    return g_shard


def test_sharded_parity_even_b():
    b = max(2, N_DEV)                     # a device multiple: no padding
    g = _assert_parity(b, None, "oracle")
    assert g.gridshard.pad == 0


def test_sharded_parity_uneven_b_exercises_padding():
    b = 6                                 # uneven on 8 (and forced elsewhere)
    g = _assert_parity(b, _forced_pad_to(b), "oracle")
    assert g.gridshard.pad > 0


def test_sharded_parity_random_policy():
    b = 5
    g = _assert_parity(b, _forced_pad_to(b), "random")
    assert g.gridshard.pad > 0


# ---------------------------------------------------------------------------
# Batched runners accept the sharded path transparently
# ---------------------------------------------------------------------------

def test_run_fixed_batched_transparent():
    g_plain, g_shard = _grid_pair(3, pad_to=_forced_pad_to(3))
    m_p, r_p = run_fixed_batched(g_plain, "local", episodes=2, steps=8,
                                 seed=11)
    m_s, r_s = run_fixed_batched(g_shard, "local", episodes=2, steps=8,
                                 seed=11)
    for name in m_p:
        assert m_s[name].shape == (g_plain.b,)
        np.testing.assert_allclose(m_s[name], m_p[name],
                                   rtol=1e-5, atol=1e-7, err_msg=name)
    np.testing.assert_allclose(np.asarray(r_s.delay), np.asarray(r_p.delay),
                               rtol=1e-5, atol=1e-7)


def test_eval_policy_batched_transparent():
    from repro.core.policies import GaussianTanhPolicy
    from repro.core.ppo import PPO, PPOConfig

    rates = (1.0, 1.5, 2.0)
    g_plain = sc.grid_from_names([("fixed_rate", {"rate": r})
                                  for r in rates])
    g_shard = sc.grid_from_names([("fixed_rate", {"rate": r})
                                  for r in rates])
    g_shard.use_mesh(make_cells_mesh(), pad_to=_forced_pad_to(g_shard.b))
    env = g_plain.scenarios[0].build()
    pol = GaussianTanhPolicy(env.obs_dim, env.L)
    agent = PPO(pol, env.obs_dim, PPOConfig())
    state = agent.init(jax.random.PRNGKey(0))
    m_p, _ = eval_policy_batched(g_plain, agent, state, episodes=1, steps=6)
    m_s, _ = eval_policy_batched(g_shard, agent, state, episodes=1, steps=6)
    for name in m_p:
        np.testing.assert_allclose(m_s[name], m_p[name],
                                   rtol=1e-5, atol=1e-7, err_msg=name)
