"""Device-sharded ScenarioGrid (repro.core.gridshard): placement, padding
mask, and sharded-vs-unsharded rollout parity.

Tier-1 runs these on one device (padding forced via ``pad_to``); CI adds a
forced-multi-device CPU leg (``XLA_FLAGS=--xla_force_host_platform_
device_count=8``) where the same tests exercise real 8-way partitioning --
including an uneven B=6 grid and, through the ``model`` parametrizations,
the 2-D ``("cells", "model")`` mesh with per-cell tensor parallelism
(``model ∈ {1, 2, 4}``; degrees not dividing the device count skip).

The parity suite iterates the ENTIRE scenario registry: a newly registered
scenario is covered automatically (every constructor must build with zero
args -- see docs/scenarios.md), at an uneven B so the padding path always
runs.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding

from _hypothesis_compat import given, settings, st

from repro.core import gridshard
from repro.core import scenarios as sc
from repro.core.lymdo import eval_policy_batched, run_fixed_batched
from repro.launch.mesh import make_cells_mesh

N_DEV = len(jax.devices())

# Per-cell tensor-parallel degrees; a degree that does not divide the live
# device count cannot build its ("cells", "model") mesh and skips (tier-1's
# single device runs model=1 only).  The CI forced-8-device matrix narrows
# each leg to ONE degree via REPRO_MODEL_DEGREES so the legs split the work
# instead of triple-running it.
MODEL_DEGREES = [
    pytest.param(m, marks=pytest.mark.skipif(
        N_DEV % m != 0, reason=f"model={m} needs a device count "
                               f"divisible by it (have {N_DEV})"))
    for m in (int(x) for x in
              os.environ.get("REPRO_MODEL_DEGREES", "1,2,4").split(","))
]


def _forced_pad_to(b: int, shards: int = N_DEV) -> int | None:
    """Padded width that guarantees pad > 0 on any cell-shard count."""
    natural = -(-b // shards) * shards
    return natural + shards if natural == b else None


# ---------------------------------------------------------------------------
# Plan / pad / mask units
# ---------------------------------------------------------------------------

def test_plan_rounds_up_to_device_multiple():
    mesh = make_cells_mesh()
    gs = gridshard.plan(3 * N_DEV, mesh)
    assert gs.b_padded == 3 * N_DEV and gs.pad == 0
    gs = gridshard.plan(3 * N_DEV + 1, mesh)
    assert gs.b_padded == 4 * N_DEV
    assert gs.pad == N_DEV - 1
    assert gs.b_padded % gs.n_shards == 0


def test_plan_validates():
    mesh = make_cells_mesh()
    with pytest.raises(ValueError):
        gridshard.plan(2, mesh, axis="nope")
    with pytest.raises(ValueError):
        gridshard.plan(0, mesh)
    with pytest.raises(ValueError):           # pad_to below the natural width
        gridshard.plan(2, mesh, pad_to=1)
    with pytest.raises(ValueError):           # b_padded < b
        gridshard.GridSharding(mesh=mesh, b=2 * N_DEV, b_padded=N_DEV)


def test_pad_unpad_roundtrip_and_mask():
    mesh = make_cells_mesh()
    b_padded = (-(-3 // N_DEV) + 1) * N_DEV   # one extra shard of padding
    gs = gridshard.GridSharding(mesh=mesh, b=3, b_padded=b_padded)
    pad = gs.pad
    assert pad > 0
    tree = {"a": jnp.arange(6.0).reshape(3, 2), "b": jnp.arange(3)}
    padded = gridshard.pad_cells(tree, gs)
    assert padded["a"].shape == (b_padded, 2)
    # edge replication: padded cells copy the last real cell
    np.testing.assert_array_equal(np.asarray(padded["a"][3:]),
                                  np.tile(np.asarray(tree["a"][2:]),
                                          (pad, 1)))
    back = gridshard.unpad(padded, gs)
    for k in tree:
        np.testing.assert_array_equal(np.asarray(back[k]),
                                      np.asarray(tree[k]))
    mask = np.asarray(gs.mask())
    assert mask.shape == (b_padded,)
    assert mask[:3].all() and not mask[3:].any()


def test_scalar_leaves_replicate_through_pad_place_unpad():
    """0-d riders in a pytree must not crash the layout helpers (the same
    bug class batch_shardings had): they replicate and pass through."""
    mesh = make_cells_mesh()
    gs = gridshard.GridSharding(mesh=mesh, b=2, b_padded=2 * N_DEV)
    assert gs.spec(0) == gridshard.P()
    tree = {"vec": jnp.arange(2.0), "scalar": jnp.float32(3.5)}
    padded = gridshard.pad_cells(tree, gs)
    assert padded["scalar"].ndim == 0
    placed = gridshard.place(padded, gs)
    assert placed["scalar"].sharding.spec == ()
    constrained = gridshard.constrain(placed, gs)
    back = gridshard.unpad(constrained, gs)
    assert float(back["scalar"]) == 3.5
    np.testing.assert_array_equal(np.asarray(back["vec"]),
                                  np.asarray(tree["vec"]))


def test_cell_keys_independent_of_padding():
    key = jax.random.PRNGKey(7)
    k_plain = jax.random.key_data(gridshard.cell_keys(key, 5))
    k_pad = jax.random.key_data(gridshard.cell_keys(key, 5, 5 + N_DEV))
    np.testing.assert_array_equal(np.asarray(k_pad[:5]), np.asarray(k_plain))
    # padded slots clamp to the last real cell's key
    np.testing.assert_array_equal(
        np.asarray(k_pad[5:]), np.tile(np.asarray(k_plain[4:5]), (N_DEV, 1)))


# ---------------------------------------------------------------------------
# Grid placement
# ---------------------------------------------------------------------------

def _grid_pair(b: int, pad_to=None, ues: int = 3, seed: int = 5):
    cells = sc.multicell_grid(cells=b, ues=ues, seed=seed)
    plain = sc.ScenarioGrid(cells)
    shard = sc.ScenarioGrid(cells).use_mesh(make_cells_mesh(), pad_to=pad_to)
    return plain, shard


def test_use_mesh_places_params_on_cells_axis():
    _, g = _grid_pair(3, pad_to=_forced_pad_to(3))
    gs = g.gridshard
    assert gs is not None and g.b_run == gs.b_padded >= g.b
    for leaf in jax.tree.leaves(g._run_params):
        assert leaf.shape[0] == g.b_run
        assert isinstance(leaf.sharding, NamedSharding)
        assert leaf.sharding.spec[0] == "cells"
    # the logical stack is untouched
    assert g.params.L.shape[0] == g.b


def test_params_for_rejects_unknown_width():
    _, g = _grid_pair(3, pad_to=_forced_pad_to(3))
    states = g.reset(jax.random.PRNGKey(0))
    assert states.t.shape[0] == g.b_run
    bad = jax.tree.map(lambda x: jnp.concatenate([x, x]), states)
    with pytest.raises(ValueError):
        g.step(bad, jnp.zeros((2 * g.b_run, g.n_ue), jnp.int32))


def test_objective_tables_on_padded_states():
    _, g = _grid_pair(4, pad_to=_forced_pad_to(4))
    states = g.reset(jax.random.PRNGKey(1))
    table = np.asarray(g.objective_tables(states, backend="lax"))
    assert table.shape == (g.b_run, g.n_ue, g.num_cuts)
    assert np.isfinite(table[table < 1e29]).all()


# ---------------------------------------------------------------------------
# Sharded == unsharded parity (the 1e-5 contract)
# ---------------------------------------------------------------------------

def _assert_parity(b: int, pad_to, policy: str, steps: int = 12):
    g_plain, g_shard = _grid_pair(b, pad_to=pad_to)
    st_p, res_p, sum_p = g_plain.rollout(policy, steps=steps, seed=3)
    st_s, res_s, sum_s = g_shard.rollout(policy, steps=steps, seed=3)
    assert set(sum_p) == set(sum_s)
    for name in sum_p:
        assert np.asarray(sum_s[name]).shape == (b,)
        np.testing.assert_allclose(np.asarray(sum_s[name]),
                                   np.asarray(sum_p[name]),
                                   rtol=1e-5, atol=1e-7, err_msg=name)
    for got, want in zip(jax.tree.leaves(res_s), jax.tree.leaves(res_p)):
        assert got.shape == want.shape     # logical B, padding sliced off
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-7)
    for got, want in zip(jax.tree.leaves(st_s), jax.tree.leaves(st_p)):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-7)
    return g_shard


def test_sharded_parity_even_b():
    b = max(2, N_DEV)                     # a device multiple: no padding
    g = _assert_parity(b, None, "oracle")
    assert g.gridshard.pad == 0


def test_sharded_parity_uneven_b_exercises_padding():
    b = 6                                 # uneven on 8 (and forced elsewhere)
    g = _assert_parity(b, _forced_pad_to(b), "oracle")
    assert g.gridshard.pad > 0


def test_sharded_parity_random_policy():
    b = 5
    g = _assert_parity(b, _forced_pad_to(b), "random")
    assert g.gridshard.pad > 0


# ---------------------------------------------------------------------------
# Mesh construction validates up front (no opaque XLA errors)
# ---------------------------------------------------------------------------

def test_make_cells_mesh_validates_device_count():
    with pytest.raises(ValueError, match="force_host_platform_device_count"):
        make_cells_mesh(2 * N_DEV)
    with pytest.raises(ValueError, match="at least one device"):
        make_cells_mesh(0)


def test_make_cells_mesh_validates_model_axis():
    with pytest.raises(ValueError, match="does not divide"):
        make_cells_mesh(N_DEV, model=3 * N_DEV)
    with pytest.raises(ValueError, match="model axis size"):
        make_cells_mesh(N_DEV, model=0)


def test_use_mesh_rejects_model_mesh_mismatch():
    cells = sc.multicell_grid(cells=2, ues=3, seed=0)
    with pytest.raises(ValueError, match="model"):
        sc.ScenarioGrid(cells).use_mesh(make_cells_mesh(), model=2 * N_DEV)


@pytest.mark.parametrize("model", MODEL_DEGREES)
def test_use_mesh_model_places_2d(model):
    """use_mesh(model=M) builds the ("cells","model") mesh itself and the
    plan records the per-cell TP degree; params leaves whose post-cell dim
    divides M shard over the model axis, the rest replicate across it."""
    cells = sc.multicell_grid(cells=3, ues=4, seed=5)
    g = sc.ScenarioGrid(cells).use_mesh(model=model)
    gs = g.gridshard
    assert gs.n_model == model
    assert gs.n_shards == N_DEV // model
    for leaf in jax.tree.leaves(g._run_params):
        assert leaf.shape[0] == g.b_run
        spec = leaf.sharding.spec
        assert spec[0] == "cells"
        if model > 1 and leaf.ndim > 1 and leaf.shape[1] % model == 0:
            assert spec[1] == "model", leaf.shape
    if model > 1:
        # the N=4 UE axis divides every tested degree: TP is actually on
        n_specs = [leaf.sharding.spec for leaf in
                   jax.tree.leaves(g._run_params) if leaf.ndim > 1]
        assert any(s[1] == "model" for s in n_specs)


# ---------------------------------------------------------------------------
# Registry-wide sharded parity: EVERY registered scenario, any model degree
# ---------------------------------------------------------------------------

_REG_STEPS = 6
_REG_B = 3                     # uneven on most shard counts -> padding runs
_plain_summaries: dict = {}


def _registry_cells(name: str):
    """B zero-arg cells of one registered scenario (per-cell randomness
    still differs through the grid's fold_in key discipline)."""
    return [sc.make(name) for _ in range(_REG_B)]


def _plain_summary(name: str):
    if name not in _plain_summaries:
        g = sc.ScenarioGrid(_registry_cells(name))
        _, _, summary = g.rollout("oracle", steps=_REG_STEPS, seed=3)
        _plain_summaries[name] = {k: np.asarray(v)
                                  for k, v in summary.items()}
    return _plain_summaries[name]


@pytest.mark.parametrize("model", MODEL_DEGREES)
@pytest.mark.parametrize("name", sc.names())
def test_registry_sharded_parity(name, model):
    """sharded(cells, model) == unsharded to 1e-5 for every registered
    scenario, uneven-B padding included -- the headline model-axis
    guarantee.  Registering a new scenario extends this suite for free."""
    mesh = make_cells_mesh(model=model)
    shards = N_DEV // model
    g = sc.ScenarioGrid(_registry_cells(name)).use_mesh(
        mesh, pad_to=_forced_pad_to(_REG_B, shards))
    assert g.gridshard.pad > 0          # the padding path always exercised
    assert g.gridshard.n_model == model
    _, _, summary = g.rollout("oracle", steps=_REG_STEPS, seed=3)
    want = _plain_summary(name)
    assert set(summary) == set(want)
    for key in want:
        got = np.asarray(summary[key])
        assert got.shape == (_REG_B,)
        np.testing.assert_allclose(got, want[key], rtol=1e-5, atol=1e-7,
                                   err_msg=f"{name}[{key}] model={model}")


def test_registry_constructors_build_with_zero_args():
    """The contract the registry-wide suite relies on: every registered
    constructor yields a Scenario with no arguments."""
    for name in sc.names():
        cell = sc.make(name)
        assert isinstance(cell, sc.Scenario), name
        assert cell.n_ue >= 1, name


# ---------------------------------------------------------------------------
# Layout round-trip property (hypothesis; fixed-examples shim on bare envs)
# ---------------------------------------------------------------------------

class TestLayoutRoundTrip:
    """pad_cells -> place -> unpad is the identity and the validity mask is
    padding-invariant, for arbitrary (B, cells, model) leaf shapes."""

    @pytest.mark.parametrize("model", MODEL_DEGREES)
    @given(b=st.integers(1, 9), extra=st.integers(0, 2),
           k=st.integers(1, 6))
    @settings(max_examples=12, deadline=None)
    def test_pad_place_unpad_identity(self, model, b, extra, k):
        mesh = make_cells_mesh(model=model)
        shards = N_DEV // model
        natural = -(-b // shards) * shards
        gs = gridshard.plan(b, mesh, pad_to=natural + extra * shards)
        rng = np.random.default_rng(b * 100 + extra * 10 + k)
        tree = {
            "vec": jnp.asarray(rng.normal(size=(b,)).astype(np.float32)),
            "mat": jnp.asarray(rng.normal(size=(b, k)).astype(np.float32)),
            "cube": jnp.asarray(
                rng.normal(size=(b, k, 3)).astype(np.float32)),
            "scalar": jnp.float32(1.5),
        }
        placed = gridshard.place(gridshard.pad_cells(tree, gs), gs)
        for key, leaf in placed.items():
            if leaf.ndim:
                assert leaf.shape[0] == gs.b_padded, key
        back = gridshard.unpad(placed, gs)
        for key in tree:
            np.testing.assert_array_equal(np.asarray(back[key]),
                                          np.asarray(tree[key]), err_msg=key)
        mask = np.asarray(gs.mask())
        assert mask.sum() == b and mask[:b].all()

    @pytest.mark.parametrize("model", MODEL_DEGREES)
    @given(b=st.integers(1, 6), extra=st.integers(1, 3))
    @settings(max_examples=8, deadline=None)
    def test_mask_is_padding_invariant(self, model, b, extra):
        """The first b mask entries are True at ANY padded width: widening
        the pad never flips a real cell's validity."""
        mesh = make_cells_mesh(model=model)
        shards = N_DEV // model
        natural = -(-b // shards) * shards
        narrow = gridshard.plan(b, mesh)
        wide = gridshard.plan(b, mesh, pad_to=natural + extra * shards)
        m_n, m_w = np.asarray(narrow.mask()), np.asarray(wide.mask())
        np.testing.assert_array_equal(m_w[:len(m_n)][:b], m_n[:b])
        assert m_n.sum() == m_w.sum() == b
        assert not m_w[b:].any()


# ---------------------------------------------------------------------------
# Batched runners accept the sharded path transparently
# ---------------------------------------------------------------------------

def test_run_fixed_batched_transparent():
    g_plain, g_shard = _grid_pair(3, pad_to=_forced_pad_to(3))
    m_p, r_p = run_fixed_batched(g_plain, "local", episodes=2, steps=8,
                                 seed=11)
    m_s, r_s = run_fixed_batched(g_shard, "local", episodes=2, steps=8,
                                 seed=11)
    for name in m_p:
        assert m_s[name].shape == (g_plain.b,)
        np.testing.assert_allclose(m_s[name], m_p[name],
                                   rtol=1e-5, atol=1e-7, err_msg=name)
    np.testing.assert_allclose(np.asarray(r_s.delay), np.asarray(r_p.delay),
                               rtol=1e-5, atol=1e-7)


def test_eval_policy_batched_transparent():
    from repro.core.policies import GaussianTanhPolicy
    from repro.core.ppo import PPO, PPOConfig

    rates = (1.0, 1.5, 2.0)
    g_plain = sc.grid_from_names([("fixed_rate", {"rate": r})
                                  for r in rates])
    g_shard = sc.grid_from_names([("fixed_rate", {"rate": r})
                                  for r in rates])
    g_shard.use_mesh(make_cells_mesh(), pad_to=_forced_pad_to(g_shard.b))
    env = g_plain.scenarios[0].build()
    pol = GaussianTanhPolicy(env.obs_dim, env.L)
    agent = PPO(pol, env.obs_dim, PPOConfig())
    state = agent.init(jax.random.PRNGKey(0))
    m_p, _ = eval_policy_batched(g_plain, agent, state, episodes=1, steps=6)
    m_s, _ = eval_policy_batched(g_shard, agent, state, episodes=1, steps=6)
    for name in m_p:
        np.testing.assert_allclose(m_s[name], m_p[name],
                                   rtol=1e-5, atol=1e-7, err_msg=name)
