"""Tests for the static-analysis subsystem (repro.analysis).

Layer 1: per-rule fixture snippets -- positive, suppressed, and baseline
paths -- through ``lint_source``/``lint_paths`` and the CLI entry point.
Layer 2: the eval_shape contract sweep pinned over the FULL config
registry, and the retrace probes.
"""
import json
import textwrap

import jax
import pytest

from repro.analysis import findings as F
from repro.analysis.__main__ import main as cli_main
from repro.analysis.linter import apply_baseline, lint_paths, lint_source


def lint(src, rules=None, path="fixture.py"):
    return lint_source(textwrap.dedent(src), path, rules=rules)


def rules_of(found):
    return [f.rule for f in found]


# ---------------------------------------------------------------------------
# key-reuse
# ---------------------------------------------------------------------------

KEY_REUSE_POSITIVE = """
    import jax

    def bad():
        key = jax.random.PRNGKey(0)
        a = jax.random.normal(key, (4,))
        b = jax.random.normal(key, (4,))
        return a + b
"""


def test_key_reuse_positive():
    found = lint(KEY_REUSE_POSITIVE)
    assert rules_of(found) == ["key-reuse"]
    assert "'key' reused" in found[0].message


def test_key_reuse_split_is_clean():
    found = lint("""
        import jax

        def good():
            key = jax.random.PRNGKey(0)
            k1, k2 = jax.random.split(key)
            return jax.random.normal(k1, (4,)) + jax.random.normal(k2, (4,))
    """)
    assert found == []


def test_key_reuse_fold_in_does_not_consume():
    found = lint("""
        import jax

        def good(key):
            key = jax.random.PRNGKey(0)
            ks = [jax.random.fold_in(key, i) for i in range(3)]
            return jax.random.normal(jax.random.fold_in(key, 9), (2,))
    """)
    assert found == []


def test_key_reuse_split_array_element():
    found = lint("""
        import jax

        def bad():
            ks = jax.random.split(jax.random.PRNGKey(0), 4)
            a = jax.random.normal(ks[0], (4,))
            b = jax.random.normal(ks[1], (4,))
            c = jax.random.normal(ks[0], (4,))
            return a, b, c
    """)
    assert rules_of(found) == ["key-reuse"]
    assert "ks[0]" in found[0].message


def test_key_reuse_cross_iteration():
    # consuming the same key every loop pass (no re-split) is reuse
    found = lint("""
        import jax

        def bad(key):
            key = jax.random.PRNGKey(0)
            out = []
            for i in range(3):
                out.append(jax.random.normal(key, (2,)))
            return out
    """)
    assert "key-reuse" in rules_of(found)


def test_key_reuse_loop_resplit_is_clean():
    found = lint("""
        import jax

        def good():
            key = jax.random.PRNGKey(0)
            out = []
            for i in range(3):
                key, k = jax.random.split(key)
                out.append(jax.random.normal(k, (2,)))
            return out
    """)
    assert found == []


def test_key_reuse_suppressed():
    found = lint("""
        import jax

        def warm():
            key = jax.random.PRNGKey(0)
            a = jax.random.normal(key, (4,))
            b = jax.random.normal(key, (4,))  # reprolint: ignore[key-reuse]
            return a, b
    """)
    assert found == []


# ---------------------------------------------------------------------------
# jit-branch
# ---------------------------------------------------------------------------

JIT_BRANCH_POSITIVE = """
    import jax

    @jax.jit
    def bad(x):
        if x > 0:
            return x
        return -x
"""


def test_jit_branch_positive():
    found = lint(JIT_BRANCH_POSITIVE)
    assert rules_of(found) == ["jit-branch"]


def test_jit_branch_shape_and_none_are_static():
    found = lint("""
        import jax

        @jax.jit
        def good(x, mask):
            if x.shape[0] > 4:
                x = x[:4]
            if mask is None:
                return x
            if len(x.shape) == 2:
                return x * mask
            return x
    """)
    assert found == []


def test_jit_branch_static_argnames_excluded():
    found = lint("""
        import jax
        from functools import partial

        @partial(jax.jit, static_argnames="n")
        def good(x, n):
            if n > 4:
                return x[:4]
            return x
    """)
    assert found == []


def test_jit_branch_wrapped_local_def():
    found = lint("""
        import jax

        def make():
            def step(x):
                while x < 3:
                    x = x + 1
                return x
            return jax.jit(step)
    """)
    assert rules_of(found) == ["jit-branch"]


def test_jit_branch_taint_flows_through_assignment():
    found = lint("""
        import jax

        @jax.jit
        def bad(x):
            y = x * 2
            if y > 1:
                return y
            return -y
    """)
    assert rules_of(found) == ["jit-branch"]


# ---------------------------------------------------------------------------
# recompile-hazard
# ---------------------------------------------------------------------------

def test_recompile_inline_jit_call():
    found = lint("""
        import jax

        def bad(x):
            return jax.jit(lambda v: v * 2)(x)
    """)
    assert rules_of(found) == ["recompile-hazard"]
    assert "inline" in found[0].message


def test_recompile_jit_in_loop():
    found = lint("""
        import jax

        def bad(fns, x):
            outs = []
            for f in fns:
                g = jax.jit(f)
                outs.append(g(x))
            return outs
    """)
    assert "recompile-hazard" in rules_of(found)


def test_recompile_unhashable_static_argnums():
    found = lint("""
        import jax

        def f(x, n):
            return x[:n]

        g = jax.jit(f, static_argnums=[1])
    """)
    assert rules_of(found) == ["recompile-hazard"]
    assert "unhashable" in found[0].message


def test_recompile_shape_varying_call_site():
    found = lint("""
        import jax
        import numpy as np

        run = jax.jit(lambda t: t.sum())

        def bad(prompt, width):
            toks = np.pad(prompt, (width - len(prompt), 0))
            return run(toks)
    """)
    assert rules_of(found) == ["recompile-hazard"]
    assert "shape-varying" in found[0].message


def test_recompile_bucketing_helper_exempt():
    found = lint("""
        import jax
        import numpy as np

        run = jax.jit(lambda t: t.sum())

        def _bucket_width(n):
            return max(8, 1 << (n - 1).bit_length())

        def good(prompt):
            width = _bucket_width(len(prompt))
            toks = np.pad(prompt, (width - len(prompt), 0))
            return run(toks)
    """)
    assert found == []


# ---------------------------------------------------------------------------
# host-sync
# ---------------------------------------------------------------------------

HOST_SYNC_POSITIVE = """
    import jax
    import numpy as np

    step = jax.jit(lambda s: s * 2)

    def serve(state, n):
        for _ in range(n):
            state = step(state)
            print(float(state))
        return state
"""


def test_host_sync_positive():
    found = lint(HOST_SYNC_POSITIVE)
    assert rules_of(found) == ["host-sync"]


def test_host_sync_suppressed():
    found = lint("""
        import jax

        step = jax.jit(lambda s: s * 2)

        def serve(state, n):
            for _ in range(n):
                state = step(state)
                print(float(state))  # reprolint: ignore[host-sync]
            return state
    """)
    assert found == []


def test_host_sync_engine_hot_zone_by_path():
    # the configured hot zone applies by file path + function name, no
    # loop required
    found = lint("""
        import jax.numpy as jnp
        import numpy as np

        class Engine:
            def _step_continuous(self):
                logits = self._decode()
                return np.asarray(jnp.argmax(logits, -1))
    """, path="src/repro/serving/engine.py")
    assert rules_of(found) == ["host-sync"]


def test_host_sync_host_data_is_clean():
    found = lint("""
        import numpy as np

        def drive(reqs, n):
            for _ in range(n):
                counts = np.asarray([len(r) for r in reqs])
                print(float(counts.sum()))
            return reqs
    """)
    assert found == []


def test_host_sync_obs_hot_zone_near_miss():
    # the telemetry read sites (repro/obs/enginehooks.py) are hot zones by
    # path: a gauge that "reads" a device value via float() IS a
    # device->host sync in the tick path and must be flagged ...
    found = lint("""
        import jax.numpy as jnp

        class EngineHooks:
            def on_decode_tick(self, engine, t0_us, live):
                toks = jnp.argmax(engine.last_logits, -1)
                self.tokens_gauge.set(float(toks[0]))
    """, path="src/repro/obs/enginehooks.py")
    assert rules_of(found) == ["host-sync"]


def test_host_sync_obs_hot_zone_host_reads_clean():
    # ... while the contract pattern -- reading host state the engine
    # already materialized (numpy rows, queue lengths, free lists) --
    # lints clean in the same function
    found = lint("""
        class EngineHooks:
            def on_decode_tick(self, engine, t0_us, live):
                self.decode_ticks.inc(engine.decode_steps)

            def sample(self, engine):
                self.queue_depth.set(len(engine.queue))
                self.pool_free.set(engine.allocator.n_free)
    """, path="src/repro/obs/enginehooks.py")
    assert found == []


def test_host_sync_real_obs_module_is_lint_clean():
    # the shipped telemetry hooks must satisfy their own contract with no
    # suppressions and no baseline entries
    found = lint_paths(paths=["src/repro/obs"])
    assert found == [], [f"{f.path}:{f.line} {f.rule}: {f.message}"
                         for f in found]


# ---------------------------------------------------------------------------
# pallas-wrapper
# ---------------------------------------------------------------------------

def test_pallas_wrapper_direct_kernel_import():
    found = lint("""
        from repro.kernels.flash_attention import flash_attention_pallas
    """, path="src/repro/serving/engine.py")
    assert rules_of(found) == ["pallas-wrapper"]


def test_pallas_wrapper_direct_pallas_import():
    found = lint("""
        from jax.experimental import pallas as pl
    """, path="src/repro/core/sweep.py")
    assert rules_of(found) == ["pallas-wrapper"]


def test_pallas_wrapper_ops_and_ref_allowed():
    found = lint("""
        from repro.kernels.ops import flash_attention
        from repro.kernels.ref import attention_ref
    """, path="src/repro/core/sweep.py")
    assert found == []


def test_pallas_wrapper_inside_kernels_allowed():
    found = lint("""
        from jax.experimental import pallas as pl
        from .flash_attention import flash_attention_pallas
    """, path="src/repro/kernels/ops.py")
    assert found == []


# ---------------------------------------------------------------------------
# baseline workflow + fingerprints
# ---------------------------------------------------------------------------

def test_baseline_roundtrip(tmp_path):
    fixture = tmp_path / "fx.py"
    fixture.write_text(textwrap.dedent(KEY_REUSE_POSITIVE))
    found = lint_paths(paths=[str(fixture)], root=tmp_path)
    assert len(found) == 1

    baseline = tmp_path / "baseline.json"
    F.write_baseline(baseline, found)
    data = json.loads(baseline.read_text())
    assert data["version"] == 1 and len(data["findings"]) == 1
    assert "note" in data["findings"][0]

    new, old, _ = apply_baseline(found, root=tmp_path,
                                 baseline_path=baseline)
    assert new == [] and len(old) == 1


def test_fingerprint_survives_line_shift(tmp_path):
    fixture = tmp_path / "fx.py"
    src = textwrap.dedent(KEY_REUSE_POSITIVE)
    fixture.write_text(src)
    (f1,) = lint_paths(paths=[str(fixture)], root=tmp_path)
    fixture.write_text("# a new header comment\n# another\n" + src)
    (f2,) = lint_paths(paths=[str(fixture)], root=tmp_path)
    assert f1.line != f2.line
    assert f1.fingerprint == f2.fingerprint


def test_missing_baseline_is_empty(tmp_path):
    assert F.load_baseline(tmp_path / "nope.json") == {}


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def test_cli_exits_nonzero_on_each_rule_fixture(tmp_path):
    fixtures = {
        "key-reuse": KEY_REUSE_POSITIVE,
        "jit-branch": JIT_BRANCH_POSITIVE,
        "host-sync": HOST_SYNC_POSITIVE,
        "recompile-hazard": """
            import jax

            def bad(x):
                return jax.jit(lambda v: v * 2)(x)
        """,
        "pallas-wrapper": """
            from jax.experimental import pallas as pl
        """,
    }
    empty = tmp_path / "empty_baseline.json"
    empty.write_text('{"version": 1, "findings": []}\n')
    for rule, src in fixtures.items():
        fx = tmp_path / f"{rule.replace('-', '_')}.py"
        fx.write_text(textwrap.dedent(src))
        rc = cli_main(["--lint", "--paths", str(fx),
                       "--baseline", str(empty)])
        assert rc == 1, f"{rule} fixture must gate"


def test_cli_baseline_silences(tmp_path):
    fx = tmp_path / "fx.py"
    fx.write_text(textwrap.dedent(KEY_REUSE_POSITIVE))
    baseline = tmp_path / "baseline.json"
    rc = cli_main(["--write-baseline", "--paths", str(fx),
                   "--baseline", str(baseline)])
    assert rc == 0
    rc = cli_main(["--lint", "--paths", str(fx),
                   "--baseline", str(baseline)])
    assert rc == 0


def test_cli_list_rules_and_unknown_rule():
    assert cli_main(["--list-rules"]) == 0
    assert cli_main(["--lint", "--rules", "no-such-rule"]) == 2


def test_repo_is_lint_clean():
    """The shipped tree carries no unsuppressed, unbaselined findings --
    the same bar `python -m repro.analysis --check` gates in CI."""
    found = lint_paths()
    new, _, _ = apply_baseline(found)
    assert new == [], "\n".join(f.render() for f in new)


# ---------------------------------------------------------------------------
# layer 2: contract sweep + retrace probes
# ---------------------------------------------------------------------------

def test_contract_sweep_full_registry():
    from repro.analysis.contracts import run_contracts
    from repro.configs import base as config_base

    report = run_contracts()
    assert report.ok, "\n".join(f.render() for f in report.failures)

    archs = set(config_base.load_all())
    covered = set(report.covered)
    skipped_paged = {a for a, p, _ in report.skipped if p == "paged"}
    for arch in archs:
        for path in ("prefill", "decode", "ragged", "pspec"):
            assert (arch, path) in covered, f"missing {arch} x {path}"
        if arch not in skipped_paged:
            assert (arch, "paged") in covered, f"missing {arch} x paged"
    # skips are contract-driven, not silent: only non-plain-decoder stacks
    for arch in skipped_paged:
        cfg = config_base.get_config(arch)
        assert cfg.enc_layers or set("xde") & set(cfg.block_pattern)
    assert report.elapsed_s < 60, "contract sweep must stay CI-cheap"


def test_retrace_serving_steady_state():
    from repro.analysis.retrace import serving_retraces

    fails = serving_retraces()
    assert fails == [], "\n".join(f.render() for f in fails)


def test_retrace_grid_rollout():
    from repro.analysis.retrace import rollout_retraces

    fails = rollout_retraces()
    assert fails == [], "\n".join(f.render() for f in fails)


# ---------------------------------------------------------------------------
# layer 4: shardcheck -- validate_spec invariants
# ---------------------------------------------------------------------------

def _mesh(m=4):
    from repro.analysis.contracts import ShapeOnlyMesh
    return ShapeOnlyMesh(cells=1, model=m)


def test_validate_spec_non_dividing_dim():
    from jax.sharding import PartitionSpec as P

    from repro.launch.sharding import validate_spec
    errs = validate_spec(_mesh(4), (6, 4), P("model", None))
    assert len(errs) == 1 and "not divisible" in errs[0]
    assert validate_spec(_mesh(4), (8, 4), P("model", None)) == []


def test_validate_spec_duplicate_axis():
    from jax.sharding import PartitionSpec as P

    from repro.launch.sharding import validate_spec
    errs = validate_spec(_mesh(2), (8, 4), P("model", "model"))
    assert any("consumed twice" in e for e in errs)


def test_validate_spec_overrank_and_unknown_axis():
    from jax.sharding import PartitionSpec as P

    from repro.launch.sharding import validate_spec
    errs = validate_spec(_mesh(2), (8,), P(None, None, "model"))
    assert len(errs) == 1 and "rank-1" in errs[0]
    errs = validate_spec(_mesh(2), (8,), P("nope"))
    assert any("unknown mesh axis" in e for e in errs)


def test_cache_spec_conv_leaf_is_not_kv():
    """Regression: "conv" ends with "v" -- a suffix match once handed conv
    caches the (B, S, KV, hd) KV layout, sharding their batch dim over
    "model"."""
    import numpy as np
    from jax.sharding import PartitionSpec as P

    from repro.launch import sharding
    tree = {"units": {"slot0": {
        "conv": jax.ShapeDtypeStruct((2, 2, 4, 8), np.float32)}}}
    ((path, leaf),) = jax.tree_util.tree_flatten_with_path(tree)[0]
    assert sharding.cache_spec(_mesh(2), path, leaf, batch=2) == P()


def test_cache_spec_kv_leaf_shards_kv_heads():
    import numpy as np

    from repro.analysis.shardcheck import _spec_axes
    from repro.launch import sharding
    tree = {"tail": {"blk0": {
        "k": jax.ShapeDtypeStruct((2, 24, 4, 8), np.float32)}}}
    ((path, leaf),) = jax.tree_util.tree_flatten_with_path(tree)[0]
    spec = sharding.cache_spec(_mesh(2), path, leaf, batch=2)
    assert "model" in _spec_axes(spec)


# ---------------------------------------------------------------------------
# layer 4: shardcheck -- registry pin + seeded violations
# ---------------------------------------------------------------------------

def test_shardcheck_full_registry_clean():
    from repro.analysis.shardcheck import run_shardcheck
    from repro.configs import base as config_base

    rep = run_shardcheck()
    assert rep.ok, "\n".join(f.render() for f in rep.failures)
    covered = set(rep.covered)
    for arch in config_base.load_all():
        for check in ("spec", "batch", "cache", "dtype"):
            assert (arch, check) in covered, f"missing {arch} x {check}"
    # pool skips are contract-driven (non-plain-decoder stacks), not silent
    for arch, check, _ in rep.skipped:
        assert check == "pool"
        cfg = config_base.get_config(arch)
        assert cfg.enc_layers or set("xde") & set(cfg.block_pattern)
    assert ("qwen3-0.6b", "donation") in covered
    assert ("mec-params", "dtype") in covered
    assert rep.elapsed_s < 60, "shardcheck must stay CI-cheap"


def test_shardcheck_seeded_duplicate_axis_fails(monkeypatch):
    """A deliberately corrupt param spec (one mesh axis on two dims) must
    surface as a [shardcheck:spec] failure."""
    from jax.sharding import PartitionSpec as P

    from repro.analysis.shardcheck import run_shardcheck
    from repro.launch import sharding

    real = sharding.param_spec

    def seeded(mesh, cfg, pstr, shape):
        if len(shape) == 2:
            return P("model", "model")
        return real(mesh, cfg, pstr, shape)

    monkeypatch.setattr(sharding, "param_spec", seeded)
    rep = run_shardcheck(["qwen3-0.6b"], model_degrees=(2,), donation=False)
    assert not rep.ok
    assert any(f.check == "spec" and "consumed twice" in f.message
               for f in rep.failures)


def test_cli_shardcheck_gates_on_seeded_violation(monkeypatch):
    """Acceptance: `python -m repro.analysis --shardcheck` exits nonzero
    when a spec violation is seeded into the sharding policy."""
    from jax.sharding import PartitionSpec as P

    from repro.analysis import shardcheck as SC
    from repro.configs import base as config_base
    from repro.launch import sharding

    one = {"qwen3-0.6b": config_base.load_all()["qwen3-0.6b"]}
    monkeypatch.setattr(SC.config_base, "load_all", lambda: one)
    real = sharding.param_spec

    def seeded(mesh, cfg, pstr, shape):
        if len(shape) == 2:
            return P("model", "model")
        return real(mesh, cfg, pstr, shape)

    monkeypatch.setattr(sharding, "param_spec", seeded)
    assert cli_main(["--shardcheck"]) == 1
    monkeypatch.setattr(sharding, "param_spec", real)
    assert cli_main(["--shardcheck"]) == 0


def test_shardcheck_kv_head_missplit(monkeypatch):
    """A kv projection spec that divides the FLAT dim but splits heads
    (qwen3 kv=8, head_dim=128: 1024 % 16 == 0 but 8 % 16 != 0) must fail
    the head-granularity check; the dividing degree is the near-miss."""
    from jax.sharding import PartitionSpec as P

    from repro.analysis.shardcheck import run_shardcheck
    from repro.launch import sharding

    real = sharding.param_spec

    def seeded(mesh, cfg, pstr, shape):
        if pstr.rsplit("/", 1)[-1] in ("wk", "wv") and len(shape) >= 2:
            return P(*[None] * (len(shape) - 1), "model")
        return real(mesh, cfg, pstr, shape)

    monkeypatch.setattr(sharding, "param_spec", seeded)
    rep = run_shardcheck(["qwen3-0.6b"], model_degrees=(16,), donation=False)
    assert any(f.check == "kv-heads" for f in rep.failures), \
        "\n".join(f.render() for f in rep.failures)
    # near miss: 8 kv heads over an 8-way model axis is head-granular
    rep = run_shardcheck(["qwen3-0.6b"], model_degrees=(8,), donation=False)
    assert not any(f.check == "kv-heads" for f in rep.failures), \
        "\n".join(f.render() for f in rep.failures)


# ---------------------------------------------------------------------------
# layer 4: dtype-flow + donation probes
# ---------------------------------------------------------------------------

def test_dtype_failures_flags_f64_and_weak_floats():
    import jax.numpy as jnp
    import numpy as np

    from repro.analysis.shardcheck import dtype_failures
    fails = dtype_failures(
        {"w": jax.ShapeDtypeStruct((2,), np.dtype("float64"))},
        arch="fx", what="t")
    assert len(fails) == 1 and "float64" in fails[0].message

    weak = jax.eval_shape(lambda: jnp.asarray(1.0))
    assert weak.weak_type, "fixture must be weak-typed"
    fails = dtype_failures({"x": weak}, arch="fx", what="t")
    assert len(fails) == 1 and "weak-typed" in fails[0].message

    clean = {"a": jax.ShapeDtypeStruct((2,), np.float32),
             "i": jax.ShapeDtypeStruct((2,), np.int32)}
    assert dtype_failures(clean, arch="fx", what="t") == []


def test_mec_params_dtype_clean():
    from repro.analysis.shardcheck import mec_params_dtype_failures
    fails = mec_params_dtype_failures()
    assert fails == [], "\n".join(f.render() for f in fails)


def test_donation_probe_positive_and_near_miss():
    import jax.numpy as jnp

    from repro.analysis.shardcheck import donation_failures
    args = ({"s": jnp.zeros(4)}, jnp.ones(4))

    bad = jax.jit(lambda s, x: ({"s": s["s"] + x}, x))
    fails = donation_failures(bad, args, arch="fx", what="tick")
    assert len(fails) == 1 and "not donated" in fails[0].message

    good = jax.jit(lambda s, x: ({"s": s["s"] + x}, x), donate_argnums=0)
    assert donation_failures(good, args, arch="fx", what="tick") == []

    opaque = donation_failures(lambda s, x: (s, x), args,
                               arch="fx", what="tick")
    assert len(opaque) == 1 and "not introspectable" in opaque[0].message


# ---------------------------------------------------------------------------
# layer 5: sanitizer -- shadow ownership over a fake paged engine
# ---------------------------------------------------------------------------

def _fake_paged_engine(slots=2, n_blocks=9, kv_block=8, table_w=4):
    import types

    import numpy as np

    from repro.analysis.sanitize import KVSanitizer
    from repro.serving.kvpool import BlockAllocator
    eng = types.SimpleNamespace(
        owned=[[] for _ in range(slots)],
        block_tables=np.zeros((slots, table_w), np.int32),
        active=[None] * slots,
        seq_lens=np.zeros(slots, np.int32),
        kv_block=kv_block,
        allocator=BlockAllocator(n_blocks, kv_block))
    return eng, KVSanitizer(eng)


def _hand(eng, san, slot, n, seq_len):
    got = eng.allocator.alloc(n)
    san.on_alloc(slot, got)
    eng.owned[slot] = list(got)
    eng.block_tables[slot, :len(got)] = got
    eng.active[slot] = object()
    eng.seq_lens[slot] = seq_len
    return got


def test_sanitizer_clean_lifecycle():
    eng, san = _fake_paged_engine()
    got = _hand(eng, san, 0, 2, seq_len=10)
    san.check_tick()
    san.on_free(0, got)
    eng.allocator.free(got)
    eng.owned[0] = []
    eng.block_tables[0, :] = 0
    eng.seq_lens[0] = 0
    eng.active[0] = None
    san.check_tick()
    san.check_drain()


def test_sanitizer_catches_double_free():
    import pytest

    from repro.analysis.sanitize import SanitizerError
    eng, san = _fake_paged_engine()
    got = _hand(eng, san, 0, 1, seq_len=4)
    san.on_free(0, got)
    with pytest.raises(SanitizerError, match="double free"):
        san.on_free(0, got)


def test_sanitizer_catches_cross_slot_aliasing_on_alloc():
    import pytest

    from repro.analysis.sanitize import SanitizerError
    eng, san = _fake_paged_engine()
    got = _hand(eng, san, 0, 1, seq_len=4)
    with pytest.raises(SanitizerError, match="aliasing"):
        san.on_alloc(1, [got[0]])


def test_sanitizer_catches_dummy_block_handout():
    import pytest

    from repro.analysis.sanitize import SanitizerError
    _, san = _fake_paged_engine()
    with pytest.raises(SanitizerError, match="dummy block 0"):
        san.on_alloc(0, [0])


def test_sanitizer_tick_catches_aliased_owned_lists():
    import pytest

    from repro.analysis.sanitize import SanitizerError
    eng, san = _fake_paged_engine()
    got = _hand(eng, san, 0, 1, seq_len=4)
    eng.owned[1] = [got[0]]
    eng.block_tables[1, 0] = got[0]
    eng.active[1] = object()
    with pytest.raises(SanitizerError, match="aliased"):
        san.check_tick()


def test_sanitizer_tick_catches_stale_table_entry():
    import pytest

    from repro.analysis.sanitize import SanitizerError
    eng, san = _fake_paged_engine()
    _hand(eng, san, 0, 2, seq_len=10)
    eng.block_tables[0, 3] = 5          # past the 2 owned blocks
    with pytest.raises(SanitizerError, match="stale"):
        san.check_tick()


def test_sanitizer_tick_catches_dummy_write():
    import pytest

    from repro.analysis.sanitize import SanitizerError
    eng, san = _fake_paged_engine()
    _hand(eng, san, 0, 1, seq_len=9)    # 9 > 1 block x 8 tokens
    with pytest.raises(SanitizerError, match="dummy block 0"):
        san.check_tick()


def test_sanitizer_tick_catches_free_owned_overlap():
    import pytest

    from repro.analysis.sanitize import SanitizerError
    eng, san = _fake_paged_engine()
    # slot claims a block the allocator never handed out (still free)
    eng.owned[0] = [3]
    san.owner[3] = 0
    eng.block_tables[0, 0] = 3
    eng.active[0] = object()
    eng.seq_lens[0] = 4
    with pytest.raises(SanitizerError, match="free and slot-owned"):
        san.check_tick()


def test_sanitizer_drain_catches_leak():
    import pytest

    from repro.analysis.sanitize import SanitizerError
    eng, san = _fake_paged_engine()
    _hand(eng, san, 0, 1, seq_len=4)
    eng.active[0] = None                # request "completed", blocks kept
    with pytest.raises(SanitizerError, match="leak at drain"):
        san.check_drain()


# ---------------------------------------------------------------------------
# layer 5: sanitizer -- real engine (injected aliasing + clean shipping run)
# ---------------------------------------------------------------------------

def test_sanitized_engine_catches_injected_aliasing():
    """Acceptance: a sanitized REAL engine whose pool state is corrupted
    mid-flight (one block reachable from two slots) fails its next tick."""
    import numpy as np
    import pytest

    from repro.analysis.sanitize import SanitizerError
    from repro.configs.base import get_config, reduced
    from repro.models import transformer
    from repro.serving.engine import Request, ServingEngine

    cfg = reduced(get_config("qwen3-0.6b"), n_layers=1)
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(cfg, params, slots=2, s_max=32, sanitize=True)
    eng.submit(Request(rid=0, prompt=np.arange(5, dtype=np.int32),
                       max_new=8))
    assert eng.step()                   # admit + first decode tick, clean
    (slot,) = [i for i, r in enumerate(eng.active) if r is not None]
    other = 1 - slot
    eng.owned[other] = [eng.owned[slot][0]]
    eng.block_tables[other, 0] = eng.owned[slot][0]
    with pytest.raises(SanitizerError, match="aliased"):
        eng.step()


@pytest.mark.slow
def test_run_sanitize_clean_on_shipping_engine():
    """Acceptance: the flash-crowd sanitize run passes clean AND actually
    exercises the dry-pool path (preemption fired, blocks churned)."""
    from repro.analysis.sanitize import run_sanitize

    rep = run_sanitize()
    assert rep.ok, "\n".join(f.render() for f in rep.failures)
    assert rep.requests == 10
    assert rep.preemptions > 0
    assert rep.block_churn > rep.requests   # growth beyond initial allocs


# ---------------------------------------------------------------------------
# baseline placeholder gate
# ---------------------------------------------------------------------------

def test_placeholder_entries_detection():
    base = {
        "aa": {"fingerprint": "aa", "path": "a.py", "rule": "r",
               "note": F.PLACEHOLDER_NOTE},
        "bb": {"fingerprint": "bb", "path": "b.py", "rule": "r",
               "note": "   "},
        "cc": {"fingerprint": "cc", "path": "c.py", "rule": "r",
               "note": "justified: warmup loop reuses the key on purpose"},
    }
    stale = F.placeholder_entries(base)
    assert [e["fingerprint"] for e in stale] == ["aa", "bb"]


def test_cli_check_gates_on_placeholder_note(tmp_path, monkeypatch):
    """--lint tolerates a fresh baseline; --check refuses entries whose
    note was never justified (heavy layers stubbed out)."""
    import types

    from repro.analysis import contracts, retrace, sanitize, shardcheck

    clean_sweep = types.SimpleNamespace(covered=(), skipped=(), failures=(),
                                        elapsed_s=0.0)
    clean_run = types.SimpleNamespace(failures=(), ticks=1, requests=1,
                                      preemptions=1, block_churn=1,
                                      elapsed_s=0.0)
    monkeypatch.setattr(contracts, "run_contracts", lambda **kw: clean_sweep)
    monkeypatch.setattr(shardcheck, "run_shardcheck",
                        lambda **kw: clean_sweep)
    monkeypatch.setattr(retrace, "run_retrace", lambda **kw: [])
    monkeypatch.setattr(sanitize, "run_sanitize", lambda **kw: clean_run)

    fx = tmp_path / "fx.py"
    fx.write_text(textwrap.dedent(KEY_REUSE_POSITIVE))
    baseline = tmp_path / "baseline.json"
    assert cli_main(["--write-baseline", "--paths", str(fx),
                     "--baseline", str(baseline)]) == 0
    assert cli_main(["--lint", "--paths", str(fx),
                     "--baseline", str(baseline)]) == 0
    assert cli_main(["--check", "--paths", str(fx),
                     "--baseline", str(baseline)]) == 1

    data = json.loads(baseline.read_text())
    for e in data["findings"]:
        e["note"] = "fixture reuse is the point of this test file"
    baseline.write_text(json.dumps(data))
    assert cli_main(["--check", "--paths", str(fx),
                     "--baseline", str(baseline)]) == 0
