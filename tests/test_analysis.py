"""Tests for the static-analysis subsystem (repro.analysis).

Layer 1: per-rule fixture snippets -- positive, suppressed, and baseline
paths -- through ``lint_source``/``lint_paths`` and the CLI entry point.
Layer 2: the eval_shape contract sweep pinned over the FULL config
registry, and the retrace probes.
"""
import json
import textwrap

from repro.analysis import findings as F
from repro.analysis.__main__ import main as cli_main
from repro.analysis.linter import apply_baseline, lint_paths, lint_source


def lint(src, rules=None, path="fixture.py"):
    return lint_source(textwrap.dedent(src), path, rules=rules)


def rules_of(found):
    return [f.rule for f in found]


# ---------------------------------------------------------------------------
# key-reuse
# ---------------------------------------------------------------------------

KEY_REUSE_POSITIVE = """
    import jax

    def bad():
        key = jax.random.PRNGKey(0)
        a = jax.random.normal(key, (4,))
        b = jax.random.normal(key, (4,))
        return a + b
"""


def test_key_reuse_positive():
    found = lint(KEY_REUSE_POSITIVE)
    assert rules_of(found) == ["key-reuse"]
    assert "'key' reused" in found[0].message


def test_key_reuse_split_is_clean():
    found = lint("""
        import jax

        def good():
            key = jax.random.PRNGKey(0)
            k1, k2 = jax.random.split(key)
            return jax.random.normal(k1, (4,)) + jax.random.normal(k2, (4,))
    """)
    assert found == []


def test_key_reuse_fold_in_does_not_consume():
    found = lint("""
        import jax

        def good(key):
            key = jax.random.PRNGKey(0)
            ks = [jax.random.fold_in(key, i) for i in range(3)]
            return jax.random.normal(jax.random.fold_in(key, 9), (2,))
    """)
    assert found == []


def test_key_reuse_split_array_element():
    found = lint("""
        import jax

        def bad():
            ks = jax.random.split(jax.random.PRNGKey(0), 4)
            a = jax.random.normal(ks[0], (4,))
            b = jax.random.normal(ks[1], (4,))
            c = jax.random.normal(ks[0], (4,))
            return a, b, c
    """)
    assert rules_of(found) == ["key-reuse"]
    assert "ks[0]" in found[0].message


def test_key_reuse_cross_iteration():
    # consuming the same key every loop pass (no re-split) is reuse
    found = lint("""
        import jax

        def bad(key):
            key = jax.random.PRNGKey(0)
            out = []
            for i in range(3):
                out.append(jax.random.normal(key, (2,)))
            return out
    """)
    assert "key-reuse" in rules_of(found)


def test_key_reuse_loop_resplit_is_clean():
    found = lint("""
        import jax

        def good():
            key = jax.random.PRNGKey(0)
            out = []
            for i in range(3):
                key, k = jax.random.split(key)
                out.append(jax.random.normal(k, (2,)))
            return out
    """)
    assert found == []


def test_key_reuse_suppressed():
    found = lint("""
        import jax

        def warm():
            key = jax.random.PRNGKey(0)
            a = jax.random.normal(key, (4,))
            b = jax.random.normal(key, (4,))  # reprolint: ignore[key-reuse]
            return a, b
    """)
    assert found == []


# ---------------------------------------------------------------------------
# jit-branch
# ---------------------------------------------------------------------------

JIT_BRANCH_POSITIVE = """
    import jax

    @jax.jit
    def bad(x):
        if x > 0:
            return x
        return -x
"""


def test_jit_branch_positive():
    found = lint(JIT_BRANCH_POSITIVE)
    assert rules_of(found) == ["jit-branch"]


def test_jit_branch_shape_and_none_are_static():
    found = lint("""
        import jax

        @jax.jit
        def good(x, mask):
            if x.shape[0] > 4:
                x = x[:4]
            if mask is None:
                return x
            if len(x.shape) == 2:
                return x * mask
            return x
    """)
    assert found == []


def test_jit_branch_static_argnames_excluded():
    found = lint("""
        import jax
        from functools import partial

        @partial(jax.jit, static_argnames="n")
        def good(x, n):
            if n > 4:
                return x[:4]
            return x
    """)
    assert found == []


def test_jit_branch_wrapped_local_def():
    found = lint("""
        import jax

        def make():
            def step(x):
                while x < 3:
                    x = x + 1
                return x
            return jax.jit(step)
    """)
    assert rules_of(found) == ["jit-branch"]


def test_jit_branch_taint_flows_through_assignment():
    found = lint("""
        import jax

        @jax.jit
        def bad(x):
            y = x * 2
            if y > 1:
                return y
            return -y
    """)
    assert rules_of(found) == ["jit-branch"]


# ---------------------------------------------------------------------------
# recompile-hazard
# ---------------------------------------------------------------------------

def test_recompile_inline_jit_call():
    found = lint("""
        import jax

        def bad(x):
            return jax.jit(lambda v: v * 2)(x)
    """)
    assert rules_of(found) == ["recompile-hazard"]
    assert "inline" in found[0].message


def test_recompile_jit_in_loop():
    found = lint("""
        import jax

        def bad(fns, x):
            outs = []
            for f in fns:
                g = jax.jit(f)
                outs.append(g(x))
            return outs
    """)
    assert "recompile-hazard" in rules_of(found)


def test_recompile_unhashable_static_argnums():
    found = lint("""
        import jax

        def f(x, n):
            return x[:n]

        g = jax.jit(f, static_argnums=[1])
    """)
    assert rules_of(found) == ["recompile-hazard"]
    assert "unhashable" in found[0].message


def test_recompile_shape_varying_call_site():
    found = lint("""
        import jax
        import numpy as np

        run = jax.jit(lambda t: t.sum())

        def bad(prompt, width):
            toks = np.pad(prompt, (width - len(prompt), 0))
            return run(toks)
    """)
    assert rules_of(found) == ["recompile-hazard"]
    assert "shape-varying" in found[0].message


def test_recompile_bucketing_helper_exempt():
    found = lint("""
        import jax
        import numpy as np

        run = jax.jit(lambda t: t.sum())

        def _bucket_width(n):
            return max(8, 1 << (n - 1).bit_length())

        def good(prompt):
            width = _bucket_width(len(prompt))
            toks = np.pad(prompt, (width - len(prompt), 0))
            return run(toks)
    """)
    assert found == []


# ---------------------------------------------------------------------------
# host-sync
# ---------------------------------------------------------------------------

HOST_SYNC_POSITIVE = """
    import jax
    import numpy as np

    step = jax.jit(lambda s: s * 2)

    def serve(state, n):
        for _ in range(n):
            state = step(state)
            print(float(state))
        return state
"""


def test_host_sync_positive():
    found = lint(HOST_SYNC_POSITIVE)
    assert rules_of(found) == ["host-sync"]


def test_host_sync_suppressed():
    found = lint("""
        import jax

        step = jax.jit(lambda s: s * 2)

        def serve(state, n):
            for _ in range(n):
                state = step(state)
                print(float(state))  # reprolint: ignore[host-sync]
            return state
    """)
    assert found == []


def test_host_sync_engine_hot_zone_by_path():
    # the configured hot zone applies by file path + function name, no
    # loop required
    found = lint("""
        import jax.numpy as jnp
        import numpy as np

        class Engine:
            def _step_continuous(self):
                logits = self._decode()
                return np.asarray(jnp.argmax(logits, -1))
    """, path="src/repro/serving/engine.py")
    assert rules_of(found) == ["host-sync"]


def test_host_sync_host_data_is_clean():
    found = lint("""
        import numpy as np

        def drive(reqs, n):
            for _ in range(n):
                counts = np.asarray([len(r) for r in reqs])
                print(float(counts.sum()))
            return reqs
    """)
    assert found == []


def test_host_sync_obs_hot_zone_near_miss():
    # the telemetry read sites (repro/obs/enginehooks.py) are hot zones by
    # path: a gauge that "reads" a device value via float() IS a
    # device->host sync in the tick path and must be flagged ...
    found = lint("""
        import jax.numpy as jnp

        class EngineHooks:
            def on_decode_tick(self, engine, t0_us, live):
                toks = jnp.argmax(engine.last_logits, -1)
                self.tokens_gauge.set(float(toks[0]))
    """, path="src/repro/obs/enginehooks.py")
    assert rules_of(found) == ["host-sync"]


def test_host_sync_obs_hot_zone_host_reads_clean():
    # ... while the contract pattern -- reading host state the engine
    # already materialized (numpy rows, queue lengths, free lists) --
    # lints clean in the same function
    found = lint("""
        class EngineHooks:
            def on_decode_tick(self, engine, t0_us, live):
                self.decode_ticks.inc(engine.decode_steps)

            def sample(self, engine):
                self.queue_depth.set(len(engine.queue))
                self.pool_free.set(engine.allocator.n_free)
    """, path="src/repro/obs/enginehooks.py")
    assert found == []


def test_host_sync_real_obs_module_is_lint_clean():
    # the shipped telemetry hooks must satisfy their own contract with no
    # suppressions and no baseline entries
    found = lint_paths(paths=["src/repro/obs"])
    assert found == [], [f"{f.path}:{f.line} {f.rule}: {f.message}"
                         for f in found]


# ---------------------------------------------------------------------------
# pallas-wrapper
# ---------------------------------------------------------------------------

def test_pallas_wrapper_direct_kernel_import():
    found = lint("""
        from repro.kernels.flash_attention import flash_attention_pallas
    """, path="src/repro/serving/engine.py")
    assert rules_of(found) == ["pallas-wrapper"]


def test_pallas_wrapper_direct_pallas_import():
    found = lint("""
        from jax.experimental import pallas as pl
    """, path="src/repro/core/sweep.py")
    assert rules_of(found) == ["pallas-wrapper"]


def test_pallas_wrapper_ops_and_ref_allowed():
    found = lint("""
        from repro.kernels.ops import flash_attention
        from repro.kernels.ref import attention_ref
    """, path="src/repro/core/sweep.py")
    assert found == []


def test_pallas_wrapper_inside_kernels_allowed():
    found = lint("""
        from jax.experimental import pallas as pl
        from .flash_attention import flash_attention_pallas
    """, path="src/repro/kernels/ops.py")
    assert found == []


# ---------------------------------------------------------------------------
# baseline workflow + fingerprints
# ---------------------------------------------------------------------------

def test_baseline_roundtrip(tmp_path):
    fixture = tmp_path / "fx.py"
    fixture.write_text(textwrap.dedent(KEY_REUSE_POSITIVE))
    found = lint_paths(paths=[str(fixture)], root=tmp_path)
    assert len(found) == 1

    baseline = tmp_path / "baseline.json"
    F.write_baseline(baseline, found)
    data = json.loads(baseline.read_text())
    assert data["version"] == 1 and len(data["findings"]) == 1
    assert "note" in data["findings"][0]

    new, old, _ = apply_baseline(found, root=tmp_path,
                                 baseline_path=baseline)
    assert new == [] and len(old) == 1


def test_fingerprint_survives_line_shift(tmp_path):
    fixture = tmp_path / "fx.py"
    src = textwrap.dedent(KEY_REUSE_POSITIVE)
    fixture.write_text(src)
    (f1,) = lint_paths(paths=[str(fixture)], root=tmp_path)
    fixture.write_text("# a new header comment\n# another\n" + src)
    (f2,) = lint_paths(paths=[str(fixture)], root=tmp_path)
    assert f1.line != f2.line
    assert f1.fingerprint == f2.fingerprint


def test_missing_baseline_is_empty(tmp_path):
    assert F.load_baseline(tmp_path / "nope.json") == {}


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def test_cli_exits_nonzero_on_each_rule_fixture(tmp_path):
    fixtures = {
        "key-reuse": KEY_REUSE_POSITIVE,
        "jit-branch": JIT_BRANCH_POSITIVE,
        "host-sync": HOST_SYNC_POSITIVE,
        "recompile-hazard": """
            import jax

            def bad(x):
                return jax.jit(lambda v: v * 2)(x)
        """,
        "pallas-wrapper": """
            from jax.experimental import pallas as pl
        """,
    }
    empty = tmp_path / "empty_baseline.json"
    empty.write_text('{"version": 1, "findings": []}\n')
    for rule, src in fixtures.items():
        fx = tmp_path / f"{rule.replace('-', '_')}.py"
        fx.write_text(textwrap.dedent(src))
        rc = cli_main(["--lint", "--paths", str(fx),
                       "--baseline", str(empty)])
        assert rc == 1, f"{rule} fixture must gate"


def test_cli_baseline_silences(tmp_path):
    fx = tmp_path / "fx.py"
    fx.write_text(textwrap.dedent(KEY_REUSE_POSITIVE))
    baseline = tmp_path / "baseline.json"
    rc = cli_main(["--write-baseline", "--paths", str(fx),
                   "--baseline", str(baseline)])
    assert rc == 0
    rc = cli_main(["--lint", "--paths", str(fx),
                   "--baseline", str(baseline)])
    assert rc == 0


def test_cli_list_rules_and_unknown_rule():
    assert cli_main(["--list-rules"]) == 0
    assert cli_main(["--lint", "--rules", "no-such-rule"]) == 2


def test_repo_is_lint_clean():
    """The shipped tree carries no unsuppressed, unbaselined findings --
    the same bar `python -m repro.analysis --check` gates in CI."""
    found = lint_paths()
    new, _, _ = apply_baseline(found)
    assert new == [], "\n".join(f.render() for f in new)


# ---------------------------------------------------------------------------
# layer 2: contract sweep + retrace probes
# ---------------------------------------------------------------------------

def test_contract_sweep_full_registry():
    from repro.analysis.contracts import run_contracts
    from repro.configs import base as config_base

    report = run_contracts()
    assert report.ok, "\n".join(f.render() for f in report.failures)

    archs = set(config_base.load_all())
    covered = set(report.covered)
    skipped_paged = {a for a, p, _ in report.skipped if p == "paged"}
    for arch in archs:
        for path in ("prefill", "decode", "ragged", "pspec"):
            assert (arch, path) in covered, f"missing {arch} x {path}"
        if arch not in skipped_paged:
            assert (arch, "paged") in covered, f"missing {arch} x paged"
    # skips are contract-driven, not silent: only non-plain-decoder stacks
    for arch in skipped_paged:
        cfg = config_base.get_config(arch)
        assert cfg.enc_layers or set("xde") & set(cfg.block_pattern)
    assert report.elapsed_s < 60, "contract sweep must stay CI-cheap"


def test_retrace_serving_steady_state():
    from repro.analysis.retrace import serving_retraces

    fails = serving_retraces()
    assert fails == [], "\n".join(f.render() for f in fails)


def test_retrace_grid_rollout():
    from repro.analysis.retrace import rollout_retraces

    fails = rollout_retraces()
    assert fails == [], "\n".join(f.render() for f in fails)
