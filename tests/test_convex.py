"""Exactness of the per-slot convex allocators (paper Sec. IV-C)."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import convex


# ---------------------------------------------------------------------------
# P3: Fibonacci search vs dense grid
# ---------------------------------------------------------------------------

@pytest.mark.slow
@given(q=st.floats(0.0, 500.0), d=st.floats(1e7, 4e8), lam=st.floats(0.2, 2.5))
@settings(max_examples=40, deadline=None)
def test_p3_beats_dense_grid(q, d, lam):
    kappa, v, f_max = 1e-28, 10.0, 1.5e9
    if d * lam * 1.01 >= f_max:
        return
    f_star = float(convex.solve_p3(jnp.float32(q), kappa, jnp.float32(d),
                                   jnp.float32(lam), v, f_max))
    grid = np.linspace(d * lam * 1.001 + 1.0, f_max, 20_000)
    obj = np.array(convex.p3_objective(jnp.asarray(grid, jnp.float32), q,
                                       kappa, d, lam, v))
    best = grid[np.argmin(obj)]
    j_star = float(convex.p3_objective(jnp.float32(f_star), q, kappa, d, lam, v))
    j_grid = float(np.min(obj))
    # Fibonacci optimum must be at least as good as a 20k-point grid (small
    # tolerance for float32 evaluation noise).
    assert j_star <= j_grid * (1 + 2e-3) + 1e-6, (f_star, best)


def test_p3_beats_coarse_grid_fast():
    """Tier-1 guard on P3 optimality (the dense sweep is slow-marked)."""
    kappa, v, f_max = 1e-28, 10.0, 1.5e9
    for q, d, lam in [(0.0, 2e8, 2.0), (250.0, 1e8, 1.0), (500.0, 4e8, 0.5)]:
        f_star = float(convex.solve_p3(jnp.float32(q), kappa, jnp.float32(d),
                                       jnp.float32(lam), v, f_max))
        grid = np.linspace(d * lam * 1.001 + 1.0, f_max, 2_000)
        j_grid = float(np.min(np.array(convex.p3_objective(
            jnp.asarray(grid, jnp.float32), q, kappa, d, lam, v))))
        j_star = float(convex.p3_objective(jnp.float32(f_star), q, kappa, d,
                                           lam, v))
        assert j_star <= j_grid * (1 + 2e-3) + 1e-6


def test_p3_zero_demand_gives_zero():
    out = convex.solve_p3(jnp.zeros(3), 1e-28, jnp.zeros(3), jnp.ones(3), 10.0, 1.5e9)
    assert np.all(np.array(out) == 0.0)


def test_p3_energy_pressure_lowers_frequency():
    d, lam = jnp.float32(2e8), jnp.float32(2.0)
    f_low_q = float(convex.solve_p3(jnp.float32(0.0), 1e-28, d, lam, 10.0, 1.5e9))
    f_high_q = float(convex.solve_p3(jnp.float32(1e4), 1e-28, d, lam, 10.0, 1.5e9))
    assert f_high_q < f_low_q  # big energy queue -> throttle the CPU
    assert f_low_q == pytest.approx(1.5e9, rel=1e-3)  # no pressure -> run flat out


# ---------------------------------------------------------------------------
# P4: closed form (eq. 23)
# ---------------------------------------------------------------------------

@given(st.lists(st.floats(0.0, 1e9), min_size=2, max_size=8))
@settings(max_examples=50, deadline=None)
def test_p4_kkt(ds):
    d = jnp.asarray(ds, jnp.float32)
    f_max = 15e9
    f = np.array(convex.solve_p4(d, f_max))
    if float(jnp.sum(d)) == 0:
        assert np.all(f == 0)
        return
    assert np.sum(f) == pytest.approx(f_max, rel=1e-5)      # C3 tight
    assert np.all(f >= 0)                                   # C5
    # proportionality f_n ~ sqrt(d_n)  (eq. 23); mask with f32 semantics:
    # XLA flushes sub-normal demands to zero -> zero share, correctly, so
    # only f32-normal demands participate in the ratio check.
    root = np.sqrt(np.maximum(np.asarray(d, np.float64), 0))
    nz = np.asarray(d, np.float64) >= 1.2e-38
    if nz.sum() >= 2:
        ratios = f[nz] / root[nz]
        assert np.allclose(ratios, ratios[0], rtol=1e-4)


def test_p4_optimality_vs_perturbation():
    d = jnp.asarray([1e8, 4e8, 9e8], jnp.float32)
    f = np.array(convex.solve_p4(d, 15e9))
    base = np.sum(np.array(d) / f)
    rng = np.random.default_rng(0)
    for _ in range(100):
        eps = rng.normal(0, 0.02 * 15e9 / 3, 3)
        eps -= eps.mean()  # stay on the simplex
        fp = np.clip(f + eps, 1e6, None)
        fp *= 15e9 / fp.sum()
        assert np.sum(np.array(d) / fp) >= base * (1 - 1e-6)


# ---------------------------------------------------------------------------
# P5: KKT bisection vs brute force & KKT residuals
# ---------------------------------------------------------------------------

def _p5_inputs(n, seed=0):
    rng = np.random.default_rng(seed)
    gain = rng.exponential(1.0, n) * 1.58e-11
    psi = rng.uniform(0.05e6, 1.0e6, n)
    lam = rng.uniform(0.5, 2.5, n)
    q = rng.uniform(0.0, 200.0, n)
    return (jnp.asarray(q, jnp.float32), 0.1, jnp.asarray(lam, jnp.float32),
            10.0, jnp.asarray(psi, jnp.float32), 5e6,
            jnp.asarray(gain, jnp.float32), 10 ** (-17.4) / 1000.0)


@pytest.mark.slow
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_p5_beats_brute_force_n2(seed):
    q, p, lam, v, psi, w, gain, n0 = _p5_inputs(2, seed)
    alpha = np.array(convex.solve_p5(q, p, lam, v, psi, w, gain, n0))
    assert alpha.sum() == pytest.approx(1.0, abs=1e-4)
    best = np.inf
    for a0 in np.linspace(1e-4, 1 - 1e-4, 4001):
        val = float(convex.p5_objective(jnp.asarray([a0, 1 - a0], jnp.float32),
                                        q, p, lam, v, psi, w, gain, n0))
        best = min(best, val)
    ours = float(convex.p5_objective(jnp.asarray(alpha, jnp.float32),
                                     q, p, lam, v, psi, w, gain, n0))
    assert ours <= best * (1 + 1e-3)


def test_p5_beats_brute_force_fast():
    """Tier-1 guard on P5 optimality (the 4001-point sweeps are slow-marked):
    a coarse n=2 line search must not beat the KKT bisection."""
    q, p, lam, v, psi, w, gain, n0 = _p5_inputs(2, seed=0)
    alpha = np.array(convex.solve_p5(q, p, lam, v, psi, w, gain, n0))
    assert alpha.sum() == pytest.approx(1.0, abs=1e-4)
    best = np.inf
    for a0 in np.linspace(1e-3, 1 - 1e-3, 401):
        val = float(convex.p5_objective(jnp.asarray([a0, 1 - a0], jnp.float32),
                                        q, p, lam, v, psi, w, gain, n0))
        best = min(best, val)
    ours = float(convex.p5_objective(jnp.asarray(alpha, jnp.float32),
                                     q, p, lam, v, psi, w, gain, n0))
    assert ours <= best * (1 + 1e-3)


@pytest.mark.parametrize("n", [3, 5, 8])
def test_p5_kkt_residual(n):
    """At the optimum the marginal value of bandwidth is equalized."""
    q, p, lam, v, psi, w, gain, n0 = _p5_inputs(n, seed=n)
    alpha = np.array(convex.solve_p5(q, p, lam, v, psi, w, gain, n0))
    assert alpha.sum() == pytest.approx(1.0, abs=1e-4)
    s = np.array(p * gain / (w * n0))
    coeff = np.array((q * p * lam + v) * 8.0 * psi / w)
    log_m = np.array(convex._log_marginal(jnp.asarray(alpha, jnp.float32),
                                          jnp.asarray(s, jnp.float32),
                                          jnp.log(jnp.asarray(coeff, jnp.float32))))
    spread = log_m.max() - log_m.min()
    assert spread < 5e-3, f"marginals not equalized: {log_m}"


def test_p5_inactive_ues_get_zero():
    q, p, lam, v, psi, w, gain, n0 = _p5_inputs(4, seed=7)
    psi = psi.at[1].set(0.0).at[3].set(0.0)
    alpha = np.array(convex.solve_p5(q, p, lam, v, psi, w, gain, n0))
    assert alpha[1] == 0.0 and alpha[3] == 0.0
    assert alpha.sum() == pytest.approx(1.0, abs=1e-4)


def test_p5_single_active_ue_takes_all():
    q, p, lam, v, psi, w, gain, n0 = _p5_inputs(3, seed=9)
    psi = psi.at[0].set(0.0).at[2].set(0.0)
    alpha = np.array(convex.solve_p5(q, p, lam, v, psi, w, gain, n0))
    assert alpha == pytest.approx([0.0, 1.0, 0.0])


def test_p5_all_idle():
    q, p, lam, v, psi, w, gain, n0 = _p5_inputs(3, seed=11)
    alpha = np.array(convex.solve_p5(q, p, lam, v, jnp.zeros(3), w, gain, n0))
    assert np.all(alpha == 0.0)
