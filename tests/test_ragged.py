"""Ragged-batch exactness across EVERY architecture kind the engine serves.

PR 3 made attention stacks pad-exact; this suite pins the remaining gaps
closed: recurrent ("r") and SSD ("s") blocks no longer scan left-pad
positions (reset-aware scan kernels + pad-zeroed conv inputs), and the
Pallas flash kernel serves ragged batches directly (per-row pad counts in
the in-kernel mask) instead of falling back to the dense reference.

Layers covered:
  * model level -- left-padded prefill + decode equals the solo run for
    hybrid ("r"+attention), pure-SSM ("s"), and mixed ("g","r","s") stacks,
    on the reference AND the interpreted-Pallas dispatch path;
  * engine level -- mixed-length prompt batches through ServingEngine match
    solo runs greedy-token-for-greedy-token on recurrent stacks;
  * dispatch level -- ops.flash_attention(pad_mask=...) keeps the Pallas
    path when Pallas is active (the dense-reference fallback is gone);
  * property level -- prefill logits are invariant to the pad count across
    engine bucket widths (hypothesis; fixed-examples fallback on bare envs).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.configs.base import get_config, reduced
from repro.kernels import ops
from repro.models import transformer
from repro.serving.engine import Request, ServingEngine

TOL = dict(rtol=1e-5, atol=1e-5)


def _hybrid_grs():
    """Mixed stack exercising attention + RG-LRU + SSD in one unit."""
    return dataclasses.replace(
        reduced(get_config("mamba2-1.3b")),
        name="hybrid-grs-smoke", block_pattern=("g", "r", "s"),
        n_layers=6, n_heads=4, n_kv=2, head_dim=16, d_ff=128, rnn_width=32)


def _configs():
    return [
        ("recurrentgemma", reduced(get_config("recurrentgemma-2b"))),
        ("mamba2", reduced(get_config("mamba2-1.3b"))),
        ("hybrid-grs", _hybrid_grs()),
    ]


CONFIGS = _configs()


@pytest.fixture(scope="module", params=[c[0] for c in CONFIGS])
def arch(request):
    cfg = dict(CONFIGS)[request.param]
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _prefill_pair(cfg, params, prompt, pad_width, s_max=48):
    """(solo logits+cache, padded-row logits+cache) for one prompt."""
    lg_s, c_s = transformer.prefill(params, cfg, {"tokens": prompt[None]},
                                    s_max=s_max)
    width = len(prompt) + pad_width
    other = jax.random.randint(jax.random.PRNGKey(2), (width,), 0, cfg.vocab)
    toks = jnp.stack([jnp.pad(prompt, (pad_width, 0)), other])
    pad = jnp.asarray([pad_width, 0], jnp.int32)
    lg_p, c_p = transformer.prefill(params, cfg, {"tokens": toks},
                                    s_max=s_max, pad=pad)
    return (lg_s, c_s), (lg_p, c_p)


def _check_decode(cfg, params, lg_s, c_s, lg_p, c_p, steps=3):
    t_s = jnp.argmax(lg_s, -1).astype(jnp.int32)
    t_p = jnp.argmax(lg_p, -1).astype(jnp.int32)
    for i in range(steps):
        lg_s, c_s = transformer.decode_step(params, cfg, c_s, t_s)
        lg_p, c_p = transformer.decode_step(params, cfg, c_p, t_p)
        np.testing.assert_allclose(np.asarray(lg_p[0]), np.asarray(lg_s[0]),
                                   err_msg=f"decode step {i}", **TOL)
        assert int(jnp.argmax(lg_p[0])) == int(jnp.argmax(lg_s[0]))
        t_s = jnp.argmax(lg_s, -1).astype(jnp.int32)
        t_p = jnp.argmax(lg_p, -1).astype(jnp.int32)


def test_left_padded_row_equals_solo_reference(arch):
    """Tier-1 leg: reference dispatch path, prefill + decode parity."""
    cfg, params = arch
    prompt = jax.random.randint(jax.random.PRNGKey(1), (9,), 0, cfg.vocab)
    ops.set_impl("reference")
    try:
        (lg_s, c_s), (lg_p, c_p) = _prefill_pair(cfg, params, prompt, 6)
        np.testing.assert_allclose(np.asarray(lg_p[0]), np.asarray(lg_s[0]),
                                   **TOL)
        _check_decode(cfg, params, lg_s, c_s, lg_p, c_p)
    finally:
        ops.set_impl("auto")


@pytest.mark.slow
def test_left_padded_row_equals_solo_pallas(arch):
    """Interpreted-Pallas dispatch path: same parity, kernel bodies live."""
    cfg, params = arch
    prompt = jax.random.randint(jax.random.PRNGKey(1), (9,), 0, cfg.vocab)
    ops.set_impl("pallas", interpret=True)
    try:
        (lg_s, c_s), (lg_p, c_p) = _prefill_pair(cfg, params, prompt, 6)
        np.testing.assert_allclose(np.asarray(lg_p[0]), np.asarray(lg_s[0]),
                                   **TOL)
        _check_decode(cfg, params, lg_s, c_s, lg_p, c_p)
    finally:
        ops.set_impl("auto")


def test_engine_mixed_lengths_match_solo_recurrent():
    """Engine-level: mixed-length prompts through a hybrid (r+l) stack equal
    their solo runs greedy-token-for-greedy-token (the ROADMAP's last
    'recurrent blocks still scan pads' caveat, retired)."""
    cfg = reduced(get_config("recurrentgemma-2b"))
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(9)
    prompts = [rng.integers(0, cfg.vocab, n).astype(np.int32)
               for n in (5, 9, 12)]
    eng = ServingEngine(cfg, params, slots=3, s_max=64)
    reqs = [Request(rid=i, prompt=p, max_new=4)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    finished = eng.run_until_idle()
    assert len(finished) == 3
    for p, r in zip(prompts, reqs):
        solo_eng = ServingEngine(cfg, params, slots=1, s_max=64)
        solo = Request(rid=0, prompt=p, max_new=4)
        solo_eng.submit(solo)
        solo_eng.run_until_idle()
        assert r.out == solo.out, f"prompt len {len(p)}"


def test_flash_attention_pad_mask_keeps_pallas_path(monkeypatch):
    """Acceptance pin: with Pallas active, ops.flash_attention(pad_mask=...)
    dispatches the masked Pallas kernel -- no dense-reference fallback."""
    import repro.kernels.flash_attention as fa
    calls = []
    real = fa.flash_attention_pallas

    def counting(*args, **kwargs):
        calls.append(kwargs.get("pad"))
        return real(*args, **kwargs)

    monkeypatch.setattr(fa, "flash_attention_pallas", counting)
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    b, s, h, kv, hd = 2, 16, 4, 2, 16
    q = jax.random.normal(ks[0], (b, s, h, hd))
    k = jax.random.normal(ks[1], (b, s, kv, hd))
    v = jax.random.normal(ks[2], (b, s, kv, hd))
    pad_mask = jnp.arange(s)[None, :] >= jnp.asarray([[0], [5]])
    ops.set_impl("pallas", interpret=True)
    try:
        got = ops.flash_attention(q, k, v, kind="causal", pad_mask=pad_mask)
    finally:
        ops.set_impl("auto")
    assert len(calls) == 1 and calls[0] is not None, \
        "ragged batch fell back off the Pallas path"
    # and the masked kernel agrees with the dense reference it replaced
    from repro.kernels import ref
    mask = (jnp.broadcast_to(pad_mask[:, None, :], (b, s, s))
            & ref.build_mask("causal", s, s)[None])
    want = ref.attention_ref(q, k, v, mask=mask)
    np.testing.assert_allclose(np.asarray(got)[1, 5:], np.asarray(want)[1, 5:],
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(got)[0], np.asarray(want)[0],
                               rtol=2e-5, atol=2e-5)


class TestPadInvariance:
    """Prefill logits are invariant to the pad count across bucket widths.

    Drawn pad widths round up to the engine's power-of-two prefill buckets
    (exactly what ``ServingEngine._admit`` does), so the jitted prefill
    compiles one shape per bucket -- the property then exercises every
    bucket's pad path at fixed-examples cost, not one compile per draw.
    """
    cfg = reduced(get_config("recurrentgemma-2b"))
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (7,), 0, cfg.vocab)
    solo = None

    @classmethod
    def _solo(cls):
        if cls.solo is None:
            lg, _ = transformer.prefill(cls.params, cls.cfg,
                                        {"tokens": cls.prompt[None]}, s_max=64)
            cls.solo = np.asarray(lg[0])
        return cls.solo

    @given(pad_width=st.integers(0, 25))
    @settings(max_examples=12, deadline=None)
    def test_logits_invariant_to_pad_count(self, pad_width):
        """Any left-pad amount (bucket slack included; 7+25=32 spans the
        8/16/32 engine buckets) leaves the row's logits unchanged."""
        width = 8
        while width < len(self.prompt) + pad_width:
            width *= 2
        pad_width = width - len(self.prompt)        # bucket-rounded pad
        toks = jnp.pad(self.prompt, (pad_width, 0))[None]
        pad = jnp.asarray([pad_width], jnp.int32)
        lg, _ = transformer.prefill(self.params, self.cfg, {"tokens": toks},
                                    s_max=64, pad=pad)
        np.testing.assert_allclose(np.asarray(lg[0]), self._solo(), **TOL)
