"""Per-cell tensor parallelism for the serving stack: model-sharded params
through ``PartitionedLM`` and ``ServingEngine`` match the unsharded
single-device run.

Degrees come from the live device count: tier-1's single device runs the
``model=1`` (degenerate placement) legs; the CI forced-8-device job runs
``model ∈ {1, 2, 4}`` with real GSPMD head/FFN splits.

The contract mirrors docs/serving.md's ragged one: greedy tokens are pinned
IDENTICAL (bit-for-bit at the token level), logits to 1e-5 -- sharding a
matmul's contraction over the model axis changes float-summation order
(psum of partials), so raw logits differ at ~1e-7, exactly like padding
does.  The recurrent engine leg drives mixed-length prompts, so PR 4's
reset-aware scans and pad masks run UNDER model sharding.
"""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, reduced
from repro.kernels import ops
from repro.launch.mesh import make_cells_mesh
from repro.launch.sharding import place_params
from repro.models import transformer
from repro.serving.engine import Request, ServingEngine
from repro.serving.partitioned import PartitionedLM

N_DEV = len(jax.devices())
TOL = dict(rtol=1e-5, atol=1e-5)   # as tests/test_ragged.py: float-sum order

# REPRO_MODEL_DEGREES narrows the degrees per CI matrix leg (see
# tests/test_gridshard.py); unset, every degree dividing N_DEV runs.
MODEL_DEGREES = [
    pytest.param(m, marks=pytest.mark.skipif(
        N_DEV % m != 0, reason=f"model={m} needs a device count "
                               f"divisible by it (have {N_DEV})"))
    for m in (int(x) for x in
              os.environ.get("REPRO_MODEL_DEGREES", "1,2,4").split(","))
]


def _hybrid_grs():
    """Mixed attention + RG-LRU + SSD stack, no tail (PartitionedLM-able)."""
    return dataclasses.replace(
        reduced(get_config("mamba2-1.3b")),
        name="hybrid-grs-tp-smoke", block_pattern=("g", "r", "s"),
        n_layers=6, n_heads=4, n_kv=2, head_dim=16, d_ff=128, rnn_width=32)


CONFIGS = {
    "attention": lambda: reduced(get_config("qwen3-0.6b")),
    "recurrent": _hybrid_grs,
}


@pytest.fixture(scope="module", params=sorted(CONFIGS))
def arch(request):
    cfg = CONFIGS[request.param]()
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


# ---------------------------------------------------------------------------
# Placement: the model axis lands on head/FFN weight dims
# ---------------------------------------------------------------------------

@pytest.mark.skipif(N_DEV < 2 or N_DEV % 2, reason="needs >= 2 devices")
def test_place_params_shards_weights_over_model_axis():
    cfg = CONFIGS["attention"]()
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    mesh = make_cells_mesh(model=2)
    placed = place_params(mesh, cfg, params)
    wq = placed["units"]["slot0"]["attn"]["wq"]
    w1 = placed["units"]["slot0"]["ffn"]["w1"]
    assert wq.sharding.spec[-1] == "model"       # heads dim split
    assert w1.sharding.spec[-1] == "model"       # FFN hidden dim split
    # nothing shards over "cells": each cell group holds a full replica
    for leaf in jax.tree.leaves(placed):
        assert "cells" not in tuple(leaf.sharding.spec)


# ---------------------------------------------------------------------------
# PartitionedLM: UE/ES halves under per-cell TP
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("model", MODEL_DEGREES)
def test_partitioned_lm_model_sharded_matches_unsharded(arch, model):
    cfg, params = arch
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab)
    plain = PartitionedLM(cfg, params, 1)
    lg_p, hid_p = plain.infer(toks)
    shard = PartitionedLM(cfg, params, 1, mesh=make_cells_mesh(model=model))
    lg_s, hid_s = shard.infer(toks)
    np.testing.assert_allclose(np.asarray(lg_s), np.asarray(lg_p), **TOL)
    np.testing.assert_allclose(np.asarray(hid_s).astype(np.float32),
                               np.asarray(hid_p).astype(np.float32), **TOL)
    np.testing.assert_array_equal(np.asarray(jnp.argmax(lg_s, -1)),
                                  np.asarray(jnp.argmax(lg_p, -1)))
    if model == 1:
        # no contraction is split: the degenerate placement is bitwise
        np.testing.assert_array_equal(np.asarray(lg_s), np.asarray(lg_p))


@pytest.mark.parametrize("model", MODEL_DEGREES)
def test_partitioned_lm_full_offload_sharded(arch, model):
    """cut_unit=0 (everything on the ES tier) under model sharding."""
    cfg, params = arch
    toks = jax.random.randint(jax.random.PRNGKey(2), (1, 9), 0, cfg.vocab)
    lg_p, _ = PartitionedLM(cfg, params, 0).infer(toks)
    lg_s, boundary = PartitionedLM(
        cfg, params, 0, mesh=make_cells_mesh(model=model)).infer(toks)
    np.testing.assert_allclose(np.asarray(lg_s), np.asarray(lg_p), **TOL)
    np.testing.assert_array_equal(np.asarray(boundary), np.asarray(toks))


# ---------------------------------------------------------------------------
# Engine: ragged prefill + decode under model sharding
# ---------------------------------------------------------------------------

def _run_engine(cfg, params, prompts, mesh=None):
    eng = ServingEngine(cfg, params, slots=len(prompts), s_max=64, mesh=mesh)
    reqs = [Request(rid=i, prompt=p, max_new=4)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    done = eng.run_until_idle()
    assert len(done) == len(prompts)
    return [r.out for r in reqs]


@pytest.mark.parametrize("model", MODEL_DEGREES)
def test_engine_model_sharded_ragged_parity(model):
    """Mixed-length prompts through a model-sharded recurrent engine give
    the exact greedy tokens of the unsharded engine -- PR 4's ragged/reset
    machinery (pad-zeroed convs, reset-aware scans, masked attention) all
    running partitioned."""
    cfg = reduced(get_config("recurrentgemma-2b"))
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(9)
    prompts = [rng.integers(0, cfg.vocab, n).astype(np.int32)
               for n in (5, 9, 12)]
    want = _run_engine(cfg, params, prompts)
    got = _run_engine(cfg, params, prompts,
                      mesh=make_cells_mesh(model=model))
    assert got == want


@pytest.mark.parametrize("model", MODEL_DEGREES)
def test_engine_model_sharded_attention_parity(model):
    cfg = CONFIGS["attention"]()
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(11)
    prompts = [rng.integers(0, cfg.vocab, n).astype(np.int32)
               for n in (4, 11)]
    want = _run_engine(cfg, params, prompts)
    got = _run_engine(cfg, params, prompts,
                      mesh=make_cells_mesh(model=model))
    assert got == want


@pytest.mark.parametrize("model", MODEL_DEGREES)
def test_engine_model_sharded_chunked_prefill_parity(arch, model):
    """Chunked prefill under per-cell TP: a prompt long enough to stream
    through several chunk ticks produces the exact greedy tokens of the
    unsharded whole-prompt engine -- the chunk-step program, the
    incremental pool commits, and the masked-table decode dispatch all
    run on model-sharded state."""
    cfg, params = arch
    rng = np.random.default_rng(15)
    prompts = [rng.integers(0, cfg.vocab, n).astype(np.int32)
               for n in (41, 7, 22)]

    def run(mesh, chunk):
        eng = ServingEngine(cfg, params, slots=3, s_max=64, mesh=mesh,
                            prefill_chunk=chunk)
        reqs = [Request(rid=i, prompt=p, max_new=4)
                for i, p in enumerate(prompts)]
        for r in reqs:
            eng.submit(r)
        assert len(eng.run_until_idle()) == len(prompts)
        return [r.out for r in reqs]

    want = run(None, None)                       # unsharded, whole-prompt
    got = run(make_cells_mesh(model=model), 16)  # sharded, streaming
    assert got == want


@pytest.mark.slow
def test_engine_model_sharded_parity_pallas_path():
    """Interpreted-Pallas dispatch under the largest buildable TP degree:
    the kernel bodies themselves run on model-sharded operands."""
    model = max((m for m in (1, 2, 4) if N_DEV % m == 0), default=1)
    cfg = reduced(get_config("recurrentgemma-2b"))
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(13)
    prompts = [rng.integers(0, cfg.vocab, n).astype(np.int32)
               for n in (6, 10)]
    ops.set_impl("pallas", interpret=True)
    try:
        want = _run_engine(cfg, params, prompts)
        got = _run_engine(cfg, params, prompts,
                          mesh=make_cells_mesh(model=model))
    finally:
        ops.set_impl("auto")
    assert got == want
