"""Distribution layer units: sharding policy rules, input specs, and the
HLO collective parser (no SPMD compilation needed)."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import get_config
from repro.launch import specs
from repro.launch.dryrun import _result_bytes, collective_bytes
from repro.launch.sharding import (ShardingOptions, batch_shardings,
                                   cache_shardings, param_spec)

pytestmark = pytest.mark.filterwarnings("ignore")


class FakeMesh:
    axis_names = ("data", "model")
    shape = {"data": 16, "model": 16}


MESH = FakeMesh()


def test_param_spec_baseline_rules():
    cfg = get_config("qwen1.5-110b")      # fsdp=True
    # attention qkv: (d, heads*hd) -> (fsdp, model)
    assert param_spec(MESH, cfg, "units/slot0/attn/wq", (80, 8192, 8192)) \
        == P(None, "data", "model")
    # output proj flips
    assert param_spec(MESH, cfg, "units/slot0/attn/wo", (80, 8192, 8192)) \
        == P(None, "model", "data")
    # embed: vocab on model
    assert param_spec(MESH, cfg, "embed", (152064, 8192)) == P("model", "data")
    # norms replicated beyond the stack axis
    assert param_spec(MESH, cfg, "units/slot0/norm1", (80, 8192)) == P(None, None)


def test_param_spec_non_divisible_replicates():
    cfg = get_config("gemma3-1b")         # 4 heads * 256 = 1024 cols; d=1152
    # Head-granular TP: gemma3's 4 query heads (and 1 kv head) cannot split
    # over a 16-way model axis, so the projections replicate even though
    # their flat column counts (1024, 256) divide 16 -- a mid-head split
    # breaks per-head ops (RoPE, qk-norm, GQA grouping).
    spec = param_spec(MESH, cfg, "units/slot0/attn/wq", (4, 1152, 1024))
    assert spec == P(None, None, None)
    spec = param_spec(MESH, cfg, "units/slot0/attn/wk", (4, 1152, 256))
    assert spec == P(None, None, None)
    assert param_spec(MESH, cfg, "units/slot0/attn/wo", (4, 1024, 1152)) \
        == P(None, None, None)
    # head-aligned counts DO shard: qwen1.5's 8 kv heads on 8-way would,
    # but on this 16-way mesh 8 % 16 != 0 -> replicated too
    cfg_q = get_config("qwen1.5-110b")
    assert param_spec(MESH, cfg_q, "units/slot0/attn/wk", (80, 8192, 1024)) \
        == P(None, "data", None)
    # d_model 1152 not divisible by 16 on the fsdp side (fsdp=False anyway)
    assert param_spec(MESH, cfg, "final_norm", (1152,)) == P(None)


def test_param_spec_moe_rules():
    cfg = get_config("llama4-maverick-400b-a17b")
    base = param_spec(MESH, cfg, "units/slot1/moe/wi", (24, 128, 5120, 8192))
    assert base == P(None, "model", "data", None)      # EP + FSDP-D
    dff = param_spec(MESH, cfg, "units/slot1/moe/wi", (24, 128, 5120, 8192),
                     ShardingOptions(expert_shard_dff=True))
    assert dff == P(None, "model", None, "data")       # resident, F over data
    epd = param_spec(MESH, cfg, "units/slot1/moe/wi", (24, 128, 5120, 8192),
                     ShardingOptions(expert_mesh="data"))
    assert epd == P(None, "data", None, "model")


def test_param_spec_tp_modes():
    cfg = get_config("qwen3-0.6b")
    full = param_spec(MESH, cfg, "units/slot0/ffn/w1", (28, 1024, 3072))
    assert full == P(None, None, "model")
    vocab_only = param_spec(MESH, cfg, "units/slot0/ffn/w1", (28, 1024, 3072),
                            ShardingOptions(tp_mode="vocab-only"))
    assert vocab_only == P(None, None, None)
    # vocab sharding survives
    assert param_spec(MESH, cfg, "embed", (151936, 1024),
                      ShardingOptions(tp_mode="vocab-only"))[0] == "model"


def test_param_spec_zero2d_without_tp():
    cfg = get_config("qwen1.5-110b")
    opts = ShardingOptions(tp_mode="vocab-only")
    spec = param_spec(MESH, cfg, "units/slot0/ffn/w1", (80, 8192, 49152), opts)
    assert spec == P(None, ("data", "model"), None)    # 256-way storage


def test_input_specs_shapes():
    cfg = get_config("qwen3-0.6b")
    train = specs.input_specs(cfg, "train_4k")
    assert train["batch"]["tokens"].shape == (256, 4096)
    assert train["batch"]["targets"].dtype == jnp.int32
    dec = specs.input_specs(cfg, "decode_32k")
    assert dec["tokens"].shape == (128,)
    # cache via eval_shape: stacked KV (units, B, s_max, kv, hd)
    kv = dec["cache"]["units"]["slot0"].k
    assert kv.shape == (28, 128, 32768 + specs.DECODE_MARGIN, 8, 128)


def test_input_specs_modalities():
    vlm = get_config("llama-3.2-vision-90b")
    b = specs.input_specs(vlm, "train_4k")["batch"]
    assert b["image_embeds"].shape == (256, 1024, 8192)
    audio = get_config("seamless-m4t-large-v2")
    b = specs.input_specs(audio, "prefill_32k")["batch"]
    assert b["src_embeds"].shape == (32, 32768, 1024)
    assert b["tokens"].shape == (32, 32768 // 4)


def test_cell_supported_skip_rules():
    ok, _ = specs.cell_supported(get_config("mamba2-1.3b"),
                                 specs.SHAPES["long_500k"])
    assert ok
    ok, reason = specs.cell_supported(get_config("qwen1.5-110b"),
                                      specs.SHAPES["long_500k"])
    assert not ok and "sub-quadratic" in reason
    ok, _ = specs.cell_supported(get_config("gemma3-1b"),
                                 specs.SHAPES["long_500k"])
    assert ok  # 5:1 local:global qualifies


# ---------------------------------------------------------------------------
# batch / cache sharding maps (need a real mesh for NamedSharding)
# ---------------------------------------------------------------------------

def _real_mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


def test_batch_shardings_scalar_leaf_replicated():
    """Regression: 0-d leaves used to raise IndexError on shape[0]."""
    mesh = _real_mesh()
    cfg = get_config("qwen3-0.6b")
    tree = {"tokens": jax.ShapeDtypeStruct((8, 16), jnp.int32),
            "step": jax.ShapeDtypeStruct((), jnp.int32)}
    sh = batch_shardings(mesh, cfg, tree)
    assert sh["step"].spec == P()
    assert sh["tokens"].spec == P(("data",), None)


def test_cache_shardings_batch_position_rules():
    """Batch-dim matching is restricted to the layout's positions: leading
    for tail leaves (B, ...), second for stacked leaves (units, B, ...).  A
    dim that merely coincides with B elsewhere stays replicated (regression:
    the old fallback sharded ANY dim equal to batch)."""
    mesh = _real_mesh()
    cfg = get_config("qwen3-0.6b")
    B = 4
    f32 = lambda *s: jax.ShapeDtypeStruct(s, jnp.float32)
    cache = {
        "units": {"slot0": {"k": f32(2, B, 8, 2, 4),
                            "v": f32(2, B, 8, 2, 4),
                            "state": f32(2, B, 16)}},
        "tail": [{"conv": f32(B, 3, 16)}],
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
        "coincidence": f32(3, 5, B),
    }
    sh = cache_shardings(mesh, cfg, cache, batch=B)
    # KV tensors keep the dedicated (lead, batch, seq, kv, hd) rule
    assert sh["units"]["slot0"]["k"].spec[1] == ("data",)
    # stacked recurrent state: batch at dim 1
    assert sh["units"]["slot0"]["state"].spec == P(None, ("data",), None)
    # tail leaf: batch leading
    assert sh["tail"][0]["conv"].spec == P(("data",), None, None)
    # scalars and coincidental matches: replicated
    assert sh["pos"].spec == P()
    assert sh["coincidence"].spec == P()


# ---------------------------------------------------------------------------
# HLO collective parser
# ---------------------------------------------------------------------------

_FAKE_HLO = """
HloModule jit_step

%region_inner.1 (arg.1: (s32[], f32[16,64])) -> (s32[], f32[16,64]) {
  %ar = f32[16,64]{1,0} all-reduce(%x), replica_groups={}
  ROOT %t = (s32[], f32[16,64]) tuple(%i, %ar)
}

%region_outer.2 (arg.2: (s32[], f32[16,64])) -> (s32[], f32[16,64]) {
  %w = (s32[], f32[16,64]) while(%arg.2), condition=%cond.9, body=%region_inner.1
  %ag = bf16[32,64]{1,0} all-gather(%y), channel_id=1
  ROOT %t2 = (s32[], f32[16,64]) tuple(%i2, %gte)
}

%cond.9 (arg.3: (s32[], f32[16,64])) -> pred[] {
  ROOT %lt = pred[] compare(%a, %b), direction=LT
}

ENTRY %main.4 (p0: f32[16,64]) -> f32[16,64] {
  %w2 = (s32[], f32[16,64]) while(%init), condition=%cond.9, body=%region_outer.2
  %ar2 = f32[8,8]{1,0} all-reduce(%z), replica_groups={}
  ROOT %out = f32[16,64] get-tuple-element(%w2), index=1
}
"""


def test_collective_parser_nested_trips():
    out = collective_bytes(_FAKE_HLO, loop_trips=[3, 5])
    # inner AR: 16*64*4 bytes x (3 outer x 5 inner) = 61440
    # entry AR: 8*8*4 = 256 (x1)
    assert out["bytes_by_kind"]["all-reduce"] == 16 * 64 * 4 * 15 + 256
    # AG at depth 1: 32*64*2 x 3
    assert out["bytes_by_kind"]["all-gather"] == 32 * 64 * 2 * 3
    # f32 split: everything except the bf16 AG
    assert out["f32_bytes"] == 16 * 64 * 4 * 15 + 256
    corrected = out["bf16_wire_corrected_bytes"]
    assert corrected == out["total_bytes"] - 0.5 * out["f32_bytes"]


def test_result_bytes_tuples_and_scalars():
    assert _result_bytes("%x = f32[4,4]{1,0} add(%a, %b)") == 64
    assert _result_bytes(
        "%t = (f32[2,2]{1,0}, bf16[4]{0}) all-reduce(%a, %b)") == 16 + 8
    assert _result_bytes("ROOT %r = pred[] compare(%a, %b)") == 1
