"""PPO mechanics + policy-head properties (paper Sec. IV-B)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.env import paper_env
from repro.core.policies import (CategoricalPolicy, GaussianTanhPolicy,
                                 JointGaussianPolicy, map_cut)
from repro.core.ppo import PPO, PPOConfig, Trajectory


@given(st.floats(-50, 50), st.integers(1, 40))
@settings(max_examples=60, deadline=None)
def test_map_cut_range(y, num_layers):
    """Eq. (13) extension: cut always lands in the closed set {0..L}."""
    cut = int(map_cut(jnp.float32(y), jnp.int32(num_layers)))
    assert 0 <= cut <= num_layers


def test_map_cut_covers_extremes():
    L = 8
    assert int(map_cut(jnp.float32(-50.0), L)) == 0      # tanh -> -1
    assert int(map_cut(jnp.float32(50.0), L)) == L       # tanh -> +1 (clipped)
    # monotone in y
    ys = jnp.linspace(-4, 4, 64)
    cuts = np.asarray(map_cut(ys, L))
    assert np.all(np.diff(cuts) >= 0)


@pytest.fixture(scope="module")
def env():
    return paper_env()


@pytest.mark.parametrize("policy_cls", [GaussianTanhPolicy, CategoricalPolicy])
def test_policy_logp_consistency(env, policy_cls):
    """sample() logp == logp() recomputed for the same action."""
    pol = policy_cls(env.obs_dim, env.L)
    params = pol.init(jax.random.PRNGKey(0))
    obs = jax.random.normal(jax.random.PRNGKey(1), (env.obs_dim,))
    a, logp = pol.sample(params, obs, jax.random.PRNGKey(2))
    logp2 = pol.logp(params, obs, a)
    assert float(jnp.abs(logp - logp2)) < 1e-5


def test_joint_policy_constraint_mappings(env):
    pol = JointGaussianPolicy(env.obs_dim, env.L, env.cfg.f_max_ue,
                              env.cfg.f_max_es)
    params = pol.init(jax.random.PRNGKey(0))
    obs = jax.random.normal(jax.random.PRNGKey(1), (env.obs_dim,))
    y, _ = pol.sample(params, obs, jax.random.PRNGKey(2))
    cut, alpha, f_ue, f_es = pol.split(y)
    assert float(jnp.sum(alpha)) == pytest.approx(1.0, abs=1e-5)   # C4
    assert float(jnp.sum(f_es)) <= env.cfg.f_max_es * (1 + 1e-5)   # C3
    assert np.all(np.asarray(f_ue) <= env.cfg.f_max_ue * (1 + 1e-5))  # C6
    assert np.all((np.asarray(cut) >= 0) & (np.asarray(cut) <= np.asarray(env.L)))


def _fake_traj(agent, n=64, seed=0):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 5)
    obs = jax.random.normal(ks[0], (n, agent.obs_dim))
    acts, logps = jax.vmap(
        lambda o, k: agent.policy.sample(agent._params0["pi"], o, k)
    )(obs, jax.random.split(ks[1], n))
    rew = jax.random.normal(ks[2], (n,)) * 5.0
    vals = jax.random.normal(ks[3], (n,))
    return Trajectory(obs=obs, action=acts, logp=logps, reward=rew,
                      value=vals, last_value=jnp.zeros(()))


@pytest.mark.slow
def test_ppo_update_improves_surrogate(env):
    pol = GaussianTanhPolicy(env.obs_dim, env.L)
    agent = PPO(pol, env.obs_dim, PPOConfig(epochs=4))
    state = agent.init(jax.random.PRNGKey(0))
    agent._params0 = state.params
    traj = _fake_traj(agent)
    new_state, metrics = agent.update(state, traj)
    assert np.isfinite(float(metrics["loss"]))
    # ratio stays clip-bounded-ish after few epochs on the same batch
    assert float(metrics["ratio_max"]) < 3.0
    # parameters moved
    delta = max(float(jnp.max(jnp.abs(a - b))) for a, b in
                zip(jax.tree.leaves(new_state.params),
                    jax.tree.leaves(state.params)))
    assert delta > 0


def test_gae_paper_estimator_limit(env):
    """gae_lambda=1, bootstrap off: advantage == discounted-return - value
    (the paper's eq. 16/17 estimator)."""
    pol = GaussianTanhPolicy(env.obs_dim, env.L)
    cfg = PPOConfig(gamma=0.9, gae_lambda=1.0, bootstrap_last=False,
                    reward_scale=1.0)
    agent = PPO(pol, env.obs_dim, cfg)
    n = 16
    rew = jnp.arange(1.0, n + 1)
    val = jnp.zeros((n,)) + 0.5
    traj = Trajectory(obs=jnp.zeros((n, 4)), action=jnp.zeros((n, 5)),
                      logp=jnp.zeros((n,)), reward=rew, value=val,
                      last_value=jnp.zeros(()))
    adv, returns = agent.gae(traj)
    g = np.zeros(n)
    acc = 0.0
    for t in reversed(range(n)):
        acc = float(rew[t]) + 0.9 * acc
        g[t] = acc
    np.testing.assert_allclose(np.asarray(returns), g, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(adv), g - 0.5, rtol=1e-5)


@pytest.mark.slow
def test_lyapunov_v_tradeoff():
    """O(1/V) delay vs O(V) queues under the Oracle (benchmarks/ablation_v)."""
    from benchmarks.ablation_v import sweep
    rows = sweep(v_values=(1.0, 100.0), episodes=1, steps=150)
    assert rows[1]["delay_s"] <= rows[0]["delay_s"] + 1e-6
    assert rows[1]["q_energy_final"] > rows[0]["q_energy_final"]
