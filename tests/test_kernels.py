"""Per-kernel validation: Pallas (interpret=True on CPU) vs pure-jnp oracle,
swept over shapes and dtypes (deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.partition_sweep import partition_sweep_pallas
from repro.kernels.rglru_scan import rglru_scan_pallas
from repro.kernels.ssd_scan import ssd_scan_pallas

KEY = jax.random.PRNGKey(0)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind,window", [("causal", 0), ("local", 96),
                                         ("full", 0)])
@pytest.mark.parametrize("b,s,h,kv,hd", [(2, 256, 8, 4, 64), (1, 128, 4, 1, 128),
                                         (2, 192, 6, 2, 64)])
@pytest.mark.parametrize("dtype", [
    jnp.float32,
    pytest.param(jnp.bfloat16, marks=pytest.mark.slow)])
def test_flash_attention(kind, window, b, s, h, kv, hd, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, s, h, hd), dtype)
    k = jax.random.normal(ks[1], (b, s, kv, hd), dtype)
    v = jax.random.normal(ks[2], (b, s, kv, hd), dtype)
    got = flash_attention_pallas(q, k, v, kind=kind, window=window,
                                 q_block=64, k_block=64, interpret=True)
    want = ref.attention_ref(q, k, v, mask=ref.build_mask(kind, s, s, window))
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.slow
def test_flash_attention_block_shape_sweep():
    b, s, h, kv, hd = 1, 256, 4, 2, 64
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, s, h, hd))
    k = jax.random.normal(ks[1], (b, s, kv, hd))
    v = jax.random.normal(ks[2], (b, s, kv, hd))
    want = ref.attention_ref(q, k, v, mask=ref.build_mask("causal", s, s))
    for qb, kb in [(32, 64), (64, 32), (128, 128), (256, 64)]:
        got = flash_attention_pallas(q, k, v, kind="causal", q_block=qb,
                                     k_block=kb, interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("kind,window", [("causal", 0), ("local", 24),
                                         ("full", 0)])
def test_flash_attention_odd_lengths(kind, window):
    """Non-block-multiple sequence lengths no longer trip the "pad seq to
    block multiple" assert: the wrapper pads to the tile grid and slices."""
    b, s, h, kv, hd = 2, 100, 4, 2, 32
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, s, h, hd))
    k = jax.random.normal(ks[1], (b, s, kv, hd))
    v = jax.random.normal(ks[2], (b, s, kv, hd))
    got = flash_attention_pallas(q, k, v, kind=kind, window=window,
                                 q_block=64, k_block=64, interpret=True)
    want = ref.attention_ref(q, k, v, mask=ref.build_mask(kind, s, s, window))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_odd_cross_shape():
    """Cross-attention shapes (Sq != Sk, both odd) through the full kind."""
    b, h, kv, hd = 2, 4, 2, 32
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, 37, h, hd))
    k = jax.random.normal(ks[1], (b, 75, kv, hd))
    v = jax.random.normal(ks[2], (b, 75, kv, hd))
    got = flash_attention_pallas(q, k, v, kind="full", q_block=32, k_block=32,
                                 interpret=True)
    want = ref.attention_ref(q, k, v, mask=None)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("kind,window", [("causal", 0), ("local", 24),
                                         ("full", 0)])
@pytest.mark.parametrize("s", [64, 50])
def test_flash_attention_ragged_pad(kind, window, s):
    """Per-row left-pad counts fold into the in-kernel mask: every real
    (non-pad) query row matches the dense reference under the combined
    causal+pad mask, pad rows come out finite, and fully-padded key tiles
    are skipped (the s=64, pad=40 row covers whole-tile skips at Kb=16)."""
    b, h, kv, hd = 3, 4, 2, 32
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, s, h, hd))
    k = jax.random.normal(ks[1], (b, s, kv, hd))
    v = jax.random.normal(ks[2], (b, s, kv, hd))
    pad = jnp.asarray([0, 13, 40], jnp.int32)
    got = flash_attention_pallas(q, k, v, kind=kind, window=window,
                                 q_block=16, k_block=16, pad=pad,
                                 interpret=True)
    pad_mask = jnp.arange(s)[None, :] >= pad[:, None]
    mask = jnp.broadcast_to(pad_mask[:, None, :], (b, s, s))
    base = ref.build_mask(kind, s, s, window)
    if base is not None:
        mask = mask & base[None]
    want = ref.attention_ref(q, k, v, mask=mask)
    gn, wn = np.asarray(got), np.asarray(want)
    assert np.isfinite(gn).all()
    for i in range(b):
        np.testing.assert_allclose(gn[i, int(pad[i]):], wn[i, int(pad[i]):],
                                   rtol=2e-5, atol=2e-5, err_msg=f"row {i}")


def test_blocked_reference_matches_dense():
    """The XLA lowering path (attention_blocked) against the dense oracle."""
    b, s, h, kv, hd = 2, 320, 4, 2, 64
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, s, h, hd))
    k = jax.random.normal(ks[1], (b, s, kv, hd))
    v = jax.random.normal(ks[2], (b, s, kv, hd))
    for kind, window in [("causal", 0), ("local", 64), ("full", 0)]:
        got = ref.attention_blocked(q, k, v, kind=kind, window=window,
                                    q_block=64)
        want = ref.attention_ref(q, k, v,
                                 mask=ref.build_mask(kind, s, s, window))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5, err_msg=kind)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,s,h,kv,hd", [(2, 256, 8, 4, 64), (1, 512, 4, 1, 128),
                                         (3, 128, 2, 2, 64)])
@pytest.mark.parametrize("dtype", [
    jnp.float32,
    pytest.param(jnp.bfloat16, marks=pytest.mark.slow)])
def test_decode_attention(b, s, h, kv, hd, dtype):
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (b, 1, h, hd), dtype)
    k = jax.random.normal(ks[1], (b, s, kv, hd), dtype)
    v = jax.random.normal(ks[2], (b, s, kv, hd), dtype)
    # ragged validity (ring-buffer style)
    lengths = jax.random.randint(ks[3], (b,), 1, s)
    valid = jnp.arange(s)[None, :] < lengths[:, None]
    got = decode_attention_pallas(q, k, v, valid_mask=valid, k_block=64,
                                  interpret=True)
    want = ref.decode_attention_ref(q, k, v, valid_mask=valid)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("s,k_block", [(10, 4), (17, 8), (33, 16), (5, 8)])
def test_decode_attention_ragged_tail_block(s, k_block):
    """S need not be a k_block multiple: the wrapper pads the tail block
    with masked entries (paged-KV gathers hand the kernel arbitrary cache
    lengths).  exp(-1e30 - m) underflows to exactly 0, so the padding is
    semantics-free, not just small."""
    ks = jax.random.split(KEY, 4)
    b, h, kv, hd = 3, 4, 2, 32
    q = jax.random.normal(ks[0], (b, 1, h, hd), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, kv, hd), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, kv, hd), jnp.float32)
    lengths = jax.random.randint(ks[3], (b,), 1, s + 1)
    valid = jnp.arange(s)[None, :] < lengths[:, None]
    got = decode_attention_pallas(q, k, v, valid_mask=valid, k_block=k_block,
                                  interpret=True)
    want = ref.decode_attention_ref(q, k, v, valid_mask=valid)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# SSD scan (mamba2)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,s,h,p,g,n,chunk", [
    (2, 64, 4, 16, 2, 8, 16),
    (1, 128, 2, 32, 1, 16, 32),
    (2, 96, 3, 16, 3, 8, 24),
])
@pytest.mark.slow
def test_ssd_scan(b, s, h, p, g, n, chunk):
    ks = jax.random.split(KEY, 4)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    a_log = jnp.log(jnp.linspace(1.0, 8.0, h))
    bm = jax.random.normal(ks[2], (b, s, g, n)) * 0.5
    cm = jax.random.normal(ks[3], (b, s, g, n)) * 0.5
    d = jnp.linspace(0.5, 1.5, h)
    y_p, st_p = ssd_scan_pallas(x, dt, a_log, bm, cm, d, chunk=chunk,
                                interpret=True)
    y_r, st_r = ref.ssd_scan_ref(x, dt, a_log, bm, cm, d, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y_p), np.asarray(y_r),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(st_p), np.asarray(st_r),
                               rtol=1e-4, atol=1e-4)


def test_ssd_decode_step_consistency():
    """Sequential ssd_step_ref over a sequence == chunked scan."""
    b, s, h, p, g, n = 1, 32, 2, 8, 1, 4
    ks = jax.random.split(KEY, 4)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    a_log = jnp.log(jnp.linspace(1.0, 4.0, h))
    bm = jax.random.normal(ks[2], (b, s, g, n)) * 0.5
    cm = jax.random.normal(ks[3], (b, s, g, n)) * 0.5
    d = jnp.ones((h,))
    y_scan, final = ref.ssd_scan_ref(x, dt, a_log, bm, cm, d, chunk=8)
    state = jnp.zeros((b, h, n, p))
    ys = []
    for t in range(s):
        y_t, state = ref.ssd_step_ref(state, x[:, t], dt[:, t], a_log,
                                      bm[:, t], cm[:, t], d)
        ys.append(y_t)
    y_seq = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_seq), np.asarray(y_scan),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(state), np.asarray(final),
                               rtol=1e-4, atol=1e-4)


def test_ssd_scan_reset():
    """Reset-aware SSD: both dispatch arms must equal a sequential
    ssd_step_ref loop that zeroes the state entering each reset step, with
    resets placed mid-chunk, exactly on a chunk boundary, and per-row."""
    b, s, h, p, g, n, chunk = 2, 64, 3, 8, 1, 4, 16
    ks = jax.random.split(KEY, 4)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    a_log = jnp.log(jnp.linspace(1.0, 8.0, h))
    bm = jax.random.normal(ks[2], (b, s, g, n)) * 0.5
    cm = jax.random.normal(ks[3], (b, s, g, n)) * 0.5
    d = jnp.linspace(0.5, 1.5, h)
    reset = (jnp.zeros((b, s), bool)
             .at[0, 5].set(True).at[0, 16].set(True).at[1, 37].set(True))
    state = jnp.zeros((b, h, n, p))
    ys = []
    for t in range(s):
        st_in = jnp.where(reset[:, t][:, None, None, None], 0.0, state)
        y_t, state = ref.ssd_step_ref(st_in, x[:, t], dt[:, t], a_log,
                                      bm[:, t], cm[:, t], d)
        ys.append(y_t)
    y_want = np.asarray(jnp.stack(ys, axis=1))
    st_want = np.asarray(state)
    for name, (y, st) in {
        "ref": ref.ssd_scan_ref(x, dt, a_log, bm, cm, d, chunk=chunk,
                                reset=reset),
        "pallas": ssd_scan_pallas(x, dt, a_log, bm, cm, d, chunk=chunk,
                                  reset=reset, interpret=True),
    }.items():
        np.testing.assert_allclose(np.asarray(y), y_want, rtol=1e-4,
                                   atol=1e-4, err_msg=name)
        np.testing.assert_allclose(np.asarray(st), st_want, rtol=1e-4,
                                   atol=1e-4, err_msg=name)
    y_plain, _ = ref.ssd_scan_ref(x, dt, a_log, bm, cm, d, chunk=chunk)
    assert not np.allclose(y_want, np.asarray(y_plain)), \
        "reset must actually change the output"


def test_ssd_scan_odd_length_dispatch():
    """ops.ssd_scan pads non-chunk-multiple S with dt=0 steps: y matches a
    chunk=1 exact scan and the final state is untouched by the padding."""
    from repro.kernels import ops
    b, s, h, p, g, n = 1, 13, 2, 8, 1, 4
    ks = jax.random.split(KEY, 4)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    a_log = jnp.log(jnp.linspace(1.0, 4.0, h))
    bm = jax.random.normal(ks[2], (b, s, g, n)) * 0.5
    cm = jax.random.normal(ks[3], (b, s, g, n)) * 0.5
    d = jnp.ones((h,))
    y, st = ops.ssd_scan(x, dt, a_log, bm, cm, d, chunk=8)
    y_want, st_want = ref.ssd_scan_ref(x, dt, a_log, bm, cm, d, chunk=1)
    assert y.shape == x.shape
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_want),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(st), np.asarray(st_want),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# RG-LRU scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,s,r,chunk", [(2, 128, 64, 32), (1, 64, 128, 64),
                                         (3, 256, 32, 128)])
@pytest.mark.slow
def test_rglru_scan(b, s, r, chunk):
    ks = jax.random.split(KEY, 2)
    x = jax.random.normal(ks[0], (b, s, r)) * 0.3
    a = jax.nn.sigmoid(jax.random.normal(ks[1], (b, s, r)) + 2.0)
    got = rglru_scan_pallas(x, a, chunk=chunk, interpret=True)
    want = ref.rglru_scan_ref(x, a)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def _rglru_reset_oracle(x, a, reset):
    """Plain python recurrence with state zeroing at reset steps."""
    b, s, r = x.shape
    h = np.zeros((b, r))
    out = []
    for t in range(s):
        h = np.where(reset[:, t, None], 0.0, a[:, t] * h) + x[:, t]
        out.append(h.copy())
    return np.stack(out, 1)


def test_rglru_scan_reset():
    """Regression: ops.rglru_scan used to silently DROP a non-None reset on
    both dispatch arms.  A reset must (a) change the output and (b) match
    the sequential state-zeroing oracle on the reference AND the
    interpreted-Pallas path, including resets at chunk boundaries."""
    b, s, r = 2, 64, 16
    ks = jax.random.split(KEY, 3)
    x = jax.random.normal(ks[0], (b, s, r)) * 0.3
    a = jax.nn.sigmoid(jax.random.normal(ks[1], (b, s, r)) + 2.0)
    # mid-chunk, chunk-boundary (16 at chunk=16), and per-row distinct resets
    reset = (jnp.zeros((b, s), bool)
             .at[0, 5].set(True).at[0, 16].set(True).at[1, 37].set(True))
    want = _rglru_reset_oracle(np.asarray(x), np.asarray(a), np.asarray(reset))
    got_ref = ref.rglru_scan_ref(x, a, reset=reset)
    got_pal = rglru_scan_pallas(x, a, reset=reset, chunk=16, interpret=True)
    np.testing.assert_allclose(np.asarray(got_ref), want, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(got_pal), want, rtol=1e-4, atol=1e-4)
    plain = np.asarray(ref.rglru_scan_ref(x, a))
    assert not np.allclose(np.asarray(got_ref), plain), \
        "reset was ignored on the reference path"
    assert not np.allclose(np.asarray(got_pal), plain), \
        "reset was ignored on the Pallas path"


def test_rglru_scan_odd_length():
    """Non-chunk-multiple S on the Pallas path: the wrapper right-pads with
    (a=0, x=0) no-op steps and slices back (with and without reset)."""
    b, s, r = 2, 37, 16
    ks = jax.random.split(KEY, 2)
    x = jax.random.normal(ks[0], (b, s, r)) * 0.3
    a = jax.nn.sigmoid(jax.random.normal(ks[1], (b, s, r)) + 2.0)
    got = rglru_scan_pallas(x, a, chunk=16, interpret=True)
    want = ref.rglru_scan_ref(x, a)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
    reset = jnp.zeros((b, s), bool).at[:, 20].set(True)
    got = rglru_scan_pallas(x, a, reset=reset, chunk=16, interpret=True)
    want = ref.rglru_scan_ref(x, a, reset=reset)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_rglru_scan_sequential_oracle():
    """associative_scan oracle vs a plain python recurrence."""
    b, s, r = 1, 16, 8
    ks = jax.random.split(KEY, 2)
    x = np.asarray(jax.random.normal(ks[0], (b, s, r)))
    a = np.asarray(jax.nn.sigmoid(jax.random.normal(ks[1], (b, s, r))))
    h = np.zeros((b, r))
    expected = []
    for t in range(s):
        h = a[:, t] * h + x[:, t]
        expected.append(h.copy())
    expected = np.stack(expected, 1)
    got = ref.rglru_scan_ref(jnp.asarray(x), jnp.asarray(a))
    np.testing.assert_allclose(np.asarray(got), expected, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# partition sweep (the paper's kernel)
# ---------------------------------------------------------------------------

def _sweep_args(seed=3, q_off=5.0):
    from repro.core.env import paper_env
    env = paper_env()
    st = env.reset(jax.random.PRNGKey(seed))
    c = env.cfg
    scalars = dict(rho=c.rho, kappa=c.kappa, p_tx=c.p_tx, w_hz=c.w_hz,
                   n0=c.n0, f_max_ue=c.f_max_ue, f_max_es=c.f_max_es, v=c.v,
                   gamma_ue=c.gamma_ue, gamma_es=c.gamma_es,
                   stability_margin=c.stability_margin)
    b = env.batch
    f32 = lambda t: jnp.asarray(t, jnp.float32)
    return (f32(b.macs), f32(b.param_bytes), f32(b.act_bytes), f32(b.psi),
            env.L, st.lam, st.gain, st.queues.energy + q_off,
            st.queues.memory + q_off), scalars


@pytest.mark.slow
@pytest.mark.parametrize("seed,q_off", [(3, 5.0), (7, 0.0), (11, 120.0)])
def test_partition_sweep(seed, q_off):
    args, scalars = _sweep_args(seed, q_off)
    want = np.asarray(ref.partition_sweep_ref(*args, scalars))
    got = np.asarray(partition_sweep_pallas(*args, scalars, interpret=True))
    feasible = want < 1e29
    np.testing.assert_allclose(got[feasible], want[feasible],
                               rtol=1e-4, atol=1e-3)
    assert ((got > 1e29) == ~feasible).all()
    assert (np.argmin(got, 1) == np.argmin(want, 1)).all()


def test_partition_sweep_padding():
    """Non-multiple UE counts go through the padding path."""
    args, scalars = _sweep_args()
    got = partition_sweep_pallas(*args, scalars, ue_block=4, interpret=True)
    want = ref.partition_sweep_ref(*args, scalars)
    feasible = np.asarray(want) < 1e29
    np.testing.assert_allclose(np.asarray(got)[feasible],
                               np.asarray(want)[feasible], rtol=1e-4, atol=1e-3)
