"""Layer-profile correctness: CNN shape math (paper workloads) + LM profiles."""
import numpy as np
import pytest

from repro.configs.base import load_all
from repro.profiling.convnets import alexnet_profile, resnet18_profile
from repro.profiling.lmprofiles import lm_profile
from repro.profiling.profiles import ProfileBatch


def test_alexnet_totals_match_literature():
    p = alexnet_profile()
    assert p.num_layers == 8
    assert p.total_macs == pytest.approx(1.14e9, rel=0.05)       # ~1.1 GMACs
    assert p.total_param_bytes == pytest.approx(61e6 * 4, rel=0.05)  # 61M params


def test_resnet18_totals_match_literature():
    p = resnet18_profile()
    assert p.num_layers == 10
    assert p.total_macs == pytest.approx(1.82e9, rel=0.05)
    assert p.total_param_bytes == pytest.approx(11.7e6 * 4, rel=0.05)


def test_profile_batch_prefix_tables():
    pb = ProfileBatch([alexnet_profile(), resnet18_profile()])
    assert pb.Lmax == 10
    # prefix + suffix == total everywhere
    np.testing.assert_allclose(
        pb.prefix_macs + pb.suffix_macs,
        np.broadcast_to(pb.total_macs[:, None], pb.prefix_macs.shape),
        rtol=1e-12)
    # transmit size at the fully-local cut is zero (result return neglected)
    for i in range(pb.n):
        assert pb.psi[i, pb.L[i]] == 0.0
        assert pb.psi[i, 0] > 0.0     # full offload ships the raw input
    # local activation max is monotone nondecreasing in the cut
    assert np.all(np.diff(pb.prefix_act_max, axis=1) >= -1e-9)
    assert np.all(np.diff(pb.suffix_act_max, axis=1) <= 1e-9)


@pytest.mark.parametrize("name", sorted(load_all().keys()))
def test_lm_profiles_valid(name):
    cfg = load_all()[name]
    p = lm_profile(cfg, prompt_tokens=128)
    # layers = input + embed + stack (+ encoder) + head
    want = 2 + cfg.n_layers + cfg.enc_layers + 1
    assert p.num_layers == want - 1  # input is the pseudo-layer 0
    assert np.all(p.macs >= 0) and np.all(p.param_bytes >= 0)
    assert np.all(np.isfinite(p.act_bytes))
    # total params (bytes/2 = count) must reconcile with the roofline
    # parameter count -- the profile is a per-layer decomposition of it
    from repro.profiling.roofline import param_count
    total_params = p.param_bytes.sum() / 2
    assert total_params == pytest.approx(param_count(cfg), rel=1e-3)


def test_moe_profile_memory_dominated():
    """The MoE insight from DESIGN §4: an MoE layer's C(l) dwarfs its M(l)
    relative to dense layers -> memory queue drives the cut."""
    cfgs = load_all()
    moe = lm_profile(cfgs["llama4-maverick-400b-a17b"])
    # layer kinds alternate g,m after embed; compare per-layer param bytes
    dense_c = moe.param_bytes[2]      # first "g" layer
    moe_c = moe.param_bytes[3]        # first "m" layer
    assert moe_c > 50 * dense_c
    # executed MACs are comparable (top-1 + shared ~ 2 dense FFNs)
    assert moe.macs[3] < 5 * moe.macs[2]


def test_ssm_profile_constant_boundary():
    """SSM boundary transfer is constant in prompt length (DESIGN §4)."""
    cfgs = load_all()
    short = lm_profile(cfgs["mamba2-1.3b"], prompt_tokens=128)
    long = lm_profile(cfgs["mamba2-1.3b"], prompt_tokens=1024)
    # hidden part scales with tokens; state part is constant; attention archs
    # scale fully linearly:
    qshort = lm_profile(cfgs["qwen3-0.6b"], prompt_tokens=128)
    qlong = lm_profile(cfgs["qwen3-0.6b"], prompt_tokens=1024)
    ratio_ssm = long.act_bytes[5] / short.act_bytes[5]
    ratio_attn = qlong.act_bytes[5] / qshort.act_bytes[5]
    assert ratio_attn == pytest.approx(8.0, rel=1e-6)
    assert ratio_ssm < 8.0  # constant state component dampens the scaling


def test_partitioning_env_runs_on_lm_profiles():
    """End-to-end: LyMDO environment over LM-arch profiles (beyond-paper)."""
    import jax
    import jax.numpy as jnp
    from repro.core.env import MecConfig, MecEnv

    cfgs = load_all()
    profiles = [lm_profile(cfgs["qwen3-0.6b"]),
                lm_profile(cfgs["gemma3-1b"]),
                lm_profile(cfgs["mamba2-1.3b"])]
    env = MecEnv(profiles, MecConfig(f_max_ue=5e9, f_max_es=200e9),
                 e_budget=[0.5] * 3, c_budget=[2.0] * 3)
    st = env.reset(jax.random.PRNGKey(0))
    st2, res = env.step(st, jnp.array([5, 10, 20], jnp.int32))
    assert np.all(np.isfinite(np.asarray(res.delay)))
    assert float(res.reward) < 0
