"""Hypothesis-optional shim.

When ``hypothesis`` is installed, this module re-exports the real
``given`` / ``settings`` / ``strategies`` unchanged.  On a bare environment
(the container that runs tier-1 verify has no hypothesis) it substitutes a
small fixed-examples fallback: ``@given`` runs the test body over a
deterministic set of examples per strategy -- both interval endpoints, the
midpoint, then seeded-random draws -- so property tests still exercise the
edge cases they were written for, just without shrinking or example search.

Usage in test modules:

    from _hypothesis_compat import given, settings, st

Only the strategy surface the suite uses is implemented in the fallback:
``st.floats(lo, hi)``, ``st.integers(lo, hi)``, ``st.lists(elem,
min_size=, max_size=)``.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    import inspect
    import random
    import zlib

    # Fallback runs min(max_examples, _CAP) examples; fixed examples don't
    # shrink, so a modest cap keeps the bare-env suite fast.
    _CAP = 20

    class _Strategy:
        def sample(self, rng: random.Random, i: int):
            raise NotImplementedError

    class _Floats(_Strategy):
        def __init__(self, min_value, max_value):
            self.lo, self.hi = float(min_value), float(max_value)

        def sample(self, rng, i):
            if i == 0:
                return self.lo
            if i == 1:
                return self.hi
            if i == 2:
                return 0.5 * (self.lo + self.hi)
            return rng.uniform(self.lo, self.hi)

    class _Integers(_Strategy):
        def __init__(self, min_value, max_value):
            self.lo, self.hi = int(min_value), int(max_value)

        def sample(self, rng, i):
            if i == 0:
                return self.lo
            if i == 1:
                return self.hi
            return rng.randint(self.lo, self.hi)

    class _Lists(_Strategy):
        def __init__(self, elements, min_size=0, max_size=None):
            self.elements = elements
            self.min_size = int(min_size)
            self.max_size = self.min_size + 8 if max_size is None else int(max_size)

        def sample(self, rng, i):
            if i == 0:  # all-endpoint-low, shortest (e.g. all-zero demands)
                return [self.elements.sample(rng, 0)] * self.min_size
            if i == 1:  # all-endpoint-high, longest
                return [self.elements.sample(rng, 1)] * self.max_size
            size = rng.randint(self.min_size, self.max_size)
            return [self.elements.sample(rng, 3) for _ in range(size)]

    class _StModule:
        floats = staticmethod(_Floats)
        integers = staticmethod(_Integers)
        lists = staticmethod(_Lists)

    st = _StModule()

    def settings(**kw):
        """Records max_examples for the fallback; everything else ignored."""
        def deco(fn):
            fn._compat_settings = dict(kw)
            return fn
        return deco

    def given(*arg_strats, **kw_strats):
        def deco(fn):
            sig = inspect.signature(fn)
            names = list(sig.parameters)
            # hypothesis maps positional strategies to the RIGHTMOST params
            strat_map = dict(zip(names[len(names) - len(arg_strats):],
                                 arg_strats))
            strat_map.update(kw_strats)
            remaining = [p for p in sig.parameters.values()
                         if p.name not in strat_map]
            n = min(getattr(fn, "_compat_settings", {}).get(
                "max_examples", _CAP), _CAP)
            seed = zlib.crc32(fn.__qualname__.encode())

            def wrapper(*args, **kwargs):
                rng = random.Random(seed)
                for i in range(n):
                    drawn = {k: s.sample(rng, i)
                             for k, s in strat_map.items()}
                    fn(*args, **kwargs, **drawn)

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            # hide strategy-filled params so pytest doesn't treat them as
            # fixtures; keep real fixtures visible
            wrapper.__signature__ = sig.replace(parameters=remaining)
            return wrapper
        return deco
