"""MEC environment + Lyapunov machinery: invariants and paper semantics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import sweep
from repro.core.env import (LAM_FIXED, LAM_PEAK, MecConfig, paper_env)
from repro.core.lyapunov import VirtualQueues, lyapunov_function, reward, update_queues


@pytest.fixture(scope="module")
def env():
    return paper_env()


@pytest.fixture(scope="module")
def state(env):
    return env.reset(jax.random.PRNGKey(0))


def test_obs_shape(env, state):
    obs = env.observe(state)
    assert obs.shape == (4 * env.n_ue,)
    assert np.all(np.isfinite(np.array(obs)))


def test_c7_projection(env, state):
    """Projected cuts always keep the local queue stable (C7)."""
    hot = state._replace(lam=jnp.full((env.n_ue,), 2.5))
    for cut_req in range(env.num_cuts):
        cut = env.project_cut(jnp.full((env.n_ue,), cut_req, jnp.int32), hot.lam)
        d_ue = env.cfg.rho * np.take_along_axis(
            np.array(env.prefix_macs), np.array(cut)[:, None], 1)[:, 0]
        mu = np.where(d_ue > 0, env.cfg.f_max_ue / np.maximum(d_ue, 1), np.inf)
        assert np.all(mu > np.array(hot.lam)), f"unstable at requested {cut_req}"


def test_cut_clipped_to_profile_length(env, state):
    cut = env.project_cut(jnp.full((env.n_ue,), 99, jnp.int32), state.lam)
    assert np.all(np.array(cut) <= np.array(env.L))


def test_step_reward_is_negative_objective(env, state):
    _, res = env.step(state, jnp.full((env.n_ue,), 5, jnp.int32))
    obj = np.sum(np.array(res.q_energy) * np.array(res.energy)
                 + np.array(res.q_memory) * np.array(res.mem_cost)
                 + env.cfg.v * np.array(res.delay))
    assert float(res.reward) == pytest.approx(-obj, rel=1e-5)


def test_bandwidth_constraint(env, state):
    for c in [0, 3, 7]:
        _, res = env.step(state, jnp.full((env.n_ue,), c, jnp.int32))
        assert float(jnp.sum(res.alpha)) <= 1.0 + 1e-4   # C4
        assert float(jnp.sum(res.f_es)) <= env.cfg.f_max_es * (1 + 1e-5)  # C3
        assert np.all(np.array(res.f_ue) <= env.cfg.f_max_ue * (1 + 1e-5))  # C6


def test_queue_dynamics_match_eq_8_9(env, state):
    st2, res = env.step(state, jnp.full((env.n_ue,), 4, jnp.int32))
    c = env.cfg
    expect_q = np.maximum(np.array(res.q_energy)
                          + c.nu_e * (np.array(res.energy) - np.array(env.e_budget)), 0)
    expect_w = np.maximum(np.array(res.q_memory)
                          + c.nu_c * (np.array(res.mem_cost) - np.array(env.c_budget)), 0)
    assert np.allclose(np.array(st2.queues.energy), expect_q, rtol=1e-5)
    assert np.allclose(np.array(st2.queues.memory), expect_w, rtol=1e-5)


def test_step_is_deterministic(env, state):
    cut = jnp.arange(env.n_ue, dtype=jnp.int32)
    _, r1 = env.step(state, cut)
    _, r2 = env.step(state, cut)
    assert float(r1.reward) == float(r2.reward)


def test_vmap_over_states(env):
    keys = jax.random.split(jax.random.PRNGKey(1), 4)
    states = jax.vmap(env.reset)(keys)
    cuts = jnp.zeros((4, env.n_ue), jnp.int32)
    _, res = jax.vmap(env.step)(states, cuts)
    assert res.reward.shape == (4,)


def test_lam_modes():
    e_fixed = paper_env(MecConfig(lam_mode=LAM_FIXED))
    st = e_fixed.reset(jax.random.PRNGKey(0))
    assert np.allclose(np.array(st.lam), 2.5)
    e_peak = paper_env(MecConfig(lam_mode=LAM_PEAK, peak_boost=1.0))
    st = e_peak.reset(jax.random.PRNGKey(0))
    st = st._replace(t=jnp.int32(80))
    st2, _ = e_peak.step(st, jnp.zeros(5, jnp.int32))
    assert np.allclose(np.array(st2.lam), 3.5)  # inside the peak window


@given(q0=st.floats(0, 100), e=st.floats(0, 0.3), budget=st.floats(0.01, 0.1))
@settings(max_examples=40, deadline=None)
def test_queue_update_properties(q0, e, budget):
    q = VirtualQueues(jnp.asarray([q0], jnp.float32), jnp.asarray([q0], jnp.float32))
    q2 = update_queues(q, jnp.asarray([e]), jnp.asarray([e]),
                       jnp.asarray([budget]), jnp.asarray([budget]), 100.0, 10.0)
    assert float(q2.energy[0]) >= 0.0          # [.]^+ projection
    if e <= budget:
        assert float(q2.energy[0]) <= q0 + 1e-5   # under budget -> non-increasing
    else:
        assert float(q2.energy[0]) >= q0 - 1e-5   # over budget -> non-decreasing


def test_lyapunov_function_and_reward():
    q = VirtualQueues(jnp.asarray([3.0, 4.0]), jnp.asarray([0.0, 0.0]))
    assert float(lyapunov_function(q)) == pytest.approx(12.5)
    r = reward(q, jnp.asarray([0.1, 0.1]), jnp.asarray([0.0, 0.0]),
               jnp.asarray([1.0, 1.0]), v=10.0)
    assert float(r) == pytest.approx(-(0.3 + 0.4 + 20.0))


def test_oracle_sweep_feasible_and_at_least_as_good_as_fixed(env, state):
    """Oracle argmin respects feasibility and beats Local/Edge on its own
    decoupled objective estimate."""
    table = np.array(sweep.env_objective_table(env, state))
    cut = np.array(sweep.oracle_cut(env, state))
    assert np.all(cut <= np.array(env.L))
    for n in range(env.n_ue):
        assert table[n, cut[n]] <= table[n, 0] + 1e-3
        assert table[n, cut[n]] <= table[n, int(env.L[n])] + 1e-3


def test_long_run_queue_stability_under_oracle(env):
    """Property the Lyapunov machinery promises: virtual queues stay bounded
    under a drift-minimizing policy (500 slots, fixed heavy load)."""
    e = paper_env(MecConfig(lam_mode=LAM_FIXED))
    st = e.reset(jax.random.PRNGKey(2))

    def body(carry, _):
        s, = carry
        s2, res = e.step(s, sweep.oracle_cut(e, s))
        return (s2,), res.q_energy

    (_,), qs = jax.lax.scan(body, (st,), None, length=500)
    qs = np.array(qs)
    # queue in the last 100 slots should not exceed ~2x its slot-250 level
    assert qs[-100:].mean() < max(2.0 * qs[200:300].mean(), 50.0)
