"""Telemetry subsystem: metrics semantics, tracer round-trips, and the
delay-breakdown exactness contract (stage sums == E2E, both engines)."""
import json

import jax
import numpy as np
import pytest

from repro.configs.base import get_config, reduced
from repro.models import transformer
from repro.obs import Telemetry
from repro.obs.breakdown import (STAGES, DelayBreakdown, from_events,
                                 stage_summary)
from repro.obs.metrics import (Counter, Histogram, MetricsRegistry,
                               log_buckets)
from repro.obs.tracer import SpanTracer
from repro.serving.engine import Request, ServingEngine
from repro.traffic import TrafficRecorder


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(get_config("qwen3-0.6b"), n_layers=2)
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

def test_log_buckets():
    assert log_buckets(1.0, 8.0, base=2.0) == (1.0, 2.0, 4.0, 8.0)
    assert log_buckets(1.0, 9.0, base=2.0)[-1] >= 9.0


def test_counter_semantics():
    c = Counter("x", "")
    c.inc()
    c.inc(4)
    assert c.value == 5
    with pytest.raises(ValueError):
        c.inc(-1)


def test_histogram_bucket_boundaries():
    # value ON a boundary lands in that bucket (le is inclusive, like
    # Prometheus); above the top bound lands in +Inf
    h = Histogram("x", "", buckets=[1, 2, 4, 8])
    for v in (2.0, 2.5, 9.0, 0.5):
        h.observe(v)
    cum = dict(h.cumulative())
    assert cum["1"] == 1           # 0.5
    assert cum["2"] == 2           # + 2.0 exactly on the boundary
    assert cum["4"] == 3           # + 2.5
    assert cum["8"] == 3           # nothing in (4, 8]
    assert cum["+Inf"] == 4        # + 9.0
    assert h.count == 4
    assert h.sum == pytest.approx(14.0)


def test_registry_get_or_create_and_kind_mismatch():
    m = MetricsRegistry()
    a = m.counter("reqs_total", "", engine="x")
    assert m.counter("reqs_total", engine="x") is a
    assert m.counter("reqs_total", engine="y") is not a
    with pytest.raises(ValueError):
        m.gauge("reqs_total", engine="x")


def test_prometheus_exposition_format():
    m = MetricsRegistry()
    m.counter("reqs_total", "requests", engine="c").inc(3)
    m.gauge("depth", "queue depth").set(2)
    h = m.histogram("lat", "latency", buckets=[1, 2], engine="c")
    h.observe(1.5)
    text = m.to_prometheus()
    assert '# HELP reqs_total requests' in text
    assert '# TYPE reqs_total counter' in text
    assert 'reqs_total{engine="c"} 3' in text
    assert "depth 2" in text
    # bucket lines are cumulative with an +Inf terminal; _sum/_count ride
    assert 'lat_bucket{engine="c",le="1"} 0' in text
    assert 'lat_bucket{engine="c",le="2"} 1' in text
    assert 'lat_bucket{engine="c",le="+Inf"} 1' in text
    assert 'lat_count{engine="c"} 1' in text
    # HELP/TYPE emitted once per metric name
    assert text.count("# TYPE reqs_total") == 1


# ---------------------------------------------------------------------------
# span tracer
# ---------------------------------------------------------------------------

def test_tracer_chrome_roundtrip(tmp_path):
    tr = SpanTracer(capacity=16)
    tr.instant("submit", cat="lifecycle", rid=1)
    t0 = tr.now_us()
    tr.complete("decode_tick", t0, t0 + 100.0, live=2)
    tr.counter("queue_depth", 3)
    path = tmp_path / "trace.json"
    tr.export_chrome(path)
    doc = json.loads(path.read_text())
    assert doc["traceEvents"] == tr.to_chrome()["traceEvents"]
    assert SpanTracer.load_chrome(path) == doc["traceEvents"]
    phs = [e["ph"] for e in doc["traceEvents"]]
    assert phs == ["i", "X", "C"]
    x = doc["traceEvents"][1]
    assert x["dur"] == pytest.approx(100.0)
    assert x["args"]["live"] == 2


def test_tracer_jsonl_roundtrip(tmp_path):
    tr = SpanTracer(capacity=16)
    tr.instant("a")
    tr.instant("b", rid=7)
    path = tmp_path / "spans.jsonl"
    tr.export_jsonl(path)
    assert SpanTracer.load_jsonl(path) == tr.to_chrome()["traceEvents"]


def test_tracer_ring_buffer_bounded():
    tr = SpanTracer(capacity=4)
    for i in range(10):
        tr.instant(f"e{i}")
    evs = tr.events()
    assert len(evs) == 4
    assert evs[-1]["name"] == "e9"


def test_tracer_span_contextmanager():
    tr = SpanTracer(capacity=4)
    with tr.span("work", tag="x"):
        pass
    (ev,) = tr.events()
    assert ev["name"] == "work" and ev["ph"] == "X"
    assert ev["args"]["tag"] == "x"
    assert ev["dur"] >= 0


# ---------------------------------------------------------------------------
# delay breakdown algebra
# ---------------------------------------------------------------------------

def test_breakdown_no_preemption():
    b = from_events(1, submit=0, admits=[3], preempts=[], complete=7)
    assert (b.queue_wait, b.prefill, b.decode, b.preempted) == (2, 1, 4, 0)
    assert b.e2e == 7 and b.n_admits == 1 and b.n_preempts == 0


def test_breakdown_complete_at_admission():
    b = from_events(1, submit=0, admits=[1], preempts=[], complete=1)
    assert (b.queue_wait, b.prefill, b.decode, b.preempted) == (0, 1, 0, 0)
    assert b.e2e == 1


def test_breakdown_with_preemption_sums_exactly():
    # submit 0, admit 2, preempted 5, re-admit 6, complete 9:
    # wait = (2-0-1) + (6-5-1) = 1, prefill = 2 admissions,
    # preempted-recompute = 5-2 = 3, decode = 9-6 = 3 -> e2e 9
    b = from_events(1, submit=0, admits=[2, 6], preempts=[5], complete=9)
    assert (b.queue_wait, b.prefill, b.decode, b.preempted) == (1, 2, 3, 3)
    assert b.e2e == 9 == b.queue_wait + b.prefill + b.decode + b.preempted


def test_breakdown_chunked_prefill_done():
    # submit 0, admit 2, prefill done 5 (a 4-tick chunked prefill),
    # complete 9: wait 1, prefill 5-2+1=4, decode 9-5=4 -> e2e 9
    b = from_events(1, submit=0, admits=[2], preempts=[], complete=9,
                    prefill_dones=[5])
    assert (b.queue_wait, b.prefill, b.decode, b.preempted) == (1, 4, 4, 0)
    assert b.e2e == 9


def test_breakdown_preempted_mid_prefill():
    # window 1 (admit 2 .. preempt 4) has NO done tick: the whole residency
    # counts as prefill and contributes zero preempted ticks; window 2
    # (admit 6) finishes prefill at 8 and completes at 9
    b = from_events(1, submit=0, admits=[2, 6], preempts=[4], complete=9,
                    prefill_dones=[8])
    assert (b.queue_wait, b.prefill, b.decode, b.preempted) == (2, 6, 1, 0)
    assert b.e2e == 9


def test_breakdown_rejects_stray_prefill_done():
    with pytest.raises(ValueError, match="outside"):
        from_events(1, submit=0, admits=[2], preempts=[], complete=9,
                    prefill_dones=[1])


def test_breakdown_in_flight_and_invalid():
    assert from_events(1, submit=0, admits=[2], preempts=[],
                       complete=None) is None
    assert from_events(1, submit=None, admits=[], preempts=[],
                       complete=None) is None
    with pytest.raises(ValueError):
        from_events(1, submit=0, admits=[2, 4], preempts=[], complete=9)
    with pytest.raises(ValueError):
        from_events(1, submit=5, admits=[2], preempts=[], complete=9)


def test_stage_summary_empty_and_keys():
    assert stage_summary({})[STAGES[0]] == {"n": 0}
    b = DelayBreakdown(rid=1, queue_wait=1, prefill=1, decode=2,
                       preempted=0, n_admits=1, n_preempts=0)
    s = stage_summary({1: b})
    assert s["e2e"]["n"] == 1 and s["e2e"]["max"] == 4
    assert set(s) == set(STAGES)


# ---------------------------------------------------------------------------
# engine integration: stage sums == E2E, exactly, on both engines
# ---------------------------------------------------------------------------

def _drive(cfg, params, *, sync, **engine_kw):
    """Bursty replay with telemetry at stride 1; returns (eng, rec, tel)."""
    rng = np.random.default_rng(3)
    tel = Telemetry(sample_every=1)
    rec = TrafficRecorder()
    eng = ServingEngine(cfg, params, slots=2, s_max=32, recorder=rec,
                        sync_batching=sync, telemetry=tel, **engine_kw)
    sched = [(int(rng.integers(0, 6)), i,
              rng.integers(0, cfg.vocab, int(rng.integers(4, 11)))
              .astype(np.int32), int(rng.integers(2, 7)))
             for i in range(8)]
    sched.sort()
    i = 0
    for _ in range(500):
        while i < len(sched) and sched[i][0] <= eng.clock:
            t, rid, p, m = sched[i]
            eng.submit(Request(rid=rid, prompt=p, max_new=m))
            i += 1
        busy = eng.step()
        if i == len(sched) and not busy:
            break
    return eng, rec, tel


def _assert_exact(rec):
    bds = rec.delay_breakdowns()
    assert bds, "no completed requests"
    for rid, b in bds.items():
        ev = rec.events[rid]
        assert b.e2e == ev.complete - ev.submit, f"rid {rid}"
        assert (b.queue_wait + b.prefill + b.decode + b.preempted
                == b.e2e), f"rid {rid}"
        assert min(b.queue_wait, b.prefill, b.decode, b.preempted) >= 0
    return bds


@pytest.mark.parametrize("sync", [False, True], ids=["continuous", "sync"])
def test_stage_sums_equal_e2e(setup, sync):
    cfg, params = setup
    eng, rec, tel = _drive(cfg, params, sync=sync)
    bds = _assert_exact(rec)
    assert len(bds) == 8
    # counters agree with engine ground truth after drain
    snap = tel.metrics.snapshot()
    mode = "sync" if sync else "continuous"
    assert snap[f'serving_completed_total{{engine="{mode}"}}'] == 8
    assert snap[f'serving_submitted_total{{engine="{mode}"}}'] == 8
    assert (snap[f'serving_decode_steps_total{{engine="{mode}"}}']
            == eng.decode_steps)


def test_stage_sums_exact_under_preemption(setup):
    """The preemption-forcing fixture (pool smaller than the slots need):
    recompute overhead must surface in the ``preempted`` stage and the
    partition must still telescope exactly."""
    cfg, params = setup
    rng = np.random.default_rng(23)
    tel = Telemetry(sample_every=1)
    rec = TrafficRecorder()
    eng = ServingEngine(cfg, params, slots=3, s_max=32, kv_block=4,
                        kv_blocks=7, recorder=rec, telemetry=tel)
    for i, n in enumerate((9, 10, 12)):
        eng.submit(Request(rid=i, prompt=rng.integers(0, cfg.vocab, n)
                           .astype(np.int32), max_new=8))
    eng.run_until_idle()
    assert eng.preemptions > 0, "pool was sized to force preemption"
    bds = _assert_exact(rec)
    assert sum(b.n_preempts for b in bds.values()) == eng.preemptions
    assert any(b.preempted > 0 for b in bds.values())
    assert any(b.n_admits > 1 for b in bds.values())
    snap = tel.metrics.snapshot()
    assert (snap['serving_preemptions_total{engine="continuous"}']
            == eng.preemptions)


def test_stage_sums_exact_chunked(setup):
    """Chunked prefill spreads the prefill stage over several ticks; the
    stage partition must still telescope exactly, and streamed requests
    must surface multi-tick prefill WITHOUT fake preempted ticks."""
    cfg, params = setup
    rng = np.random.default_rng(29)
    tel = Telemetry(sample_every=1)
    rec = TrafficRecorder()
    eng = ServingEngine(cfg, params, slots=2, s_max=32, prefill_chunk=8,
                        recorder=rec, telemetry=tel)
    for i, n in enumerate((20, 9, 25, 6)):
        eng.submit(Request(rid=i, prompt=rng.integers(0, cfg.vocab, n)
                           .astype(np.int32), max_new=4))
    eng.run_until_idle()
    bds = _assert_exact(rec)
    assert len(bds) == 4
    assert any(b.prefill > 1 and b.n_preempts == 0 for b in bds.values()), \
        "streamed prompts must show multi-tick prefill"
    snap = tel.metrics.snapshot()
    assert snap['serving_prefill_chunks_total{engine="continuous"}'] > 0


def test_engine_gauges_and_spans(setup):
    cfg, params = setup
    eng, rec, tel = _drive(cfg, params, sync=False)
    snap = tel.metrics.snapshot()
    # pool fully drained: utilization back to 0, all blocks free
    assert snap['kvpool_blocks_free{engine="continuous"}'] \
        == eng.allocator.capacity
    assert snap['kvpool_utilization{engine="continuous"}'] == 0.0
    assert snap['serving_prefill_compiles{engine="continuous"}'] \
        == eng.prefill_compiles
    names = {e["name"] for e in tel.tracer.events()}
    assert {"submit", "admit", "complete", "prefill",
            "decode_tick"} <= names


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def test_cli_smoke(tmp_path, capsys):
    from repro.obs.__main__ import main
    prom = tmp_path / "metrics.prom"
    trace = tmp_path / "trace.json"
    rc = main(["--layers", "1", "--requests", "6", "--slots", "2",
               "--prom", str(prom), "--trace", str(trace)])
    assert rc == 0
    out = capsys.readouterr().out
    assert "exactness: stage sums == recorded E2E" in out and "OK" in out
    assert "# TYPE serving_e2e_ticks histogram" in prom.read_text()
    assert json.loads(trace.read_text())["traceEvents"]
